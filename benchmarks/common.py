"""Shared benchmark infra: container-scale datasets, cached indexes, the
95%-recall tuning ladder, and CSV emission (one row per measured config)."""
from __future__ import annotations

import os
import pickle
import sys
import time
import zlib

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (SYSTEM, SearchParams, WorkloadSpec,
                        assign_family_bitmaps, build_exclusion, build_graph,
                        build_scann, cycle_breakdown, engine_scale,
                        filtered_knn, generate_bitmaps, generate_families,
                        make_executor, measured_miss_penalty, quantize_store,
                        recall_at_k, stats_table_row)
from repro.data import DatasetSpec, make_dataset

CACHE_DIR = os.path.join(os.path.dirname(__file__), ".cache")
NUM_QUERIES = 16

# Container-scale stand-ins for the paper's four datasets (Table 2 shapes).
BENCH_DATASETS = {
    "sift10m": DatasetSpec("sift10m", 20_000, 128, "l2", clusters=64),
    "openai5m": DatasetSpec("openai5m", 8_000, 768, "ip", clusters=32),
    "cohere10m": DatasetSpec("cohere10m", 16_000, 256, "l2", clusters=48),
    "text2image10m": DatasetSpec("text2image10m", 16_000, 200, "l2",
                                 clusters=64, ood_queries=True),
}

GRAPH_METHODS = ("navix", "acorn", "sweeping", "iterative_scan")
ALL_METHODS = GRAPH_METHODS + ("scann",)
EF_LADDER = (64, 128, 256)
LEAVES_LADDER = (16, 32, 64)


def _cache(key: str, builder):
    os.makedirs(CACHE_DIR, exist_ok=True)
    path = os.path.join(CACHE_DIR, key + ".pkl")
    if os.path.exists(path):
        with open(path, "rb") as f:
            return pickle.load(f)
    val = builder()
    with open(path, "wb") as f:
        pickle.dump(jax.tree.map(np.asarray, val), f)
    return val


def _qtag(quant: str) -> str:
    """Cache-key suffix for the graph quant mode: artifacts built while a
    quantized tier is in play live in their own key space, so
    graph_quant="sq8" runs can never collide with cached f32 artifacts
    (nor vice versa) even if quantization ever perturbs a build input."""
    return "" if quant in (None, "none") else f"_{quant}"


def _method_quant(method: str) -> str:
    """Graph quant mode a benchmark method name implies."""
    return "sq8" if method.endswith("_sq8") else "none"


def get_dataset(name: str, quant: str = "none"):
    spec = BENCH_DATASETS[name]
    store, queries = make_dataset(spec, num_queries=NUM_QUERIES, seed=0)
    if quant == "sq8":
        store = quantize_store(store)
    return store, jnp.asarray(queries)


def get_graph(name: str, quant: str = "none"):
    from repro.core.hnsw import HNSWGraph
    store, _ = get_dataset(name, quant)

    def build():
        g = build_graph(store, m=16, ef_construction=64, seed=0)
        return (g.neighbors, g.node_level, g.entry_point)

    nb, lv, ep = _cache(f"graph_{name}{_qtag(quant)}", build)
    return HNSWGraph(neighbors=jnp.asarray(nb), node_level=jnp.asarray(lv),
                     entry_point=jnp.asarray(ep), m=16)


def get_scann(name: str, pca: bool = False, quant: str = "none"):
    from repro.core.scann import ScannIndex
    store, _ = get_dataset(name, quant)
    spec = BENCH_DATASETS[name]
    pca_dims = max(spec.dim // 8, 32) if (pca and spec.dim >= 256) else None

    def build():
        idx = build_scann(store, num_leaves=max(64, store.n // 128),
                          levels=2, pca_dims=pca_dims, seed=0)
        return jax.tree.map(np.asarray, idx)

    idx = _cache(f"scann_{name}_{'pca' if pca_dims else 'raw'}"
                 f"{_qtag(quant)}", build)
    return jax.tree.map(jnp.asarray, idx)


FAMILY_COUNT = 4


def _ftag(sel: float, num_families: int, seed: int) -> str:
    """Cache-key suffix for family-scoped artifacts (DESIGN.md §14):
    exclusion radii and partitioned graphs are built against a specific
    family catalog, so the catalog parameters ride the key the same way
    `_qtag` isolates quantized builds from f32 ones."""
    return f"_fam{num_families}_s{sel:g}_fs{seed}"


def get_families(name: str, sel: float, num_families: int = FAMILY_COUNT,
                 seed: int = 0) -> dict:
    """Cached clustered predicate families (tag -> packed bitmap)."""
    store, _ = get_dataset(name)
    return _cache(f"fams_{name}{_ftag(sel, num_families, seed)}",
                  lambda: generate_families(store, sel,
                                            num_families=num_families,
                                            seed=seed))


def get_family_bitmaps(name: str, sel: float,
                       num_families: int = FAMILY_COUNT, seed: int = 0,
                       quant: str = "none"):
    """((Q, W) bitmaps, (Q,) family assignment) for the bench queries —
    each query carries its family's bitmap verbatim (the exact-match
    contract of the selectivity-aware tiers)."""
    fams = get_families(name, sel, num_families, seed)
    _, queries = get_dataset(name, quant)
    bm, assign = assign_family_bitmaps(fams, int(queries.shape[0]),
                                       seed=seed + 1)
    return jnp.asarray(bm), assign


def get_exclusion(name: str, sel: float,
                  num_families: int = FAMILY_COUNT, seed: int = 0,
                  quant: str = "none"):
    """Cached FAVOR exclusion index (ladder + family-exact radii)."""
    store, _ = get_dataset(name, quant)
    fams = get_families(name, sel, num_families, seed)
    return _cache(f"excl_{name}{_ftag(sel, num_families, seed)}"
                  f"{_qtag(quant)}",
                  lambda: build_exclusion(store, families=fams))


def get_partitions(name: str, sel: float,
                   num_families: int = FAMILY_COUNT, seed: int = 0,
                   quant: str = "none"):
    """Cached JAG partitioned graph.  Only the per-family adjacency and
    row maps are pickled; the gathered sub-stores are rebuilt from the
    base store on load (`hnsw.gather_substore`) — same convention as
    `get_graph`, which caches (neighbors, level, entry) rather than the
    dataclass."""
    from repro.core.hnsw import (GraphPartition, HNSWGraph,
                                 PartitionedGraph, build_graph_partitioned,
                                 gather_substore)
    store, _ = get_dataset(name, quant)
    fams = get_families(name, sel, num_families, seed)

    def build():
        pg = build_graph_partitioned(store, fams, m=16, ef_construction=64,
                                     seed=0)
        return [(p.tag, np.asarray(p.bitmap), np.asarray(p.rows),
                 np.asarray(p.graph.neighbors),
                 np.asarray(p.graph.node_level),
                 np.asarray(p.graph.entry_point))
                for p in pg.partitions]

    raw = _cache(f"parts_{name}{_ftag(sel, num_families, seed)}"
                 f"{_qtag(quant)}", build)
    parts = tuple(GraphPartition(
        tag=tag, bitmap=bm, rows=rows, store=gather_substore(store, rows),
        graph=HNSWGraph(neighbors=jnp.asarray(nb),
                        node_level=jnp.asarray(lv),
                        entry_point=jnp.asarray(ep), m=16))
        for tag, bm, rows, nb, lv, ep in raw)
    return PartitionedGraph(partitions=parts, built_n=store.n)


def family_ground_truth(name: str, sel: float,
                        num_families: int = FAMILY_COUNT, seed: int = 0,
                        k: int = 10):
    store, queries = get_dataset(name)
    bm, _ = get_family_bitmaps(name, sel, num_families, seed)
    return filtered_knn(store, queries, bm, k)


def get_bitmaps(name: str, sel: float, corr: str, quant: str = "none"):
    store, queries = get_dataset(name, quant)

    # stable digest: hash() varies with PYTHONHASHSEED, which would make
    # cached bitmaps silently disagree with freshly generated ones; the
    # seed is part of the cache key so stale old-seed caches are ignored
    seed = zlib.crc32(repr((sel, corr)).encode()) % 9973

    def build():
        return np.asarray(generate_bitmaps(store, queries,
                                           WorkloadSpec(sel, corr),
                                           seed=seed))

    return jnp.asarray(_cache(f"bm_{name}_{sel}_{corr}_s{seed}"
                              f"{_qtag(quant)}", build))


def ground_truth(name: str, sel: float, corr: str, k: int = 10):
    store, queries = get_dataset(name)
    bm = get_bitmaps(name, sel, corr)
    return filtered_knn(store, queries, bm, k)


def mean_recall(ids, tid, k=10) -> float:
    return float(np.mean(np.asarray(
        jax.vmap(lambda f, t: recall_at_k(f, t, k))(ids, tid))))


def get_executor(name: str, method: str, use_pallas: bool = False,
                 storage=None, exclusion=None, partitions=None,
                 planner_candidates=None):
    """Executor-registry dispatch for a benchmark dataset: builds (cached)
    whichever components `method` needs and returns the executor.
    `storage` attaches a StorageEngine (build one with
    `get_storage_engine`) for measured page accounting.  The
    selectivity-aware tiers need their artifacts passed in (`exclusion=`
    from `get_exclusion`, `partitions=` from `get_partitions`) — they are
    family-catalog-scoped, so the registry can't build them from the
    method name alone.

    "scann_distributed" runs the mesh-sharded executor on this host's
    devices (leaves sharded, queries replicated) with per-query
    SearchStats riding the all-gather — so table6/fig10 can tabulate the
    distributed path next to the local ones.  No storage accounting
    (the collective pipeline carries counters, not page traces).

    "<strategy>_sq8" methods run the SQ8 quantized-traversal tier
    (DESIGN.md §9) — their dataset/graph artifacts use the quant-tagged
    cache keys."""
    quant = _method_quant(method)
    store, _ = get_dataset(name, quant)
    if method == "scann_distributed":
        # cached per dataset: re-sharding the index and dropping the
        # executor's jit cache at every grid point would re-compile the
        # collective program for identical params over and over
        ex = _DISTRIBUTED_EXECUTORS.get(name)
        if ex is None:
            from repro import compat
            from repro.core.distributed import (DistributedScannExecutor,
                                                shard_index)
            mesh = compat.make_mesh((jax.device_count(),), ("data",))
            sharded = shard_index(get_scann(name), store, mesh, "data")
            ex = _DISTRIBUTED_EXECUTORS[name] = \
                DistributedScannExecutor(sharded)
        return ex
    graph = index = None
    if method in ("scann", "scann_vmapped", "adaptive"):
        index = get_scann(name)
    if method not in ("scann", "scann_vmapped", "bruteforce"):
        graph = get_graph(name, quant)
    kw = {} if planner_candidates is None \
        else {"planner_candidates": tuple(planner_candidates)}
    return make_executor(method, store, graph=graph, index=index,
                         use_pallas=use_pallas, graph_m=16, storage=storage,
                         exclusion=exclusion, partitions=partitions, **kw)


_DISTRIBUTED_EXECUTORS: dict = {}


def get_sharded_executor(name: str, num_shards: int,
                         strategy: str = "sweeping", quant: str = "none",
                         storage=None):
    """Mesh-sharded graph executor over a benchmark dataset (DESIGN.md
    §13), cached per (dataset, shard count, strategy, quant) like
    `_DISTRIBUTED_EXECUTORS`: re-blocking the adjacency/heap/shadow tiers
    at every grid point would redo the host-side shard packing, and a
    fresh instance per point would thrash nothing (the collective jit
    cache is module-level) but waste the packing.

    `storage` attaches a ShardedStorageAccountant (build one with
    `get_sharded_storage`); storage-attached executors are NOT cached —
    the accountant carries mutable pool state owned by the caller — and
    at container bench scale the repacking they redo is trivial."""
    from repro.core.distributed import ShardedGraphExecutor
    key = (name, int(num_shards), strategy, quant)
    ex = _SHARDED_EXECUTORS.get(key)
    if ex is None:
        ex = _SHARDED_EXECUTORS[key] = ShardedGraphExecutor(
            get_graph(name, quant), get_dataset(name, quant)[0],
            num_shards, strategy=strategy, graph_quant=quant)
    if storage is None:
        return ex
    return ShardedGraphExecutor(ex.graph, ex.store, num_shards,
                                strategy=strategy, graph_quant=quant,
                                storage=storage)


def get_sharded_storage(name: str, num_shards: int, quant: str = "none",
                        capacity_frac: float = 1.0, policy: str = "lru"):
    """Per-shard StorageEngines (each holding capacity_frac / num_shards
    of the dataset's page space — the aggregate pool budget stays fixed
    as the shard count sweeps) wrapped in the accounting facade."""
    from repro.core.distributed import make_sharded_storage
    from repro.storage import make_storage_engine
    store, _ = get_dataset(name, quant)
    graph = get_graph(name, quant)
    engines = [make_storage_engine(
        store, graph=graph, capacity_frac=capacity_frac / num_shards,
        policy=policy) for _ in range(num_shards)]
    return make_sharded_storage(engines, store.n)


_SHARDED_EXECUTORS: dict = {}


def run_storage_measured(name: str, method: str, sel: float, params):
    """One cold-pool measured run at `params` (capacity = full page
    space): the shared protocol behind table6's measured-page columns and
    fig10's cold-miss penalty.  Returns the SearchResult (`.storage`
    carries the StorageStats)."""
    quant = _method_quant(method)
    store, queries = get_dataset(name, quant)
    bm = get_bitmaps(name, sel, "none", quant)
    eng = get_storage_engine(name, method, capacity_frac=1.0)
    return get_executor(name, method, storage=eng).search(queries, bm,
                                                          params)


def measured_graph_cycles(res, params, q_batch: int, dim: int) -> float:
    """Per-query SYSTEM cycles of a pooled graph run in the engine-true
    currency: quant-aware component costs from the measured counters
    (frontier `engine_scale`) plus the measured pool miss penalty — the
    same costing the planner predicts against (DESIGN.md §9).  Shared by
    bench_graph_quant and table4 so both report in ONE currency."""
    base = cycle_breakdown(
        res.stats, dim, SYSTEM,
        engine_scale(res.strategy, params, q_batch),
        graph_quant=params.graph_quant)["total"]
    return base + measured_miss_penalty(res.storage, q_batch, SYSTEM)


def heap_read_misses(res) -> int:
    """Physical page reads of the row-fetch segments (heap + qheap)."""
    return int(res.storage.misses.get("heap", 0)
               + res.storage.misses.get("qheap", 0))


def get_storage_engine(name: str, method: str = "adaptive", **kw):
    """StorageEngine over the dataset's page space, with the layouts the
    method needs (scann leaves / graph adjacency / heap + the always-laid
    qheap shadow segment)."""
    from repro.storage import make_storage_engine
    quant = _method_quant(method)
    store, _ = get_dataset(name, quant)
    index = get_scann(name) if method in ("scann", "scann_vmapped",
                                          "adaptive") else None
    graph = get_graph(name, quant) if method not in (
        "scann", "scann_vmapped", "bruteforce") else None
    return make_storage_engine(store, index=index, graph=graph, **kw)


def _ladder(method: str, k: int, tm: bool, page_accounting: str):
    """Param ladder per method (paper §5: climb until target recall)."""
    if method in ("scann", "scann_vmapped", "scann_distributed"):
        return [SearchParams(k=k, num_leaves_to_search=nl, reorder_factor=4,
                             scann_page_accounting=page_accounting)
                for nl in LEAVES_LADDER]
    if method in ("bruteforce",):
        return [SearchParams(k=k)]
    quant = _method_quant(method)
    strat = method[:-4] if quant == "sq8" else method
    ladder = []
    for ef in EF_LADDER:
        ef = max(ef, 2 * k)
        ladder.append(SearchParams(
            k=k, ef_search=ef, beam_width=max(512, 4 * ef), strategy=strat,
            max_hops=3000, translation_map=tm, graph_quant=quant,
            scann_page_accounting=page_accounting,
            batch_tuples=max(64, k * 8), max_rounds=16))
    return ladder


def run_method(name: str, method: str, sel: float, corr: str, k: int = 10,
               target_recall: float = 0.95, tm: bool = True,
               page_accounting: str = "batch"):
    """Tuning-ladder run (paper §5: highest QPS at 95% recall) through the
    executor registry.  Returns (recall, stats_row, wall_us_per_query,
    params_used).  `method` is any registered executor ("adaptive"
    included).

    `page_accounting` picks the ScaNN index-page counter semantics:
    "batch" amortizes each opened leaf over the query batch (the batched
    pipeline's real access pattern), "per_query" reproduces the paper's
    per-query accounting (Fig. 10/13)."""
    quant = _method_quant(method)
    store, queries = get_dataset(name, quant)
    bm = get_bitmaps(name, sel, corr, quant)
    _, tid = ground_truth(name, sel, corr, k)
    executor = get_executor(name, method)
    best = None
    if method == "adaptive":
        # the planner picks its own strategy; one balanced config
        ladder = [SearchParams(k=k, ef_search=128, beam_width=512,
                               max_hops=3000, translation_map=tm,
                               scann_page_accounting=page_accounting,
                               batch_tuples=max(64, k * 8), max_rounds=16)]
    else:
        ladder = _ladder(method, k, tm, page_accounting)
    for p in ladder:
        t0 = time.perf_counter()
        res = executor.search(queries, bm, p)
        jax.block_until_ready(res.ids)
        wall = (time.perf_counter() - t0) / queries.shape[0] * 1e6
        rec = mean_recall(res.ids, tid, k)
        best = (rec, stats_table_row(res.stats), wall, p)
        if rec >= target_recall:
            break
    return best


def emit(rows: list[dict], name: str) -> None:
    """Print benchmark rows as `name,us_per_call,derived` CSV lines."""
    for r in rows:
        derived = ";".join(f"{k}={v}" for k, v in r.items()
                           if k not in ("name", "us_per_call"))
        print(f"{r.get('name', name)},{r.get('us_per_call', 0):.1f},"
              f"{derived}")
