"""Mutability bench: the crash-consistent live-ingestion tier under load
(DESIGN.md §12).

Three measured axes on one WAL-backed `MutableIndex` (storage engine
attached, so every mutation / scan / checkpoint / compaction flows
through the buffer pool):

  delta-fill sweep   at each fill level of the LSM delta tier: merged
                     search latency + per-query delta-scan counters for
                     bruteforce and graph strategies, exact-recall check
                     against the rebuild oracle (must be 1.0 — the merge
                     is bit-identical, not approximate), the modeled
                     delta-scan tax, and the `should_compact` decision
  write path         cumulative write amplification (WAL bytes + 8 KiB
                     page write-backs over user payload bytes) after the
                     ingest stream, a checkpoint, and a compaction, plus
                     compaction's own page I/O and the post-compaction
                     recall delta vs a cold rebuild (must be within 0.02)
  crash matrix       kill-at-every-record-boundary recovery over a
                     scripted op stream: counts crash points and asserts
                     recovered searches are bit-identical to the durable
                     prefix reference (the tests' harness, summarized as
                     a benchmark gate)

Emits one JSON record to BENCH_mutability.json; `--tiny` (CI smoke)
writes the gitignored .tiny variant.

    PYTHONPATH=src python benchmarks/bench_mutability.py [--tiny]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import SearchParams, filtered_knn
from repro.core import costmodel
from repro.core.mutable import MutableIndex, rebuild_oracle_store
from repro.data import DatasetSpec, make_dataset
from repro.storage import wal as W

SELECTIVITY = 0.5


def _mk(tmpdir, tag, base, **kw):
    return MutableIndex(base, os.path.join(tmpdir, f"wal_{tag}"),
                        os.path.join(tmpdir, f"ck_{tag}"), **kw)


def _oracle_ids(index, bitmaps, queries, k):
    store, live = rebuild_oracle_store(index)
    bm = np.asarray(bitmaps, np.uint32)
    w = live.shape[0]
    if bm.shape[-1] < w:
        bm = np.concatenate([bm, np.zeros(
            bm.shape[:-1] + (w - bm.shape[-1],), np.uint32)], -1)
    return np.asarray(filtered_knn(store, jnp.asarray(queries),
                                   jnp.asarray(bm & live[None]), k)[1])


def _bitmaps(rng, nq, words, sel):
    bits = rng.rand(nq, words * 32) < sel
    return np.packbits(bits, axis=-1, bitorder="little").view(np.uint32)


def _timed_search(idx, queries, bm, params, method, reps=3):
    res = idx.search(queries, bm, params, method=method)   # warm compile
    jax.block_until_ready(res.dists)
    t0 = time.perf_counter()
    for _ in range(reps):
        res = idx.search(queries, bm, params, method=method)
        jax.block_until_ready(res.dists)
    dt = (time.perf_counter() - t0) / reps
    return res, dt * 1e3


def _fill_sweep(idx, rng, queries, fills, k):
    """Ingest to each fill level; measure merged-search behavior there."""
    nq = queries.shape[0]
    p_bf = SearchParams(k=k, strategy="bruteforce")
    p_gr = SearchParams(k=k, strategy="sweeping", ef_search=48,
                        beam_width=48, max_hops=200)
    out = []
    for fill in fills:
        target = int(round(fill * idx.delta_capacity))
        while idx.delta.count < target:
            m = min(64, target - idx.delta.count)
            idx.insert(rng.randn(m, idx.store.dim).astype(np.float32))
        if target:  # tombstone a slice of both base and delta rows
            dead = rng.choice(idx.base_n + idx.delta.count,
                              size=max(1, target // 16), replace=False)
            idx.delete(dead.astype(np.int64))
        bm = _bitmaps(rng, nq, idx.words(), SELECTIVITY)
        res, ms_bf = _timed_search(idx, jnp.asarray(queries),
                                   jnp.asarray(bm), p_bf, "bruteforce")
        oracle = _oracle_ids(idx, bm, queries, k)
        exact = bool(np.array_equal(oracle, np.asarray(res.ids)))
        _, ms_gr = _timed_search(idx, jnp.asarray(queries),
                                 jnp.asarray(bm), p_gr, "sweeping")
        n_delta = idx.delta.count
        wal_bytes = idx.wal.offset
        pw = idx.engine.pool.counters.page_writes
        out.append(dict(
            fill=round(n_delta / idx.delta_capacity, 3),
            n_delta=n_delta, tombstones=int(idx.tombstones.count),
            bruteforce_ms=round(ms_bf, 3), sweeping_ms=round(ms_gr, 3),
            oracle_exact=exact,
            delta_distance_comps=int(np.asarray(
                res.delta.stats.distance_comps).sum()),
            modeled_delta_cycles=round(costmodel.delta_scan_cycles(
                n_delta, idx.store.dim, SELECTIVITY, k=k), 1),
            should_compact=bool(costmodel.should_compact(
                n_delta, idx.delta_capacity, idx.base_n, idx.store.dim,
                SELECTIVITY)),
            write_amplification=round(costmodel.write_amplification(
                idx.user_bytes, pw, wal_bytes=wal_bytes), 3)))
        assert exact, f"merged search diverged from oracle at fill {fill}"
    return out


def _recall(ids, gt, k):
    return float(sum(len(set(gt[i]) & set(ids[i])) for i in range(len(gt)))
                 / (len(gt) * k))


def _compaction_phase(idx, rng, queries, tmpdir, k, build_kw):
    """Checkpoint + compact the swept index; compare recall against a
    cold rebuild over the same union."""
    nq = queries.shape[0]
    ck_writes = idx.engine.account_checkpoint(idx.delta.count)
    idx.checkpoint()
    union = np.concatenate([np.asarray(idx.store.vectors),
                            idx.delta.vectors[:idx.delta.count]])
    t0 = time.perf_counter()
    idx.compact()
    compact_s = time.perf_counter() - t0
    # the rebuilt engine's counters at this instant = compaction I/O
    cpw = idx.engine.pool.counters.page_writes
    cold = _mk(tmpdir, "cold", union, **build_kw)
    bm = np.full((nq, idx.words()), 0xFFFFFFFF, np.uint32)
    p = SearchParams(k=k, strategy="scann", num_leaves_to_search=4)
    gt = _oracle_ids(idx, bm, queries, k)
    got = np.asarray(idx.search(jnp.asarray(queries), jnp.asarray(bm), p,
                                method="scann").ids)
    ref = np.asarray(cold.search(
        jnp.asarray(queries),
        jnp.asarray(bm[:, :cold.words()]), p, method="scann").ids)
    r_live, r_cold = _recall(got, gt, k), _recall(ref, gt, k)
    cold.close()
    assert r_live >= r_cold - 0.02, (r_live, r_cold)
    return dict(compact_seconds=round(compact_s, 3),
                compaction_page_writes=int(cpw),
                checkpoint_page_writes=int(ck_writes["page_writes"]),
                recall_compacted=round(r_live, 4),
                recall_cold_rebuild=round(r_cold, 4),
                recall_delta=round(r_live - r_cold, 4))


def _crash_matrix(tmpdir, rng, dim, k):
    """Kill-at-every-boundary recovery sweep (bruteforce comparison)."""
    base = rng.randn(200, dim).astype(np.float32)
    queries = rng.randn(4, dim).astype(np.float32)
    kw = dict(delta_capacity=32, with_graph=False, with_scann=False)
    idx = _mk(tmpdir, "crash", base, **kw)
    bm = np.full((4, idx.words()), 0xFFFFFFFF, np.uint32)
    p = SearchParams(k=k, strategy="bruteforce")

    def snap(ix):
        r = ix.search(jnp.asarray(queries), jnp.asarray(bm), p)
        return np.asarray(r.ids).copy()

    snaps = {0: snap(idx)}
    for i in range(6):
        if i % 3 == 2:
            idx.delete(rng.randint(0, idx.base_n + idx.delta.count,
                                   size=3).astype(np.int64))
        else:
            idx.insert(rng.randn(4, dim).astype(np.float32))
        snaps[idx.applied_lsn] = snap(idx)
    recs = idx.wal.replay()
    points, prev = [(0, 0)], 0
    for r in recs:
        points.append((r.offset + r.length // 2, prev))
        points.append((r.end, r.lsn))
        prev = r.lsn
    identical = 0
    for i, (cut, lsn) in enumerate(points):
        crashed = idx.wal.crash_copy(
            os.path.join(tmpdir, f"crash_{i}"), at_bytes=cut)
        r_idx = MutableIndex.recover(
            base, crashed, os.path.join(tmpdir, f"ck_crash_{i}"), **kw)
        ok = (r_idx.applied_lsn == lsn
              and np.array_equal(snaps[lsn], snap(r_idx)))
        identical += int(ok)
        r_idx.close()
    idx.close()
    assert identical == len(points), f"{identical}/{len(points)}"
    return dict(crash_points=len(points), bit_identical=True)


def run(tiny: bool) -> dict:
    import tempfile
    if tiny:
        spec = DatasetSpec("mut-tiny", 2_000, 32, "l2", clusters=16)
        delta_cap, fills, nq, k = 128, (0.5, 1.0), 8, 10
    else:
        spec = DatasetSpec("mut-bench", 8_000, 48, "l2", clusters=32)
        delta_cap, fills, nq, k = 512, (0.25, 0.5, 0.75, 1.0), 16, 10
    store, queries = make_dataset(spec, num_queries=nq, seed=0)
    queries = np.asarray(queries, np.float32)
    rng = np.random.RandomState(1)
    tmpdir = tempfile.mkdtemp(prefix="bench_mut_")
    build_kw = dict(delta_capacity=delta_cap, num_leaves=16, graph_m=8,
                    ef_construction=48, seed=0, with_storage=True)
    idx = _mk(tmpdir, "main", np.asarray(store.vectors), **build_kw)

    out = {"bench": "mutability", "backend": jax.default_backend(),
           "tiny": tiny, "n": store.n, "dim": store.dim,
           "delta_capacity": delta_cap, "selectivity": SELECTIVITY,
           "queries": nq, "k": k,
           "wal_record_header_bytes": W.HEADER_BYTES}
    out["fill_sweep"] = _fill_sweep(idx, rng, queries, fills, k)
    print("# fill sweep:", json.dumps(out["fill_sweep"]))
    out["compaction"] = _compaction_phase(idx, rng, queries, tmpdir, k,
                                          build_kw)
    print("# compaction:", json.dumps(out["compaction"]))
    idx.close()
    out["crash_matrix"] = _crash_matrix(tmpdir, rng, store.dim, k)
    print("# crash matrix:", json.dumps(out["crash_matrix"]))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="small fresh-built dataset (CI smoke)")
    args = ap.parse_args()
    result = run(tiny=args.tiny)
    line = json.dumps(result)
    # --tiny (CI smoke) must not clobber the tracked full record
    name = "BENCH_mutability.tiny.json" if args.tiny \
        else "BENCH_mutability.json"
    path = os.path.join(os.path.dirname(__file__), "..", name)
    with open(path, "w") as f:
        f.write(line + "\n")
    print(line)
    assert result["crash_matrix"]["bit_identical"]
    assert all(r["oracle_exact"] for r in result["fill_sweep"])
    assert abs(result["compaction"]["recall_delta"]) <= 1.0  # reported
    assert result["compaction"]["recall_compacted"] >= \
        result["compaction"]["recall_cold_rebuild"] - 0.02


if __name__ == "__main__":
    main()
