"""Paper Table 2: dataset characteristics — LID, LRC, and the measured
distance-vs-filter relative cost for each benchmark dataset."""
from __future__ import annotations

from benchmarks.common import BENCH_DATASETS, emit, get_dataset
from repro.core.hardness import dist_filter_relative_cost, lid_mle, lrc


def run() -> list[dict]:
    rows = []
    for name, spec in BENCH_DATASETS.items():
        store, queries = get_dataset(name)
        rows.append({
            "name": f"table2/{name}",
            "us_per_call": 0.0,
            "n": store.n, "dims": spec.dim, "metric": spec.metric,
            "lid": round(lid_mle(store, queries), 2),
            "lrc": round(lrc(store, queries), 3),
            "dist_filt_rel_cost": round(
                dist_filter_relative_cost(spec.dim), 2),
        })
    return rows


if __name__ == "__main__":
    emit(run(), "table2")
