"""Robustness bench: the graceful-degradation ladder vs fail-stop serving
under seeded storage faults and per-request deadlines (DESIGN.md §10).

One request queue is served twice through identical fault schedules
(same FaultPlan seed, fresh pools — the determinism contract makes the
comparison exact):

  fail-stop — the primary executor only (a one-rung ladder).  A request
              whose batch hits a failed page read, or whose deadline
              budget the primary plan exhausts, stays flagged: that is
              the pre-ladder serving behavior, and every flagged request
              counts against goodput.
  ladder    — the full ladder (f32 graph -> sq8-no-rerank -> scann-lite
              -> partial scan): faulted requests retry once, then
              descend rung by rung until one serves them cleanly or the
              last rung's flagged partial answer is returned.

Goodput counts a request good when it was admitted, returned at least
one valid id, and carries no unresolved fault.  Modeled per-request
latency walks the priced rungs (`price_ladder`): each request pays every
rung it visited (plus the primary again when retried), plus its share of
the fault penalty (`costmodel.fault_penalty`) — so the ladder's goodput
win is priced honestly against the extra rungs it runs.  Deadlines are a
mix of generous, tight (between the admission floor and the primary's
price — the band where degradation pays), and impossible (below the
admission floor — rejected at admission in BOTH modes).

Emits one JSON record to BENCH_robustness.json; `--tiny` (CI smoke)
writes the gitignored .tiny variant.

    PYTHONPATH=src python benchmarks/bench_robustness.py [--tiny]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (SearchParams, WorkloadSpec, build_graph,
                        build_scann, generate_bitmaps, quantize_store)
from repro.core import costmodel
from repro.core.executor import (BruteForceExecutor, GraphExecutor,
                                 ScannExecutor)
from repro.data import DatasetSpec, make_dataset
from repro.serving.rag import (LadderRung, RetrievalAugmentedServer,
                               admission_floor, price_ladder)
from repro.storage import FaultPlan, make_storage_engine

SELECTIVITY = 0.3
# per-ATTEMPT failure 0.1 with 2 retries -> ~1e-3 failed reads per miss:
# a few queries per batch see an unrecoverable read, most retry clean
FAULTS = dict(read_fail_prob=0.1, max_retries=2, latency_spike_prob=0.05,
              pressure_prob=0.002, pressure_len=512, pressure_frac=0.25)


def _setup(tiny: bool):
    if tiny:
        spec = DatasetSpec("robust-tiny", 4_000, 32, "l2", clusters=16)
        nreq, batch, leaves = 32, 8, 16
    else:
        spec = DatasetSpec("robust-bench", 20_000, 64, "l2", clusters=64)
        nreq, batch, leaves = 128, 16, 32
    store, queries = make_dataset(spec, num_queries=nreq, seed=0)
    store = quantize_store(store)
    graph = build_graph(store, m=8, ef_construction=48, seed=0)
    index = build_scann(store, num_leaves=leaves, levels=1, seed=0)
    return store, jnp.asarray(queries), graph, index, nreq, batch


def _components(store, graph, index, seed: int):
    """Executors sharing one faulted storage engine (one pool, one
    deterministic fault schedule)."""
    eng = make_storage_engine(store, index=index, graph=graph,
                              capacity_frac=0.25,
                              faults=FaultPlan(seed=seed, **FAULTS))
    gex = GraphExecutor(graph, store, strategy="sweeping", storage=eng,
                        graph_quant="none")
    sq8 = GraphExecutor(graph, store, strategy="sweeping", storage=eng,
                        graph_quant="sq8")
    sc = ScannExecutor(index, store, storage=eng)
    bf = BruteForceExecutor(store, storage=eng)
    return eng, gex, sq8, sc, bf


def _full_ladder(gex, sq8, sc, bf, store):
    from repro.core.types import heap_pages_per_vector
    ppv = heap_pages_per_vector(store.dim)

    def _partial(p):
        if p.page_budget > 0 or p.deadline_cycles > 0:
            return p
        return dataclasses.replace(
            p, page_budget=max(p.k, store.n // 10) * ppv)

    return [
        LadderRung("primary", gex),
        LadderRung("sq8_norerank", sq8,
                   lambda p: dataclasses.replace(p, sq8_rerank=False)),
        LadderRung("scann_lite", sc,
                   lambda p: dataclasses.replace(
                       p, num_leaves_to_search=max(
                           1, p.num_leaves_to_search // 2))),
        LadderRung("partial_scan", bf, _partial),
    ]


def _server(store, executor, params, qtable):
    # pure-retrieval server: prompts are (B, 1) indices into a
    # precomputed query table, no LM in the loop
    docs = np.zeros((store.n, 4), np.int32)
    return RetrievalAugmentedServer(
        bundle=None, params=None, executor=executor,
        search_params=params, doc_tokens=docs, chunk_len=4,
        embed_fn=lambda p, tok: qtable[tok[:, 0]])


def _deadlines(nreq: int, floor: float, primary_price: float,
               seed: int) -> np.ndarray:
    """70% generous (10x primary), 20% tight (the degradation band),
    10% impossible (below the admission floor)."""
    rng = np.random.RandomState(seed)
    n_imp = max(1, nreq // 10)
    n_tight = max(1, nreq // 5)
    kinds = np.array([2] * n_imp + [1] * n_tight
                     + [0] * (nreq - n_imp - n_tight))
    rng.shuffle(kinds)
    d = np.full(nreq, 10.0 * primary_price)
    d[kinds == 1] = 0.5 * (floor + max(primary_price, floor * 1.5))
    d[kinds == 2] = 0.5 * floor
    return d


def _latency(info, prices: dict, default_price: float,
             fault_share: float) -> np.ndarray:
    """Modeled per-request cycles: every rung walked is paid (retry pays
    the primary twice), plus the request's share of the fault penalty."""
    names = info["ladder"]
    level = info["rung_level"]
    lat = np.zeros(len(level))
    for i, lv in enumerate(level):
        if lv < 0:
            continue                         # rejected: never dispatched
        walked = [prices.get(names[j], default_price)
                  for j in range(lv + 1)]
        if info["retried"][i]:
            walked.append(prices.get(names[0], default_price))
        lat[i] = sum(walked) + fault_share
    return lat


def _serve(srv, queries, bm, params, ladder, deadlines, batch,
           prices, floor):
    import types as _t
    prompts = np.arange(queries.shape[0], dtype=np.int32)[:, None]
    res, info = srv.serve_queue(prompts, bm, batch_size=batch,
                                policy="fifo", deadlines=deadlines,
                                ladder=ladder)
    adm = info["admitted"]
    served_ok = (np.asarray(res.ids) >= 0).any(axis=1)
    good = adm & served_ok & ~info["faulted"]
    pen = costmodel.fault_penalty(
        _t.SimpleNamespace(retries=info.get("pool_retries", 0),
                           spikes=info.get("pool_spikes", 0)),
        batch_q=max(int(adm.sum()), 1))
    lat = _latency(info, prices, floor, pen)
    lat_adm = lat[adm] if adm.any() else np.zeros(1)
    rungs, counts = np.unique(info["rung"].astype(str),
                              return_counts=True)
    return {
        "goodput": round(float(good.mean()), 4),
        "p99_cycles": round(float(np.percentile(lat_adm, 99)), 1),
        "mean_cycles": round(float(lat_adm.mean()), 1),
        "flagged_degraded_frac": round(float(info["degraded"].mean()), 4),
        "rejected_frac": round(float((~adm).mean()), 4),
        "retried_frac": round(float(info["retried"].mean()), 4),
        "faulted_final_frac": round(float(info["faulted"].mean()), 4),
        "budget_exhausted_frac": round(
            float(info["budget_exhausted"].mean()), 4),
        "rung_hist": {r: int(c) for r, c in zip(rungs, counts)},
        "pool_failed_reads": int(info.get("pool_failed_reads", 0)),
        "pool_retries": int(info.get("pool_retries", 0)),
        "pool_spikes": int(info.get("pool_spikes", 0)),
    }


def run(tiny: bool = False) -> dict:
    store, queries, graph, index, nreq, batch = _setup(tiny)
    params = SearchParams(k=10, ef_search=64, beam_width=128,
                          max_hops=300 if tiny else 1000,
                          num_leaves_to_search=8,
                          graph_exec_mode="frontier",
                          scann_page_accounting="per_query")
    bm = generate_bitmaps(store, queries,
                          WorkloadSpec(SELECTIVITY, "none"), seed=1)
    floor = admission_floor(store, params)
    fault_seed = 11

    # price the rungs once (fault-free components, prediction only)
    _, gex, sq8, sc, bf = _components(store, graph, index, seed=0)
    ladder = _full_ladder(gex, sq8, sc, bf, store)
    prices = price_ladder(ladder, params, SELECTIVITY, batch_q=batch)
    deadlines = _deadlines(nreq, floor, prices["primary"], seed=2)

    out = {"bench": "robustness", "backend": jax.default_backend(),
           "tiny": tiny, "n": store.n, "dim": store.dim,
           "requests": nreq, "batch": batch, "selectivity": SELECTIVITY,
           "fault_plan": dict(seed=fault_seed, **FAULTS),
           "admission_floor": round(floor, 1),
           "rung_prices": {k: round(v, 1) for k, v in prices.items()}}

    # fail-stop: primary rung only, same fault schedule
    _, gex, _, _, _ = _components(store, graph, index, seed=fault_seed)
    srv = _server(store, gex, params, queries)
    out["failstop"] = _serve(srv, queries, bm, params,
                             [LadderRung("primary", gex)], deadlines,
                             batch, prices, floor)
    print("# failstop:", json.dumps(out["failstop"]))

    # ladder: fresh engine, identical fault schedule (same seed)
    _, gex, sq8, sc, bf = _components(store, graph, index,
                                      seed=fault_seed)
    ladder = _full_ladder(gex, sq8, sc, bf, store)
    srv = _server(store, gex, params, queries)
    out["ladder"] = _serve(srv, queries, bm, params, ladder, deadlines,
                           batch, prices, floor)
    print("# ladder:  ", json.dumps(out["ladder"]))

    out["goodput_gain"] = round(
        out["ladder"]["goodput"] - out["failstop"]["goodput"], 4)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="small fresh-built dataset (CI smoke)")
    args = ap.parse_args()
    result = run(tiny=args.tiny)
    line = json.dumps(result)
    # --tiny (CI smoke) must not clobber the tracked full record
    name = "BENCH_robustness.tiny.json" if args.tiny \
        else "BENCH_robustness.json"
    path = os.path.join(os.path.dirname(__file__), "..", name)
    with open(path, "w") as f:
        f.write(line + "\n")
    print(line)
    lg, fg = result["ladder"]["goodput"], result["failstop"]["goodput"]
    assert lg >= fg, f"ladder goodput {lg} below fail-stop {fg}"
    if fg < 1.0:
        assert lg > fg, (
            f"fail-stop dropped requests (goodput {fg}) but the ladder "
            f"recovered none (goodput {lg})")


if __name__ == "__main__":
    main()
