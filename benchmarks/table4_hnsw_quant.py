"""Paper Table 4: does quantization help HNSW in a page-based engine?

Two answers, side by side (DESIGN.md §9):

  modeled  — the paper's own back-of-envelope, as this repo always ran
             it: halve the vector bytes (halfvec), rescale the heap-page
             counter, leave the dominant neighbor-page traffic untouched
             → speedup ≈ 1×.
  measured — the SQ8 quantized-traversal tier executed on our storage
             engine: the SAME sweeping search runs under
             graph_quant ∈ {none, sq8} with a cold full-capacity buffer
             pool; costs come from the measured counters (quant-aware
             materialization + exact-rerank surcharge) plus the pool's
             measured miss penalty.  The physical heap-read cut (dense
             qheap pages) is real, but index/neighbor-page traffic and
             page-hit costs don't move — so the end-to-end speedup stays
             far below the 4× size reduction, which is Table 4's point,
             now demonstrated rather than assumed.

    PYTHONPATH=src python benchmarks/table4_hnsw_quant.py
"""
from __future__ import annotations

import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import time

import jax
import jax.numpy as jnp

from benchmarks.common import (_method_quant, emit, get_bitmaps,
                               get_dataset, get_executor, heap_read_misses,
                               measured_graph_cycles, run_method,
                               run_storage_measured)
from repro.core import (SYSTEM, SearchParams, SearchStats, modeled_qps)


def _timed_measured(ds, method, sel, params, q_batch):
    """One cold-pool measured run (page accounting) + a SEARCH-ONLY wall
    time from an accounting-off executor (first call warms the jit cache,
    second is timed) — so the emitted us_per_call is comparable to the
    modeled row's run_method wall, not dominated by engine construction
    and host-side trace replay."""
    res = run_storage_measured(ds, method, sel, params)
    quant = _method_quant(method)
    _, queries = get_dataset(ds, quant)
    bm = get_bitmaps(ds, sel, "none", quant)
    ex = get_executor(ds, method)
    jax.block_until_ready(ex.search(queries, bm, params).ids)     # warm
    t0 = time.perf_counter()
    jax.block_until_ready(ex.search(queries, bm, params).ids)
    wall_us = (time.perf_counter() - t0) / q_batch * 1e6
    return res, wall_us


def run(ds="openai5m", sel=0.2) -> list[dict]:
    store, queries = get_dataset(ds)

    # ---- modeled (the legacy analytic halfvec rescale) ----
    rec, srow, wall, _ = run_method(ds, "sweeping", sel, "none")
    z = lambda v: jnp.asarray(round(v), jnp.int32)
    full = SearchStats(z(srow["distance_comps"]), z(srow["filter_checks"]),
                       z(srow["hops"]), z(srow["page_accesses_index"]),
                       z(srow["page_accesses_heap"]),
                       z(srow["tmap_lookups"]), z(srow["reorder_rows"]))
    # halfvec: heap pages per vector halve; index (neighbor) pages unchanged
    half = dataclasses.replace(
        full, page_accesses_heap=z(srow["page_accesses_heap"] / 2))
    q_full = modeled_qps(full, store.dim, SYSTEM)
    q_half = modeled_qps(half, store.dim // 2, SYSTEM)

    # ---- measured (SQ8 tier on the storage engine, cold pool) ----
    p = SearchParams(k=10, ef_search=128, beam_width=512,
                     strategy="sweeping", max_hops=3000)
    q_batch = queries.shape[0]
    p_sq8 = dataclasses.replace(p, graph_quant="sq8")
    res_f32, _ = _timed_measured(ds, "sweeping", sel, p, q_batch)
    res_sq8, wall_sq8 = _timed_measured(ds, "sweeping_sq8", sel, p_sq8,
                                        q_batch)
    cyc_f32 = measured_graph_cycles(res_f32, p, q_batch, store.dim)
    cyc_sq8 = measured_graph_cycles(res_sq8, p_sq8, q_batch, store.dim)
    return [{
        "name": f"table4/{ds}/halfvec-modeled/sel={sel}",
        "us_per_call": wall,
        "qps_speedup": round(q_half / q_full, 2),
        "index_size_reduction": 2.0,
        "note": "speedup~1x: neighbor-page traffic dominates (paper T4)",
    }, {
        "name": f"table4/{ds}/sq8-measured/sel={sel}",
        "us_per_call": wall_sq8,
        "qps_speedup": round(cyc_f32 / cyc_sq8, 2),
        "index_size_reduction": 4.0,
        "heap_read_reduction": round(
            heap_read_misses(res_f32) / max(heap_read_misses(res_sq8), 1),
            2),
        "note": "measured on the storage engine: physical heap reads drop, "
                "index pages + hit costs don't -> speedup << 4x",
    }]


if __name__ == "__main__":
    emit(run(), "table4")
