"""Paper Table 4: HNSW quantization (halfvec) does NOT improve QPS in a
page-based engine — modeled via the cost model: halving vector bytes
halves heap-page traffic but leaves the dominant neighbor-page traffic
untouched (paper §5 'Quantization')."""
from __future__ import annotations

import dataclasses
import jax.numpy as jnp

from benchmarks.common import emit, get_dataset, run_method
from repro.core import SYSTEM, SearchStats, modeled_qps


def run(ds="openai5m", sel=0.2) -> list[dict]:
    store, _ = get_dataset(ds)
    rec, srow, wall, _ = run_method(ds, "sweeping", sel, "none")
    z = lambda v: jnp.asarray(round(v), jnp.int32)
    full = SearchStats(z(srow["distance_comps"]), z(srow["filter_checks"]),
                       z(srow["hops"]), z(srow["page_accesses_index"]),
                       z(srow["page_accesses_heap"]),
                       z(srow["tmap_lookups"]), z(srow["reorder_rows"]))
    # halfvec: heap pages per vector halve; index (neighbor) pages unchanged
    half = dataclasses.replace(
        full, page_accesses_heap=z(srow["page_accesses_heap"] / 2))
    q_full = modeled_qps(full, store.dim, SYSTEM)
    q_half = modeled_qps(half, store.dim // 2, SYSTEM)
    return [{
        "name": f"table4/{ds}/halfvec/sel={sel}",
        "us_per_call": wall,
        "qps_speedup": round(q_half / q_full, 2),
        "index_size_reduction": 2.0,
        "note": "speedup~1x: neighbor-page traffic dominates (paper T4)",
    }]


if __name__ == "__main__":
    emit(run(), "table4")
