"""Benchmark: mesh-sharded graph + storage tiers (DESIGN.md §13) — the
paper's single-node engine scaled out by partitioning adjacency, heap
pages, and the SQ8 shadow store by row range across shards.

The shard-count sweep runs the SAME sweeping search at S ∈ {1, 2, 4, 8}
lockstep shards (beam_exchange_interval=1) over a streamed 1M×768 ip
dataset (the paper's openai operating point rescaled to the ≥1M-row
floor) and records, per point:

  * recall@10 against exact filtered kNN — lockstep results are
    bit-identical across shard counts by construction (owner-masked
    pmin/pmax reductions SELECT the owner's value), asserted on ids;
  * aggregated modeled QPS from `costmodel.sharded_cycle_summary`: the
    single-device cycle total parallelizes 1/S, plus the beam-exchange
    collective-roofline term (bytes × collective_per_byte) and the
    straggler term (max−mean of per-shard measured miss penalties);
  * beam-exchange collective bytes per query (lockstep: 8 B per scored
    candidate moved ~2·(S−1)/S times by the ring all-reduce);
  * per-shard buffer-pool hit rates from the ShardedStorageAccountant
    replay (each shard pools capacity_frac/S — the aggregate page budget
    stays fixed as S sweeps).

A drift-mode sweep (S=4, E ∈ {1, 2, 4, 8}) records how recall decays and
collective bytes shrink as supersteps between top-ef beam exchanges grow.

Acceptance (asserted on the full grid): ≥2.5× aggregated modeled QPS at
8 shards vs 1 at equal recall (equal is free — the ids are identical).

`--tiny` (CI smoke, tools/smoke.sh) runs the openai5m container dataset
through the cached `get_sharded_executor` path and writes the gitignored
.tiny variant.  `--xl` is the paper-scale 5M×768 point: the serving
store is built f32-free (`make_dataset_streamed(..., f32=False)` — only
the int8 shadow is materialized; traversal is SQ8-only with
sq8_rerank=False), but the graph build and the exact ground truth still
materialize f32 rows transiently, so it is NOT run in CI —
document-and-run-by-hand only.

    PYTHONPATH=src python benchmarks/bench_sharding.py [--tiny|--xl]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import (_cache, get_bitmaps, get_dataset, get_graph,
                               get_sharded_executor, get_sharded_storage,
                               mean_recall)
from repro.core import (SearchParams, WorkloadSpec, filtered_knn,
                        generate_bitmaps)
from repro.core import costmodel
from repro.core.distributed import (ShardedGraphExecutor,
                                    make_sharded_storage)
from repro.core.hnsw import HNSWGraph, build_graph_blocked
from repro.data import DatasetSpec, make_dataset_streamed
from repro.storage import make_storage_engine

SHARDS = (1, 2, 4, 8)
E_SWEEP = (1, 2, 4, 8)           # drift-mode exchange intervals at S=4
QPS_TARGET = 2.5                 # ≥2.5× modeled QPS at 8 shards vs 1
CAPACITY_FRAC = 0.5              # aggregate pool budget over the sweep

FULL_SPEC = DatasetSpec("openai1m", 1_000_000, 768, "ip", clusters=64)
XL_SPEC = DatasetSpec("openai5m_xl", 5_000_000, 768, "ip", clusters=64)


def _full_setup(spec: DatasetSpec, num_queries: int, f32: bool = True):
    """Streamed dataset + blocked-built graph (graph disk-cached)."""
    t0 = time.perf_counter()
    store, queries = make_dataset_streamed(spec, num_queries=num_queries,
                                           seed=0, f32=f32)
    print(f"# dataset {spec.name} {spec.n}x{spec.dim} streamed in "
          f"{time.perf_counter() - t0:.0f}s (f32={f32})")

    def build():
        src = store
        if not f32:
            # the builder needs real f32 rows; materialize them once,
            # transiently (this is why --xl never runs in CI)
            src, _ = make_dataset_streamed(spec, num_queries=1, seed=0,
                                           f32=True, quantize=False)
        g = build_graph_blocked(src, m=16, ef_construction=32, seed=0)
        return (np.asarray(g.neighbors), np.asarray(g.node_level),
                np.asarray(g.entry_point))

    t0 = time.perf_counter()
    nb, lv, ep = _cache(f"graph_{spec.name}_stream_m16", build)
    print(f"# graph ready in {time.perf_counter() - t0:.0f}s")
    graph = HNSWGraph(neighbors=jnp.asarray(nb), node_level=jnp.asarray(lv),
                      entry_point=jnp.asarray(ep), m=16)
    return store, jnp.asarray(queries), graph


def _shadow_ground_truth(store, queries, bm, k: int):
    """Exact filtered kNN over the DEQUANTIZED shadow, blockwise — the
    f32-free (--xl) ground truth, never materializing the (n, d) f32."""
    q = np.asarray(queries, np.float32)
    scale = np.asarray(store.q_scale)
    mean = np.asarray(store.q_mean)
    qv = np.asarray(store.q_vectors)
    words = np.asarray(bm)
    n, block = store.n, 262_144
    best_d = np.full((q.shape[0], k), np.inf, np.float32)
    best_i = np.full((q.shape[0], k), -1, np.int64)
    for lo in range(0, n, block):
        hi = min(lo + block, n)
        x = qv[lo:hi].astype(np.float32) * scale + mean
        if store.metric == "ip":
            d = -(q @ x.T)
        else:
            d = ((x * x).sum(-1)[None, :] - 2.0 * (q @ x.T)
                 + (q * q).sum(-1)[:, None])
        ids = np.arange(lo, hi)
        passing = (words[:, ids // 32] >> (ids % 32)) & 1
        d = np.where(passing.astype(bool), d, np.inf)
        cat_d = np.concatenate([best_d, d], axis=1)
        cat_i = np.concatenate(
            [best_i, np.broadcast_to(ids, d.shape)], axis=1)
        top = np.argpartition(cat_d, k - 1, axis=1)[:, :k]
        best_d = np.take_along_axis(cat_d, top, axis=1)
        best_i = np.take_along_axis(cat_i, top, axis=1)
    order = np.argsort(best_d, axis=1)
    return jnp.asarray(np.take_along_axis(best_i, order, axis=1))


def _point(ex, accountant, queries, bm, tid, p, num_shards):
    """One cold-pool measured grid point → bench record."""
    if accountant is not None:
        accountant.reset_cold()
    t0 = time.perf_counter()
    res = ex.search(queries, bm, p)
    jax.block_until_ready(res.ids)
    wall = time.perf_counter() - t0
    q = int(queries.shape[0])
    per_shard = accountant.last_per_shard if accountant is not None else None
    summary = costmodel.sharded_cycle_summary(
        res.stats, p, ex.store.dim, num_shards,
        graph_quant=p.graph_quant, per_shard_storage=per_shard, batch_q=q)
    rec = {"shards": num_shards, "E": p.beam_exchange_interval,
           "recall": round(mean_recall(res.ids, tid, p.k), 4),
           "wall_ms": round(wall * 1e3, 1),
           "hops": round(float(np.asarray(res.stats.hops).mean()), 1),
           "distance_comps": round(
               float(np.asarray(res.stats.distance_comps).mean()), 1),
           "collective_bytes_per_query": round(
               summary["collective_bytes"], 1),
           "mcycles_per_query": round(
               summary["cycles_per_query"] / 1e6, 3),
           "modeled_qps": round(summary["modeled_qps"], 1),
           "straggler_mcycles": round(
               summary["straggler_cycles"] / 1e6, 4)}
    if per_shard is not None:
        rec["pool_hit_rates"] = [round(s.hit_rate, 4) for s in per_shard]
        rec["pool_miss_pages"] = [int(s.miss_total) for s in per_shard]
    return rec, np.asarray(res.ids)


def _shard_sweep(store, graph, queries, bm, tid, p, shards,
                 capacity_frac, f32=True) -> list[dict]:
    """Lockstep shard-count sweep; asserts bit-identical ids across S."""
    rows, ref_ids = [], None
    for S in shards:
        engines = [make_storage_engine(store, graph=graph,
                                       capacity_frac=capacity_frac / S)
                   for _ in range(S)]
        acct = make_sharded_storage(engines, store.n)
        ex = ShardedGraphExecutor(graph, store, S, strategy=p.strategy,
                                  graph_quant=p.graph_quant, storage=acct,
                                  f32=f32)
        rec, ids = _point(ex, acct, queries, bm, tid, p, S)
        if ref_ids is None:
            ref_ids = ids
        else:
            assert np.array_equal(ids, ref_ids), (
                f"S={S} ids diverge from S={shards[0]} — lockstep "
                "shard-count invariance broken")
        rec["ids_match_base"] = True
        rows.append(rec)
        print(f"# S={S}: recall {rec['recall']}, modeled QPS "
              f"{rec['modeled_qps']}, collective "
              f"{rec['collective_bytes_per_query']} B/q, pool hit rates "
              f"{rec.get('pool_hit_rates')}")
        del ex, acct, engines
    return rows


def _drift_sweep(store, graph, queries, bm, tid, p, f32=True) -> list[dict]:
    """E-sweep at S=4: recall decay vs collective-byte savings."""
    S = 4
    ex = ShardedGraphExecutor(graph, store, S, strategy=p.strategy,
                              graph_quant=p.graph_quant, f32=f32)
    rows = []
    for E in E_SWEEP:
        pe = dataclasses.replace(p, beam_exchange_interval=E)
        rec, _ = _point(ex, None, queries, bm, tid, pe, S)
        rows.append(rec)
        print(f"# drift S={S} E={E}: recall {rec['recall']}, collective "
              f"{rec['collective_bytes_per_query']} B/q")
    del ex
    return rows


def run(tiny: bool = False, xl: bool = False) -> dict:
    if tiny:
        name = "openai5m"
        store, queries = get_dataset(name)
        graph = get_graph(name)
        bm = get_bitmaps(name, 0.1, "none")
        _, tid = filtered_knn(store, queries, bm, 10)
        p = SearchParams(k=10, ef_search=64, beam_width=256,
                         strategy="sweeping", max_hops=500)
        rows, ref_ids = [], None
        for S in (1, 2, 4):
            # the cached-executor satellite path: storage-free instance
            # is cached per (dataset, S, strategy, quant), the pooled one
            # rides a fresh accountant
            get_sharded_executor(name, S)
            acct = get_sharded_storage(name, S,
                                       capacity_frac=CAPACITY_FRAC)
            ex = get_sharded_executor(name, S, storage=acct)
            rec, ids = _point(ex, acct, queries, bm, tid, p, S)
            if ref_ids is None:
                ref_ids = ids
            else:
                assert np.array_equal(ids, ref_ids), \
                    f"S={S} ids diverge (lockstep invariance)"
            rec["ids_match_base"] = True
            rows.append(rec)
            print(f"# S={S}: recall {rec['recall']}, modeled QPS "
                  f"{rec['modeled_qps']}")
        drift = _drift_sweep(store, graph, queries, bm, tid, p)
    else:
        spec = XL_SPEC if xl else FULL_SPEC
        f32 = not xl            # --xl: f32-free store, SQ8-only traversal
        store, queries, graph = _full_setup(spec, num_queries=8, f32=f32)
        bm = generate_bitmaps(store, queries, WorkloadSpec(0.2, "none"),
                              seed=11)
        if f32:
            _, tid = filtered_knn(store, queries, bm, 10)
        else:
            tid = _shadow_ground_truth(store, queries, bm, 10)
        p = SearchParams(k=10, ef_search=128, beam_width=512,
                         strategy="sweeping", max_hops=1500,
                         graph_quant="sq8", sq8_rerank=f32)
        rows = _shard_sweep(store, graph, queries, bm, tid, p, SHARDS,
                            CAPACITY_FRAC, f32=f32)
        drift = _drift_sweep(store, graph, queries, bm, tid, p, f32=f32)

    qps = {r["shards"]: r["modeled_qps"] for r in rows}
    gain = qps[max(qps)] / qps[min(qps)]
    out = {"bench": "sharding", "backend": jax.default_backend(),
           "tiny": tiny, "xl": xl, "n": store.n, "dim": store.dim,
           "params": {"k": p.k, "ef_search": p.ef_search,
                      "beam_width": p.beam_width, "max_hops": p.max_hops,
                      "strategy": p.strategy, "graph_quant": p.graph_quant,
                      "sel": 0.1 if tiny else 0.2},
           "capacity_frac": CAPACITY_FRAC,
           "shard_sweep": rows, "drift_sweep": drift,
           "max_shards": max(qps),
           "qps_gain_at_max_shards": round(gain, 2),
           "all_ids_match_base": all(r["ids_match_base"] for r in rows)}
    print(f"# modeled QPS gain at S={out['max_shards']}: "
          f"{out['qps_gain_at_max_shards']}x (target {QPS_TARGET}x on "
          "the full grid)")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="container dataset, 3 shard points (CI smoke)")
    ap.add_argument("--xl", action="store_true",
                    help="5M x 768 f32-free point (not run in CI; the "
                         "graph build transiently materializes f32 rows)")
    args = ap.parse_args()
    result = run(tiny=args.tiny, xl=args.xl)
    line = json.dumps(result)
    # --tiny (CI smoke) must not clobber the tracked full-grid record
    name = "BENCH_sharding.tiny.json" if args.tiny else (
        "BENCH_sharding.xl.json" if args.xl else "BENCH_sharding.json")
    path = os.path.join(os.path.dirname(__file__), "..", name)
    with open(path, "w") as f:
        f.write(line + "\n")
    print(line)
    assert result["all_ids_match_base"], "shard-count invariance broken"
    if not result["tiny"]:
        assert result["qps_gain_at_max_shards"] >= QPS_TARGET, (
            f"modeled QPS gain at {result['max_shards']} shards "
            f"{result['qps_gain_at_max_shards']}x < {QPS_TARGET}x")


if __name__ == "__main__":
    main()
