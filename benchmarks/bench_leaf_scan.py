"""Microbenchmark: query-batched vs vmapped single-query fused leaf scan.

Measures the tentpole claim directly: the batched kernel fetches each int8
leaf tile once per *batch* and scores it with one MXU (Q, d) × (d, C)
contraction, while `jax.vmap` of the single-query kernel re-streams every
tile per query.  Emits one JSON line (and writes it to
`BENCH_leaf_scan.json`) so the perf trajectory is tracked run-over-run.
"""
from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.types import pack_bool_bitmap
from repro.kernels import ops

U, C, D = 12, 128, 128          # leaves × rows/leaf × dims (container scale)
BATCHES = (1, 8, 16, 32)
REPS = 5


def _time(fn, *args) -> float:
    jax.block_until_ready(fn(*args))          # compile + warm cache
    t0 = time.perf_counter()
    for _ in range(REPS):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / REPS * 1e6


def run(use_pallas: bool = True) -> dict:
    rng = np.random.RandomState(0)
    n_rows = U * C
    tiles = jnp.asarray(rng.randint(-127, 128, (U, C, D)).astype(np.int8))
    rowids = jnp.asarray(rng.permutation(n_rows).reshape(U, C).astype(
        np.int32))
    scale = jnp.asarray(np.abs(rng.randn(D)).astype(np.float32) * 0.02)
    mean = jnp.asarray(rng.randn(D).astype(np.float32) * 0.05)
    x = tiles.astype(jnp.float32) * scale + mean
    norms = jnp.sum(x * x, axis=-1)

    vmapped = jax.jit(jax.vmap(lambda q, bm: ops.leaf_scan(
        q, tiles, rowids, scale, mean, bm, "l2", use_pallas)))
    batched = jax.jit(lambda qs, bms: ops.leaf_scan_batched(
        qs, tiles, rowids, scale, mean, bms, norms, "l2", use_pallas))

    out = {"bench": "leaf_scan", "backend": jax.default_backend(),
           "use_pallas": use_pallas, "U": U, "C": C, "D": D, "points": []}
    for q in BATCHES:
        qs = jnp.asarray(rng.randn(q, D).astype(np.float32))
        bms = jnp.stack([pack_bool_bitmap(rng.rand(n_rows) < 0.5)
                         for _ in range(q)])
        t_v = _time(vmapped, qs, bms)
        t_b = _time(batched, qs, bms)
        out["points"].append({"batch": q, "vmapped_us": round(t_v, 1),
                              "batched_us": round(t_b, 1),
                              "speedup": round(t_v / t_b, 2)})
    return out


def main() -> None:
    result = run(use_pallas=True)
    line = json.dumps(result)
    path = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_leaf_scan.json")
    with open(path, "w") as f:
        f.write(line + "\n")
    print(line)


if __name__ == "__main__":
    main()
