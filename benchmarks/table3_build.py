"""Paper Table 3: index build time and size, HNSW vs ScaNN per dataset."""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import BENCH_DATASETS, emit, get_dataset
from repro.core import build_graph, build_scann


def _tree_bytes(tree) -> int:
    return sum(np.asarray(x).nbytes for x in jax.tree.leaves(tree))


def run(datasets=("sift10m", "openai5m")) -> list[dict]:
    rows = []
    for name in datasets:
        store, _ = get_dataset(name)
        t0 = time.perf_counter()
        g = build_graph(store, m=16, ef_construction=64, seed=0)
        t_h = time.perf_counter() - t0
        t0 = time.perf_counter()
        s = build_scann(store, num_leaves=max(64, store.n // 128), levels=2,
                        seed=0)
        t_s = time.perf_counter() - t0
        rows.append({"name": f"table3/{name}/hnsw",
                     "us_per_call": t_h * 1e6,
                     "build_s": round(t_h, 2),
                     "size_mb": round(_tree_bytes(g) / 1e6, 1)})
        rows.append({"name": f"table3/{name}/scann",
                     "us_per_call": t_s * 1e6,
                     "build_s": round(t_s, 2),
                     "size_mb": round(_tree_bytes(s) / 1e6, 1)})
    return rows


if __name__ == "__main__":
    emit(run(), "table3")
