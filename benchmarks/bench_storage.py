"""Storage-engine bench: the paper's buffer-manager claims, measured
(DESIGN.md §8).

Four sections over the fig_planner workload (sift10m-shaped):

  cold_warm     — identical batch served twice through each executor with
                  a pooled StorageEngine: cold pass misses every
                  first-touch page, warm pass must hit ~100 %.
  capacity      — pool-capacity sweep under the centroid-routed queue:
                  hit rate vs capacity fraction (the shared-buffers
                  sizing curve).
  counters      — measured vs predicted page counters at one grid point:
                  analytic SearchStats vs `predict_counters` vs the
                  pool-measured logical accesses.
  routing       — the serving-layer batch policy (ROADMAP item): a
                  64-request queue dispatched in batches of 16, FIFO
                  arrival order vs clustered by nearest ScaNN centroid
                  (serving/rag.py policy).  Reports the buffer-pool
                  hit-rate lift; asserts warm centroid-routed hit rate
                  > 0.5.
  planner       — fig_planner's regret sweep re-run in the warm-serving
                  regime with warm-cache-aware costs on BOTH sides:
                  predictions carry `cache_miss_penalty(pool_state)`,
                  measured cycles carry `measured_miss_penalty` from the
                  pools' observed misses.  Asserts planner regret ≤ 1.5
                  at recall ≥ 0.9 at every grid point.

Emits one JSON record to BENCH_storage.json.

    PYTHONPATH=src python benchmarks/bench_storage.py [--tiny] [--ds sift10m]
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import (BENCH_DATASETS, emit, get_bitmaps,
                               get_dataset, get_executor, get_scann,
                               get_storage_engine, ground_truth, mean_recall)
from repro.core import (SYSTEM, SearchParams, WorkloadSpec, cycle_breakdown,
                        engine_scale, generate_bitmaps, index_shape,
                        measured_miss_penalty, predict_counters,
                        stats_table_row)
from repro.data import make_dataset
from repro.serving.rag import nearest_centroid

SELS = (0.01, 0.05, 0.2, 0.5, 0.9)
FIXED = ("bruteforce", "sweeping", "navix", "iterative_scan", "scann")
RECALL_FLOOR = 0.9
REGRET_TARGET = 1.5
WARM_HIT_TARGET = 0.5


def _params(k: int = 10) -> SearchParams:
    # fig_planner's balanced config (benchmarks/fig_planner.py)
    return SearchParams(k=k, ef_search=128, beam_width=512, max_hops=3000,
                        num_leaves_to_search=32, reorder_factor=4,
                        scann_page_accounting="batch",
                        batch_tuples=max(64, k * 8), max_rounds=16)


def _per_query_params(k: int = 10) -> SearchParams:
    import dataclasses
    return dataclasses.replace(_params(k),
                               scann_page_accounting="per_query")


# ---------------------------------------------------------------------------
# cold vs warm
# ---------------------------------------------------------------------------

def bench_cold_warm(ds: str, rows: list) -> dict:
    store, queries = get_dataset(ds)
    bm = get_bitmaps(ds, 0.2, "none")
    p = _params()
    out = {}
    for m in ("scann", "sweeping", "bruteforce"):
        eng = get_storage_engine(ds, m, capacity_frac=1.0)
        ex = get_executor(ds, m, storage=eng)
        cold = ex.search(queries, bm, p).storage
        warm = ex.search(queries, bm, p).storage
        eng.reset_cold()
        recold = ex.search(queries, bm, p).storage
        out[m] = {"cold_hit_rate": round(cold.hit_rate, 4),
                  "warm_hit_rate": round(warm.hit_rate, 4),
                  "cold_misses": cold.miss_total,
                  "warm_misses": warm.miss_total,
                  "recold_misses": recold.miss_total}
        rows.append({"name": f"bench_storage/{ds}/cold_warm/{m}",
                     "us_per_call": 0.0, **out[m]})
        assert warm.miss_total == 0, (m, "warm pass must be fully resident")
        assert recold.miss_total == cold.miss_total, (m, "cold reset")
    return out


# ---------------------------------------------------------------------------
# serving-layer batch routing (centroid vs FIFO) + capacity sweep
# ---------------------------------------------------------------------------

def _routed_queue(ds: str, nreq: int, sel: float, seed: int = 1,
                  copies: int = 4):
    """A request queue larger than one batch, with hot-topic structure:
    nreq/copies base queries, each arriving `copies` times with small
    jitter (heavy-traffic serving — many users ask similar things), in a
    shuffled arrival order.  FIFO batching interleaves the topics;
    centroid routing regroups them.  Returns (queries, bitmaps,
    nearest-centroid keys, arrival order)."""
    spec = BENCH_DATASETS[ds]
    nbase = max(1, nreq // copies)
    # seed=0 reproduces EXACTLY the store the cached executors/index were
    # built on (make_dataset's store draw precedes and is independent of
    # num_queries), so the queue is clustered w.r.t. the indexed centroids
    store, base = make_dataset(spec, num_queries=nbase, seed=0)
    rng = np.random.RandomState(seed)
    reps = [np.asarray(base)]
    scale = 0.05 * float(np.abs(np.asarray(base)).mean())
    for _ in range(copies - 1):
        reps.append(np.asarray(base)
                    + scale * rng.randn(*base.shape).astype(np.float32))
    queries = jnp.asarray(np.concatenate(reps, axis=0)[:nreq])
    bm = generate_bitmaps(store, queries, WorkloadSpec(sel, "none"),
                          seed=seed + 7)
    idx = get_scann(ds)
    keys = np.asarray(nearest_centroid(idx, queries))
    order = rng.permutation(queries.shape[0])             # arrival order
    return queries, bm, keys, order


def _run_queue(ds: str, queries, bm, dispatch: np.ndarray, batch: int,
               capacity_frac: float, p: SearchParams) -> dict:
    """Dispatch the queue through a pooled ScannExecutor in `batch`-sized
    groups (two epochs: cold, then warm) and return pool telemetry."""
    eng = get_storage_engine(ds, "scann", capacity_frac=capacity_frac)
    ex = get_executor(ds, "scann", storage=eng)
    epochs = []
    for _ in range(2):
        h = m = 0
        for s in range(0, len(dispatch), batch):
            sel_ids = jnp.asarray(dispatch[s:s + batch])
            st = ex.search(queries[sel_ids], bm[sel_ids], p).storage
            h += sum(st.hits.values())
            m += sum(st.misses.values())
        epochs.append({"hits": h, "misses": m,
                       "hit_rate": round(h / max(h + m, 1), 4)})
    return {"cold_epoch": epochs[0], "warm_epoch": epochs[1],
            "capacity_pages": eng.pool.capacity,
            "total_pages": eng.total_pages}


def bench_routing(ds: str, rows: list, nreq: int, batch: int = 16,
                  capacity_frac: float = 0.25) -> dict:
    queries, bm, keys, order = _routed_queue(ds, nreq, sel=0.2)
    p = _per_query_params()       # pool sees every query's opens (§5)
    fifo = _run_queue(ds, queries, bm, order, batch, capacity_frac, p)
    routed = np.argsort(keys[order], kind="stable")
    cent = _run_queue(ds, queries, bm, order[routed], batch, capacity_frac,
                      p)
    lift = {
        "cold": round(cent["cold_epoch"]["hit_rate"]
                      - fifo["cold_epoch"]["hit_rate"], 4),
        "warm": round(cent["warm_epoch"]["hit_rate"]
                      - fifo["warm_epoch"]["hit_rate"], 4),
    }
    out = {"nreq": nreq, "batch": batch, "capacity_frac": capacity_frac,
           "fifo": fifo, "centroid": cent, "hit_rate_lift": lift}
    rows.append({"name": f"bench_storage/{ds}/routing/centroid_vs_fifo",
                 "us_per_call": 0.0,
                 "fifo_warm": fifo["warm_epoch"]["hit_rate"],
                 "centroid_warm": cent["warm_epoch"]["hit_rate"],
                 "lift_warm": lift["warm"], "lift_cold": lift["cold"]})
    assert cent["warm_epoch"]["hit_rate"] > WARM_HIT_TARGET, (
        f"warm centroid-routed hit rate "
        f"{cent['warm_epoch']['hit_rate']} <= {WARM_HIT_TARGET}")
    assert lift["cold"] > 0, "centroid routing must lift cold hit rate"
    return out


def bench_capacity(ds: str, rows: list, nreq: int,
                   fracs=(0.05, 0.15, 0.3, 0.6, 1.0)) -> list[dict]:
    queries, bm, keys, order = _routed_queue(ds, nreq, sel=0.2)
    p = _per_query_params()
    dispatch = order[np.argsort(keys[order], kind="stable")]
    sweep = []
    for frac in fracs:
        r = _run_queue(ds, queries, bm, dispatch, 16, frac, p)
        sweep.append({"capacity_frac": frac,
                      "capacity_pages": r["capacity_pages"],
                      "cold_hit_rate": r["cold_epoch"]["hit_rate"],
                      "warm_hit_rate": r["warm_epoch"]["hit_rate"]})
        rows.append({"name": f"bench_storage/{ds}/capacity/frac={frac}",
                     "us_per_call": 0.0, **sweep[-1]})
    # hit rate is monotone-ish in capacity; assert the envelope
    assert sweep[-1]["warm_hit_rate"] >= sweep[0]["warm_hit_rate"]
    return sweep


# ---------------------------------------------------------------------------
# measured vs predicted page counters
# ---------------------------------------------------------------------------

def bench_counters(ds: str, rows: list, sel: float = 0.2) -> dict:
    store, queries = get_dataset(ds)
    bm = get_bitmaps(ds, sel, "none")
    p = _per_query_params()
    shape = index_shape(store, get_scann(ds), graph_m=16)
    out = {}
    for m in ("scann", "sweeping", "bruteforce"):
        eng = get_storage_engine(ds, m, capacity_frac=1.0)
        ex = get_executor(ds, m, storage=eng)
        res = ex.search(queries, bm, p)
        srow = stats_table_row(res.stats)
        pred = predict_counters(m, shape, p, sel)
        q = queries.shape[0]
        meas = {"page_accesses_index": float(res.storage.index_pages.mean()),
                "page_accesses_heap": float(res.storage.heap_pages.mean())}
        out[m] = {
            "analytic_index": srow["page_accesses_index"],
            "analytic_heap": srow["page_accesses_heap"],
            "measured_index": meas["page_accesses_index"],
            "measured_heap": meas["page_accesses_heap"],
            "predicted_index": round(pred["page_accesses_index"], 1),
            "predicted_heap": round(pred["page_accesses_heap"], 1),
            "pool_hit_rate": round(res.storage.hit_rate, 4),
        }
        rows.append({"name": f"bench_storage/{ds}/counters/{m}",
                     "us_per_call": 0.0, **out[m]})
        # measured logical never exceeds analytic; exact for scann/seqscan
        assert meas["page_accesses_heap"] <= srow["page_accesses_heap"] + 1e-9
        if m in ("scann", "bruteforce"):
            assert meas["page_accesses_heap"] == srow["page_accesses_heap"]
            assert meas["page_accesses_index"] == srow["page_accesses_index"]
    return out


# ---------------------------------------------------------------------------
# warm-cache-aware planner regret (fig_planner grid, storage-aware)
# ---------------------------------------------------------------------------

def bench_planner(ds: str, rows: list, sels=SELS,
                  capacity_frac: float = 0.5) -> dict:
    store, queries = get_dataset(ds)
    p = _params()
    q_batch = queries.shape[0]
    execs = {}
    for m in FIXED:
        execs[m] = get_executor(ds, m, storage=get_storage_engine(
            ds, m, capacity_frac=capacity_frac))
    execs["adaptive"] = get_executor(ds, "adaptive",
                                     storage=get_storage_engine(
                                         ds, "adaptive",
                                         capacity_frac=capacity_frac))
    # steady-state warm serving: every pool is warmed once before the
    # measured sweep (the cold transient is the cold_warm section's story)
    warm_bm = get_bitmaps(ds, sels[0], "none")
    for ex in execs.values():
        jax.block_until_ready(ex.search(queries, warm_bm, p).ids)
    grid = []
    for sel in sels:
        bm = get_bitmaps(ds, sel, "none")
        _, tid = ground_truth(ds, sel, "none", p.k)
        cyc, rec, chosen = {}, {}, {}
        for m, ex in execs.items():
            t0 = time.perf_counter()
            res = ex.search(queries, bm, p)
            jax.block_until_ready(res.ids)
            wall = (time.perf_counter() - t0) / q_batch * 1e6
            # warm-cache-aware currency: engine-scaled modeled cycles +
            # the pool's MEASURED miss penalty for this batch
            cyc[m] = cycle_breakdown(
                res.stats, store.dim, SYSTEM,
                engine_scale(res.strategy, p, q_batch))["total"] + \
                measured_miss_penalty(res.storage, q_batch, SYSTEM)
            rec[m] = mean_recall(res.ids, tid, p.k)
            chosen[m] = res.strategy
            if m == "adaptive":
                rows.append({
                    "name": f"bench_storage/{ds}/planner/sel={sel}",
                    "us_per_call": wall, "chosen": res.strategy,
                    "recall": round(rec[m], 3),
                    "mcycles": round(cyc[m] / 1e6, 3)})
        qualified = {m: cyc[m] for m in FIXED if rec[m] >= RECALL_FLOOR}
        pool = qualified or {m: cyc[m] for m in FIXED}
        best = min(pool, key=pool.get)
        point = {"sel": sel, "best_fixed": best,
                 "chosen": chosen["adaptive"],
                 "recall": {m: round(rec[m], 3) for m in rec},
                 "regret": {}}
        for m in (*FIXED, "adaptive"):
            r = cyc[m] / cyc[best]
            point["regret"][m] = round(r, 3) if rec[m] >= RECALL_FLOOR \
                else "inf"
        grid.append(point)
    regrets = [pt["regret"]["adaptive"] for pt in grid]
    max_regret = math.inf if "inf" in regrets else max(regrets)
    out = {"grid": grid, "max_regret_adaptive":
           (round(max_regret, 3) if math.isfinite(max_regret) else "inf"),
           "recall_floor": RECALL_FLOOR, "regret_target": REGRET_TARGET}
    assert all(pt["recall"]["adaptive"] >= RECALL_FLOOR for pt in grid), \
        "planner fell below the recall floor under warm-cache-aware costs"
    assert math.isfinite(max_regret) and max_regret <= REGRET_TARGET, (
        f"warm-cache-aware planner regret {max_regret} > {REGRET_TARGET}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="2-point CI configuration (smoke.sh)")
    ap.add_argument("--ds", default="sift10m")
    args = ap.parse_args()
    nreq = 32 if args.tiny else 64
    sels = (0.05, 0.5) if args.tiny else SELS
    fracs = (0.15, 1.0) if args.tiny else (0.05, 0.15, 0.3, 0.6, 1.0)
    rows: list[dict] = []
    rec = {"bench": "storage", "dataset": args.ds, "tiny": args.tiny,
           "cold_warm": bench_cold_warm(args.ds, rows),
           "capacity": bench_capacity(args.ds, rows, nreq, fracs),
           "counters": bench_counters(args.ds, rows),
           "routing": bench_routing(args.ds, rows, nreq),
           "planner": bench_planner(args.ds, rows, sels)}
    # --tiny (CI smoke) must not clobber the tracked full-grid record
    name = "BENCH_storage.tiny.json" if args.tiny else "BENCH_storage.json"
    path = os.path.join(os.path.dirname(__file__), "..", name)
    with open(path, "w") as f:
        f.write(json.dumps(rec) + "\n")
    emit(rows, "bench_storage")
    print(f"# warm centroid-routed hit rate: "
          f"{rec['routing']['centroid']['warm_epoch']['hit_rate']} "
          f"(lift over FIFO: {rec['routing']['hit_rate_lift']['warm']}); "
          f"warm-cache-aware planner max regret: "
          f"{rec['planner']['max_regret_adaptive']}")


if __name__ == "__main__":
    main()
