"""Paper Table 5: ScaNN quantization/PCA ablation — latency speedup vs the
non-PCA index across selectivities (wall time, CPU)."""
from __future__ import annotations

import time

import jax

from benchmarks.common import (emit, get_bitmaps, get_dataset, get_scann,
                               ground_truth, mean_recall)
from repro.core import ScannExecutor, SearchParams

SELS = (0.01, 0.05, 0.2, 0.5, 0.8)


def _run_once(idx, store, queries, bm, p):
    ex = ScannExecutor(idx, store, pipeline="batched")
    jax.block_until_ready(ex.search(queries, bm, p).ids)
    t0 = time.perf_counter()
    ids = ex.search(queries, bm, p).ids
    jax.block_until_ready(ids)
    return (time.perf_counter() - t0) / queries.shape[0] * 1e6, ids


def run(ds="openai5m") -> list[dict]:
    store, queries = get_dataset(ds)
    base = get_scann(ds, pca=False)
    pca = get_scann(ds, pca=True)
    rows = []
    for sel in SELS:
        bm = get_bitmaps(ds, sel, "none")
        _, tid = ground_truth(ds, sel, "none")
        p = SearchParams(k=10, num_leaves_to_search=32, reorder_factor=6)
        t_base, ids_b = _run_once(base, store, queries, bm, p)
        t_pca, ids_p = _run_once(pca, store, queries, bm, p)
        rows.append({
            "name": f"table5/{ds}/pca_quant/sel={sel}",
            "us_per_call": t_pca,
            "speedup_vs_raw": round(t_base / max(t_pca, 1e-9), 2),
            "recall_raw": round(mean_recall(ids_b, tid), 3),
            "recall_pca": round(mean_recall(ids_p, tid), 3),
        })
    return rows


if __name__ == "__main__":
    emit(run(), "table5")
