"""Open-loop serving bench: continuous batching vs batch-synchronous
dispatch on identical arrival traces (DESIGN.md §11).

The paper's Table 7 concurrency study is closed-loop — a fixed batch
enters together, so measured QPS hides head-of-line blocking.  This
bench replays an OPEN-LOOP trace (Poisson background arrivals at a swept
offered load, plus bursty hot-topic arrivals whose correlated
low-selectivity predicates make them stragglers) through the same
`SlotPool` twice:

  continuous — finished lanes retire mid-flight, queued requests are
               admitted into freed slots every tick
  batch      — the pool refills only when EMPTY and harvests only when
               every lane is done: all co-batched requests share the
               last finisher's retire tick (exactly `serve_queue`'s
               dispatch shape, measured on the same engine)

Per-lane results are bit-identical between the two modes (and to
`serve_queue` itself — the precheck asserts this BEFORE any timing);
only the clock differs.  Latency is virtual time: 1 tick = 1 stepped
hop chunk.  Reported per load point: p50/p99 tick latency, goodput
(fraction served within the SLO), slot utilization and jit compile
count.  Emits one JSON record to BENCH_serving.json; `--tiny` (CI
smoke) writes the gitignored .tiny variant.

    PYTHONPATH=src python benchmarks/bench_serving.py [--tiny]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (SearchParams, WorkloadSpec, build_graph,
                        generate_bitmaps)
from repro.core.executor import GraphExecutor
from repro.data import DatasetSpec, make_dataset
from repro.serving.continuous import (ContinuousServer, Request,
                                      results_in_order)
from repro.serving.rag import RetrievalAugmentedServer

STRAGGLER_FRAC = 0.2        # hot-topic share of arrivals (heavy tail)
SEL_FAST, SEL_SLOW = 0.5, 0.02
BURST_LEN = 4               # consecutive hot-topic arrivals per burst


def _setup(tiny: bool):
    if tiny:
        spec = DatasetSpec("serving-tiny", 4_000, 32, "l2", clusters=16)
        nreq, width, hop_chunk, max_hops = 48, 4, 8, 200
    else:
        spec = DatasetSpec("serving-bench", 20_000, 64, "l2", clusters=64)
        nreq, width, hop_chunk, max_hops = 160, 8, 8, 600
    store, queries = make_dataset(spec, num_queries=64, seed=0)
    graph = build_graph(store, m=8, ef_construction=48, seed=0)
    params = SearchParams(k=10, ef_search=64, beam_width=64,
                          max_hops=max_hops, strategy="sweeping",
                          graph_exec_mode="frontier")
    return store, np.asarray(queries), graph, params, nreq, width, hop_chunk


def make_trace(queries: np.ndarray, bm_fast, bm_slow, nreq: int,
               load: float, seed: int) -> list[Request]:
    """Open-loop trace: Poisson arrivals at `load` requests/tick.
    Background requests draw a random query with a selectivity-0.5
    uncorrelated predicate; ~STRAGGLER_FRAC of arrivals come in
    hot-topic bursts — BURST_LEN consecutive requests repeating one
    query with its correlated selectivity-0.02 predicate (the
    `workload.py` correlated family), which makes them traversal
    stragglers under the sweeping strategy."""
    rng = np.random.RandomState(seed)
    arrivals = np.floor(np.cumsum(
        rng.exponential(1.0 / load, nreq))).astype(np.int64)
    bm_fast = np.asarray(bm_fast)
    bm_slow = np.asarray(bm_slow)
    nq = queries.shape[0]
    reqs: list[Request] = []
    i = 0
    while i < nreq:
        if rng.rand() < STRAGGLER_FRAC / BURST_LEN:
            hot = rng.randint(nq)
            for _ in range(min(BURST_LEN, nreq - i)):
                reqs.append(Request(rid=i, query=queries[hot],
                                    bitmap=bm_slow[hot],
                                    arrival=int(arrivals[i])))
                i += 1
        else:
            qi = rng.randint(nq)
            reqs.append(Request(rid=i, query=queries[qi],
                                bitmap=bm_fast[qi],
                                arrival=int(arrivals[i])))
            i += 1
    return reqs


def replay(executor, params, requests: list[Request], width: int,
           hop_chunk: int, mode: str, slo_ticks: float,
           fairness=None) -> tuple[dict, dict]:
    """Trace-replay harness shared with table7_concurrency.py: run one
    trace through a `ContinuousServer` in `mode` and reduce to the
    serving metrics (p50/p99 tick latency, goodput within `slo_ticks`,
    slot utilization, compiles).  Returns (metrics, raw records)."""
    srv = ContinuousServer(executor, params, width=width,
                           hop_chunk=hop_chunk, fairness=fairness)
    t0 = time.perf_counter()
    recs, info = srv.serve(requests, mode=mode)
    wall = time.perf_counter() - t0
    served = [r for r in recs.values() if r.get("retire_tick", -1) >= 0]
    lat = np.array([r["latency_ticks"] for r in served], np.float64)
    good = sum(1 for r in served
               if (np.asarray(r["ids"]) >= 0).any()
               and r["latency_ticks"] <= slo_ticks)
    return {
        "mode": mode,
        "p50_ticks": float(np.percentile(lat, 50)),
        "p99_ticks": float(np.percentile(lat, 99)),
        "mean_ticks": round(float(lat.mean()), 2),
        "goodput": round(good / max(len(requests), 1), 4),
        "slot_utilization": round(info["slot_utilization"], 4),
        "compiles": info["compiles"],
        "ticks": info["ticks"],
        "wall_s": round(wall, 2),
    }, recs


def _precheck(store, queries, bm_fast, executor, params, width: int,
              hop_chunk: int, nreq: int) -> None:
    """Bit-identicality gate, asserted BEFORE any timing run: with all
    arrivals at t=0 and fairness off, continuous slot-retire ids/dists
    must equal `serve_queue(policy="fifo")` exactly."""
    n = min(nreq, 24)
    qt = jnp.asarray(queries)
    srv = RetrievalAugmentedServer(
        bundle=None, params=None, executor=executor, search_params=params,
        doc_tokens=np.zeros((store.n, 4), np.int32), chunk_len=4,
        embed_fn=lambda p, tok: qt[tok[:, 0]])
    prompts = np.arange(n, dtype=np.int32)[:, None]
    res, _ = srv.serve_queue(prompts, jnp.asarray(np.asarray(bm_fast)[:n]),
                             batch_size=width, policy="fifo")
    reqs = [Request(rid=i, query=queries[i],
                    bitmap=np.asarray(bm_fast)[i]) for i in range(n)]
    cs = ContinuousServer(executor, params, width=width,
                          hop_chunk=hop_chunk)
    recs, _ = cs.serve(reqs, mode="continuous")
    ids, dists = results_in_order(recs, n, params.k)
    assert np.array_equal(np.asarray(res.ids), ids), \
        "precheck failed: continuous ids differ from serve_queue"
    assert np.array_equal(np.asarray(res.dists), dists, equal_nan=True), \
        "precheck failed: continuous dists differ from serve_queue"


def _service_estimate(queries, bm_fast, bm_slow, executor, params,
                      width: int, hop_chunk: int) -> tuple[float, float]:
    """Mean service ticks of the fast and straggler classes, measured on
    an uncontended pool (arrivals at t=0, one request per slot wave)."""
    out = []
    for bm in (bm_fast, bm_slow):
        reqs = [Request(rid=i, query=queries[i],
                        bitmap=np.asarray(bm)[i]) for i in range(width)]
        cs = ContinuousServer(executor, params, width=width,
                              hop_chunk=hop_chunk)
        recs, _ = cs.serve(reqs, mode="continuous")
        out.append(float(np.mean([recs[i]["latency_ticks"]
                                  for i in range(width)])))
    return out[0], out[1]


def run(tiny: bool = False) -> dict:
    store, queries, graph, params, nreq, width, hop_chunk = _setup(tiny)
    executor = GraphExecutor(graph, store, strategy="sweeping")
    qj = jnp.asarray(queries)
    bm_fast = generate_bitmaps(store, qj, WorkloadSpec(SEL_FAST, "none"),
                               seed=1)
    bm_slow = generate_bitmaps(store, qj,
                               WorkloadSpec(SEL_SLOW, "high_pos"), seed=2)

    _precheck(store, queries, bm_fast, executor, params, width, hop_chunk,
              nreq)
    s_fast, s_slow = _service_estimate(queries, bm_fast, bm_slow,
                                       executor, params, width, hop_chunk)
    s_mean = (1 - STRAGGLER_FRAC) * s_fast + STRAGGLER_FRAC * s_slow
    capacity = width / max(s_mean, 1e-9)        # requests/tick
    # tight enough that head-of-line-blocked fast requests miss it, wide
    # enough that an uncontended straggler (s_slow) meets it
    slo_ticks = 1.5 * s_slow

    out = {"bench": "serving", "backend": jax.default_backend(),
           "tiny": tiny, "n": store.n, "dim": store.dim,
           "requests": nreq, "width": width, "hop_chunk": hop_chunk,
           "straggler_frac": STRAGGLER_FRAC, "burst_len": BURST_LEN,
           "sel_fast": SEL_FAST, "sel_slow": SEL_SLOW,
           "precheck_bit_identical": True,
           "service_ticks": {"fast": round(s_fast, 2),
                             "slow": round(s_slow, 2),
                             "mean": round(s_mean, 2)},
           "capacity_req_per_tick": round(capacity, 4),
           "slo_ticks": round(slo_ticks, 1), "sweep": []}

    fracs = (0.5, 0.9) if tiny else (0.35, 0.6, 0.9)
    for frac in fracs:
        load = frac * capacity
        trace = make_trace(queries, bm_fast, bm_slow, nreq, load, seed=7)
        point = {"frac_capacity": frac,
                 "offered_load": round(load, 4)}
        for mode in ("continuous", "batch"):
            point[mode], _ = replay(executor, params, trace, width,
                                    hop_chunk, mode, slo_ticks)
        point["p99_ratio"] = round(
            point["batch"]["p99_ticks"]
            / max(point["continuous"]["p99_ticks"], 1e-9), 3)
        out["sweep"].append(point)
        print(f"# load={frac:.2f}c cont p99={point['continuous']['p99_ticks']:.0f} "
              f"goodput={point['continuous']['goodput']:.3f} | "
              f"batch p99={point['batch']['p99_ticks']:.0f} "
              f"goodput={point['batch']['goodput']:.3f} "
              f"(p99 ratio {point['p99_ratio']:.2f})")

    # the knee is the highest swept load — the operating point where
    # batch-synchronous dispatch saturates (its effective service time is
    # the per-batch max, so its capacity knee arrives first)
    knee = out["sweep"][-1]
    out["knee"] = {"frac_capacity": knee["frac_capacity"],
                   "p99_ratio": knee["p99_ratio"],
                   "goodput_continuous": knee["continuous"]["goodput"],
                   "goodput_batch": knee["batch"]["goodput"]}
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="small fresh-built dataset (CI smoke)")
    args = ap.parse_args()
    result = run(tiny=args.tiny)
    line = json.dumps(result)
    # --tiny (CI smoke) must not clobber the tracked full record
    name = "BENCH_serving.tiny.json" if args.tiny else "BENCH_serving.json"
    path = os.path.join(os.path.dirname(__file__), "..", name)
    with open(path, "w") as f:
        f.write(line + "\n")
    print(line)
    knee = result["knee"]
    assert knee["p99_ratio"] >= 1.5, (
        f"continuous p99 win {knee['p99_ratio']}x at the knee is below "
        f"the 1.5x bar")
    assert knee["goodput_continuous"] > knee["goodput_batch"], (
        f"continuous goodput {knee['goodput_continuous']} not strictly "
        f"better than batch {knee['goodput_batch']} at the knee")


if __name__ == "__main__":
    main()
