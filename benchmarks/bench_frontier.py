"""Microbenchmark: batch-synchronous frontier engine vs legacy vmapped path.

Measures the tentpole claim directly: the frontier engine advances the
whole query batch one superstep at a time — packed uint32 visited bitsets
(8× less in-flight state than the legacy (Q, n) bool arrays), need-only
chunked candidate scoring with lazy 2-hop expansion for filter-first
strategies, visited-probe dedup instead of a per-hop argsort over the full
2-hop block, and fold-the-pop queue merges — while the vmapped path pays
all of those per query per hop.  Every point is verified **bit-identical**
(ids, dists, all 7 SearchStats counters) before its timing is reported;
a mismatch fails the run.

The full sweep runs on a dedicated container-scale dataset (n=100k — big
enough that the legacy engine's (Q, n) visited state is a real cost, the
regime the paper's 5–10M-row tables live in) with the `SearchParams`
default search knobs (ef=64, beam=64, k=10) at selectivity 0.2.  The
first run builds and caches the graph (benchmarks/.cache, several
minutes); `--tiny` uses a freshly built 8k-row set for CI smoke.

Emits one JSON record to BENCH_frontier.json so the perf trajectory is
tracked run-over-run.

    PYTHONPATH=src python benchmarks/bench_frontier.py [--tiny]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import _cache
from repro.core import (SearchParams, WorkloadSpec, build_graph,
                        generate_bitmaps, search_batch)
from repro.core.hnsw import HNSWGraph
from repro.core.types import VectorStore
from repro.data import DatasetSpec, make_dataset

STRATEGIES = ("sweeping", "acorn")
BATCHES = (1, 8, 32, 128)
REPS = 3
STAT_FIELDS = ("distance_comps", "filter_checks", "hops",
               "page_accesses_index", "page_accesses_heap", "tmap_lookups",
               "reorder_rows")


def _setup(tiny: bool):
    if tiny:
        spec = DatasetSpec("frontier-tiny", 8_000, 64, "l2", clusters=32)
        store, queries = make_dataset(spec, num_queries=16, seed=0)
        graph = build_graph(store, m=8, ef_construction=48, seed=0)
        return store, jnp.asarray(queries), graph
    spec = DatasetSpec("frontier-bench", 100_000, 128, "l2", clusters=128)
    store, queries = make_dataset(spec, num_queries=128, seed=0)

    def build():
        g = build_graph(store, m=16, ef_construction=64, seed=0)
        return (np.asarray(g.neighbors), np.asarray(g.node_level),
                np.asarray(g.entry_point))

    nb, lv, ep = _cache("graph_frontier_bench_100k", build)
    graph = HNSWGraph(neighbors=jnp.asarray(nb), node_level=jnp.asarray(lv),
                      entry_point=jnp.asarray(ep), m=16)
    return store, jnp.asarray(queries), graph


def _run_point(graph, store, queries, bm, params):
    d, ids, st = search_batch(graph, store, queries, bm, params)
    jax.block_until_ready(ids)                  # compile + warm
    ts = []
    for _ in range(REPS):
        t0 = time.perf_counter()
        d, ids, st = search_batch(graph, store, queries, bm, params)
        jax.block_until_ready(ids)
        ts.append(time.perf_counter() - t0)
    return min(ts), np.asarray(ids), np.asarray(d), st


def run(tiny: bool = False) -> dict:
    store, queries, graph = _setup(tiny)
    sel = 0.2
    bm = generate_bitmaps(store, queries, WorkloadSpec(sel, "none"), seed=1)
    batches = (1, 8) if tiny else BATCHES
    max_hops = 300 if tiny else 3000
    out = {"bench": "frontier", "backend": jax.default_backend(),
           "tiny": tiny, "n": store.n, "dim": store.dim, "sel": sel,
           "params": {"k": 10, "ef_search": 64, "beam_width": 64,
                      "max_hops": max_hops},
           "points": []}
    ok_all = True
    for strat in STRATEGIES:
        base = SearchParams(k=10, strategy=strat, max_hops=max_hops)
        for q in batches:
            qs, bs = queries[:q], bm[:q]
            tv, iv, dv, sv = _run_point(
                graph, store, qs, bs,
                dataclasses.replace(base, graph_exec_mode="vmapped"))
            tf, iff, df, sf = _run_point(
                graph, store, qs, bs,
                dataclasses.replace(base, graph_exec_mode="frontier"))
            identical = bool(
                (iv == iff).all()
                and np.array_equal(dv, df, equal_nan=True)
                and all((np.asarray(getattr(sv, f))
                         == np.asarray(getattr(sf, f))).all()
                        for f in STAT_FIELDS))
            ok_all &= identical
            pt = {"strategy": strat, "batch": q,
                  "vmapped_ms": round(tv * 1e3, 1),
                  "frontier_ms": round(tf * 1e3, 1),
                  "speedup": round(tv / tf, 2),
                  "steps": int(np.asarray(sv.hops).max()),
                  "identical": identical}
            out["points"].append(pt)
            print(f"# {strat} Q={q}: vmapped {pt['vmapped_ms']}ms "
                  f"frontier {pt['frontier_ms']}ms "
                  f"speedup {pt['speedup']}x identical={identical}")
    big = [p["speedup"] for p in out["points"] if p["batch"] >= 32]
    out["min_speedup_q32plus"] = min(big) if big else None
    out["all_identical"] = ok_all
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="small fresh-built dataset, Q ∈ {1, 8} (CI smoke)")
    args = ap.parse_args()
    result = run(tiny=args.tiny)
    line = json.dumps(result)
    # --tiny (CI smoke) must not clobber the tracked full-sweep record:
    # tiny runs write the gitignored .tiny variant
    name = "BENCH_frontier.tiny.json" if args.tiny else "BENCH_frontier.json"
    path = os.path.join(os.path.dirname(__file__), "..", name)
    with open(path, "w") as f:
        f.write(line + "\n")
    print(line)
    assert result["all_identical"], \
        "frontier engine diverged from the vmapped oracle"
    if not result["tiny"]:
        assert result["min_speedup_q32plus"] and \
            result["min_speedup_q32plus"] >= 3.0, (
                "frontier engine under the 3x bar at Q>=32: "
                f"{result['min_speedup_q32plus']}")


if __name__ == "__main__":
    main()
