"""Microbenchmark: SQ8 quantized graph traversal vs full-precision
(DESIGN.md §9) — the measured version of the paper's Table 4 question.

The paper argues (Table 4) that quantization barely helps HNSW in a
page-based engine because neighbor-page traffic dominates; our repro used
to *model* that claim by rescaling counters.  This bench measures it on
the repo's own storage engine: at every (selectivity × batch) grid point
the same sweeping search runs under graph_quant ∈ {none, sq8} with a cold
full-capacity buffer pool, and we record

  * measured heap-page traffic — physical page reads of the traversal's
    row fetches (the f32 "heap" segment vs the 4×-denser SQ8 "qheap"
    shadow, plus the exact rerank's full-width fetches) straight from the
    pool's StorageStats;
  * recall-qualified modeled QPS — SYSTEM cycles from the measured
    counters (quant-aware materialization + rerank surcharge, frontier
    engine_scale) plus the measured miss penalty, with the sq8 point only
    credited when its recall@10 stays within 0.02 of f32 (the rerank's
    recall bound, asserted);
  * wall time per batch, for orientation (CPU interpret mode).

The interesting regime is heap-traffic-bound: traversal touches many more
distinct rows than the rerank re-fetches (low selectivity, small-to-mid
batch), where the 4× page density shows up as a ≥2× physical-read cut and
the miss-side modeled QPS follows.  At large Q the rerank's full-width
fetches claw much of it back — exactly the paper's Table 4 shape.

Emits one JSON record to BENCH_graph_quant.json; `--tiny` (CI smoke,
tools/smoke.sh) uses a fresh small dataset and writes the gitignored
.tiny variant.

    PYTHONPATH=src python benchmarks/bench_graph_quant.py [--tiny]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import (_cache, heap_read_misses,
                               measured_graph_cycles, mean_recall)
from repro.core import (SearchParams, WorkloadSpec, build_graph,
                        filtered_knn, generate_bitmaps, make_executor,
                        quantize_store)
from repro.core.hnsw import HNSWGraph
from repro.data import DatasetSpec, make_dataset
from repro.storage import make_storage_engine

SELS = (0.02, 0.1, 0.3)
BATCHES = (1, 8, 32)
RECALL_SLACK = 0.02              # the rerank's recall bound (DESIGN.md §9)
TRAFFIC_TARGET = 2.0             # ≥2× physical heap-read cut somewhere
QPS_TARGET = 1.5                 # ≥1.5× modeled-QPS gain somewhere
REPS = 2


def _setup(tiny: bool):
    if tiny:
        spec = DatasetSpec("graphquant-tiny", 6_000, 64, "l2", clusters=32)
        store, queries = make_dataset(spec, num_queries=8, seed=0)
        graph = build_graph(store, m=8, ef_construction=48, seed=0)
        return store, jnp.asarray(queries), graph
    spec = DatasetSpec("graphquant-bench", 40_000, 128, "l2", clusters=96)
    store, queries = make_dataset(spec, num_queries=32, seed=0)

    def build():
        g = build_graph(store, m=16, ef_construction=64, seed=0)
        return (np.asarray(g.neighbors), np.asarray(g.node_level),
                np.asarray(g.entry_point))

    nb, lv, ep = _cache("graph_graphquant_bench_40k", build)
    graph = HNSWGraph(neighbors=jnp.asarray(nb), node_level=jnp.asarray(lv),
                      entry_point=jnp.asarray(ep), m=16)
    return store, jnp.asarray(queries), graph


def run(tiny: bool = False) -> dict:
    store, queries, graph = _setup(tiny)
    store = quantize_store(store)
    sels = (SELS[1],) if tiny else SELS
    batches = (queries.shape[0],) if tiny else BATCHES
    max_hops = 500 if tiny else 3000
    base = SearchParams(k=10, ef_search=64, beam_width=256,
                        strategy="sweeping", max_hops=max_hops)
    clock = 3.0e9
    out = {"bench": "graph_quant", "backend": jax.default_backend(),
           "tiny": tiny, "n": store.n, "dim": store.dim,
           "params": {"k": base.k, "ef_search": base.ef_search,
                      "beam_width": base.beam_width, "max_hops": max_hops},
           "points": []}
    executors = {}
    for quant in ("none", "sq8"):
        method = "sweeping" if quant == "none" else "sweeping_sq8"
        eng = make_storage_engine(store, graph=graph, capacity_frac=1.0)
        executors[quant] = (make_executor(method, store, graph=graph,
                                          storage=eng), eng)
    for sel in sels:
        bm_full = generate_bitmaps(store, queries, WorkloadSpec(sel, "none"),
                                   seed=3)
        _, tid = filtered_knn(store, queries, bm_full, base.k)
        for q in batches:
            qs, bs, tq = queries[:q], bm_full[:q], tid[:q]
            point = {"sel": sel, "batch": q}
            cyc, rec = {}, {}
            for quant in ("none", "sq8"):
                ex, eng = executors[quant]
                p = dataclasses.replace(base, graph_quant=quant)
                eng.reset_cold()
                res = ex.search(qs, bs, p)
                jax.block_until_ready(res.ids)
                ts = []
                for _ in range(REPS):        # timed reps: accounting off
                    ex_t = make_executor(
                        "sweeping" if quant == "none" else "sweeping_sq8",
                        store, graph=graph)
                    t0 = time.perf_counter()
                    r2 = ex_t.search(qs, bs, p)
                    jax.block_until_ready(r2.ids)
                    ts.append(time.perf_counter() - t0)
                rec[quant] = mean_recall(res.ids, tq, base.k)
                cyc[quant] = measured_graph_cycles(res, p, q, store.dim)
                point[quant] = {
                    "recall": round(rec[quant], 4),
                    "wall_ms": round(min(ts) * 1e3, 1),
                    "heap_reads": heap_read_misses(res),
                    "heap_logical": int(
                        res.storage.logical.get("heap", 0)
                        + res.storage.logical.get("qheap", 0)),
                    "reorder_rows": int(
                        np.asarray(res.stats.reorder_rows).sum()),
                    "mcycles_per_query": round(cyc[quant] / 1e6, 3),
                    "modeled_qps": round(clock / cyc[quant], 1),
                }
            point["heap_read_reduction"] = round(
                point["none"]["heap_reads"]
                / max(point["sq8"]["heap_reads"], 1), 2)
            point["qps_gain"] = round(cyc["none"] / cyc["sq8"], 2)
            point["recall_qualified"] = bool(
                rec["sq8"] >= rec["none"] - RECALL_SLACK)
            out["points"].append(point)
            print(f"# sel={sel} Q={q}: heap reads "
                  f"{point['none']['heap_reads']}→"
                  f"{point['sq8']['heap_reads']} "
                  f"({point['heap_read_reduction']}x), modeled QPS gain "
                  f"{point['qps_gain']}x, recall "
                  f"{point['none']['recall']}→{point['sq8']['recall']}")
    qualified = [p for p in out["points"] if p["recall_qualified"]]
    out["all_recall_qualified"] = len(qualified) == len(out["points"])
    out["best_heap_read_reduction"] = max(
        (p["heap_read_reduction"] for p in qualified), default=0.0)
    out["best_qps_gain"] = max(
        (p["qps_gain"] for p in qualified), default=0.0)
    out["heap_bound_points"] = [
        {"sel": p["sel"], "batch": p["batch"],
         "heap_read_reduction": p["heap_read_reduction"],
         "qps_gain": p["qps_gain"]}
        for p in qualified
        if p["heap_read_reduction"] >= TRAFFIC_TARGET
        and p["qps_gain"] >= QPS_TARGET]
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="small fresh-built dataset, 1 grid point (CI)")
    args = ap.parse_args()
    result = run(tiny=args.tiny)
    line = json.dumps(result)
    # --tiny (CI smoke) must not clobber the tracked full-grid record
    name = "BENCH_graph_quant.tiny.json" if args.tiny \
        else "BENCH_graph_quant.json"
    path = os.path.join(os.path.dirname(__file__), "..", name)
    with open(path, "w") as f:
        f.write(line + "\n")
    print(line)
    assert result["all_recall_qualified"], (
        "sq8+rerank recall fell more than "
        f"{RECALL_SLACK} below f32 at some grid point")
    if not result["tiny"]:
        assert result["heap_bound_points"], (
            "no recall-qualified grid point reached "
            f"{TRAFFIC_TARGET}x measured heap-read reduction AND "
            f"{QPS_TARGET}x modeled-QPS gain: best "
            f"{result['best_heap_read_reduction']}x traffic, "
            f"{result['best_qps_gain']}x QPS")


if __name__ == "__main__":
    main()
