"""Filter-cost sweep for the selectivity-aware tiers (DESIGN.md §14).

The paper's Table 6 shows filter checks dominating graph strategies at low
selectivity; FAVOR-style exclusion radii and JAG-style attribute
partitioning both attack exactly that term.  This bench measures the
attack on the workload the tiers are built for — clustered predicate
*families* shared by many queries — and on the workload they are NOT
built for (per-query uncorrelated bitmaps), at each selectivity:

  sweeping        — the filter-agnostic baseline (PR-1 engine)
  sweeping_excl   — exclusion-pruned sweeping, family-exact radii +
                    "prune_exact" accounting (FAVOR's eliminated probes),
                    margin 0.3: the aggressive end of the heuristic
                    margin knob (< 1.0 trades recall for pruning, >= 1.0
                    is provably inert) — reported as the tradeoff
                    diagnostic, not the gate carrier
  partitioned     — per-family subgraph, traversed unfiltered (carries
                    the >= GATE_FC_RATIO x gate)

Every row reports measured SearchStats counters, recall against exact
filtered KNN, and the physical page story through a cold StorageEngine
(heap + index pool misses = distinct pages actually read).  Gates
(ISSUE 10 acceptance):

  * at every family point with sel <= GATE_SEL, the best selectivity-
    aware tier must measure >= GATE_FC_RATIO x fewer filter checks AND
    fewer physical heap+index pages than sweeping, at recall within
    GATE_RECALL_SLACK of it;
  * on the uncorrelated control the tiers stay recall-safe (the
    exclusion ladder prunes ~nothing by design — no signal, no savings).

Emits BENCH_filtercost.json (tracked) or BENCH_filtercost.tiny.json
(--tiny, gitignored; wired into tools/smoke.sh).

    PYTHONPATH=src python benchmarks/bench_filtercost.py [--tiny]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import numpy as np

from benchmarks.common import (emit, family_ground_truth, get_bitmaps,
                               get_dataset, get_exclusion, get_executor,
                               get_family_bitmaps, get_graph, get_partitions,
                               get_storage_engine, ground_truth, mean_recall)
from repro.core import SearchParams

DATASET = "sift10m"
SELS = (0.02, 0.05, 0.1)
TINY_SELS = (0.05,)
METHODS = ("sweeping", "sweeping_excl", "partitioned")
EXCL_MARGIN = 0.3
GATE_SEL = 0.05
GATE_FC_RATIO = 3.0
GATE_RECALL_SLACK = 0.02


def _params(k: int = 10) -> SearchParams:
    return SearchParams(k=k, ef_search=96, beam_width=512, max_hops=3000,
                        strategy="sweeping", exclusion_margin=EXCL_MARGIN)


def _measure(ex, queries, bm, tid, k):
    t0 = time.perf_counter()
    res = ex.search(queries, bm, _params(k))
    jax.block_until_ready(res.ids)
    wall = (time.perf_counter() - t0) / queries.shape[0] * 1e6
    st = res.stats
    pages = res.storage
    return {
        "recall": mean_recall(res.ids, tid, k),
        "fc": float(np.mean(np.asarray(st.filter_checks))),
        "dc": float(np.mean(np.asarray(st.distance_comps))),
        "hops": float(np.mean(np.asarray(st.hops))),
        # gate carrier = physical reads (pool misses): each method gets its
        # own cold full-capacity pool, so misses = distinct pages actually
        # fetched from storage; logical accesses reported alongside
        "pages_heap": int(pages.misses.get("heap", 0)
                          + pages.misses.get("qheap", 0)),
        "pages_index": int(pages.misses.get("graph", 0)),
        "pages_heap_logical": int(pages.logical.get("heap", 0)
                                  + pages.logical.get("qheap", 0)),
        "pages_index_logical": int(pages.logical.get("graph", 0)),
        "us_per_call": wall,
    }


def _executor(ds, method, sel):
    # every run gets its own cold engine so the physical page story is a
    # per-(method, sel) measurement, not an artifact of pool history
    eng = get_storage_engine(ds, "sweeping", capacity_frac=1.0)
    if method == "sweeping":
        return get_executor(ds, method, storage=eng)
    if method == "sweeping_excl":
        return get_executor(ds, method, storage=eng,
                            exclusion=get_exclusion(ds, sel))
    if method == "partitioned":
        return get_executor(ds, method, storage=eng,
                            partitions=get_partitions(ds, sel))
    raise ValueError(method)


def run(ds: str = DATASET, sels=SELS, k: int = 10):
    store, queries = get_dataset(ds)
    get_graph(ds)                                   # warm the shared cache
    rows, grid = [], []
    for sel in sels:
        # --- clustered-family workload: the tiers' home regime ---------
        bm, _ = get_family_bitmaps(ds, sel)
        _, tid = family_ground_truth(ds, sel, k=k)
        point = {"sel": sel, "workload": "family", "methods": {}}
        for m in METHODS:
            r = _measure(_executor(ds, m, sel), queries, bm, tid, k)
            point["methods"][m] = {kk: round(v, 4) if isinstance(v, float)
                                   else v for kk, v in r.items()}
            rows.append({"name": f"bench_filtercost/{ds}/family/"
                                 f"sel={sel}/{m}",
                         "us_per_call": r["us_per_call"],
                         "recall": round(r["recall"], 3),
                         "fc": round(r["fc"], 1),
                         "pages": r["pages_heap"] + r["pages_index"]})
        base = point["methods"]["sweeping"]
        for m in METHODS[1:]:
            t = point["methods"][m]
            t["fc_ratio"] = round(base["fc"] / max(t["fc"], 1e-9), 2)
            t["page_ratio"] = round(
                (base["pages_heap"] + base["pages_index"])
                / max(t["pages_heap"] + t["pages_index"], 1), 2)
            t["page_ratio_logical"] = round(
                (base["pages_heap_logical"] + base["pages_index_logical"])
                / max(t["pages_heap_logical"]
                      + t["pages_index_logical"], 1), 2)
        grid.append(point)
        # --- uncorrelated control: no family signal, safety only -------
        cbm = get_bitmaps(ds, sel, "none")
        _, ctid = ground_truth(ds, sel, "none", k)
        ctrl = {"sel": sel, "workload": "uncorrelated", "methods": {}}
        for m in ("sweeping", "sweeping_excl"):
            r = _measure(_executor(ds, m, sel), queries, cbm, ctid, k)
            ctrl["methods"][m] = {kk: round(v, 4) if isinstance(v, float)
                                  else v for kk, v in r.items()}
        grid.append(ctrl)

    gates = []
    for pt in grid:
        if pt["workload"] != "family" or pt["sel"] > GATE_SEL:
            continue
        base = pt["methods"]["sweeping"]
        best = {}
        for m in METHODS[1:]:
            t = pt["methods"][m]
            ok = (t["fc_ratio"] >= GATE_FC_RATIO
                  and t["page_ratio"] > 1.0
                  and t["recall"] >= base["recall"] - GATE_RECALL_SLACK)
            if ok and (not best or t["fc_ratio"] > best["fc_ratio"]):
                best = {"method": m, "fc_ratio": t["fc_ratio"],
                        "page_ratio": t["page_ratio"],
                        "recall": t["recall"]}
        gates.append({"sel": pt["sel"], "passed": bool(best), **best})
    summary = {"bench": "filtercost", "dataset": ds,
               "excl_margin": EXCL_MARGIN, "gate_sel": GATE_SEL,
               "gate_fc_ratio": GATE_FC_RATIO,
               "gate_recall_slack": GATE_RECALL_SLACK,
               "grid": grid, "gates": gates,
               "all_gates_passed": all(g["passed"] for g in gates)}
    return rows, summary


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="1-point CI sweep (smoke.sh)")
    ap.add_argument("--ds", default=DATASET)
    args = ap.parse_args()
    rows, summary = run(args.ds, TINY_SELS if args.tiny else SELS)
    name = "BENCH_filtercost.tiny.json" if args.tiny \
        else "BENCH_filtercost.json"
    path = os.path.join(os.path.dirname(__file__), "..", name)
    with open(path, "w") as f:
        f.write(json.dumps(summary) + "\n")
    emit(rows, "bench_filtercost")
    print(f"# filtercost gates: {summary['gates']}")
    assert summary["all_gates_passed"], (
        f"selectivity-aware tier gate failed: {summary['gates']}")


if __name__ == "__main__":
    main()
