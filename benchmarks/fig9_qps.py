"""Paper Fig. 9: QPS at 95% Recall@10 across selectivities, 4 datasets,
no correlation — all five methods, with SYSTEM-modeled QPS (cost model)
plus measured per-query wall time and raw counters."""
from __future__ import annotations

from benchmarks.common import (ALL_METHODS, BENCH_DATASETS, emit, get_dataset,
                               run_method)
from repro.core import SYSTEM, modeled_qps, SearchStats
import jax.numpy as jnp

SELECTIVITIES = (0.01, 0.05, 0.1, 0.3, 0.5, 0.8)


def _row_to_stats(row):
    z = lambda v: jnp.asarray(round(v), jnp.int32)
    return SearchStats(z(row["distance_comps"]), z(row["filter_checks"]),
                       z(row["hops"]), z(row["page_accesses_index"]),
                       z(row["page_accesses_heap"]), z(row["tmap_lookups"]),
                       z(row["reorder_rows"]))


def run(datasets=("sift10m", "openai5m"), sels=SELECTIVITIES) -> list[dict]:
    rows = []
    for ds in datasets:
        store, _ = get_dataset(ds)
        for sel in sels:
            for method in ALL_METHODS:
                # batch page accounting: QPS under concurrent load, where
                # the batched ScaNN pipeline amortizes leaf fetches
                rec, srow, wall, p = run_method(ds, method, sel, "none",
                                                page_accounting="batch")
                qps = modeled_qps(_row_to_stats(srow), store.dim, SYSTEM)
                rows.append({
                    "name": f"fig9/{ds}/{method}/sel={sel}",
                    "us_per_call": wall,
                    "recall": round(rec, 3),
                    "modeled_qps": round(qps, 1),
                    "dc": round(srow["distance_comps"]),
                    "fc": round(srow["filter_checks"]),
                    "hops": round(srow["hops"], 1),
                    "pages": round(srow["page_accesses_index"]
                                   + srow["page_accesses_heap"]),
                })
    return rows


if __name__ == "__main__":
    emit(run(), "fig9")
