"""Planner regret sweep (companion to paper Fig. 1): selectivity ×
correlation grid, all fixed strategies vs the AdaptivePlanner.

At every grid point each fixed executor runs with the SAME balanced params,
its measured SearchStats are converted to SYSTEM-modeled cycles under the
accounting of the engine that actually executed it — ScaNN's batched
union-scan pipeline uses "batch" page accounting (DESIGN.md §5), graph
strategies on the frontier engine get the `engine_scale` page-cost
amortization (DESIGN.md §7) — and the "best fixed" is the cheapest
strategy meeting the recall floor (the paper's QPS-at-recall framing: a
strategy that can't hit recall doesn't get to be called fast).  Regret =
own cycles / best-fixed cycles; a strategy below the recall floor at a
point scores regret = inf there.  (For the paper's standalone-query
Fig. 10/13 semantics see fig10_breakdown.py / fig13_tmap.py, which keep
per-query accounting and unscaled weights.)

The paper's Fig. 1 finding is that no fixed strategy stays near-optimal
across the grid; the planner's job is to track the per-point best within
1.5x everywhere.  Emits one JSON record to BENCH_planner.json with the
full grid + the max-regret summary so the trajectory is tracked
run-over-run.  This sweep stays buffer-pool-blind (no StorageEngine
attached) so its currency is reproducible run-over-run; the warm-serving
variant — same grid, pooled executors, warm-cache-aware costs on both
sides — lives in benchmarks/bench_storage.py (`bench_planner`).

    PYTHONPATH=src python benchmarks/fig_planner.py [--tiny] [--ds sift10m]
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax

from benchmarks.common import (emit, get_bitmaps, get_dataset, get_exclusion,
                               get_executor, get_partitions, ground_truth,
                               mean_recall)
from repro.core import SYSTEM, SearchParams, cycle_breakdown, engine_scale

SELS = (0.01, 0.05, 0.2, 0.5, 0.9)
CORRS = ("none", "high_pos", "negative")
FIXED = ("bruteforce", "sweeping", "navix", "iterative_scan", "scann")
# the full planner menu: the six PR-4 candidates plus the two
# selectivity-aware tiers (DESIGN.md §14).  This grid's bitmaps are
# per-query and never family-match, so the planner must keep the new
# candidates honest — partitioned is batch-infeasible everywhere here and
# sweeping_excl falls back to ladder radii (prunes ~nothing, cost ≈
# sweeping); neither may cost the planner regret.
MENU = ("bruteforce", "scann", "sweeping", "sweeping_sq8", "navix",
        "iterative_scan", "sweeping_excl", "partitioned")
RECALL_FLOOR = 0.9
REGRET_TARGET = 1.5


def _params(k: int = 10) -> SearchParams:
    return SearchParams(k=k, ef_search=128, beam_width=512, max_hops=3000,
                        num_leaves_to_search=32, reorder_factor=4,
                        scann_page_accounting="batch",
                        batch_tuples=max(64, k * 8), max_rounds=16)


def run(ds: str = "sift10m", sels=SELS, corrs=CORRS,
        methods=FIXED) -> tuple[list[dict], dict]:
    store, queries = get_dataset(ds)
    p = _params()
    executors = {m: get_executor(ds, m) for m in methods}
    executors["adaptive"] = get_executor(
        ds, "adaptive", exclusion=get_exclusion(ds, 0.05),
        partitions=get_partitions(ds, 0.05), planner_candidates=MENU)
    # warm the jit caches once per executor (shapes/params are identical
    # across grid points) so timed rows exclude compile time
    warm_bm = get_bitmaps(ds, sels[0], corrs[0])
    for ex in executors.values():
        jax.block_until_ready(ex.search(queries, warm_bm, p).ids)
    rows, grid = [], []
    for corr in corrs:
        for sel in sels:
            bm = get_bitmaps(ds, sel, corr)
            _, tid = ground_truth(ds, sel, corr, p.k)
            cyc, rec, wall, chosen = {}, {}, {}, {}
            for m, ex in executors.items():
                t0 = time.perf_counter()
                res = ex.search(queries, bm, p)
                jax.block_until_ready(res.ids)
                wall[m] = (time.perf_counter() - t0) / queries.shape[0] * 1e6
                # engine-mode-aware currency (DESIGN.md §7): graph
                # strategies ran on the frontier engine, whose batched
                # fetches amortize page costs — the same scale the
                # planner's predictions use
                cyc[m] = cycle_breakdown(
                    res.stats, store.dim, SYSTEM,
                    engine_scale(res.strategy, p, queries.shape[0]))["total"]
                rec[m] = mean_recall(res.ids, tid, p.k)
                chosen[m] = res.strategy
            qualified = {m: cyc[m] for m in methods
                         if rec[m] >= RECALL_FLOOR}
            best_pool = qualified or {m: cyc[m] for m in methods}
            best = min(best_pool, key=best_pool.get)
            point = {"sel": sel, "corr": corr, "best_fixed": best,
                     "chosen": chosen["adaptive"], "regret": {}, "recall": {},
                     "mcycles": {}}
            for m in (*methods, "adaptive"):
                r = cyc[m] / cyc[best]
                if rec[m] < RECALL_FLOOR:
                    r = math.inf
                point["regret"][m] = round(r, 3) if math.isfinite(r) \
                    else "inf"
                point["recall"][m] = round(rec[m], 3)
                point["mcycles"][m] = round(cyc[m] / 1e6, 3)
            grid.append(point)
            rows.append({
                "name": f"fig_planner/{ds}/{corr}/sel={sel}",
                "us_per_call": wall["adaptive"],
                "chosen": chosen["adaptive"], "best_fixed": best,
                "regret_adaptive": point["regret"]["adaptive"],
                "recall_adaptive": point["recall"]["adaptive"],
                "best_mcycles": round(cyc[best] / 1e6, 3),
            })

    def max_regret(m):
        vals = [pt["regret"][m] for pt in grid]
        return math.inf if "inf" in vals else max(vals)

    summary = {
        "bench": "planner", "dataset": ds, "recall_floor": RECALL_FLOOR,
        "regret_target": REGRET_TARGET, "planner_menu": list(MENU),
        "grid": grid,
        "max_regret": {m: (round(v, 3) if math.isfinite(v) else "inf")
                       for m in (*methods, "adaptive")
                       for v in [max_regret(m)]},
        "planner_within_target": max_regret("adaptive") <= REGRET_TARGET,
        "fixed_within_target": sorted(
            m for m in methods if max_regret(m) <= REGRET_TARGET),
    }
    return rows, summary


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="2-point CI grid (smoke.sh)")
    ap.add_argument("--ds", default="sift10m")
    args = ap.parse_args()
    sels = (0.05, 0.5) if args.tiny else SELS
    corrs = ("none",) if args.tiny else CORRS
    rows, summary = run(args.ds, sels, corrs)
    # --tiny (CI smoke) must not clobber the tracked full-grid record
    name = "BENCH_planner.tiny.json" if args.tiny else "BENCH_planner.json"
    path = os.path.join(os.path.dirname(__file__), "..", name)
    with open(path, "w") as f:
        f.write(json.dumps(summary) + "\n")
    emit(rows, "fig_planner")
    print(f"# planner max regret: {summary['max_regret']['adaptive']}, "
          f"fixed strategies within {REGRET_TARGET}x everywhere: "
          f"{summary['fixed_within_target'] or 'none'}")
    # the frontier-engine recalibration contract: the planner must stay
    # within the regret target at recall ≥ RECALL_FLOOR at every point
    # (recall checked first — a floor miss also scores regret = inf)
    assert all(pt["recall"]["adaptive"] >= RECALL_FLOOR for pt in
               summary["grid"]), "planner fell below the recall floor"
    assert summary["planner_within_target"], (
        f"planner regret exceeded {REGRET_TARGET}x: "
        f"{summary['max_regret']['adaptive']}")


if __name__ == "__main__":
    main()
