"""Paper Fig. 12: vector-predicate correlation effects on the OpenAI-5M-
shaped dataset (QPS + recall per correlation x selectivity)."""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import emit, get_dataset, run_method
from repro.core import SYSTEM, SearchStats, modeled_qps

CORRS = ("high_pos", "low_pos", "negative")
SELS = (0.01, 0.1, 0.5)
METHODS = ("navix", "sweeping", "scann")


def run(ds="openai5m") -> list[dict]:
    store, _ = get_dataset(ds)
    rows = []
    for corr in CORRS:
        for sel in SELS:
            for m in METHODS:
                rec, srow, wall, _ = run_method(ds, m, sel, corr)
                z = lambda v: jnp.asarray(round(v), jnp.int32)
                stats = SearchStats(z(srow["distance_comps"]),
                                    z(srow["filter_checks"]),
                                    z(srow["hops"]),
                                    z(srow["page_accesses_index"]),
                                    z(srow["page_accesses_heap"]),
                                    z(srow["tmap_lookups"]),
                                    z(srow["reorder_rows"]))
                rows.append({
                    "name": f"fig12/{ds}/{m}/{corr}/sel={sel}",
                    "us_per_call": wall, "recall": round(rec, 3),
                    "modeled_qps": round(modeled_qps(stats, store.dim,
                                                     SYSTEM), 1),
                    "hops": round(srow["hops"], 1),
                })
    return rows


if __name__ == "__main__":
    emit(run(), "fig12")
