"""Paper Table 7: concurrency effects. The PG side is modeled (cycle
amplification at 16T); the TPU-native side is MEASURED: per-query wall
time at batch 1 vs batch 16 (vmap) — batching amortizes weight traffic,
the opposite sign of PG's contention (DESIGN.md §3 'what does not
transfer')."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import (emit, get_bitmaps, get_dataset, get_graph,
                               run_method)
from repro.core import (SYSTEM, GraphExecutor, SearchParams, SearchStats,
                        cycle_breakdown)


def run(ds="openai5m", sel=0.1) -> list[dict]:
    store, queries = get_dataset(ds)
    graph = get_graph(ds)
    bm = get_bitmaps(ds, sel, "none")
    rows = []
    # modeled PG-side 1T vs 16T
    rec, srow, _, _ = run_method(ds, "navix", sel, "none")
    z = lambda v: jnp.asarray(round(v), jnp.int32)
    stats = SearchStats(z(srow["distance_comps"]), z(srow["filter_checks"]),
                        z(srow["hops"]), z(srow["page_accesses_index"]),
                        z(srow["page_accesses_heap"]),
                        z(srow["tmap_lookups"]), z(srow["reorder_rows"]))
    br = cycle_breakdown(stats, store.dim, SYSTEM)
    sysoh = br["index_page_access"] + br["vector_retrieval"]
    rows.append({"name": f"table7/{ds}/navix/modeled",
                 "us_per_call": 0.0,
                 "total_mcycles_1t": round(br["total"] / 1e6, 1),
                 "total_mcycles_16t": round(br["total"] * 1.5 / 1e6, 1),
                 "sysoh_share": round(sysoh / br["total"], 3)})
    # measured TPU-native batching effect
    p = SearchParams(k=10, ef_search=128, beam_width=512,
                     strategy="sweeping", max_hops=2048)
    ex = GraphExecutor(graph, store, strategy="sweeping")
    for b in (1, 16):
        q, m = queries[:b], bm[:b]
        jax.block_until_ready(ex.search(q, m, p).ids)
        t0 = time.perf_counter()
        ids = ex.search(q, m, p).ids
        jax.block_until_ready(ids)
        us = (time.perf_counter() - t0) / b * 1e6
        rows.append({"name": f"table7/{ds}/sweeping/batch={b}",
                     "us_per_call": us, "batch": b})
    return rows


if __name__ == "__main__":
    emit(run(), "table7")
