"""Paper Table 7: concurrency effects. The PG side is modeled (cycle
amplification at 16T); the TPU-native side is MEASURED: per-query wall
time at batch 1 vs batch 16 (vmap) — batching amortizes weight traffic,
the opposite sign of PG's contention (DESIGN.md §3 'what does not
transfer').

Beyond the paper's aggregate-QPS view, the closed-loop batch is also
replayed through the SAME trace-replay harness as bench_serving.py
(`benchmarks.bench_serving.replay`): all requests arrive at t=0 and are
served batch-synchronously vs continuously on one `SlotPool`, so the
closed-loop table and the open-loop curves report p50/p99 per-query
latency through one measurement path (DESIGN.md §11)."""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.bench_serving import replay
from benchmarks.common import (emit, get_bitmaps, get_dataset, get_graph,
                               run_method)
from repro.core import (SYSTEM, GraphExecutor, SearchParams, SearchStats,
                        cycle_breakdown)
from repro.serving.continuous import Request


def _latency_rows(ds, store, queries, graph, bm, nreq: int = 16,
                  width: int = 4, hop_chunk: int = 8) -> list[dict]:
    """Closed-loop trace (all arrivals t=0) through the shared replay
    harness: per-query p50/p99 tick latency, batch-synchronous vs
    continuous on the same slot pool."""
    p = SearchParams(k=10, ef_search=64, beam_width=64, max_hops=600,
                     strategy="sweeping", graph_exec_mode="frontier")
    ex = GraphExecutor(graph, store, strategy="sweeping")
    bm_np = np.asarray(bm)
    q_np = np.asarray(queries)
    reqs = [Request(rid=i, query=q_np[i % q_np.shape[0]],
                    bitmap=bm_np[i % bm_np.shape[0]])
            for i in range(nreq)]
    rows = []
    for mode in ("batch", "continuous"):
        m, _ = replay(ex, p, reqs, width, hop_chunk, mode,
                      slo_ticks=float("inf"))
        rows.append({"name": f"table7/{ds}/sweeping/closed_loop/{mode}",
                     "us_per_call": 0.0, "mode": mode,
                     "p50_ticks": m["p50_ticks"],
                     "p99_ticks": m["p99_ticks"],
                     "mean_ticks": m["mean_ticks"],
                     "slot_utilization": m["slot_utilization"]})
    return rows


def run(ds="openai5m", sel=0.1) -> list[dict]:
    store, queries = get_dataset(ds)
    graph = get_graph(ds)
    bm = get_bitmaps(ds, sel, "none")
    rows = []
    # modeled PG-side 1T vs 16T
    rec, srow, _, _ = run_method(ds, "navix", sel, "none")
    z = lambda v: jnp.asarray(round(v), jnp.int32)
    stats = SearchStats(z(srow["distance_comps"]), z(srow["filter_checks"]),
                        z(srow["hops"]), z(srow["page_accesses_index"]),
                        z(srow["page_accesses_heap"]),
                        z(srow["tmap_lookups"]), z(srow["reorder_rows"]))
    br = cycle_breakdown(stats, store.dim, SYSTEM)
    sysoh = br["index_page_access"] + br["vector_retrieval"]
    rows.append({"name": f"table7/{ds}/navix/modeled",
                 "us_per_call": 0.0,
                 "total_mcycles_1t": round(br["total"] / 1e6, 1),
                 "total_mcycles_16t": round(br["total"] * 1.5 / 1e6, 1),
                 "sysoh_share": round(sysoh / br["total"], 3)})
    # measured TPU-native batching effect
    p = SearchParams(k=10, ef_search=128, beam_width=512,
                     strategy="sweeping", max_hops=2048)
    ex = GraphExecutor(graph, store, strategy="sweeping")
    for b in (1, 16):
        q, m = queries[:b], bm[:b]
        jax.block_until_ready(ex.search(q, m, p).ids)
        t0 = time.perf_counter()
        ids = ex.search(q, m, p).ids
        jax.block_until_ready(ids)
        us = (time.perf_counter() - t0) / b * 1e6
        rows.append({"name": f"table7/{ds}/sweeping/batch={b}",
                     "us_per_call": us, "batch": b})
    # per-query latency distribution via the shared serving harness
    rows.extend(_latency_rows(ds, store, queries, graph, bm))
    return rows


if __name__ == "__main__":
    emit(run(), "table7")
