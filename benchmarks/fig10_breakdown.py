"""Paper Fig. 10: per-component modeled cycle breakdown (SYSTEM regime)
at 1/10/50/80% selectivity on the OpenAI-5M-shaped dataset."""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import emit, get_dataset, run_method
from repro.core import SYSTEM, SearchStats, cycle_breakdown

SELS = (0.01, 0.1, 0.5, 0.8)
METHODS = ("navix", "acorn", "sweeping", "scann")


def run(ds="openai5m") -> list[dict]:
    store, _ = get_dataset(ds)
    rows = []
    for sel in SELS:
        for m in METHODS:
            # per-query page accounting: Fig. 10 models one standalone query
            rec, srow, wall, _ = run_method(ds, m, sel, "none",
                                            page_accounting="per_query")
            z = lambda v: jnp.asarray(round(v), jnp.int32)
            stats = SearchStats(z(srow["distance_comps"]),
                                z(srow["filter_checks"]), z(srow["hops"]),
                                z(srow["page_accesses_index"]),
                                z(srow["page_accesses_heap"]),
                                z(srow["tmap_lookups"]),
                                z(srow["reorder_rows"]))
            br = cycle_breakdown(stats, store.dim, SYSTEM)
            row = {"name": f"fig10/{ds}/{m}/sel={sel}", "us_per_call": wall,
                   "recall": round(rec, 3)}
            row.update({k: round(v / 1e6, 2) for k, v in br.items()})
            rows.append(row)
    return rows


if __name__ == "__main__":
    emit(run(), "fig10")
