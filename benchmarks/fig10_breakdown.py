"""Paper Fig. 10: per-component modeled cycle breakdown (SYSTEM regime)
at 1/10/50/80% selectivity on the OpenAI-5M-shaped dataset.

With --storage, rows gain `total_cold`: the per-query total with the
MEASURED cold buffer-pool miss penalty added (DESIGN.md §8) — the
standalone-query cost when nothing is resident, vs the warm `total` the
classic bars model."""
from __future__ import annotations

import sys

import jax.numpy as jnp

from benchmarks.common import (NUM_QUERIES, emit, get_dataset, run_method,
                               run_storage_measured)
from repro.core import (SYSTEM, SearchStats, cycle_breakdown,
                        measured_miss_penalty)

SELS = (0.01, 0.1, 0.5, 0.8)
# scann_distributed: mesh-path counters now cross the all-gather, so its
# Fig. 10 bars come from the same cycle_breakdown as the local methods
METHODS = ("navix", "acorn", "sweeping", "scann", "scann_distributed")


def _cold_penalty(ds: str, m: str, sel: float, params) -> float:
    res = run_storage_measured(ds, m, sel, params)
    return measured_miss_penalty(res.storage, NUM_QUERIES, SYSTEM)


def run(ds="openai5m", storage=False) -> list[dict]:
    store, _ = get_dataset(ds)
    rows = []
    for sel in SELS:
        for m in METHODS:
            # per-query page accounting: Fig. 10 models one standalone query
            rec, srow, wall, params = run_method(ds, m, sel, "none",
                                                 page_accounting="per_query")
            z = lambda v: jnp.asarray(round(v), jnp.int32)
            stats = SearchStats(z(srow["distance_comps"]),
                                z(srow["filter_checks"]), z(srow["hops"]),
                                z(srow["page_accesses_index"]),
                                z(srow["page_accesses_heap"]),
                                z(srow["tmap_lookups"]),
                                z(srow["reorder_rows"]))
            br = cycle_breakdown(stats, store.dim, SYSTEM)
            row = {"name": f"fig10/{ds}/{m}/sel={sel}", "us_per_call": wall,
                   "recall": round(rec, 3)}
            row.update({k: round(v / 1e6, 2) for k, v in br.items()})
            if storage and m != "scann_distributed":
                # the mesh path carries counters, not page traces
                pen = _cold_penalty(ds, m, sel, params)
                row["total_cold"] = round((br["total"] + pen) / 1e6, 2)
            rows.append(row)
    return rows


if __name__ == "__main__":
    emit(run(storage="--storage" in sys.argv[1:]), "fig10")
