"""Paper Fig. 11: sensitivity to LIMIT k (k in {5, 100}) — search effort
growth per method at low selectivity."""
from __future__ import annotations

from benchmarks.common import emit, run_method

METHODS = ("navix", "sweeping", "scann")


def run(ds="sift10m", sel=0.05) -> list[dict]:
    rows = []
    effort = {}
    for k in (5, 100):
        for m in METHODS:
            rec, srow, wall, _ = run_method(ds, m, sel, "none", k=k)
            key = "hops" if m != "scann" else "hops"  # leaves for scann
            effort.setdefault(m, {})[k] = srow[key]
            rows.append({
                "name": f"fig11/{ds}/{m}/k={k}",
                "us_per_call": wall, "recall": round(rec, 3),
                "hops_or_leaves": round(srow[key], 1),
                "dist_comps": round(srow["distance_comps"]),
            })
    for m in METHODS:
        growth = effort[m][100] / max(effort[m][5], 1e-9)
        rows.append({"name": f"fig11/{ds}/{m}/growth", "us_per_call": 0.0,
                     "hops_growth_5_to_100": round(growth, 2)})
    return rows


if __name__ == "__main__":
    emit(run(), "fig11")
