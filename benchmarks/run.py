"""Benchmark driver: one module per paper table/figure.

Prints `name,us_per_call,derived` CSV rows.  `--fast` trims the grids
(single dataset, fewer selectivities) for CI-style runs.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig9,table6,fig10,fig11,fig12,fig13,"
                         "fig_planner,table2,table3,table4,table5,table7")
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()

    from benchmarks import (fig9_qps, fig10_breakdown, fig11_limit_k,
                            fig12_correlation, fig13_tmap, fig_planner,
                            table2_datasets, table3_build,
                            table4_hnsw_quant, table5_quant, table6_metrics,
                            table7_concurrency)
    from benchmarks.common import emit

    suites = {
        "table2": lambda: table2_datasets.run(),
        "table3": lambda: table3_build.run(
            ("sift10m",) if args.fast else ("sift10m", "openai5m")),
        "fig9": lambda: fig9_qps.run(
            ("sift10m",) if args.fast else ("sift10m", "openai5m"),
            (0.05, 0.3) if args.fast else fig9_qps.SELECTIVITIES),
        "table6": lambda: table6_metrics.run(
            sels=(0.01, 0.1, 0.5) if args.fast
            else table6_metrics.SELECTIVITIES),
        "fig10": lambda: fig10_breakdown.run(),
        "fig11": lambda: fig11_limit_k.run(),
        "fig12": lambda: fig12_correlation.run(),
        "fig13": lambda: fig13_tmap.run(),
        "fig_planner": lambda: fig_planner.run(
            sels=(0.05, 0.5) if args.fast else fig_planner.SELS,
            corrs=("none",) if args.fast else fig_planner.CORRS)[0],
        "table4": lambda: table4_hnsw_quant.run(),
        "table5": lambda: table5_quant.run(),
        "table7": lambda: table7_concurrency.run(),
    }
    chosen = (args.only.split(",") if args.only else list(suites))
    t0 = time.time()
    failures = 0
    for name in chosen:
        print(f"# --- {name} ---", flush=True)
        try:
            t1 = time.time()
            rows = suites[name]()
            emit(rows, name)
            print(f"# {name}: {len(rows)} rows in {time.time()-t1:.0f}s",
                  flush=True)
        except Exception as e:  # keep the suite running
            failures += 1
            import traceback
            traceback.print_exc()
            print(f"# {name}: FAILED {type(e).__name__}: {e}", flush=True)
    print(f"# total {time.time()-t0:.0f}s, failures={failures}")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
