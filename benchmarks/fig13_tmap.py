"""Paper Fig. 13: translation-map ablation — cycle breakdown with and
without the TM for the filter-first methods."""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import emit, get_dataset, run_method
from repro.core import SYSTEM, SearchStats, cycle_breakdown

SELS = (0.01, 0.1, 0.5, 0.8)


def run(ds="openai5m") -> list[dict]:
    store, _ = get_dataset(ds)
    rows = []
    for sel in SELS:
        for tm in (True, False):
            rec, srow, wall, _ = run_method(ds, "navix", sel, "none", tm=tm,
                                            page_accounting="per_query")
            z = lambda v: jnp.asarray(round(v), jnp.int32)
            stats = SearchStats(z(srow["distance_comps"]),
                                z(srow["filter_checks"]), z(srow["hops"]),
                                z(srow["page_accesses_index"]),
                                z(srow["page_accesses_heap"]),
                                z(srow["tmap_lookups"]),
                                z(srow["reorder_rows"]))
            br = cycle_breakdown(stats, store.dim, SYSTEM)
            rows.append({
                "name": f"fig13/{ds}/navix/tm={'on' if tm else 'off'}"
                        f"/sel={sel}",
                "us_per_call": wall, "recall": round(rec, 3),
                "total_mcycles": round(br["total"] / 1e6, 2),
                "metadata_fetch_share": round(
                    (br["index_page_access"] + br["translation_map"])
                    / br["total"], 3),
            })
    return rows


if __name__ == "__main__":
    emit(run(), "fig13")
