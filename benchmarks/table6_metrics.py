"""Paper Table 6: internal index metrics across selectivities on the
OpenAI-5M-shaped dataset (no correlation).

With --storage, each chosen config is re-run through a cold paged
StorageEngine (DESIGN.md §8) and the row gains the MEASURED page
accounting: pool-logical page accesses (exact == the analytic counters
for scann; ≤ for graph strategies — zoom-in revisit delta) and the cold
buffer-pool hit rate."""
from __future__ import annotations

import sys

from benchmarks.common import emit, run_method, run_storage_measured

SELECTIVITIES = (0.01, 0.02, 0.05, 0.1, 0.2, 0.3, 0.5, 0.8, 0.9)
# scann_distributed: the mesh-sharded path, included since its per-query
# SearchStats ride the all-gather (core/distributed.py)
METHODS = ("navix", "acorn", "sweeping", "scann", "scann_distributed")


def _measured(ds: str, m: str, sel: float, params) -> dict:
    res = run_storage_measured(ds, m, sel, params)
    return {
        "pages_measured": round(float(res.storage.index_pages.mean()
                                      + res.storage.heap_pages.mean())),
        "pool_hit_rate_cold": round(res.storage.hit_rate, 3),
    }


def run(ds="openai5m", sels=SELECTIVITIES, storage=False) -> list[dict]:
    rows = []
    for sel in sels:
        for m in METHODS:
            # Table 6 tabulates per-query counters; keep legacy accounting
            rec, srow, wall, params = run_method(
                ds, m, sel, "none", page_accounting="per_query")
            row = {
                "name": f"table6/{ds}/{m}/sel={sel}",
                "us_per_call": wall,
                "recall": round(rec, 3),
                "dist_comps": round(srow["distance_comps"]),
                "filter_checks": round(srow["filter_checks"]),
                "hops_or_leaves": round(srow["hops"], 1),
                "reorder": round(srow["reorder_rows"]),
                "page_accesses": round(srow["page_accesses_index"]
                                       + srow["page_accesses_heap"]),
            }
            if storage and m != "scann_distributed":
                # the mesh path carries counters, not page traces
                row.update(_measured(ds, m, sel, params))
            rows.append(row)
    return rows


if __name__ == "__main__":
    emit(run(storage="--storage" in sys.argv[1:]), "table6")
