"""Paper Table 6: internal index metrics across selectivities on the
OpenAI-5M-shaped dataset (no correlation)."""
from __future__ import annotations

from benchmarks.common import emit, run_method

SELECTIVITIES = (0.01, 0.02, 0.05, 0.1, 0.2, 0.3, 0.5, 0.8, 0.9)
METHODS = ("navix", "acorn", "sweeping", "scann")


def run(ds="openai5m", sels=SELECTIVITIES) -> list[dict]:
    rows = []
    for sel in sels:
        for m in METHODS:
            # Table 6 tabulates per-query counters; keep legacy accounting
            rec, srow, wall, _ = run_method(ds, m, sel, "none",
                                            page_accounting="per_query")
            rows.append({
                "name": f"table6/{ds}/{m}/sel={sel}",
                "us_per_call": wall,
                "recall": round(rec, 3),
                "dist_comps": round(srow["distance_comps"]),
                "filter_checks": round(srow["filter_checks"]),
                "hops_or_leaves": round(srow["hops"], 1),
                "reorder": round(srow["reorder_rows"]),
                "page_accesses": round(srow["page_accesses_index"]
                                       + srow["page_accesses_heap"]),
            })
    return rows


if __name__ == "__main__":
    emit(run(), "table6")
