"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # optional dev dep (requirements-dev.txt):
    # property tests skip individually; plain tests in this module still run
    def given(*a, **k):
        import pytest
        return lambda f: pytest.mark.skip(
            reason="hypothesis not installed")(f)

    def settings(*a, **k):
        return lambda f: f

    class st:  # stub strategies so decorator arguments still evaluate
        integers = floats = sampled_from = staticmethod(
            lambda *a, **k: None)

from repro.core.types import pack_bool_bitmap
from repro.kernels import ops, ref


@settings(max_examples=12, deadline=None)
@given(q=st.integers(1, 70), n=st.integers(1, 300),
       d=st.integers(1, 160), metric=st.sampled_from(["l2", "ip"]),
       seed=st.integers(0, 99))
def test_distance_matrix_sweep(q, n, d, metric, seed):
    rng = np.random.RandomState(seed)
    qs = jnp.asarray(rng.randn(q, d).astype(np.float32))
    xs = jnp.asarray(rng.randn(n, d).astype(np.float32))
    a = ops.distance_matrix(qs, xs, metric, use_pallas=True)
    b = ref.distance_matrix_ref(qs, xs, metric)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4,
                               rtol=1e-4)


@settings(max_examples=10, deadline=None)
@given(nl=st.integers(1, 6), c=st.integers(1, 40), d=st.integers(1, 100),
       metric=st.sampled_from(["l2", "ip"]), density=st.floats(0.0, 1.0),
       seed=st.integers(0, 99))
def test_leaf_scan_sweep(nl, c, d, metric, density, seed):
    rng = np.random.RandomState(seed)
    n_rows = 1024
    tiles = jnp.asarray(rng.randint(-127, 128, (nl, c, d)).astype(np.int8))
    rowids = rng.permutation(n_rows)[: nl * c].reshape(nl, c).astype(
        np.int32)
    rowids[rng.rand(nl, c) < 0.1] = -1        # padding holes
    scale = jnp.asarray(np.abs(rng.randn(d)).astype(np.float32) * 0.02)
    mean = jnp.asarray(rng.randn(d).astype(np.float32) * 0.05)
    bm = pack_bool_bitmap(rng.rand(n_rows) < density)
    q = jnp.asarray(rng.randn(d).astype(np.float32))
    a = ops.leaf_scan(q, tiles, jnp.asarray(rowids), scale, mean, bm,
                      metric, use_pallas=True)
    b = ref.leaf_scan_ref(q, tiles, jnp.asarray(rowids), scale, mean, bm,
                          metric)
    fa, fb = np.isfinite(np.asarray(a)), np.isfinite(np.asarray(b))
    assert (fa == fb).all()
    np.testing.assert_allclose(np.asarray(a)[fa], np.asarray(b)[fb],
                               atol=2e-3, rtol=1e-3)


@settings(max_examples=12, deadline=None)
@given(n=st.integers(1, 5000), k=st.integers(1, 64),
       seed=st.integers(0, 99))
def test_topk_sweep(n, k, seed):
    k = min(k, n)
    rng = np.random.RandomState(seed)
    v = jnp.asarray(rng.randn(n).astype(np.float32))
    av, ai = ops.topk_smallest(v, k, use_pallas=True)
    bv, bi = ref.topk_partial_ref(v, k)
    np.testing.assert_allclose(np.sort(np.asarray(av)),
                               np.sort(np.asarray(bv)), atol=1e-6)
    # indices must point at the right values
    va = np.asarray(v)[np.asarray(ai)]
    np.testing.assert_allclose(np.sort(va), np.sort(np.asarray(bv)),
                               atol=1e-6)


def test_leaf_scan_all_filtered():
    """Fully-failing filter -> all +inf (empty result is well-defined)."""
    rng = np.random.RandomState(0)
    tiles = jnp.asarray(rng.randint(-127, 128, (2, 8, 16)).astype(np.int8))
    rowids = jnp.asarray(np.arange(16).reshape(2, 8).astype(np.int32))
    bm = pack_bool_bitmap(np.zeros(64, bool))
    out = ops.leaf_scan(jnp.ones((16,)), tiles, rowids, jnp.ones((16,)),
                        jnp.zeros((16,)), bm, "l2", use_pallas=True)
    assert not np.isfinite(np.asarray(out)).any()
