"""Crash-consistent live ingestion (DESIGN.md §12) — functional layer.

The correctness bar for the streaming-mutability subsystem is
BIT-IDENTICALITY, not approximate agreement: a `MutableIndex` search
(base executor top-k merged with the delta tier's exact scan, tombstones
AND-NOT-composed into the filter) must equal `bruteforce.filtered_knn`
over a from-scratch rebuild of the union at every step of every
insert/delete/search interleaving — including immediately after
compaction.  Covers:

  - `merge_topk` / `bitmap_andnot` primitives (types.py)
  - DeltaTier / Tombstones mechanics (storage/delta.py)
  - scripted + randomized (hypothesis when available) interleavings vs
    the rebuild oracle, selective bitmaps and tombstone composition
    included
  - compaction: recall within 0.02 of a cold rebuild, dead rows pruned
    from ScaNN postings, post-compaction searches still oracle-identical
  - buffer-pool dirty-page tracking, flush/invalidate/reset semantics
  - costmodel delta-scan and write-amplification terms
  - continuous serving with live ingest: snapshot-at-admit isolation
"""
import dataclasses
import os

import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # optional dev dep: property test skips,
    HAVE_HYPOTHESIS = False  # the deterministic grid below still runs

from repro.core import SearchParams
from repro.core.bruteforce import filtered_knn
from repro.core.executor import GraphExecutor
from repro.core.mutable import MutableIndex, rebuild_oracle_store
from repro.core.types import (bitmap_andnot, bitset_words, merge_topk,
                              topk_smallest)
from repro.core import costmodel
from repro.serving.continuous import (ContinuousServer, IngestEvent,
                                      Request, results_in_order)
from repro.storage.bufferpool import BufferPool
from repro.storage.delta import DeltaFull, DeltaTier, Tombstones

K = 5
DIM = 16


def _params(**kw):
    base = dict(k=K, strategy="bruteforce")
    base.update(kw)
    return SearchParams(**base)


def _mk_index(tmp_path, base, tag="a", **kw):
    kw.setdefault("with_graph", False)
    kw.setdefault("with_scann", False)
    kw.setdefault("delta_capacity", 32)
    return MutableIndex(base, str(tmp_path / f"wal_{tag}"),
                        str(tmp_path / f"ck_{tag}"), **kw)


def _oracle(index, bitmaps, queries, k=K):
    """filtered_knn over the capacity-padded rebuild — the ground truth
    every merged search must equal bit-for-bit."""
    store, live = rebuild_oracle_store(index)
    bm = np.asarray(bitmaps, np.uint32)
    w = live.shape[0]
    if bm.shape[-1] < w:
        bm = np.concatenate([bm, np.zeros(
            bm.shape[:-1] + (w - bm.shape[-1],), np.uint32)], -1)
    return filtered_knn(store, jnp.asarray(queries),
                        jnp.asarray(bm & live[None]), k)


def _assert_matches_oracle(index, queries, bitmaps, ctx=""):
    res = index.search(jnp.asarray(queries), jnp.asarray(bitmaps),
                       _params())
    od, oi = _oracle(index, bitmaps, queries)
    np.testing.assert_array_equal(np.asarray(oi), np.asarray(res.ids),
                                  err_msg=f"ids diverged from oracle {ctx}")
    assert np.array_equal(np.asarray(od), np.asarray(res.dists),
                          equal_nan=True), f"dists diverged {ctx}"


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------

def test_merge_topk_equals_joint_topk():
    rng = np.random.RandomState(0)
    da = rng.rand(3, 7).astype(np.float32)
    db = rng.rand(3, 4).astype(np.float32)
    ia = rng.permutation(7)[None].repeat(3, 0).astype(np.int32)
    ib = (100 + rng.permutation(4))[None].repeat(3, 0).astype(np.int32)
    md, mi = merge_topk(jnp.asarray(da), jnp.asarray(ia),
                        jnp.asarray(db), jnp.asarray(ib), 5)
    jd, pos = topk_smallest(jnp.concatenate([da, db], -1), 5)
    ji = np.take_along_axis(np.concatenate([ia, ib], -1),
                            np.asarray(pos), -1)
    np.testing.assert_array_equal(np.asarray(mi), ji)
    np.testing.assert_array_equal(np.asarray(md), np.asarray(jd))


def test_merge_topk_inf_padding_yields_minus_one():
    da = jnp.asarray([[0.5, jnp.inf]])
    ia = jnp.asarray([[3, 9]], dtype=jnp.int32)
    db = jnp.full((1, 2), jnp.inf)
    ib = jnp.asarray([[7, 8]], dtype=jnp.int32)
    md, mi = merge_topk(da, ia, db, ib, 3)
    np.testing.assert_array_equal(np.asarray(mi)[0], [3, -1, -1])
    assert np.isinf(np.asarray(md)[0, 1:]).all()


def test_bitmap_andnot_composition():
    bm = jnp.asarray([[0xFFFFFFFF, 0xFFFFFFFF, 0x0000FFFF]],
                     dtype=jnp.uint32)
    minus = jnp.asarray([0x1, 0x80000000], dtype=jnp.uint32)
    out = np.asarray(bitmap_andnot(bm, minus))
    assert out[0, 0] == 0xFFFFFFFE
    assert out[0, 1] == 0x7FFFFFFF
    assert out[0, 2] == 0x0000FFFF      # beyond minus: untouched
    # input not mutated
    assert np.asarray(bm)[0, 0] == 0xFFFFFFFF


# ---------------------------------------------------------------------------
# delta tier / tombstones mechanics
# ---------------------------------------------------------------------------

def test_delta_tier_append_ids_and_full():
    tier = DeltaTier(base_n=100, capacity=8, dim=4)
    rng = np.random.RandomState(1)
    ids = tier.append(rng.randn(3, 4).astype(np.float32))
    np.testing.assert_array_equal(ids, [100, 101, 102])
    assert tier.count == 3 and 0.0 < tier.fill < 1.0
    tier.append(rng.randn(5, 4).astype(np.float32))
    assert tier.fill == 1.0
    with pytest.raises(DeltaFull):
        tier.append(rng.randn(1, 4).astype(np.float32))
    v = tier.version
    tier.reset(base_n=108)
    assert tier.count == 0 and tier.base_n == 108 and tier.version == v + 1
    assert not tier.vectors.any()


def test_tombstones_mark_and_live_mask():
    tomb = Tombstones(70)
    assert tomb.mark(np.array([3, 33, 64])) == 3
    assert tomb.count == 3
    assert tomb.mark(np.array([3])) == 0          # idempotent
    np.testing.assert_array_equal(
        tomb.is_dead(np.array([3, 4, 64])), [True, False, True])
    bm = np.full((1, 3), 0xFFFFFFFF, np.uint32)
    before = bm.copy()
    live = tomb.live_mask(bm)
    np.testing.assert_array_equal(bm, before)      # input untouched
    assert not (live[0, 0] & (1 << 3))
    assert not (live[0, 1] & (1 << 1))
    assert not (live[0, 2] & 1)
    assert live[0, 0] & (1 << 4)
    with pytest.raises(ValueError):
        tomb.mark(np.array([70]))


# ---------------------------------------------------------------------------
# oracle equivalence
# ---------------------------------------------------------------------------

def _run_ops(index, ops, queries, rng, sel=0.6):
    """Apply (kind, payload) ops; after each, assert oracle equality under
    both an all-pass and a random selective bitmap."""
    w = index.words()
    for step, (kind, payload) in enumerate(ops):
        if kind == "insert":
            index.insert(payload)
        else:
            index.delete(payload)
        full = np.full((queries.shape[0], w), 0xFFFFFFFF, np.uint32)
        bits = (rng.rand(queries.shape[0], w * 32) < sel)
        selw = np.packbits(bits, axis=-1,
                           bitorder="little").view(np.uint32)
        _assert_matches_oracle(index, queries, full, f"step {step} full")
        _assert_matches_oracle(index, queries, selw,
                               f"step {step} selective")


def test_scripted_interleaving_matches_oracle(tmp_path):
    rng = np.random.RandomState(3)
    base = rng.randn(120, DIM).astype(np.float32)
    idx = _mk_index(tmp_path, base)
    queries = rng.randn(4, DIM).astype(np.float32)
    ops = [
        ("insert", rng.randn(6, DIM).astype(np.float32)),
        ("delete", np.array([0, 5, 121], np.int64)),    # base + delta ids
        ("insert", rng.randn(10, DIM).astype(np.float32)),
        ("delete", np.array([121, 130], np.int64)),     # re-delete + delta
        ("insert", rng.randn(1, DIM).astype(np.float32)),
        ("delete", np.arange(20, 40, dtype=np.int64)),  # dense base kill
    ]
    _run_ops(idx, ops, queries, rng)
    assert idx.live_count == 120 + 17 - 24   # 121 deleted twice
    idx.close()


def test_random_interleaving_grid_matches_oracle(tmp_path):
    """Deterministic randomized interleavings — always runs (the
    hypothesis property below strengthens it when the dep exists)."""
    for seed in (0, 1, 2):
        rng = np.random.RandomState(seed)
        base = rng.randn(80, DIM).astype(np.float32)
        idx = _mk_index(tmp_path, base, tag=f"g{seed}", delta_capacity=64)
        queries = rng.randn(3, DIM).astype(np.float32)
        ops = []
        for _ in range(8):
            if rng.rand() < 0.6 or idx is None:
                ops.append(("insert",
                            rng.randn(rng.randint(1, 6),
                                      DIM).astype(np.float32)))
            else:
                hi = 80 + sum(o[1].shape[0] for o in ops
                              if o[0] == "insert")
                ops.append(("delete",
                            rng.randint(0, hi, size=3).astype(np.int64)))
        _run_ops(idx, ops, queries, rng, sel=0.5)
        idx.close()


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
def test_interleaving_property_matches_oracle(tmp_path):
    """Property form: ANY insert/delete/search interleaving is oracle-
    identical at every step (tombstone ∧ filter-bitmap composition
    included)."""

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2 ** 16), nops=st.integers(1, 10),
           sel=st.floats(0.1, 1.0))
    def prop(seed, nops, sel):
        rng = np.random.RandomState(seed)
        base = rng.randn(60, DIM).astype(np.float32)
        idx = _mk_index(tmp_path, base, tag=f"h{seed}_{nops}",
                        delta_capacity=64)
        queries = rng.randn(2, DIM).astype(np.float32)
        ops = []
        for _ in range(nops):
            if rng.rand() < 0.55:
                ops.append(("insert", rng.randn(
                    rng.randint(1, 5), DIM).astype(np.float32)))
            else:
                hi = 60 + sum(o[1].shape[0] for o in ops
                              if o[0] == "insert")
                ops.append(("delete", rng.randint(
                    0, hi, size=rng.randint(1, 4)).astype(np.int64)))
        _run_ops(idx, ops, queries, rng, sel=sel)
        idx.close()

    prop()


def test_delta_rows_surface_and_tombstones_kill_everywhere(tmp_path):
    """A planted delta row must rank first; tombstoning it removes it
    from the merged answer under every base method."""
    rng = np.random.RandomState(5)
    base = rng.randn(150, DIM).astype(np.float32)
    idx = _mk_index(tmp_path, base, with_graph=True, with_scann=True,
                    num_leaves=8, graph_m=8, ef_construction=32)
    row = rng.randn(1, DIM).astype(np.float32)
    (rid,) = idx.insert(row)
    assert rid == 150
    q = row + 0.001 * rng.randn(1, DIM).astype(np.float32)
    bm = np.full((1, idx.words()), 0xFFFFFFFF, np.uint32)
    p = _params(ef_search=48, beam_width=48, max_hops=200, num_leaves_to_search=8)
    for method in ("bruteforce", "scann", "sweeping"):
        res = idx.search(jnp.asarray(q), jnp.asarray(bm),
                         dataclasses.replace(p, strategy=method),
                         method=method)
        assert int(np.asarray(res.ids)[0, 0]) == rid, method
    idx.delete(np.array([rid], np.int64))
    for method in ("bruteforce", "scann", "sweeping"):
        res = idx.search(jnp.asarray(q), jnp.asarray(bm),
                         dataclasses.replace(p, strategy=method),
                         method=method)
        assert rid not in np.asarray(res.ids), method
    idx.close()


# ---------------------------------------------------------------------------
# compaction
# ---------------------------------------------------------------------------

def test_compaction_folds_delta_and_stays_oracle_identical(tmp_path):
    rng = np.random.RandomState(7)
    base = rng.randn(100, DIM).astype(np.float32)
    idx = _mk_index(tmp_path, base, delta_capacity=16)
    queries = rng.randn(4, DIM).astype(np.float32)
    idx.insert(rng.randn(12, DIM).astype(np.float32))
    idx.delete(np.array([2, 104], np.int64))
    idx.compact()
    assert idx.base_n == 112 and idx.delta.count == 0
    assert idx.compactions == 1
    w = idx.words()
    full = np.full((4, w), 0xFFFFFFFF, np.uint32)
    _assert_matches_oracle(idx, queries, full, "post-compaction")
    # deleted rows stay dead across the fold; inserts still work after
    res = idx.search(jnp.asarray(queries), jnp.asarray(full), _params())
    assert 2 not in np.asarray(res.ids) and 104 not in np.asarray(res.ids)
    idx.insert(rng.randn(3, DIM).astype(np.float32))
    _assert_matches_oracle(idx, queries, full, "insert after compaction")
    idx.close()


def test_compaction_auto_triggers_and_prunes_scann_postings(tmp_path):
    rng = np.random.RandomState(9)
    base = rng.randn(90, DIM).astype(np.float32)
    idx = _mk_index(tmp_path, base, delta_capacity=8, with_scann=True,
                    num_leaves=4)
    idx.insert(rng.randn(6, DIM).astype(np.float32))
    idx.delete(np.array([10, 92], np.int64))
    idx.insert(rng.randn(6, DIM).astype(np.float32))   # overflow -> compact
    assert idx.compactions == 1 and idx.base_n == 96
    rowids = np.asarray(idx.scann.leaf_rowids)
    assert 10 not in rowids and 92 not in rowids       # postings pruned
    assert idx.tombstones.is_dead(np.array([10, 92])).all()
    idx.close()


def test_compaction_recall_within_cold_rebuild(tmp_path):
    """Compacted index vs a cold index built directly over the same
    union: recall@10 against brute-force ground truth within 0.02."""
    rng = np.random.RandomState(11)
    base = rng.randn(400, DIM).astype(np.float32)
    extra = rng.randn(48, DIM).astype(np.float32)
    idx = _mk_index(tmp_path, base, tag="rc", delta_capacity=48,
                    with_graph=True, with_scann=True, num_leaves=8,
                    graph_m=8, ef_construction=32)
    idx.insert(extra)
    idx.compact()
    cold = _mk_index(tmp_path, np.concatenate([base, extra]), tag="cold",
                     delta_capacity=48, with_graph=True, with_scann=True,
                     num_leaves=8, graph_m=8, ef_construction=32)
    queries = rng.randn(8, DIM).astype(np.float32)
    w = idx.words()
    bm = np.full((8, w), 0xFFFFFFFF, np.uint32)
    p = _params(k=10, strategy="scann", num_leaves_to_search=4)
    gt = _oracle(idx, bm, queries, k=10)[1]
    recalls = {}
    for name, ix in (("compacted", idx), ("cold", cold)):
        got = np.asarray(ix.search(jnp.asarray(queries), jnp.asarray(bm),
                                   p, method="scann").ids)
        hits = sum(len(set(np.asarray(gt)[i]) & set(got[i]))
                   for i in range(8))
        recalls[name] = hits / (8 * 10)
    assert recalls["compacted"] >= recalls["cold"] - 0.02, recalls
    idx.close(); cold.close()


# ---------------------------------------------------------------------------
# buffer pool: dirty pages / invalidate / reset
# ---------------------------------------------------------------------------

def test_bufferpool_dirty_tracking_and_flush():
    pool = BufferPool(4, segments={"delta": (0, 10)})
    pool.access(np.array([0, 1]), dirty=True)
    st_ = pool.state()
    assert st_.dirty == 2 and st_.dirty_by_segment["delta"] == 2
    assert pool.counters.dirtied == 2
    # flush: pages stay resident, dirty drains, write-back counted
    assert pool.flush() == 2
    st_ = pool.state()
    assert st_.dirty == 0 and pool.counters.page_writes == 2
    assert st_.used == 2


def test_bufferpool_dirty_eviction_writes_back():
    pool = BufferPool(2, segments={"delta": (0, 100)})
    pool.access(np.array([0, 1]), dirty=True)
    base_writes = pool.counters.page_writes
    pool.access(np.array([2, 3]))          # evicts both dirty victims
    assert pool.counters.page_writes == base_writes + 2
    assert pool.state().dirty == 0


def test_bufferpool_invalidate_drops_without_writeback():
    pool = BufferPool(8, segments={"scann": (0, 4),
                                            "delta": (4, 8)})
    pool.access(np.array([0, 1, 5]), dirty=True)
    writes = pool.counters.page_writes
    dropped = pool.invalidate(0, 4)        # compaction kills scann pages
    assert dropped == 2
    assert pool.counters.page_writes == writes        # NO write-back
    assert pool.counters.invalidated == 2
    st_ = pool.state()
    assert st_.dirty == 1 and st_.dirty_by_segment.get("scann", 0) == 0
    # reset() is the cold-restart: dirty dropped silently (durability is
    # the WAL's job, not the pool's)
    pool.reset()
    assert pool.state().dirty == 0 and pool.state().used == 0


# ---------------------------------------------------------------------------
# costmodel: delta scan + write amplification
# ---------------------------------------------------------------------------

def test_costmodel_delta_scan_terms():
    c0 = costmodel.delta_scan_counters(0, DIM, 0.5)
    assert c0["filter_checks"] == 0 and c0["distance_comps"] == 0
    c = costmodel.delta_scan_counters(256, DIM, 0.5)
    assert c["filter_checks"] == 256
    assert 0 < c["distance_comps"] <= 256
    lo = costmodel.delta_scan_cycles(64, DIM, 0.5)
    hi = costmodel.delta_scan_cycles(1024, DIM, 0.5)
    assert 0 < lo < hi


def test_costmodel_write_amplification():
    assert costmodel.write_amplification(0, 0) == 1.0          # idle
    assert costmodel.write_amplification(0, 3) == np.inf
    wa = costmodel.write_amplification(1024, 2, wal_bytes=2048)
    assert wa == (2048 + 2 * costmodel.PAGE_BYTES_WA) / 1024


def test_costmodel_should_compact_policy():
    # fill pressure alone triggers
    assert costmodel.should_compact(96, 100, 10_000, DIM, 0.5)
    # near-empty small delta over a huge base: keep accumulating
    assert not costmodel.should_compact(4, 1024, 1_000_000, DIM, 0.5)
    # scan tax grows with query volume until folding pays
    heavy = costmodel.should_compact(512, 10_000, 2_000, DIM, 1.0,
                                     queries_per_epoch=1e9)
    assert heavy


# ---------------------------------------------------------------------------
# continuous serving with live ingest
# ---------------------------------------------------------------------------

def _graph_params():
    return SearchParams(k=K, ef_search=32, beam_width=32, max_hops=150,
                        strategy="sweeping", graph_exec_mode="frontier")


def test_serving_ingest_visible_after_tick(tmp_path):
    """Mutations applied at tick 0; every later-arriving request's merged
    answer equals MutableIndex.search on the post-mutation state."""
    rng = np.random.RandomState(13)
    base = rng.randn(250, DIM).astype(np.float32)
    idx = _mk_index(tmp_path, base, tag="srv", delta_capacity=64,
                    with_graph=True, num_leaves=8, graph_m=8,
                    ef_construction=32)
    p = _graph_params()
    ex = GraphExecutor(idx.graph, idx.store, strategy="sweeping")
    nq = 4
    queries = rng.randn(nq, DIM).astype(np.float32)
    ins = rng.randn(8, DIM).astype(np.float32)
    queries[0] = ins[0] + 0.01 * rng.randn(DIM).astype(np.float32)
    bms = np.full((nq, idx.words()), 0xFFFFFFFF, np.uint32)
    events = [IngestEvent(tick=0, kind="insert", rows=ins),
              IngestEvent(tick=0, kind="delete",
                          ids=np.array([7, 251], np.int64))]
    reqs = [Request(rid=i, query=queries[i], bitmap=bms[i], arrival=1)
            for i in range(nq)]
    srv = ContinuousServer(ex, p, width=2, hop_chunk=8, index=idx,
                           ingest=events)
    recs, info = srv.serve(reqs, mode="continuous")
    assert info["ingest_inserts"] == 1 and info["ingest_deletes"] == 1
    ids, dists = results_in_order(recs, nq, p.k)
    ref = idx.search(jnp.asarray(queries), jnp.asarray(bms), p,
                     method="sweeping")
    np.testing.assert_array_equal(np.asarray(ref.ids), ids)
    assert np.array_equal(np.asarray(ref.dists), dists, equal_nan=True)
    assert int(ids[0, 0]) == 250          # planted delta row ranks first
    assert 251 not in ids                 # tombstoned delta row gone
    idx.close()


def test_serving_snapshot_isolation_mid_flight(tmp_path):
    """A request in flight when an insert lands must NOT see it; a
    request admitted afterwards must."""
    rng = np.random.RandomState(17)
    base = rng.randn(250, DIM).astype(np.float32)
    idx = _mk_index(tmp_path, base, tag="iso", delta_capacity=64,
                    with_graph=True, num_leaves=8, graph_m=8,
                    ef_construction=32)
    p = _graph_params()
    ex = GraphExecutor(idx.graph, idx.store, strategy="sweeping")
    q = rng.randn(2, DIM).astype(np.float32)
    ins = rng.randn(4, DIM).astype(np.float32)
    bms = np.full((2, idx.words()), 0xFFFFFFFF, np.uint32)
    reqs = [Request(rid=0, query=q[0], bitmap=bms[0], arrival=0),
            Request(rid=1, query=q[1], bitmap=bms[1], arrival=60)]
    srv = ContinuousServer(ex, p, width=1, hop_chunk=8, index=idx,
                           ingest=[IngestEvent(tick=2, kind="insert",
                                               rows=ins)])
    recs, _ = srv.serve(reqs, mode="continuous")
    assert recs[0]["delta_count"] == 0     # admitted before the insert
    assert recs[1]["delta_count"] == 4     # admitted after
    idx.close()
