"""Property tests: bitmap pack/probe and the workload generator (paper §4)."""
import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # optional dev dep (requirements-dev.txt):
    # property tests skip individually; plain tests in this module still run
    def given(*a, **k):
        import pytest
        return lambda f: pytest.mark.skip(
            reason="hypothesis not installed")(f)

    def settings(*a, **k):
        return lambda f: f

    class st:  # stub strategies so decorator arguments still evaluate
        integers = floats = sampled_from = staticmethod(
            lambda *a, **k: None)

from repro.core import (CORRELATIONS, VectorStore, WorkloadSpec, pack_bitmap,
                        pack_bool_bitmap, probe_bitmap, unpack_bitmap)
from repro.core.workload import (empirical_correlation,
                                 generate_passing_rows, generate_bitmaps)


@settings(max_examples=30, deadline=None)
@given(n=st.integers(1, 2000), seed=st.integers(0, 10_000))
def test_bitmap_roundtrip(n, seed):
    rng = np.random.RandomState(seed)
    bits = rng.rand(n) < rng.rand()
    bm = pack_bool_bitmap(bits)
    assert bm.shape == ((n + 31) // 32,)
    back = unpack_bitmap(bm, n)
    assert (back == bits).all()
    ids = jnp.arange(n)
    probed = probe_bitmap(bm, ids)
    assert (np.asarray(probed) == bits).all()


@settings(max_examples=20, deadline=None)
@given(n=st.integers(32, 500), k=st.integers(1, 50), seed=st.integers(0, 99))
def test_probe_negative_ids_false(n, k, seed):
    rng = np.random.RandomState(seed)
    rows = rng.choice(n, size=min(k, n), replace=False)
    bm = pack_bitmap(rows, n)
    assert not bool(probe_bitmap(bm, jnp.array([-1]))[0])
    assert bool(np.asarray(probe_bitmap(bm, jnp.asarray(rows))).all())


@pytest.mark.parametrize("sel", [0.01, 0.1, 0.5, 0.9])
def test_selectivity_exact(small_dataset, sel):
    store, queries = small_dataset
    rows = generate_passing_rows(store, queries[:3],
                                 WorkloadSpec(sel, "none"), seed=1)
    want = max(1, round(sel * store.n))
    for r in rows:
        assert len(np.unique(r)) == len(r) == want


def test_correlation_ordering(small_dataset):
    """high_pos > med_pos > low_pos > none > negative (paper Fig. 8)."""
    store, queries = small_dataset
    means = {}
    for corr in CORRELATIONS:
        rows = generate_passing_rows(store, queries,
                                     WorkloadSpec(0.1, corr), seed=2)
        vals = [empirical_correlation(store, queries[i], rows[i], k=50)
                for i in range(queries.shape[0])]
        means[corr] = float(np.mean(vals))
    assert means["high_pos"] > means["med_pos"] > means["low_pos"]
    assert means["low_pos"] > means["none"] > means["negative"]
    assert means["negative"] < 0.05


def test_bitmaps_match_rows(small_dataset):
    store, queries = small_dataset
    spec = WorkloadSpec(0.2, "med_pos")
    rows = generate_passing_rows(store, queries[:2], spec, seed=3)
    bms = generate_bitmaps(store, queries[:2], spec, seed=3)
    for i in range(2):
        bits = unpack_bitmap(np.asarray(bms[i]), store.n)
        assert set(np.where(bits)[0]) == set(np.asarray(rows[i]).tolist())


def test_high_pos_within_pool(small_dataset):
    """High positive correlation samples only from the closest third."""
    store, queries = small_dataset
    from repro.core.workload import full_distances
    rows = generate_passing_rows(store, queries[:2],
                                 WorkloadSpec(0.05, "high_pos"), seed=4)
    d = np.asarray(full_distances(store, queries[:2]))
    for i, r in enumerate(rows):
        order = np.argsort(d[i])
        pool = set(order[: int(np.ceil(store.n / 3))].tolist())
        assert set(np.asarray(r).tolist()) <= pool
