"""Paged storage engine (src/repro/storage/, DESIGN.md §8).

Three layers of guarantees:

  * BufferPool invariants — capacity never exceeded, LRU eviction order,
    hit + miss == logical, batch-dedup idempotence (deterministic tests +
    hypothesis property tests when the dev dep is installed);
  * storage-on vs legacy executor paths are BIT-IDENTICAL (ids, dists,
    all seven SearchStats counters) across strategies × selectivity —
    trace collection is write-only bookkeeping;
  * measured logical page counters agree with the analytic SearchStats
    counters: exactly for scann (per_query and batch accounting) and
    bruteforce, and as a bounded under-count for graph strategies (the
    documented zoom-in-revisit / rank-rescore delta).
"""
import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # optional dev dep (requirements-dev.txt):
    # property tests skip individually; plain tests in this module still run
    def given(*a, **k):
        return lambda f: pytest.mark.skip(
            reason="hypothesis not installed")(f)

    def settings(*a, **k):
        return lambda f: f

    class st:  # stub strategies so decorator arguments still evaluate
        integers = floats = lists = sampled_from = booleans = staticmethod(
            lambda *a, **k: None)

from repro.core import (SearchParams, WorkloadSpec, build_scann,
                        generate_bitmaps, heap_pages_per_vector,
                        make_executor, predict_cycles)
from repro.core.costmodel import SYSTEM, IndexShape, cache_miss_penalty
from repro.storage import (BufferPool, GraphAdjacencyLayout, HeapLayout,
                           ScannLeafLayout, StorageEngine,
                           make_storage_engine, scann_pages_per_leaf)
from repro.storage.pages import PAGE_BYTES
from repro.storage.pages import heap_pages_per_vector as hpv_storage

PARAMS = SearchParams(k=10, ef_search=96, beam_width=512, max_hops=2048,
                      num_leaves_to_search=16, reorder_factor=4,
                      scann_page_accounting="per_query")
STRATEGIES = ("sweeping", "acorn", "navix", "iterative_scan", "unfiltered",
              "scann", "bruteforce")
STAT_FIELDS = ("distance_comps", "filter_checks", "hops",
               "page_accesses_index", "page_accesses_heap", "tmap_lookups",
               "reorder_rows")


@pytest.fixture(scope="module")
def scann_index(small_dataset):
    store, _ = small_dataset
    return build_scann(store, num_leaves=64, levels=2, seed=0)


# ---------------- page layouts: one owner for geometry ----------------

def test_heap_pages_per_vector_one_owner():
    # the core.types re-export IS the storage-layer function
    assert heap_pages_per_vector is hpv_storage
    for dim in (48, 128, 768, 1536, 2048, 3000, 8192):
        layout = HeapLayout(n=1000, dim=dim)
        ppr = heap_pages_per_vector(dim)
        assert layout.pages_per_row == ppr
        pages = layout.pages_for_rows(np.arange(17))
        # logical accesses per fetched row == the analytic constant
        assert len(pages) == 17 * ppr
        assert pages.max() < layout.num_pages
        if ppr == 1:
            # rows never straddle pages they don't have to
            assert layout.rows_per_page >= PAGE_BYTES // (dim * 4)


def test_scann_leaf_layout_matches_quant_pages(scann_index):
    L, C, dp = scann_index.leaf_tiles.shape
    from repro.core.scann import _quant_pages_per_leaf
    layout = ScannLeafLayout(num_leaves=L, cap=C, dp=dp)
    assert layout.pages_per_leaf == _quant_pages_per_leaf(scann_index)
    assert layout.pages_per_leaf == scann_pages_per_leaf(C, dp)
    pages = layout.pages_for_leaves(np.array([0, 3, 3]))
    assert len(pages) == 3 * layout.pages_per_leaf


def test_graph_adjacency_layout():
    layout = GraphAdjacencyLayout(n=1000, degree=32)
    assert layout.nodes_per_page >= 1
    pages = layout.pages_for_nodes(np.arange(1000))
    assert len(pages) == 1000                 # one logical access per node
    assert pages.max() == layout.num_pages - 1


# ---------------- buffer pool invariants ----------------

def test_pool_capacity_never_exceeded_and_lru_order():
    pool = BufferPool(capacity_pages=3, policy="lru")
    pool.access(np.array([1, 2, 3]))
    assert len(pool) == 3
    pool.access(np.array([1]))                # 1 becomes most-recent
    d = pool.access(np.array([4]))            # evicts LRU == 2
    assert d.evictions == 1 and len(pool) == 3
    assert 2 not in pool and 1 in pool and 3 in pool and 4 in pool
    d = pool.access(np.array([2]))            # 2 misses back in, evicts 3
    assert d.misses == 1 and 3 not in pool


def test_pool_hit_plus_miss_equals_logical():
    pool = BufferPool(capacity_pages=8)
    rng = np.random.RandomState(0)
    for _ in range(20):
        trace = rng.randint(0, 30, size=rng.randint(1, 40))
        d = pool.access(trace)
        assert d.hits + d.misses == d.logical == len(trace)
        assert len(pool) <= 8
    c = pool.counters
    assert c.hits + c.misses == c.logical


def test_pool_batch_dedup_idempotent():
    pool = BufferPool(capacity_pages=100)
    trace = np.array([5, 5, 7, 5, 9, 7])
    d1 = pool.access(trace, dedup=True)
    assert d1.logical == 3 and d1.misses == 3       # {5, 7, 9} once each
    pool2 = BufferPool(capacity_pages=100)
    d2 = pool2.access(np.array([5, 7, 9]), dedup=True)
    assert (d2.logical, d2.misses) == (d1.logical, d1.misses)


def test_pool_clock_policy_and_cold_reset():
    pool = BufferPool(capacity_pages=2, policy="clock")
    pool.access(np.array([1, 2]))
    pool.access(np.array([1]))                # reference 1
    pool.access(np.array([3]))                # second-chance: evicts 2
    assert 1 in pool and 3 in pool and 2 not in pool
    pool.reset()
    assert len(pool) == 0
    d = pool.access(np.array([1]))
    assert d.misses == 1                      # cold again


def test_pool_state_residency_is_plain_fraction():
    """Residency must be resident/segment_size (the miss-fraction
    contract), NOT normalized by capacity — a small full pool is not a
    warm segment."""
    pool = BufferPool(capacity_pages=10)
    pool.access(np.arange(10))
    st = pool.state({"seg": (0, 100)})
    assert st.residency["seg"] == pytest.approx(0.1)
    assert st.miss_fraction("seg") == pytest.approx(0.9)


def test_pool_unbounded_capacity():
    pool = BufferPool(capacity_pages=0)
    d = pool.access(np.arange(10_000))
    assert d.evictions == 0 and len(pool) == 10_000
    assert pool.access(np.arange(10_000)).hits == 10_000


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=50), min_size=1,
                max_size=200),
       st.integers(min_value=1, max_value=20),
       st.sampled_from(["lru", "clock"]))
def test_pool_invariants_property(trace, cap, policy):
    """Property: for ANY trace/capacity/policy — occupancy ≤ capacity,
    hits + misses == logical, evictions == misses - final occupancy."""
    pool = BufferPool(capacity_pages=cap, policy=policy)
    d = pool.access(np.array(trace))
    assert len(pool) <= cap
    assert d.hits + d.misses == d.logical == len(trace)
    assert d.evictions == d.misses - len(pool)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=30), min_size=1,
                max_size=60))
def test_pool_lru_eviction_order_property(trace):
    """Property: under LRU, after any trace the resident set is exactly
    the `capacity` most-recently-accessed distinct pages."""
    cap = 5
    pool = BufferPool(capacity_pages=cap, policy="lru")
    pool.access(np.array(trace))
    recent: list[int] = []
    for p in trace:
        if p in recent:
            recent.remove(p)
        recent.append(p)
    expect = set(recent[-cap:])
    assert set(pool._pages.keys()) == expect


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=40), min_size=1,
                max_size=80))
def test_pool_batch_dedup_idempotence_property(trace):
    """Property: access(trace, dedup=True) == access(unique-first-touch)
    == doubling the trace first — duplicates never change the outcome."""
    t = np.array(trace)
    a = BufferPool(8).access(t, dedup=True)
    b = BufferPool(8).access(np.concatenate([t, t]), dedup=True)
    assert (a.logical, a.hits, a.misses) == (b.logical, b.hits, b.misses)
    assert a.logical == len(set(trace))


# ---------------- storage-on vs legacy: bit-identical ----------------

def _engine(store, index, graph, **kw):
    kw.setdefault("capacity_frac", 1.0)
    return make_storage_engine(store, index=index, graph=graph, **kw)


@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("sel", (0.05, 0.5))
def test_storage_on_bit_identical(small_dataset, small_graph, scann_index,
                                  strategy, sel):
    store, queries = small_dataset
    bm = generate_bitmaps(store, queries, WorkloadSpec(sel, "none"),
                          seed=int(sel * 100))
    ex0 = make_executor(strategy, store, graph=small_graph,
                        index=scann_index)
    ex1 = make_executor(strategy, store, graph=small_graph,
                        index=scann_index,
                        storage=_engine(store, scann_index, small_graph))
    r0 = ex0.search(queries, bm, PARAMS)
    r1 = ex1.search(queries, bm, PARAMS)
    np.testing.assert_array_equal(np.asarray(r0.ids), np.asarray(r1.ids))
    assert np.array_equal(np.asarray(r0.dists), np.asarray(r1.dists),
                          equal_nan=True)
    for f in STAT_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(r0.stats, f)),
            np.asarray(getattr(r1.stats, f)), err_msg=(strategy, f))
    assert r0.storage is None and r1.storage is not None


# ---------------- measured vs analytic page counters ----------------

def test_scann_measured_logical_exact_per_query(small_dataset, scann_index,
                                                small_graph):
    store, queries = small_dataset
    bm = generate_bitmaps(store, queries, WorkloadSpec(0.2, "none"), seed=7)
    ex = make_executor("scann", store, index=scann_index,
                       storage=_engine(store, scann_index, None))
    res = ex.search(queries, bm, PARAMS)
    np.testing.assert_array_equal(
        res.storage.index_pages, np.asarray(res.stats.page_accesses_index))
    np.testing.assert_array_equal(
        res.storage.heap_pages, np.asarray(res.stats.page_accesses_heap))


def test_scann_measured_logical_exact_batch(small_dataset, scann_index):
    """Batch accounting: the pool's first-touch dedup reproduces the
    SearchStats batch attribution — per-query sums AND the batch total
    (= unique opened leaves × pages_per_leaf) agree exactly."""
    store, queries = small_dataset
    bm = generate_bitmaps(store, queries, WorkloadSpec(0.2, "none"), seed=7)
    p = dataclasses.replace(PARAMS, scann_page_accounting="batch")
    ex = make_executor("scann", store, index=scann_index,
                       storage=_engine(store, scann_index, None))
    res = ex.search(queries, bm, p)
    np.testing.assert_array_equal(
        res.storage.index_pages, np.asarray(res.stats.page_accesses_index))
    np.testing.assert_array_equal(
        res.storage.heap_pages, np.asarray(res.stats.page_accesses_heap))
    # batch total == unique leaves opened × pages per leaf
    assert res.storage.logical["scann"] == \
        int(np.asarray(res.stats.page_accesses_index).sum())


@pytest.mark.parametrize("block", (1, 3, 8))
def test_scann_measured_logical_exact_batch_tiled(small_dataset,
                                                  scann_index, block):
    """Query-block tiling amortizes "batch" accounting per TILE
    (DESIGN.md §4/§5); the pool-side dedup window must follow the tile
    boundaries so measured stays exactly == analytic."""
    store, queries = small_dataset
    bm = generate_bitmaps(store, queries, WorkloadSpec(0.2, "none"), seed=7)
    p = dataclasses.replace(PARAMS, scann_page_accounting="batch",
                            scann_query_block=block)
    ex = make_executor("scann", store, index=scann_index,
                       storage=_engine(store, scann_index, None))
    res = ex.search(queries, bm, p)
    np.testing.assert_array_equal(
        res.storage.index_pages, np.asarray(res.stats.page_accesses_index))
    np.testing.assert_array_equal(
        res.storage.heap_pages, np.asarray(res.stats.page_accesses_heap))


def test_bruteforce_measured_logical_exact(small_dataset):
    store, queries = small_dataset
    bm = generate_bitmaps(store, queries, WorkloadSpec(0.3, "none"), seed=9)
    ex = make_executor("bruteforce", store,
                       storage=make_storage_engine(store, capacity_frac=1.0))
    res = ex.search(queries, bm, PARAMS)
    np.testing.assert_array_equal(
        res.storage.heap_pages, np.asarray(res.stats.page_accesses_heap))
    assert res.storage.logical["heap"] == \
        int(np.asarray(res.stats.page_accesses_heap).sum())


@pytest.mark.parametrize("strategy", ("sweeping", "acorn", "navix",
                                      "iterative_scan"))
def test_graph_measured_logical_bounded(small_dataset, small_graph,
                                        scann_index, strategy):
    """Graph traces count each touched object once; analytic counters also
    charge zoom-in revisits and rank-only re-scores, so measured ≤
    analytic, and never less than the unique-candidate floor (> 0)."""
    store, queries = small_dataset
    bm = generate_bitmaps(store, queries, WorkloadSpec(0.2, "none"), seed=5)
    ex = make_executor(strategy, store, graph=small_graph,
                       storage=_engine(store, None, small_graph))
    res = ex.search(queries, bm, PARAMS)
    ppv = heap_pages_per_vector(store.dim)
    heap_meas = res.storage.heap_pages
    heap_stat = np.asarray(res.stats.page_accesses_heap)
    idx_meas = res.storage.index_pages
    idx_stat = np.asarray(res.stats.page_accesses_index)
    assert (heap_meas > 0).all() and (idx_meas > 0).all()
    assert (heap_meas <= heap_stat).all(), strategy
    assert (idx_meas <= idx_stat).all(), strategy
    # the under-count is the revisit delta, not a different formula: each
    # unique scored row still charges exactly ppv pages
    assert (heap_meas % ppv == 0).all()


def test_pool_physical_bounded_by_logical(small_dataset, small_graph,
                                          scann_index):
    store, queries = small_dataset
    bm = generate_bitmaps(store, queries, WorkloadSpec(0.2, "none"), seed=5)
    eng = _engine(store, scann_index, small_graph)
    ex = make_executor("scann", store, index=scann_index, storage=eng)
    r1 = ex.search(queries, bm, PARAMS)
    assert r1.storage.miss_total <= r1.storage.logical_total
    # warm re-run: same batch again — everything resident, zero misses
    r2 = ex.search(queries, bm, PARAMS)
    assert r2.storage.miss_total == 0
    assert r2.storage.hit_rate == 1.0
    # cold reset brings the misses back
    eng.reset_cold()
    r3 = ex.search(queries, bm, PARAMS)
    assert r3.storage.miss_total == r1.storage.miss_total


# ---------------- warm-cache-aware planner inputs ----------------

def test_pool_state_residency_and_miss_fraction(small_dataset, scann_index,
                                                small_graph):
    store, queries = small_dataset
    bm = generate_bitmaps(store, queries, WorkloadSpec(0.2, "none"), seed=5)
    eng = _engine(store, scann_index, small_graph)
    st0 = eng.state()
    assert st0.miss_fraction("scann") == 1.0          # cold
    ex = make_executor("scann", store, index=scann_index, storage=eng)
    ex.search(queries, bm, PARAMS)
    st1 = eng.state()
    assert st1.residency["scann"] > 0.0               # leaves resident now
    assert st1.miss_fraction("scann") < 1.0
    assert st1.used <= max(eng.pool.capacity, eng.total_pages)


def test_predict_cycles_warm_cache_aware(small_dataset, scann_index,
                                         small_graph):
    """A warm pool must make a strategy's predicted cycles cheaper than
    cold, and a fully warm scann segment must beat a cold one by exactly
    the cache_miss_penalty."""
    store, _ = small_dataset
    L, C, _ = scann_index.leaf_tiles.shape
    shape = IndexShape(store.n, store.dim, graph_m=12, scann_leaves=L,
                       scann_rows_per_leaf=min(store.n // L, C),
                       scann_cent_scored=L, scann_pages_per_leaf=1)
    eng = _engine(store, scann_index, small_graph)
    cold = eng.state()
    base = predict_cycles("scann", shape, PARAMS, 0.2)
    cold_cost = predict_cycles("scann", shape, PARAMS, 0.2,
                               pool_state=cold)
    assert cold_cost > base                           # misses are charged
    # simulate a warm pool: touch every scann + heap page
    ranges = eng.segment_ranges()
    eng.pool.access(np.arange(*ranges["scann"]))
    warm_cost = predict_cycles("scann", shape, PARAMS, 0.2,
                               pool_state=eng.state())
    assert warm_cost < cold_cost
    # penalty accounting is self-consistent
    from repro.core import predict_counters
    counters = predict_counters("scann", shape, PARAMS, 0.2)
    pen = cache_miss_penalty(counters, "scann", cold, SYSTEM)
    assert cold_cost == pytest.approx(base + pen)


def test_planner_dispatch_is_warm_cache_aware(small_dataset, small_graph,
                                              scann_index):
    """The planner's predictions must shift with pool residency: with the
    scann segment warm and everything else cold, scann's predicted cycles
    drop relative to the cold plan (the residency-driven dispatch input)."""
    store, queries = small_dataset
    bm = generate_bitmaps(store, queries, WorkloadSpec(0.2, "none"),
                          seed=13)
    eng = _engine(store, scann_index, small_graph, capacity_frac=1.0)
    planner = make_executor("adaptive", store, graph=small_graph,
                            index=scann_index, graph_m=small_graph.m,
                            storage=eng)
    cold_plan = planner.plan(queries, bm, PARAMS)
    ranges = eng.segment_ranges()
    eng.pool.access(np.arange(*ranges["scann"]))      # warm scann segment
    warm_plan = planner.plan(queries, bm, PARAMS)
    drop = {m: cold_plan.predicted_cycles[m] - warm_plan.predicted_cycles[m]
            for m in cold_plan.predicted_cycles}
    assert drop["scann"] > 0                          # scann got cheaper
    # and no other candidate's prediction moved by more than scann's
    assert drop["scann"] == max(drop.values())


# ---------------- trace flag is loud on unsupported paths ----------------

def test_storage_requires_frontier_engine(small_dataset, small_graph):
    store, queries = small_dataset
    bm = generate_bitmaps(store, queries, WorkloadSpec(0.2, "none"), seed=3)
    ex = make_executor("sweeping", store, graph=small_graph,
                       storage=_engine(store, None, small_graph))
    p = dataclasses.replace(PARAMS, graph_exec_mode="vmapped")
    with pytest.raises(ValueError, match="frontier"):
        ex.search(queries, bm, p)


def test_storage_requires_batched_scann(small_dataset, scann_index):
    store, _ = small_dataset
    from repro.core.executor import ScannExecutor
    with pytest.raises(ValueError, match="batched"):
        ScannExecutor(scann_index, store, pipeline="vmapped",
                      storage=_engine(store, scann_index, None))
