"""Leaf-scan kernel coverage (no hypothesis): Pallas-vs-ref equivalence for
the single and batched variants, padding edges, all-filtered bitmaps,
top-k with k > n, and batched-pipeline-vs-vmapped ScaNN equivalence."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (SearchParams, WorkloadSpec, generate_bitmaps,
                        scann_search_batch, scann_search_batch_vmapped)
from repro.core.types import pack_bool_bitmap
from repro.kernels import ops, ref


def _leaf_case(nl, c, d, q=4, n_rows=1024, density=0.5, seed=0):
    rng = np.random.RandomState(seed)
    tiles = jnp.asarray(rng.randint(-127, 128, (nl, c, d)).astype(np.int8))
    rowids = rng.permutation(n_rows)[: nl * c].reshape(nl, c).astype(np.int32)
    rowids[rng.rand(nl, c) < 0.1] = -1
    scale = jnp.asarray(np.abs(rng.randn(d)).astype(np.float32) * 0.02)
    mean = jnp.asarray(rng.randn(d).astype(np.float32) * 0.05)
    bms = jnp.stack([pack_bool_bitmap(rng.rand(n_rows) < density)
                     for _ in range(q)])
    queries = jnp.asarray(rng.randn(q, d).astype(np.float32))
    return queries, tiles, jnp.asarray(rowids), scale, mean, bms


def _assert_scores_match(a, b, atol=2e-3, rtol=1e-3):
    fa, fb = np.isfinite(np.asarray(a)), np.isfinite(np.asarray(b))
    assert (fa == fb).all()
    np.testing.assert_allclose(np.asarray(a)[fa], np.asarray(b)[fb],
                               atol=atol, rtol=rtol)


# shape grid: scalar-ish, unaligned C and d, exactly-aligned tiles
SHAPES = [(1, 1, 1), (3, 17, 40), (2, 33, 130), (2, 128, 128)]


@pytest.mark.parametrize("metric", ["l2", "ip"])
@pytest.mark.parametrize("nl,c,d", SHAPES)
def test_leaf_scan_single_pallas_vs_ref(nl, c, d, metric):
    queries, tiles, rowids, scale, mean, bms = _leaf_case(nl, c, d, q=1)
    a = ops.leaf_scan(queries[0], tiles, rowids, scale, mean, bms[0],
                      metric, use_pallas=True)
    b = ref.leaf_scan_ref(queries[0], tiles, rowids, scale, mean, bms[0],
                          metric)
    _assert_scores_match(a, b)


@pytest.mark.parametrize("metric", ["l2", "ip"])
@pytest.mark.parametrize("nl,c,d", SHAPES)
def test_leaf_scan_batched_pallas_vs_ref(nl, c, d, metric):
    queries, tiles, rowids, scale, mean, bms = _leaf_case(nl, c, d, q=5)
    x = tiles.astype(jnp.float32) * scale + mean
    norms = jnp.sum(x * x, axis=-1)
    a = ops.leaf_scan_batched(queries, tiles, rowids, scale, mean, bms,
                              norms, metric, use_pallas=True)
    b = ref.leaf_scan_batched_ref(queries, tiles, rowids, scale, mean, bms,
                                  norms, metric)
    assert a.shape == (5, nl, c)
    _assert_scores_match(a, b)


@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_leaf_scan_batched_matches_vmapped_single(metric):
    """The batched kernel must agree with vmap of the single-query kernel
    row for row — same scores, same +inf mask."""
    queries, tiles, rowids, scale, mean, bms = _leaf_case(3, 20, 48, q=6,
                                                          seed=7)
    x = tiles.astype(jnp.float32) * scale + mean
    norms = jnp.sum(x * x, axis=-1)
    for use_pallas in (False, True):
        a = ops.leaf_scan_batched(queries, tiles, rowids, scale, mean, bms,
                                  norms, metric, use_pallas=use_pallas)
        b = jax.vmap(lambda q, bm: ops.leaf_scan(
            q, tiles, rowids, scale, mean, bm, metric,
            use_pallas=use_pallas))(queries, bms)
        _assert_scores_match(a, b)


def test_leaf_scan_batched_all_filtered():
    """Fully-failing filters -> all +inf for every query in the batch."""
    rng = np.random.RandomState(0)
    tiles = jnp.asarray(rng.randint(-127, 128, (2, 8, 16)).astype(np.int8))
    rowids = jnp.asarray(np.arange(16).reshape(2, 8).astype(np.int32))
    bms = jnp.stack([pack_bool_bitmap(np.zeros(64, bool))] * 3)
    norms = jnp.zeros((2, 8), jnp.float32)
    for use_pallas in (False, True):
        out = ops.leaf_scan_batched(
            jnp.ones((3, 16)), tiles, rowids, jnp.ones((16,)),
            jnp.zeros((16,)), bms, norms, "l2", use_pallas=use_pallas)
        assert not np.isfinite(np.asarray(out)).any()


def test_leaf_scan_batched_mixed_filters():
    """Each query sees its own bitmap: query 0 passes everything, query 1
    nothing — in the same batched call."""
    rng = np.random.RandomState(1)
    tiles = jnp.asarray(rng.randint(-127, 128, (2, 8, 16)).astype(np.int8))
    rowids = jnp.asarray(np.arange(16).reshape(2, 8).astype(np.int32))
    bms = jnp.stack([pack_bool_bitmap(np.ones(64, bool)),
                     pack_bool_bitmap(np.zeros(64, bool))])
    norms = jnp.zeros((2, 8), jnp.float32)
    out = ops.leaf_scan_batched(jnp.ones((2, 16)), tiles, rowids,
                                jnp.ones((16,)), jnp.zeros((16,)), bms,
                                norms, "ip", use_pallas=True)
    out = np.asarray(out)
    assert np.isfinite(out[0]).all()
    assert not np.isfinite(out[1]).any()


@pytest.mark.parametrize("use_pallas", [False, True])
def test_topk_k_greater_than_n(use_pallas):
    """k > n must yield the n real entries plus (+inf, -1) padding, on
    both the Pallas kernel and the jnp oracle."""
    v = jnp.asarray(np.array([3.0, 1.0, 2.0], np.float32))
    vals, idx = ops.topk_smallest(v, 8, use_pallas=use_pallas)
    vals, idx = np.asarray(vals), np.asarray(idx)
    np.testing.assert_allclose(vals[:3], [1.0, 2.0, 3.0])
    assert (idx[:3] == [1, 2, 0]).all()
    assert np.isinf(vals[3:]).all()
    assert (idx[3:] == -1).all()


@pytest.mark.parametrize("use_pallas", [False, True])
def test_topk_inf_sentinels(use_pallas):
    """+inf (the universal filtered marker) reports index -1 on both
    backends; -inf is a legitimate smallest value and keeps its index."""
    v = jnp.asarray(np.array([np.inf, 1.0, np.inf, -np.inf], np.float32))
    vals, idx = ops.topk_smallest(v, 4, use_pallas=use_pallas)
    vals, idx = np.asarray(vals), np.asarray(idx)
    assert vals[0] == -np.inf and idx[0] == 3
    assert vals[1] == 1.0 and idx[1] == 1
    assert np.isposinf(vals[2:]).all()
    assert (idx[2:] == -1).all()


# ---------------- batched pipeline vs legacy vmapped path ----------------

@pytest.fixture(scope="module")
def scann_fixture(small_dataset):
    from repro.core import build_scann
    store, queries = small_dataset
    idx = build_scann(store, num_leaves=64, levels=2, seed=0)
    bm = generate_bitmaps(store, queries, WorkloadSpec(0.3, "none"), seed=3)
    return store, queries, idx, bm


@pytest.mark.parametrize("use_pallas", [False, True])
def test_scann_batched_matches_vmapped(scann_fixture, use_pallas):
    """Acceptance: ids, distances, and SearchStats identical to the
    pre-refactor vmapped path under per-query page accounting.  Final
    distances are bit-for-bit because the exact-rescore stage uses the
    same distance() formulation as the legacy path."""
    store, queries, idx, bm = scann_fixture
    p = SearchParams(k=10, num_leaves_to_search=16,
                     scann_page_accounting="per_query")
    d1, i1, s1 = scann_search_batch_vmapped(idx, store, queries, bm, p,
                                            use_pallas=use_pallas)
    d2, i2, s2 = scann_search_batch(idx, store, queries, bm, p,
                                    use_pallas=use_pallas)
    assert (np.asarray(i1) == np.asarray(i2)).all()
    assert (np.asarray(d1) == np.asarray(d2)).all()
    for a, b in zip(jax.tree.leaves(s1), jax.tree.leaves(s2)):
        assert (np.asarray(a) == np.asarray(b)).all()


def test_scann_batch_page_accounting(scann_fixture):
    """Batch accounting totals unique opened leaves; per-query accounting
    totals nl per query.  Only the index-page counter may differ."""
    from repro.core.scann import _quant_pages_per_leaf
    store, queries, idx, bm = scann_fixture
    nl = 16
    kw = dict(k=10, num_leaves_to_search=nl)
    pb = SearchParams(**kw, scann_page_accounting="batch")
    pq = SearchParams(**kw, scann_page_accounting="per_query")
    db, ib, sb = scann_search_batch(idx, store, queries, bm, pb)
    dq, iq, sq = scann_search_batch(idx, store, queries, bm, pq)
    assert (np.asarray(ib) == np.asarray(iq)).all()
    qppl = _quant_pages_per_leaf(idx)
    per_query = np.asarray(sq.page_accesses_index)
    assert (per_query == nl * qppl).all()
    batch_total = int(np.asarray(sb.page_accesses_index).sum())
    assert batch_total <= per_query.sum()
    assert batch_total % qppl == 0
    assert nl * qppl <= batch_total          # at least one query's worth
    for f in ("distance_comps", "filter_checks", "hops",
              "page_accesses_heap", "reorder_rows"):
        assert (np.asarray(getattr(sb, f))
                == np.asarray(getattr(sq, f))).all()


def test_scann_row_norms_backcompat(scann_fixture):
    """An index without precomputed row_norms_sq (pre-field pickles) must
    produce identical results via the lazy fallback."""
    import dataclasses
    store, queries, idx, bm = scann_fixture
    assert idx.row_norms_sq is not None
    old = dataclasses.replace(idx, row_norms_sq=None)
    p = SearchParams(k=10, num_leaves_to_search=16)
    d1, i1, _ = scann_search_batch(idx, store, queries, bm, p)
    d2, i2, _ = scann_search_batch(old, store, queries, bm, p)
    assert (np.asarray(i1) == np.asarray(i2)).all()
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), rtol=1e-5)
