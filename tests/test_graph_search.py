"""Strategy behaviour tests: recall, counters, ablations (paper §6)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (SearchParams, WorkloadSpec, filtered_knn,
                        generate_bitmaps, knn, recall_at_k, search_batch,
                        stats_table_row)

STRATS = ("sweeping", "acorn", "navix", "iterative_scan")


def _recall(ids, tid, k=10):
    return float(np.mean(np.asarray(
        jax.vmap(lambda f, t: recall_at_k(f, t, k))(ids, tid))))


def test_unfiltered_recall(small_dataset, small_graph, full_bitmaps):
    store, queries = small_dataset
    _, tid = knn(store, queries, 10)
    p = SearchParams(k=10, ef_search=96, beam_width=512,
                     strategy="unfiltered")
    _, ids, stats = search_batch(small_graph, store, queries, full_bitmaps, p)
    assert _recall(ids, tid) >= 0.95
    row = stats_table_row(stats)
    assert row["filter_checks"] == 0          # unfiltered: no probes
    assert row["distance_comps"] > 0


@pytest.mark.parametrize("strategy", STRATS)
def test_filtered_recall_mid_selectivity(small_dataset, small_graph,
                                         strategy):
    store, queries = small_dataset
    bm = generate_bitmaps(store, queries, WorkloadSpec(0.3, "none"), seed=1)
    _, tid = filtered_knn(store, queries, bm, 10)
    p = SearchParams(k=10, ef_search=128, beam_width=1024, strategy=strategy,
                     max_hops=2048)
    _, ids, _ = search_batch(small_graph, store, queries, bm, p)
    assert _recall(ids, tid) >= 0.9, strategy


def test_results_respect_filter(small_dataset, small_graph):
    """Every returned id must pass the filter — across strategies/sels."""
    from repro.core import probe_bitmap
    store, queries = small_dataset
    for sel in (0.05, 0.5):
        bm = generate_bitmaps(store, queries, WorkloadSpec(sel, "none"),
                              seed=2)
        for strategy in STRATS:
            p = SearchParams(k=10, ef_search=64, beam_width=512,
                             strategy=strategy, max_hops=1024)
            _, ids, _ = search_batch(small_graph, store, queries, bm, p)
            ok = jax.vmap(probe_bitmap)(bm, jnp.maximum(ids, 0))
            valid = np.asarray(ids) >= 0
            assert np.asarray(ok)[valid].all(), (strategy, sel)


def test_results_sorted_and_unique(small_dataset, small_graph):
    store, queries = small_dataset
    bm = generate_bitmaps(store, queries, WorkloadSpec(0.3, "none"), seed=3)
    p = SearchParams(k=10, ef_search=64, beam_width=512, strategy="acorn")
    d, ids, _ = search_batch(small_graph, store, queries, bm, p)
    d, ids = np.asarray(d), np.asarray(ids)
    for i in range(ids.shape[0]):
        v = ids[i][ids[i] >= 0]
        assert len(np.unique(v)) == len(v)
        dv = d[i][np.isfinite(d[i])]
        assert (np.diff(dv) >= -1e-6).all()


def test_paper_trend_filter_first_vs_traversal_first(small_dataset,
                                                     small_graph):
    """Paper Table 6 @ low selectivity: filter-first does FEWER distance
    comps and hops but MORE filter checks than traversal-first."""
    store, queries = small_dataset
    bm = generate_bitmaps(store, queries, WorkloadSpec(0.05, "none"), seed=4)
    rows = {}
    for strategy in ("acorn", "sweeping"):
        p = SearchParams(k=10, ef_search=96, beam_width=1024,
                         strategy=strategy, max_hops=2048)
        _, _, stats = search_batch(small_graph, store, queries, bm, p)
        rows[strategy] = stats_table_row(stats)
    assert rows["acorn"]["distance_comps"] < rows["sweeping"][
        "distance_comps"]
    assert rows["acorn"]["hops"] < rows["sweeping"]["hops"]
    assert rows["acorn"]["filter_checks"] > rows["sweeping"]["filter_checks"]


def test_translation_map_ablation(small_dataset, small_graph):
    """Fig. 13: disabling the TM converts map lookups into index-page
    accesses (the dominant cost class)."""
    store, queries = small_dataset
    bm = generate_bitmaps(store, queries, WorkloadSpec(0.1, "none"), seed=5)
    rows = {}
    for tm in (True, False):
        p = SearchParams(k=10, ef_search=64, beam_width=512,
                         strategy="acorn", translation_map=tm)
        _, _, stats = search_batch(small_graph, store, queries, bm, p)
        rows[tm] = stats_table_row(stats)
    assert rows[True]["tmap_lookups"] > 0
    assert rows[False]["tmap_lookups"] == 0
    assert rows[False]["page_accesses_index"] > rows[True][
        "page_accesses_index"] * 2


def test_iterative_scan_subsumes_post_filter(small_dataset, small_graph):
    """Paper §2.1: with a large enough first batch, iterative scan IS
    post-filtering: one round, and results equal filtering the unfiltered
    top-batch."""
    store, queries = small_dataset
    bm = generate_bitmaps(store, queries, WorkloadSpec(0.5, "none"), seed=6)
    _, tid = filtered_knn(store, queries, bm, 10)
    p = SearchParams(k=10, ef_search=256, beam_width=512,
                     strategy="iterative_scan", batch_tuples=256,
                     max_rounds=4)
    _, ids, stats = search_batch(small_graph, store, queries, bm, p)
    assert _recall(ids, tid) >= 0.9


def test_navix_heuristics_run(small_dataset, small_graph):
    store, queries = small_dataset
    bm = generate_bitmaps(store, queries, WorkloadSpec(0.2, "none"), seed=7)
    _, tid = filtered_knn(store, queries, bm, 10)
    for h in ("blind", "directed", "onehop", "adaptive"):
        p = SearchParams(k=10, ef_search=96, beam_width=1024,
                         strategy="navix", navix_heuristic=h, max_hops=2048)
        _, ids, _ = search_batch(small_graph, store, queries, bm, p)
        assert _recall(ids, tid) >= 0.75, h


def test_hardened_acorn_reduces_page_accesses(small_dataset, small_graph):
    """Paper §3.1 opt (ii): skipping 2-hop expansion for passing branches
    cuts index-page accesses at high selectivity."""
    store, queries = small_dataset
    bm = generate_bitmaps(store, queries, WorkloadSpec(0.8, "none"), seed=8)
    rows = {}
    for skip in (True, False):
        p = SearchParams(k=10, ef_search=64, beam_width=512,
                         strategy="acorn", adaptive_skip_2hop=skip)
        _, _, stats = search_batch(small_graph, store, queries, bm, p)
        rows[skip] = stats_table_row(stats)
    assert rows[True]["page_accesses_index"] < rows[False][
        "page_accesses_index"]
