"""Frontier engine vs legacy vmapped path: bit-identical equivalence.

The batch-synchronous frontier engine (core/graph_search.py, DESIGN.md §7)
must reproduce the legacy per-query beam search *exactly* — same ids, same
distances (bitwise), and all seven SearchStats counters — across every
strategy, selectivity regime, and bitmap correlation.  Also covers the
packed-bitset helpers (incl. the node-0 padding-collision regression the
engine work uncovered in the legacy visited update) and interpret-mode
parity of the fused `frontier_scan` Pallas kernel against its jnp oracle.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # optional dev dep (requirements-dev.txt):
    # property tests skip individually; plain tests in this module still run
    def given(*a, **k):
        return lambda f: pytest.mark.skip(
            reason="hypothesis not installed")(f)

    def settings(*a, **k):
        return lambda f: f

    class st:  # stub strategies so decorator arguments still evaluate
        integers = floats = sampled_from = staticmethod(
            lambda *a, **k: None)

from repro.core import (SearchParams, WorkloadSpec, bitset_mark,
                        bitset_words, bitset_zeros, generate_bitmaps,
                        pack_bool_bitmap, probe_bitmap, search_batch)
from repro.core.hnsw import HNSWGraph
from repro.core.types import VectorStore
from repro.kernels import ops, ref

STRATS = ("unfiltered", "sweeping", "acorn", "navix", "iterative_scan")
STAT_FIELDS = ("distance_comps", "filter_checks", "hops",
               "page_accesses_index", "page_accesses_heap", "tmap_lookups",
               "reorder_rows")


def _assert_identical(graph, store, queries, bm, p):
    pv = dataclasses.replace(p, graph_exec_mode="vmapped")
    pf = dataclasses.replace(p, graph_exec_mode="frontier")
    dv, iv, sv = search_batch(graph, store, queries, bm, pv)
    df, iff, sf = search_batch(graph, store, queries, bm, pf)
    np.testing.assert_array_equal(np.asarray(iv), np.asarray(iff))
    assert np.array_equal(np.asarray(dv), np.asarray(df), equal_nan=True), \
        "distances not bit-identical"
    for f in STAT_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(sv, f)), np.asarray(getattr(sf, f)),
            err_msg=f"counter {f} diverged")


@pytest.mark.parametrize("strategy", STRATS)
def test_frontier_bit_identical(small_dataset, small_graph, strategy):
    """ids, dists, and all 7 counters identical across the selectivity ×
    correlation grid (one jit per engine per strategy — params shared)."""
    store, queries = small_dataset
    p = SearchParams(k=10, ef_search=48, beam_width=128, strategy=strategy,
                     max_hops=500)
    for sel in (0.01, 0.2, 0.8):
        for corr in ("none", "high_pos"):
            bm = generate_bitmaps(store, queries, WorkloadSpec(sel, corr),
                                  seed=7)
            _assert_identical(small_graph, store, queries, bm, p)


def test_frontier_bit_identical_ablations(small_dataset, small_graph):
    """The Fig. 13 / hardened-ACORN ablation flags and the navix
    heuristics keep the engines bit-identical too."""
    store, queries = small_dataset
    bm = generate_bitmaps(store, queries, WorkloadSpec(0.2, "none"), seed=8)
    for p in (
        SearchParams(k=10, ef_search=48, beam_width=128, strategy="acorn",
                     max_hops=500, translation_map=False),
        SearchParams(k=10, ef_search=48, beam_width=128, strategy="acorn",
                     max_hops=500, adaptive_skip_2hop=False),
        SearchParams(k=10, ef_search=48, beam_width=128, strategy="navix",
                     max_hops=500, navix_heuristic="directed"),
        SearchParams(k=10, ef_search=48, beam_width=128, strategy="navix",
                     max_hops=500, navix_heuristic="onehop"),
    ):
        _assert_identical(small_graph, store, queries, bm, p)


def test_frontier_chunked_paths_identical(small_dataset, small_graph):
    """Forcing multi-chunk scoring (tiny chunk sizes) exercises the inner
    while_loop + compaction path without changing any output."""
    store, queries = small_dataset
    bm = generate_bitmaps(store, queries, WorkloadSpec(0.3, "none"), seed=9)
    for p in (
        SearchParams(k=10, ef_search=48, beam_width=128,
                     strategy="sweeping", max_hops=500, frontier_chunk=4),
        SearchParams(k=10, ef_search=48, beam_width=128, strategy="acorn",
                     max_hops=500, frontier_chunk2=16),
        SearchParams(k=10, ef_search=48, beam_width=128,
                     strategy="iterative_scan", max_hops=500,
                     frontier_chunk=4),
    ):
        _assert_identical(small_graph, store, queries, bm, p)


def test_frontier_single_query(small_dataset, small_graph):
    """Q=1 degenerate batch."""
    store, queries = small_dataset
    bm = generate_bitmaps(store, queries, WorkloadSpec(0.2, "none"), seed=10)
    p = SearchParams(k=5, ef_search=32, beam_width=64, strategy="sweeping",
                     max_hops=300)
    _assert_identical(small_graph, store, queries[:1], bm[:1], p)


# ---------------- packed bitset helpers ----------------

def test_bitset_mark_node0_padding_regression():
    """-1 padding ids map to word 0; a gather-or-SET scatter would let a
    padding entry clobber node 0's freshly written bit (the legacy visited
    bug the frontier work uncovered: node 0 then re-scores forever through
    2-hop cycles).  bitset_mark must be order-safe."""
    words = bitset_zeros(64)
    marked = bitset_mark(words, jnp.asarray([0, -1, -1, 37], jnp.int32),
                         jnp.asarray([True, False, False, True]))
    got = probe_bitmap(marked, jnp.arange(64))
    want = np.zeros(64, bool)
    want[[0, 37]] = True
    np.testing.assert_array_equal(np.asarray(got), want)


def test_bitset_roundtrip_matches_bool_semantics():
    rng = np.random.RandomState(0)
    n = 1000
    ids = rng.permutation(n)[:200].astype(np.int32)
    words = bitset_mark(bitset_zeros(n), jnp.asarray(ids),
                        jnp.ones((200,), bool))
    assert words.shape == (bitset_words(n),)
    got = np.asarray(probe_bitmap(words, jnp.arange(n)))
    want = np.zeros(n, bool)
    want[ids] = True
    np.testing.assert_array_equal(got, want)


def test_legacy_visited_marking_is_order_safe(small_dataset, small_graph):
    """The fixed legacy path must terminate without re-scoring node 0:
    hops stay far below the safety cap at moderate selectivity (the buggy
    gather-or-set walked to max_hops whenever node 0 resurrected)."""
    store, queries = small_dataset
    bm = generate_bitmaps(store, queries, WorkloadSpec(0.3, "none"), seed=11)
    p = SearchParams(k=10, ef_search=48, beam_width=128, strategy="acorn",
                     max_hops=2000, graph_exec_mode="vmapped")
    _, _, stats = search_batch(small_graph, store, queries, bm, p)
    assert int(np.asarray(stats.hops).max()) < 2000


# ---------------- frontier_scan kernel parity ----------------

def test_frontier_scan_parity_basic():
    rng = np.random.RandomState(3)
    q, c, d, n_rows = 5, 33, 70, 512
    queries = jnp.asarray(rng.randn(q, d).astype(np.float32))
    ids = rng.randint(-1, n_rows, (q, c)).astype(np.int32)
    vecs = jnp.asarray(rng.randn(q, c, d).astype(np.float32))
    norms = jnp.sum(vecs * vecs, -1)
    bms = jnp.stack([pack_bool_bitmap(rng.rand(n_rows) < 0.5)
                     for _ in range(q)])
    for metric in ("l2", "ip"):
        da, pa = ops.frontier_scan(queries, vecs, norms, jnp.asarray(ids),
                                   bms, metric=metric, use_pallas=True)
        db, pb = ref.frontier_scan_ref(queries, vecs, norms,
                                       jnp.asarray(ids), bms, metric)
        fa, fb = np.isfinite(np.asarray(da)), np.isfinite(np.asarray(db))
        np.testing.assert_array_equal(fa, fb)
        np.testing.assert_allclose(np.asarray(da)[fa], np.asarray(db)[fb],
                                   atol=2e-3, rtol=1e-3)
        np.testing.assert_array_equal(np.asarray(pa), np.asarray(pb))


@settings(max_examples=10, deadline=None)
@given(q=st.integers(1, 9), c=st.integers(1, 70), d=st.integers(1, 150),
       metric=st.sampled_from(["l2", "ip"]), density=st.floats(0.0, 1.0),
       seed=st.integers(0, 99))
def test_frontier_scan_parity_sweep(q, c, d, metric, density, seed):
    rng = np.random.RandomState(seed)
    n_rows = 256
    queries = jnp.asarray(rng.randn(q, d).astype(np.float32))
    ids = rng.randint(0, n_rows, (q, c)).astype(np.int32)
    ids[rng.rand(q, c) < 0.15] = -1
    vecs = jnp.asarray(rng.randn(q, c, d).astype(np.float32))
    norms = jnp.sum(vecs * vecs, -1)
    bms = jnp.stack([pack_bool_bitmap(rng.rand(n_rows) < density)
                     for _ in range(q)])
    da, pa = ops.frontier_scan(queries, vecs, norms, jnp.asarray(ids), bms,
                               metric=metric, use_pallas=True)
    db, pb = ref.frontier_scan_ref(queries, vecs, norms, jnp.asarray(ids),
                                   bms, metric)
    fa, fb = np.isfinite(np.asarray(da)), np.isfinite(np.asarray(db))
    np.testing.assert_array_equal(fa, fb)
    np.testing.assert_allclose(np.asarray(da)[fa], np.asarray(db)[fb],
                               atol=2e-3, rtol=1e-3)
    np.testing.assert_array_equal(np.asarray(pa), np.asarray(pb))


# ---------------- hypothesis: random graphs, fixed shapes ----------------

_HN, _HDEG, _HQ, _HD = 160, 8, 4, 24   # fixed shapes → one jit per engine


def _random_graph_case(seed: int):
    """Random base-layer graph with duplicate-free neighbor lists (the
    HNSW construction invariant both engines rely on), random vectors,
    random bitmaps."""
    rng = np.random.RandomState(seed)
    nbrs = np.full((1, _HN, _HDEG), -1, np.int64)
    for i in range(_HN):
        k = rng.randint(1, _HDEG + 1)
        cand = rng.permutation(_HN - 1)[:k]
        cand = np.where(cand >= i, cand + 1, cand)     # no self-loop
        nbrs[0, i, :k] = cand
    graph = HNSWGraph(neighbors=jnp.asarray(nbrs, jnp.int32),
                      node_level=jnp.zeros((_HN,), jnp.int32),
                      entry_point=jnp.asarray(rng.randint(_HN), jnp.int32),
                      m=_HDEG // 2)
    store = VectorStore.build(rng.randn(_HN, _HD).astype(np.float32))
    bits = rng.rand(_HQ, _HN) < rng.uniform(0.05, 0.9)
    bm = pack_bool_bitmap(bits)
    queries = jnp.asarray(rng.randn(_HQ, _HD).astype(np.float32))
    return graph, store, queries, bm


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000),
       strategy=st.sampled_from(list(STRATS)))
def test_frontier_random_graph_property(seed, strategy):
    """Property: on arbitrary random graphs (islands, dead ends, skewed
    degrees) the engines stay bit-identical."""
    graph, store, queries, bm = _random_graph_case(seed)
    p = SearchParams(k=5, ef_search=16, beam_width=32, strategy=strategy,
                     max_hops=200, batch_tuples=16, max_rounds=4)
    _assert_identical(graph, store, queries, bm, p)
