import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import VectorStore, build_graph
from repro.data import DatasetSpec, make_dataset


@pytest.fixture(scope="session")
def small_dataset():
    spec = DatasetSpec("t-small", 4000, 48, "l2", clusters=16)
    store, queries = make_dataset(spec, num_queries=8, seed=0)
    return store, jnp.asarray(queries)


@pytest.fixture(scope="session")
def small_graph(small_dataset):
    store, _ = small_dataset
    return build_graph(store, m=12, ef_construction=48, seed=0)


@pytest.fixture(scope="session")
def full_bitmaps(small_dataset):
    store, queries = small_dataset
    words = (store.n + 31) // 32
    return jnp.ones((queries.shape[0], words), jnp.uint32) * jnp.uint32(
        0xFFFFFFFF)
