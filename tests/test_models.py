"""Per-arch smoke tests (assignment §f) + decode/forward consistency."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, applicable_shapes, get_config, \
    smoke_config
from repro.launch.specs import make_smoke_batch
from repro.models import build_model


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_train_step(arch_id):
    """Reduced config: one forward/train step, output shapes, no NaNs."""
    cfg = smoke_config(arch_id)
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    batch = make_smoke_batch(cfg, batch=2, seq=64, kind="train")
    loss, grads = jax.jit(jax.value_and_grad(bundle.loss))(params, batch)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_prefill(arch_id):
    cfg = smoke_config(arch_id)
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    pb = make_smoke_batch(cfg, batch=2, seq=48, kind="prefill")
    out = jax.jit(bundle.prefill)(params, pb)
    assert np.isfinite(np.asarray(out)).all()
    if cfg.family == "encoder":
        assert out.shape == (2, 48, cfg.d_model)
    else:
        assert out.shape == (2, 1, cfg.vocab)


@pytest.mark.parametrize("arch_id", [a for a in ARCH_IDS
                                     if get_config(a).family != "encoder"])
def test_decode_matches_forward(arch_id):
    """Token-by-token decode must reproduce the full forward's last-token
    logits — validates KV caches, ring buffers, SSM states, rope offsets."""
    import dataclasses
    cfg = smoke_config(arch_id)
    if cfg.family == "moe":
        # capacity dropping is a train-time effect; decode (1 token/group)
        # never drops, so compare at a no-drop capacity factor
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    b, t = 2, 24
    rng = np.random.RandomState(3)
    tokens = rng.randint(0, cfg.vocab, (b, t)).astype(np.int32)
    if cfg.family == "vlm":
        # decode path of the VLM backbone is text-only; prefix with tokens
        full = jax.jit(bundle.prefill)(
            params, {"tokens": jnp.asarray(tokens),
                     "patch_embeds": jnp.zeros((b, 0, cfg.d_model))})
        pytest.skip("vlm decode uses the dense path (covered by dense)")
    full = jax.jit(bundle.prefill)(params, {"tokens": jnp.asarray(tokens)})
    cache = bundle.init_cache(b, t)
    dec = jax.jit(bundle.decode)
    logits = None
    for i in range(t):
        logits, cache = dec(params, cache,
                            {"tokens": jnp.asarray(tokens[:, i:i+1])},
                            jnp.int32(i))
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full),
                               atol=2e-2, rtol=2e-2)


def test_applicable_shapes_rules():
    assert applicable_shapes(get_config("hubert-xlarge")) == [
        "train_4k", "prefill_32k"]
    assert "long_500k" in applicable_shapes(get_config("rwkv6-3b"))
    assert "long_500k" in applicable_shapes(get_config("zamba2-1.2b"))
    assert "long_500k" not in applicable_shapes(get_config("gemma3-12b"))
    for a in ARCH_IDS:
        assert "train_4k" in applicable_shapes(get_config(a))


def test_full_configs_match_assignment():
    """Exact assignment-line numbers."""
    c = get_config("kimi-k2-1t-a32b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ff, c.vocab,
            c.n_experts, c.moe_top_k) == (61, 7168, 64, 8, 2048, 163840,
                                          384, 8)
    assert 0.9e12 < c.param_count() < 1.2e12      # trillion-param MoE
    c = get_config("granite-20b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ff, c.vocab) == \
        (52, 6144, 48, 1, 24576, 49152)
    c = get_config("gemma3-12b")
    assert (c.window > 0 and c.global_every == 6 and c.vocab == 262144)
    c = get_config("rwkv6-3b")
    assert c.family == "ssm" and c.d_model == 2560 and c.d_ff == 8960
    c = get_config("zamba2-1.2b")
    assert c.ssm_state == 64 and c.attn_every == 6
    c = get_config("hubert-xlarge")
    assert c.family == "encoder" and not c.causal and c.num_classes == 504
    c = get_config("llava-next-mistral-7b")
    assert c.family == "vlm" and c.num_patches > 0


def test_rwkv_chunked_equals_scan_end_to_end():
    import dataclasses
    cfg = smoke_config("rwkv6-3b")
    bundle_s = build_model(cfg)
    params = bundle_s.init(jax.random.PRNGKey(0))
    batch = make_smoke_batch(cfg, batch=2, seq=40, kind="train")
    l_scan = float(jax.jit(bundle_s.loss)(params, batch))
    cfg_c = dataclasses.replace(cfg, rwkv_mode="chunked", ssm_chunk=16)
    bundle_c = build_model(cfg_c)
    l_chunk = float(jax.jit(bundle_c.loss)(params, batch))
    assert abs(l_scan - l_chunk) < 1e-3


def test_loss_decreases_under_training():
    """Three SGD steps reduce the loss on a fixed batch (end-to-end grads)."""
    from repro.optim import AdamWConfig, adamw_init, adamw_update
    cfg = smoke_config("llama3.2-3b")
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    batch = make_smoke_batch(cfg, batch=4, seq=32, kind="train")
    ocfg = AdamWConfig(lr=5e-3, warmup_steps=1, total_steps=100)
    state = adamw_init(params, ocfg)
    losses = []

    @jax.jit
    def step(p, s):
        l, g = jax.value_and_grad(bundle.loss)(p, batch)
        p, s, _ = adamw_update(p, g, s, ocfg)
        return p, s, l

    for _ in range(5):
        params, state, l = step(params, state)
        losses.append(float(l))
    assert losses[-1] < losses[0]
