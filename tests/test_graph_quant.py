"""SQ8 quantized graph traversal (DESIGN.md §9).

Four layers of guarantees:

  * the int8 `frontier_scan_sq8` kernel matches its jnp oracle in
    interpret mode (deterministic + hypothesis sweeps when the dev dep
    is installed);
  * graph_quant="none" stays bit-identical to the pre-quantization
    engines (the shadow arrays are inert), and under graph_quant="sq8"
    the frontier and vmapped engines stay bit-identical to EACH OTHER
    (ids, dists, all seven counters) across strategies × selectivity;
  * the exact full-precision rerank bounds recall: sq8 recall@10 within
    0.02 of f32 across the selectivity grid, with ScaNN-reorder-style
    accounting (reorder_rows, full-width heap pages);
  * the storage engine routes quantized traversal through the dense
    "qheap" shadow segment, and the first-touch trace replays pages in
    superstep order (the order-faithful LRU regression).

Plus the quant-aware cost model (rerank surcharge, cheaper int8
materialization, shadow-segment misses) and the planner's sweeping_sq8
dispatch candidate + pool-measured engine amortization.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # optional dev dep (requirements-dev.txt):
    # property tests skip individually; plain tests in this module still run
    def given(*a, **k):
        return lambda f: pytest.mark.skip(
            reason="hypothesis not installed")(f)

    def settings(*a, **k):
        return lambda f: f

    class st:  # stub strategies so decorator arguments still evaluate
        integers = floats = sampled_from = staticmethod(
            lambda *a, **k: None)

from repro.core import (SYSTEM, SearchParams, SearchPlan, WorkloadSpec,
                        build_scann, filtered_knn, generate_bitmaps,
                        heap_pages_per_vector, make_executor, pack_bool_bitmap,
                        predict_counters, predict_cycles,
                        quant_heap_pages_per_vector, quantize_store,
                        recall_at_k, search_batch)
from repro.core.costmodel import (FRONTIER_CALIB_UNIQUE, FRONTIER_PAGE_AMORT,
                                  IndexShape, cache_miss_penalty,
                                  engine_scale)
from repro.core.graph_search import TRACE_UNTOUCHED
from repro.kernels import ops, ref
from repro.storage import (BufferPoolState, GraphAdjacencyLayout, HeapLayout,
                           StorageEngine, make_storage_engine)

STRATS = ("unfiltered", "sweeping", "acorn", "navix", "iterative_scan")
STAT_FIELDS = ("distance_comps", "filter_checks", "hops",
               "page_accesses_index", "page_accesses_heap", "tmap_lookups",
               "reorder_rows")
PARAMS = SearchParams(k=10, ef_search=48, beam_width=128, max_hops=500)


@pytest.fixture(scope="module")
def quant_store(small_dataset):
    store, _ = small_dataset
    return quantize_store(store)


@pytest.fixture(scope="module")
def scann_index(small_dataset):
    store, _ = small_dataset
    return build_scann(store, num_leaves=64, levels=2, seed=0)


def _assert_engines_identical(graph, store, queries, bm, p):
    pv = dataclasses.replace(p, graph_exec_mode="vmapped")
    pf = dataclasses.replace(p, graph_exec_mode="frontier")
    dv, iv, sv = search_batch(graph, store, queries, bm, pv)
    df, iff, sf = search_batch(graph, store, queries, bm, pf)
    np.testing.assert_array_equal(np.asarray(iv), np.asarray(iff))
    assert np.array_equal(np.asarray(dv), np.asarray(df), equal_nan=True), \
        "distances not bit-identical"
    for f in STAT_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(sv, f)), np.asarray(getattr(sf, f)),
            err_msg=f"counter {f} diverged")
    return dv, iv, sv


# ---------------- the SQ8 shadow store ----------------

def test_quantize_store_roundtrip(small_dataset, quant_store):
    store, _ = small_dataset
    sq = quant_store
    assert not store.has_sq8 and sq.has_sq8
    assert quantize_store(sq) is sq                    # idempotent
    assert sq.q_vectors.dtype == jnp.int8
    deq = (np.asarray(sq.q_vectors, np.float32) * np.asarray(sq.q_scale)
           + np.asarray(sq.q_mean))
    err = np.abs(deq - np.asarray(store.vectors))
    # affine SQ8 over [lo, hi] with 254 steps: error ≤ scale/2 per dim
    assert (err <= np.asarray(sq.q_scale)[None, :] * 0.51).all()
    np.testing.assert_allclose(np.asarray(sq.q_norms_sq),
                               (deq * deq).sum(-1), rtol=1e-5)


def test_sq8_requires_shadow(small_dataset, small_graph):
    store, queries = small_dataset
    bm = generate_bitmaps(store, queries, WorkloadSpec(0.2, "none"), seed=2)
    p = dataclasses.replace(PARAMS, strategy="sweeping", graph_quant="sq8")
    with pytest.raises(ValueError, match="quantize_store"):
        search_batch(small_graph, store, queries, bm, p)
    with pytest.raises(ValueError, match="graph_quant"):
        search_batch(small_graph, store, queries, bm,
                     dataclasses.replace(PARAMS, graph_quant="fp4"))


# ---------------- frontier_scan_sq8 kernel parity ----------------

def _sq8_case(rng, q, c, d, n_rows, density):
    queries = jnp.asarray(rng.randn(q, d).astype(np.float32))
    ids = rng.randint(0, n_rows, (q, c)).astype(np.int32)
    ids[rng.rand(q, c) < 0.15] = -1
    qv = rng.randint(-127, 128, (q, c, d)).astype(np.int8)
    scale = jnp.asarray((np.abs(rng.randn(d)) * 0.05 + 1e-3)
                        .astype(np.float32))
    mean = jnp.asarray((rng.randn(d) * 0.1).astype(np.float32))
    x = jnp.asarray(qv, jnp.float32) * scale + mean
    norms = jnp.sum(x * x, -1)
    bms = jnp.stack([pack_bool_bitmap(rng.rand(n_rows) < density)
                     for _ in range(q)])
    return queries, jnp.asarray(qv), scale, mean, norms, \
        jnp.asarray(ids), bms


def _assert_sq8_parity(case, metric):
    queries, qv, scale, mean, norms, ids, bms = case
    da, pa = ops.frontier_scan_sq8(queries, qv, scale, mean, norms, ids,
                                   bms, metric=metric, use_pallas=True)
    db, pb = ref.frontier_scan_sq8_ref(queries, qv, scale, mean, norms,
                                       ids, bms, metric)
    fa, fb = np.isfinite(np.asarray(da)), np.isfinite(np.asarray(db))
    np.testing.assert_array_equal(fa, fb)
    np.testing.assert_allclose(np.asarray(da)[fa], np.asarray(db)[fb],
                               atol=2e-3, rtol=1e-3)
    np.testing.assert_array_equal(np.asarray(pa), np.asarray(pb))


def test_frontier_scan_sq8_parity_basic():
    rng = np.random.RandomState(5)
    case = _sq8_case(rng, q=5, c=33, d=70, n_rows=512, density=0.5)
    for metric in ("l2", "ip"):
        _assert_sq8_parity(case, metric)


@settings(max_examples=10, deadline=None)
@given(q=st.integers(1, 9), c=st.integers(1, 70), d=st.integers(1, 150),
       metric=st.sampled_from(["l2", "ip"]), density=st.floats(0.0, 1.0),
       seed=st.integers(0, 99))
def test_frontier_scan_sq8_parity_sweep(q, c, d, metric, density, seed):
    rng = np.random.RandomState(seed)
    case = _sq8_case(rng, q, c, d, n_rows=256, density=density)
    _assert_sq8_parity(case, metric)


# ---------------- engine equivalence ----------------

@pytest.mark.parametrize("strategy", STRATS)
def test_none_mode_ignores_shadow(small_dataset, small_graph, quant_store,
                                  strategy):
    """graph_quant="none" on a shadow-carrying store must be bit-identical
    to the plain store (the shadow arrays are inert bookkeeping), on both
    engines."""
    store, queries = small_dataset
    bm = generate_bitmaps(store, queries, WorkloadSpec(0.2, "none"), seed=4)
    p = dataclasses.replace(PARAMS, strategy=strategy, graph_quant="none")
    d0, i0, s0 = _assert_engines_identical(small_graph, store, queries, bm,
                                           p)
    d1, i1, s1 = _assert_engines_identical(small_graph, quant_store,
                                           queries, bm, p)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    assert np.array_equal(np.asarray(d0), np.asarray(d1), equal_nan=True)
    for f in STAT_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(s0, f)),
                                      np.asarray(getattr(s1, f)), f)


@pytest.mark.parametrize("strategy", STRATS)
def test_sq8_engines_bit_identical(small_dataset, small_graph, quant_store,
                                   strategy):
    """Under graph_quant="sq8" the frontier engine must reproduce the
    vmapped engine exactly (same quantized traversal, same exact rerank,
    same counters) across the selectivity grid."""
    _, queries = small_dataset
    p = dataclasses.replace(PARAMS, strategy=strategy, graph_quant="sq8")
    for sel in (0.05, 0.5):
        bm = generate_bitmaps(quant_store, queries, WorkloadSpec(sel, "none"),
                              seed=int(sel * 100) + 1)
        _, _, stats = _assert_engines_identical(small_graph, quant_store,
                                                queries, bm, p)
        assert int(np.asarray(stats.reorder_rows).sum()) > 0


def test_sq8_rerank_accounting(small_dataset, small_graph, quant_store):
    """ScaNN-reorder-style rerank semantics: reorder_rows counts the valid
    final-beam entries, each charged one full-width heap fetch and one
    exact distance comp on top of the quantized traversal."""
    store, queries = small_dataset
    bm = generate_bitmaps(store, queries, WorkloadSpec(0.2, "none"), seed=6)
    p0 = dataclasses.replace(PARAMS, strategy="sweeping")
    p1 = dataclasses.replace(p0, graph_quant="sq8")
    _, _, s0 = search_batch(small_graph, quant_store, queries, bm, p0)
    _, _, s1 = search_batch(small_graph, quant_store, queries, bm, p1)
    rr = np.asarray(s1.reorder_rows)
    assert (rr > 0).all() and (rr <= PARAMS.ef_search).all()
    assert (np.asarray(s0.reorder_rows) == 0).all()
    ppv = heap_pages_per_vector(store.dim)
    # the rerank's full-width pages ride the heap counter
    assert (np.asarray(s1.page_accesses_heap) >= rr * ppv).all()


@pytest.mark.parametrize("strategy", ("sweeping", "acorn"))
def test_sq8_recall_guardrail(small_dataset, small_graph, quant_store,
                              strategy):
    """sq8 + exact rerank recall@10 stays within 0.02 of f32 across the
    selectivity grid (the quantized tier's recall bound)."""
    store, queries = small_dataset
    p = SearchParams(k=10, ef_search=64, beam_width=128, strategy=strategy,
                     max_hops=1000)
    for sel in (0.05, 0.2, 0.5):
        bm = generate_bitmaps(store, queries, WorkloadSpec(sel, "none"),
                              seed=int(sel * 1000))
        _, tid = filtered_knn(store, queries, bm, p.k)

        def rec(params):
            _, ids, _ = search_batch(small_graph, quant_store, queries, bm,
                                     params)
            return float(np.mean(np.asarray(jax.vmap(
                lambda f, t: recall_at_k(f, t, p.k))(ids, tid))))

        r_f32 = rec(p)
        r_sq8 = rec(dataclasses.replace(p, graph_quant="sq8"))
        assert r_sq8 >= r_f32 - 0.02, (strategy, sel, r_f32, r_sq8)


# ---------------- storage integration ----------------

def test_sq8_storage_uses_qheap_segment(small_dataset, small_graph,
                                        quant_store):
    store, queries = small_dataset
    bm = generate_bitmaps(store, queries, WorkloadSpec(0.2, "none"), seed=8)
    p = SearchParams(k=10, ef_search=96, beam_width=512, max_hops=2048)
    runs = {}
    for method in ("sweeping", "sweeping_sq8"):
        eng = make_storage_engine(quant_store, graph=small_graph,
                                  capacity_frac=1.0)
        ex = make_executor(method, quant_store, graph=small_graph,
                           storage=eng)
        runs[method] = ex.search(queries, bm, p)
    s_f32, s_sq8 = runs["sweeping"].storage, runs["sweeping_sq8"].storage
    assert "qheap" not in s_f32.logical
    assert s_sq8.logical["qheap"] > 0
    # traversal logical moves to the shadow segment; what remains on
    # "heap" is the rerank (full-width, reorder_rows pages)
    rr = int(np.asarray(runs["sweeping_sq8"].stats.reorder_rows).sum())
    assert s_sq8.logical["heap"] == rr * heap_pages_per_vector(store.dim)
    # the dense shadow segment is 4x smaller -> cold physical reads of
    # the traversal can never exceed the f32 run's
    assert s_sq8.misses["qheap"] < s_f32.misses["heap"]
    # tracing is write-only bookkeeping: same ids as the un-pooled run
    ex0 = make_executor("sweeping_sq8", quant_store, graph=small_graph)
    r0 = ex0.search(queries, bm, p)
    np.testing.assert_array_equal(np.asarray(r0.ids),
                                  np.asarray(runs["sweeping_sq8"].ids))


def test_trace_first_touch_superstep_order(small_dataset, small_graph):
    """The graph trace stamps first touches with the hop counter: the
    entry row is stamped 0, every stamp is bounded by the query's hop
    count, and the resulting replay order differs from id-ascending
    (the pre-PR approximation) for real traversals."""
    store, queries = small_dataset
    bm = generate_bitmaps(store, queries, WorkloadSpec(0.2, "none"), seed=9)
    p = SearchParams(k=10, ef_search=48, beam_width=128,
                     strategy="sweeping", max_hops=500)
    _, _, stats, trace = search_batch(small_graph, store, queries, bm, p,
                                      collect_trace=True)
    hs = np.asarray(trace["heap_steps"])
    entry = int(small_graph.entry_point)
    assert (hs[:, entry] == 0).all()
    hops = np.asarray(stats.hops)
    touched = hs < TRACE_UNTOUCHED
    assert touched.any(axis=1).all()
    for i in range(hs.shape[0]):
        assert hs[i][touched[i]].max() <= hops[i]
    # the stamps carry real order information: for at least one query the
    # step-sorted replay differs from plain id-ascending order
    nontrivial = any(
        not np.all(np.diff(np.argsort(hs[i][touched[i]],
                                      kind="stable")) > 0)
        for i in range(hs.shape[0]))
    assert nontrivial, "replay order degenerated to id-ascending"


def test_account_graph_replay_order_is_superstep_faithful():
    """Order-faithful LRU regression (ROADMAP follow-up): with a
    capacity-1 pool, the page of the LAST-touched row must be resident
    after replay — id-ascending replay (the old semantics) would keep
    the highest row id instead."""
    heap = HeapLayout(n=100, dim=2048)          # 1 row per page
    gl = GraphAdjacencyLayout(n=100, degree=8)
    eng = StorageEngine(heap, graph=gl, capacity_pages=1)
    steps = np.full((1, 100), TRACE_UNTOUCHED, np.int32)
    steps[0, 50] = 0                            # touched first...
    steps[0, 3] = 1                             # ...then row 3
    isteps = np.full((1, 100), TRACE_UNTOUCHED, np.int32)
    eng.account_graph(steps, isteps)
    base = eng.segment_ranges()["heap"][0]
    assert (base + 3) in eng.pool               # last touch stays resident
    assert (base + 50) not in eng.pool
    # id-ascending would have replayed 3 then 50 and kept page 50


def test_storage_stats_unique_fraction(small_dataset, small_graph):
    store, queries = small_dataset
    bm = generate_bitmaps(store, queries, WorkloadSpec(0.2, "none"), seed=10)
    eng = make_storage_engine(store, graph=small_graph, capacity_frac=1.0)
    ex = make_executor("sweeping", store, graph=small_graph, storage=eng)
    res = ex.search(queries, bm,
                    SearchParams(k=10, ef_search=96, beam_width=512,
                                 max_hops=2048))
    s = res.storage
    for seg in s.logical:
        assert 0 < s.unique[seg] <= s.logical[seg]
    assert 0.0 < s.unique_fraction() <= 1.0
    assert s.unique_fraction(["heap"]) == s.unique["heap"] / s.logical["heap"]


# ---------------- quant-aware cost model ----------------

def test_predict_counters_sq8_rerank_surcharge():
    shape = IndexShape(n=20_000, dim=768, graph_m=16)
    p = SearchParams(k=10, ef_search=64, strategy="sweeping")
    psq = dataclasses.replace(p, graph_quant="sq8")
    c0 = predict_counters("sweeping", shape, p, 0.1)
    c1 = predict_counters("sweeping", shape, psq, 0.1)
    ef = float(max(p.ef_search, 2 * p.k))
    assert c1["reorder_rows"] == ef and c0["reorder_rows"] == 0.0
    assert c1["distance_comps"] == pytest.approx(c0["distance_comps"] + ef)
    ppv = heap_pages_per_vector(shape.dim)
    qppv = quant_heap_pages_per_vector(shape.dim)
    assert c1["page_accesses_heap"] == pytest.approx(
        c0["page_accesses_heap"] / ppv * qppv + ef * ppv)
    # at transformer widths the int8 materialization saving beats the
    # rerank surcharge even cold-blind
    assert predict_cycles("sweeping", shape, psq, 0.1) < \
        predict_cycles("sweeping", shape, p, 0.1)


def test_cache_miss_penalty_sq8_uses_shadow_segment():
    shape = IndexShape(n=20_000, dim=768, graph_m=16)
    p = SearchParams(k=10, ef_search=64, strategy="sweeping")
    psq = dataclasses.replace(p, graph_quant="sq8")
    c1 = predict_counters("sweeping", shape, psq, 0.1)
    cold = BufferPoolState(capacity=0, used=0, residency={})
    warm_shadow = BufferPoolState(
        capacity=0, used=0,
        residency={"qheap": 1.0, "heap": 0.0, "graph": 0.0})
    pen_cold = cache_miss_penalty(c1, "sweeping", cold, SYSTEM,
                                  graph_quant="sq8", dim=shape.dim)
    pen_warm = cache_miss_penalty(c1, "sweeping", warm_shadow, SYSTEM,
                                  graph_quant="sq8", dim=shape.dim)
    assert pen_warm < pen_cold
    # with the shadow fully warm, only the rerank's full-width pages and
    # the index pages still pay misses
    extra = SYSTEM.page_access * (SYSTEM.page_miss_extra - 1.0)
    expect = (c1["reorder_rows"] * heap_pages_per_vector(shape.dim)
              + c1["page_accesses_index"]) * extra
    assert pen_warm == pytest.approx(expect)


def test_engine_scale_measured_amortization():
    p = SearchParams(k=10, strategy="sweeping")
    assert engine_scale("sweeping", p, 1) is None
    s0 = engine_scale("sweeping", p, 32)
    assert s0["vector_retrieval"] == FRONTIER_PAGE_AMORT
    s1 = engine_scale("sweeping", p, 32,
                      measured_unique_frac=FRONTIER_CALIB_UNIQUE / 2)
    assert s1["vector_retrieval"] == pytest.approx(FRONTIER_PAGE_AMORT / 2)
    assert s1["index_page_access"] == s1["vector_retrieval"]
    # clamped: a pathological measurement can't zero the costs
    s2 = engine_scale("sweeping", p, 32, measured_unique_frac=1e-6)
    assert s2["vector_retrieval"] == 0.05


# ---------------- planner integration ----------------

def test_planner_has_sq8_candidate(small_dataset, small_graph, scann_index):
    store, queries = small_dataset
    planner = make_executor("adaptive", store, graph=small_graph,
                            index=scann_index, graph_m=small_graph.m)
    assert "sweeping_sq8" in planner.candidates
    ex = planner.candidates["sweeping_sq8"]
    assert ex.strategy == "sweeping" and ex.graph_quant == "sq8"
    assert ex.store.has_sq8
    bm = generate_bitmaps(store, queries, WorkloadSpec(0.2, "none"), seed=12)
    plan = planner.plan(queries, bm, PARAMS)
    assert "sweeping_sq8" in plan.predicted_cycles
    # the twins are priced differently (rerank surcharge vs int8 saving)
    assert plan.predicted_cycles["sweeping_sq8"] != \
        plan.predicted_cycles["sweeping"]


def test_registry_sq8_methods(small_dataset, small_graph):
    store, _ = small_dataset
    ex = make_executor("sweeping_sq8", store, graph=small_graph)
    assert ex.name == "sweeping_sq8" and ex.store.has_sq8
    with pytest.raises(ValueError, match="needs graph"):
        make_executor("acorn_sq8", store)


def test_planner_measured_amortization_feedback(small_dataset, small_graph,
                                                scann_index):
    """After a pooled graph dispatch, the planner reprices graph
    candidates with the batch's MEASURED page-sharing fraction instead of
    the FRONTIER_PAGE_AMORT constant (ROADMAP follow-up)."""
    store, queries = small_dataset
    eng = make_storage_engine(store, index=scann_index, graph=small_graph,
                              capacity_frac=1.0)
    planner = make_executor("adaptive", store, graph=small_graph,
                            index=scann_index, graph_m=small_graph.m,
                            storage=eng)
    bm = generate_bitmaps(store, queries, WorkloadSpec(0.2, "none"), seed=13)
    p = SearchParams(k=10, ef_search=96, beam_width=512, max_hops=2048)
    assert planner._measured_unique is None
    before = planner.plan(queries, bm, p).predicted_cycles
    # force a graph dispatch through the planner's execute path
    inner = planner.candidates["sweeping"].plan(queries, bm, p)
    planner.execute(SearchPlan("sweeping", inner.params, queries, bm))
    assert planner._measured_unique is not None
    assert 0.0 < planner._measured_unique <= 1.0
    after = planner.plan(queries, bm, p).predicted_cycles
    assert after["sweeping"] != before["sweeping"]
