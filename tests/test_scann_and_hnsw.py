"""ScaNN index + HNSW construction invariants."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (SearchParams, VectorStore, WorkloadSpec, build_graph,
                        build_incremental, build_scann, filtered_knn, knn,
                        generate_bitmaps, recall_at_k, scann_search_batch,
                        search_batch, stats_table_row)
from repro.core.hnsw import _components
from repro.core.scann import project_query
from repro.data import DatasetSpec, make_dataset


def _recall(ids, tid, k=10):
    return float(np.mean(np.asarray(
        jax.vmap(lambda f, t: recall_at_k(f, t, k))(ids, tid))))


# ---------------- HNSW construction ----------------

def test_graph_invariants(small_dataset, small_graph):
    store, _ = small_dataset
    nb = np.asarray(small_graph.neighbors)
    n = store.n
    assert (nb < n).all()
    # no self edges at level 0
    self_edges = nb[0][np.arange(n)] == np.arange(n)[:, None]
    assert not self_edges.any()
    # base layer is a single component (repair pass)
    assert len(np.unique(_components(nb[0]))) == 1
    # entry point has max level
    lv = np.asarray(small_graph.node_level)
    assert lv[int(small_graph.entry_point)] == lv.max()


def test_incremental_builder_recall():
    spec = DatasetSpec("t-inc", 600, 24, "l2", clusters=8)
    store, queries = make_dataset(spec, num_queries=5, seed=1)
    g = build_incremental(store, m=8, ef_construction=40, seed=0)
    _, tid = knn(store, jnp.asarray(queries), 5)
    words = (store.n + 31) // 32
    full = jnp.ones((5, words), jnp.uint32) * jnp.uint32(0xFFFFFFFF)
    p = SearchParams(k=5, ef_search=64, beam_width=256,
                     strategy="unfiltered")
    _, ids, _ = search_batch(g, store, jnp.asarray(queries), full, p)
    assert _recall(ids, tid, 5) >= 0.9


# ---------------- ScaNN ----------------

@pytest.fixture(scope="module")
def scann_setup(small_dataset):
    store, queries = small_dataset
    idx = build_scann(store, num_leaves=64, levels=2, seed=0)
    return store, queries, idx


def test_scann_leaf_partition(scann_setup):
    store, _, idx = scann_setup
    rid = np.asarray(idx.leaf_rowids)
    valid = rid[rid >= 0]
    assert len(valid) == store.n            # every row in exactly one leaf
    assert len(np.unique(valid)) == store.n


def test_scann_filtered_recall(scann_setup):
    store, queries, idx = scann_setup
    for sel in (0.1, 0.5):
        bm = generate_bitmaps(store, queries, WorkloadSpec(sel, "none"),
                              seed=1)
        _, tid = filtered_knn(store, queries, bm, 10)
        p = SearchParams(k=10, num_leaves_to_search=32, reorder_factor=4)
        _, ids, stats = scann_search_batch(idx, store, queries, bm, p)
        assert _recall(ids, tid) >= 0.9, sel
        row = stats_table_row(stats)
        assert row["hops"] == 32            # leaves scanned
        assert row["reorder_rows"] > 0


def test_scann_results_pass_filter(scann_setup):
    from repro.core import probe_bitmap
    store, queries, idx = scann_setup
    bm = generate_bitmaps(store, queries, WorkloadSpec(0.05, "none"), seed=2)
    p = SearchParams(k=10, num_leaves_to_search=32)
    _, ids, _ = scann_search_batch(idx, store, queries, bm, p)
    ok = jax.vmap(probe_bitmap)(bm, jnp.maximum(ids, 0))
    valid = np.asarray(ids) >= 0
    assert np.asarray(ok)[valid].all()


def test_scann_quantization_error_bounded(scann_setup):
    """SQ8 reconstruction error ≤ scale/2 per dim (affine quantizer)."""
    store, _, idx = scann_setup
    rid = np.asarray(idx.leaf_rowids)
    tiles = np.asarray(idx.leaf_tiles, np.float32)
    scale = np.asarray(idx.scale)
    mean = np.asarray(idx.mean)
    recon = tiles * scale + mean
    mask = rid >= 0
    orig = np.asarray(store.vectors)[rid[mask]]
    err = np.abs(recon[mask] - orig)
    assert (err <= scale[None, :] * 0.51 + 1e-5).all()


def test_scann_pca_path():
    spec = DatasetSpec("t-pca", 2000, 96, "ip", clusters=8)
    store, queries = make_dataset(spec, num_queries=4, seed=2)
    idx = build_scann(store, num_leaves=32, levels=1, pca_dims=24, seed=0)
    assert idx.leaf_tiles.shape[-1] == 24
    q = jnp.asarray(queries)
    qp = project_query(idx, q[0])
    assert qp.shape == (24,)
    words = (store.n + 31) // 32
    bm = jnp.ones((4, words), jnp.uint32) * jnp.uint32(0xFFFFFFFF)
    _, tid = knn(store, q, 10)
    p = SearchParams(k=10, num_leaves_to_search=16, reorder_factor=10)
    _, ids, _ = scann_search_batch(idx, store, q, bm, p)
    assert _recall(ids, tid) >= 0.8    # PCA 96->24 is lossy; reorder saves it


def test_scann_pallas_path_matches_ref(scann_setup):
    store, queries, idx = scann_setup
    bm = generate_bitmaps(store, queries[:2], WorkloadSpec(0.3, "none"),
                          seed=3)
    p = SearchParams(k=10, num_leaves_to_search=16)
    d1, i1, _ = scann_search_batch(idx, store, queries[:2], bm, p,
                                   use_pallas=False)
    d2, i2, _ = scann_search_batch(idx, store, queries[:2], bm, p,
                                   use_pallas=True)
    assert (np.asarray(i1) == np.asarray(i2)).all()
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), rtol=1e-4)
