"""Mesh-sharded graph + storage tiers (DESIGN.md §13).

Lockstep (beam_exchange_interval=1) sharding must be INVISIBLE: the
owner-masked pmin/pmax reductions select the owning shard's bit-exact
values, so ids, dists, and every counter match the single-device engine
for any shard count.  Drift mode (E>1) trades recall for collective
volume.  Multi-device shard_map execution runs in a subprocess with 8
forced host devices (XLA locks the device count at first init)."""
import dataclasses
import json
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (SearchParams, WorkloadSpec, filtered_knn,
                        generate_bitmaps, make_executor, quantize_store,
                        recall_at_k)
from repro.core import costmodel
from repro.core.distributed import (ShardedGraphExecutor,
                                    make_sharded_storage,
                                    shard_graph_tiers)
from repro.core.types import SearchStats, sq8_quantize
from repro.data import DatasetSpec, make_dataset, make_dataset_streamed
from repro.data.datasets import _stream_block, _stream_centers
from repro.launch.mesh import make_mesh, validate_mesh_request
from repro.storage import make_storage_engine

STRATEGIES = ("unfiltered", "sweeping", "acorn", "navix", "iterative_scan")


@pytest.fixture(scope="module")
def sharding_setup(small_dataset, small_graph):
    store, queries = small_dataset
    store = quantize_store(store)
    bm = generate_bitmaps(store, queries, WorkloadSpec(0.3, "none"), seed=5)
    return store, queries, small_graph, bm


def _params(strategy, quant="none", E=1):
    return SearchParams(k=10, ef_search=32, beam_width=128,
                        strategy=strategy, max_hops=150, graph_quant=quant,
                        beam_exchange_interval=E,
                        batch_tuples=64, max_rounds=8)


def _stats_dict(stats):
    return {k: np.asarray(v) for k, v in stats.as_dict().items()}


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_lockstep_shard_count_invariance(sharding_setup, strategy):
    """1/2/4/8 shards × none/sq8: bit-identical ids, dists, counters."""
    store, queries, graph, bm = sharding_setup
    for quant in ("none", "sq8"):
        p = _params(strategy, quant)
        method = strategy if quant == "none" else f"{strategy}_sq8"
        base = make_executor(method, store, graph=graph).search(
            queries, bm, p)
        bstats = _stats_dict(base.stats)
        for S in (1, 2, 4, 8):
            ex = ShardedGraphExecutor(graph, store, S, strategy=strategy,
                                      graph_quant=quant)
            res = ex.search(queries, bm, p)
            assert np.array_equal(np.asarray(res.ids),
                                  np.asarray(base.ids)), (strategy, quant, S)
            assert np.array_equal(np.asarray(res.dists),
                                  np.asarray(base.dists)), (strategy,
                                                            quant, S)
            for k, v in _stats_dict(res.stats).items():
                assert np.array_equal(v, bstats[k]), (strategy, quant, S, k)


def test_drift_recall_monotone_in_exchange_interval(sharding_setup):
    """E=1 (lockstep) is exact w.r.t. the base engine; widening E only
    loses recall (within noise slack), never collapses it."""
    store, queries, graph, bm = sharding_setup
    _, tid = filtered_knn(store, queries, bm, 10)

    def rec(ids):
        return float(np.mean(np.asarray(jax.vmap(
            lambda f, t: recall_at_k(f, t, 10))(ids, tid))))

    ex = ShardedGraphExecutor(graph, store, 2, strategy="sweeping")
    recalls = {}
    for E in (1, 2, 4, 8):
        recalls[E] = rec(ex.search(queries, bm, _params("sweeping",
                                                        E=E)).ids)
    base = make_executor("sweeping", store, graph=graph)
    assert recalls[1] == rec(base.search(queries, bm,
                                         _params("sweeping")).ids)
    prev = recalls[1]
    for E in (2, 4, 8):
        assert recalls[E] <= prev + 0.05, recalls   # monotone within slack
        assert recalls[E] >= 0.5, recalls           # still a real search
        prev = recalls[E]


def test_drift_mode_validations(sharding_setup):
    store, queries, graph, bm = sharding_setup
    ex = ShardedGraphExecutor(graph, store, 2, strategy="iterative_scan")
    with pytest.raises(ValueError, match="emission buffer"):
        ex.search(queries, bm, _params("iterative_scan", E=2))
    engines = [make_storage_engine(store, graph=graph, capacity_frac=0.5)
               for _ in range(2)]
    acct = make_sharded_storage(engines, store.n)
    exs = ShardedGraphExecutor(graph, store, 2, storage=acct)
    with pytest.raises(ValueError, match="lockstep"):
        exs.search(queries, bm, _params("sweeping", E=4))
    with pytest.raises(ValueError, match="shards"):
        ShardedGraphExecutor(graph, store, 4, storage=acct)
    with pytest.raises(ValueError, match="sq8"):
        ShardedGraphExecutor(graph, store, 2, f32=False,
                             graph_quant="none")


def test_sharded_storage_aggregation(sharding_setup):
    """Per-shard pools see disjoint row slices; the merged StorageStats
    equals the single-engine accounting in every logical counter."""
    store, queries, graph, bm = sharding_setup
    p = _params("sweeping")
    single = make_storage_engine(store, graph=graph, capacity_frac=1.0)
    base = make_executor("sweeping", store, graph=graph,
                         storage=single).search(queries, bm, p)
    S = 2
    engines = [make_storage_engine(store, graph=graph, capacity_frac=1.0)
               for _ in range(S)]
    acct = make_sharded_storage(engines, store.n)
    ex = ShardedGraphExecutor(graph, store, S, strategy="sweeping",
                              storage=acct)
    res = ex.search(queries, bm, p)
    assert res.storage.logical == base.storage.logical
    assert len(acct.last_per_shard) == S
    for s in acct.last_per_shard:
        assert 0.0 <= s.hit_rate <= 1.0
    # each shard only touches its own rows: per-shard heap logical sums
    # to the single-engine heap logical
    heap = sum(s.logical.get("heap", 0) for s in acct.last_per_shard)
    assert heap == base.storage.logical.get("heap", 0)
    st = acct.state()
    assert st.capacity == sum(e.state().capacity for e in engines)


def test_serving_delegates_match_graph_executor(sharding_setup):
    """init/step/finalize (continuous-batching surface) are bit-equal to
    GraphExecutor's — the server consumes the sharded tier unchanged."""
    store, queries, graph, bm = sharding_setup
    p = _params("sweeping")
    base = make_executor("sweeping", store, graph=graph)
    ex = ShardedGraphExecutor(graph, store, 4, strategy="sweeping")
    st_b = base.init_frontier(queries, bm, p)
    st_s = ex.init_frontier(queries, bm, p)
    for _ in range(3):
        st_b = base.step_frontier(st_b, p, 20)
        st_s = ex.step_frontier(st_s, p, 20)
    db, ib, _ = base.finalize_frontier(st_b, p)[:3]
    ds, is_, _ = ex.finalize_frontier(st_s, p)[:3]
    assert np.array_equal(np.asarray(ib), np.asarray(is_))
    assert np.array_equal(np.asarray(db), np.asarray(ds))
    with pytest.raises(ValueError, match="lockstep"):
        ex.init_frontier(queries, bm, _params("sweeping", E=2))


def test_shard_tiers_partition(sharding_setup):
    """Blocked views cover every row exactly once, −1-pad the tail, and
    keep global ids in the adjacency."""
    store, queries, graph, bm = sharding_setup
    gv, sv = shard_graph_tiers(graph, store, 4)
    S, rps = 4, -(-store.n // 4)
    assert sv.vectors.shape == (S, rps, store.dim)
    flat = np.asarray(sv.vectors).reshape(S * rps, store.dim)[:store.n]
    assert np.array_equal(flat, np.asarray(store.vectors))
    nb = np.asarray(gv.neighbors)
    assert nb.shape[0] == S and nb.max() < store.n
    # local entries: each shard's entry is a row it owns (or −1)
    le = np.asarray(gv.local_entry)
    for s in range(S):
        if le[s] >= 0:
            assert s * rps <= le[s] < (s + 1) * rps


def test_mesh_validation_errors():
    validate_mesh_request((2, 4), ("data", "model"))
    with pytest.raises(ValueError, match="one name per dim"):
        validate_mesh_request((2, 4), ("data",))
    with pytest.raises(ValueError, match="non-positive"):
        validate_mesh_request((0,), ("data",))
    with pytest.raises(ValueError, match="duplicate"):
        validate_mesh_request((2, 2), ("data", "data"))
    with pytest.raises(ValueError, match="did you mean 'shard'"):
        validate_mesh_request((2,), ("shrad",))
    with pytest.raises(ValueError, match="divisible"):
        validate_mesh_request((3,), ("data",), num_devices=8)
    m = make_mesh((1,), ("shard",))
    assert m.axis_names == ("shard",)


def test_streamed_dataset_matches_batch_quantizer():
    """Streamed two-pass SQ8 is bit-equal to quantizing the materialized
    array; block RNG is deterministic and block_rows-stable for a fixed
    value; f32=False carries the same shadow with placeholder f32."""
    spec = DatasetSpec("t-stream", 3_000, 16, "ip", clusters=8)
    s1, q1 = make_dataset_streamed(spec, num_queries=6, seed=3,
                                   block_rows=512)
    s2, q2 = make_dataset_streamed(spec, num_queries=6, seed=3,
                                   block_rows=512)
    assert np.array_equal(np.asarray(s1.vectors), np.asarray(s2.vectors))
    assert np.array_equal(np.asarray(q1), np.asarray(q2))
    q, scale, mean = sq8_quantize(np.asarray(s1.vectors))
    assert np.array_equal(np.asarray(s1.q_vectors), q)
    assert np.array_equal(np.asarray(s1.q_scale), scale)
    assert np.array_equal(np.asarray(s1.q_mean), mean)
    # f32-free twin: same shadow, placeholder (zero-strided) f32 tier
    s3, q3 = make_dataset_streamed(spec, num_queries=6, seed=3,
                                   block_rows=512, f32=False)
    assert np.array_equal(np.asarray(s3.q_vectors), q)
    assert np.array_equal(np.asarray(q3), np.asarray(q1))
    assert np.asarray(s3.vectors).shape == (spec.n, spec.dim)
    assert not np.asarray(s3.vectors).any()
    # per-block streams: block contents don't depend on which other
    # blocks were generated
    centers = _stream_centers(spec, 3)
    blk = _stream_block(spec, centers, 3, 2, 1024, 1536, None)
    assert np.array_equal(blk, np.asarray(s1.vectors)[1024:1536])


def test_blocked_graph_builder_routed_path():
    """Force the routed/blocked code path (exact_threshold below n) and
    check the graph still navigates to high recall."""
    from repro.core.hnsw import build_graph_blocked
    spec = DatasetSpec("t-blocked", 2_500, 24, "l2", clusters=12)
    store, queries = make_dataset(spec, num_queries=6, seed=1)
    queries = jnp.asarray(queries)
    g = build_graph_blocked(store, m=12, ef_construction=32, seed=0,
                            exact_threshold=500)
    nb = np.asarray(g.neighbors)
    assert nb.max() < store.n and nb.min() >= -1
    words = (store.n + 31) // 32
    bm = jnp.ones((queries.shape[0], words), jnp.uint32) * jnp.uint32(
        0xFFFFFFFF)
    _, tid = filtered_knn(store, queries, bm, 10)
    res = make_executor("sweeping", store, graph=g).search(
        queries, bm, _params("sweeping"))
    rec = float(np.mean(np.asarray(jax.vmap(
        lambda f, t: recall_at_k(f, t, 10))(res.ids, tid))))
    assert rec >= 0.8, rec


def test_cost_model_sharded_terms():
    counters = {"distance_comps": 2_000.0, "hops": 400.0}
    p = _params("sweeping")
    assert costmodel.beam_exchange_bytes(counters, p, 1) == 0.0
    lock = costmodel.beam_exchange_bytes(counters, p, 8)
    assert lock == 8.0 * 2_000.0 * 2.0 * 7 / 8
    drift = costmodel.beam_exchange_bytes(
        counters, dataclasses.replace(p, beam_exchange_interval=4), 8)
    assert drift == 8.0 * p.ef_search * 100 * 7
    z = jnp.full((4,), 2_000, jnp.int32)
    stats = SearchStats(z, z, jnp.full((4,), 400, jnp.int32),
                        z // 10, z // 10, z * 0, z * 0)
    s1 = costmodel.sharded_cycle_summary(stats, p, 768, 1)
    s8 = costmodel.sharded_cycle_summary(stats, p, 768, 8)
    assert s8["collective_bytes"] > 0 and s1["collective_bytes"] == 0
    assert s8["modeled_qps"] / s1["modeled_qps"] >= 2.5
    # predict_cycles carries the same sharding terms
    shape = costmodel.IndexShape(n=1_000_000, dim=768, graph_m=16)
    c1 = costmodel.predict_cycles("sweeping", shape, p, 0.2)
    c8 = costmodel.predict_cycles("sweeping", shape, p, 0.2, num_shards=8)
    assert c8 < c1 and c8 > c1 / 8


_SUBPROCESS_SRC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import json
    import numpy as np, jax, jax.numpy as jnp
    from repro.core import (SearchParams, WorkloadSpec, generate_bitmaps,
                            quantize_store)
    from repro.core.distributed import (ShardedGraphExecutor,
                                        sharded_graph_search_fn)
    from repro.core import build_graph
    from repro.data import DatasetSpec, make_dataset

    spec = DatasetSpec("t-shmap", 3000, 32, "l2", clusters=12)
    store, queries = make_dataset(spec, num_queries=6, seed=0)
    store = quantize_store(store)
    queries = jnp.asarray(queries)
    graph = build_graph(store, m=12, ef_construction=32, seed=0)
    bm = generate_bitmaps(store, queries, WorkloadSpec(0.3, "none"), seed=5)
    p = SearchParams(k=10, ef_search=32, beam_width=128,
                     strategy="sweeping", max_hops=150)
    out = {"devices": jax.device_count()}
    for S in (2, 8):
        fn = sharded_graph_search_fn(graph, store, S, p)
        d, ids, stats = fn(queries, bm)
        ref = ShardedGraphExecutor(graph, store, S,
                                   strategy="sweeping").search(queries,
                                                               bm, p)
        out[f"ids_eq_{S}"] = bool(np.array_equal(np.asarray(ids),
                                                 np.asarray(ref.ids)))
        out[f"d_eq_{S}"] = bool(np.array_equal(np.asarray(d),
                                               np.asarray(ref.dists)))
    print(json.dumps(out))
""")


@pytest.mark.slow
def test_shard_map_matches_vmap_8dev():
    """The same shard body under real shard_map devices reproduces the
    single-process vmap executor bit-exactly."""
    proc = subprocess.run([sys.executable, "-c", _SUBPROCESS_SRC],
                          capture_output=True, text=True, cwd="/root/repo",
                          timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    rec = json.loads(proc.stdout.splitlines()[-1])
    assert rec["devices"] == 8
    assert rec["ids_eq_2"] and rec["d_eq_2"]
    assert rec["ids_eq_8"] and rec["d_eq_8"]
