"""WAL durability + deterministic crash recovery (DESIGN.md §12).

The crash-consistency contract, tested mechanically: kill the ingestion
pipeline at EVERY WAL record boundary (and mid-record, the torn-tail
case) and assert the recovered index's search results are bit-identical
— ids, dists — to a reference that executed the same durable prefix
uncrashed, across bruteforce, graph, and ScaNN executors.  Plus the WAL
unit layer (CRC32C, torn-tail truncation vs true corruption, reopen,
rollback-to-durable) and write-path fault injection survival
(torn appends + failed fsyncs leave deterministic never-happened state).
"""
import os
import shutil

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import SearchParams
from repro.core.mutable import MutableIndex
from repro.storage import wal as W
from repro.storage.faults import FaultPlan

DIM = 12
METHODS = ("bruteforce", "sweeping", "scann")


def _params(method):
    return SearchParams(k=5, strategy=method, ef_search=32, beam_width=32,
                        max_hops=150, num_leaves_to_search=4)


def _snap(idx, queries, bitmaps):
    """Search results for every executor — the per-LSN reference the
    crash matrix compares recovered indexes against."""
    out = {}
    for m in METHODS:
        res = idx.search(jnp.asarray(queries), jnp.asarray(bitmaps),
                         _params(m), method=m)
        out[m] = (np.asarray(res.ids).copy(),
                  np.asarray(res.dists).copy())
    return out


def _assert_snap_equal(ref, got, ctx):
    for m in METHODS:
        np.testing.assert_array_equal(
            ref[m][0], got[m][0], err_msg=f"{m} ids diverged: {ctx}")
        assert np.array_equal(ref[m][1], got[m][1], equal_nan=True), \
            f"{m} dists diverged: {ctx}"


def _index_kwargs():
    return dict(delta_capacity=32, with_graph=True, with_scann=True,
                num_leaves=4, graph_m=8, ef_construction=32, seed=0)


# ---------------------------------------------------------------------------
# WAL unit layer
# ---------------------------------------------------------------------------

def test_crc32c_known_vector():
    # RFC 3720 / iSCSI check value for "123456789"
    assert W.crc32c(b"123456789") == 0xE3069283
    assert W.crc32c(b"") == 0


def test_record_roundtrip(tmp_path):
    path = str(tmp_path / "wal")
    w = W.WriteAheadLog(path)
    rng = np.random.RandomState(0)
    vecs = rng.randn(3, DIM).astype(np.float32)
    ids = np.array([4, 9], np.int64)
    w.append(W.REC_INSERT, W.encode_insert(100, vecs))
    w.append(W.REC_DELETE, W.encode_delete(ids))
    w.append(W.REC_CHECKPOINT, W.encode_meta({"step": 1}))
    w.sync()
    recs = w.replay()
    assert [r.lsn for r in recs] == [1, 2, 3]
    start, got = W.decode_insert(recs[0].payload)
    assert start == 100
    np.testing.assert_array_equal(got, vecs)
    np.testing.assert_array_equal(W.decode_delete(recs[1].payload), ids)
    assert W.decode_meta(recs[2].payload) == {"step": 1}
    w.close()


def test_torn_tail_truncates_at_every_cut(tmp_path):
    """For every byte cut inside the last record, iteration yields
    exactly the intact prefix and never raises — a crash can only lose
    the tail, not poison the log."""
    path = str(tmp_path / "wal")
    w = W.WriteAheadLog(path)
    for i in range(3):
        w.append(W.REC_DELETE,
                 W.encode_delete(np.arange(i + 1, dtype=np.int64)))
    w.sync()
    recs = w.replay()
    w.close()
    full = open(path, "rb").read()
    bounds = [0] + [r.end for r in recs]
    for cut in range(len(full) + 1):
        t = str(tmp_path / "cut")
        with open(t, "wb") as f:
            f.write(full[:cut])
        got = list(W.iter_records(t))
        expect = sum(1 for b in bounds[1:] if b <= cut)
        assert len(got) == expect, f"cut at {cut}"
        assert [r.lsn for r in got] == list(range(1, expect + 1))


def test_mid_log_damage_raises_corruption(tmp_path):
    path = str(tmp_path / "wal")
    w = W.WriteAheadLog(path)
    for i in range(3):
        w.append(W.REC_DELETE,
                 W.encode_delete(np.array([i], np.int64)))
    w.sync()
    w.close()
    data = bytearray(open(path, "rb").read())
    data[W.HEADER_BYTES + 2] ^= 0xFF          # payload bit-flip, record 1
    bad = str(tmp_path / "bad")
    open(bad, "wb").write(bytes(data))
    with pytest.raises(W.WalCorruption):
        list(W.iter_records(bad))
    # the SAME damage at the tail (later records cut away) is torn, not
    # corrupt: silently truncated
    first_end = next(iter(W.iter_records(path))).end
    open(bad, "wb").write(bytes(data[:first_end]))
    assert list(W.iter_records(bad)) == []


def test_reopen_truncates_torn_tail_and_continues(tmp_path):
    path = str(tmp_path / "wal")
    w = W.WriteAheadLog(path)
    w.append(W.REC_DELETE, W.encode_delete(np.array([1], np.int64)))
    rec2 = w.append(W.REC_DELETE,
                    W.encode_delete(np.array([2], np.int64)))
    w.sync()
    w.close()
    # tear the second record's tail off on disk
    with open(path, "r+b") as f:
        f.truncate(rec2.end - 3)
    w2 = W.WriteAheadLog(path)
    assert w2.next_lsn == 2                   # lsn 2 was torn away
    assert w2.offset == rec2.offset
    w2.append(W.REC_DELETE, W.encode_delete(np.array([3], np.int64)))
    w2.sync()
    recs = w2.replay()
    assert [r.lsn for r in recs] == [1, 2]
    np.testing.assert_array_equal(W.decode_delete(recs[1].payload), [3])
    w2.close()


def test_rollback_to_durable(tmp_path):
    path = str(tmp_path / "wal")
    w = W.WriteAheadLog(path)
    w.append(W.REC_DELETE, W.encode_delete(np.array([1], np.int64)))
    w.sync()
    w.append(W.REC_DELETE, W.encode_delete(np.array([2], np.int64)))
    # fsync "failed": the un-synced tail must be dropped wholesale
    w.rollback_to_durable()
    assert w.offset == w.durable_offset and w.next_lsn == 2
    w.append(W.REC_DELETE, W.encode_delete(np.array([3], np.int64)))
    w.sync()
    recs = w.replay()
    assert [r.lsn for r in recs] == [1, 2]
    np.testing.assert_array_equal(W.decode_delete(recs[1].payload), [3])
    w.close()


# ---------------------------------------------------------------------------
# the crash matrix
# ---------------------------------------------------------------------------

def _ops(rng):
    return [
        ("insert", rng.randn(4, DIM).astype(np.float32)),
        ("delete", np.array([3, 151], np.int64)),
        ("insert", rng.randn(2, DIM).astype(np.float32)),
        ("insert", rng.randn(5, DIM).astype(np.float32)),
        ("delete", np.array([155, 40], np.int64)),
        ("insert", rng.randn(1, DIM).astype(np.float32)),
    ]


def _apply(idx, op):
    if op[0] == "insert":
        idx.insert(op[1])
    else:
        idx.delete(op[1])


@pytest.mark.crash
def test_crash_matrix_every_record_boundary(tmp_path):
    """Kill ingestion at every WAL record boundary AND mid-record; the
    recovered index's searches must be bit-identical to the uncrashed
    reference at that durable prefix, for all three executor families."""
    rng = np.random.RandomState(2)
    base = rng.randn(150, DIM).astype(np.float32)
    queries = rng.randn(3, DIM).astype(np.float32)
    wal_path = str(tmp_path / "wal")
    ck = str(tmp_path / "ck")
    idx = MutableIndex(base, wal_path, ck, **_index_kwargs())
    bm = np.full((3, idx.words()), 0xFFFFFFFF, np.uint32)

    # reference run: snapshot every executor's results after each op,
    # keyed by the op's (durable) LSN
    snaps = {0: _snap(idx, queries, bm)}
    for op in _ops(rng):
        _apply(idx, op)
        snaps[idx.applied_lsn] = _snap(idx, queries, bm)
    recs = idx.wal.replay()

    # crash points: byte 0, every record end, and a cut inside every
    # record's payload (the torn tail)
    points = [(0, 0)]
    prev_lsn = 0
    for r in recs:
        points.append((r.offset + r.length // 2,
                       prev_lsn))                      # mid-record tear
        points.append((r.end, r.lsn))
        prev_lsn = r.lsn
    for i, (cut, durable_lsn) in enumerate(points):
        crashed = str(tmp_path / f"crash_{i}")
        idx.wal.crash_copy(crashed, at_bytes=cut)
        rec_ck = str(tmp_path / f"ck_{i}")             # no checkpoints yet
        r_idx = MutableIndex.recover(base, crashed, rec_ck,
                                     **_index_kwargs())
        assert r_idx.applied_lsn == durable_lsn, f"point {i} (cut {cut})"
        _assert_snap_equal(snaps[durable_lsn], _snap(r_idx, queries, bm),
                           f"crash point {i} (cut {cut}, "
                           f"lsn {durable_lsn})")
        r_idx.close()
    idx.close()


@pytest.mark.crash
def test_checkpoint_bounds_replay(tmp_path):
    """Recovery restores the latest checkpoint and replays ONLY records
    past its applied_lsn — and the result is still bit-identical."""
    rng = np.random.RandomState(4)
    base = rng.randn(150, DIM).astype(np.float32)
    queries = rng.randn(3, DIM).astype(np.float32)
    wal_path = str(tmp_path / "wal")
    ck = str(tmp_path / "ck")
    idx = MutableIndex(base, wal_path, ck, **_index_kwargs())
    bm = np.full((3, idx.words()), 0xFFFFFFFF, np.uint32)
    ops = _ops(rng)
    for op in ops[:3]:
        _apply(idx, op)
    step = idx.checkpoint()
    ckpt_lsn = idx.applied_lsn
    for op in ops[3:]:
        _apply(idx, op)
    ref = _snap(idx, queries, bm)
    r_idx = MutableIndex.recover(base, wal_path, ck, **_index_kwargs())
    assert r_idx._ckpt_step == step
    assert r_idx.applied_lsn == idx.applied_lsn > ckpt_lsn
    _assert_snap_equal(ref, _snap(r_idx, queries, bm), "post-checkpoint")
    idx.close(); r_idx.close()


@pytest.mark.crash
def test_compaction_crash_recovery(tmp_path):
    """The compaction ordering invariant: the FULL checkpoint is durable
    BEFORE the COMPACT marker.  Crashing (a) before compaction started,
    (b) after the checkpoint but before the marker, and (c) after the
    marker all recover to bit-identical states."""
    rng = np.random.RandomState(6)
    base = rng.randn(120, DIM).astype(np.float32)
    queries = rng.randn(3, DIM).astype(np.float32)
    wal_path = str(tmp_path / "wal")
    ck = str(tmp_path / "ck")
    idx = MutableIndex(base, wal_path, ck, **_index_kwargs())
    bm = np.full((3, idx.words()), 0xFFFFFFFF, np.uint32)
    idx.insert(rng.randn(10, DIM).astype(np.float32))
    idx.delete(np.array([5, 125], np.int64))
    pre_snap = _snap(idx, queries, bm)
    pre_lsn = idx.applied_lsn
    pre_offset = idx.wal.offset
    # (a)'s disk state must predate the compaction checkpoint too
    ck_pre = str(tmp_path / "ck_pre")
    shutil.copytree(ck, ck_pre) if os.path.isdir(ck) \
        else os.makedirs(ck_pre)
    idx.compact()
    post_snap = _snap(idx, queries, bm)
    marker = idx.wal.replay()[-1]
    assert marker.kind == W.REC_COMPACT

    # (a) crash before compaction began: empty ckpt dir + old WAL prefix
    wal_a = idx.wal.crash_copy(str(tmp_path / "wal_a"),
                               at_bytes=pre_offset)
    r_a = MutableIndex.recover(base, wal_a, ck_pre, **_index_kwargs())
    assert r_a.applied_lsn == pre_lsn and r_a.compactions == 0
    _assert_snap_equal(pre_snap, _snap(r_a, queries, bm), "pre-compaction")

    # (b) checkpoint durable, marker torn away with the crash
    wal_b = idx.wal.crash_copy(str(tmp_path / "wal_b"),
                               at_bytes=marker.offset)
    r_b = MutableIndex.recover(base, wal_b, ck, **_index_kwargs())
    assert r_b.compactions == 1 and r_b.base_n == idx.base_n
    _assert_snap_equal(post_snap, _snap(r_b, queries, bm),
                       "checkpoint-before-marker")

    # (c) clean: marker present, replay past the checkpoint is a no-op
    wal_c = idx.wal.crash_copy(str(tmp_path / "wal_c"))
    r_c = MutableIndex.recover(base, wal_c, ck, **_index_kwargs())
    _assert_snap_equal(post_snap, _snap(r_c, queries, bm), "post-marker")
    for ix in (idx, r_a, r_b, r_c):
        ix.close()


@pytest.mark.crash
def test_write_fault_survival(tmp_path):
    """Injected torn appends + failed fsyncs: every faulted mutation is
    deterministically 'never happened'; the index matches a clean shadow
    that executed only the successful ops, before AND after recovery."""
    plan = FaultPlan(seed=3, wal_torn_prob=0.35, fsync_fail_prob=0.25)
    rng = np.random.RandomState(8)
    base = rng.randn(100, DIM).astype(np.float32)
    queries = rng.randn(3, DIM).astype(np.float32)
    kwargs = dict(delta_capacity=64, with_graph=False, with_scann=False)
    idx = MutableIndex(base, str(tmp_path / "wal"), str(tmp_path / "ck"),
                       faults=plan, **kwargs)
    shadow = MutableIndex(base, str(tmp_path / "wal_s"),
                          str(tmp_path / "ck_s"), **kwargs)
    faulted = 0
    for i in range(20):
        if rng.rand() < 0.7:
            op = ("insert", rng.randn(rng.randint(1, 4),
                                      DIM).astype(np.float32))
        else:
            hi = 100 + idx.delta.count
            op = ("delete", rng.randint(0, hi, size=2).astype(np.int64))
        try:
            _apply(idx, op)
        except (W.WalTornWrite, W.WalSyncError):
            faulted += 1
            continue                       # op never happened
        _apply(shadow, op)
    assert 0 < faulted < 20                # the plan actually fired
    bm = np.full((3, idx.words()), 0xFFFFFFFF, np.uint32)
    p = SearchParams(k=5, strategy="bruteforce")
    live = idx.search(jnp.asarray(queries), jnp.asarray(bm), p)
    want = shadow.search(jnp.asarray(queries), jnp.asarray(bm), p)
    np.testing.assert_array_equal(np.asarray(want.ids),
                                  np.asarray(live.ids))
    idx.close()
    # recovery from the faulted files reproduces the same state exactly
    r_idx = MutableIndex.recover(base, str(tmp_path / "wal"),
                                 str(tmp_path / "ck"), **kwargs)
    rec = r_idx.search(jnp.asarray(queries), jnp.asarray(bm), p)
    np.testing.assert_array_equal(np.asarray(want.ids),
                                  np.asarray(rec.ids))
    assert np.array_equal(np.asarray(want.dists), np.asarray(rec.dists),
                          equal_nan=True)
    shadow.close(); r_idx.close()
