"""Fault tolerance: checkpoint/restart replay, stragglers, compression."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import (latest_step, restore_checkpoint,
                              save_checkpoint)
from repro.configs import smoke_config
from repro.launch.specs import make_smoke_batch
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.train.loop import TrainConfig, Trainer


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_config("llama3.2-3b")
    bundle = build_model(cfg)
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=100)

    def batch_fn(step):
        return make_smoke_batch(cfg, batch=2, seq=32, kind="train",
                                seed=step)

    return bundle, opt_cfg, batch_fn


def _leaves_equal(a, b):
    fa, fb = jax.tree.leaves(a), jax.tree.leaves(b)
    return all(np.allclose(np.asarray(x), np.asarray(y), atol=1e-6)
               for x, y in zip(fa, fb))


def test_checkpoint_roundtrip(tmp_path, setup):
    bundle, opt_cfg, batch_fn = setup
    params = bundle.init(jax.random.PRNGKey(0))
    path = save_checkpoint(str(tmp_path), 7, {"params": params},
                           extra={"note": "x"})
    assert os.path.isdir(path)
    assert latest_step(str(tmp_path)) == 7
    like = jax.tree.map(lambda p: jnp.zeros_like(p), {"params": params})
    restored, extra = restore_checkpoint(str(tmp_path), 7, like)
    assert extra == {"note": "x"}
    assert _leaves_equal(restored, {"params": params})


def test_crash_restart_replays_exactly(tmp_path, setup):
    """Train 10 steps straight vs crash-at-6 + restore + resume: identical
    final params (deterministic data pipeline + atomic checkpoints)."""
    bundle, opt_cfg, batch_fn = setup

    tc = TrainConfig(steps=10, checkpoint_every=3,
                     checkpoint_dir=str(tmp_path / "a"), log_every=100)
    tr = Trainer(bundle, opt_cfg, tc, batch_fn)
    p0, o0, _ = tr.init_or_restore(jax.random.PRNGKey(1))
    p_straight, _ = tr.run(p0, o0, 0)

    tc2 = TrainConfig(steps=10, checkpoint_every=3,
                      checkpoint_dir=str(tmp_path / "b"), log_every=100)
    tr2 = Trainer(bundle, opt_cfg, tc2, batch_fn)
    p0, o0, _ = tr2.init_or_restore(jax.random.PRNGKey(1))
    with pytest.raises(RuntimeError, match="injected failure"):
        tr2.run(p0, o0, 0, fail_at=7)
    tr2.ckpt.wait()
    # "restart": a fresh trainer restores from the last checkpoint (step 6)
    tr3 = Trainer(bundle, opt_cfg, tc2, batch_fn)
    p1, o1, start = tr3.init_or_restore(jax.random.PRNGKey(1))
    assert start == 6
    p_resumed, _ = tr3.run(p1, o1, start)
    assert _leaves_equal(p_straight, p_resumed)


def test_straggler_deadline_hook(setup):
    bundle, opt_cfg, batch_fn = setup
    times = iter([0.0, 10.0, 10.0, 10.1, 10.1, 10.2] + [10.2 + i * 0.01
                                                        for i in range(50)])
    tc = TrainConfig(steps=3, step_deadline_s=1.0, log_every=100)
    tr = Trainer(bundle, opt_cfg, tc, batch_fn,
                 clock=lambda: next(times))
    p0, o0, _ = tr.init_or_restore(jax.random.PRNGKey(0))
    tr.run(p0, o0, 0)
    assert 0 in tr.stragglers                 # first step exceeded deadline


def test_grad_compression_converges(setup):
    """int8 EF compression still trains (loss decreases)."""
    bundle, opt_cfg, batch_fn = setup
    tc = TrainConfig(steps=6, grad_compression=True, log_every=1)
    tr = Trainer(bundle, opt_cfg, tc, lambda s: batch_fn(0))
    p0, o0, _ = tr.init_or_restore(jax.random.PRNGKey(0))
    tr.run(p0, o0, 0)
    losses = [h["loss"] for h in tr.history]
    assert losses[-1] < losses[0]


def test_microbatch_accumulation_matches_big_batch(setup):
    """2 microbatches of 2 == 1 batch of 4 (up to fp error)."""
    bundle, opt_cfg, _ = setup
    cfg = bundle.cfg
    big = make_smoke_batch(cfg, batch=4, seq=32, kind="train", seed=0)
    micro = jax.tree.map(lambda a: a.reshape((2, 2) + a.shape[1:]), big)
    from repro.train.loop import make_train_step, init_opt_state
    params = bundle.init(jax.random.PRNGKey(2))

    tc1 = TrainConfig(microbatches=1)
    s1 = make_train_step(bundle, opt_cfg, tc1, donate=False)
    o1 = init_opt_state(bundle, params, opt_cfg, tc1)
    p1, _, m1 = s1(params, o1, big)

    tc2 = TrainConfig(microbatches=2)
    s2 = make_train_step(bundle, opt_cfg, tc2, donate=False)
    o2 = init_opt_state(bundle, params, opt_cfg, tc2)
    p2, _, m2 = s2(params, o2, micro)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    assert _leaves_equal(p1, p2)


def test_elastic_restore_resharding(tmp_path, setup):
    """Restore with explicit shardings (single-device here) exercises the
    device_put path used for elastic re-mesh."""
    bundle, opt_cfg, _ = setup
    params = bundle.init(jax.random.PRNGKey(0))
    save_checkpoint(str(tmp_path), 1, {"params": params})
    sh = jax.tree.map(
        lambda p: jax.sharding.SingleDeviceSharding(jax.devices()[0]),
        {"params": params})
    restored, _ = restore_checkpoint(str(tmp_path), 1, {"params": params},
                                     shardings=sh)
    assert _leaves_equal(restored, {"params": params})
