"""Selectivity-aware pruned traversal (DESIGN.md §14).

Four layers of guarantees:

  * the exclusion-radius build pass is correct (ladder rungs are K-th-NN
    radii, family radii are exact nearest-passing-row distances, zero on
    passing rows) and the fused keep mask is bit-identical Pallas
    (interpret) vs jnp oracle, f32 and sq8;
  * safety: `exclusion="none"` is bit-identical to the pre-exclusion
    engine on both drivers × both quant tiers; family-exact radii with
    margin >= 1 are provably inert; "prune_exact" only re-prices fc
    (identical ids/dists, never more fc than "prune"); pruned recall
    stays within slack of unpruned across the grid;
  * the partitioned (JAG) tier answers family batches exactly, falls
    back per-query for unmatched bitmaps, refuses stale partitions, and
    charges only the deduped plan-time match as filter work;
  * the planner prices both new tiers, keeps batch-infeasible
    partitioned executors off the dispatch path, and its CHARGED
    planning overhead is identical from the old 6-candidate menu to the
    new one (the memoized proxy satellite).
"""
import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (GraphExecutor, PartitionedGraphExecutor,
                        SearchParams, WorkloadSpec, assign_family_bitmaps,
                        build_exclusion, build_graph_partitioned,
                        filtered_knn, generate_bitmaps, generate_families,
                        ladder_rung, make_executor, match_families,
                        quantize_store, recall_at_k, search_batch,
                        select_radii, unpack_bitmap)
from repro.kernels import ops as kops

PARAMS = SearchParams(k=10, ef_search=96, beam_width=512, max_hops=2048,
                      strategy="sweeping")
SEL = 0.05


@pytest.fixture(scope="module")
def families(small_dataset):
    store, _ = small_dataset
    return generate_families(store, SEL, num_families=3, seed=3)


@pytest.fixture(scope="module")
def family_batch(small_dataset, families):
    _, queries = small_dataset
    bm, assign = assign_family_bitmaps(families, int(queries.shape[0]),
                                       seed=4)
    return jnp.asarray(bm), assign


@pytest.fixture(scope="module")
def exclusion(small_dataset, families):
    store, _ = small_dataset
    return build_exclusion(store, families=families)


@pytest.fixture(scope="module")
def partitions(small_dataset, families):
    store, _ = small_dataset
    return build_graph_partitioned(store, families, m=8,
                                   ef_construction=32, seed=5)


def _recall(ids, tid, k=10):
    return float(np.mean(np.asarray(recall_at_k(ids, tid, k))))


# ---------------- build pass correctness ----------------

def test_ladder_radii_are_kth_nn(small_dataset, exclusion):
    store, _ = small_dataset
    v = np.asarray(store.vectors)
    d = ((v[:3, None, :] - v[None, :, :]) ** 2).sum(-1)
    d[np.arange(3), np.arange(3)] = np.inf
    srt = np.sort(d, axis=1)
    ladder = np.asarray(exclusion.ladder)
    for r, k in enumerate(exclusion.ladder_ks):
        np.testing.assert_allclose(ladder[r, :3], srt[:, k - 1],
                                   rtol=2e-4, atol=1e-3)
    # nondecreasing in K at every node
    assert (np.diff(ladder, axis=0) >= -1e-4).all()


def test_family_radii_exact(small_dataset, families, exclusion):
    store, _ = small_dataset
    v = np.asarray(store.vectors)
    fam = np.asarray(exclusion.family_radii)
    for f, tag in enumerate(exclusion.family_tags):
        passing = unpack_bitmap(np.asarray(families[tag]), store.n)
        rows = np.flatnonzero(passing)
        # zero exactly on passing rows
        assert (fam[f, rows] == 0.0).all()
        probe = np.flatnonzero(~passing)[:3]
        d = ((v[probe, None, :] - v[None, rows, :]) ** 2).sum(-1).min(1)
        np.testing.assert_allclose(fam[f, probe], d, rtol=2e-4, atol=1e-3)
        assert (fam[f, probe] > 0.0).all()


def test_ladder_rung_tracks_inverse_selectivity(exclusion):
    ks = exclusion.ladder_ks
    assert ks[ladder_rung(exclusion, 1.0)] == ks[0]
    assert ks[ladder_rung(exclusion, 1e-9)] == ks[-1]
    assert ks[ladder_rung(exclusion, 1 / 16)] == 16


def test_match_and_select_radii(small_dataset, families, exclusion,
                                family_batch):
    store, _ = small_dataset
    bm, assign = family_batch
    fam = match_families(exclusion, bm)
    assert (fam >= 0).all()
    # assign indexes generate_families' insertion order; match indexes the
    # sorted-tag order — compare through the tags
    tags = sorted(families)
    assert [exclusion.family_tags[f] for f in fam] == \
        [list(families)[a] for a in assign]
    radii = np.asarray(select_radii(exclusion, bm))
    np.testing.assert_array_equal(
        radii, np.asarray(exclusion.family_radii)[fam])
    # an unregistered bitmap falls back to the ladder rung
    other = jnp.zeros_like(bm[:1])
    assert match_families(exclusion, other)[0] == -1
    lr = np.asarray(select_radii(exclusion, other, selectivity=SEL))
    rung = ladder_rung(exclusion, SEL)
    np.testing.assert_array_equal(lr[0], np.asarray(exclusion.ladder)[rung])
    assert tags == list(exclusion.family_tags)


def test_build_exclusion_validation(small_dataset):
    store, _ = small_dataset
    from repro.core.types import VectorStore
    ip_store = VectorStore.build(np.asarray(store.vectors)[:64],
                                 metric="ip")
    with pytest.raises(ValueError, match="l2"):
        build_exclusion(ip_store)
    with pytest.raises(ValueError, match="ladder_ks"):
        build_exclusion(store, ladder_ks=())


# ---------------- fused keep mask: kernel vs oracle ----------------

@pytest.mark.parametrize("quant", ["none", "sq8"])
def test_keep_mask_kernel_oracle_identical(small_dataset, family_batch,
                                           exclusion, quant):
    store, queries = small_dataset
    if quant == "sq8":
        store = quantize_store(store)
    bm, _ = family_batch
    q = int(queries.shape[0])
    rng = np.random.default_rng(0)
    cids = jnp.asarray(rng.integers(0, store.n, (q, 64), np.int32))
    excl = jnp.take_along_axis(select_radii(exclusion, bm), cids, axis=1)
    tau = jnp.full((q, 1), 2.0, jnp.float32)
    if quant == "sq8":
        args = (queries, store.q_vectors[cids], store.q_scale,
                store.q_mean, store.q_norms_sq[cids], cids, bm, excl, tau)
        fn = kops.frontier_scan_excl_sq8
    else:
        args = (queries, store.vectors[cids], store.norms_sq[cids], cids,
                bm, excl, tau)
        fn = kops.frontier_scan_excl
    d_ref, p_ref, k_ref = fn(*args, margin=0.3, use_pallas=False)
    d_pl, p_pl, k_pl = fn(*args, margin=0.3, use_pallas=True)
    # the MASKS are bit-identical (shared excl_keep_mask rule on both
    # paths); distances carry the usual kernel-vs-oracle float wobble
    np.testing.assert_array_equal(np.asarray(k_ref), np.asarray(k_pl))
    np.testing.assert_array_equal(np.asarray(p_ref), np.asarray(p_pl))
    np.testing.assert_allclose(np.asarray(d_ref), np.asarray(d_pl),
                               atol=2e-4, rtol=2e-4)
    # the mask keeps every passing candidate regardless of margin
    assert np.asarray(k_ref)[np.asarray(p_ref)].all()


# ---------------- inertness guarantees ----------------

@pytest.mark.parametrize("use_pallas", [False, True])
@pytest.mark.parametrize("quant", ["none", "sq8"])
def test_exclusion_none_bit_identical(small_dataset, small_graph,
                                      family_batch, quant, use_pallas):
    """params.exclusion='none' (the default) must leave the engine's
    program untouched: same ids, dists, and all counters as a call that
    never heard of the exclusion tier."""
    store, queries = small_dataset
    if quant == "sq8":
        store = quantize_store(store)
    bm, _ = family_batch
    p = dataclasses.replace(PARAMS, graph_quant=quant)
    base = search_batch(small_graph, store, queries, bm, p,
                        use_pallas=use_pallas)
    again = search_batch(small_graph, store, queries, bm,
                         dataclasses.replace(p, exclusion="none"),
                         use_pallas=use_pallas)
    np.testing.assert_array_equal(np.asarray(base[1]), np.asarray(again[1]))
    np.testing.assert_array_equal(np.asarray(base[0]), np.asarray(again[0]))
    for f in dataclasses.fields(base[2]):
        np.testing.assert_array_equal(
            np.asarray(getattr(base[2], f.name)),
            np.asarray(getattr(again[2], f.name)), err_msg=f.name)


def test_family_exact_margin_ge1_inert(small_dataset, small_graph,
                                       family_batch, exclusion):
    """With exact family radii the nearest passing row itself witnesses
    sqrt(e) <= sqrt(d)+sqrt(tau), so margin >= 1 never prunes: identical
    ids/dists AND identical counters (prune_exact re-prices nothing when
    keep is all-true)."""
    store, queries = small_dataset
    bm, _ = family_batch
    base = search_batch(small_graph, store, queries, bm, PARAMS)
    excl = select_radii(exclusion, bm)
    for margin in (1.0, 1.5):
        p = dataclasses.replace(PARAMS, exclusion="prune_exact",
                                exclusion_margin=margin)
        out = search_batch(small_graph, store, queries, bm, p, excl=excl)
        np.testing.assert_array_equal(np.asarray(base[1]),
                                      np.asarray(out[1]))
        np.testing.assert_array_equal(np.asarray(base[0]),
                                      np.asarray(out[0]))
        for f in dataclasses.fields(base[2]):
            np.testing.assert_array_equal(
                np.asarray(getattr(base[2], f.name)),
                np.asarray(getattr(out[2], f.name)),
                err_msg=(margin, f.name))


def test_prune_exact_reprices_fc_only(small_dataset, small_graph,
                                      family_batch, exclusion):
    store, queries = small_dataset
    bm, _ = family_batch
    excl = select_radii(exclusion, bm)
    pr = search_batch(small_graph, store, queries, bm,
                      dataclasses.replace(PARAMS, exclusion="prune",
                                          exclusion_margin=0.3), excl=excl)
    px = search_batch(small_graph, store, queries, bm,
                      dataclasses.replace(PARAMS, exclusion="prune_exact",
                                          exclusion_margin=0.3), excl=excl)
    np.testing.assert_array_equal(np.asarray(pr[1]), np.asarray(px[1]))
    np.testing.assert_array_equal(np.asarray(pr[0]), np.asarray(px[0]))
    fc_pr = np.asarray(pr[2].filter_checks)
    fc_px = np.asarray(px[2].filter_checks)
    assert (fc_px <= fc_pr).all()
    assert fc_px.sum() < fc_pr.sum()     # exact radii: discount is real
    # traversal counters unchanged — only the fc pricing differs
    for name in ("distance_comps", "hops", "page_accesses_heap"):
        np.testing.assert_array_equal(np.asarray(getattr(pr[2], name)),
                                      np.asarray(getattr(px[2], name)))


def test_pruning_actually_prunes_and_stays_recall_safe(
        small_dataset, small_graph, family_batch, exclusion):
    store, queries = small_dataset
    bm, _ = family_batch
    _, tid = filtered_knn(store, queries, bm, PARAMS.k)
    base = search_batch(small_graph, store, queries, bm, PARAMS)
    excl = select_radii(exclusion, bm)
    p = dataclasses.replace(PARAMS, exclusion="prune_exact",
                            exclusion_margin=0.3)
    out = search_batch(small_graph, store, queries, bm, p, excl=excl)
    assert np.asarray(out[2].filter_checks).sum() < \
        np.asarray(base[2].filter_checks).sum()
    assert _recall(out[1], tid) >= _recall(base[1], tid) - 0.05


@pytest.mark.parametrize("corr", ["none", "high_pos"])
@pytest.mark.parametrize("sel", [0.05, 0.2])
def test_ladder_pruning_recall_grid(small_dataset, small_graph, exclusion,
                                    sel, corr):
    """Uncorrelated/correlated per-query bitmaps use the ladder rung —
    pruning must stay within slack of the unpruned engine everywhere."""
    store, queries = small_dataset
    bm = generate_bitmaps(store, queries, WorkloadSpec(sel, corr), seed=9)
    _, tid = filtered_knn(store, queries, bm, PARAMS.k)
    base = search_batch(small_graph, store, queries, bm, PARAMS)
    excl = select_radii(exclusion, bm, selectivity=sel)
    out = search_batch(small_graph, store, queries, bm,
                       dataclasses.replace(PARAMS, exclusion="prune",
                                           exclusion_margin=0.3),
                       excl=excl)
    assert _recall(out[1], tid) >= _recall(base[1], tid) - 0.05


# ---------------- executor integration ----------------

def test_graph_executor_exclusion_plan_and_search(small_dataset,
                                                  small_graph, family_batch,
                                                  exclusion):
    store, queries = small_dataset
    bm, _ = family_batch
    ex = GraphExecutor(small_graph, store, strategy="sweeping",
                       exclusion=exclusion)
    assert ex.name == "sweeping_excl"
    plan = ex.plan(queries, bm, PARAMS)
    assert plan.params.exclusion == "prune_exact"     # all queries match
    _, tid = filtered_knn(store, queries, bm, PARAMS.k)
    res = ex.search(queries, bm, dataclasses.replace(
        PARAMS, exclusion_margin=0.3))
    base = GraphExecutor(small_graph, store,
                         strategy="sweeping").search(queries, bm, PARAMS)
    assert np.asarray(res.stats.filter_checks).sum() < \
        np.asarray(base.stats.filter_checks).sum()
    assert _recall(res.ids, tid) >= _recall(base.ids, tid) - 0.05
    # mixed batch (one unregistered bitmap) downgrades to ladder "prune"
    mixed = jnp.concatenate([bm[:-1], jnp.zeros_like(bm[:1])])
    assert ex.plan(queries, mixed, PARAMS).params.exclusion == "prune"


def test_graph_executor_exclusion_validation(small_dataset, small_graph,
                                             exclusion):
    store, _ = small_dataset
    with pytest.raises(ValueError, match="sweeping"):
        GraphExecutor(small_graph, store, strategy="unfiltered",
                      exclusion=exclusion)
    short = dataclasses.replace(
        exclusion, ladder=exclusion.ladder[:, :100],
        family_radii=exclusion.family_radii[:, :100])
    with pytest.raises(ValueError, match="n"):
        GraphExecutor(small_graph, store, strategy="sweeping",
                      exclusion=short)
    ex = GraphExecutor(small_graph, store, strategy="sweeping",
                      exclusion=exclusion)
    with pytest.raises(ValueError, match="stepped"):
        ex.idle_frontier(PARAMS, 4)


def test_search_batch_exclusion_validation(small_dataset, small_graph,
                                           family_batch, exclusion):
    store, queries = small_dataset
    bm, _ = family_batch
    excl = select_radii(exclusion, bm)
    with pytest.raises(ValueError, match="margin"):
        search_batch(small_graph, store, queries, bm,
                     dataclasses.replace(PARAMS, exclusion="prune",
                                         exclusion_margin=0.0), excl=excl)
    with pytest.raises(ValueError, match="radii"):
        search_batch(small_graph, store, queries, bm,
                     dataclasses.replace(PARAMS, exclusion="prune"))
    with pytest.raises(ValueError, match="none"):
        search_batch(small_graph, store, queries, bm, PARAMS, excl=excl)
    with pytest.raises(ValueError, match="sweeping"):
        search_batch(small_graph, store, queries, bm,
                     dataclasses.replace(PARAMS, strategy="unfiltered",
                                         exclusion="prune"), excl=excl)


# ---------------- partitioned (JAG) tier ----------------

def test_partitioned_answers_family_batch_exactly(small_dataset,
                                                  family_batch, partitions,
                                                  families):
    store, queries = small_dataset
    bm, _ = family_batch
    ex = PartitionedGraphExecutor(partitions, store)
    _, tid = filtered_knn(store, queries, bm, PARAMS.k)
    res = ex.search(queries, bm, PARAMS)
    assert _recall(res.ids, tid) >= 0.97
    # every returned row actually passes its query's family predicate
    ids = np.asarray(res.ids)
    full = np.stack([unpack_bitmap(np.asarray(b), store.n)[None]
                     for b in np.asarray(bm)]).squeeze(1)
    for qi in range(ids.shape[0]):
        got = ids[qi][ids[qi] >= 0]
        assert full[qi][got].all()
    # the only filter work is the deduped plan-time catalog match
    uniq = np.unique(np.asarray(bm), axis=0).shape[0]
    expect = uniq * len(partitions.partitions) * bm.shape[1]
    assert int(np.asarray(res.stats.filter_checks).sum()) == expect


def test_partitioned_fallback_and_staleness(small_dataset, small_graph,
                                            family_batch, partitions):
    store, queries = small_dataset
    bm, _ = family_batch
    mixed = jnp.concatenate([bm[:-1], jnp.zeros_like(bm[:1])])
    with pytest.raises(ValueError, match="fallback"):
        PartitionedGraphExecutor(partitions, store).search(queries, mixed,
                                                           PARAMS)
    base = GraphExecutor(small_graph, store, strategy="sweeping")
    ex = PartitionedGraphExecutor(partitions, store, base=base)
    _, tid = filtered_knn(store, queries, mixed, PARAMS.k)
    res = ex.search(queries, mixed, PARAMS)
    assert _recall(res.ids[:-1], tid[:-1]) >= 0.97
    # stale partitions (store grew since build) must never serve
    stale = dataclasses.replace(partitions, built_n=store.n + 1)
    sres = PartitionedGraphExecutor(stale, store, base=base).search(
        queries, bm, PARAMS)
    # everything fell back: counters match the base executor's run
    bres = base.search(queries, bm, PARAMS)
    np.testing.assert_array_equal(np.asarray(sres.ids),
                                  np.asarray(bres.ids))


def test_partitioned_validation(small_dataset, partitions):
    store, _ = small_dataset
    import repro.core.hnsw as hnsw
    with pytest.raises(ValueError, match="no partitions"):
        PartitionedGraphExecutor(
            hnsw.PartitionedGraph(partitions=(), built_n=store.n), store)
    with pytest.raises(ValueError, match="quantize_store"):
        PartitionedGraphExecutor(partitions, store, graph_quant="sq8")


# ---------------- planner integration ----------------

OLD_MENU = ("bruteforce", "sweeping", "navix", "iterative_scan")
NEW_MENU = OLD_MENU + ("sweeping_excl", "partitioned")


def _planner(small_dataset, small_graph, menu, exclusion=None,
             partitions=None):
    store, _ = small_dataset
    return make_executor("adaptive", store, graph=small_graph,
                         exclusion=exclusion, partitions=partitions,
                         planner_candidates=menu)


def test_planner_menu_has_new_tiers(small_dataset, small_graph, exclusion,
                                    partitions):
    pl = _planner(small_dataset, small_graph, NEW_MENU, exclusion,
                  partitions)
    assert set(NEW_MENU) <= set(pl.candidates)
    assert isinstance(pl.candidates["partitioned"],
                      PartitionedGraphExecutor)
    assert pl.candidates["sweeping_excl"].exclusion is not None


def test_planner_dispatches_partitioned_on_family_batch(
        small_dataset, small_graph, family_batch, exclusion, partitions):
    _, queries = small_dataset
    bm, _ = family_batch
    pl = _planner(small_dataset, small_graph, NEW_MENU, exclusion,
                  partitions)
    assert pl.plan(queries, bm, PARAMS).strategy == "partitioned"
    # one unmatched bitmap makes partitioned batch-infeasible
    mixed = jnp.concatenate([bm[:-1], jnp.zeros_like(bm[:1])])
    assert pl.plan(queries, mixed, PARAMS).strategy != "partitioned"


def test_planner_charged_overhead_flat_across_menus(
        small_dataset, small_graph, family_batch, exclusion, partitions):
    """The satellite claim: growing the menu 4 -> 6 candidates must not
    change the planner's CHARGED overhead (the proxy computation is
    menu-independent and memoized) — same chosen strategy in, same
    counters out."""
    store, queries = small_dataset
    bm = generate_bitmaps(store, queries, WorkloadSpec(0.2, "none"),
                          seed=11)
    old = _planner(small_dataset, small_graph, OLD_MENU)
    new = _planner(small_dataset, small_graph, NEW_MENU, exclusion,
                   partitions)
    r_old = old.search(queries, bm, PARAMS)
    r_new = new.search(queries, bm, PARAMS)
    # uncorrelated per-query bitmaps: neither new tier wins, same pick
    assert r_old.strategy == r_new.strategy
    for f in dataclasses.fields(r_old.stats):
        np.testing.assert_array_equal(
            np.asarray(getattr(r_old.stats, f.name)),
            np.asarray(getattr(r_new.stats, f.name)), err_msg=f.name)
    # the proxy memoizes per batch: a replan of the same arrays hits
    key = new._proxy_key
    val = new._selectivity_proxy(queries, bm)
    assert new._proxy_key == key and val is new._proxy_val
