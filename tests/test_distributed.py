"""Distributed FVS + sharding rules. Multi-device cases run in a
subprocess with 8 forced host devices (XLA locks the device count at
first init, so the main test process stays single-device)."""
import json
import subprocess
import sys
import textwrap

import numpy as np
import jax
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.launch.sharding import fit_spec, param_specs
from jax.sharding import PartitionSpec as P


class FakeMesh:
    axis_names = ("data", "model")

    class devices:
        shape = (4, 8)


def test_fit_spec_divisibility():
    m = FakeMesh()
    assert fit_spec(P("model", None), (16, 4), m) == P("model", None)
    assert fit_spec(P("model", None), (17, 4), m) == P(None, None)
    assert fit_spec(P(("data", "model")), (32,), m) == P(("data", "model"))
    assert fit_spec(P(("data", "model")), (4,), m) == P("data")
    assert fit_spec(P("bogus"), (8,), m) == P(None)


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_param_specs_cover_all_leaves(arch_id):
    """Every param leaf gets a spec, and every named axis divides its dim."""
    import jax.numpy as jnp
    from repro.models import build_model
    cfg = get_config(arch_id)
    bundle = build_model(cfg)
    pshape = jax.eval_shape(bundle.init, jax.ShapeDtypeStruct((2,),
                                                              jnp.uint32))
    m = FakeMesh()
    m.devices.shape = (16, 16)
    specs = param_specs(cfg, pshape, m)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P))
    flat_p = jax.tree.leaves(pshape)
    assert len(flat_s) == len(flat_p)
    sizes = {"data": 16, "model": 16}
    for spec, leaf in zip(flat_s, flat_p):
        for dim, ax in zip(leaf.shape, tuple(spec)):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            prod = int(np.prod([sizes[a] for a in axes]))
            assert dim % prod == 0, (arch_id, spec, leaf.shape)


_SUBPROCESS_SRC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import json
    import numpy as np, jax, jax.numpy as jnp
    from repro.core import (SearchParams, WorkloadSpec, filtered_knn,
                            generate_bitmaps, recall_at_k)
    from repro.core.distributed import (build_sharded_scann,
                                        distributed_search_fn,
                                        distributed_kmeans_fn)
    from repro.data import DatasetSpec, make_dataset

    spec = DatasetSpec("t-dist", 4000, 32, "l2", clusters=16)
    store, queries = make_dataset(spec, num_queries=6, seed=0)
    queries = jnp.asarray(queries)
    mesh = jax.make_mesh((8,), ("data",))
    sh = build_sharded_scann(store, mesh, "data", num_leaves=64, levels=1,
                             seed=0)
    params = SearchParams(k=10, num_leaves_to_search=48, reorder_factor=6)
    fn = distributed_search_fn(sh, params)
    bm = generate_bitmaps(store, queries, WorkloadSpec(0.3, "none"), seed=1)
    d, ids = fn(queries, bm)
    td, tid = filtered_knn(store, queries, bm, 10)
    rec = float(np.mean(np.asarray(jax.vmap(
        lambda f, t: recall_at_k(f, t, 10))(ids, tid))))

    # executor path WITH per-query stats riding the all-gather: identical
    # ids/dists to the stats-free fn, counters per-query and sane
    from repro.core.distributed import DistributedScannExecutor
    from repro.core.scann import _quant_pages_per_leaf
    ex = DistributedScannExecutor(sh)
    res = ex.search(queries, bm, params)
    ids_eq = bool(np.array_equal(np.asarray(res.ids), np.asarray(ids)))
    st = res.stats
    nd = 8
    nsel = min(max(1, -(-params.num_leaves_to_search // nd)),
               sh.index.num_leaves // nd)
    hops_ok = bool((np.asarray(st.hops) == nd * nsel).all())
    qppl = _quant_pages_per_leaf(sh.index)
    pages_ok = bool((np.asarray(st.page_accesses_index)
                     == nd * nsel * qppl).all())
    stats_pos = bool((np.asarray(st.filter_checks) > 0).all()
                     and (np.asarray(st.distance_comps) > 0).all()
                     and (np.asarray(st.reorder_rows)
                          == np.asarray(st.page_accesses_heap)).all())
    # (ppv == 1 at dim=32, so heap pages == reorder rows)

    # distributed kmeans == single-device kmeans (same init, fori semantics)
    km = distributed_kmeans_fn(mesh, "data", k=8, iters=5)
    x = np.asarray(store.vectors)
    init = x[np.random.RandomState(0).choice(len(x), 8, False)]
    c_dist = np.asarray(km(jnp.asarray(x), jnp.asarray(init)))
    mesh1 = jax.make_mesh((1,), ("data",))
    km1 = distributed_kmeans_fn(mesh1, "data", k=8, iters=5)
    c_one = np.asarray(km1(jnp.asarray(x), jnp.asarray(init)))
    err = float(np.abs(c_dist - c_one).max())
    print(json.dumps({"recall": rec, "kmeans_err": err,
                      "devices": jax.device_count(), "ids_eq": ids_eq,
                      "hops_ok": hops_ok, "pages_ok": pages_ok,
                      "stats_pos": stats_pos}))
""")


@pytest.mark.slow
def test_distributed_search_8dev():
    proc = subprocess.run([sys.executable, "-c", _SUBPROCESS_SRC],
                          capture_output=True, text=True, cwd="/root/repo",
                          timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    rec = json.loads(proc.stdout.splitlines()[-1])
    assert rec["devices"] == 8
    assert rec["recall"] >= 0.9
    assert rec["kmeans_err"] < 1e-3
    # the executor's per-query SearchStats (satellite: stats across the
    # mesh) must not perturb results and must carry the mesh semantics
    assert rec["ids_eq"] and rec["hops_ok"] and rec["pages_ok"] \
        and rec["stats_pos"]
