"""Cost model (Fig. 1/10 semantics) + serving engine + RAG."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (LIBRARY, SYSTEM, SearchParams, SearchStats,
                        WorkloadSpec, cycle_breakdown, generate_bitmaps,
                        modeled_qps, search_batch)
from repro.configs import smoke_config
from repro.launch.specs import make_smoke_batch
from repro.models import build_model
from repro.serving import ServeEngine


def _stats(dc=100, fc=50, hops=10, pai=20, pah=120, tm=30, rr=0):
    z = lambda v: jnp.asarray(v, jnp.int32)
    return SearchStats(z(dc), z(fc), z(hops), z(pai), z(pah), z(tm), z(rr))


def test_system_tax_dominates():
    """Paper §6.2.2: page access costs dwarf distance computation in the
    SYSTEM regime but not in the LIBRARY regime."""
    s = _stats()
    sys_b = cycle_breakdown(s, dim=1536, constants=SYSTEM)
    lib_b = cycle_breakdown(s, dim=1536, constants=LIBRARY)
    sys_overhead = sys_b["index_page_access"] + sys_b["vector_retrieval"]
    assert sys_overhead > sys_b["distance_compute"]
    assert lib_b["total"] < sys_b["total"] / 5        # Fig. 1: up to 10x gap
    assert lib_b["translation_map"] == 0.0


def test_crossover_shift(small_dataset, small_graph):
    """Fig. 1's point: the acorn-vs-sweeping cost RATIO differs between the
    SYSTEM and LIBRARY regimes (so crossover points move)."""
    store, queries = small_dataset
    ratios = {}
    bm = generate_bitmaps(store, queries, WorkloadSpec(0.1, "none"), seed=0)
    rowss = {}
    for strat in ("acorn", "sweeping"):
        p = SearchParams(k=10, ef_search=96, beam_width=1024,
                         strategy=strat, max_hops=2048)
        _, _, stats = search_batch(small_graph, store, queries, bm, p)
        rowss[strat] = stats
    for regime, consts in (("system", SYSTEM), ("library", LIBRARY)):
        a = cycle_breakdown(rowss["acorn"], store.dim, consts)["total"]
        s = cycle_breakdown(rowss["sweeping"], store.dim, consts)["total"]
        ratios[regime] = a / s
    assert abs(ratios["system"] - ratios["library"]) > 0.1


def test_modeled_qps_monotonic():
    s = _stats()
    q1 = modeled_qps(s, 128, SYSTEM, threads=1, thread_overhead={1: 1.0})
    q16 = modeled_qps(s, 128, SYSTEM, threads=16)
    assert q16 > q1                       # throughput scales (sub-linearly)
    assert q16 < q1 * 16


def test_serve_engine_greedy_deterministic():
    cfg = smoke_config("llama3.2-3b")
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    prompts = rng.randint(0, cfg.vocab, (2, 8)).astype(np.int32)
    eng = ServeEngine(bundle, params, max_seq=32, batch_size=2)
    out1 = eng.generate(prompts, 6)
    out2 = ServeEngine(bundle, params, max_seq=32,
                       batch_size=2).generate(prompts, 6)
    assert out1.shape == (2, 6)
    assert (out1 == out2).all()
    assert (out1 >= 0).all() and (out1 < cfg.vocab).all()


def test_rag_retrieval_respects_filter():
    from repro.core.distributed import (DistributedScannExecutor,
                                        build_sharded_scann)
    from repro.core.types import probe_bitmap
    from repro.data import DatasetSpec, make_dataset
    from repro.serving import RetrievalAugmentedServer
    from repro.launch.mesh import make_mesh

    cfg = smoke_config("llama3.2-3b")
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    spec = DatasetSpec("t-rag", 2000, 32, "l2", clusters=8)
    store, _ = make_dataset(spec, num_queries=1, seed=0)
    mesh = make_mesh((1,), ("data",))
    sharded = build_sharded_scann(store, mesh, "data", num_leaves=32,
                                  levels=1)
    sp = SearchParams(k=4, num_leaves_to_search=16)
    rng = np.random.RandomState(1)
    docs = rng.randint(0, cfg.vocab, (2000, 8)).astype(np.int32)
    srv = RetrievalAugmentedServer(bundle, params,
                                   DistributedScannExecutor(sharded), sp,
                                   docs, chunk_len=8)
    prompts = rng.randint(0, cfg.vocab, (2, 16)).astype(np.int32)
    queries = jnp.asarray(rng.randn(2, 32).astype(np.float32))
    bm = generate_bitmaps(store, queries, WorkloadSpec(0.2, "none"), seed=2)
    res = srv.retrieve(prompts, bm)
    assert res.tokens.shape == (2, 16 + 4 * 8)
    for i in range(2):
        valid = res.ids[i][res.ids[i] >= 0]
        ok = probe_bitmap(bm[i], jnp.asarray(valid))
        assert np.asarray(ok).all()


def test_serve_queue_centroid_routing_order_invariant():
    """The centroid batch policy must reorder only the DISPATCH, not the
    results: serve_queue(centroid) == serve_queue(fifo) == retrieve, and
    the dispatch order must actually group by nearest centroid."""
    from repro.core import build_scann
    from repro.core.executor import ScannExecutor
    from repro.data import DatasetSpec, make_dataset
    from repro.serving import RetrievalAugmentedServer
    from repro.serving.rag import nearest_centroid
    from repro.storage import make_storage_engine

    cfg = smoke_config("llama3.2-3b")
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    spec = DatasetSpec("t-rag2", 2000, 32, "l2", clusters=8)
    store, _ = make_dataset(spec, num_queries=1, seed=0)
    idx = build_scann(store, num_leaves=32, levels=1, seed=0)
    eng = make_storage_engine(store, index=idx, capacity_frac=1.0)
    ex = ScannExecutor(idx, store, storage=eng)
    # per_query accounting: the pool's logical total is then dispatch-
    # grouping-invariant, so the FIFO == centroid telemetry equality
    # below is exact (under "batch" accounting the total depends on
    # within-batch leaf overlap — the very thing routing changes)
    sp = SearchParams(k=4, num_leaves_to_search=8,
                      scann_page_accounting="per_query")
    rng = np.random.RandomState(1)
    docs = rng.randint(0, cfg.vocab, (2000, 8)).astype(np.int32)
    srv = RetrievalAugmentedServer(bundle, params, ex, sp, docs,
                                   chunk_len=8)
    prompts = rng.randint(0, cfg.vocab, (12, 16)).astype(np.int32)
    queries = jnp.asarray(rng.randn(12, 32).astype(np.float32))
    bm = generate_bitmaps(store, queries, WorkloadSpec(0.5, "none"), seed=2)
    r_fifo, info_f = srv.serve_queue(prompts, bm, batch_size=4,
                                     policy="fifo")
    eng.reset_cold()
    r_cent, info_c = srv.serve_queue(prompts, bm, batch_size=4,
                                     policy="centroid")
    assert np.array_equal(r_fifo.ids, r_cent.ids)
    assert np.array_equal(r_fifo.tokens, r_cent.tokens)
    # dispatch order sorts by nearest-centroid key
    q = srv._embed(params, jnp.asarray(prompts))
    keys = np.asarray(nearest_centroid(idx, q))
    routed = keys[info_c["order"]]
    assert (np.diff(routed) >= 0).all()
    # telemetry rides along (pool attached): hits+misses accounted, and
    # the delta covers THIS call only — the same workload replayed after
    # reset_cold must report the same logical access count (regression:
    # an empty-but-present pool is falsy, `is not None` must gate the
    # baseline snapshot)
    assert info_c["pool_hits"] + info_c["pool_misses"] > 0
    assert info_c["pool_hits"] + info_c["pool_misses"] == \
        info_f["pool_hits"] + info_f["pool_misses"]
