"""Continuous-batching serving engine (DESIGN.md §11).

Covers the external-stepping contract and the scheduler built on it:

  - chunked `step_supersteps` is bit-identical to the one-shot
    `lax.while_loop` (ids, dists, all 7 SearchStats counters) across all
    five graph strategies x both graph_quant modes, chunk boundaries
    included, storage traces included
  - dynamic per-lane deadlines (data) match static `deadline_cycles`
    (compile-time) exactly — the compile-once-across-buckets win
  - slot retire/admit: per-request results and stats are
    arrival-order-invariant (hypothesis property + deterministic grid)
  - with fairness off and all arrivals at t=0, `ContinuousServer` is
    bit-identical to `serve_queue(policy="fifo")`
  - per-tenant DRR fairness: a flooding heavy tenant cannot starve a
    light tenant past what FIFO would do at sub-saturation load
  - compile-count telemetry stays bounded regardless of how many
    distinct deadline buckets a workload carries
  - `admission_floor` memoization and the costmodel queueing-delay term
"""
import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # optional dev dep (requirements-dev.txt):
    # property tests skip individually; plain tests in this module still run
    def given(*a, **k):
        return lambda f: pytest.mark.skip(
            reason="hypothesis not installed")(f)

    def settings(*a, **k):
        return lambda f: f

    class st:  # stub strategies so decorator arguments still evaluate
        integers = floats = sampled_from = staticmethod(
            lambda *a, **k: None)

from repro.core import (SearchParams, WorkloadSpec, generate_bitmaps,
                        quantize_store, search_batch)
from repro.core import costmodel
from repro.core.executor import GraphExecutor
from repro.core.graph_search import (frontier_finalize, frontier_init,
                                     step_supersteps)
from repro.serving.continuous import (ContinuousServer, FairQueue, Request,
                                      SlotPool, results_in_order)
from repro.serving.rag import (RetrievalAugmentedServer,
                               _admission_floor_cached, admission_floor)

STRATS = ("unfiltered", "sweeping", "acorn", "navix", "iterative_scan")
STAT_FIELDS = ("distance_comps", "filter_checks", "hops",
               "page_accesses_index", "page_accesses_heap", "tmap_lookups",
               "reorder_rows")


def _params(strategy, quant="none", **kw):
    base = dict(k=5, ef_search=32, beam_width=32, max_hops=150,
                strategy=strategy, graph_exec_mode="frontier",
                graph_quant=quant)
    base.update(kw)
    return SearchParams(**base)


def _stepped(graph, store, q, bm, p, chunks, collect_trace=False,
             deadlines=None, dynamic=False):
    state = frontier_init(graph, store, q, bm, p,
                          collect_trace=collect_trace, deadlines=deadlines)
    ci = 0
    while not bool(np.asarray(state.done).all()):
        state = step_supersteps(graph, store, state, p,
                                chunks[min(ci, len(chunks) - 1)],
                                dynamic_deadline=dynamic)
        ci += 1
    return frontier_finalize(graph, store, state, p)


def _assert_same(ref, got, ctx):
    d0, i0, s0 = ref[:3]
    d1, i1, s1 = got[:3]
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1),
                                  err_msg=f"ids diverged: {ctx}")
    assert np.array_equal(np.asarray(d0), np.asarray(d1),
                          equal_nan=True), f"dists diverged: {ctx}"
    for f in STAT_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(s0, f)), np.asarray(getattr(s1, f)),
            err_msg=f"counter {f} diverged: {ctx}")


@pytest.mark.parametrize("quant", ("none", "sq8"))
@pytest.mark.parametrize("strategy", STRATS)
def test_stepped_equivalence(small_dataset, small_graph, strategy, quant):
    """Chunked external stepping == one-shot while_loop, bitwise, for
    every strategy x quant combination (the acceptance grid)."""
    store, queries = small_dataset
    store = quantize_store(store)
    bm = generate_bitmaps(store, queries, WorkloadSpec(0.2, "none"), seed=7)
    p = _params(strategy, quant)
    ref = search_batch(small_graph, store, queries, bm, p)
    got = _stepped(small_graph, store, queries, bm, p, chunks=(16,))
    _assert_same(ref, got, f"{strategy}/{quant}")


@pytest.mark.parametrize("strategy", ("sweeping", "iterative_scan"))
def test_stepped_chunk_boundaries(small_dataset, small_graph, strategy):
    """Chunk boundaries are unobservable: ragged chunk sizes (1, 7, 64)
    give the same bits as any other chunking, traces included."""
    store, queries = small_dataset
    store = quantize_store(store)
    bm = generate_bitmaps(store, queries, WorkloadSpec(0.1, "high_pos"),
                          seed=3)
    p = _params(strategy, "sq8")
    ref = search_batch(small_graph, store, queries, bm, p,
                       collect_trace=True)
    got = _stepped(small_graph, store, queries, bm, p, chunks=(1, 7, 64),
                   collect_trace=True)
    _assert_same(ref, got, strategy)
    for key in ref[3]:
        np.testing.assert_array_equal(
            np.asarray(ref[3][key]), np.asarray(got[3][key]),
            err_msg=f"trace {key} diverged: {strategy}")


@pytest.mark.parametrize("strategy", ("sweeping", "iterative_scan"))
def test_dynamic_deadline_matches_static(small_dataset, small_graph,
                                         strategy):
    """A per-lane deadline array (data) reproduces the static
    `deadline_cycles` compile (knob) bit-for-bit — one compiled stepper
    covers every deadline bucket."""
    store, queries = small_dataset
    bm = generate_bitmaps(store, queries, WorkloadSpec(0.1, "none"), seed=5)
    base = _params(strategy, max_hops=300)
    for dl in (4e5, 2e6):
        pstat = dataclasses.replace(base, deadline_cycles=dl)
        ref = search_batch(small_graph, store, queries, bm, pstat)
        got = _stepped(small_graph, store, queries, bm, base, chunks=(16,),
                       deadlines=np.full(queries.shape[0], dl, np.float32),
                       dynamic=True)
        _assert_same(ref, got, f"{strategy}/deadline={dl}")


def _requests(queries, bm, nreq, arrivals=None, tenants=None,
              deadlines=None):
    bm = np.asarray(bm)
    q = np.asarray(queries)
    nq = q.shape[0]
    return [Request(rid=i, query=q[i % nq], bitmap=bm[i % nq],
                    tenant=0 if tenants is None else tenants[i],
                    arrival=0 if arrivals is None else int(arrivals[i]),
                    deadline_cycles=0.0 if deadlines is None
                    else float(deadlines[i]))
            for i in range(nreq)]


@pytest.fixture(scope="module")
def serving_setup(small_dataset, small_graph):
    store, queries = small_dataset
    bm = generate_bitmaps(store, queries, WorkloadSpec(0.3, "none"), seed=9)
    p = _params("sweeping")
    ex = GraphExecutor(small_graph, store, strategy="sweeping")
    ref = search_batch(small_graph, store, queries, bm, p)
    return store, queries, bm, p, ex, ref


def test_continuous_matches_serve_queue(serving_setup):
    """Fairness off + all arrivals at t=0: slot-retire ids/dists are
    bit-identical to the batch-synchronous serve_queue path."""
    store, queries, bm, p, ex, _ = serving_setup
    n = queries.shape[0]
    qt = jnp.asarray(queries)
    srv = RetrievalAugmentedServer(
        bundle=None, params=None, executor=ex, search_params=p,
        doc_tokens=np.zeros((store.n, 4), np.int32), chunk_len=4,
        embed_fn=lambda pr, tok: qt[tok[:, 0]])
    res, info = srv.serve_queue(np.arange(n, dtype=np.int32)[:, None],
                                bm, batch_size=4, policy="fifo")
    assert info["compiles"] >= 1          # telemetry present
    cs = ContinuousServer(ex, p, width=4, hop_chunk=8)
    recs, cinfo = cs.serve(_requests(queries, bm, n), mode="continuous")
    ids, dists = results_in_order(recs, n, p.k)
    np.testing.assert_array_equal(np.asarray(res.ids), ids)
    assert np.array_equal(np.asarray(res.dists), dists, equal_nan=True)
    # batch comparator mode: same bits, different clock
    recs_b, _ = cs.serve(_requests(queries, bm, n), mode="batch")
    ids_b, _ = results_in_order(recs_b, n, p.k)
    np.testing.assert_array_equal(ids, ids_b)


def _order_invariance_check(serving_setup, perm, arrivals):
    store, queries, bm, p, ex, ref = serving_setup
    n = queries.shape[0]
    d_ref, i_ref, s_ref = ref
    reqs = _requests(queries, bm, n)
    reqs = [reqs[j] for j in perm]
    for pos, r in enumerate(reqs):
        reqs[pos] = dataclasses.replace(r, arrival=int(arrivals[pos]))
    cs = ContinuousServer(ex, p, width=3, hop_chunk=8)
    recs, _ = cs.serve(reqs, mode="continuous")
    ids, dists = results_in_order(recs, n, p.k)
    np.testing.assert_array_equal(np.asarray(i_ref), ids)
    assert np.array_equal(np.asarray(d_ref), dists, equal_nan=True)
    for rid in range(n):
        for f in STAT_FIELDS:
            np.testing.assert_array_equal(
                np.asarray(getattr(s_ref, f))[rid:rid + 1],
                np.asarray(getattr(recs[rid]["stats"], f)),
                err_msg=f"stats {f} depend on arrival order (rid {rid})")


def test_retire_admit_deterministic_orders(serving_setup):
    """Per-request results/stats are invariant under two fixed arrival
    permutations (runs even without hypothesis)."""
    n = serving_setup[1].shape[0]
    rng = np.random.RandomState(0)
    for _ in range(2):
        perm = rng.permutation(n)
        arrivals = np.sort(rng.randint(0, 6, n))
        _order_invariance_check(serving_setup, perm, arrivals)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_retire_admit_property(serving_setup, seed):
    """Hypothesis: ANY arrival order / spacing harvests the same bits
    per request — lanes are independent rows of the pool state."""
    n = serving_setup[1].shape[0]
    rng = np.random.RandomState(seed)
    perm = rng.permutation(n)
    arrivals = np.sort(rng.randint(0, 10, n))
    _order_invariance_check(serving_setup, perm, arrivals)


def test_tenant_fairness_no_starvation(serving_setup):
    """A heavy tenant flooding the queue at t=0 cannot starve a light
    tenant under DRR: the light tenant's worst latency is strictly
    better than under FIFO, where it drains dead last."""
    store, queries, bm, p, ex, _ = serving_setup
    n_heavy, n_light = 16, 4
    n = n_heavy + n_light
    tenants = [0] * n_heavy + [1] * n_light
    reqs = _requests(queries, bm, n, tenants=tenants)
    lat = {}
    for name, fairness in (("fifo", None), ("drr", {0: 1.0, 1: 1.0})):
        cs = ContinuousServer(ex, p, width=2, hop_chunk=8,
                              fairness=fairness)
        recs, _ = cs.serve(list(reqs), mode="continuous")
        lat[name] = max(recs[r]["latency_ticks"]
                        for r in range(n_heavy, n))
        # fairness must not change any request's results
        ids, _ = results_in_order(recs, n, p.k)
        np.testing.assert_array_equal(
            ids[n_heavy:],
            np.asarray([recs[r]["ids"] for r in range(n_heavy, n)]))
    assert lat["drr"] < lat["fifo"], (
        f"DRR light-tenant worst latency {lat['drr']} not better than "
        f"FIFO {lat['fifo']}")


def test_compiles_bounded_across_deadline_buckets(serving_setup):
    """Dynamic per-lane deadlines keep the jit cache bounded: 8 distinct
    deadline buckets must NOT add 8 stepper compiles (the static-arg
    path would).  Budget flags still derive per-request."""
    store, queries, bm, p, ex, _ = serving_setup
    n = queries.shape[0]
    floor = admission_floor(store, p)
    deadlines = [floor * (2.0 + i) for i in range(n)]   # n distinct buckets
    reqs = _requests(queries, bm, n, deadlines=deadlines)
    cs = ContinuousServer(ex, p, width=4, hop_chunk=8)
    recs, info = cs.serve(reqs, mode="continuous")
    assert len({bucketed for bucketed in deadlines}) == n
    assert info["compiles"] <= 6, (
        f"{info['compiles']} compiles for {n} deadline buckets — the "
        "slot pool is supposed to compile once")
    assert all(recs[r]["anytime"] is not None for r in range(n))


def test_admission_rejects_subfloor_deadline(serving_setup):
    store, queries, bm, p, ex, _ = serving_setup
    floor = admission_floor(store, p)
    reqs = _requests(queries, bm, 2, deadlines=[0.5 * floor, 10 * floor])
    cs = ContinuousServer(ex, p, width=2, hop_chunk=8)
    recs, info = cs.serve(reqs, mode="continuous")
    assert not recs[0]["admitted"] and recs[0]["rung"] == "rejected"
    assert (recs[0]["ids"] == -1).all()
    assert recs[1]["admitted"] and recs[1]["retire_tick"] >= 0
    assert info["rejected_frac"] == 0.5


def test_fair_queue_validation_and_fifo():
    with pytest.raises(ValueError, match="weight must be > 0"):
        FairQueue({0: 0.0})
    q = FairQueue(None)
    for i in range(3):
        q.push(Request(rid=i, query=np.zeros(2), bitmap=np.zeros(1)))
    assert [q.pop().rid for _ in range(3)] == [0, 1, 2]
    assert q.pop() is None


def test_slot_pool_validation(serving_setup):
    store, queries, bm, p, ex, _ = serving_setup
    with pytest.raises(ValueError, match="width"):
        SlotPool(ex, p, width=0)
    pool = SlotPool(ex, p, width=2)
    req = Request(rid=0, query=np.asarray(queries[0]),
                  bitmap=np.asarray(bm)[0])
    pool.admit(req, 0)
    with pytest.raises(ValueError, match="occupied"):
        pool.admit(req, 0)


def test_admission_floor_memoized(serving_setup):
    store, _, _, p, _, _ = serving_setup
    _admission_floor_cached.cache_clear()
    a = admission_floor(store, p)
    h0 = _admission_floor_cached.cache_info().hits
    b = admission_floor(store, p)
    assert a == b
    assert _admission_floor_cached.cache_info().hits == h0 + 1
    # different k -> different cache entry, not a stale hit
    c = admission_floor(store, dataclasses.replace(p, k=p.k * 2))
    assert c > a


def test_queueing_delay_properties():
    s, c = 1000.0, 4
    assert costmodel.queueing_delay_cycles(0.0, s, c) == 0.0
    loads = [0.5 * c / s, 0.8 * c / s, 0.95 * c / s]
    waits = [costmodel.queueing_delay_cycles(lam, s, c) for lam in loads]
    assert waits[0] < waits[1] < waits[2], "wait not monotone in load"
    assert np.isinf(costmodel.queueing_delay_cycles(1.2 * c / s, s, c))
    # queue-aware floor: identity on an empty queue, additive otherwise
    assert costmodel.queue_aware_floor(5.0, 0, c, s) == 5.0
    assert costmodel.queue_aware_floor(5.0, 8, 4, s) == 5.0 + 2 * s
