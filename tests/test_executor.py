"""Executor layer (core/executor.py): port equivalence + planner props.

Equivalence: every pre-refactor strategy entry point must be reproduced
bit-identically by its executor port — same ids AND same SearchStats
counters (the executor layer is plumbing, not a reimplementation).

Planner: over a selectivity sweep the AdaptivePlanner must stay within
1.5x of the per-point best *recall-qualified* fixed strategy's modeled
SYSTEM cycles — the paper's Fig. 1 claim turned into a regression test.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (SYSTEM, AdaptivePlanner, BruteForceExecutor,
                        GraphExecutor, ScannExecutor, SearchParams,
                        WorkloadSpec, build_scann, cycle_breakdown,
                        engine_scale, filtered_knn, generate_bitmaps,
                        make_executor, predict_counters, recall_at_k,
                        scann_search_batch,
                        scann_search_batch_vmapped, search_batch,
                        stats_table_row)
from repro.core.costmodel import IndexShape
from repro.core.executor import GRAPH_STRATEGIES

GRAPH_PARAMS = SearchParams(k=10, ef_search=96, beam_width=512,
                            max_hops=2048)
SCANN_PARAMS = SearchParams(k=10, num_leaves_to_search=32, reorder_factor=4,
                            scann_page_accounting="per_query")


@pytest.fixture(scope="module")
def scann_index(small_dataset):
    store, _ = small_dataset
    return build_scann(store, num_leaves=64, levels=2, seed=0)


@pytest.fixture(scope="module")
def bitmaps_mid(small_dataset):
    store, queries = small_dataset
    return generate_bitmaps(store, queries, WorkloadSpec(0.2, "none"),
                            seed=11)


def _assert_stats_equal(a, b, ctx=""):
    for f in dataclasses.fields(a):
        av, bv = np.asarray(getattr(a, f.name)), np.asarray(getattr(b, f.name))
        assert np.array_equal(av, bv), (ctx, f.name, av, bv)


# ---------------- port equivalence (bit-identical) ----------------

@pytest.mark.parametrize("strategy", GRAPH_STRATEGIES)
def test_graph_executor_equivalence(small_dataset, small_graph, bitmaps_mid,
                                    strategy):
    store, queries = small_dataset
    ex = GraphExecutor(small_graph, store, strategy=strategy)
    res = ex.search(queries, bitmaps_mid, GRAPH_PARAMS)
    legacy_p = dataclasses.replace(GRAPH_PARAMS, strategy=strategy)
    d0, i0, s0 = search_batch(small_graph, store, queries, bitmaps_mid,
                              legacy_p)
    assert np.array_equal(np.asarray(res.ids), np.asarray(i0)), strategy
    assert np.array_equal(np.asarray(res.dists), np.asarray(d0)), strategy
    _assert_stats_equal(res.stats, s0, strategy)
    assert res.strategy == strategy


@pytest.mark.parametrize("pipeline", ("batched", "vmapped"))
def test_scann_executor_equivalence(small_dataset, scann_index, bitmaps_mid,
                                    pipeline):
    store, queries = small_dataset
    ex = ScannExecutor(scann_index, store, pipeline=pipeline)
    res = ex.search(queries, bitmaps_mid, SCANN_PARAMS)
    legacy = scann_search_batch if pipeline == "batched" \
        else scann_search_batch_vmapped
    d0, i0, s0 = legacy(scann_index, store, queries, bitmaps_mid,
                        res.plan.params, use_pallas=False)
    assert np.array_equal(np.asarray(res.ids), np.asarray(i0))
    assert np.array_equal(np.asarray(res.dists), np.asarray(d0))
    _assert_stats_equal(res.stats, s0, pipeline)


def test_bruteforce_executor_equivalence(small_dataset, bitmaps_mid):
    store, queries = small_dataset
    ex = BruteForceExecutor(store)
    res = ex.search(queries, bitmaps_mid, SCANN_PARAMS)
    d0, i0 = filtered_knn(store, queries, bitmaps_mid, SCANN_PARAMS.k)
    assert np.array_equal(np.asarray(res.ids), np.asarray(i0))
    assert np.array_equal(np.asarray(res.dists), np.asarray(d0))
    # seqscan counters: fc = n, dc = popcount, closed-form predictable
    row = stats_table_row(res.stats)
    assert row["filter_checks"] == store.n
    pred = predict_counters("bruteforce", IndexShape(store.n, store.dim),
                            SCANN_PARAMS, row["distance_comps"] / store.n)
    assert row["distance_comps"] == pytest.approx(pred["distance_comps"])
    assert row["page_accesses_heap"] == pytest.approx(
        pred["page_accesses_heap"])


def test_scann_query_block_tiling_oracle(small_dataset, scann_index,
                                         bitmaps_mid):
    """Satellite: query-block tiling must not change ids/dists (nor any
    counter under per_query accounting) for ANY tile size."""
    store, queries = small_dataset
    base = scann_search_batch(scann_index, store, queries, bitmaps_mid,
                              SCANN_PARAMS)
    for block in (1, 3, 8):
        p = dataclasses.replace(SCANN_PARAMS, scann_query_block=block)
        d, ids, stats = scann_search_batch(scann_index, store, queries,
                                           bitmaps_mid, p)
        assert np.array_equal(np.asarray(ids), np.asarray(base[1])), block
        assert np.array_equal(np.asarray(d), np.asarray(base[0])), block
        _assert_stats_equal(stats, base[2], f"block={block}")


def test_registry_dispatch_and_errors(small_dataset, small_graph,
                                      scann_index):
    store, _ = small_dataset
    assert make_executor("navix", store, graph=small_graph).name == "navix"
    assert make_executor("scann", store, index=scann_index).name == "scann"
    assert make_executor("bruteforce", store).name == "bruteforce"
    with pytest.raises(ValueError):
        make_executor("navix", store)          # graph missing
    with pytest.raises(ValueError):
        make_executor("scann", store)          # index missing
    with pytest.raises(ValueError):
        make_executor("no_such_method", store)


# ---------------- the adaptive planner ----------------

def _recall(ids, tid, k=10):
    return float(np.mean(np.asarray(
        jax.vmap(lambda f, t: recall_at_k(f, t, k))(ids, tid))))


@pytest.fixture(scope="module")
def planner_setup(small_dataset, small_graph, scann_index):
    store, _ = small_dataset
    planner = make_executor("adaptive", store, graph=small_graph,
                            index=scann_index, graph_m=small_graph.m)
    fixed = {name: ex for name, ex in planner.candidates.items()}
    return store, planner, fixed


PLANNER_PARAMS = SearchParams(k=10, ef_search=96, beam_width=512,
                              max_hops=2048,
                              scann_page_accounting="per_query")
RECALL_FLOOR = 0.85


@pytest.mark.parametrize("corr", ("none", "high_pos"))
def test_planner_regret_selectivity_sweep(small_dataset, planner_setup,
                                          corr):
    """Property: at every selectivity the planner's modeled SYSTEM cycles
    stay within 1.5x of the best recall-qualified fixed strategy — while
    (asserted once per sweep) the winning strategy changes with
    selectivity, i.e. the decision is real."""
    store, queries = small_dataset
    _, planner, fixed = planner_setup
    seen_best = set()
    for i, sel in enumerate((0.02, 0.1, 0.3, 0.7)):
        bm = generate_bitmaps(store, queries, WorkloadSpec(sel, corr),
                              seed=20 + i)
        _, tid = filtered_knn(store, queries, bm, PLANNER_PARAMS.k)
        cyc, rec = {}, {}
        q_batch = queries.shape[0]
        for name, ex in fixed.items():
            r = ex.search(queries, bm, PLANNER_PARAMS)
            # engine-mode-aware currency: graph strategies execute on the
            # frontier engine whose batched fetches amortize page costs
            cyc[name] = cycle_breakdown(
                r.stats, store.dim, SYSTEM,
                engine_scale(r.strategy, PLANNER_PARAMS, q_batch))["total"]
            rec[name] = _recall(r.ids, tid, PLANNER_PARAMS.k)
        qualified = {m: c for m, c in cyc.items()
                     if rec[m] >= RECALL_FLOOR} or cyc
        best = min(qualified, key=qualified.get)
        seen_best.add(best)
        pres = planner.search(queries, bm, PLANNER_PARAMS)
        pcyc = cycle_breakdown(
            pres.stats, store.dim, SYSTEM,
            engine_scale(pres.strategy, PLANNER_PARAMS, q_batch))["total"]
        assert pcyc <= 1.5 * qualified[best], (
            corr, sel, pres.strategy, best,
            {m: round(c / 1e6, 2) for m, c in cyc.items()})
        assert _recall(pres.ids, tid, PLANNER_PARAMS.k) >= RECALL_FLOOR, (
            corr, sel, pres.strategy)
    if corr == "none":
        assert len(seen_best) >= 2      # the crossover exists (Fig. 1)


def test_planner_decision_boundaries(small_dataset, planner_setup):
    """Sanity on the closed-form boundaries: very low selectivity →
    bruteforce (scan the few survivors); high selectivity → never
    bruteforce (heap-page traffic explodes)."""
    store, queries = small_dataset
    _, planner, _ = planner_setup
    lo = generate_bitmaps(store, queries, WorkloadSpec(0.005, "none"),
                          seed=31)
    hi = generate_bitmaps(store, queries, WorkloadSpec(0.8, "none"),
                          seed=32)
    assert planner.search(queries, lo, PLANNER_PARAMS).strategy == \
        "bruteforce"
    assert planner.search(queries, hi, PLANNER_PARAMS).strategy != \
        "bruteforce"


def test_planner_annotations_and_overhead(small_dataset, planner_setup,
                                          bitmaps_mid):
    """The plan carries estimates; the result carries the chosen strategy
    and the stats include the planning overhead (popcount word reads)."""
    store, queries = small_dataset
    _, planner, fixed = planner_setup
    plan = planner.plan(queries, bitmaps_mid, PLANNER_PARAMS)
    np.testing.assert_allclose(plan.est_selectivity, 0.2, atol=0.01)
    assert plan.correlation_proxy is not None
    assert set(plan.predicted_cycles) == set(fixed)
    res = planner.execute(plan)
    assert res.strategy == plan.strategy
    delegate = fixed[plan.strategy].search(queries, bitmaps_mid,
                                           PLANNER_PARAMS)
    extra = (np.asarray(res.stats.filter_checks)
             - np.asarray(delegate.stats.filter_checks))
    assert (extra >= bitmaps_mid.shape[1]).all()   # ≥ one read per word
