"""Robustness layer (DESIGN.md §10): anytime budgets + exhaustion flags,
storage fault injection, and the serving degradation ladder."""
import dataclasses
import warnings

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (SearchParams, WorkloadSpec, build_scann,
                        evaluate_anytime, generate_bitmaps, linear_cycles,
                        search_batch)
from repro.core.costmodel import GRAPH_STRATEGIES
from repro.core.executor import (BruteForceExecutor, GraphExecutor,
                                 ScannExecutor)
from repro.core.types import quantize_store
from repro.storage import (BufferPool, FaultInjector, FaultPlan,
                           make_storage_engine)

STRATEGIES = GRAPH_STRATEGIES          # all 5 (incl. unfiltered)
ENGINES = ("vmapped", "frontier")
STAT_FIELDS = ("distance_comps", "filter_checks", "hops",
               "page_accesses_index", "page_accesses_heap",
               "tmap_lookups", "reorder_rows")


def _params(**kw):
    base = dict(k=8, ef_search=32, beam_width=64, max_hops=64)
    base.update(kw)
    return SearchParams(**base)


def _bitmaps(store, queries, sel=0.3, seed=3):
    return generate_bitmaps(store, queries, WorkloadSpec(sel, "none"),
                            seed=seed)


# ---------------------------------------------------------------------------
# budget semantics on the graph engines
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", STRATEGIES)
def test_max_hops_truncation_flags_and_best_so_far(small_dataset,
                                                   small_graph, strategy):
    """Satellite: a max_hops-capped traversal must FLAG truncation (the
    pre-§10 code swallowed it) while ids/dists stay the best-so-far beam
    — valid, bitmap-passing, sorted ascending."""
    store, queries = small_dataset
    bm = _bitmaps(store, queries)
    for mode in ENGINES:
        p = _params(strategy=strategy, max_hops=4, graph_exec_mode=mode)
        ex = GraphExecutor(small_graph, store, strategy=strategy)
        res = ex.search(queries, bm, dataclasses.replace(p))
        hops = np.asarray(res.stats.hops)
        capped = hops >= 4
        assert capped.any(), "4 hops did not cap any query — bad setup"
        an = res.anytime
        assert an is not None
        assert np.array_equal(np.asarray(an.truncated), capped)
        # best-so-far: valid prefix, ascending dists, -1 padding after
        ids = np.asarray(res.ids)
        d = np.asarray(res.dists)
        for i in range(ids.shape[0]):
            valid = ids[i] >= 0
            assert (~valid[np.argmax(valid)] == 0 if valid.any()
                    else True)
            dv = d[i][valid]
            assert (np.diff(dv) >= 0).all()
            assert np.isinf(d[i][~valid]).all()


def test_hop_budget_caps_exactly_and_engines_identical(small_dataset,
                                                       small_graph):
    store, queries = small_dataset
    bm = _bitmaps(store, queries)
    results = {}
    for mode in ENGINES:
        p = _params(strategy="sweeping", hop_budget=6,
                    graph_exec_mode=mode)
        d, ids, st = search_batch(small_graph, store, queries, bm, p)
        # predicate is hops >= budget at loop top: the crossing hop
        # completes, so the counter lands on budget or budget+1
        assert (np.asarray(st.hops) <= 7).all()
        assert (np.asarray(st.hops) >= 6).any()
        results[mode] = (np.asarray(d), np.asarray(ids), st)
    dv, iv, sv = results["vmapped"]
    df, iff, sf = results["frontier"]
    assert np.array_equal(iv, iff)
    assert np.array_equal(dv, df, equal_nan=True)
    for f in STAT_FIELDS:
        assert np.array_equal(np.asarray(getattr(sv, f)),
                              np.asarray(getattr(sf, f))), f


def test_page_and_deadline_budgets_flagged(small_dataset, small_graph):
    store, queries = small_dataset
    bm = _bitmaps(store, queries)
    ex = GraphExecutor(small_graph, store, strategy="sweeping")
    free = ex.search(queries, bm, _params(max_hops=256))
    assert not np.asarray(free.anytime.budget_exhausted).any()
    assert not np.asarray(free.anytime.truncated).any()
    assert (np.asarray(free.anytime.completion) == 1.0).all()
    pages = int(np.asarray(free.stats.page_accesses_heap).min())
    res = ex.search(queries, bm,
                    _params(max_hops=256, page_budget=max(pages // 2, 1)))
    an = res.anytime
    assert np.asarray(an.budget_exhausted).all()
    assert np.asarray(an.truncated).all()
    cyc = linear_cycles(free.stats, store.dim)
    res2 = ex.search(queries, bm,
                     _params(max_hops=256,
                             deadline_cycles=float(cyc.min()) / 2))
    assert np.asarray(res2.anytime.budget_exhausted).all()
    assert (np.asarray(res2.stats.hops)
            < np.asarray(free.stats.hops)).any()


@pytest.mark.parametrize(
    "strategy", [s for s in STRATEGIES if s != "unfiltered"])
def test_fewer_than_k_passing_rows_padding(small_dataset, small_graph,
                                           strategy):
    """Satellite: with fewer passing rows than k, every filtered executor
    pads with ids=-1 / dists=inf and completion < 1 is reported.
    ("unfiltered" ignores the bitmap by design, so it is exempt.)"""
    store, queries = small_dataset
    words = (store.n + 31) // 32
    bm = np.zeros((queries.shape[0], words), np.uint32)
    passing = [1, 5, 9]                       # 3 rows pass, k=8
    for r in passing:
        bm[:, r // 32] |= np.uint32(1) << np.uint32(r % 32)
    bm = jnp.asarray(bm)
    for mode in ENGINES:
        p = _params(strategy=strategy, graph_exec_mode=mode)
        ex = GraphExecutor(small_graph, store, strategy=strategy)
        res = ex.search(queries, bm, p)
        ids = np.asarray(res.ids)
        assert ((ids >= 0).sum(axis=1) <= len(passing)).all()
        assert np.isinf(np.asarray(res.dists)[ids < 0]).all()
        assert set(ids[ids >= 0].tolist()) <= set(passing)
        assert (np.asarray(res.anytime.completion) < 1.0).all()


def test_fewer_than_k_scann_and_bruteforce(small_dataset):
    store, queries = small_dataset
    idx = build_scann(store, num_leaves=16, levels=1, seed=0)
    words = (store.n + 31) // 32
    bm = np.zeros((queries.shape[0], words), np.uint32)
    for r in (2, 7):
        bm[:, r // 32] |= np.uint32(1) << np.uint32(r % 32)
    bm = jnp.asarray(bm)
    p = _params(num_leaves_to_search=16)
    for ex in (ScannExecutor(idx, store), BruteForceExecutor(store)):
        res = ex.search(queries, bm, p)
        ids = np.asarray(res.ids)
        assert ((ids >= 0).sum(axis=1) <= 2).all()
        assert np.isinf(np.asarray(res.dists)[ids < 0]).all()
        assert (np.asarray(res.anytime.completion) < 1.0).all()


def test_scann_leaf_clamp_and_bruteforce_row_cap(small_dataset):
    store, queries = small_dataset
    bm = _bitmaps(store, queries)
    idx = build_scann(store, num_leaves=16, levels=1, seed=0)
    sx = ScannExecutor(idx, store)
    plan = sx.plan(queries, bm, _params(num_leaves_to_search=8,
                                        hop_budget=3))
    assert plan.notes == {"leaf_clamp": 3}
    assert plan.params.num_leaves_to_search == 3
    res = sx.execute(plan)
    assert np.asarray(res.anytime.budget_exhausted).all()
    # no budget -> no clamp, no flags
    plain = sx.search(queries, bm, _params(num_leaves_to_search=8))
    assert plain.plan.notes is None
    assert not np.asarray(plain.anytime.budget_exhausted).any()

    bx = BruteForceExecutor(store)
    from repro.core.types import heap_pages_per_vector
    ppv = heap_pages_per_vector(store.dim)
    cap = 50
    res = bx.search(queries, bm, _params(page_budget=cap * ppv))
    assert res.plan.notes == {"max_rows": cap}
    assert (np.asarray(res.stats.distance_comps) <= cap).all()
    assert np.asarray(res.anytime.budget_exhausted).all()
    ids = np.asarray(res.ids)
    assert (ids >= 0).all()                   # k=8 <= 50 scanned rows
    # partial top-k == exact top-k over the scanned prefix
    full = bx.search(queries, bm, _params())
    probes = np.asarray(res.stats.filter_checks)
    full_ids = np.asarray(full.ids)
    for i in range(ids.shape[0]):
        expect = [r for r in full_ids[i] if 0 <= r < probes[i]]
        got = [r for r in ids[i] if r in expect]
        assert got == expect[:len(got)] or set(ids[i]) >= set(expect[:8])


def test_evaluate_anytime_zero_budget_noop():
    st = None
    p = SearchParams()
    ids = np.array([[1, 2, -1], [3, -1, -1]])
    an = evaluate_anytime(st, p, dim=16, ids=ids)
    assert not an.truncated.any() and not an.budget_exhausted.any()
    assert np.allclose(an.completion, [2 / 3, 1 / 3])


# ---------------------------------------------------------------------------
# fault injection
# ---------------------------------------------------------------------------

def test_faultplan_deterministic_and_seed_sensitive():
    rng = np.random.default_rng(0)
    trace = rng.integers(0, 300, size=4000)
    plan = FaultPlan(seed=9, read_fail_prob=0.05, max_retries=1,
                     latency_spike_prob=0.1, pressure_prob=0.003,
                     pressure_len=200, pressure_frac=0.3)

    def run(pl):
        pool = BufferPool(64, faults=FaultInjector(pl))
        return pool.access(trace).as_dict()

    a, b = run(plan), run(plan)
    assert a == b
    assert a["retries"] > 0 and a["spikes"] > 0
    c = run(dataclasses.replace(plan, seed=10))
    assert c != a


def test_zero_fault_plan_is_identity():
    rng = np.random.default_rng(1)
    trace = rng.integers(0, 200, size=3000)
    clean = BufferPool(32)
    inert = BufferPool(32, faults=FaultInjector(FaultPlan()))
    assert clean.access(trace).as_dict() == inert.access(trace).as_dict()
    assert inert.counters.retries == 0
    assert inert.counters.failed_reads == 0
    assert inert.counters.spikes == 0


def test_engine_zero_fault_storage_stats_identical(small_dataset):
    store, queries = small_dataset
    bm = _bitmaps(store, queries)
    idx = build_scann(store, num_leaves=16, levels=1, seed=0)
    p = _params(num_leaves_to_search=8,
                scann_page_accounting="per_query")
    runs = {}
    for tag, faults in (("none", None), ("zero", FaultPlan())):
        eng = make_storage_engine(store, index=idx, capacity_frac=0.5,
                                  faults=faults)
        res = ScannExecutor(idx, store, storage=eng).search(queries, bm, p)
        runs[tag] = res
    a, b = runs["none"].storage, runs["zero"].storage
    assert a.logical == b.logical and a.misses == b.misses
    assert a.hits == b.hits and a.evictions == b.evictions
    assert b.retries == 0 and b.failed_reads == 0 and b.spikes == 0
    assert not b.faulted.any()
    assert np.array_equal(np.asarray(runs["none"].ids),
                          np.asarray(runs["zero"].ids))


def test_faulted_queries_flagged_results_uncorrupted(small_dataset):
    """Faults are accounting-only: ids/dists bit-identical to the clean
    run, but per-query faulted flags fire deterministically."""
    store, queries = small_dataset
    bm = _bitmaps(store, queries)
    idx = build_scann(store, num_leaves=16, levels=1, seed=0)
    p = _params(num_leaves_to_search=8,
                scann_page_accounting="per_query")
    plan = FaultPlan(seed=4, read_fail_prob=0.3, max_retries=0)

    def run():
        eng = make_storage_engine(store, index=idx, capacity_frac=0.25,
                                  faults=plan)
        return ScannExecutor(idx, store, storage=eng).search(queries, bm, p)

    clean_eng = make_storage_engine(store, index=idx, capacity_frac=0.25)
    clean = ScannExecutor(idx, store, storage=clean_eng).search(
        queries, bm, p)
    r1, r2 = run(), run()
    assert r1.storage.failed_reads > 0
    assert r1.storage.faulted.any()
    assert np.array_equal(r1.storage.faulted, r2.storage.faulted)
    assert r1.storage.retries == r2.storage.retries
    assert np.array_equal(np.asarray(r1.ids), np.asarray(clean.ids))
    assert np.array_equal(np.asarray(r1.dists), np.asarray(clean.dists))


def test_pressure_window_shrinks_pool():
    plan = FaultPlan(seed=1, pressure_prob=1.0, pressure_len=10 ** 9,
                     pressure_frac=0.25)
    pool = BufferPool(64, faults=FaultInjector(plan))
    pool.access(np.arange(500))
    assert len(pool) <= 16


# ---------------------------------------------------------------------------
# serving: validation, fallback, ladder chaos
# ---------------------------------------------------------------------------

def _server(store, executor, params):
    from repro.serving import RetrievalAugmentedServer
    docs = np.zeros((store.n, 4), np.int32)
    qtable = jnp.asarray(np.zeros((store.n, store.dim), np.float32))
    return RetrievalAugmentedServer(
        bundle=None, params=None, executor=executor,
        search_params=params, doc_tokens=docs, chunk_len=4,
        embed_fn=lambda p, tok: qtable[tok[:, 0]])


def _query_server(store, queries, executor, params):
    from repro.serving import RetrievalAugmentedServer
    docs = np.zeros((store.n, 4), np.int32)
    qt = jnp.asarray(queries)
    return RetrievalAugmentedServer(
        bundle=None, params=None, executor=executor,
        search_params=params, doc_tokens=docs, chunk_len=4,
        embed_fn=lambda p, tok: qt[tok[:, 0]])


def test_serve_queue_validates_inputs(small_dataset):
    store, queries = small_dataset
    srv = _server(store, BruteForceExecutor(store), _params())
    bm = np.zeros((4, (store.n + 31) // 32), np.uint32)
    prompts = np.zeros((4, 1), np.int32)
    with pytest.raises(ValueError, match="empty request queue"):
        srv.serve_queue(prompts[:0], bm[:0])
    with pytest.raises(ValueError, match="length mismatch"):
        srv.serve_queue(prompts, bm[:2])
    with pytest.raises(ValueError, match="empty request queue"):
        srv.retrieve(prompts[:0], bm[:0])
    with pytest.raises(ValueError, match="length mismatch"):
        srv.retrieve(prompts, bm[:1])
    with pytest.raises(ValueError, match="deadlines length mismatch"):
        srv.serve_queue(prompts, bm, policy="fifo", deadlines=np.ones(2))


def test_serve_queue_centroid_fallback_is_loud(small_dataset,
                                               small_graph):
    store, queries = small_dataset
    ex = GraphExecutor(small_graph, store, strategy="sweeping")
    srv = _query_server(store, queries, ex, _params())
    bm = np.asarray(_bitmaps(store, queries))
    prompts = np.arange(queries.shape[0], dtype=np.int32)[:, None]
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        res, info = srv.serve_queue(prompts, bm, batch_size=4,
                                    policy="centroid")
    assert info["policy"] == "centroid"
    assert info["policy_effective"] == "fifo"
    assert "policy_fallback_reason" in info
    assert any(issubclass(x.category, RuntimeWarning) for x in w)
    # fallback serves correctly: same results as asking for fifo
    res2, _ = srv.serve_queue(prompts, bm, batch_size=4, policy="fifo")
    assert np.array_equal(res.ids, res2.ids)


def test_serve_queue_clean_path_unchanged(small_dataset):
    """No deadlines + fault-free pool + no budgets: the ladder never
    engages and every request is served by the primary rung."""
    store, queries = small_dataset
    idx = build_scann(store, num_leaves=16, levels=1, seed=0)
    eng = make_storage_engine(store, index=idx, capacity_frac=1.0)
    ex = ScannExecutor(idx, store, storage=eng)
    p = _params(num_leaves_to_search=8,
                scann_page_accounting="per_query")
    srv = _query_server(store, queries, ex, p)
    bm = np.asarray(_bitmaps(store, queries))
    prompts = np.arange(queries.shape[0], dtype=np.int32)[:, None]
    res, info = srv.serve_queue(prompts, bm, batch_size=4, policy="fifo")
    assert (info["rung_level"] == 0).all()
    assert (info["rung"] == "primary").all()
    assert not info["degraded"].any()
    assert info["admitted"].all()
    direct = ex.search(jnp.asarray(queries), jnp.asarray(bm), p)
    assert np.array_equal(res.ids, np.asarray(direct.ids))


def test_serve_queue_chaos_ladder(small_dataset, small_graph):
    """Acceptance: under seeded faults every request either returns k
    results or is explicitly flagged partial/degraded — and the whole
    outcome is deterministic under the same FaultPlan seed."""
    store, queries = small_dataset
    qstore = quantize_store(store)
    idx = build_scann(qstore, num_leaves=16, levels=1, seed=0)
    plan = FaultPlan(seed=13, read_fail_prob=0.12, max_retries=1,
                     latency_spike_prob=0.05)
    p = _params(graph_exec_mode="frontier", num_leaves_to_search=8,
                scann_page_accounting="per_query")

    def serve():
        eng = make_storage_engine(qstore, index=idx, graph=small_graph,
                                  capacity_frac=0.25, faults=plan)
        ex = GraphExecutor(small_graph, qstore, strategy="sweeping",
                           storage=eng)
        srv = _query_server(qstore, queries, ex, p)
        bm = np.asarray(_bitmaps(qstore, queries))
        prompts = np.arange(queries.shape[0], dtype=np.int32)[:, None]
        return srv.serve_queue(prompts, bm, batch_size=4, policy="fifo")

    res, info = serve()
    assert info["pool_failed_reads"] > 0, "fault plan too weak — retune"
    ids = np.asarray(res.ids)
    full = (ids >= 0).all(axis=1)
    assert (full | info["degraded"]).all()
    assert set(info["ladder"]) >= {"primary", "sq8_norerank",
                                   "partial_scan"}
    # deterministic replay: same seed -> same rungs, flags, results
    res2, info2 = serve()
    assert np.array_equal(ids, np.asarray(res2.ids))
    assert np.array_equal(info["rung"], info2["rung"])
    assert np.array_equal(info["retried"], info2["retried"])
    assert np.array_equal(info["faulted"], info2["faulted"])


def test_serve_queue_deadline_admission_and_degradation(small_dataset):
    from repro.serving.rag import admission_floor, bucket_deadline
    store, queries = small_dataset
    idx = build_scann(store, num_leaves=16, levels=1, seed=0)
    ex = ScannExecutor(idx, store)
    p = _params(num_leaves_to_search=8)
    srv = _query_server(store, queries, ex, p)
    bm = np.asarray(_bitmaps(store, queries))
    prompts = np.arange(queries.shape[0], dtype=np.int32)[:, None]
    floor = admission_floor(store, p)
    nreq = queries.shape[0]
    dls = np.full(nreq, floor * 50)
    dls[0] = floor * 0.4                      # impossible -> rejected
    res, info = srv.serve_queue(prompts, bm, batch_size=4, policy="fifo",
                                deadlines=dls)
    assert not info["admitted"][0]
    assert info["rung"][0] == "rejected"
    assert (np.asarray(res.ids)[0] == -1).all()
    assert info["admitted"][1:].all()
    assert (info["rung_level"][1:] >= 0).all()
    # bucketing: 2 significant figures, floored
    assert bucket_deadline(123456.0) == 120000.0
    assert bucket_deadline(98.7) == 98.0
    assert bucket_deadline(0.0) == 0.0
    assert bucket_deadline(float("inf")) == 0.0


def test_default_ladder_shapes(small_dataset, small_graph):
    from repro.serving.rag import default_ladder, price_ladder
    store, _ = small_dataset
    qstore = quantize_store(store)
    idx = build_scann(qstore, num_leaves=16, levels=1, seed=0)
    gex = GraphExecutor(small_graph, qstore, strategy="sweeping")
    names = [r.name for r in default_ladder(gex)]
    assert names == ["primary", "sq8_norerank", "partial_scan"]
    sx = ScannExecutor(idx, qstore)
    names = [r.name for r in default_ladder(sx)]
    assert names == ["primary", "scann_lite", "partial_scan"]
    prices = price_ladder(default_ladder(sx),
                          _params(num_leaves_to_search=8), 0.3, batch_q=8)
    assert prices["scann_lite"] < prices["primary"]
    assert prices["partial_scan"] > 0
