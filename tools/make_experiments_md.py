"""Generate EXPERIMENTS.md from dry-run/perf JSONs + benchmark CSV log."""
from __future__ import annotations

import json
import os
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..")

PEAK = 197e12


def load(path):
    with open(os.path.join(ROOT, path)) as f:
        return json.load(f)


def fmt_row(r):
    rf = r["roofline"]
    dom = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
    ideal = r["model_flops"] / (r["chips"] * PEAK)
    frac = ideal / dom if dom else 0.0
    mem = r.get("memory_analysis", {})
    argb = mem.get("argument_size_in_bytes", 0) / 1e9
    tmpb = mem.get("temp_size_in_bytes", 0) / 1e9
    return (f"| {r['arch']} | {r['shape']} | {rf['dominant'][:-2]} | "
            f"{rf['compute_s']:.3f} | {rf['memory_s']:.3f} | "
            f"{rf['collective_s']:.3f} | "
            f"{r['useful_flops_ratio']:.2f} | {frac:.3f} | "
            f"{argb:.1f}/{tmpb:.1f} |")


def main() -> None:
    single = load("dryrun_single_pod.json")
    multi = load("dryrun_multi_pod.json")
    perf = {}
    pdir = os.path.join(ROOT, "perf_runs")
    if os.path.isdir(pdir):
        for f in sorted(os.listdir(pdir)):
            if f.endswith(".json"):
                try:
                    perf[f[:-5]] = json.load(open(os.path.join(pdir, f)))
                except Exception:
                    pass

    def by(arch, shape, rows):
        for r in rows:
            if r.get("arch") == arch and r.get("shape") == shape:
                return r
        return None

    out = []
    a = out.append
    a(HEADER)

    a("\n## §Dry-run\n")
    a("Every live (architecture × shape) cell lowered **and compiled** on "
      "both production meshes from this CPU container (512 forced host "
      "devices):\n")
    a(f"- single-pod `(data=16, model=16)` = 256 chips: "
      f"**{len([r for r in single if 'error' not in r])}/{len(single)} "
      f"cells OK** (`dryrun_single_pod.json`)")
    a(f"- multi-pod `(pod=2, data=16, model=16)` = 512 chips: "
      f"**{len([r for r in multi if 'error' not in r])}/{len(multi)} "
      f"cells OK** (`dryrun_multi_pod.json`)\n")
    a("Per-cell records hold `memory_analysis()` (argument/temp bytes per "
      "device), `cost_analysis()` raw output, jaxpr-exact FLOPs/bytes, and "
      "the per-collective byte breakdown parsed from the optimized HLO "
      "(all-gather / all-reduce / reduce-scatter / all-to-all / "
      "collective-permute, while-body ops × scan trip count, XLA:CPU's "
      "bf16→f32 all-reduce promotion un-done). Reproduce any cell:\n")
    a("```\nPYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b "
      "--shape train_4k [--multi-pod]\n```\n")
    a("Skipped cells per the assignment rules (DESIGN.md §5): hubert "
      "decode/long (encoder-only); long_500k for all pure-full-attention "
      "archs (runs for rwkv6-3b and zamba2-1.2b).\n")

    a("\n## §Roofline — single-pod baseline, every cell\n")
    a("Terms in **seconds per step** (per device): compute = FLOPs/(197 "
      "TF/s), memory = HBM bytes/(819 GB/s), collective = bytes/(50 GB/s "
      "link). `useful` = MODEL_FLOPS/HLO_FLOPs (remat/capacity waste); "
      "`frac` = MODEL_FLOPS/(chips·peak)/dominant-term = the roofline "
      "fraction this report is scored on. `mem GB` = per-device "
      "argument/temp bytes from `memory_analysis()`.\n")
    a("| arch | shape | bound | compute_s | memory_s | collective_s | "
      "useful | frac | mem GB arg/temp |")
    a("|---|---|---|---|---|---|---|---|---|")
    for r in single:
        if "error" not in r:
            a(fmt_row(r))
    a("")
    a(NOTES_ROOFLINE)

    a("\n### Multi-pod (512-chip) deltas\n")
    a("| arch | shape | coll_s 256c | coll_s 512c | note |")
    a("|---|---|---|---|---|")
    for r in multi:
        if "error" in r:
            continue
        s = by(r["arch"], r["shape"], single)
        if s is None:
            continue
        c1 = s["roofline"]["collective_s"]
        c2 = r["roofline"]["collective_s"]
        note = "DP over pod axis adds cross-DCI grad reduce" \
            if c2 > c1 * 1.05 else "≈ unchanged (per-device shards halve)"
        a(f"| {r['arch']} | {r['shape']} | {c1:.3f} | {c2:.3f} | {note} |")
    a("")

    a(PERF_SECTION)

    # fill in perf numbers
    def cell(name, key="roofline"):
        r = perf.get(name)
        if not r:
            return "n/a"
        rf = r["roofline"]
        return (f"comp {rf['compute_s']:.3f} / mem {rf['memory_s']:.3f} / "
                f"coll {rf['collective_s']:.3f}")

    a("\n### Raw per-variant roofline terms (perf_runs/*.json)\n")
    a("| variant | terms (s) | dominant |")
    a("|---|---|---|")
    for name, r in perf.items():
        rf = r["roofline"]
        a(f"| {name} | {cell(name)} | {rf['dominant'][:-2]} |")
    a("")

    a(BENCH_SECTION)

    with open(os.path.join(ROOT, "EXPERIMENTS.md"), "w") as f:
        f.write("\n".join(out))
    print("wrote EXPERIMENTS.md", len(out), "lines")


HEADER = """# EXPERIMENTS — dry-run, roofline, and perf iteration log

System: `vexa` — filter-agnostic FVS framework (see DESIGN.md). Hardware
target: TPU v5e (197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI);
container runtime: CPU (dry-run lower+compile, kernels in interpret mode).

Measurement conventions (why you can trust these numbers):
- **FLOPs/bytes** are jaxpr-exact: scan bodies × static trip count, remat
  recompute included, scan carries charged 2× per iteration (the HBM cost
  XLA's `cost_analysis()` misses — it counts while bodies ONCE; raw XLA
  numbers are kept in each record as `xla_*_loop_once` for comparison).
  Elementwise ops are treated as fused (TPU-realistic); matmul, gather/
  scatter, reduce, sort classes are charged operands+outputs.
- **Collective bytes** are parsed from the optimized HLO per computation,
  ×layer-count for while bodies, result-shape bytes per op (exact for
  all-gather/reduce-scatter; ring all-reduce moves up to 2× this), with
  XLA:CPU's bf16→f32 all-reduce promotion counted at bf16 width.
- **MODEL_FLOPS** is analytic: 6·N_active·tokens (train) / 2·N_active
  (inference) for matmul params (non-embedding) + exact attention-context
  and SSM-state terms per arch (`analytic_model_flops`).
"""

NOTES_ROOFLINE = """**Reading the table (one line per dominant bottleneck):**
- `granite-20b train_4k` is the healthiest cell — compute-bound at
  **0.93 roofline fraction** (MQA + huge d_ff amortize collectives).
- All dense train cells are collective-bound at baseline: Megatron-TP
  activation all-reduces (2/layer fwd + bwd) at seq 4096. This is what the
  §Perf SP scheme attacks.
- `kimi-k2` (1T MoE) is dominated by EP combine traffic (top-8 × d=7168
  per token crossing the `model` axis) — the deepest §Perf target.
- 32k prefills are memory-bound at baseline: the pure-JAX blocked
  attention materializes score tensors and loop carries per KV block;
  the §Perf Pallas flash kernel removes exactly this term.
- decode cells: tiny absolute times; `long_500k` runs only for the
  sub-quadratic archs (rwkv6 state 5.2 MB/layer; zamba2 ring-buffer
  window) — both memory-bound on state traffic, as expected.
- `mem GB arg/temp`: kimi train needs ~26 GB arguments/device (bf16
  params+opt on 256 chips) — fits HBM only at 512 chips (multi-pod) or
  with int8 states; recorded honestly rather than hidden.
"""

PERF_SECTION = """
## §Perf — hypothesis → change → measure → validate

Method per the assignment: baseline every cell (§Roofline), hillclimb the
three most interesting, napkin-math before each change, record confirmed
AND refuted. The paper-faithful baseline (Megatron-TP, jnp blocked
attention, GSPMD-default MoE, token-scan RWKV) is kept as the default
config; every optimization is a config flag, so baseline and optimized
lower side by side.

**Iteration 0 (pre-baseline correctness): partitionable cross-entropy.**
- Hypothesis: 85 GB/device of all-gathers in llama train came from
  `take_along_axis`+`logsumexp` over model-sharded logits (GSPMD gathers
  (B,T,V)).
- Change: one-hot einsum cross-entropy (partial V-reduction + psum).
- Measured: the (B,T,128256) gathers left the HLO; remaining all-gathers
  were TP-misfit reshapes (llama's 24 heads vs 16-way model axis).
  CONFIRMED — and folded into the baseline since it is a correctness-of-
  sharding fix, not an arch change.

### Cell B — llama3.2-3b × train_4k (worst dense-train roofline fraction)
Baseline: coll **2.63 s** / comp 0.52 / mem 1.03; bound by 2
TP all-reduces per layer (f32-promoted on CPU; bf16 on TPU) plus 24-head
TP-misfit gathers.
- **it1 — SP scheme** (`sharding_scheme=sp`: seq over `model`, weights
  FSDP over `data`, K/V gathered per layer). Napkin: AR payload drops
  16×; new costs = per-layer weight gather (~230 MB) + K/V gather
  (~134 MB) ⇒ predict coll ≈ 0.7–1.2 s. Measured: **coll 2.63 → 1.20 s**
  (AR 92 → 3.3 GB; AG became weight+KV gathers). CONFIRMED (2.2×).
- **it2 — bf16 params under SP.** Napkin: weight gathers halve ⇒ −40%
  coll. Measured: **no change** — REFUTED: the AD-transpose side
  up-casts before the gather, pinning gather width at f32; lesson: dtype
  of the *gather*, not the parameter store, is what matters; needs
  convert-before-gather control, deferred.
- **it3 — SP + remat none.** Napkin: dropping remat removes the bwd
  re-gather of weights (the recompute path re-all-gathers) ⇒ −25% coll,
  −6% comp. Measured: **coll 1.20 → 0.90 s**, comp 0.52 → 0.49, mem
  1.03 → 0.77, useful 0.86 → 0.91. CONFIRMED.
- Cell result: dominant term **2.63 → 0.90 s (2.9×)**; roofline fraction
  0.17 → 0.49.

### Cell A — kimi-k2-1t-a32b × train_4k (most collective-bound)
Baseline: coll **38.6 s** (AR 1178 GB + AG 751 GB per device) vs comp
6.4 s — the EP combine moves k=8 × d=7168 per token across `model`.
- **it1 — capacity factor 1.25 → 1.0.** Napkin: dispatch buffers ∝ cf ⇒
  −20% coll. Measured: comp 6.43 → 5.55 (−14%), **coll unchanged** —
  REFUTED: the dominant AR is token-sized (n·k·d), not capacity-sized;
  lesson: the combine, not the dispatch buffers, is the wire cost.
- **it2 — SP scheme.** Measured: coll 38.6 → **43.8 s** — REFUTED: SP
  helps dense layers but adds dispatch gathers from seq-sharded tokens;
  lesson: MoE wants token-contiguous (group-aligned) activations.
- **it4 — shard_map local-combine** (sum each shard's k-subset locally,
  psum (n,d) partials: k× fewer bytes in theory). Measured: coll
  39.7 s (±3%) — REFUTED in practice: the psum payload shrank but GSPMD
  re-materialized the gather elsewhere; partial-manual shard_map also hit
  an XLA:CPU crash (worked around with full-manual). Lesson + next step:
  needs per-collective HLO attribution inside the loop and an explicit
  ppermute all-to-all EP; kept behind `moe_local_combine` flag.
- Cell result: compute-side −14% (cf=1.0); collective floor identified as
  ≈2·tokens·k·d/devices ≈ 15 GB/layer — within ~2× of the all-to-all
  optimum; honest conclusion: GSPMD-level EP at top-8/d=7168 is wire-
  limited, the 2× gap needs manual all-to-all.

### Cell C — hubert-xlarge × prefill_32k (most memory-bound; exercises the
serving path the paper's technique lives on)
Baseline: mem **2.11 s** vs comp 0.22 — the jnp blocked attention
materializes (Tq×block) scores and carries the f32 accumulator through
HBM every KV block (65 GB/device/layer of pure overhead traffic).
- **it1 — fused Pallas flash-attention kernel** (`pallas_flash=true`;
  kernels/flash_attention.py: online softmax fully VMEM-resident,
  shard_map over batch×kv-heads, validated vs the jnp oracle to 6e-7).
  Napkin: HBM traffic collapses to Q/K/V/O ≈ 4·B·T·D·2B per layer ⇒
  mem ≈ 0.03 s. Measured: **mem 2.11 → 0.025 s**, bound flips to
  collective (0.32 s). CONFIRMED (dominant term **6.5×**; roofline
  fraction 0.07 → 0.42). The same kernel serves every full-attention
  arch's prefill path (`allow_pallas` in models/api.py).

### Cell D — the paper's technique at scale: distributed filtered ScaNN
serving (10M × 768 store, batch-128 filtered queries, 256 chips)
`python -m repro.launch.fvs_dryrun [--pallas] [--multi-pod]` — the
shard_map'd search step lowered+compiled abstractly like every LM cell.
Baseline: **memory-bound at 12.9 ms/batch (9.9k QPS bound)**; collective
term 3 µs (the k×devices top-k merge all-gather is 160 KB — negligible by
construction, validating DESIGN.md §4). Compute term 9 µs — filtered
ScaNN on TPU is pure bandwidth, the paper's §6.2.3 conclusion amplified.
- **it1 — 4× bigger leaves (2048 rows), 4× fewer searched.** Napkin:
  centroid streaming ∝ num_leaves ⇒ −75% of that share. Measured: only
  −3.5% — REFUTED: centroids are ~4% of traffic; the per-query f32
  dequantized tiles dominate.
- **it2 — fused Pallas leaf-scan kernel in the distributed path**
  (`--pallas`): int8 tiles cross HBM once; dequant+bitmap-probe+score stay
  in VMEM. Napkin: removes the 4×-sized f32 tile copies ⇒ ~1.6×.
  Measured: **12.9 → 7.9 ms (1.62×, 16.1k QPS bound)**. CONFIRMED — the
  paper's "SIMD-friendly sequential leaf scan" advantage, realized as a
  TPU kernel.
- Multi-pod (512 chips): per-device terms unchanged (queries replicated,
  shards halve) — throughput scales linearly with pods for this workload.
- Next step (identified, deferred): scalar-prefetch BlockSpec indexing to
  skip the gather copy of selected leaves (~further 1.5×).

### Beyond-paper extras (baseline-all rule: reported, not hillclimbed)
- **gemma3-12b prefill_32k + windowed kernel** (`windowed_kernel=true`,
  O(T·window) local-attention path for the 5-of-6 local layers):
  comp 0.98 → 0.56 s, mem 2.84 → 0.72 s — dominant 2.84 → 1.10 s (2.6×).
- **rwkv6-3b train_4k chunked** (`rwkv_mode=chunked`, GLA-style): moves
  the recurrence onto MXU matmuls; measured mem 1.88 → 1.78 s (the cost
  model keeps small scan states resident, so this delta is conservative —
  on hardware the token-scan's per-step state round-trip is the known
  killer). Equivalence to the scan recurrence is tested to 7e-7
  (tests/test_models.py).
- **int8 error-feedback gradient compression** (`--grad-compression`):
  4× smaller DP all-reduce payload, convergence verified in
  tests/test_train_and_checkpoint.py.
- Stop criterion: cells B and C reached a different dominant term than
  they started with; cell A recorded three refutations with a quantified
  gap to the wire floor — further GSPMD-level iterations were <5%.
"""

BENCH_SECTION = """
## §Paper benchmarks (the reproduction itself)

`PYTHONPATH=src:. python -m benchmarks.run` executes one module per paper
table/figure at container scale (173 rows, 0 failures; full CSV in
bench_output.txt). Key reproduced findings:

| paper claim | our measurement |
|---|---|
| T6: filter-first does ~100× fewer distance comps at low selectivity, at the cost of ~30× more filter checks | sift10m sel=0.05: acorn dc=1.0K/fc=141K vs sweeping dc=11.1K/fc=4.7K (benchmarks table6/fig9 rows) |
| T6: ScaNN filter checks decrease and distance comps increase with selectivity | openai5m scann: fc 4.1K→1.9K, dc 218→1.6K across sel 0.01→0.8 |
| Fig 9 T1: clustering beats graphs at low-dim; gap narrows at high-dim | scann vs graphs QPS ratio higher on sift10m (128d) than openai5m (768d) |
| Fig 9 T2: filter-first wins at low selectivity, traversal-first at high | modeled-QPS crossover present per dataset (fig9 rows) |
| Fig 10: system overheads dominate CPU cycles | SYSTEM regime: page-access+retrieval ≥70% of modeled cycles for sweeping at 1% sel |
| Fig 11: ScaNN scales leaves with k (+220%); filter-first is robust | leaves 16→64 (4×) at k 5→100; navix hops ×3.2, sweeping ×2.3 |
| Fig 12: negative correlation hurts graphs, ScaNN robust | graph recall/QPS drop at 1% negative; scann QPS ≈ unchanged (fig12 rows) |
| Fig 13: without the Translation Map, metadata fetch ≈60–75% of cycles | tm=off metadata share 0.6–0.75 vs tm=on ~0.2 (fig13 rows) |
| Fig 1: DB-vs-library gap shifts the crossover point | SYSTEM/LIBRARY modeled-QPS rankings differ per selectivity (examples/filtered_search_study.py) |
| T4: HNSW quantization ≈no QPS gain in a page engine | halfvec modeled speedup 1.0–1.1× (table4 row) |
| T3: ScaNN builds ~5–10× faster and smaller than HNSW | sift10m: 2.8 s/7 MB vs 17.6 s/10 MB (table3 rows) |

Known container-scale deviation (documented in DESIGN.md §8): at N≤20k,
the predicate subgraph stops percolating below ~2–3% selectivity, so
filter-first recall collapses at sel=0.01 where the paper (at 5–10M rows)
still reaches 95%. The effect is the same 2-hop-bridging physics the
paper describes — the threshold just shifts with N; sweeping/iterative
scan (and pre-filtering, per the paper's own footnote) cover that regime.

## §Scale-out readiness (1000+ nodes)

- DP×TP×EP(+FSDP/SP) on an explicit (pod, data, model) mesh; all cells
  compile at 512 chips; the pod axis generalizes to more pods (DP only —
  gradient all-reduce crosses DCI once per step).
- Fault tolerance: atomic+async checkpoints, deterministic step-replay
  data, elastic restore-with-reshard, straggler deadline hook, int8 EF
  gradient compression — all tested (tests/test_train_and_checkpoint.py).
- Serving: batched prefill/decode engines per arch; distributed filtered
  retrieval (shard_map leaf scan + tiny top-k all-gather) as the
  first-class paper feature (examples/rag_serving.py).
"""

if __name__ == "__main__":
    sys.exit(main())
