"""Benchmark drift report: tracked BENCH_*.json vs fresh *.tiny.json.

The tracked records are full-grid runs committed with the PR that changed
the perf story; the .tiny.json twins are what CI (tools/smoke.sh) just
measured on the same machine.  This tool pairs them up, aligns grid
points by their identifying fields (sel / corr / workload / name — NOT
list position, since tiny grids are subsets), and prints a one-screen
table of the numeric drift so a regression shows up in the CI log the
run it lands, instead of the PR that happens to re-run the full bench.

Non-gating by design: tiny runs are noisy (16 queries, cold jit, shared
CI box), so this report informs, never fails the build.  tools/ci.sh
invokes it after the smoke benchmarks with `|| true`.

    PYTHONPATH=src python tools/bench_report.py [--threshold 0.25] [--all]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

REPO = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
# grid-point fields that identify a row (used for keys, never diffed)
ID_KEYS = ("sel", "corr", "workload", "name", "method", "bench", "dataset",
           "quant", "shards", "policy", "capacity_frac", "fault", "tier")
# run-scale knobs: a tiny twin legitimately runs a smaller config, so
# these are reported as a header note, never as metric drift
CONFIG_KEYS = ("n", "dim", "queries", "tiny", "delta_capacity", "k",
               "fill", "n_delta", "seed")


def _flat(obj, path=""):
    """Flatten to {path: leaf}. List elements key by their ID fields when
    they have any (stable across grid subsets), else by index."""
    out = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            out.update(_flat(v, f"{path}.{k}" if path else str(k)))
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            if isinstance(v, dict):
                ids = [f"{k}={v[k]}" for k in ID_KEYS if k in v]
                tag = ",".join(ids) if ids else str(i)
            else:
                tag = str(i)
            out.update(_flat(v, f"{path}[{tag}]"))
    else:
        out[path] = obj
    return out


def _leaf(path: str) -> str:
    return path.rsplit(".", 1)[-1].split("[")[0]


def diff_pair(tracked: dict, fresh: dict, threshold: float):
    """(rows, config_notes): metric drift rows of (path, tracked, fresh,
    rel_delta) over the common paths, plus the differing run-scale knobs
    (expected for a tiny twin, reported but not counted as drift)."""
    ft, ff = _flat(tracked), _flat(fresh)
    rows, config = [], []
    for path in sorted(set(ft) & set(ff)):
        if _leaf(path) in ID_KEYS:
            continue
        if _leaf(path) in CONFIG_KEYS:
            if ft[path] != ff[path]:
                config.append(f"{path} {ft[path]}->{ff[path]}")
            continue
        a, b = ft[path], ff[path]
        if isinstance(a, bool) or isinstance(b, bool):
            if a != b:
                rows.append((path, a, b, float("inf")))
            continue
        if not (isinstance(a, (int, float)) and isinstance(b, (int, float))):
            if a != b:
                rows.append((path, a, b, float("inf")))
            continue
        denom = max(abs(a), abs(b), 1e-12)
        rel = abs(a - b) / denom
        if rel >= threshold:
            rows.append((path, a, b, rel))
    return rows, config


def pairs():
    for tiny in sorted(glob.glob(os.path.join(REPO, "BENCH_*.tiny.json"))):
        tracked = tiny.replace(".tiny.json", ".json")
        if os.path.exists(tracked):
            yield tracked, tiny


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="relative drift below this is noise (default .25)")
    ap.add_argument("--all", action="store_true",
                    help="print every drifting path, not the top 20")
    args = ap.parse_args()
    any_pair = False
    for tracked, tiny in pairs():
        any_pair = True
        name = os.path.basename(tracked)
        with open(tracked) as f:
            t = json.load(f)
        with open(tiny) as f:
            n = json.load(f)
        rows, config = diff_pair(t, n, args.threshold)
        flips = [r for r in rows if isinstance(r[1], bool) or r[3] == float(
            "inf")]
        drift = sorted((r for r in rows if r not in flips),
                       key=lambda r: -r[3])
        if not args.all:
            drift = drift[:20]
        status = "FLIP" if flips else ("drift" if drift else "ok")
        print(f"== {name} vs {os.path.basename(tiny)}: {status} "
              f"({len(rows)} paths past {args.threshold:.0%})")
        if config:
            print(f"   (scaled-down twin: {'; '.join(config[:6])}"
                  f"{' ...' if len(config) > 6 else ''} — scale-driven "
                  "drift below is expected)")
        for path, a, b, rel in flips + drift:
            d = "flip" if rel == float("inf") else f"{rel:+.0%}"
            print(f"   {path:<68} {a!r:>12} -> {b!r:<12} {d}")
    if not any_pair:
        print("bench_report: no BENCH_*.tiny.json twins found — run "
              "tools/smoke.sh first")


if __name__ == "__main__":
    main()
