#!/usr/bin/env bash
# CI smoke: tier-1 test suite + the perf/planner/storage microbenchmarks.
# Each benchmark emits one JSON record (BENCH_leaf_scan.json /
# BENCH_frontier.json / BENCH_planner.json / BENCH_storage.json /
# BENCH_graph_quant.json) so the perf trajectory gets populated
# run-over-run;
# benchmarks run even when tier-1 fails, but the tier-1 status is
# propagated.  SMOKE_SKIP_TESTS=1 skips the pytest phase (tools/ci.sh runs
# the full suite itself first).
set -uo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

tier1=0
if [ "${SMOKE_SKIP_TESTS:-0}" != "1" ]; then
    python -m pytest -x -q
    tier1=$?
    if [ "$tier1" -ne 0 ]; then
        # -x died early in some unrelated file: still report whether the
        # executor/planner tests themselves are green
        python -m pytest -q tests/test_executor.py
    fi
fi

python benchmarks/bench_leaf_scan.py || exit 1
python benchmarks/bench_frontier.py --tiny || exit 1
python benchmarks/fig_planner.py --tiny || exit 1
python benchmarks/bench_storage.py --tiny || exit 1
python benchmarks/bench_graph_quant.py --tiny || exit 1

exit "$tier1"
