#!/usr/bin/env bash
# CI smoke: tier-1 test suite + the leaf-scan microbenchmark.
# The microbenchmark emits one JSON line (also written to
# BENCH_leaf_scan.json) so the perf trajectory gets populated run-over-run;
# it runs even when tier-1 fails, but the tier-1 status is propagated.
set -uo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q
tier1=$?

python benchmarks/bench_leaf_scan.py || exit 1

exit "$tier1"
