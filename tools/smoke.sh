#!/usr/bin/env bash
# CI smoke: tier-1 test suite + the perf/planner/storage microbenchmarks.
# Each benchmark emits one JSON record (BENCH_leaf_scan.json /
# BENCH_frontier.json / BENCH_planner.json / BENCH_storage.json /
# BENCH_graph_quant.json / BENCH_robustness.tiny.json /
# BENCH_mutability.tiny.json / BENCH_sharding.tiny.json) so the perf
# trajectory gets populated
# run-over-run;
# benchmarks run even when tier-1 fails, but the tier-1 status is
# propagated.  SMOKE_SKIP_TESTS=1 skips the pytest phase (tools/ci.sh runs
# the full suite itself first).
set -uo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# hang protection: per-test --timeout needs the optional pytest-timeout
# plugin (requirements-dev.txt); without it, fall back to pytest's
# built-in faulthandler, which dumps stacks after the same budget but
# does not kill the test
PYTEST_GUARD=(-o faulthandler_timeout=600)
if python -c "import pytest_timeout" 2>/dev/null; then
    PYTEST_GUARD+=(--timeout=600 --timeout-method=thread)
fi

tier1=0
if [ "${SMOKE_SKIP_TESTS:-0}" != "1" ]; then
    python -m pytest -x -q "${PYTEST_GUARD[@]}"
    tier1=$?
    if [ "$tier1" -ne 0 ]; then
        # -x died early in some unrelated file: still report whether the
        # executor/planner tests themselves are green
        python -m pytest -q "${PYTEST_GUARD[@]}" tests/test_executor.py
    fi
fi

python benchmarks/bench_leaf_scan.py || exit 1
python benchmarks/bench_frontier.py --tiny || exit 1
python benchmarks/fig_planner.py --tiny || exit 1
python benchmarks/bench_storage.py --tiny || exit 1
python benchmarks/bench_graph_quant.py --tiny || exit 1
python benchmarks/bench_robustness.py --tiny || exit 1
python benchmarks/bench_serving.py --tiny || exit 1
python benchmarks/bench_mutability.py --tiny || exit 1
python benchmarks/bench_sharding.py --tiny || exit 1
python benchmarks/bench_filtercost.py --tiny || exit 1

exit "$tier1"
