#!/usr/bin/env bash
# One-command CI: the full tier-1 pytest suite, then the smoke benchmarks
# (which skip their own pytest phase — SMOKE_SKIP_TESTS — so tests run
# exactly once).  Exit status: tests win; benchmark failures also fail.
set -uo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# hang protection: pass --timeout only when the optional pytest-timeout
# plugin is installed; the built-in faulthandler dump needs no plugin
PYTEST_GUARD=(-o faulthandler_timeout=600)
if python -c "import pytest_timeout" 2>/dev/null; then
    PYTEST_GUARD+=(--timeout=600 --timeout-method=thread)
fi

python -m pytest -q "${PYTEST_GUARD[@]}"
tier1=$?

SMOKE_SKIP_TESTS=1 tools/smoke.sh || exit 1

# non-gating drift report: tracked full-grid records vs the tiny twins
# the smoke run just produced (tiny noise must never fail the build)
python tools/bench_report.py || true

exit "$tier1"
