#!/usr/bin/env bash
# One-command CI: the full tier-1 pytest suite, then the smoke benchmarks
# (which skip their own pytest phase — SMOKE_SKIP_TESTS — so tests run
# exactly once).  Exit status: tests win; benchmark failures also fail.
set -uo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -q
tier1=$?

SMOKE_SKIP_TESTS=1 tools/smoke.sh || exit 1

exit "$tier1"
