"""End-to-end training driver: a ~20M-param llama-family model for a few
hundred steps on CPU, with checkpointing, deterministic data, and resume.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]

(on a real slice: `python -m repro.launch.train --arch llama3.2-3b --full
--mesh single` runs the assigned config on the production mesh.)
"""
import argparse
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses
import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.data.pipeline import DataConfig, batch_for_step
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.train.loop import TrainConfig, Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    cfg = dataclasses.replace(smoke_config("llama3.2-3b"),
                              d_model=256, n_layers=6, d_ff=1024,
                              vocab=2048, n_heads=8, n_kv=4, d_head=32)
    bundle = build_model(cfg)
    dcfg = DataConfig(cfg.vocab, seq_len=256, global_batch=8)

    def batch_fn(step):
        return {k: jnp.asarray(v) for k, v in batch_for_step(dcfg,
                                                             step).items()}

    ckpt = tempfile.mkdtemp(prefix="vexa_ckpt_")
    tc = TrainConfig(steps=args.steps, checkpoint_every=args.steps // 4,
                     checkpoint_dir=ckpt, log_every=20)
    opt = AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    trainer = Trainer(bundle, opt, tc, batch_fn)
    params, opt_state, start = trainer.init_or_restore(jax.random.PRNGKey(0))
    n = sum(int(x.size) for x in jax.tree.leaves(params))
    print(f"params: {n/1e6:.1f}M  steps: {args.steps}  ckpt: {ckpt}")
    t0 = time.time()
    trainer.run(params, opt_state, start)
    dt = time.time() - t0
    for h in trainer.history:
        print(f"  step {h['step']:4d}  loss {h['loss']:.4f}  "
              f"gnorm {h['grad_norm']:.2f}")
    toks = args.steps * dcfg.global_batch * dcfg.seq_len
    print(f"throughput: {toks/dt:,.0f} tok/s  "
          f"(loss {trainer.history[0]['loss']:.3f} -> "
          f"{trainer.history[-1]['loss']:.3f})")


if __name__ == "__main__":
    main()
