"""Retrieval-augmented serving: the paper's FVS inside a serving stack.

A llama-family LM is paired with a sharded filtered vector store; each
request carries a structured predicate (simulated as a bitmap), retrieval
runs the filtered ScaNN search across the device mesh, and the retrieved
document chunks are spliced into the prompt before generation — the
paper's introduction e-commerce query, end to end.

    PYTHONPATH=src python examples/rag_serving.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.core import SearchParams, WorkloadSpec, generate_bitmaps
from repro.core.distributed import (DistributedScannExecutor,
                                    build_sharded_scann)
from repro.data import DatasetSpec, make_dataset
from repro.launch.mesh import make_mesh
from repro.models import build_model
from repro.serving import RetrievalAugmentedServer, ServeEngine


def main() -> None:
    cfg = smoke_config("llama3.2-3b")
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)

    # document store: 4096 chunks with embeddings + token payloads
    spec = DatasetSpec("docs", 4096, 64, "l2", clusters=16)
    store, _ = make_dataset(spec, num_queries=1)
    docs = rng.randint(0, cfg.vocab, (4096, 8)).astype(np.int32)
    mesh = make_mesh((jax.device_count(),), ("data",))
    sharded = build_sharded_scann(store, mesh, "data", num_leaves=64,
                                  levels=1)
    server = RetrievalAugmentedServer(
        bundle, params, DistributedScannExecutor(sharded),
        SearchParams(k=4, num_leaves_to_search=32), docs, chunk_len=8)

    # two requests with different predicates (20% vs 5% selectivity)
    prompts = rng.randint(0, cfg.vocab, (2, 16)).astype(np.int32)
    q_embed = jnp.asarray(rng.randn(2, 64).astype(np.float32))
    bm = jnp.concatenate([
        generate_bitmaps(store, q_embed[:1], WorkloadSpec(0.2, "none"), 1),
        generate_bitmaps(store, q_embed[1:], WorkloadSpec(0.05, "none"), 2),
    ])
    res = server.retrieve(prompts, bm)
    print("retrieved ids per request (filtered):", res.ids.tolist())
    print("augmented prompt length:", res.tokens.shape[1])

    engine = ServeEngine(bundle, params, max_seq=res.tokens.shape[1] + 16,
                         batch_size=2)
    out = engine.generate(res.tokens, max_new_tokens=12)
    print("generated token ids:", out.tolist())
    print(f"decode throughput: {engine.stats.decoded_tokens} tokens")


if __name__ == "__main__":
    main()
