"""Quickstart: filtered vector search, five strategies, one page.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (SYSTEM, SearchParams, WorkloadSpec, build_graph,
                        build_scann, cycle_breakdown, filtered_knn,
                        generate_bitmaps, make_executor, recall_at_k,
                        stats_table_row)
from repro.data import DatasetSpec, make_dataset


def main() -> None:
    print("== 1. dataset (clustered, Table-2-shaped) ==")
    spec = DatasetSpec("quickstart", 10_000, 96, "l2", clusters=32)
    store, queries = make_dataset(spec, num_queries=8)
    queries = jnp.asarray(queries)
    print(f"   {store.n} vectors, d={store.dim}, {queries.shape[0]} queries")

    print("== 2. indexes ==")
    graph = build_graph(store, m=16, ef_construction=64, seed=0)
    scann = build_scann(store, num_leaves=96, levels=2, seed=0)
    print(f"   HNSW: {graph.num_levels} levels | ScaNN: "
          f"{scann.num_leaves} leaves")

    print("== 3. workload: 10% selectivity, medium positive correlation ==")
    ws = WorkloadSpec(selectivity=0.10, correlation="med_pos")
    bitmaps = generate_bitmaps(store, queries, ws, seed=1)
    _, true_ids = filtered_knn(store, queries, bitmaps, 10)

    print("== 4. strategies behind the one executor API ==")
    p = SearchParams(k=10, ef_search=96, beam_width=512, max_hops=2048,
                     num_leaves_to_search=24, reorder_factor=4)
    print(f"   {'method':16s} {'recall':>6s} {'dist':>7s} {'filter':>8s} "
          f"{'hops':>6s} {'pages':>7s} {'Mcycles':>8s}")
    for method in ("sweeping", "acorn", "navix", "iterative_scan", "scann",
                   "bruteforce"):
        ex = make_executor(method, store, graph=graph, index=scann)
        res = ex.search(queries, bitmaps, p)
        rec = float(np.mean(np.asarray(jax.vmap(
            lambda f, t: recall_at_k(f, t, 10))(res.ids, true_ids))))
        row = stats_table_row(res.stats)
        cyc = cycle_breakdown(res.stats, store.dim, SYSTEM)["total"] / 1e6
        print(f"   {method:16s} {rec:6.3f} {row['distance_comps']:7.0f} "
              f"{row['filter_checks']:8.0f} {row['hops']:6.0f} "
          f"{row['page_accesses_index']+row['page_accesses_heap']:7.0f}"
              f" {cyc:8.2f}")
    print("\nNote the paper's Table-6 pattern: filter-first (acorn/navix) "
          "trades filter checks for distance computations; ScaNN batches "
          "both.")

    print("== 5. the system-aware adaptive planner ==")
    planner = make_executor("adaptive", store, graph=graph, index=scann)
    for sel in (0.01, 0.10, 0.8):
        bm = generate_bitmaps(store, queries, WorkloadSpec(sel, "none"),
                              seed=2)
        res = planner.search(queries, bm, p)
        preds = {m: round(c / 1e6, 2)
                 for m, c in res.plan.predicted_cycles.items()}
        print(f"   sel={sel:<5} -> chose {res.strategy:15s} "
              f"(predicted Mcycles: {preds})")
    print("\nThe planner picks the cheapest recall-feasible strategy per "
          "batch from bitmap popcounts + a leaf-probe correlation proxy "
          "(DESIGN.md \u00a76).")


if __name__ == "__main__":
    main()
