"""End-to-end mini-reproduction of the paper's core experiment: the
selectivity × correlation grid on one dataset, all methods, with the
system-tax cost model — a small Fig. 9 + Fig. 12 in one run.

    PYTHONPATH=src python examples/filtered_search_study.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (LIBRARY, SYSTEM, SearchParams, WorkloadSpec,
                        build_graph, build_scann, cycle_breakdown,
                        filtered_knn, generate_bitmaps, make_executor,
                        modeled_qps, recall_at_k)
from repro.data import DatasetSpec, make_dataset

SELS = (0.05, 0.2, 0.5)
CORRS = ("high_pos", "none", "negative")
METHODS = ("navix", "sweeping", "iterative_scan", "scann", "adaptive")


def main() -> None:
    spec = DatasetSpec("study", 12_000, 128, "l2", clusters=48)
    store, queries = make_dataset(spec, num_queries=8)
    queries = jnp.asarray(queries)
    graph = build_graph(store, m=16, ef_construction=64, seed=0)
    scann = build_scann(store, num_leaves=96, levels=2, seed=0)

    print(f"{'corr':9s} {'sel':>5s} {'method':15s} {'recall':>6s} "
          f"{'sysQPS':>8s} {'libQPS':>8s}")
    for corr in CORRS:
        for sel in SELS:
            bm = generate_bitmaps(store, queries,
                                  WorkloadSpec(sel, corr), seed=7)
            _, tid = filtered_knn(store, queries, bm, 10)
            p = SearchParams(k=10, ef_search=96, beam_width=512,
                             max_hops=2048, num_leaves_to_search=24)
            for m in METHODS:
                ex = make_executor(m, store, graph=graph, index=scann)
                res = ex.search(queries, bm, p)
                rec = float(np.mean(np.asarray(jax.vmap(
                    lambda f, t: recall_at_k(f, t, 10))(res.ids, tid))))
                qs = modeled_qps(res.stats, store.dim, SYSTEM)
                ql = modeled_qps(res.stats, store.dim, LIBRARY)
                tag = m if m != "adaptive" else f"adaptive>{res.strategy}"
                print(f"{corr:9s} {sel:5.2f} {tag:15s} {rec:6.3f} "
                      f"{qs:8.0f} {ql:8.0f}")
    print("\nThe SYSTEM/LIBRARY QPS columns reproduce Fig. 1's point: the "
          "method ranking differs between the two regimes.")


if __name__ == "__main__":
    main()
