"""Deterministic, stateless synthetic token pipeline.

`batch_for_step(cfg, step)` is a pure function of (config, step) — that is
the whole fault-tolerance story for data: on restart/elastic re-mesh the
loop replays exactly, with no iterator state to checkpoint (DESIGN.md §6).
Each host materializes only its shard of the global batch.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0


def make_data_config(vocab_size: int, seq_len: int, global_batch: int,
                     seed: int = 0) -> DataConfig:
    return DataConfig(vocab_size, seq_len, global_batch, seed)


def token_batch_specs(cfg: DataConfig) -> dict[str, jax.ShapeDtypeStruct]:
    shape = (cfg.global_batch, cfg.seq_len)
    return {
        "tokens": jax.ShapeDtypeStruct(shape, jnp.int32),
        "targets": jax.ShapeDtypeStruct(shape, jnp.int32),
        "mask": jax.ShapeDtypeStruct(shape, jnp.float32),
    }


def batch_for_step(cfg: DataConfig, step: int,
                   shard: tuple[int, int] = (0, 1)) -> dict[str, np.ndarray]:
    """Pure (config, step, shard) -> batch. shard = (index, count)."""
    idx, count = shard
    assert cfg.global_batch % count == 0
    local = cfg.global_batch // count
    rng = np.random.RandomState((cfg.seed * 1_000_003 + step) % (2 ** 31))
    toks = rng.randint(0, cfg.vocab_size,
                       (cfg.global_batch, cfg.seq_len + 1), dtype=np.int64)
    # Markov-ish structure so the loss is learnable, not pure noise:
    toks[:, 1:] = (toks[:, :-1] * 31 + toks[:, 1:] % 17) % cfg.vocab_size
    sl = slice(idx * local, (idx + 1) * local)
    return {
        "tokens": toks[sl, :-1].astype(np.int32),
        "targets": toks[sl, 1:].astype(np.int32),
        "mask": np.ones((local, cfg.seq_len), np.float32),
    }
