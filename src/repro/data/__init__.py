from repro.data.datasets import DatasetSpec, PAPER_DATASETS, make_dataset
from repro.data.pipeline import (DataConfig, batch_for_step, make_data_config,
                                 token_batch_specs)

__all__ = ["DatasetSpec", "PAPER_DATASETS", "make_dataset", "DataConfig",
           "batch_for_step", "make_data_config", "token_batch_specs"]
