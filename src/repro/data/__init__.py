from repro.data.datasets import (DatasetSpec, PAPER_DATASETS, STREAM_BLOCK,
                                 make_dataset, make_dataset_streamed)
from repro.data.pipeline import (DataConfig, batch_for_step, make_data_config,
                                 token_batch_specs)

__all__ = ["DatasetSpec", "PAPER_DATASETS", "STREAM_BLOCK", "make_dataset",
           "make_dataset_streamed", "DataConfig", "batch_for_step",
           "make_data_config", "token_batch_specs"]
