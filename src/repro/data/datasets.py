"""Synthetic vector datasets shaped like the paper's Table 2.

SIFT/OpenAI/Cohere/Text2Image embeddings are not available offline, so we
synthesize clustered Gaussian mixtures with matched *shape* parameters:
dimensionality, metric, and query hardness (in-distribution queries drawn
near clusters; OOD queries planted away from all clusters to mimic
text2image10M's out-of-distribution queries, paper §5 Datasets).
Scale defaults are container-sized; the generators stream in blocks so
larger N is only a time cost.
"""
from __future__ import annotations

import dataclasses
import os

import numpy as np

from repro.core.types import VectorStore


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    n: int
    dim: int
    metric: str
    clusters: int = 64
    ood_queries: bool = False       # text2image-style OOD query hardness
    cluster_spread: float = 0.8     # intra-cluster std (unit-norm centers ≈
    #                                 √2 apart): 0.8 overlaps clusters enough
    #                                 for a connected navigable graph, like
    #                                 real embedding manifolds


# Container-scale stand-ins for the paper's Table 2 rows.
PAPER_DATASETS = {
    "sift10m": DatasetSpec("sift10m", 50_000, 128, "l2", clusters=128),
    "openai5m": DatasetSpec("openai5m", 25_000, 1536, "ip", clusters=64),
    "cohere10m": DatasetSpec("cohere10m", 50_000, 768, "l2", clusters=96),
    "text2image10m": DatasetSpec("text2image10m", 50_000, 200, "l2",
                                 clusters=128, ood_queries=True),
}


def make_dataset(spec: DatasetSpec, num_queries: int = 100, seed: int = 0
                 ) -> tuple[VectorStore, np.ndarray]:
    """Returns (store, queries (num_queries, dim) float32)."""
    rng = np.random.RandomState(seed)
    centers = rng.randn(spec.clusters, spec.dim).astype(np.float32)
    centers /= np.linalg.norm(centers, axis=1, keepdims=True)
    assign = rng.randint(0, spec.clusters, spec.n)
    x = centers[assign] + spec.cluster_spread * rng.randn(
        spec.n, spec.dim).astype(np.float32) / np.sqrt(spec.dim)
    if spec.metric == "ip":
        x /= np.linalg.norm(x, axis=1, keepdims=True)

    if spec.ood_queries:
        q = rng.randn(num_queries, spec.dim).astype(np.float32)
        q /= np.linalg.norm(q, axis=1, keepdims=True)
        q *= 1.4  # planted away from the unit-norm cluster shell
    else:
        qa = rng.randint(0, spec.clusters, num_queries)
        q = centers[qa] + spec.cluster_spread * rng.randn(
            num_queries, spec.dim).astype(np.float32) / np.sqrt(spec.dim)
        if spec.metric == "ip":
            q /= np.linalg.norm(q, axis=1, keepdims=True)
    return VectorStore.build(x, metric=spec.metric), q.astype(np.float32)


# ---------------------------------------------------------------------------
# Streamed generation (DESIGN.md §13) — row-block generation + two-pass
# global SQ8, for the ≥5M×768 operating points the sharding bench runs.
# ---------------------------------------------------------------------------

# Default row-block quantum.  block_rows is part of the dataset identity:
# each block b draws from its own counter-based Philox stream keyed
# (seed, b), so the same (spec, seed, block_rows) always regenerates the
# same rows — block by block, with no full-array RNG state to carry —
# while a different block_rows is a different (equally valid) dataset.
STREAM_BLOCK = 65_536


def _stream_centers(spec: DatasetSpec, seed: int) -> np.ndarray:
    # Same first-draws recipe as make_dataset, so query geometry matches.
    rng = np.random.RandomState(seed)
    centers = rng.randn(spec.clusters, spec.dim).astype(np.float32)
    centers /= np.linalg.norm(centers, axis=1, keepdims=True)
    return centers


def _stream_block(spec: DatasetSpec, centers: np.ndarray, seed: int,
                  b: int, lo: int, hi: int,
                  cache_dir: str | None) -> np.ndarray:
    """Rows [lo, hi) of the dataset — regenerated from the (seed, b)
    Philox key, or reloaded from the per-block cache."""
    path = None
    if cache_dir is not None:
        path = os.path.join(
            cache_dir, f"{spec.name}-n{spec.n}-d{spec.dim}"
            f"-c{spec.clusters}-sp{spec.cluster_spread}-s{seed}-b{b}.npy")
        if os.path.exists(path):
            return np.load(path)
    rng = np.random.Generator(
        np.random.Philox(key=[np.uint64(seed), np.uint64(b)]))
    m = hi - lo
    assign = rng.integers(0, spec.clusters, m)
    x = centers[assign] + spec.cluster_spread * rng.standard_normal(
        (m, spec.dim), dtype=np.float32) / np.sqrt(spec.dim)
    if spec.metric == "ip":
        x /= np.linalg.norm(x, axis=1, keepdims=True)
    # the /sqrt(dim) promotes to f64; cast at the block boundary so every
    # consumer (f32 heap, two-pass quantizer, direct block reads) sees
    # the same float32 bits
    x = x.astype(np.float32)
    if path is not None:
        os.makedirs(cache_dir, exist_ok=True)
        np.save(path, x)
    return x


def make_dataset_streamed(spec: DatasetSpec, num_queries: int = 100,
                          seed: int = 0, block_rows: int = STREAM_BLOCK,
                          f32: bool = True, quantize: bool = True,
                          cache_dir: str | None = None
                          ) -> tuple[VectorStore, np.ndarray]:
    """Block-streamed twin of `make_dataset` for giant N.

    Rows generate (and optionally disk-cache) in `block_rows` blocks;
    quantization is the exact two-pass global per-dim SQ8 of
    `types.sq8_quantize` — pass 1 accumulates the per-dimension lo/hi
    over blocks (min/max compose exactly over any blocking), pass 2
    re-streams each block through the same affine clip/round and the
    same dequantized-norm arithmetic, so the shadow tier is bit-equal to
    quantizing the materialized array.

    `f32=False` never materializes the (n, d) float32 heap: the returned
    store's `vectors`/`norms_sq` are zero-strided all-zero PLACEHOLDERS
    (shape-only, a few KB) and only the int8 shadow (+ norms) is real.
    Such a store is valid for geometry (`n`/`dim`), page layouts, and
    SQ8-only sharded traversal (`ShardedGraphExecutor(..., f32=False)`
    with graph_quant="sq8", sq8_rerank=False); feeding it to a
    full-precision path would silently score zeros — don't.
    """
    if num_queries > spec.n:
        raise ValueError("more queries than rows")
    centers = _stream_centers(spec, seed)
    nblocks = -(-spec.n // block_rows)
    blocks = [(b, b * block_rows, min((b + 1) * block_rows, spec.n))
              for b in range(nblocks)]

    x_full = np.empty((spec.n, spec.dim), np.float32) if f32 else None
    lo_d = np.full((spec.dim,), np.inf, np.float32)
    hi_d = np.full((spec.dim,), -np.inf, np.float32)
    for b, lo, hi in blocks:
        x = _stream_block(spec, centers, seed, b, lo, hi, cache_dir)
        if quantize:
            np.minimum(lo_d, x.min(0), out=lo_d)
            np.maximum(hi_d, x.max(0), out=hi_d)
        if f32:
            x_full[lo:hi] = x

    if f32:
        store = VectorStore.build(x_full, metric=spec.metric)
    else:
        placeholder = np.broadcast_to(
            np.zeros((1, spec.dim), np.float32), (spec.n, spec.dim))
        store = VectorStore(
            vectors=placeholder,
            norms_sq=np.broadcast_to(np.zeros((1,), np.float32),
                                     (spec.n,)),
            metric=spec.metric)

    if quantize:
        import jax.numpy as jnp
        scale = np.maximum((hi_d - lo_d) / 254.0, 1e-8).astype(np.float32)
        mean = ((hi_d + lo_d) / 2.0).astype(np.float32)
        scale_j, mean_j = jnp.asarray(scale), jnp.asarray(mean)
        q = np.empty((spec.n, spec.dim), np.int8)
        qn = np.empty((spec.n,), np.float32)
        for b, lo, hi in blocks:
            x = x_full[lo:hi] if f32 else _stream_block(
                spec, centers, seed, b, lo, hi, cache_dir)
            qb = np.clip(np.round((x - mean) / scale), -127, 127
                         ).astype(np.int8)
            q[lo:hi] = qb
            deq = jnp.asarray(qb).astype(jnp.float32) * scale_j + mean_j
            qn[lo:hi] = np.asarray(jnp.sum(deq * deq, axis=-1))
        store = dataclasses.replace(
            store, q_vectors=jnp.asarray(q), q_scale=scale_j,
            q_mean=mean_j, q_norms_sq=jnp.asarray(qn))

    # Queries ride their own stream (block id past any data block), same
    # hardness recipe as make_dataset.
    qrng = np.random.Generator(
        np.random.Philox(key=[np.uint64(seed), np.uint64(2**63)]))
    if spec.ood_queries:
        qs = qrng.standard_normal((num_queries, spec.dim),
                                  dtype=np.float32)
        qs /= np.linalg.norm(qs, axis=1, keepdims=True)
        qs *= 1.4
    else:
        qa = qrng.integers(0, spec.clusters, num_queries)
        qs = centers[qa] + spec.cluster_spread * qrng.standard_normal(
            (num_queries, spec.dim), dtype=np.float32) / np.sqrt(spec.dim)
        if spec.metric == "ip":
            qs /= np.linalg.norm(qs, axis=1, keepdims=True)
    return store, qs.astype(np.float32)
