"""Synthetic vector datasets shaped like the paper's Table 2.

SIFT/OpenAI/Cohere/Text2Image embeddings are not available offline, so we
synthesize clustered Gaussian mixtures with matched *shape* parameters:
dimensionality, metric, and query hardness (in-distribution queries drawn
near clusters; OOD queries planted away from all clusters to mimic
text2image10M's out-of-distribution queries, paper §5 Datasets).
Scale defaults are container-sized; the generators stream in blocks so
larger N is only a time cost.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.types import VectorStore


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    n: int
    dim: int
    metric: str
    clusters: int = 64
    ood_queries: bool = False       # text2image-style OOD query hardness
    cluster_spread: float = 0.8     # intra-cluster std (unit-norm centers ≈
    #                                 √2 apart): 0.8 overlaps clusters enough
    #                                 for a connected navigable graph, like
    #                                 real embedding manifolds


# Container-scale stand-ins for the paper's Table 2 rows.
PAPER_DATASETS = {
    "sift10m": DatasetSpec("sift10m", 50_000, 128, "l2", clusters=128),
    "openai5m": DatasetSpec("openai5m", 25_000, 1536, "ip", clusters=64),
    "cohere10m": DatasetSpec("cohere10m", 50_000, 768, "l2", clusters=96),
    "text2image10m": DatasetSpec("text2image10m", 50_000, 200, "l2",
                                 clusters=128, ood_queries=True),
}


def make_dataset(spec: DatasetSpec, num_queries: int = 100, seed: int = 0
                 ) -> tuple[VectorStore, np.ndarray]:
    """Returns (store, queries (num_queries, dim) float32)."""
    rng = np.random.RandomState(seed)
    centers = rng.randn(spec.clusters, spec.dim).astype(np.float32)
    centers /= np.linalg.norm(centers, axis=1, keepdims=True)
    assign = rng.randint(0, spec.clusters, spec.n)
    x = centers[assign] + spec.cluster_spread * rng.randn(
        spec.n, spec.dim).astype(np.float32) / np.sqrt(spec.dim)
    if spec.metric == "ip":
        x /= np.linalg.norm(x, axis=1, keepdims=True)

    if spec.ood_queries:
        q = rng.randn(num_queries, spec.dim).astype(np.float32)
        q /= np.linalg.norm(q, axis=1, keepdims=True)
        q *= 1.4  # planted away from the unit-norm cluster shell
    else:
        qa = rng.randint(0, spec.clusters, num_queries)
        q = centers[qa] + spec.cluster_spread * rng.randn(
            num_queries, spec.dim).astype(np.float32) / np.sqrt(spec.dim)
        if spec.metric == "ip":
            q /= np.linalg.norm(q, axis=1, keepdims=True)
    return VectorStore.build(x, metric=spec.metric), q.astype(np.float32)
