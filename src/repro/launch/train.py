"""Training launcher: `python -m repro.launch.train --arch <id> [...]`.

Container-scale by default (reduced config, CPU). On a real slice, pass
--full to use the exact assigned config and --mesh to pick the production
mesh; params/optimizer are sharded by the partition rules, the data
pipeline is deterministic-by-step, and checkpoints are preemption-safe —
the same invocation resumes after a failure (optionally on a different
device count: elastic restore reshards).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax

from repro import compat
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.data.pipeline import DataConfig, batch_for_step
from repro.launch.mesh import make_mesh, make_production_mesh
from repro.launch.sharding import batch_specs, opt_specs, param_specs, \
    to_named
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.train.loop import TrainConfig, Trainer, init_opt_state
from repro.launch.specs import make_smoke_batch


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--full", action="store_true",
                    help="use the full assigned config (needs a real slice)")
    ap.add_argument("--mesh", default=None,
                    help="'single'|'multi' production mesh, default unsharded")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else smoke_config(args.arch)
    bundle = build_model(cfg)
    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=max(10, args.steps // 20))
    tc = TrainConfig(steps=args.steps, microbatches=args.microbatches,
                     checkpoint_dir=args.checkpoint_dir,
                     checkpoint_every=max(20, args.steps // 5),
                     grad_compression=args.grad_compression)

    if cfg.family == "encoder":
        def batch_fn(step):
            return make_smoke_batch(cfg, args.batch, args.seq, "train",
                                    seed=step)
    else:
        dcfg = DataConfig(cfg.vocab, args.seq, args.batch)

        def batch_fn(step):
            raw = batch_for_step(dcfg, step)
            b = {k: jnp.asarray(v) for k, v in raw.items()}
            if cfg.family == "vlm":
                rngp = np.random.RandomState(step)
                b["patch_embeds"] = jnp.asarray(rngp.randn(
                    args.batch, cfg.num_patches, cfg.d_model
                ).astype(np.float32) * 0.02)
            return b

    ctx = None
    if args.mesh:
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")
        ctx = compat.set_mesh(mesh)
        ctx.__enter__()

    trainer = Trainer(bundle, opt_cfg, tc, batch_fn)
    params, opt_state, start = trainer.init_or_restore(jax.random.PRNGKey(0))
    print(f"arch={cfg.name} params={sum(np.prod(p.shape) for p in jax.tree.leaves(params)):,}")
    t0 = time.time()
    params, opt_state = trainer.run(params, opt_state, start)
    dt = time.time() - t0
    for h in trainer.history:
        print(f"step {h['step']:5d} loss {h['loss']:.4f} "
              f"gnorm {h['grad_norm']:.3f} {h['sec']*1e3:.0f}ms")
    toks = args.steps * args.batch * args.seq
    print(f"done: {args.steps} steps, {toks/dt:.0f} tok/s, "
          f"stragglers={len(trainer.stragglers)}")
    if ctx:
        ctx.__exit__(None, None, None)


if __name__ == "__main__":
    main()
