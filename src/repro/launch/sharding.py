"""Parameter/activation partition rules (DP / TP / EP / FSDP / SP).

Rules are (path-regex → PartitionSpec template) pairs; templates name the
TRAILING dims of a leaf (scan/stack dims are left-padded with None).  With
`cfg.fsdp` the weights additionally shard over the data axis (ZeRO-style —
optimizer state inherits the same specs, so m/v are fully sharded).

GQA caveat: kv-head counts (often 8) don't divide the 16-way model axis;
kv projections/caches stay replicated across `model` (Megatron GQA-TP
semantics) while q/o shard.  GSPMD handles the one uneven case
(llama3.2-3b's 24 heads) by padding.
"""
from __future__ import annotations

import re
from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig


def _rules(cfg: ArchConfig, data_axis, model_axis) -> list[tuple[str, P]]:
    if cfg.sharding_scheme == "sp":
        # sequence-parallel activations: weights FSDP over data, no TP dims
        d, m = data_axis, None
    else:
        d = data_axis if cfg.fsdp else None
        m = model_axis
    return [
        # embeddings / head
        (r"embed.*\btok\b", P(m, d)),
        (r"embed.*unembed", P(d, m)),
        (r"\bhead\b", P(d, None)),
        (r"adapter", P(d, None)),
        # attention
        (r"attn.*\bwq\b|shared_attn.*\bwq\b", P(d, m)),
        (r"attn.*\bwk\b|shared_attn.*\bwk\b", P(d, None)),
        (r"attn.*\bwv\b|shared_attn.*\bwv\b", P(d, None)),
        (r"attn.*\bwo\b|shared_attn.*\bwo\b", P(m, d)),
        # MoE (leading E dim shards over model = EP)
        (r"ffn.*router", P(None, None)),
        (r"ffn.*\bwg\b|ffn.*\bwu\b", _moe_spec(cfg, m, d, up=True)),
        (r"ffn.*\bwd\b", _moe_spec(cfg, m, d, up=False)),
        # dense MLP / rwkv cmix / shared mlp
        (r"(mlp|cmix).*\bwk\b", P(d, m)),
        (r"(mlp|cmix).*\bwv\b", P(m, d)),
        (r"cmix.*\bwr\b", P(d, None)),
        # rwkv tmix
        (r"tmix.*\bw[rkvg]\b", P(d, m)),
        (r"tmix.*\bwo\b", P(m, d)),
        (r"tmix.*lora_a", P(d, None)),
        (r"tmix.*wlora_a", P(d, None)),
        # mamba
        (r"in_proj", P(d, m)),
        (r"out_proj", P(m, d)),
        # catch-alls
        (r"norm|mu\b|w0|\bu\b|ln_w|a_log|d_skip|dt_bias|conv|mask_embed"
         r"|lora_b|wlora_b", P()),
    ]


def _moe_spec(cfg: ArchConfig, m, d, up: bool) -> P:
    if cfg.family != "moe":
        return P(d, m) if up else P(m, d)
    # experts always shard over `model` (EP) — including under the SP
    # scheme, where dense weights are FSDP-only (§Perf cell A it3)
    return P("model", d, None) if up else P("model", None, d)


def _dense_fallback(cfg: ArchConfig, ndim: int, data_axis, model_axis) -> P:
    return P(*([None] * ndim))


def fit_spec(spec: P, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Drop axis names whose size doesn't divide the dim (jit in_shardings
    require exact divisibility) or that the mesh doesn't have."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def fit(dim: int, a):
        names = a if isinstance(a, (tuple, list)) else (a,)
        kept = []
        prod = 1
        for n in names:
            if n is None or n not in sizes:
                continue
            if dim % (prod * sizes[n]) == 0:
                kept.append(n)
                prod *= sizes[n]
        if not kept:
            return None
        return tuple(kept) if len(kept) > 1 else kept[0]

    tpl = tuple(spec) + (None,) * (len(shape) - len(tuple(spec)))
    return P(*[fit(d, a) for d, a in zip(shape, tpl)])


def spec_for_path(cfg: ArchConfig, path: str, ndim: int, data_axis,
                  model_axis) -> P:
    for pat, spec in _rules(cfg, data_axis, model_axis):
        if re.search(pat, path):
            tpl = tuple(spec)
            if len(tpl) > ndim:
                tpl = tpl[len(tpl) - ndim:]
            pad = ndim - len(tpl)
            return P(*([None] * pad + list(tpl)))
    return _dense_fallback(cfg, ndim, data_axis, model_axis)


def param_specs(cfg: ArchConfig, params_shape: Any,
                mesh: Optional[Mesh] = None) -> Any:
    """PartitionSpec pytree matching a params (shape) pytree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    out = []
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        spec = spec_for_path(cfg, name, len(leaf.shape), "data", "model")
        if mesh is not None:
            spec = fit_spec(spec, leaf.shape, mesh)
        out.append(spec)
    return jax.tree_util.tree_unflatten(treedef, out)


def opt_specs(cfg: ArchConfig, opt_shape: Any, pspecs: Any,
              mesh: Optional[Mesh] = None) -> Any:
    """Optimizer state: m/v inherit the weight specs; scalars replicate."""
    def build(shape_leafed, like):
        return like

    flat, treedef = jax.tree_util.tree_flatten_with_path(opt_shape)
    out = []
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        if re.search(r"\bstep\b", name):
            out.append(P())
            continue
        # strip the leading ['m']/['v']/['adamw']/['ef_error'] wrappers and
        # look the rest up in the param rules
        stripped = re.sub(r"^\['(adamw|ef_error)'\]", "", name)
        stripped = re.sub(r"^\['(m|v)'\]", "", stripped)
        spec = spec_for_path(cfg, stripped, len(leaf.shape), "data", "model")
        if mesh is not None:
            spec = fit_spec(spec, leaf.shape, mesh)
        out.append(spec)
    return jax.tree_util.tree_unflatten(treedef, out)


def batch_specs(cfg: ArchConfig, batch_shape: Any, mesh: Mesh,
                shard_seq: bool = False) -> Any:
    """Input batch: batch dim over (pod, data); optionally SP on seq."""
    baxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bspec = baxes if baxes else None

    def one(path, leaf):
        ndim = len(leaf.shape)
        if leaf.shape[0] == 1:          # long_500k batch=1: replicate batch
            rest = [None] * (ndim - 1)
            if shard_seq and ndim >= 2:
                rest[0] = "data"
            return P(None, *rest)
        rest = [None] * (ndim - 1)
        if shard_seq and ndim >= 2:
            rest[0] = "model"           # SP: seq over model axis
        return P(bspec, *rest)

    flat, treedef = jax.tree_util.tree_flatten_with_path(batch_shape)
    return jax.tree_util.tree_unflatten(
        treedef, [fit_spec(one(p, l), l.shape, mesh) for p, l in flat])


def cache_specs(cfg: ArchConfig, cache_shape: Any, mesh: Mesh) -> Any:
    """KV caches: (layers/groups..., B, S, kv, hd): batch over (pod,data);
    kv heads replicated (GQA-TP); SSM states batch-sharded."""
    baxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def one(path, leaf):
        shape = leaf.shape
        ndim = len(shape)
        # find the batch dim: first dim whose size matches a known batch is
        # fragile — instead: caches are built as (stack..., B, ...) where
        # the number of leading stack dims is ndim - per-leaf batch rank.
        name = jax.tree_util.keystr(path)
        if re.search(r"attn_k|attn_v|local_k|local_v|global_k|global_v",
                     name):
            # (g[, per], B, S, kv, hd)
            lead = ndim - 4
            spec = [None] * lead + [baxes, None, None, None]
            return P(*spec)
        if re.search(r"\bk\b|\bv\b", name) and ndim == 5:
            return P(None, baxes, None, None, None)
        if re.search(r"wkv", name):      # (L, B, H, C, C)
            return P(None, baxes, "model", None, None)
        if re.search(r"ssm", name):      # (..., B, H, P, N)
            lead = ndim - 4
            return P(*([None] * lead + [baxes, "model", None, None]))
        if re.search(r"x_prev|conv", name):
            lead = ndim - 3
            return P(*([None] * lead + [baxes, None, None]))
        return P(*([None] * ndim))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shape)
    return jax.tree_util.tree_unflatten(
        treedef, [fit_spec(one(p, l), l.shape, mesh) for p, l in flat])


def to_named(specs: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, P))
