"""Serving launcher: `python -m repro.launch.serve --arch <id> [...]`.

Runs the batched serve engine (prefill + decode) on a reduced config, and
with --rag pairs it with the distributed filtered vector store — the
paper's FVS as a first-class serving feature (filtered retrieval with a
per-request predicate bitmap, then generation).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.core import SearchParams, WorkloadSpec, generate_bitmaps
from repro.core.distributed import (DistributedScannExecutor,
                                    build_sharded_scann)
from repro.data import DatasetSpec, make_dataset
from repro.launch.mesh import make_mesh
from repro.models import build_model
from repro.serving import RetrievalAugmentedServer, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--rag", action="store_true")
    ap.add_argument("--selectivity", type=float, default=0.2)
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else smoke_config(args.arch)
    if cfg.family == "encoder":
        raise SystemExit("encoder-only arch has no decode step")
    bundle = build_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    prompts = rng.randint(0, cfg.vocab,
                          (args.batch, args.prompt_len)).astype(np.int32)

    if args.rag:
        spec = DatasetSpec("ragdemo", 4096, 64, "l2", clusters=16)
        store, _ = make_dataset(spec, num_queries=1)
        mesh = make_mesh((jax.device_count(),), ("data",))
        sharded = build_sharded_scann(store, mesh, "data", num_leaves=64,
                                      levels=1)
        executor = DistributedScannExecutor(sharded)
        sp = SearchParams(k=4, num_leaves_to_search=16)
        doc_tokens = rng.randint(0, cfg.vocab, (4096, 8)).astype(np.int32)
        server = RetrievalAugmentedServer(bundle, params, executor, sp,
                                          doc_tokens, chunk_len=8)
        bitmaps = generate_bitmaps(
            store, jnp.asarray(rng.randn(args.batch, 64).astype(np.float32)),
            WorkloadSpec(args.selectivity, "none"))
        res = server.retrieve(prompts, bitmaps)
        print(f"retrieved ids (filtered, sel={args.selectivity}):")
        print(res.ids)
        prompts = res.tokens
        print("augmented prompt len:", prompts.shape[1])

    engine = ServeEngine(bundle, params,
                         max_seq=prompts.shape[1] + args.max_new,
                         batch_size=args.batch)
    t0 = time.time()
    out = engine.generate(prompts, args.max_new)
    dt = time.time() - t0
    print(f"generated {out.shape} tokens in {dt:.1f}s "
          f"({engine.stats.decoded_tokens / dt:.1f} tok/s decode)")
    print(out[:, :16])


if __name__ == "__main__":
    main()
