import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Dry-run of the paper's technique AT SCALE: distributed filtered vector
search over the production mesh (EXPERIMENTS.md §Perf, paper-technique cell).

A 10M-row × 768-d store (the paper's cohere10m scale) is sharded across all
mesh devices (leaves + heap rows local, queries replicated); the jitted
search step is lowered + compiled with ShapeDtypeStructs only, and the
three roofline terms extracted exactly like the LM cells.

  PYTHONPATH=src python -m repro.launch.fvs_dryrun [--multi-pod] \
      [--n 10000000] [--dim 768] [--queries 128] [--leaves-searched 256]
"""
import argparse
import dataclasses
import json
import time

import jax

from repro import compat
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.scann import ScannIndex
from repro.core.types import SearchParams, VectorStore
from repro.core.distributed import DistributedScannExecutor, ShardedFVS
from repro.launch.dryrun import (HBM_BW, ICI_BW, PEAK_FLOPS,
                                 collective_bytes)
from repro.launch.jaxpr_cost import step_cost
from repro.launch.mesh import make_production_mesh


def abstract_sharded_fvs(mesh, n: int, dim: int, leaf_rows: int,
                         axis: str = "data") -> tuple[ShardedFVS, dict]:
    """Build a ShapeDtypeStruct-only ShardedFVS (no allocation)."""
    num_leaves = -(-n // leaf_rows)
    nd = mesh.shape[axis]
    num_leaves += (-num_leaves) % nd
    cap = leaf_rows + (-leaf_rows) % 8
    words = (n + 31) // 32
    sds = jax.ShapeDtypeStruct
    idx = ScannIndex(
        leaf_tiles=sds((num_leaves, cap, dim), jnp.int8),
        leaf_rowids=sds((num_leaves, cap), jnp.int32),
        leaf_centroids=sds((num_leaves, dim), jnp.float32),
        scale=sds((dim,), jnp.float32), mean=sds((dim,), jnp.float32),
        branch_centroids=sds((1, dim), jnp.float32),
        branch_leaves=sds((1, num_leaves), jnp.int32),
        pca=sds((dim + 1, dim), jnp.float32), metric="l2", levels=1)
    store = VectorStore(vectors=sds((n, dim), jnp.float32),
                        norms_sq=sds((n,), jnp.float32), metric="l2")
    shardings = dict(
        leaf_tiles=NamedSharding(mesh, P(axis, None, None)),
        leaf_rowids=NamedSharding(mesh, P(axis, None)),
        leaf_centroids=NamedSharding(mesh, P(axis, None)),
        rep=NamedSharding(mesh, P()),
        vectors=NamedSharding(mesh, P(axis, None)),
        norms=NamedSharding(mesh, P(axis)),
    )
    return ShardedFVS(index=idx, store=store, mesh=mesh, axis=axis), \
        {"words": words}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--n", type=int, default=10_000_000)
    ap.add_argument("--dim", type=int, default=768)
    ap.add_argument("--queries", type=int, default=128)
    ap.add_argument("--leaf-rows", type=int, default=512)
    ap.add_argument("--leaves-searched", type=int, default=256)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--pallas", action="store_true")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    chips = mesh.devices.size
    sharded, meta = abstract_sharded_fvs(mesh, args.n, args.dim,
                                         args.leaf_rows)
    params = SearchParams(k=args.k,
                          num_leaves_to_search=args.leaves_searched,
                          reorder_factor=4)
    executor = DistributedScannExecutor(sharded, use_pallas=args.pallas,
                                        heap_layout="leaf_ordered")
    t0 = time.time()
    with compat.set_mesh(mesh):
        fn = executor.raw_search_fn(params)
        idx, store = sharded.index, sharded.store
        sargs = (idx.leaf_tiles, idx.leaf_rowids, idx.leaf_centroids,
                 idx.scale, idx.mean, idx.pca, store.vectors,
                 store.norms_sq,
                 jax.ShapeDtypeStruct((args.queries, args.dim), jnp.float32),
                 jax.ShapeDtypeStruct((args.queries, meta["words"]),
                                      jnp.uint32))
        axis = sharded.axis
        in_sh = (NamedSharding(mesh, P(axis, None, None)),
                 NamedSharding(mesh, P(axis, None)),
                 NamedSharding(mesh, P(axis, None)),
                 NamedSharding(mesh, P()), NamedSharding(mesh, P()),
                 NamedSharding(mesh, P()),
                 NamedSharding(mesh, P(axis, None)),
                 NamedSharding(mesh, P(axis)),
                 NamedSharding(mesh, P()), NamedSharding(mesh, P()))
        jc = step_cost(fn, *sargs)
        lowered = jax.jit(fn, in_shardings=in_sh).lower(*sargs)
        compiled = lowered.compile()
        hlo = compiled.as_text()
        coll = collective_bytes(hlo, loop_multiplier=1)
        try:
            ma = compiled.memory_analysis()
            mem = {"argument_gb": ma.argument_size_in_bytes / 1e9,
                   "temp_gb": ma.temp_size_in_bytes / 1e9}
        except Exception:
            mem = {}
    flops_dev = jc.flops / chips
    bytes_dev = jc.bytes / chips
    coll_dev = sum(coll.values())
    rec = {
        "cell": "distributed-filtered-scann-serving",
        "mesh": "2x16x16" if args.multi_pod else "16x16", "chips": chips,
        "store": {"n": args.n, "dim": args.dim,
                  "leaves_searched": args.leaves_searched,
                  "batch_queries": args.queries},
        "compile_s": round(time.time() - t0, 1),
        "roofline": {
            "compute_s": flops_dev / PEAK_FLOPS,
            "memory_s": bytes_dev / HBM_BW,
            "collective_s": coll_dev / ICI_BW,
        },
        "collectives": coll, "memory_analysis": mem,
        "queries_per_s_bound": args.queries / max(
            flops_dev / PEAK_FLOPS, bytes_dev / HBM_BW,
            coll_dev / ICI_BW, 1e-12),
    }
    print(json.dumps(rec, indent=1))


if __name__ == "__main__":
    main()
