import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production meshes and extract roofline terms (assignment MULTI-POD DRY-RUN
and ROOFLINE ANALYSIS blocks).

MUST be run as its own process (`python -m repro.launch.dryrun ...`): the
XLA_FLAGS line above executes before any other import so the 512 placeholder
devices exist before jax initializes.  Never import this module from tests
or benchmarks.

Usage:
  python -m repro.launch.dryrun --arch granite-8b --shape train_4k
  python -m repro.launch.dryrun --arch granite-8b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --all            # every live cell, subprocesses
"""
import argparse
import json
import re
import subprocess
import sys
import time

import jax

from repro import compat
import jax.numpy as jnp

from repro.configs import SHAPES, applicable_shapes, get_config
from repro.configs.registry import ARCH_IDS
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import (batch_specs, cache_specs, opt_specs,
                                   param_specs, to_named)
from repro.launch.jaxpr_cost import step_cost
from repro.launch.specs import input_specs
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.train.loop import TrainConfig, init_opt_state, make_train_step

# v5e hardware constants (ROOFLINE ANALYSIS block)
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16, "f8e4m3fn": 1,
                "f8e5m2": 1, "s4": 1, "u4": 1}

_COLL_RE = re.compile(
    r"=\s+(\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY )?%?([\w.\-]+)\s*\(.*\)\s*->")
_WHILE_BODY_RE = re.compile(r"body=%?([\w.\-]+)")


def _shape_bytes(text: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo: str, loop_multiplier: int = 1) -> dict[str, float]:
    """Per-device collective traffic from optimized HLO text, keyed by op.

    Bytes = result-shape bytes of each collective (for `-start` tuples, the
    last tuple element — the destination buffer).  Ops inside `while` bodies
    (the scan-over-layers) are multiplied by `loop_multiplier`, since the
    printed body executes once per layer.  This is exact for all-gather /
    reduce-scatter payloads and within 2× for ring all-reduce (which moves
    ~2·(n−1)/n · bytes); EXPERIMENTS.md states the convention.
    """
    per_comp: dict[str, dict[str, float]] = {}
    while_bodies: set[str] = set()
    comp = "__entry__"
    for line in hlo.splitlines():
        mc = _COMP_RE.match(line.strip())
        if mc and "=" not in line.split("(")[0]:
            comp = mc.group(1)
        for mb in _WHILE_BODY_RE.finditer(line):
            while_bodies.add(mb.group(1))
        m = _COLL_RE.search(line)
        if not m:
            continue
        result, kind = m.group(1), m.group(2)
        if result.startswith("("):
            shapes = _SHAPE_RE.findall(result)
            if shapes:
                dt, dims = shapes[-1]
                result = f"{dt}[{dims}]"
        b = _shape_bytes(result)
        # XLA:CPU promotes bf16 all-reduces to f32 ("*_promo" reducers);
        # on TPU they stay bf16 — count at the unpromoted width.
        if "promo" in line:
            b *= 0.5
        per_comp.setdefault(comp, {}).setdefault(kind, 0.0)
        per_comp[comp][kind] += b
    out: dict[str, float] = {}
    for comp_name, kinds in per_comp.items():
        mult = loop_multiplier if comp_name in while_bodies else 1
        for kind, b in kinds.items():
            out[kind] = out.get(kind, 0.0) + b * mult
    return out


def analytic_model_flops(cfg, shape) -> float:
    """Useful-FLOPs estimate (no remat, no capacity waste): matmul params ×
    6·tokens (train) / 2·tokens (inference) + attention/SSM state terms."""
    tokens = shape.global_batch * (shape.seq_len
                                   if shape.kind != "decode" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    d, hd = cfg.d_model, cfg.head_dim
    # per-token matmul params, non-embedding (embed lookup is a gather)
    if cfg.family == "ssm":
        per_layer = 4 * d * d + 2 * d * cfg.d_ff + d * LORA_FLOPS_DIM
    else:
        attn_p = d * cfg.n_heads * hd * 2 + d * cfg.n_kv * hd * 2
        if cfg.family == "moe":
            ffn_p = cfg.moe_top_k * 3 * d * cfg.d_ff + d * cfg.n_experts
        else:
            ffn_p = 3 * d * cfg.d_ff
        per_layer = attn_p + ffn_p
        if cfg.family == "hybrid":
            d_in = 2 * d
            mamba_p = d * (2 * d_in + 2 * cfg.ssm_state) + d_in * d
            n_attn = cfg.n_layers // max(cfg.attn_every, 1)
            total_p = cfg.n_layers * mamba_p + n_attn * (
                attn_p + 3 * d * cfg.d_ff)
            per_layer = None
    if cfg.family == "hybrid":
        matmul = total_p
    else:
        matmul = cfg.n_layers * per_layer
    matmul += d * (cfg.num_classes if cfg.family == "encoder" else cfg.vocab)
    flops = mult * matmul * tokens
    # attention context term (scores + pv): fwd = 4·hd·H·ctx per token
    if cfg.family not in ("ssm",):
        ctx_layers = []
        if cfg.family == "hybrid":
            n_attn = cfg.n_layers // max(cfg.attn_every, 1)
            ctx_layers = [("full", n_attn)]
        elif cfg.global_every > 1 and cfg.window > 0:
            g = cfg.n_layers // cfg.global_every
            ctx_layers = [("win", g * (cfg.global_every - 1)), ("full", g)]
        else:
            ctx_layers = [("win" if cfg.window > 0 else "full",
                           cfg.n_layers)]
        t = shape.seq_len
        for kindw, n_l in ctx_layers:
            if shape.kind == "decode":
                ctx = min(cfg.window, t) if (kindw == "win" or (
                    cfg.family == "hybrid" and t > cfg.shared_attn_window)
                ) else t
                if cfg.family == "hybrid":
                    ctx = min(cfg.shared_attn_window, t)
                per_tok = 4 * hd * cfg.n_heads * ctx
            else:
                ctx = min(cfg.window, t) if kindw == "win" else t
                avg = ctx if kindw == "win" else t / 2
                per_tok = 4 * hd * cfg.n_heads * avg
            flops += (3.0 if shape.kind == "train" else 1.0) * \
                n_l * per_tok * tokens
    # SSM state terms
    if cfg.family in ("ssm", "hybrid"):
        if cfg.family == "ssm":
            n_heads = d // 64
            per_tok = 4 * n_heads * 64 * 64          # wkv state update+read
            flops += (3.0 if shape.kind == "train" else 1.0) * \
                cfg.n_layers * per_tok * tokens
        else:
            d_in = 2 * d
            nh = d_in // 64
            per_tok = 4 * nh * 64 * cfg.ssm_state
            flops += (3.0 if shape.kind == "train" else 1.0) * \
                cfg.n_layers * per_tok * tokens
    return float(flops)


LORA_FLOPS_DIM = 2 * 32 * 6   # rwkv ddlerp loras (5 mix + decay)


def _flatten_memory_analysis(ma) -> dict:
    keys = ("generated_code_size_in_bytes", "argument_size_in_bytes",
            "output_size_in_bytes", "alias_size_in_bytes",
            "temp_size_in_bytes", "host_generated_code_size_in_bytes",
            "host_argument_size_in_bytes", "host_output_size_in_bytes",
            "host_alias_size_in_bytes", "host_temp_size_in_bytes")
    out = {}
    for k in keys:
        if hasattr(ma, k):
            out[k] = int(getattr(ma, k))
    return out


def lower_cell(arch_id: str, shape_name: str, multi_pod: bool = False,
               overrides: dict | None = None) -> dict:
    import dataclasses
    cfg = get_config(arch_id)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    bundle = build_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.time()

    with compat.set_mesh(mesh):
        key_sds = jax.ShapeDtypeStruct((2,), jnp.uint32)
        pshape = jax.eval_shape(bundle.init, key_sds)
        pspec = param_specs(cfg, pshape, mesh)
        pshard = to_named(pspec, mesh)
        batch = input_specs(cfg, shape_name)
        bshard = to_named(batch_specs(cfg, batch, mesh), mesh)

        if shape.kind == "train":
            opt_cfg = AdamWConfig(
                state_dtype="bfloat16" if cfg.param_dtype == "bfloat16"
                else "float32")
            tc = TrainConfig(microbatches=1)
            step = make_train_step(bundle, opt_cfg, tc, donate=False)
            oshape = jax.eval_shape(
                lambda p: init_opt_state(bundle, p, opt_cfg, tc), pshape)
            oshard = to_named(opt_specs(cfg, oshape, pspec, mesh), mesh)
            jfn = jax.jit(step, in_shardings=(pshard, oshard, bshard),
                          donate_argnums=(0, 1))
            jcost = step_cost(step, pshape, oshape, batch)
            lowered = jfn.lower(pshape, oshape, batch)
        elif shape.kind == "prefill":
            fn = lambda p, b: bundle.prefill(p, b)
            jfn = jax.jit(fn, in_shardings=(pshard, bshard))
            jcost = step_cost(fn, pshape, batch)
            lowered = jfn.lower(pshape, batch)
        else:  # decode
            cshape = jax.eval_shape(
                lambda: bundle.init_cache(shape.global_batch, shape.seq_len))
            cshard = to_named(cache_specs(cfg, cshape, mesh), mesh)
            fn = lambda p, c, b: bundle.decode(
                p, c, b, jnp.int32(shape.seq_len - 1))
            jfn = jax.jit(fn, in_shardings=(pshard, cshard, bshard),
                          donate_argnums=(1,))
            jcost = step_cost(fn, pshape, cshape, batch)
            lowered = jfn.lower(pshape, cshape, batch)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        cost = compiled.cost_analysis() or {}
        try:
            mem = _flatten_memory_analysis(compiled.memory_analysis())
        except Exception as e:  # backend-dependent
            mem = {"error": str(e)[:200]}
        hlo = compiled.as_text()
        if cfg.attn_every > 1:
            loop_mult = cfg.n_layers // cfg.attn_every
        elif cfg.global_every > 1 and cfg.window > 0:
            loop_mult = cfg.n_layers // cfg.global_every
        else:
            loop_mult = cfg.n_layers
        coll = collective_bytes(hlo, loop_multiplier=loop_mult)

    # jaxpr-exact totals (scan bodies x length); XLA's numbers kept raw
    flops_dev = jcost.flops / chips
    bytes_dev = jcost.bytes / chips
    xla_flops_dev = float(cost.get("flops", 0.0))
    xla_bytes_dev = float(cost.get("bytes accessed", 0.0))
    coll_dev = sum(coll.values())
    # roofline terms (seconds); cost_analysis is per-device (SPMD module)
    t_comp = flops_dev / PEAK_FLOPS
    t_mem = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    terms = {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll}
    dom = max(terms, key=terms.get)

    n_params = cfg.param_count()
    n_active = cfg.active_param_count()
    model_flops = analytic_model_flops(cfg, shape)
    hlo_flops_total = flops_dev * chips
    return {
        "arch": arch_id, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops_per_device": flops_dev, "bytes_per_device": bytes_dev,
        "xla_flops_per_device_loop_once": xla_flops_dev,
        "xla_bytes_per_device_loop_once": xla_bytes_dev,
        "collective_bytes_per_device": coll_dev,
        "collectives": coll, "memory_analysis": mem,
        "roofline": {**{k: float(v) for k, v in terms.items()},
                     "dominant": dom},
        "model_flops": float(model_flops),
        "hlo_flops_total": float(hlo_flops_total),
        "useful_flops_ratio": float(model_flops / hlo_flops_total)
        if hlo_flops_total else None,
        "params": int(n_params), "active_params": int(n_active),
    }


def run_all(multi_pod: bool, out_path: str, archs=None, shapes=None) -> int:
    """Drive every live cell in a fresh subprocess (compile isolation)."""
    fails = 0
    results = []
    for arch in (archs or ARCH_IDS):
        cfg = get_config(arch)
        for shape in (shapes or applicable_shapes(cfg)):
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--json"]
            if multi_pod:
                cmd.append("--multi-pod")
            t0 = time.time()
            proc = subprocess.run(cmd, capture_output=True, text=True,
                                  timeout=3600)
            if proc.returncode != 0:
                fails += 1
                print(f"FAIL {arch} {shape}: {proc.stderr[-500:]}",
                      flush=True)
                results.append({"arch": arch, "shape": shape,
                                "error": proc.stderr[-2000:]})
            else:
                rec = json.loads(proc.stdout.splitlines()[-1])
                results.append(rec)
                r = rec["roofline"]
                print(f"OK   {arch:24s} {shape:12s} dom={r['dominant']:12s}"
                      f" comp={r['compute_s']:.4f}s mem={r['memory_s']:.4f}s"
                      f" coll={r['collective_s']:.4f}s"
                      f" ({time.time()-t0:.0f}s)", flush=True)
            with open(out_path, "w") as f:
                json.dump(results, f, indent=1)
    return fails


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--json", action="store_true")
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--override", default=None,
                    help="JSON dict of ArchConfig overrides (perf sweeps)")
    args = ap.parse_args()

    if args.all:
        fails = run_all(args.multi_pod, args.out)
        sys.exit(1 if fails else 0)

    overrides = json.loads(args.override) if args.override else None
    rec = lower_cell(args.arch, args.shape, args.multi_pod, overrides)
    if args.json:
        print(json.dumps(rec))
    else:
        print(json.dumps(rec, indent=2))


if __name__ == "__main__":
    main()
