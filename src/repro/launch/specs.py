"""input_specs(): ShapeDtypeStruct stand-ins for every model input, per
(arch × shape) cell — the dry-run lowers against these (no allocation).
`make_smoke_batch` materializes small real batches with the same layout.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SHAPES, ArchConfig, ShapeSpec


def train_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict[str, Any]:
    b, t = shape.global_batch, shape.seq_len
    if cfg.family == "encoder":
        return {
            "frames": jax.ShapeDtypeStruct((b, t, cfg.d_model), jnp.float32),
            "mask_positions": jax.ShapeDtypeStruct((b, t), jnp.bool_),
            "targets": jax.ShapeDtypeStruct((b, t), jnp.int32),
        }
    batch = {
        "tokens": jax.ShapeDtypeStruct((b, t), jnp.int32),
        "targets": jax.ShapeDtypeStruct((b, t), jnp.int32),
        "mask": jax.ShapeDtypeStruct((b, t), jnp.float32),
    }
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.num_patches, cfg.d_model), jnp.float32)
    return batch


def prefill_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict[str, Any]:
    b, t = shape.global_batch, shape.seq_len
    if cfg.family == "encoder":
        return {"frames": jax.ShapeDtypeStruct((b, t, cfg.d_model),
                                               jnp.float32)}
    batch = {"tokens": jax.ShapeDtypeStruct((b, t), jnp.int32)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.num_patches, cfg.d_model), jnp.float32)
    return batch


def decode_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict[str, Any]:
    b = shape.global_batch
    return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}


def input_specs(cfg: ArchConfig, shape_name: str) -> dict[str, Any]:
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return train_specs(cfg, shape)
    if shape.kind == "prefill":
        return prefill_specs(cfg, shape)
    return decode_specs(cfg, shape)


def make_smoke_batch(cfg: ArchConfig, batch: int, seq: int,
                     kind: str = "train", seed: int = 0) -> dict[str, Any]:
    rng = np.random.RandomState(seed)
    if cfg.family == "encoder":
        out = {"frames": jnp.asarray(
            rng.randn(batch, seq, cfg.d_model).astype(np.float32))}
        if kind == "train":
            out["mask_positions"] = jnp.asarray(rng.rand(batch, seq) < 0.3)
            out["targets"] = jnp.asarray(
                rng.randint(0, cfg.num_classes, (batch, seq)), jnp.int32)
        return out
    out = {"tokens": jnp.asarray(
        rng.randint(0, cfg.vocab, (batch, seq if kind != "decode" else 1)),
        jnp.int32)}
    if kind == "train":
        out["targets"] = jnp.asarray(
            rng.randint(0, cfg.vocab, (batch, seq)), jnp.int32)
        out["mask"] = jnp.ones((batch, seq), jnp.float32)
    if cfg.family == "vlm" and kind != "decode":
        out["patch_embeds"] = jnp.asarray(
            rng.randn(batch, cfg.num_patches, cfg.d_model).astype(
                np.float32) * 0.02)
    return out
