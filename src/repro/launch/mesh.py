"""Production mesh factory (assignment MULTI-POD DRY-RUN §1).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state.  Single pod: (data=16, model=16) = 256 chips of a
v5e pod; multi-pod: (pod=2, data=16, model=16) = 512 chips, the `pod` axis
crossing DCI.

`make_mesh` front-loads shape/axis validation: shard_map's own failures
on a malformed mesh surface deep inside jaxpr lowering ("NamedSharding
axis ... undefined", size-mismatch asserts), so the factory rejects the
request with an actionable message instead — wrong arity, non-positive or
non-divisible dims, duplicate or misspelled axis names (suggesting the
closest known spelling).
"""
from __future__ import annotations

import difflib

import jax

from repro import compat

# Axis names the repo's shard_map programs bind (core/distributed.py,
# launch/fvs_dryrun.py): misspelling one of these is the typo class the
# validator catches — any OTHER novel name is legal, just unknown.
KNOWN_AXES = ("pod", "data", "model", "shard")


def validate_mesh_request(shape: tuple[int, ...], axes: tuple[str, ...],
                          num_devices: int | None = None) -> None:
    """Raise ValueError (with the fix spelled out) on a bad mesh request.

    `num_devices=None` checks shape/axes consistency only — the abstract
    multi-pod dry-run builds 512-chip meshes from a CPU container, so
    device-count checks must stay opt-in.
    """
    if len(shape) != len(axes):
        raise ValueError(
            f"mesh shape {tuple(shape)} has {len(shape)} dims but "
            f"{len(axes)} axis names {tuple(axes)} — one name per dim")
    for dim, name in zip(shape, axes):
        if int(dim) < 1:
            raise ValueError(
                f"mesh axis {name!r} has non-positive size {dim}; every "
                "axis needs at least one device")
    dupes = {a for a in axes if list(axes).count(a) > 1}
    if dupes:
        raise ValueError(
            f"duplicate mesh axis name(s) {sorted(dupes)} in {tuple(axes)}"
            " — collectives bind by name, so names must be unique")
    for name in axes:
        if name not in KNOWN_AXES:
            close = difflib.get_close_matches(name, KNOWN_AXES, n=1)
            hint = f" — did you mean {close[0]!r}?" if close else ""
            raise ValueError(
                f"unknown mesh axis name {name!r}{hint} (known axes: "
                f"{KNOWN_AXES}; a shard_map program binding the intended "
                "axis would fail to find it at lowering time)")
    if num_devices is not None:
        total = 1
        for dim in shape:
            total *= int(dim)
        if num_devices % total != 0:
            raise ValueError(
                f"mesh shape {tuple(shape)} needs {total} devices but "
                f"{num_devices} are available — {num_devices} is not "
                f"divisible by {total}; shrink an axis (e.g. shard over "
                f"{num_devices} or a divisor) or free devices")


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...],
              check_devices: bool = False):
    """Validated mesh construction; `check_devices=True` additionally
    checks the request against the live `jax.devices()` count (leave off
    for abstract dry-run meshes)."""
    validate_mesh_request(
        shape, axes,
        num_devices=len(jax.devices()) if check_devices else None)
    return compat.make_mesh(shape, axes)


def batch_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
