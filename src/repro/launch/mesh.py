"""Production mesh factory (assignment MULTI-POD DRY-RUN §1).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state.  Single pod: (data=16, model=16) = 256 chips of a
v5e pod; multi-pod: (pod=2, data=16, model=16) = 512 chips, the `pod` axis
crossing DCI.
"""
from __future__ import annotations

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    return compat.make_mesh(shape, axes)


def batch_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
