"""Exact jaxpr-level cost accounting for the roofline analysis.

XLA's `compiled.cost_analysis()` counts `while` bodies ONCE, so any
scan-over-layers model under-reports FLOPs/bytes by ~n_layers.  This module
traverses the jaxpr instead: `scan` bodies are multiplied by their static
`length` (nested scans compose), so matmul FLOPs are exact.

Bytes are a *fusion-aware estimate*: only memory-bound primitive classes
are charged (matmul operands/results, gathers/scatters, dynamic slices,
reductions, sorts, RNG) — elementwise ops are assumed fused into their
producers, as on TPU.  Both this number and XLA's raw one are reported in
EXPERIMENTS.md; the roofline uses this one.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np
from jax import core as jcore


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0

    def __add__(self, o: "Cost") -> "Cost":
        return Cost(self.flops + o.flops, self.bytes + o.bytes)

    def __mul__(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.bytes * k)


def _aval_bytes(aval) -> float:
    try:
        return float(np.prod(aval.shape, dtype=np.float64)
                     * np.dtype(aval.dtype).itemsize)
    except Exception:
        return 0.0


_MEM_PRIMS = {
    "gather", "scatter", "scatter-add", "scatter_add", "dynamic_slice",
    "dynamic_update_slice", "reduce_sum", "reduce_max", "reduce_min",
    "reduce_prod", "argmax", "argmin", "sort", "top_k", "cumsum",
    "cumlogsumexp", "cummax", "rng_bit_generator", "random_bits", "iota",
    "concatenate", "pad", "rev", "reduce_window",
}


def _dot_flops(eqn) -> float:
    dn = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dn
    lhs = eqn.invars[0].aval
    out = eqn.outvars[0].aval
    k = float(np.prod([lhs.shape[i] for i in lc], dtype=np.float64)) \
        if lc else 1.0
    return 2.0 * float(np.prod(out.shape, dtype=np.float64)) * k


def _conv_flops(eqn) -> float:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval
    # flops ≈ 2 · out_elems · (kernel spatial × in_channels)
    k = float(np.prod(rhs.shape[:-1], dtype=np.float64))
    return 2.0 * float(np.prod(out.shape, dtype=np.float64)) * k


def _eqn_io_bytes(eqn) -> float:
    return sum(_aval_bytes(v.aval) for v in list(eqn.invars)
               if hasattr(v, "aval")) + \
        sum(_aval_bytes(v.aval) for v in eqn.outvars)


def count_jaxpr(jaxpr) -> Cost:
    total = Cost()
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "dot_general":
            total += Cost(_dot_flops(eqn), _eqn_io_bytes(eqn))
        elif name in ("conv_general_dilated",):
            total += Cost(_conv_flops(eqn), _eqn_io_bytes(eqn))
        elif name == "scan":
            inner = count_jaxpr(eqn.params["jaxpr"].jaxpr)
            length = eqn.params["length"]
            total += inner * float(length)
            # loop carries cross HBM once per iteration (read + write) —
            # this is the true cost of token-level recurrence and of
            # unfused online-softmax accumulators (§Perf cells C/rwkv)
            ncarry = eqn.params.get("num_carry", 0)
            ncons = eqn.params.get("num_consts", 0)
            carry_avals = eqn.params["jaxpr"].in_avals[ncons:ncons + ncarry]
            carry_bytes = sum(_aval_bytes(a) for a in carry_avals)
            total += Cost(0.0, 2.0 * carry_bytes * float(length))
        elif name == "while":
            # models use scan; FVS loops are bounded by max_hops — charge 1×
            total += count_jaxpr(eqn.params["body_jaxpr"].jaxpr)
        elif name == "cond":
            branches = [count_jaxpr(b.jaxpr)
                        for b in eqn.params["branches"]]
            total += max(branches, key=lambda c: c.flops) if branches \
                else Cost()
        elif name == "shard_map":
            # body is per-device: scale to global totals (divided back by
            # chips when forming per-device roofline terms)
            sub = eqn.params.get("jaxpr")
            mesh = eqn.params.get("mesh")
            n_dev = getattr(mesh, "size", 1) if mesh is not None else 1
            if sub is not None:
                total += count_jaxpr(getattr(sub, "jaxpr", sub)) * float(
                    n_dev)
        elif name in ("pjit", "jit", "xla_call", "closed_call", "core_call",
                      "remat_call", "custom_jvp_call", "custom_vjp_call",
                      "custom_vjp_call_jaxpr", "checkpoint", "remat",
                      "remat2", "custom_lin",
                      "sharding_constraint_call"):
            sub = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr") \
                or eqn.params.get("fun_jaxpr")
            if sub is not None:
                total += count_jaxpr(getattr(sub, "jaxpr", sub))
            elif name in ("custom_jvp_call", "custom_vjp_call"):
                pass
        elif name == "pallas_call":
            # fused kernel: HBM traffic = operands + outputs (everything
            # else stays in VMEM).  FLOPs: the flash kernel is recognized
            # structurally (q 4D + identical k/v 3D) and charged its two
            # matmuls over the full S (upper bound for causal); other
            # kernels charge their body jaxpr x grid steps.
            b = _eqn_io_bytes(eqn)
            ins = [v.aval for v in eqn.invars if hasattr(v, "aval")]
            if (len(ins) == 3 and ins[0].ndim == 4 and ins[1].ndim == 3
                    and ins[1].shape == ins[2].shape):
                bkv, t, g, hd = ins[0].shape
                f = 4.0 * bkv * t * g * hd * ins[1].shape[1]
            else:
                gm = eqn.params.get("grid_mapping")
                grid = tuple(getattr(gm, "grid", ()) or ())
                steps = float(np.prod(grid)) if grid else 1.0
                body = eqn.params.get("jaxpr")
                f = count_jaxpr(body).flops * steps if body is not None \
                    else 0.0
            total += Cost(f, b)
        elif name in _MEM_PRIMS:
            total += Cost(0.0, _eqn_io_bytes(eqn))
        # elementwise / layout ops: assumed fused (0 bytes, ~0 flops)
    return total


def step_cost(fn, *args) -> Cost:
    """Cost of `fn(*args)` (args may be ShapeDtypeStructs)."""
    closed = jax.make_jaxpr(fn)(*args)
    c = count_jaxpr(closed.jaxpr)
    # charge input/output residency once (params, batch, caches)
    io = sum(_aval_bytes(v.aval) for v in closed.jaxpr.invars) + \
        sum(_aval_bytes(v.aval) for v in closed.jaxpr.outvars)
    return Cost(c.flops, c.bytes + io)
