"""Zamba2-style hybrid: Mamba2 backbone + one SHARED attention block.

Structure: the n_layers Mamba2 layers are scanned in groups of
`attn_every`; after each group the shared attention+MLP block (single
parameter set, reused) runs.  Tail layers (n_layers % attn_every) scan
separately.  Each shared-attention call site has its OWN KV cache (weights
shared, state not), ring-buffered to `shared_attn_window` for long-context
decode (DESIGN.md §5 note).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import (attention_block, cdtype, embed_tokens,
                                 init_attention, init_embeddings, init_mlp,
                                 lm_logits, mlp_block, softmax_xent)
from repro.models.ssm import mamba_block, mamba_init_state, init_mamba
from repro.models.transformer import _decode_attn, _remat


def _group_counts(cfg: ArchConfig) -> tuple[int, int]:
    g = cfg.n_layers // cfg.attn_every
    tail = cfg.n_layers - g * cfg.attn_every
    return g, tail


def init_hybrid(key, cfg: ArchConfig) -> dict:
    ke, km, kt, ka, kf = jax.random.split(key, 5)
    g, tail = _group_counts(cfg)
    keys = jax.random.split(km, g * cfg.attn_every).reshape(
        g, cfg.attn_every, 2)
    groups = jax.vmap(jax.vmap(lambda k: init_mamba(k, cfg)))(keys)
    p = {"embed": init_embeddings(ke, cfg), "mamba_groups": groups,
         "shared_attn": init_attention(ka, cfg),
         "shared_mlp": init_mlp(kf, cfg)}
    if tail:
        p["mamba_tail"] = jax.vmap(lambda k: init_mamba(k, cfg))(
            jax.random.split(kt, tail).reshape(tail, 2))
    return p


def forward(params: dict, cfg: ArchConfig, tokens: jax.Array) -> jax.Array:
    x = embed_tokens(params["embed"], tokens, cfg).astype(cdtype(cfg))
    g, tail = _group_counts(cfg)

    def group_fn(x, gp):
        for i in range(cfg.attn_every):
            sub = jax.tree.map(lambda a: a[i], gp)
            m, _ = mamba_block(sub, x, cfg)
            x = x + m
        a, _ = attention_block(params["shared_attn"], x, cfg,
                               is_global=True)
        x = x + a
        return x + mlp_block(params["shared_mlp"], x, cfg), None

    x, _ = jax.lax.scan(_remat(group_fn, cfg), x, params["mamba_groups"])
    if tail:
        def tail_fn(x, lp):
            m, _ = mamba_block(lp, x, cfg)
            return x + m, None
        x, _ = jax.lax.scan(tail_fn, x, params["mamba_tail"])
    return x


def hybrid_loss(params: dict, batch: dict, cfg: ArchConfig) -> jax.Array:
    x = forward(params, cfg, batch["tokens"])
    logits = lm_logits(params["embed"], x, cfg)
    return softmax_xent(logits, batch["targets"], batch["mask"])


def init_hybrid_cache(cfg: ArchConfig, batch: int, seq_len: int) -> dict:
    g, tail = _group_counts(cfg)
    ms = mamba_init_state(cfg, batch)
    cache = {
        "mamba": jax.tree.map(
            lambda a: jnp.broadcast_to(
                a, (g, cfg.attn_every) + a.shape), ms),
        "attn_k": jnp.zeros(
            (g, batch, min(cfg.shared_attn_window, seq_len), cfg.n_kv,
             cfg.head_dim), cdtype(cfg)),
        "attn_v": jnp.zeros(
            (g, batch, min(cfg.shared_attn_window, seq_len), cfg.n_kv,
             cfg.head_dim), cdtype(cfg)),
    }
    if tail:
        cache["mamba_tail"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (tail,) + a.shape), ms)
    return cache


def hybrid_decode_step(params: dict, cache: dict, tokens: jax.Array, pos,
                       cfg: ArchConfig):
    x = embed_tokens(params["embed"], tokens, cfg).astype(cdtype(cfg))
    g, tail = _group_counts(cfg)

    def group_fn(x, xs):
        gp, ms, kc, vc = xs
        new_ms = []
        for i in range(cfg.attn_every):
            sub = jax.tree.map(lambda a: a[i], gp)
            st = jax.tree.map(lambda a: a[i], ms)
            m, ns = mamba_block(sub, x, cfg, state=st)
            x = x + m
            new_ms.append(ns)
        a, nc = _decode_attn(params["shared_attn"], x, kc, vc, cfg,
                             is_global=True, pos=pos)
        x = x + a
        x = x + mlp_block(params["shared_mlp"], x, cfg)
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *new_ms)
        return x, (stacked, nc["k"], nc["v"])

    x, (nms, nk, nv) = jax.lax.scan(
        group_fn, x, (params["mamba_groups"], cache["mamba"],
                      cache["attn_k"], cache["attn_v"]))
    new_cache = {"mamba": nms, "attn_k": nk, "attn_v": nv}
    if tail:
        def tail_fn(x, xs):
            lp, st = xs
            m, ns = mamba_block(lp, x, cfg, state=st)
            return x + m, ns
        x, nts = jax.lax.scan(tail_fn, x,
                              (params["mamba_tail"], cache["mamba_tail"]))
        new_cache["mamba_tail"] = nts
    logits = lm_logits(params["embed"], x, cfg)
    return logits, new_cache


def hybrid_prefill(params: dict, cfg: ArchConfig, tokens: jax.Array):
    x = forward(params, cfg, tokens)
    return lm_logits(params["embed"], x[:, -1:], cfg)
