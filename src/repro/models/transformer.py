"""Unified decoder-only LM: dense / MoE / sliding-window mixes / VLM backbone.

Layer stacking:
  * homogeneous archs — params stacked (L, ...), one `lax.scan`.
  * gemma3-style local:global mixes — params stacked (G, group, ...) where
    each scanned group holds `global_every-1` local layers + 1 global layer,
    so local layers get small (window) KV caches and global layers full ones
    (no O(L·S) waste, compile stays O(group)).

Decode caches are ring buffers for windowed layers (slot = pos mod window).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import (attention_block, cdtype, embed_tokens,
                                 init_attention, init_embeddings, init_mlp,
                                 init_moe, lm_logits, mlp_block, moe_block,
                                 shard, softmax_xent)


def _remat(fn, cfg: ArchConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def _grouped(cfg: ArchConfig) -> bool:
    return cfg.global_every > 1 and cfg.window > 0


def init_lm(key, cfg: ArchConfig) -> dict:
    ke, kl = jax.random.split(key)

    def init_layer(k):
        ka, kf = jax.random.split(k)
        p = {"attn": init_attention(ka, cfg)}
        p["ffn"] = init_moe(kf, cfg) if cfg.family == "moe" \
            else init_mlp(kf, cfg)
        return p

    if _grouped(cfg):
        g = cfg.n_layers // cfg.global_every
        keys = jax.random.split(kl, g * cfg.global_every).reshape(
            g, cfg.global_every, 2)
        layers = jax.vmap(jax.vmap(init_layer))(keys)
    else:
        layers = jax.vmap(init_layer)(jax.random.split(kl, cfg.n_layers))
    return {"embed": init_embeddings(ke, cfg), "layers": layers}


def _layer(p, x, cfg: ArchConfig, is_global: bool, cache=None, pos=None,
           use_windowed_kernel: bool = False):
    a, new_cache = attention_block(p["attn"], x, cfg, is_global=is_global,
                                   cache=cache, pos=pos,
                                   use_windowed_kernel=use_windowed_kernel)
    x = x + a
    f = moe_block(p["ffn"], x, cfg) if cfg.family == "moe" \
        else mlp_block(p["ffn"], x, cfg)
    return x + f, new_cache


def forward(params: dict, cfg: ArchConfig, tokens: Optional[jax.Array] = None,
            embeds: Optional[jax.Array] = None,
            use_windowed_kernel: bool = False) -> jax.Array:
    """Full-sequence forward (training / prefill-hidden). Returns (B, T, D)."""
    use_windowed_kernel = use_windowed_kernel or cfg.windowed_kernel
    x = embeds if embeds is not None else embed_tokens(params["embed"],
                                                       tokens, cfg)
    x = x.astype(cdtype(cfg))

    if _grouped(cfg):
        def group_fn(x, gp):
            for i in range(cfg.global_every):
                sub = jax.tree.map(lambda a: a[i], gp)
                is_global = i == cfg.global_every - 1
                x, _ = _layer(sub, x, cfg, is_global,
                              use_windowed_kernel=use_windowed_kernel)
            return x, None

        x, _ = jax.lax.scan(_remat(group_fn, cfg), x, params["layers"])
    else:
        def layer_fn(x, lp):
            window_only = cfg.window > 0 and cfg.global_every == 0
            x, _ = _layer(lp, x, cfg, is_global=not window_only,
                          use_windowed_kernel=use_windowed_kernel)
            return x, None

        x, _ = jax.lax.scan(_remat(layer_fn, cfg), x, params["layers"])
    return x


def lm_loss(params: dict, batch: dict, cfg: ArchConfig,
            use_windowed_kernel: bool = False) -> jax.Array:
    x = forward(params, cfg, tokens=batch.get("tokens"),
                embeds=batch.get("embeds"),
                use_windowed_kernel=use_windowed_kernel
                or cfg.windowed_kernel)
    logits = lm_logits(params["embed"], x, cfg)
    return softmax_xent(logits, batch["targets"], batch["mask"])


# ---------------------------------------------------------------------------
# Serving: cache init / prefill / decode
# ---------------------------------------------------------------------------

def _cache_sizes(cfg: ArchConfig, seq_len: int) -> tuple[int, int]:
    """(local_len, global_len) KV capacities for one layer."""
    local = min(cfg.window, seq_len) if cfg.window > 0 else seq_len
    return local, seq_len


def init_cache(cfg: ArchConfig, batch: int, seq_len: int) -> dict:
    hd, kv = cfg.head_dim, cfg.n_kv
    dt = cdtype(cfg)
    local_len, global_len = _cache_sizes(cfg, seq_len)
    if _grouped(cfg):
        g, per = cfg.n_layers // cfg.global_every, cfg.global_every
        return {
            "local_k": jnp.zeros((g, per - 1, batch, local_len, kv, hd), dt),
            "local_v": jnp.zeros((g, per - 1, batch, local_len, kv, hd), dt),
            "global_k": jnp.zeros((g, batch, global_len, kv, hd), dt),
            "global_v": jnp.zeros((g, batch, global_len, kv, hd), dt),
        }
    length = local_len if (cfg.window > 0 and cfg.global_every == 0) \
        else global_len
    return {"k": jnp.zeros((cfg.n_layers, batch, length, kv, hd), dt),
            "v": jnp.zeros((cfg.n_layers, batch, length, kv, hd), dt)}


def decode_step(params: dict, cache: dict, tokens: jax.Array,
                pos: jax.Array, cfg: ArchConfig,
                embeds: Optional[jax.Array] = None):
    """One-token decode. tokens: (B, 1); pos: scalar int32 (uniform batch).
    Returns (logits (B, 1, V), new_cache)."""
    x = embeds if embeds is not None else embed_tokens(params["embed"],
                                                       tokens, cfg)
    x = x.astype(cdtype(cfg))

    if _grouped(cfg):
        def group_fn(x, xs):
            gp, lk, lv, gk, gv = xs
            nlk, nlv = [], []
            for i in range(cfg.global_every - 1):
                sub = jax.tree.map(lambda a: a[i], gp)
                a, nc = _decode_attn(sub["attn"], x, lk[i], lv[i], cfg,
                                     is_global=False, pos=pos)
                x = x + a
                x = x + (moe_block(sub["ffn"], x, cfg) if cfg.family == "moe"
                         else mlp_block(sub["ffn"], x, cfg))
                nlk.append(nc["k"])
                nlv.append(nc["v"])
            sub = jax.tree.map(lambda a: a[cfg.global_every - 1], gp)
            a, nc = _decode_attn(sub["attn"], x, gk, gv, cfg,
                                 is_global=True, pos=pos)
            x = x + a
            x = x + (moe_block(sub["ffn"], x, cfg) if cfg.family == "moe"
                     else mlp_block(sub["ffn"], x, cfg))
            return x, (jnp.stack(nlk), jnp.stack(nlv), nc["k"], nc["v"])

        x, (nlk, nlv, ngk, ngv) = jax.lax.scan(
            group_fn, x, (params["layers"], cache["local_k"],
                          cache["local_v"], cache["global_k"],
                          cache["global_v"]))
        new_cache = {"local_k": nlk, "local_v": nlv,
                     "global_k": ngk, "global_v": ngv}
    else:
        window_only = cfg.window > 0 and cfg.global_every == 0

        def layer_fn(x, xs):
            lp, kc, vc = xs
            a, nc = _decode_attn(lp["attn"], x, kc, vc, cfg,
                                 is_global=not window_only, pos=pos)
            x = x + a
            x = x + (moe_block(lp["ffn"], x, cfg) if cfg.family == "moe"
                     else mlp_block(lp["ffn"], x, cfg))
            return x, (nc["k"], nc["v"])

        x, (nk, nv) = jax.lax.scan(layer_fn, x,
                                   (params["layers"], cache["k"],
                                    cache["v"]))
        new_cache = {"k": nk, "v": nv}

    logits = lm_logits(params["embed"], x, cfg)
    return logits, new_cache


def _decode_attn(p, x, k_cache, v_cache, cfg: ArchConfig, is_global: bool,
                 pos):
    """Single-token attention against a (ring-buffered if windowed) cache."""
    from repro.models.layers import apply_rope, flash_attention
    b = x.shape[0]
    hd, kv = cfg.head_dim, cfg.n_kv
    from repro.models.layers import rmsnorm
    h = rmsnorm(x, p["norm"])
    q = (h @ p["wq"].astype(h.dtype)).reshape(b, 1, cfg.n_heads, hd)
    k = (h @ p["wk"].astype(h.dtype)).reshape(b, 1, kv, hd)
    v = (h @ p["wv"].astype(h.dtype)).reshape(b, 1, kv, hd)
    posn = jnp.asarray(pos, jnp.int32)[None, None]
    q = apply_rope(q, posn, cfg.rope_theta)
    k = apply_rope(k, posn, cfg.rope_theta)
    cap = k_cache.shape[1]
    slot = jnp.asarray(pos, jnp.int32) % cap
    kc = jax.lax.dynamic_update_slice_in_dim(k_cache, k, slot, axis=1)
    vc = jax.lax.dynamic_update_slice_in_dim(v_cache, v, slot, axis=1)
    kv_len = jnp.minimum(jnp.asarray(pos, jnp.int32) + 1, cap)
    o = flash_attention(q, kc, vc, causal=False, kv_len=kv_len, block=2048)
    o = o.reshape(b, 1, cfg.n_heads * hd)
    out = o @ p["wo"].astype(o.dtype)
    return shard(out, ("pod", "data"), None, None), {"k": kc, "v": vc}


def prefill(params: dict, cfg: ArchConfig, tokens: Optional[jax.Array] = None,
            embeds: Optional[jax.Array] = None):
    """Prefill forward: returns last-position logits (cache write is modeled
    by the decode path; the prefill benchmark measures the forward)."""
    x = forward(params, cfg, tokens=tokens, embeds=embeds)
    logits = lm_logits(params["embed"], x[:, -1:], cfg)
    return logits
