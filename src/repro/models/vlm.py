"""LLaVA-NeXT-style VLM: stub anyres patch frontend + Mistral LM backbone.

Per the assignment, `[vlm]` specifies the transformer BACKBONE only; the
vision tower is a STUB — `input_specs()` provides precomputed patch
embeddings (B, P, d_model), already projected.  The model splices them over
the first P token positions (prefix layout) and runs the standard decoder.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import cdtype, embed_tokens, lm_logits, shard, \
    softmax_xent
from repro.models import transformer as tfm


def init_vlm(key, cfg: ArchConfig) -> dict:
    return tfm.init_lm(key, cfg)


def splice_embeds(params: dict, tokens: jax.Array, patch_embeds: jax.Array,
                  cfg: ArchConfig) -> jax.Array:
    """Prefix splice: positions [0, P) take patch embeddings."""
    x = embed_tokens(params["embed"], tokens, cfg)
    p = patch_embeds.shape[1]
    pe = patch_embeds.astype(x.dtype)
    x = jnp.concatenate([pe, x[:, p:]], axis=1)
    return shard(x, ("pod", "data"), None, None)


def vlm_loss(params: dict, batch: dict, cfg: ArchConfig) -> jax.Array:
    embeds = splice_embeds(params, batch["tokens"], batch["patch_embeds"],
                           cfg)
    x = tfm.forward(params, cfg, embeds=embeds)
    logits = lm_logits(params["embed"], x, cfg)
    # image-prefix positions are masked out of the LM loss
    p = batch["patch_embeds"].shape[1]
    mask = batch["mask"].at[:, :p].set(0.0)
    return softmax_xent(logits, batch["targets"], mask)


def vlm_prefill(params: dict, cfg: ArchConfig, tokens: jax.Array,
                patch_embeds: jax.Array):
    embeds = splice_embeds(params, tokens, patch_embeds, cfg)
    return tfm.prefill(params, cfg, embeds=embeds)
