"""Unified model API: one bundle per architecture family.

  bundle = build_model(cfg)
  params = bundle.init(key)
  loss   = bundle.loss(params, batch)           # training objective
  logits = bundle.prefill(params, batch)        # inference prefill
  cache  = bundle.init_cache(batch, seq_len)    # decode state
  logits, cache = bundle.decode(params, cache, batch, pos)

`batch` layouts per family are produced by `input_specs()` in
repro.launch.specs (ShapeDtypeStructs for the dry-run, real arrays from
repro.data for smoke tests / training).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import encoder, hybrid, rwkv, transformer as tfm, vlm


@dataclasses.dataclass(frozen=True)
class ModelBundle:
    cfg: ArchConfig
    init: Callable[[jax.Array], Any]
    loss: Callable[[Any, dict], jax.Array]
    prefill: Callable[[Any, dict], jax.Array]
    init_cache: Optional[Callable[[int, int], Any]]
    decode: Optional[Callable[[Any, Any, dict, Any], tuple]]


def build_model(cfg: ArchConfig) -> ModelBundle:
    fam = cfg.family
    if fam in ("dense", "moe"):
        return ModelBundle(
            cfg=cfg,
            init=lambda key: tfm.init_lm(key, cfg),
            loss=lambda p, b: tfm.lm_loss(p, b, cfg),
            prefill=lambda p, b: tfm.prefill(p, cfg, tokens=b["tokens"]),
            init_cache=lambda bsz, s: tfm.init_cache(cfg, bsz, s),
            decode=lambda p, c, b, pos: tfm.decode_step(
                p, c, b["tokens"], pos, cfg),
        )
    if fam == "vlm":
        return ModelBundle(
            cfg=cfg,
            init=lambda key: vlm.init_vlm(key, cfg),
            loss=lambda p, b: vlm.vlm_loss(p, b, cfg),
            prefill=lambda p, b: vlm.vlm_prefill(
                p, cfg, b["tokens"], b["patch_embeds"]),
            init_cache=lambda bsz, s: tfm.init_cache(cfg, bsz, s),
            decode=lambda p, c, b, pos: tfm.decode_step(
                p, c, b["tokens"], pos, cfg),
        )
    if fam == "encoder":
        return ModelBundle(
            cfg=cfg,
            init=lambda key: encoder.init_encoder(key, cfg),
            loss=lambda p, b: encoder.encoder_loss(p, b, cfg),
            prefill=lambda p, b: encoder.encode(p, b["frames"], cfg,
                                                allow_pallas=True),
            init_cache=None,
            decode=None,
        )
    if fam == "ssm":
        return ModelBundle(
            cfg=cfg,
            init=lambda key: rwkv.init_rwkv_lm(key, cfg),
            loss=lambda p, b: rwkv.rwkv_loss(p, b, cfg),
            prefill=lambda p, b: rwkv.rwkv_prefill(p, cfg, b["tokens"]),
            init_cache=lambda bsz, s: rwkv.init_rwkv_cache(cfg, bsz, s),
            decode=lambda p, c, b, pos: rwkv.rwkv_decode_step(
                p, c, b["tokens"], pos, cfg),
        )
    if fam == "hybrid":
        return ModelBundle(
            cfg=cfg,
            init=lambda key: hybrid.init_hybrid(key, cfg),
            loss=lambda p, b: hybrid.hybrid_loss(p, b, cfg),
            prefill=lambda p, b: hybrid.hybrid_prefill(p, cfg, b["tokens"]),
            init_cache=lambda bsz, s: hybrid.init_hybrid_cache(cfg, bsz, s),
            decode=lambda p, c, b, pos: hybrid.hybrid_decode_step(
                p, c, b["tokens"], pos, cfg),
        )
    raise ValueError(f"unknown family {fam!r}")
