"""HuBERT-style bidirectional encoder (masked-unit prediction).

The conv waveform frontend is a STUB per the assignment: `input_specs()`
provides precomputed frame embeddings (B, T, D); a learned linear adapter
stands in for the feature projection.  Training objective: cross-entropy
over `num_classes` codebook units at masked positions (vocab=504 in the
assignment line is the codebook size).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import (attention_block, cdtype, init_attention,
                                 init_dense, init_mlp, mlp_block, pdtype,
                                 rmsnorm, shard, softmax_xent)
from repro.models.transformer import _remat


def init_encoder(key, cfg: ArchConfig) -> dict:
    ks = jax.random.split(key, 4)

    def init_layer(k):
        ka, kf = jax.random.split(k)
        return {"attn": init_attention(ka, cfg), "ffn": init_mlp(kf, cfg)}

    layers = jax.vmap(init_layer)(jax.random.split(ks[0], cfg.n_layers))
    return {
        "adapter": init_dense(ks[1], cfg.d_model, cfg.d_model, pdtype(cfg)),
        "mask_embed": (jax.random.normal(ks[2], (cfg.d_model,), jnp.float32)
                       * 0.02),
        "layers": layers,
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "head": init_dense(ks[3], cfg.d_model, cfg.num_classes, pdtype(cfg)),
    }


def encode(params: dict, frames: jax.Array, cfg: ArchConfig,
           mask_positions: jax.Array | None = None,
           allow_pallas: bool = False) -> jax.Array:
    """frames: (B, T, D) stub frontend output. Bidirectional attention."""
    x = (frames.astype(cdtype(cfg)) @ params["adapter"].astype(cdtype(cfg)))
    if mask_positions is not None:
        x = jnp.where(mask_positions[..., None],
                      params["mask_embed"].astype(x.dtype), x)
    x = shard(x, ("pod", "data"), None, None)

    def layer_fn(x, lp):
        a, _ = attention_block(lp["attn"], x, cfg, is_global=True,
                               allow_pallas=allow_pallas)
        x = x + a
        return x + mlp_block(lp["ffn"], x, cfg), None

    x, _ = jax.lax.scan(_remat(layer_fn, cfg), x, params["layers"])
    return rmsnorm(x, params["final_norm"])


def encoder_loss(params: dict, batch: dict, cfg: ArchConfig) -> jax.Array:
    """Masked-prediction CE at masked frames (paper: HuBERT objective)."""
    x = encode(params, batch["frames"], cfg,
               mask_positions=batch["mask_positions"])
    logits = x @ params["head"].astype(x.dtype)
    m = batch["mask_positions"].astype(jnp.float32)
    return softmax_xent(logits, batch["targets"], m)
