"""State-space / linear-recurrence layers: Mamba2 (SSD) and RWKV6.

Mamba2 uses the chunked SSD formulation (intra-chunk masked matmul +
inter-chunk carried state), which maps the recurrence onto MXU matmuls.
RWKV6 ("Finch": data-dependent per-channel decay) has two selectable paths:

  * `scan`    — token-level `lax.scan` recurrence (the faithful baseline;
                HBM-bound: the (dk × dv) state round-trips per token)
  * `chunked` — GLA-style chunked form (the §Perf hillclimb variant: state
                traffic reduced by the chunk length, compute moved to MXU)

Both paths share the single-token `*_decode_step` used by serve_step, and
the chunked path is validated against scan in tests.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.layers import (BATCH_AXES, cdtype, init_dense, pdtype,
                                 rmsnorm, shard)

MAMBA_HEAD_DIM = 64
RWKV_HEAD_DIM = 64
LORA_RANK = 32


# ---------------------------------------------------------------------------
# Mamba2 (SSD)
# ---------------------------------------------------------------------------

def mamba_dims(cfg: ArchConfig) -> tuple[int, int, int]:
    d_inner = 2 * cfg.d_model
    n_heads = d_inner // MAMBA_HEAD_DIM
    return d_inner, n_heads, cfg.ssm_state


def init_mamba(key, cfg: ArchConfig) -> dict:
    d_inner, n_heads, n_state = mamba_dims(cfg)
    ks = jax.random.split(key, 6)
    dt = pdtype(cfg)
    return {
        "norm": jnp.ones((cfg.d_model,), jnp.float32),
        "in_proj": init_dense(ks[0], cfg.d_model,
                              2 * d_inner + 2 * n_state + n_heads, dt),
        "conv": (jax.random.normal(ks[1], (4, d_inner), jnp.float32)
                 * 0.2).astype(dt),
        "a_log": jnp.zeros((n_heads,), jnp.float32),        # A = -exp(a_log)
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "out_proj": init_dense(ks[2], d_inner, cfg.d_model, dt),
    }


def _ssd_chunked(x, a_log, bm, cm, chunk: int,
                 init_state: Optional[jax.Array] = None):
    """Chunked SSD. x: (B,T,H,P); a_log: (B,T,H) (≤0); bm, cm: (B,T,N).

    Returns (y (B,T,H,P), final_state (B,H,P,N)).
    """
    b, t, h, p = x.shape
    n = bm.shape[-1]
    pad = (-t) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a_log = jnp.pad(a_log, ((0, 0), (0, pad), (0, 0)))
        bm = jnp.pad(bm, ((0, 0), (0, pad), (0, 0)))
        cm = jnp.pad(cm, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // chunk
    xc = x.reshape(b, nc, chunk, h, p)
    ac = a_log.reshape(b, nc, chunk, h)
    bc = bm.reshape(b, nc, chunk, n)
    cc = cm.reshape(b, nc, chunk, n)

    ca = jnp.cumsum(ac, axis=2)                       # (b,nc,Q,h) inclusive
    # intra-chunk: L[t,i] = exp(ca_t - ca_i) for i <= t (per head)
    diff = ca[:, :, :, None, :] - ca[:, :, None, :, :]     # (b,nc,Q,Q,h)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    L = jnp.where(tri[None, None, :, :, None], jnp.exp(diff), 0.0)
    cb = jnp.einsum("bcqn,bckn->bcqk", cc, bc,
                    preferred_element_type=jnp.float32)
    y_intra = jnp.einsum("bcqk,bcqkh,bckhp->bcqhp", cb, L, xc,
                         preferred_element_type=jnp.float32)

    # chunk summaries: S_c = Σ_i exp(ca_Q - ca_i) · x_i ⊗ B_i
    decay_out = jnp.exp(ca[:, :, -1:, :] - ca)             # (b,nc,Q,h)
    s_c = jnp.einsum("bcqh,bcqhp,bcqn->bchpn", decay_out, xc, bc,
                     preferred_element_type=jnp.float32)
    a_tot = jnp.exp(ca[:, :, -1, :])                       # (b,nc,h)

    def body(s, inp):
        sc, at = inp
        s_new = s * at[:, :, None, None] + sc
        return s_new, s

    s0 = jnp.zeros((b, h, p, n), jnp.float32) if init_state is None \
        else init_state.astype(jnp.float32)
    s_final, s_prevs = jax.lax.scan(
        body, s0, (s_c.transpose(1, 0, 2, 3, 4), a_tot.transpose(1, 0, 2)))
    s_prevs = s_prevs.transpose(1, 0, 2, 3, 4)             # (b,nc,h,p,n)
    decay_in = jnp.exp(ca)                                  # (b,nc,Q,h)
    y_inter = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", cc, s_prevs, decay_in,
                         preferred_element_type=jnp.float32)
    y = (y_intra + y_inter).reshape(b, nc * chunk, h, p)[:, :t]
    return y.astype(x.dtype), s_final


def mamba_block(params: dict, x: jax.Array, cfg: ArchConfig,
                state: Optional[dict] = None):
    """Mamba2 block. Training: chunked SSD over T. Decode: state holds
    (conv_buf (B,3,d_inner), ssm (B,H,P,N)); x is (B,1,D)."""
    b, t, _ = x.shape
    d_inner, n_heads, n_state = mamba_dims(cfg)
    h = rmsnorm(x, params["norm"])
    zxbcdt = h @ params["in_proj"].astype(h.dtype)
    z, xin, bm, cm, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + n_state,
                 2 * d_inner + 2 * n_state], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"])              # (b,t,H)
    a = -jnp.exp(params["a_log"])                          # (H,)

    if state is None:
        # causal depthwise conv (kernel 4)
        xp = jnp.pad(xin, ((0, 0), (3, 0), (0, 0)))
        conv = sum(xp[:, i:i + t] * params["conv"][i].astype(xin.dtype)
                   for i in range(4))
        xs = jax.nn.silu(conv)
        xh = xs.reshape(b, t, n_heads, MAMBA_HEAD_DIM)
        xdt = xh * dt[..., None].astype(xh.dtype)
        a_log_t = dt * a                                   # (b,t,H) ≤ 0
        y, _ = _ssd_chunked(xdt, a_log_t, bm.astype(jnp.float32),
                            cm.astype(jnp.float32), cfg.ssm_chunk)
        y = y + xh * params["d_skip"][None, None, :, None].astype(xh.dtype)
        new_state = None
    else:
        conv_buf = jnp.concatenate([state["conv"], xin], axis=1)  # (b,4,di)
        conv = jnp.einsum("bkd,kd->bd", conv_buf,
                          params["conv"].astype(xin.dtype))[:, None]
        xs = jax.nn.silu(conv)
        xh = xs.reshape(b, 1, n_heads, MAMBA_HEAD_DIM)
        xdt = (xh * dt[..., None].astype(xh.dtype))[:, 0]   # (b,H,P)
        decay = jnp.exp(dt[:, 0] * a)                       # (b,H)
        s = state["ssm"] * decay[:, :, None, None] + jnp.einsum(
            "bhp,bn->bhpn", xdt.astype(jnp.float32), bm[:, 0].astype(
                jnp.float32))
        y = jnp.einsum("bn,bhpn->bhp", cm[:, 0].astype(jnp.float32), s)
        y = y[:, None].reshape(b, 1, n_heads, MAMBA_HEAD_DIM).astype(xh.dtype)
        y = y + xh * params["d_skip"][None, None, :, None].astype(xh.dtype)
        new_state = {"conv": conv_buf[:, 1:], "ssm": s}

    y = y.reshape(b, t, d_inner) * jax.nn.silu(z)
    out = y @ params["out_proj"].astype(y.dtype)
    return shard(out, BATCH_AXES, None, None), new_state


def mamba_init_state(cfg: ArchConfig, batch: int) -> dict:
    d_inner, n_heads, n_state = mamba_dims(cfg)
    return {"conv": jnp.zeros((batch, 3, d_inner), cdtype(cfg)),
            "ssm": jnp.zeros((batch, n_heads, MAMBA_HEAD_DIM, n_state),
                             jnp.float32)}


# ---------------------------------------------------------------------------
# RWKV6
# ---------------------------------------------------------------------------

def rwkv_dims(cfg: ArchConfig) -> tuple[int, int]:
    n_heads = cfg.d_model // RWKV_HEAD_DIM
    return n_heads, RWKV_HEAD_DIM


def init_rwkv_tmix(key, cfg: ArchConfig) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 10)
    dt = pdtype(cfg)
    n_heads, _ = rwkv_dims(cfg)
    return {
        "norm": jnp.ones((d,), jnp.float32),
        "mu": (jax.random.uniform(ks[0], (5, d), jnp.float32)).astype(
            jnp.float32),                               # r,k,v,g,w lerp base
        "lora_a": init_dense(ks[1], d, LORA_RANK * 5, dt, 0.1),
        "lora_b": (jax.random.normal(ks[2], (5, LORA_RANK, d), jnp.float32)
                   * 0.01).astype(dt),
        "wr": init_dense(ks[3], d, d, dt),
        "wk": init_dense(ks[4], d, d, dt),
        "wv": init_dense(ks[5], d, d, dt),
        "wg": init_dense(ks[6], d, d, dt),
        "wo": init_dense(ks[7], d, d, dt),
        "w0": (jnp.zeros((d,), jnp.float32) - 0.6),      # decay bias
        "wlora_a": init_dense(ks[8], d, LORA_RANK, dt, 0.1),
        "wlora_b": (jax.random.normal(ks[9], (LORA_RANK, d), jnp.float32)
                    * 0.01).astype(dt),
        "u": jnp.zeros((d,), jnp.float32),               # current-token bonus
        "ln_w": jnp.ones((d,), jnp.float32),             # per-head groupnorm
    }


def _rwkv_mix(params, x, x_prev):
    """RWKV6 ddlerp: 5 data-dependent token-shift mixes -> r,k,v,g,w inputs.
    x: (B,T,D); x_prev: (B,T,D) (token-shifted x)."""
    delta = x_prev - x
    lora = jax.nn.tanh(x @ params["lora_a"].astype(x.dtype))    # (B,T,5R)
    b_, t_, _ = lora.shape
    lora = lora.reshape(b_, t_, 5, LORA_RANK)
    dyn = jnp.einsum("btfr,frd->btfd", lora,
                     params["lora_b"].astype(x.dtype))          # (B,T,5,D)
    mixed = x[:, :, None] + delta[:, :, None] * (
        params["mu"][None, None].astype(x.dtype) + dyn)
    return [mixed[:, :, i] for i in range(5)]


def _rwkv_scan(r, k, v, w_log, u, init_state=None):
    """Token-recurrent WKV. r,k,v: (B,T,H,C); w_log: (B,T,H,C) (≤0);
    u: (H,C). Returns (out (B,T,H,C), final_state (B,H,C,C))."""
    b, t, h, c = r.shape

    def body(s, inp):
        rt, kt, vt, wt = inp                              # (b,h,c)
        kv = jnp.einsum("bhc,bhd->bhcd", kt, vt)
        out = jnp.einsum("bhc,bhcd->bhd", rt, s + u[None, :, :, None] * kv)
        s = jnp.exp(wt)[..., None] * s + kv
        return s, out

    s0 = jnp.zeros((b, h, c, c), jnp.float32) if init_state is None \
        else init_state
    xs = (r.transpose(1, 0, 2, 3).astype(jnp.float32),
          k.transpose(1, 0, 2, 3).astype(jnp.float32),
          v.transpose(1, 0, 2, 3).astype(jnp.float32),
          w_log.transpose(1, 0, 2, 3).astype(jnp.float32))
    s, out = jax.lax.scan(body, s0, xs)
    return out.transpose(1, 0, 2, 3), s


def _rwkv_chunked(r, k, v, w_log, u, chunk: int, init_state=None):
    """GLA-style chunked WKV with per-channel decay (the perf variant).

    Numerics: per-chunk cumulative log-decay is clamped to ≥ -60 before
    exponentiation (contributions below e⁻⁶⁰ are zero in f32 anyway).
    """
    b, t, h, c = r.shape
    pad = (-t) % chunk
    if pad:
        z = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v, w_log = z(r), z(k), z(v), z(w_log)
    nc = r.shape[1] // chunk
    sh = lambda a: a.reshape(b, nc, chunk, h, c).astype(jnp.float32)
    rc, kc, vc, wc = sh(r), sh(k), sh(v), sh(w_log)
    cw = jnp.cumsum(wc, axis=2)                      # (b,nc,Q,h,c) inclusive
    cw_ex = cw - wc                                  # exclusive (up to q-1)
    # scan semantics: out_q reads S_{q-1}, so kv_i decays by
    # prod_{j=i+1..q-1} w_j = exp(cw_{q-1} - cw_i) = exp(cw_ex_q - cw_i)
    diff = cw_ex[:, :, :, None] - cw[:, :, None, :, :]      # (b,nc,Q,Q,h,c)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
    ldec = jnp.where(tri[None, None, :, :, None, None],
                     jnp.clip(diff, -60.0, 0.0), -jnp.inf)
    scores = jnp.einsum("bcqhd,bcqkhd,bckhd->bcqkh", rc, jnp.exp(ldec), kc)
    y_intra = jnp.einsum("bcqkh,bckhd->bcqhd", scores, vc)
    # current-token bonus (i == q uses u instead of the state)
    y_bonus = jnp.einsum("bcqhd,bcqhd->bcqh", rc, u[None, None, None] * kc
                         )[..., None] * vc
    # inter-chunk: y_q += r_q ⊙ exp(cw_{q-1}) · S_prev (same exclusive rule)
    dec_in = jnp.exp(jnp.clip(cw_ex, -60.0, 0.0))
    # chunk summary: S_c = Σ_i exp(cw_Q - cw_i) k_i ⊗ v_i
    dec_out = jnp.exp(jnp.clip(cw[:, :, -1:] - cw, -60.0, 0.0))
    s_c = jnp.einsum("bcqhd,bcqhe->bchde", kc * dec_out, vc)
    a_tot = jnp.exp(jnp.clip(cw[:, :, -1], -60.0, 0.0))     # (b,nc,h,c)

    def body(s, inp):
        sc, at = inp
        return at[..., None] * s + sc, s

    s0 = jnp.zeros((b, h, c, c), jnp.float32) if init_state is None \
        else init_state
    s_fin, s_prev = jax.lax.scan(
        body, s0, (s_c.transpose(1, 0, 2, 3, 4),
                   a_tot.transpose(1, 0, 2, 3)))
    s_prev = s_prev.transpose(1, 0, 2, 3, 4)                # (b,nc,h,c,c)
    y_inter = jnp.einsum("bcqhd,bchde->bcqhe", rc * dec_in, s_prev)
    y = (y_intra + y_bonus + y_inter).reshape(b, nc * chunk, h, c)[:, :t]
    return y, s_fin


def rwkv_tmix(params: dict, x: jax.Array, cfg: ArchConfig,
              state: Optional[dict] = None):
    """RWKV6 time-mix. state (decode): {"x_prev": (B,1,D), "wkv": (B,H,C,C)}."""
    b, t, d = x.shape
    n_heads, hd = rwkv_dims(cfg)
    h = rmsnorm(x, params["norm"])
    if state is None:
        h_prev = jnp.pad(h, ((0, 0), (1, 0), (0, 0)))[:, :t]
    else:
        h_prev = state["x_prev"]
    xr, xk, xv, xg, xw = _rwkv_mix(params, h, h_prev)
    r = (xr @ params["wr"].astype(h.dtype)).reshape(b, t, n_heads, hd)
    k = (xk @ params["wk"].astype(h.dtype)).reshape(b, t, n_heads, hd)
    v = (xv @ params["wv"].astype(h.dtype)).reshape(b, t, n_heads, hd)
    g = jax.nn.silu(xg @ params["wg"].astype(h.dtype))
    wl = params["w0"] + jax.nn.tanh(
        xw @ params["wlora_a"].astype(h.dtype)).astype(jnp.float32) \
        @ params["wlora_b"].astype(jnp.float32)
    w_log = -jnp.exp(wl.astype(jnp.float32))                # (B,T,D) ≤ 0
    w_log = w_log.reshape(b, t, n_heads, hd)
    u = params["u"].reshape(n_heads, hd)

    if state is None:
        if cfg.rwkv_mode == "chunked":
            y, _ = _rwkv_chunked(r, k, v, w_log, u, cfg.ssm_chunk)
        else:
            y, _ = _rwkv_scan(r, k, v, w_log, u)
        new_state = None
    else:
        y, s = _rwkv_scan(r, k, v, w_log, u, init_state=state["wkv"])
        new_state = {"x_prev": h, "wkv": s}

    y = y.reshape(b, t, d).astype(x.dtype)
    y = rmsnorm(y.reshape(b, t, n_heads, hd),
                params["ln_w"].reshape(n_heads, hd)).reshape(b, t, d)
    out = (y * g) @ params["wo"].astype(x.dtype)
    return shard(out, BATCH_AXES, None, None), new_state


def init_rwkv_cmix(key, cfg: ArchConfig) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    dt = pdtype(cfg)
    return {
        "norm": jnp.ones((d,), jnp.float32),
        "mu": jax.random.uniform(ks[0], (2, d), jnp.float32),
        "wk": init_dense(ks[1], d, cfg.d_ff, dt),
        "wv": init_dense(ks[2], cfg.d_ff, d, dt),
        "wr": init_dense(jax.random.fold_in(key, 7), d, d, dt),
    }


def rwkv_cmix(params: dict, x: jax.Array, cfg: ArchConfig,
              state: Optional[dict] = None):
    b, t, d = x.shape
    h = rmsnorm(x, params["norm"])
    if state is None:
        h_prev = jnp.pad(h, ((0, 0), (1, 0), (0, 0)))[:, :t]
        new_state = None
    else:
        h_prev = state["x_prev"]
        new_state = {"x_prev": h}
    delta = h_prev - h
    mu = params["mu"].astype(h.dtype)
    xk = h + delta * mu[0]
    xr = h + delta * mu[1]
    kk = jnp.square(jax.nn.relu(xk @ params["wk"].astype(h.dtype)))
    kk = shard(kk, BATCH_AXES, None, "model")
    vv = kk @ params["wv"].astype(h.dtype)
    out = jax.nn.sigmoid(xr @ params["wr"].astype(h.dtype)) * vv
    return shard(out, BATCH_AXES, None, None), new_state


def rwkv_init_state(cfg: ArchConfig, batch: int) -> dict:
    n_heads, hd = rwkv_dims(cfg)
    return {
        "tmix": {"x_prev": jnp.zeros((batch, 1, cfg.d_model), cdtype(cfg)),
                 "wkv": jnp.zeros((batch, n_heads, hd, hd), jnp.float32)},
        "cmix": {"x_prev": jnp.zeros((batch, 1, cfg.d_model), cdtype(cfg))},
    }
