"""Transformer building blocks — pure-functional, pytree params.

Conventions:
  * params are nested dicts of jnp arrays; layer-stacked params have a
    leading (L, ...) axis consumed by `lax.scan` (O(1) compile in depth).
  * activations: (B, T, D); compute dtype per config (bf16 default), norms
    and softmax accumulate in f32.
  * `shard(x, *axes)` applies a sharding constraint iff a mesh is active —
    model code is mesh-agnostic and runs unsharded in unit tests.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax

from repro import compat
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig

BATCH_AXES = ("pod", "data")


def shard(x: jax.Array, *axes):
    """with_sharding_constraint that no-ops without an active mesh, drops
    axis names the mesh doesn't have, and drops axes that don't divide the
    dim (avoids GSPMD forced-remat on e.g. 8 kv heads over a 16-way axis)."""
    m = compat.get_abstract_mesh()
    if m is None or m.empty:
        return x
    sizes = dict(zip(m.axis_names, m.axis_sizes))

    def keep(dim: int, a):
        names = a if isinstance(a, (tuple, list)) else (a,)
        kept, prod = [], 1
        for n in names:
            if n is None or n not in sizes:
                continue
            if dim % (prod * sizes[n]) == 0:
                kept.append(n)
                prod *= sizes[n]
        if not kept:
            return None
        return tuple(kept) if len(kept) > 1 else kept[0]

    spec = P(*[keep(d, a) for d, a in zip(x.shape, axes)])
    return jax.lax.with_sharding_constraint(x, spec)


def act_spec(cfg: ArchConfig, kind: str):
    """Activation sharding templates per scheme (DESIGN.md §6).

    tp — Megatron-style: heads/ffn-hidden/vocab over `model`.
    sp — sequence-parallel: seq over `model`, weights FSDP over `data`;
         K/V gathered for attention (the §Perf beyond-baseline scheme).
    """
    if cfg.sharding_scheme == "sp":
        return {
            "resid": (BATCH_AXES, "model", None),
            "heads": (BATCH_AXES, "model", None, None),
            "kv": (BATCH_AXES, None, None, None),
            "ffn": (BATCH_AXES, "model", None),
            "logits": (BATCH_AXES, "model", None),
        }[kind]
    return {
        "resid": (BATCH_AXES, None, None),
        "heads": (BATCH_AXES, None, "model", None),
        "kv": (BATCH_AXES, None, "model", None),
        "ffn": (BATCH_AXES, None, "model"),
        "logits": (BATCH_AXES, None, "model"),
    }[kind]


def shard_act(x: jax.Array, cfg: ArchConfig, kind: str):
    return shard(x, *act_spec(cfg, kind))


def cdtype(cfg: ArchConfig):
    return jnp.dtype(cfg.compute_dtype)


def pdtype(cfg: ArchConfig):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# Norms / embeddings / rope
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(ms + eps)) * w.astype(jnp.float32)
            ).astype(x.dtype)


def init_dense(key, d_in: int, d_out: int, dtype, scale: float = 1.0):
    std = scale / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * std
            ).astype(dtype)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., T, H, hd), positions: (..., T)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., T, hd/2)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA / MQA, full or sliding-window, flash-style blocked)
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ArchConfig) -> dict:
    ks = jax.random.split(key, 5)
    hd, dt = cfg.head_dim, pdtype(cfg)
    return {
        "wq": init_dense(ks[0], cfg.d_model, cfg.n_heads * hd, dt),
        "wk": init_dense(ks[1], cfg.d_model, cfg.n_kv * hd, dt),
        "wv": init_dense(ks[2], cfg.d_model, cfg.n_kv * hd, dt),
        "wo": init_dense(ks[3], cfg.n_heads * hd, cfg.d_model, dt),
        "norm": jnp.ones((cfg.d_model,), jnp.float32),
    }


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, window: int = 0,
                    q_offset=0, kv_len: Optional[jax.Array] = None,
                    block: int = 512) -> jax.Array:
    """Blocked (flash-style) attention in pure JAX.

    q: (B, Tq, H, hd); k, v: (B, Tk, KV, hd). GQA via head grouping.
    window > 0 limits attention to the last `window` key positions
    (sliding-window causal).  kv_len masks a padded cache (decode).
    Memory: O(Tq × block) — required for the 32k/500k shapes.
    """
    b, tq, h, hd = q.shape
    tk, kv = k.shape[1], k.shape[2]
    g = h // kv
    scale = 1.0 / np.sqrt(hd)
    qh = (q * scale).reshape(b, tq, kv, g, hd)
    block = min(block, tk)
    nblk = -(-tk // block)
    pad = nblk * block - tk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(b, nblk, block, kv, hd)
    vb = v.reshape(b, nblk, block, kv, hd)
    qpos = (jnp.arange(tq) + q_offset)[None, :]          # (1, Tq)

    def body(carry, inp):
        m, l, acc = carry
        kblk, vblk, i = inp
        kpos = i * block + jnp.arange(block)[None, :]    # (1, block)
        s = jnp.einsum("btkgh,bskh->bkgts", qh, kblk,
                       preferred_element_type=jnp.float32)
        mask = jnp.ones((tq, block), bool)
        if causal:
            mask &= kpos <= qpos[0][:, None]
        if window > 0:
            mask &= (qpos[0][:, None] - kpos) < window
        mask &= kpos < (tk if kv_len is None else kv_len)
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(-1))
        # guard fully-masked rows (m_new = -inf): exp(-inf - -inf) -> nan
        m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(jnp.isinf(m_new)[..., None], 0.0, p)
        corr = jnp.exp(jnp.where(jnp.isinf(m), 0.0, m) - m_safe)
        corr = jnp.where(jnp.isinf(m), 0.0, corr)
        l_new = l * corr + p.sum(-1)
        pv = jnp.einsum("bkgts,bskh->btkgh", p.astype(vblk.dtype), vblk,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr.transpose(0, 3, 1, 2)[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kv, g, tq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, kv, g, tq), jnp.float32)
    a0 = jnp.zeros((b, tq, kv, g, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (kb.transpose(1, 0, 2, 3, 4), vb.transpose(1, 0, 2, 3, 4),
         jnp.arange(nblk)))
    lt = l.transpose(0, 3, 1, 2)[..., None]
    out = acc / jnp.maximum(lt, 1e-20)
    return out.reshape(b, tq, h, hd).astype(q.dtype)


def windowed_attention(q, k, v, window: int, block: int = 512):
    """Local (sliding-window causal) attention computing only the blocks a
    query block can see — O(T × window) FLOPs instead of O(T²).

    Used for gemma3's 5-of-6 local layers (beyond-paper perf feature; the
    baseline path can also run these through `flash_attention` with a mask).
    """
    b, t, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    block = min(block, t)
    w_blocks = -(-window // block) + 1
    nblk = -(-t // block)
    padq = nblk * block - t
    if padq:
        q = jnp.pad(q, ((0, 0), (0, padq), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, padq), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, padq), (0, 0), (0, 0)))
    tp = nblk * block
    scale = 1.0 / np.sqrt(hd)
    qb = (q * scale).reshape(b, nblk, block, kvh, g, hd)
    # For query block i, gather key blocks [i-w_blocks+1 .. i]
    kpad = jnp.pad(k, ((0, 0), ((w_blocks - 1) * block, 0), (0, 0), (0, 0)))
    vpad = jnp.pad(v, ((0, 0), ((w_blocks - 1) * block, 0), (0, 0), (0, 0)))

    def per_block(qi, i):
        ks = jax.lax.dynamic_slice_in_dim(kpad, i * block, w_blocks * block, 1)
        vs = jax.lax.dynamic_slice_in_dim(vpad, i * block, w_blocks * block, 1)
        s = jnp.einsum("btkgh,bskh->bkgts", qi, ks,
                       preferred_element_type=jnp.float32)
        qpos = i * block + jnp.arange(block)
        kpos = (i - w_blocks + 1) * block + jnp.arange(w_blocks * block)
        mask = (kpos[None, :] <= qpos[:, None]) & \
               (qpos[:, None] - kpos[None, :] < window) & (kpos[None, :] >= 0)
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
        m = s.max(-1, keepdims=True)
        msafe = jnp.where(jnp.isinf(m), 0.0, m)
        p = jnp.exp(s - msafe)
        p = jnp.where(jnp.isinf(m), 0.0, p)
        o = jnp.einsum("bkgts,bskh->btkgh", p.astype(vs.dtype), vs,
                       preferred_element_type=jnp.float32)
        return o / jnp.maximum(p.sum(-1), 1e-20).transpose(
            0, 3, 1, 2)[..., None]

    out = jax.lax.map(lambda args: per_block(*args),
                      (qb.transpose(1, 0, 2, 3, 4, 5), jnp.arange(nblk)))
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, tp, h, hd)
    return out[:, :t].astype(q.dtype)


def attention_block(params: dict, x: jax.Array, cfg: ArchConfig,
                    is_global: bool = True, positions=None,
                    cache: Optional[dict] = None, pos=None,
                    use_windowed_kernel: bool = False,
                    allow_pallas: bool = False):
    """Pre-norm attention. If `cache` is given, runs as one decode step
    (x: (B, 1, D)) reading/writing the cache at `pos`.  Returns (out, cache).
    """
    b, t, _ = x.shape
    hd = cfg.head_dim
    h = rmsnorm(x, params["norm"])
    q = (h @ params["wq"].astype(h.dtype)).reshape(b, t, cfg.n_heads, hd)
    k = (h @ params["wk"].astype(h.dtype)).reshape(b, t, cfg.n_kv, hd)
    v = (h @ params["wv"].astype(h.dtype)).reshape(b, t, cfg.n_kv, hd)
    q = shard_act(q, cfg, "heads")
    k = shard_act(k, cfg, "kv")
    v = shard_act(v, cfg, "kv")
    window = 0 if is_global else cfg.window
    if cache is None:
        if positions is None:
            positions = jnp.arange(t)[None, :]
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        if cfg.pallas_flash and window == 0 and allow_pallas:
            from repro.kernels.ops import flash_attention_fused
            o = flash_attention_fused(q, k, v, causal=cfg.causal)
        elif not cfg.causal:
            o = flash_attention(q, k, v, causal=False, window=0)
        elif window and use_windowed_kernel:
            o = windowed_attention(q, k, v, window)
        else:
            o = flash_attention(q, k, v, causal=True, window=window)
        new_cache = None
    else:
        # single-token decode: append to cache, attend over it
        q = apply_rope(q, pos[None, None] if pos.ndim == 0 else pos,
                       cfg.rope_theta)
        k = apply_rope(k, pos[None, None] if pos.ndim == 0 else pos,
                       cfg.rope_theta)
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, pos, axis=1) \
            if cache["k"].shape[1] != 0 else cache["k"]
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, pos, axis=1) \
            if cache["v"].shape[1] != 0 else cache["v"]
        o = flash_attention(q, ck, cv, causal=False, kv_len=pos + 1,
                            block=2048)
        new_cache = {"k": ck, "v": cv}
    o = o.reshape(b, t, cfg.n_heads * hd)
    out = o @ params["wo"].astype(o.dtype)
    return shard_act(out, cfg, "resid"), new_cache


# ---------------------------------------------------------------------------
# MLP / MoE
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ArchConfig, d_ff: Optional[int] = None) -> dict:
    ks = jax.random.split(key, 3)
    dff = d_ff or cfg.d_ff
    dt = pdtype(cfg)
    return {
        "wg": init_dense(ks[0], cfg.d_model, dff, dt),
        "wu": init_dense(ks[1], cfg.d_model, dff, dt),
        "wd": init_dense(ks[2], dff, cfg.d_model, dt),
        "norm": jnp.ones((cfg.d_model,), jnp.float32),
    }


def mlp_block(params: dict, x: jax.Array,
              cfg: ArchConfig | None = None) -> jax.Array:
    h = rmsnorm(x, params["norm"])
    g = jax.nn.silu(h @ params["wg"].astype(h.dtype))
    u = h @ params["wu"].astype(h.dtype)
    g = shard_act(g, cfg, "ffn") if cfg is not None else \
        shard(g, BATCH_AXES, None, "model")
    out = (g * u) @ params["wd"].astype(h.dtype)
    return shard_act(out, cfg, "resid") if cfg is not None else \
        shard(out, BATCH_AXES, None, None)


def init_moe(key, cfg: ArchConfig) -> dict:
    ks = jax.random.split(key, 4)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    dt = pdtype(cfg)
    std = 1.0 / np.sqrt(d)
    return {
        "router": init_dense(ks[0], d, e, jnp.float32),
        "wg": (jax.random.normal(ks[1], (e, d, f), jnp.float32) * std
               ).astype(dt),
        "wu": (jax.random.normal(ks[2], (e, d, f), jnp.float32) * std
               ).astype(dt),
        "wd": (jax.random.normal(ks[3], (e, f, d), jnp.float32)
               / np.sqrt(f)).astype(dt),
        "norm": jnp.ones((d,), jnp.float32),
    }


def moe_block(params: dict, x: jax.Array, cfg: ArchConfig,
              groups: int = 32) -> jax.Array:
    """Token-choice top-k routing with grouped capacity-factor dispatch.

    Tokens are split into G groups along the batch dim (G shards over the
    data axes), and routing/rank assignment is computed PER GROUP — so the
    sort, capacity bookkeeping, and dispatch scatter are all local to a
    data shard, and the only cross-device traffic is the EP combine
    (gather from model-sharded expert buffers ≙ the all-to-all).  Expert
    compute is a batched (G, E, C_g, d) × (E, d, f) einsum with E sharded
    over `model` (EP) and G over the data axes.
    """
    b, t, d = x.shape
    e, k = cfg.n_experts, cfg.moe_top_k
    g_ = np.gcd(b, groups)
    h = rmsnorm(x, params["norm"])
    xt = h.reshape(g_, (b // g_) * t, d)                    # (G, n_g, d)
    xt = shard(xt, BATCH_AXES, None, None)
    n_g = xt.shape[1]
    logits = jnp.einsum("gnd,de->gne", xt.astype(jnp.float32),
                        params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate, sel = jax.lax.top_k(probs, k)                     # (G, n_g, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    cap = int(np.ceil(n_g * k / e * cfg.capacity_factor))
    cap = max(8, -(-cap // 8) * 8)

    def route_one(sel_g, x_g):
        """Per-group local dispatch (vmapped over G)."""
        e_flat = sel_g.reshape(-1)                          # (n_g·k,)
        order = jnp.argsort(e_flat)
        sorted_e = e_flat[order]
        counts = jnp.bincount(e_flat, length=e)
        seg_start = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                                     jnp.cumsum(counts)[:-1]])
        rank = jnp.arange(n_g * k) - seg_start[sorted_e]
        keep = rank < cap
        tok = order // k
        buf = jnp.zeros((e, cap, d), x_g.dtype)
        buf = buf.at[sorted_e, jnp.minimum(rank, cap - 1)].add(
            jnp.where(keep[:, None], x_g[tok], 0))
        return buf, (sorted_e, rank, keep, tok, order)

    buf, route = jax.vmap(route_one)(sel, xt)               # (G, E, C, d)
    buf = shard(buf, BATCH_AXES, "model", None, None)

    gg = jnp.einsum("gecd,edf->gecf", buf, params["wg"].astype(buf.dtype))
    uu = jnp.einsum("gecd,edf->gecf", buf, params["wu"].astype(buf.dtype))
    oo = jnp.einsum("gecf,efd->gecd", jax.nn.silu(gg) * uu,
                    params["wd"].astype(buf.dtype))
    oo = shard(oo, BATCH_AXES, "model", None, None)

    def combine_one(o_g, x_g, gate_g, r):
        sorted_e, rank, keep, tok, order = r
        vals = o_g[sorted_e, jnp.minimum(rank, cap - 1)]    # (n_g·k, d)
        w = gate_g.reshape(-1)[order]
        return jnp.zeros((n_g, d), x_g.dtype).at[tok].add(
            jnp.where(keep[:, None], vals * w[:, None].astype(vals.dtype),
                      0))

    if cfg.moe_local_combine and _model_axis_size() > 1:
        out = _ep_local_combine(oo, xt, gate, route, cap, n_g, d)
    else:
        out = jax.vmap(combine_one)(oo, xt, gate, route)
    out = out.reshape(b, t, d)
    return shard(out, BATCH_AXES, None, None)


def _model_axis_size() -> int:
    m = compat.get_abstract_mesh()
    if m is None or m.empty or "model" not in m.axis_names:
        return 1
    return dict(zip(m.axis_names, m.axis_sizes))["model"]


def _ep_local_combine(oo, xt, gate, route, cap: int, n_g: int, d: int):
    """EP combine with per-shard partial reduction (§Perf cell A it4).

    GSPMD's default plan all-reduces the per-(token, choice) expert outputs
    — (n_g·k, d) bytes.  Summing each shard's k-subset LOCALLY first and
    psumming the (n_g, d) partials moves k× fewer bytes across the `model`
    axis.  Implemented as a manual shard_map over `model` (data/pod stay
    auto-sharded).
    """
    mesh = compat.get_abstract_mesh()

    def local(oo_l, w_, se, rk, kp, tk):
        # oo_l: (G, E/shard, C, d) — this shard's experts only
        ax = jax.lax.axis_index("model")
        e_loc = oo_l.shape[1]
        in_shard = (se - ax * e_loc >= 0) & (se - ax * e_loc < e_loc) & kp

        def one(o_g, w_g, se_g, rk_g, ok_g, tk_g):
            vals = o_g[jnp.clip(se_g - ax * e_loc, 0, e_loc - 1),
                       jnp.minimum(rk_g, cap - 1)]           # (n_g·k, d)
            return jnp.zeros((n_g, d), vals.dtype).at[tk_g].add(
                jnp.where(ok_g[:, None],
                          vals * w_g[:, None].astype(vals.dtype), 0))

        out = jax.vmap(one)(oo_l, w_, se, rk, in_shard, tk)
        return jax.lax.psum(out, "model")

    sorted_e, rank, keep, tok, order = route
    # gate weight aligned with the sorted (token, choice) order
    w_sorted = jnp.take_along_axis(gate.reshape(gate.shape[0], -1), order,
                                   axis=1)
    baxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    g_spec = baxes if len(baxes) > 1 else (baxes[0] if baxes else None)
    fn = compat.shard_map(
        local, mesh=mesh,
        in_specs=(P(g_spec, "model", None, None), P(g_spec), P(g_spec),
                  P(g_spec), P(g_spec), P(g_spec)),
        out_specs=P(g_spec), check_vma=False)
    return fn(oo, w_sorted, sorted_e, rank, keep, tok)


# ---------------------------------------------------------------------------
# LM head / embeddings
# ---------------------------------------------------------------------------

def init_embeddings(key, cfg: ArchConfig) -> dict:
    k1, k2 = jax.random.split(key)
    dt = pdtype(cfg)
    p = {"tok": (jax.random.normal(k1, (cfg.vocab, cfg.d_model), jnp.float32)
                 * 0.02).astype(dt),
         "final_norm": jnp.ones((cfg.d_model,), jnp.float32)}
    if not cfg.tie_embeddings:
        p["unembed"] = init_dense(k2, cfg.d_model, cfg.vocab, dt, scale=0.5)
    return p


def embed_tokens(params: dict, tokens: jax.Array, cfg: ArchConfig):
    x = params["tok"].astype(cdtype(cfg))[tokens]
    return shard_act(x, cfg, "resid")


def lm_logits(params: dict, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    h = rmsnorm(x, params["final_norm"])
    w = (params["tok"].T if cfg.tie_embeddings else params["unembed"])
    logits = h @ w.astype(h.dtype)
    return shard_act(logits, cfg, "logits")


def softmax_xent(logits: jax.Array, targets: jax.Array,
                 mask: jax.Array) -> jax.Array:
    """Cross-entropy that stays partitionable when the vocab dim is sharded:
    the label log-prob is an einsum against a (fused) one-hot instead of a
    take_along_axis gather — GSPMD turns the V-reduction into a local
    partial sum + psum rather than all-gathering the (B, T, V) logits
    (EXPERIMENTS.md §Perf iteration 1)."""
    logits = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=logits.dtype)
    ll = jnp.einsum("...v,...v->...", shifted, onehot) + m[..., 0]
    loss = (lse - ll) * mask
    return loss.sum() / jnp.maximum(mask.sum(), 1.0)
