"""RWKV6 ("Finch") language model: attention-free, data-dependent decay."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import (cdtype, embed_tokens, init_embeddings,
                                 lm_logits, softmax_xent)
from repro.models.ssm import (init_rwkv_cmix, init_rwkv_tmix, rwkv_cmix,
                              rwkv_init_state, rwkv_tmix)
from repro.models.transformer import _remat


def init_rwkv_lm(key, cfg: ArchConfig) -> dict:
    ke, kl = jax.random.split(key)

    def init_layer(k):
        kt, kc = jax.random.split(k)
        return {"tmix": init_rwkv_tmix(kt, cfg),
                "cmix": init_rwkv_cmix(kc, cfg)}

    layers = jax.vmap(init_layer)(jax.random.split(kl, cfg.n_layers))
    return {"embed": init_embeddings(ke, cfg), "layers": layers}


def forward(params: dict, cfg: ArchConfig, tokens: jax.Array) -> jax.Array:
    x = embed_tokens(params["embed"], tokens, cfg).astype(cdtype(cfg))

    def layer_fn(x, lp):
        t, _ = rwkv_tmix(lp["tmix"], x, cfg)
        x = x + t
        c, _ = rwkv_cmix(lp["cmix"], x, cfg)
        return x + c, None

    x, _ = jax.lax.scan(_remat(layer_fn, cfg), x, params["layers"])
    return x


def rwkv_loss(params: dict, batch: dict, cfg: ArchConfig) -> jax.Array:
    x = forward(params, cfg, batch["tokens"])
    logits = lm_logits(params["embed"], x, cfg)
    return softmax_xent(logits, batch["targets"], batch["mask"])


def init_rwkv_cache(cfg: ArchConfig, batch: int, seq_len: int) -> dict:
    """O(1) state per layer — seq_len-independent (the point of the arch)."""
    one = rwkv_init_state(cfg, batch)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), one)


def rwkv_decode_step(params: dict, cache: dict, tokens: jax.Array,
                     pos, cfg: ArchConfig):
    x = embed_tokens(params["embed"], tokens, cfg).astype(cdtype(cfg))

    def layer_fn(x, xs):
        lp, st = xs
        t, ts = rwkv_tmix(lp["tmix"], x, cfg, state=st["tmix"])
        x = x + t
        c, cs = rwkv_cmix(lp["cmix"], x, cfg, state=st["cmix"])
        return x + c, {"tmix": ts, "cmix": cs}

    x, new_cache = jax.lax.scan(layer_fn, x, (params["layers"], cache))
    logits = lm_logits(params["embed"], x, cfg)
    return logits, new_cache


def rwkv_prefill(params: dict, cfg: ArchConfig, tokens: jax.Array):
    x = forward(params, cfg, tokens)
    return lm_logits(params["embed"], x[:, -1:], cfg)
