from repro.serving.engine import ServeEngine
from repro.serving.rag import RetrievalAugmentedServer

__all__ = ["ServeEngine", "RetrievalAugmentedServer"]
