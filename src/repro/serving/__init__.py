from repro.serving.engine import ServeEngine
from repro.serving.continuous import (ContinuousServer, FairQueue, Request,
                                      SlotPool, results_in_order)
from repro.serving.rag import (LadderRung, RetrievalAugmentedServer,
                               admission_floor, bucket_deadline,
                               default_ladder, price_ladder)

__all__ = ["ServeEngine", "RetrievalAugmentedServer", "LadderRung",
           "admission_floor", "bucket_deadline", "default_ladder",
           "price_ladder", "ContinuousServer", "FairQueue", "Request",
           "SlotPool", "results_in_order"]
