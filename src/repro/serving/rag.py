"""Retrieval-augmented serving: the paper's FVS as a first-class feature.

The server pairs an LM (any assigned architecture) with a filtered vector
search *executor* (core/executor.py): at request time it embeds the prompt
(mean-pooled hidden state projected into store space), runs FILTERED top-k
retrieval (the request's structured predicate becomes the bitmap — e.g.
tenant id, document freshness), and splices retrieved rows into the
context.  This is the e-commerce query of the paper's introduction, served
end to end.

Any Executor works: a local `ScannExecutor`/`GraphExecutor`, the
`AdaptivePlanner` (the server then picks the strategy per batch), or the
mesh-sharded `DistributedScannExecutor` — the server never hard-codes an
index type.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.executor import Executor
from repro.core.types import SearchParams, SearchResult
from repro.models.api import ModelBundle


@dataclasses.dataclass
class RetrievalResult:
    ids: np.ndarray        # (B, k) retrieved row ids
    dists: np.ndarray      # (B, k)
    tokens: np.ndarray     # (B, P + k*chunk) augmented prompts
    strategy: str          # strategy that served the batch (planner-aware)


class RetrievalAugmentedServer:
    def __init__(self, bundle: ModelBundle, params, executor: Executor,
                 search_params: SearchParams,
                 doc_tokens: np.ndarray, chunk_len: int = 32,
                 embed_fn: Optional[Callable] = None):
        """doc_tokens: (N, chunk_len) token rows aligned with store rows."""
        self.bundle = bundle
        self.params = params
        self.executor = executor
        self.search_params = search_params
        self.k = search_params.k
        self.doc_tokens = doc_tokens
        self.chunk_len = chunk_len
        dim = executor.store.dim
        if embed_fn is None:
            d_model = bundle.cfg.d_model
            key = jax.random.PRNGKey(7)
            proj = jax.random.normal(key, (d_model, dim),
                                     jnp.float32) / np.sqrt(d_model)

            def embed_fn(p, tokens):
                emb = p["embed"]["tok"].astype(jnp.float32)[tokens]
                return jnp.mean(emb, axis=1) @ proj

        self._embed = jax.jit(embed_fn)

    def retrieve(self, prompts: np.ndarray,
                 bitmaps: jax.Array) -> RetrievalResult:
        """prompts (B, P) int32; bitmaps (B, words) — the evaluated filter."""
        q = self._embed(self.params, jnp.asarray(prompts))
        res: SearchResult = self.executor.search(q, bitmaps,
                                                 self.search_params)
        idn = np.asarray(res.ids)
        chunks = self.doc_tokens[np.maximum(idn, 0)]       # (B, k, chunk)
        chunks = np.where((idn >= 0)[..., None], chunks, 0)
        aug = np.concatenate(
            [chunks.reshape(idn.shape[0], -1), prompts], axis=1)
        return RetrievalResult(ids=idn, dists=np.asarray(res.dists),
                               tokens=aug.astype(np.int32),
                               strategy=res.strategy)
