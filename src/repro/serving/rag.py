"""Retrieval-augmented serving: the paper's FVS as a first-class feature.

The server pairs an LM (any assigned architecture) with a filtered vector
search *executor* (core/executor.py): at request time it embeds the prompt
(mean-pooled hidden state projected into store space), runs FILTERED top-k
retrieval (the request's structured predicate becomes the bitmap — e.g.
tenant id, document freshness), and splices retrieved rows into the
context.  This is the e-commerce query of the paper's introduction, served
end to end.

Any Executor works: a local `ScannExecutor`/`GraphExecutor`, the
`AdaptivePlanner` (the server then picks the strategy per batch), or the
mesh-sharded `DistributedScannExecutor` — the server never hard-codes an
index type.

Under heavy traffic the server batches its request queue, and HOW it
batches decides buffer-pool locality (ROADMAP "frontier-union overlap"
item, DESIGN.md §8): `serve_queue(policy="centroid")` clusters queued
requests by their nearest ScaNN centroid before dispatch, so queries
landing in the same leaves share a batch — their leaf opens, frontier
unions, and reorder fetches hit the same pages.  The executor's
StorageEngine (buffer pool) persists across request batches, so the
hit-rate lift vs FIFO batching is directly measurable
(benchmarks/bench_storage.py).
"""
from __future__ import annotations

import dataclasses
import functools
import math
import warnings
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costmodel
from repro.core.executor import (AdaptivePlanner, BruteForceExecutor,
                                 Executor, GraphExecutor, ScannExecutor,
                                 index_shape)
from repro.core.types import (SearchParams, SearchResult,
                              heap_pages_per_vector)
from repro.models.api import ModelBundle

BATCH_POLICIES = ("fifo", "centroid")


@dataclasses.dataclass
class RetrievalResult:
    ids: np.ndarray        # (B, k) retrieved row ids
    dists: np.ndarray      # (B, k)
    tokens: np.ndarray     # (B, P + k*chunk) augmented prompts
    strategy: str          # strategy that served the batch (planner-aware)


def find_scann_index(executor: Executor):
    """The ScaNN index an executor routes with, if it has one (duck-typed:
    ScannExecutor, AdaptivePlanner with a scann candidate, or the
    mesh-sharded executor)."""
    idx = getattr(executor, "index", None)
    if idx is not None:
        return idx
    scann_ex = getattr(executor, "_scann", None)       # AdaptivePlanner
    if scann_ex is not None:
        return scann_ex.index
    sharded = getattr(executor, "sharded", None)       # distributed
    if sharded is not None:
        return sharded.index
    return None


@jax.jit
def nearest_centroid(index, queries):
    """Leaf-centroid id nearest to each (already-embedded) query — the
    routing key of the centroid batch policy.  (Q,) int32.  Metric-aware
    (same ranking as `scann._select_leaves`): the routing key must be the
    leaf the query will actually open, under L2 AND IP indexes."""
    from repro.core.scann import project_query
    from repro.core.types import distance
    qp = project_query(index, queries)
    cents = index.leaf_centroids
    d = distance(index.metric, qp[:, None, :], cents[None, :, :],
                 jnp.sum(cents * cents, -1)[None, :])
    return jnp.argmin(d, axis=-1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Graceful degradation (DESIGN.md §10): deadline buckets, admission
# control, and the rung ladder serve_queue walks under budget/fault
# pressure.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LadderRung:
    """One rung of the graceful-degradation ladder: which executor serves
    the rung and how the request's SearchParams degrade on it.  Rung 0 is
    always the primary executor with untouched params; each later rung
    trades recall/precision for a cheaper, more fault-tolerant plan."""

    name: str
    executor: Executor
    adjust: Optional[Callable[[SearchParams], SearchParams]] = None

    def resolve(self, params: SearchParams) -> SearchParams:
        return self.adjust(params) if self.adjust is not None else params


def _find_graph_executor(executor: Executor) -> Optional[GraphExecutor]:
    if isinstance(executor, GraphExecutor):
        return executor
    if isinstance(executor, AdaptivePlanner):
        gs = [ex for ex in executor.candidates.values()
              if isinstance(ex, GraphExecutor)]
        for g in gs:
            if g.graph_quant == "sq8":
                return g
        return gs[0] if gs else None
    return None


def _find_scann_executor(executor: Executor) -> Optional[ScannExecutor]:
    if isinstance(executor, ScannExecutor):
        return executor
    if isinstance(executor, AdaptivePlanner):
        return executor._scann
    return None


def default_ladder(executor: Executor) -> list[LadderRung]:
    """The standard ladder for whatever the primary executor supports:

        primary  ->  sq8_norerank  ->  scann_lite  ->  partial_scan

    sq8_norerank reruns the graph traversal on the SQ8 shadow tier with
    the exact rerank off (cheapest graph answer); scann_lite halves the
    opened leaves; partial_scan is BruteForceExecutor's budgeted prefix
    seqscan — always available, always returns a flagged-but-usable
    top-k.  Rungs whose components the executor lacks are skipped."""
    rungs = [LadderRung("primary", executor)]
    g = _find_graph_executor(executor)
    if g is not None and (g.graph_quant == "sq8"
                          or g.store.q_vectors is not None):
        sq8 = g if g.graph_quant == "sq8" else GraphExecutor(
            g.graph, g.store, strategy=g.strategy, use_pallas=g.use_pallas,
            storage=g.storage, graph_quant="sq8")
        rungs.append(LadderRung(
            "sq8_norerank", sq8,
            lambda p: dataclasses.replace(p, sq8_rerank=False)))
    sc = _find_scann_executor(executor)
    if sc is not None:
        rungs.append(LadderRung(
            "scann_lite", sc,
            lambda p: dataclasses.replace(
                p, num_leaves_to_search=max(
                    1, p.num_leaves_to_search // 2))))
    store = executor.store
    bf = BruteForceExecutor(store,
                            storage=getattr(executor, "storage", None))
    ppv = heap_pages_per_vector(store.dim)

    def _partial(p: SearchParams) -> SearchParams:
        # a budgetless request still gets a PARTIAL scan on the last rung
        # (~10% of the heap, never below k rows) — the rung exists to be
        # cheap, not to silently fall back to a full exact scan
        if p.page_budget > 0 or p.deadline_cycles > 0:
            return p
        return dataclasses.replace(
            p, page_budget=max(p.k, store.n // 10) * ppv)

    rungs.append(LadderRung("partial_scan", bf, _partial))
    return rungs


def bucket_deadline(deadline: float) -> float:
    """Floor a per-request deadline (modeled cycles) to 2 significant
    figures.  SearchParams is a static jit argument, so every distinct
    deadline value compiles a fresh program — bucketing keeps the compile
    cache small; flooring keeps the bucket conservative (never serves
    with MORE budget than the request asked for)."""
    if not math.isfinite(deadline) or deadline <= 0:
        return 0.0
    exp = math.floor(math.log10(deadline))
    scale = 10.0 ** (exp - 1)
    return float(math.floor(deadline / scale + 1e-9) * scale)


@functools.lru_cache(maxsize=256)
def _admission_floor_cached(n: int, dim: int, k: int,
                            constants) -> float:
    w = costmodel.budget_cycle_weights(dim, constants)
    ppv = heap_pages_per_vector(dim)
    return (n * w["filter_checks"]
            + k * (w["distance_comps"] + ppv * w["page_accesses_heap"]))


def admission_floor(store, params: SearchParams,
                    constants=costmodel.SYSTEM) -> float:
    """Cheapest possible service in modeled cycles: the last rung's
    minimal partial scan (probe every filter bit, fetch+score k rows).
    A request whose deadline is below this cannot be served at ANY rung
    and is rejected at admission rather than burning pool bandwidth.

    Memoized on the values it actually depends on — (store.n, store.dim,
    params.k, constants) — because continuous admission recomputes it per
    arrival (CostConstants is frozen/hashable; `store` identity is
    irrelevant beyond its shape)."""
    return _admission_floor_cached(store.n, store.dim, params.k, constants)


def price_ladder(rungs: list[LadderRung], params: SearchParams,
                 selectivity: float, batch_q: int = 16,
                 constants=costmodel.SYSTEM) -> dict[str, float]:
    """Modeled per-query cycles of each priceable rung
    (costmodel.predict_cycles) — the AdaptivePlanner's prediction
    machinery reused to price degradation instead of strategy choice.
    Planner rungs are skipped (their price depends on their own
    dispatch); the dict is telemetry for admission/bench, not a
    decision boundary."""
    sc = next((r.executor for r in rungs
               if isinstance(r.executor, ScannExecutor)), None)
    prices: dict[str, float] = {}
    for r in rungs:
        ex = r.executor
        if isinstance(ex, AdaptivePlanner):
            continue
        if isinstance(ex, ScannExecutor):
            kind = "scann"
        elif isinstance(ex, BruteForceExecutor):
            # budget-aware: a partial scan is priced on the rows its
            # budget affords (mirrors BruteForceExecutor._budget_rows),
            # not on a full seqscan
            p = r.resolve(params)
            n = ex.store.n
            ppv = heap_pages_per_vector(ex.store.dim)
            w = costmodel.budget_cycle_weights(ex.store.dim, constants)
            rows = selectivity * n
            if p.page_budget > 0:
                rows = min(rows, p.page_budget // ppv)
            if p.deadline_cycles > 0:
                per = w["distance_comps"] + ppv * w["page_accesses_heap"]
                rows = min(rows, max(p.deadline_cycles
                                     - n * w["filter_checks"], 0.0) / per)
            rows = max(min(rows, n), p.k)
            prices[r.name] = (n * w["filter_checks"]
                              + rows * (w["distance_comps"]
                                        + ppv * w["page_accesses_heap"]))
            continue
        elif isinstance(ex, GraphExecutor):
            kind = ex.strategy
        else:
            continue
        p = r.resolve(params)
        gm = 16
        if isinstance(ex, GraphExecutor):
            gm = int(ex.graph.neighbors.shape[2])
            p = dataclasses.replace(p, strategy=ex.strategy,
                                    graph_quant=ex.graph_quant)
        shape = index_shape(ex.store,
                            sc.index if sc is not None else None,
                            graph_m=gm)
        try:
            prices[r.name] = costmodel.predict_cycles(
                kind, shape, p, selectivity, constants=constants,
                batch_q=batch_q)
        except ValueError:
            continue
    return prices


class RetrievalAugmentedServer:
    def __init__(self, bundle: ModelBundle, params, executor: Executor,
                 search_params: SearchParams,
                 doc_tokens: np.ndarray, chunk_len: int = 32,
                 embed_fn: Optional[Callable] = None):
        """doc_tokens: (N, chunk_len) token rows aligned with store rows."""
        self.bundle = bundle
        self.params = params
        self.executor = executor
        self.search_params = search_params
        self.k = search_params.k
        self.doc_tokens = doc_tokens
        self.chunk_len = chunk_len
        dim = executor.store.dim
        if embed_fn is None:
            d_model = bundle.cfg.d_model
            key = jax.random.PRNGKey(7)
            proj = jax.random.normal(key, (d_model, dim),
                                     jnp.float32) / np.sqrt(d_model)

            def embed_fn(p, tokens):
                emb = p["embed"]["tok"].astype(jnp.float32)[tokens]
                return jnp.mean(emb, axis=1) @ proj

        self._embed = jax.jit(embed_fn)

    def _augment(self, idn: np.ndarray, prompts: np.ndarray) -> np.ndarray:
        chunks = self.doc_tokens[np.maximum(idn, 0)]       # (B, k, chunk)
        chunks = np.where((idn >= 0)[..., None], chunks, 0)
        aug = np.concatenate(
            [chunks.reshape(idn.shape[0], -1), prompts], axis=1)
        return aug.astype(np.int32)

    @staticmethod
    def _validate_queue(prompts: np.ndarray, bitmaps) -> None:
        if prompts.ndim != 2:
            raise ValueError(
                f"prompts must be (B, P) token rows, got shape "
                f"{prompts.shape}")
        if prompts.shape[0] == 0:
            raise ValueError("empty request queue (B=0): nothing to "
                             "serve — submit at least one prompt")
        if bitmaps.ndim != 2 or bitmaps.shape[0] != prompts.shape[0]:
            raise ValueError(
                f"prompts/bitmaps length mismatch: {prompts.shape[0]} "
                f"prompts vs {np.shape(bitmaps)[0] if np.ndim(bitmaps) else 0} "
                f"bitmaps — every request needs exactly one filter bitmap "
                f"row")

    def retrieve(self, prompts: np.ndarray,
                 bitmaps: jax.Array) -> RetrievalResult:
        """prompts (B, P) int32; bitmaps (B, words) — the evaluated filter."""
        prompts = np.asarray(prompts)
        bitmaps = jnp.asarray(bitmaps)
        self._validate_queue(prompts, bitmaps)
        q = self._embed(self.params, jnp.asarray(prompts))
        res: SearchResult = self.executor.search(q, bitmaps,
                                                 self.search_params)
        idn = np.asarray(res.ids)
        return RetrievalResult(ids=idn, dists=np.asarray(res.dists),
                               tokens=self._augment(idn, prompts),
                               strategy=res.strategy)

    def serve_queue(self, prompts: np.ndarray, bitmaps: jax.Array,
                    batch_size: int = 16, policy: str = "centroid",
                    deadlines: Optional[np.ndarray] = None,
                    ladder: Optional[list[LadderRung]] = None,
                    admit: bool = True) -> tuple[RetrievalResult, dict]:
        """Serve a whole request queue in dispatch batches.

        policy "fifo" batches requests in arrival order; "centroid"
        (the serving-layer routing policy, DESIGN.md §8) sorts the queue
        by each embedded query's nearest ScaNN leaf centroid first, so
        requests that will open the same leaves (and walk the same graph
        neighborhoods) share a batch — raising buffer-pool hit rates and
        frontier-union overlap.  When the executor has no ScaNN index to
        route with, "centroid" falls back to "fifo" LOUDLY: a
        RuntimeWarning fires and info records policy_effective="fifo"
        with the reason — never a silently different batching than asked
        for.  Results are returned in arrival order either way, and for
        FIXED executors ids/dists are policy-invariant (each query's
        result depends only on the query itself).  An AdaptivePlanner
        executor picks its strategy per dispatch batch from batch-level
        selectivity estimates, so regrouping the queue can change which
        strategy serves a query — same recall target, not bit-identical
        results.

        Robust serving (DESIGN.md §10): `deadlines` gives each request a
        budget in modeled cycles (0/inf = none).  Deadlines are floored
        to 2-significant-figure buckets (`bucket_deadline` — SearchParams
        is a static jit arg, so distinct deadlines mean distinct
        programs) and requests dispatch bucket by bucket.  Requests whose
        deadline cannot cover even the minimal partial scan
        (`admission_floor`) are rejected at admission (`admit=False`
        disables this) — ids stay -1 and info flags them, they never
        reach an executor.  Each dispatch batch then walks the
        degradation `ladder` (default: `default_ladder(executor)`):
        requests that come back FAULTED (a storage read that never
        completed — StorageStats.faulted) are retried once on the primary
        rung; requests still faulted or budget-exhausted descend rung by
        rung (f32 graph -> sq8-no-rerank -> scann-lite -> partial scan)
        until one serves them cleanly or the ladder ends, in which case
        the last rung's flagged partial answer is returned.  Every
        request therefore ends with either k results or an explicit
        degraded/truncated/rejected marking in info.  With no deadlines,
        a fault-free pool, and no budgets in search_params, the ladder
        never engages and the dispatch loop is exactly the classic one
        (bit-identical results).

        Returns (RetrievalResult in arrival order, info) where info
        carries the dispatch order, per-batch strategies, per-request
        rung/flag telemetry, and the executor's storage telemetry delta
        when a StorageEngine is attached (the pool persists across
        batches — warm serving).
        """
        if policy not in BATCH_POLICIES:
            raise ValueError(
                f"unknown policy {policy!r}; one of {BATCH_POLICIES}")
        prompts = np.asarray(prompts)
        bitmaps = jnp.asarray(bitmaps)
        self._validate_queue(prompts, bitmaps)
        q = self._embed(self.params, jnp.asarray(prompts))
        nreq = q.shape[0]
        order = np.arange(nreq)
        policy_effective = policy
        fallback_reason = None
        if policy == "centroid":
            index = find_scann_index(self.executor)
            if index is None:
                fallback_reason = ("centroid batching needs an executor "
                                   "with a ScaNN index; serving FIFO "
                                   "instead")
                warnings.warn(fallback_reason, RuntimeWarning,
                              stacklevel=2)
                policy_effective = "fifo"
            else:
                keys = np.asarray(nearest_centroid(index, q))
                order = np.argsort(keys, kind="stable")
        if ladder is None:
            ladder = default_ladder(self.executor)
        # -- admission + deadline buckets -------------------------------
        buckets = np.zeros(nreq)
        admitted = np.ones(nreq, bool)
        if deadlines is not None:
            deadlines = np.asarray(deadlines, np.float64).reshape(-1)
            if deadlines.shape[0] != nreq:
                raise ValueError(
                    f"deadlines length mismatch: {deadlines.shape[0]} "
                    f"deadlines vs {nreq} requests")
            buckets = np.array([bucket_deadline(d) for d in deadlines])
            if admit:
                floor = admission_floor(self.executor.store,
                                        self.search_params)
                admitted = (buckets <= 0) | (buckets >= floor)
        k = self.k
        ids = np.full((nreq, k), -1, np.int32)
        dists = np.full((nreq, k), np.inf, np.float32)
        rung_names = np.full(nreq, "rejected", object)
        rung_level = np.full(nreq, -1, np.int32)
        truncated = np.zeros(nreq, bool)
        exhausted = np.zeros(nreq, bool)
        faulted = np.zeros(nreq, bool)
        retried = np.zeros(nreq, bool)
        strategies = []
        # NB: `is not None`, not truthiness — BufferPool defines __len__,
        # so an empty (freshly reset) pool is falsy
        pool = getattr(getattr(self.executor, "storage", None), "pool",
                       None)
        h0, m0 = (pool.counters.hits, pool.counters.misses) \
            if pool is not None else (0, 0)
        bm_np = np.asarray(bitmaps)
        # distinct (rung, resolved-params, batch-width) jit cache keys
        # this call would populate — the compile-cost telemetry the
        # deadline bucketing exists to bound (DESIGN.md §10/§11)
        compile_keys: set = set()
        order_adm = order[admitted[order]]
        for b in sorted(set(buckets[order_adm].tolist())):
            idxs = order_adm[buckets[order_adm] == b]
            params = self.search_params
            if b > 0:
                params = dataclasses.replace(params,
                                             deadline_cycles=float(b))
            for s in range(0, len(idxs), batch_size):
                sel = idxs[s:s + batch_size]
                strategies.append(self._ladder_dispatch(
                    q, bm_np, sel, params, ladder,
                    ids, dists, rung_names, rung_level,
                    truncated, exhausted, faulted, retried,
                    compile_keys))
        degraded = (rung_level > 0) | truncated | exhausted | faulted
        info = {"order": order, "strategies": strategies, "policy": policy,
                "policy_effective": policy_effective,
                "ladder": [r.name for r in ladder],
                "rung": rung_names, "rung_level": rung_level,
                "admitted": admitted, "deadline_bucket": buckets,
                "truncated": truncated, "budget_exhausted": exhausted,
                "faulted": faulted, "retried": retried,
                "degraded": degraded, "compiles": len(compile_keys)}
        if fallback_reason is not None:
            info["policy_fallback_reason"] = fallback_reason
        if pool is not None:
            dh = pool.counters.hits - h0
            dm = pool.counters.misses - m0
            info["pool_hits"] = dh
            info["pool_misses"] = dm
            info["pool_hit_rate"] = dh / max(dh + dm, 1)
            info["pool_retries"] = pool.counters.retries
            info["pool_failed_reads"] = pool.counters.failed_reads
            info["pool_spikes"] = pool.counters.spikes
        strategy = strategies[0] if len(set(strategies)) == 1 else "mixed"
        if not strategies:
            strategy = "rejected"
        return RetrievalResult(ids=ids, dists=dists,
                               tokens=self._augment(ids, prompts),
                               strategy=strategy), info

    def _ladder_dispatch(self, q, bm_np, sel, params, ladder,
                         ids, dists, rung_names, rung_level,
                         truncated, exhausted, faulted, retried,
                         compile_keys: Optional[set] = None) -> str:
        """Serve one dispatch batch, walking the degradation ladder for
        requests that come back faulted or budget-exhausted.  Scatters
        results/flags into the queue-level output arrays; returns the
        primary rung's strategy name (the batch's nominal strategy).
        `compile_keys` accumulates the distinct (rung, resolved params,
        batch width) combinations dispatched — each is one potential jit
        cache entry (SearchParams and the batch shape are static args)."""
        pend = np.asarray(sel)
        batch_strategy = None
        for level, rung in enumerate(ladder):
            if not len(pend):
                break
            rp = rung.resolve(params)
            if compile_keys is not None:
                compile_keys.add((rung.name, rp, len(pend)))
            res = self._run_rung(rung, q, bm_np, pend, rp)
            if level == 0:
                batch_strategy = res.strategy
                f, _ = self._flags(res, len(pend))
                if f.any():
                    # transient faults: one retry on the primary rung
                    # before any degradation (the injector's counter has
                    # advanced, so the retry draws a fresh schedule)
                    bad = pend[f]
                    if compile_keys is not None:
                        compile_keys.add((rung.name, rp, len(bad)))
                    res2 = self._run_rung(rung, q, bm_np, bad, rp)
                    self._scatter(res2, bad, level, rung.name, ids, dists,
                                  rung_names, rung_level, truncated,
                                  exhausted, faulted)
                    retried[bad] = True
                    ok = pend[~f]
                    if len(ok):
                        self._scatter(self._subset(res, ~f), ok, level,
                                      rung.name, ids, dists, rung_names,
                                      rung_level, truncated, exhausted,
                                      faulted)
                    pend = pend[faulted[pend] | exhausted[pend]]
                    continue
            self._scatter(res, pend, level, rung.name, ids, dists,
                          rung_names, rung_level, truncated, exhausted,
                          faulted)
            pend = pend[faulted[pend] | exhausted[pend]]
        return batch_strategy

    def _run_rung(self, rung: LadderRung, q, bm_np, sel,
                  params: SearchParams) -> SearchResult:
        gather = jnp.asarray(sel)
        return rung.executor.search(q[gather], jnp.asarray(bm_np[sel]),
                                    params)

    @staticmethod
    def _flags(res: SearchResult, m: int) -> tuple[np.ndarray, np.ndarray]:
        """(faulted, budget_exhausted) bool masks of one rung's result."""
        f = np.zeros(m, bool)
        st = res.storage
        if st is not None and getattr(st, "faulted", None) is not None:
            f = np.asarray(st.faulted, bool).copy()
        b = np.zeros(m, bool)
        if res.anytime is not None:
            b = np.asarray(res.anytime.budget_exhausted, bool).copy()
        return f, b

    @staticmethod
    def _subset(res: SearchResult, mask: np.ndarray) -> SearchResult:
        """Row-select a SearchResult's per-query fields (enough for
        scatter: ids/dists/anytime/storage.faulted)."""
        anytime = res.anytime
        if anytime is not None:
            anytime = dataclasses.replace(
                anytime,
                truncated=np.asarray(anytime.truncated)[mask],
                budget_exhausted=np.asarray(
                    anytime.budget_exhausted)[mask],
                completion=np.asarray(anytime.completion)[mask])
        storage = res.storage
        if storage is not None and getattr(storage, "faulted",
                                           None) is not None:
            storage = dataclasses.replace(
                storage, faulted=np.asarray(storage.faulted)[mask])
        return dataclasses.replace(
            res, ids=np.asarray(res.ids)[mask],
            dists=np.asarray(res.dists)[mask], anytime=anytime,
            storage=storage)

    def _scatter(self, res: SearchResult, sel: np.ndarray, level: int,
                 name: str, ids, dists, rung_names, rung_level,
                 truncated, exhausted, faulted) -> None:
        ids[sel] = np.asarray(res.ids)
        dists[sel] = np.asarray(res.dists)
        rung_names[sel] = name
        rung_level[sel] = level
        f, b = self._flags(res, len(sel))
        faulted[sel] = f
        exhausted[sel] = b
        if res.anytime is not None:
            truncated[sel] = np.asarray(res.anytime.truncated, bool)
        else:
            truncated[sel] = False
