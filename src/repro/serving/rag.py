"""Retrieval-augmented serving: the paper's FVS as a first-class feature.

The server pairs an LM (any assigned architecture) with a filtered vector
search *executor* (core/executor.py): at request time it embeds the prompt
(mean-pooled hidden state projected into store space), runs FILTERED top-k
retrieval (the request's structured predicate becomes the bitmap — e.g.
tenant id, document freshness), and splices retrieved rows into the
context.  This is the e-commerce query of the paper's introduction, served
end to end.

Any Executor works: a local `ScannExecutor`/`GraphExecutor`, the
`AdaptivePlanner` (the server then picks the strategy per batch), or the
mesh-sharded `DistributedScannExecutor` — the server never hard-codes an
index type.

Under heavy traffic the server batches its request queue, and HOW it
batches decides buffer-pool locality (ROADMAP "frontier-union overlap"
item, DESIGN.md §8): `serve_queue(policy="centroid")` clusters queued
requests by their nearest ScaNN centroid before dispatch, so queries
landing in the same leaves share a batch — their leaf opens, frontier
unions, and reorder fetches hit the same pages.  The executor's
StorageEngine (buffer pool) persists across request batches, so the
hit-rate lift vs FIFO batching is directly measurable
(benchmarks/bench_storage.py).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.executor import Executor
from repro.core.types import SearchParams, SearchResult
from repro.models.api import ModelBundle

BATCH_POLICIES = ("fifo", "centroid")


@dataclasses.dataclass
class RetrievalResult:
    ids: np.ndarray        # (B, k) retrieved row ids
    dists: np.ndarray      # (B, k)
    tokens: np.ndarray     # (B, P + k*chunk) augmented prompts
    strategy: str          # strategy that served the batch (planner-aware)


def find_scann_index(executor: Executor):
    """The ScaNN index an executor routes with, if it has one (duck-typed:
    ScannExecutor, AdaptivePlanner with a scann candidate, or the
    mesh-sharded executor)."""
    idx = getattr(executor, "index", None)
    if idx is not None:
        return idx
    scann_ex = getattr(executor, "_scann", None)       # AdaptivePlanner
    if scann_ex is not None:
        return scann_ex.index
    sharded = getattr(executor, "sharded", None)       # distributed
    if sharded is not None:
        return sharded.index
    return None


@jax.jit
def nearest_centroid(index, queries):
    """Leaf-centroid id nearest to each (already-embedded) query — the
    routing key of the centroid batch policy.  (Q,) int32.  Metric-aware
    (same ranking as `scann._select_leaves`): the routing key must be the
    leaf the query will actually open, under L2 AND IP indexes."""
    from repro.core.scann import project_query
    from repro.core.types import distance
    qp = project_query(index, queries)
    cents = index.leaf_centroids
    d = distance(index.metric, qp[:, None, :], cents[None, :, :],
                 jnp.sum(cents * cents, -1)[None, :])
    return jnp.argmin(d, axis=-1).astype(jnp.int32)


class RetrievalAugmentedServer:
    def __init__(self, bundle: ModelBundle, params, executor: Executor,
                 search_params: SearchParams,
                 doc_tokens: np.ndarray, chunk_len: int = 32,
                 embed_fn: Optional[Callable] = None):
        """doc_tokens: (N, chunk_len) token rows aligned with store rows."""
        self.bundle = bundle
        self.params = params
        self.executor = executor
        self.search_params = search_params
        self.k = search_params.k
        self.doc_tokens = doc_tokens
        self.chunk_len = chunk_len
        dim = executor.store.dim
        if embed_fn is None:
            d_model = bundle.cfg.d_model
            key = jax.random.PRNGKey(7)
            proj = jax.random.normal(key, (d_model, dim),
                                     jnp.float32) / np.sqrt(d_model)

            def embed_fn(p, tokens):
                emb = p["embed"]["tok"].astype(jnp.float32)[tokens]
                return jnp.mean(emb, axis=1) @ proj

        self._embed = jax.jit(embed_fn)

    def _augment(self, idn: np.ndarray, prompts: np.ndarray) -> np.ndarray:
        chunks = self.doc_tokens[np.maximum(idn, 0)]       # (B, k, chunk)
        chunks = np.where((idn >= 0)[..., None], chunks, 0)
        aug = np.concatenate(
            [chunks.reshape(idn.shape[0], -1), prompts], axis=1)
        return aug.astype(np.int32)

    def retrieve(self, prompts: np.ndarray,
                 bitmaps: jax.Array) -> RetrievalResult:
        """prompts (B, P) int32; bitmaps (B, words) — the evaluated filter."""
        q = self._embed(self.params, jnp.asarray(prompts))
        res: SearchResult = self.executor.search(q, bitmaps,
                                                 self.search_params)
        idn = np.asarray(res.ids)
        return RetrievalResult(ids=idn, dists=np.asarray(res.dists),
                               tokens=self._augment(idn, prompts),
                               strategy=res.strategy)

    def serve_queue(self, prompts: np.ndarray, bitmaps: jax.Array,
                    batch_size: int = 16, policy: str = "centroid"
                    ) -> tuple[RetrievalResult, dict]:
        """Serve a whole request queue in dispatch batches.

        policy "fifo" batches requests in arrival order; "centroid"
        (the serving-layer routing policy, DESIGN.md §8) sorts the queue
        by each embedded query's nearest ScaNN leaf centroid first, so
        requests that will open the same leaves (and walk the same graph
        neighborhoods) share a batch — raising buffer-pool hit rates and
        frontier-union overlap.  Results are returned in arrival order
        either way, and for FIXED executors ids/dists are policy-invariant
        (each query's result depends only on the query itself).  An
        AdaptivePlanner executor picks its strategy per dispatch batch
        from batch-level selectivity estimates, so regrouping the queue
        can change which strategy serves a query — same recall target,
        not bit-identical results.

        Returns (RetrievalResult in arrival order, info) where info
        carries the dispatch order, per-batch strategies, and the
        executor's storage telemetry delta when a StorageEngine is
        attached (the pool persists across batches — warm serving).
        """
        if policy not in BATCH_POLICIES:
            raise ValueError(
                f"unknown policy {policy!r}; one of {BATCH_POLICIES}")
        prompts = np.asarray(prompts)
        q = self._embed(self.params, jnp.asarray(prompts))
        nreq = q.shape[0]
        order = np.arange(nreq)
        if policy == "centroid":
            index = find_scann_index(self.executor)
            if index is None:
                raise ValueError("centroid policy needs an executor with "
                                 "a ScaNN index (use policy='fifo')")
            keys = np.asarray(nearest_centroid(index, q))
            order = np.argsort(keys, kind="stable")
        bitmaps = jnp.asarray(bitmaps)
        k = self.k
        ids = np.full((nreq, k), -1, np.int32)
        dists = np.full((nreq, k), np.inf, np.float32)
        strategies = []
        # NB: `is not None`, not truthiness — BufferPool defines __len__,
        # so an empty (freshly reset) pool is falsy
        pool = getattr(getattr(self.executor, "storage", None), "pool",
                       None)
        h0, m0 = (pool.counters.hits, pool.counters.misses) \
            if pool is not None else (0, 0)
        for s in range(0, nreq, batch_size):
            sel = jnp.asarray(order[s:s + batch_size])
            res: SearchResult = self.executor.search(
                q[sel], bitmaps[sel], self.search_params)
            ids[order[s:s + batch_size]] = np.asarray(res.ids)
            dists[order[s:s + batch_size]] = np.asarray(res.dists)
            strategies.append(res.strategy)
        info = {"order": order, "strategies": strategies, "policy": policy}
        if pool is not None:
            dh = pool.counters.hits - h0
            dm = pool.counters.misses - m0
            info["pool_hits"] = dh
            info["pool_misses"] = dm
            info["pool_hit_rate"] = dh / max(dh + dm, 1)
        strategy = strategies[0] if len(set(strategies)) == 1 else "mixed"
        return RetrievalResult(ids=ids, dists=dists,
                               tokens=self._augment(ids, prompts),
                               strategy=strategy), info
