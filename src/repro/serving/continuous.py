"""Continuous-batching frontier serving engine (DESIGN.md §11).

`serve_queue` (rag.py) is batch-synchronous: a dispatch batch enters the
frontier engine together and leaves together, so one straggler (a
sparse-filter query burning its full hop budget) holds every co-batched
request hostage — the serving-layer head-of-line blocking the paper's
closed-loop Table 7 cannot see.  The frontier superstep loop already
carries per-query done/budget state; this module steps it *externally*
(`GraphExecutor.step_frontier`, fixed-hop chunks) over a fixed-width
`SlotPool` so finished lanes retire mid-flight and waiting requests are
admitted into freed slots without waiting for anyone else — LLM-serving
continuous batching applied to filtered vector search.

Pieces:

  Request             one arrival: query row, filter bitmap, tenant id,
                      arrival tick, optional deadline (modeled cycles)
  FairQueue           arrival queue with per-tenant weighted deficit
                      round-robin (weights=None -> plain FIFO), optional
                      centroid-affinity pop preference
  SlotPool            the compile-once pool: admit / step / harvest over
                      a FrontierState of fixed width, storage-trace
                      accounting and per-request AnytimeInfo flags
  ContinuousServer    the event loop in virtual time (1 tick = 1 hop
                      chunk): open-loop arrivals, queue-aware admission,
                      fairness, degradation-ladder walks for faulted /
                      budget-exhausted retires, and a batch-synchronous
                      comparator mode on the same pool

Correctness bar (tests/test_continuous.py): with fairness off and all
arrivals at t=0, harvested ids/dists are bit-identical to
`serve_queue(policy="fifo")`, and per-request SearchStats are
arrival-order-invariant — each lane's trajectory depends only on its own
row of the pool state.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict, deque
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core import costmodel
from repro.core.executor import GraphExecutor
from repro.core.types import (SearchParams, SearchStats, bitset_words,
                              merge_topk)
from repro.serving.rag import (LadderRung, admission_floor, bucket_deadline,
                               find_scann_index, nearest_centroid)


@dataclasses.dataclass
class Request:
    """One serving arrival.  `deadline_cycles` <= 0 means no deadline;
    positive deadlines are bucketed (`bucket_deadline`) at admission so
    flag derivation matches the batch-synchronous path bit-for-bit."""
    rid: int
    query: np.ndarray           # (dim,) float32
    bitmap: np.ndarray          # (words,) uint32 packed filter
    tenant: int = 0
    arrival: int = 0            # tick the request becomes visible
    deadline_cycles: float = 0.0


@dataclasses.dataclass
class IngestEvent:
    """One live mutation interleaved with serving (DESIGN.md §12): at the
    first loop iteration with virtual time >= `tick`, the event is applied
    durably (WAL first) to the server's `MutableIndex`.  kind="insert"
    appends `rows` to the delta tier; kind="delete" tombstones `ids`.

    Consistency contract: every request sees the index state as of its
    ADMIT tick — the tombstone-composed live bitmap and the delta tier's
    (count, rows) are snapshotted at admission (DeltaExecutor.plan), and
    the delta top-k is merged into the lane's base-graph answer at retire
    (`types.merge_topk`).  Mutations landing while a request is in flight
    are invisible to it, exactly as if it had run to completion at its
    admit instant — snapshot isolation per request."""
    tick: int
    kind: str                              # "insert" | "delete"
    rows: Optional[np.ndarray] = None      # insert: (m, dim) float32
    ids: Optional[np.ndarray] = None       # delete: (m,) int64 global ids


class FairQueue:
    """Arrival queue with per-tenant weighted fair service.

    Deficit round-robin over tenant ids: each visit to a tenant's queue
    adds `weight * quantum` to its deficit counter; serving one request
    costs 1.  A tenant with weight 2 therefore drains twice as fast as a
    tenant with weight 1 under contention, and an idle tenant's deficit
    is cleared (no banked credit — classic DRR).  `weights=None` is
    plain FIFO across all tenants (the bit-identicality mode).

    `pop(prefer_key)` optionally serves the first request *of the chosen
    tenant* whose centroid key matches `prefer_key` (slot-affinity
    composes with fairness: fairness picks WHO, affinity picks WHICH of
    theirs).  Under FIFO the scan covers the whole queue in arrival
    order, so affinity never reorders across what fairness would pick.
    """

    def __init__(self, weights: Optional[dict] = None,
                 quantum: float = 1.0):
        if weights is not None:
            for t, w in weights.items():
                if w <= 0:
                    raise ValueError(
                        f"tenant {t!r} weight must be > 0, got {w}")
        self.weights = weights
        self.quantum = quantum
        self._fifo: deque[Request] = deque()
        self._tenants: "OrderedDict[int, deque[Request]]" = OrderedDict()
        self._deficit: dict[int, float] = {}

    def __len__(self) -> int:
        if self.weights is None:
            return len(self._fifo)
        return sum(len(d) for d in self._tenants.values())

    def push(self, req: Request) -> None:
        if self.weights is None:
            self._fifo.append(req)
            return
        if req.tenant not in self._tenants:
            self._tenants[req.tenant] = deque()
            self._deficit[req.tenant] = 0.0
        self._tenants[req.tenant].append(req)

    @staticmethod
    def _take(dq: deque, prefer_key, keys) -> Request:
        if prefer_key is not None and keys is not None:
            for i, r in enumerate(dq):
                if keys.get(r.rid) == prefer_key:
                    del dq[i]
                    return r
        return dq.popleft()

    def pop(self, prefer_key=None, keys: Optional[dict] = None
            ) -> Optional[Request]:
        if self.weights is None:
            if not self._fifo:
                return None
            return self._take(self._fifo, prefer_key, keys)
        if not len(self):
            return None
        # DRR: cycle tenants in arrival order; the loop terminates
        # because every full round adds >= min weight * quantum to some
        # non-empty tenant's deficit
        while True:
            for t in list(self._tenants):
                dq = self._tenants[t]
                if not dq:
                    self._deficit[t] = 0.0      # no banked credit
                    continue
                self._deficit[t] += \
                    self.weights.get(t, 1.0) * self.quantum
                if self._deficit[t] >= 1.0:
                    self._deficit[t] -= 1.0
                    req = self._take(dq, prefer_key, keys)
                    # rotate so the next pop resumes AFTER this tenant
                    self._tenants.move_to_end(t)
                    return req


class SlotPool:
    """Fixed-width pool of frontier lanes, stepped in hop chunks.

    The pool state is one `FrontierState` of width `width`; every jitted
    entry point (idle init, per-request init, slot write, step, harvest)
    compiles once per (width, resolved params, hop_chunk, flags) and is
    reused for the whole run — `compiles` property reports the distinct
    cache keys touched, asserted bounded in tests.  Storage-trace
    collection follows the executor's storage attachment exactly like
    `GraphExecutor.execute`; harvested lanes replay only their own trace
    rows through the buffer pool.
    """

    def __init__(self, executor: GraphExecutor, params: SearchParams,
                 width: int, hop_chunk: int = 8,
                 dynamic_deadline: bool = False):
        if width <= 0:
            raise ValueError(f"slot pool width must be > 0, got {width}")
        if hop_chunk <= 0:
            raise ValueError(f"hop_chunk must be > 0, got {hop_chunk}")
        self.executor = executor
        self.params = executor.resolve_params(params)
        self.width = width
        self.hop_chunk = hop_chunk
        self.dynamic_deadline = dynamic_deadline
        self.state = executor.idle_frontier(self.params, width)
        self.occupied = np.zeros(width, bool)
        self.slot_rid = np.full(width, -1, np.int64)
        self.slot_bucket = np.zeros(width, np.float64)
        self.slot_key = np.full(width, -1, np.int64)   # centroid affinity
        self._keys: set = {("idle", self.params, width)}

    @property
    def compiles(self) -> int:
        return len(self._keys)

    def free_slots(self) -> np.ndarray:
        return np.flatnonzero(~self.occupied)

    def done_slots(self) -> np.ndarray:
        return np.flatnonzero(self.occupied
                              & np.asarray(self.state.done))

    def all_done(self) -> bool:
        return bool((~self.occupied | np.asarray(self.state.done)).all())

    def admit(self, req: Request, slot: int, key: int = -1) -> None:
        """Write one request into a free slot (fresh lane state from
        `frontier_init`; the previous occupant's rows are replaced
        wholesale, trace stamps included)."""
        if self.occupied[slot]:
            raise ValueError(f"slot {slot} is occupied")
        bucket = bucket_deadline(req.deadline_cycles) \
            if req.deadline_cycles > 0 else 0.0
        dl = np.asarray([bucket if bucket > 0 else np.inf], np.float32)
        lane = self.executor.init_frontier(
            jnp.asarray(req.query)[None], jnp.asarray(req.bitmap)[None],
            self.params, deadlines=dl)
        self._keys.add(("init", self.params, 1))
        self.state = self.executor.write_frontier_slot(self.state, lane,
                                                       slot)
        self._keys.add(("write", self.width))
        self.occupied[slot] = True
        self.slot_rid[slot] = req.rid
        self.slot_bucket[slot] = bucket
        self.slot_key[slot] = key

    def step(self) -> None:
        self.state = self.executor.step_frontier(
            self.state, self.params, self.hop_chunk,
            dynamic_deadline=self.dynamic_deadline)
        self._keys.add(("step", self.params, self.width, self.hop_chunk,
                        self.dynamic_deadline))

    def harvest(self, slots: np.ndarray) -> list[dict]:
        """Finalize the pool and retire `slots`: returns one record per
        slot with ids/dists/stats/AnytimeInfo (flags derived against the
        request's own deadline bucket) and per-lane StorageStats when a
        storage engine is attached.  Lanes not in `slots` keep running —
        `frontier_finalize` is a pure function of the state."""
        if not len(slots):
            return []
        d, ids, stats, trace = self.executor.finalize_frontier(
            self.state, self.params)
        self._keys.add(("final", self.params, self.width))
        d = np.asarray(d)
        ids = np.asarray(ids)
        stats_np = {f: np.asarray(getattr(stats, f))
                    for f in SearchStats.__dataclass_fields__}
        out = []
        for s in np.asarray(slots):
            row = {f: stats_np[f][s:s + 1] for f in stats_np}
            st_row = SearchStats(**row)
            sstats = None
            if trace is not None and self.executor.storage is not None:
                rr = trace.get("rerank_rows")
                sstats = self.executor.storage.account_graph(
                    np.asarray(trace["heap_steps"])[s:s + 1],
                    np.asarray(trace["index_steps"])[s:s + 1],
                    rerank_rows=None if rr is None
                    else np.asarray(rr)[s:s + 1],
                    quant=self.executor.graph_quant == "sq8")
            bucket = float(self.slot_bucket[s])
            p = self.params if bucket <= 0 else dataclasses.replace(
                self.params, deadline_cycles=bucket)
            anytime = costmodel.evaluate_anytime(
                st_row, p, self.executor.store.dim, ids[s],
                hop_cap=p.max_hops)
            out.append(dict(
                rid=int(self.slot_rid[s]), slot=int(s),
                ids=ids[s].copy(), dists=d[s].copy(), stats=st_row,
                anytime=anytime, storage=sstats,
                cycles=float(costmodel.linear_cycles(
                    st_row, self.executor.store.dim)[0])))
            self.occupied[s] = False
            self.slot_rid[s] = -1
            self.slot_bucket[s] = 0.0
            self.slot_key[s] = -1
        return out


class ContinuousServer:
    """Open-loop serving event loop over a `SlotPool`.

    Virtual time advances one tick per stepped hop chunk (idle ticks when
    the pool is empty and no arrival is due).  mode="continuous" admits
    into any freed slot every tick; mode="batch" is the batch-synchronous
    comparator — it admits only into an EMPTY pool and harvests only when
    every occupied lane is done, so all co-batched requests share the
    last finisher's retire tick (exactly `serve_queue`'s head-of-line
    behavior, measured on the same engine).  Per-lane results are
    identical in both modes; only the clock differs.

    Admission composes three gates (DESIGN.md §11): the static
    `admission_floor` (a deadline below the cheapest possible service is
    rejected), the queue-aware floor (`costmodel.queue_aware_floor` —
    the wait already visible in the queue, priced with a running mean of
    completed requests' modeled cycles), and per-tenant weighted fairness
    (`FairQueue`).  Faulted retires retry once on the primary executor;
    still-faulted or budget-exhausted retires walk the degradation
    `ladder` rung by rung as single-shot slot occupants (+1 tick per
    rung — the slot is held one extra chunk per rung walked).
    """

    def __init__(self, executor: GraphExecutor, params: SearchParams,
                 width: int = 8, hop_chunk: int = 8,
                 fairness: Optional[dict] = None, assign: str = "fifo",
                 ladder: Optional[list[LadderRung]] = None,
                 admit: bool = True, slo_ticks: Optional[int] = None,
                 index=None, ingest: Optional[list[IngestEvent]] = None):
        if assign not in ("fifo", "centroid"):
            raise ValueError(f"unknown assign policy {assign!r}; "
                             "expected 'fifo' or 'centroid'")
        self.executor = executor
        self.params = executor.resolve_params(params)
        self.width = width
        self.hop_chunk = hop_chunk
        self.fairness = fairness
        self.assign = assign
        self.ladder = ladder
        self.admit = admit
        self.slo_ticks = slo_ticks
        # live-ingestion mode (DESIGN.md §12): `index` is a
        # core.mutable.MutableIndex whose base tiers `executor` was built
        # over; `ingest` is the mutation stream applied at tick
        # boundaries.  Request bitmaps must then be sized to
        # index.words() (global capacity id space).  Compaction is
        # DEFERRED while serving — the pool's compiled lanes capture the
        # base graph, so an insert that would overflow the delta tier is
        # an error (size delta_capacity for the serve window, compact
        # between windows).
        self.index = index
        self.ingest = list(ingest) if ingest else []
        if self.ingest and index is None:
            raise ValueError("ingest events require a MutableIndex")

    def _live_base_bitmap(self, bitmap: np.ndarray
                          ) -> tuple[np.ndarray, np.ndarray]:
        """Tombstone-compose the request's global-id bitmap; returns
        (live, base): the full capacity-wide live bitmap for the delta
        snapshot, and its clip to the base id space [0, base_n) — the
        lane's view of the filter."""
        bm = np.asarray(bitmap, np.uint32)
        w = self.index.words()
        if bm.shape[-1] < w:
            bm = np.concatenate(
                [bm, np.zeros(w - bm.shape[-1], np.uint32)])
        live = self.index.tombstones.live_mask(bm[None])[0]
        base_n = self.index.base_n
        base = np.array(live[:bitset_words(base_n)], np.uint32, copy=True)
        rem = base_n & 31
        if rem:
            base[-1] &= np.uint32((1 << rem) - 1)
        return live, base

    def _centroid_keys(self, requests: list[Request]) -> Optional[dict]:
        if self.assign != "centroid":
            return None
        index = find_scann_index(self.executor)
        if index is None:
            return None
        q = jnp.asarray(np.stack([r.query for r in requests]))
        keys = np.asarray(nearest_centroid(index, q))
        return {r.rid: int(k) for r, k in zip(requests, keys)}

    def _prefer_key(self, pool: SlotPool) -> Optional[int]:
        """Most common centroid key among active slots — admit requests
        that will walk the neighborhoods the pool already has warm."""
        act = pool.slot_key[pool.occupied & (pool.slot_key >= 0)]
        if not len(act):
            return None
        vals, counts = np.unique(act, return_counts=True)
        return int(vals[np.argmax(counts)])

    def _ladder_walk(self, req: Request, rec: dict, bucket: float,
                     pool: SlotPool) -> int:
        """Retry-then-descend for a faulted/budget-exhausted retire.
        Returns the extra ticks spent (1 per rung dispatch); mutates
        `rec` in place with the serving rung's results/flags."""
        p = self.params if bucket <= 0 else dataclasses.replace(
            self.params, deadline_cycles=bucket)
        q1 = jnp.asarray(req.query)[None]
        b1 = jnp.asarray(req.bitmap)[None]
        extra = 0
        faulted = rec["storage"] is not None and \
            bool(np.asarray(rec["storage"].faulted).any())
        if faulted:
            # transient faults: one retry on the primary before degrading
            res = self.executor.search(q1, b1, p)
            pool._keys.add(("rung", "primary", p, 1))
            extra += 1
            rec.update(ids=np.asarray(res.ids)[0],
                       dists=np.asarray(res.dists)[0],
                       anytime=res.anytime, storage=res.storage,
                       retried=True)
            faulted = res.storage is not None and \
                bool(np.asarray(res.storage.faulted).any())
        exhausted = rec["anytime"] is not None and \
            bool(np.asarray(rec["anytime"].budget_exhausted).any())
        if self.ladder is None or not (faulted or exhausted):
            rec["rung"], rec["rung_level"] = "primary", 0
            return extra
        rec["rung"], rec["rung_level"] = "primary", 0
        for level, rung in enumerate(self.ladder[1:], start=1):
            rp = rung.resolve(p)
            res = rung.executor.search(q1, b1, rp)
            pool._keys.add(("rung", rung.name, rp, 1))
            extra += 1
            rec.update(ids=np.asarray(res.ids)[0],
                       dists=np.asarray(res.dists)[0],
                       anytime=res.anytime, storage=res.storage,
                       rung=rung.name, rung_level=level)
            faulted = res.storage is not None and \
                bool(np.asarray(res.storage.faulted).any())
            exhausted = res.anytime is not None and \
                bool(np.asarray(res.anytime.budget_exhausted).any())
            if not (faulted or exhausted):
                break
        return extra

    def serve(self, requests: list[Request], mode: str = "continuous"
              ) -> tuple[dict, dict]:
        """Run the event loop over `requests` (any order; sorted by
        arrival tick internally).  Returns (records, info): `records`
        maps rid -> harvest record (ids, dists, stats, anytime, rung,
        arrival/admit/retire ticks, latency_ticks); `info` carries the
        run-level telemetry (compiles, ticks, slot utilization,
        admission rejects, queue depth trace).
        """
        if mode not in ("continuous", "batch"):
            raise ValueError(f"unknown mode {mode!r}; expected "
                             "'continuous' or 'batch'")
        pending = sorted(requests, key=lambda r: (r.arrival, r.rid))
        n = len(pending)
        any_deadline = any(r.deadline_cycles > 0 for r in pending)
        pool = SlotPool(self.executor, self.params, self.width,
                        self.hop_chunk, dynamic_deadline=any_deadline)
        queue = FairQueue(self.fairness)
        keys = self._centroid_keys(requests)
        floor = admission_floor(self.executor.store, self.params) \
            if (self.admit and any_deadline) else 0.0
        records: dict[int, dict] = {}
        rejected: list[int] = []
        t = 0
        ai = 0                       # arrival cursor into `pending`
        step_ticks = 0
        occupied_ticks = 0
        queue_depth: list[int] = []
        done_cycles: list[float] = []    # completed service, modeled cycles
        ing = sorted(self.ingest, key=lambda e: e.tick)
        gi = 0                           # ingest cursor
        delta_plans: dict[int, object] = {}   # rid -> admit-time snapshot
        ingested = dict(inserts=0, deletes=0, rows=0)

        def _apply_ingest(force: bool = False) -> None:
            """Durably apply every ingest event due at the current tick
            (WAL-first through MutableIndex; `force` drains the stream at
            loop exit so events past the last tick still land)."""
            nonlocal gi
            while gi < len(ing) and (force or ing[gi].tick <= t):
                ev = ing[gi]
                gi += 1
                if ev.kind == "insert":
                    rows = np.asarray(ev.rows, np.float32)
                    if self.index.delta.count + rows.shape[0] \
                            > self.index.delta_capacity:
                        raise RuntimeError(
                            "delta tier full mid-serve: compaction is "
                            "deferred while lanes hold the base graph — "
                            "size delta_capacity for the serve window")
                    self.index.insert(rows)
                    ingested["inserts"] += 1
                    ingested["rows"] += int(rows.shape[0])
                elif ev.kind == "delete":
                    self.index.delete(np.asarray(ev.ids, np.int64))
                    ingested["deletes"] += 1
                else:
                    raise ValueError(f"unknown ingest kind {ev.kind!r}")

        def _enqueue_arrivals() -> None:
            nonlocal ai
            while ai < n and pending[ai].arrival <= t:
                req = pending[ai]
                ai += 1
                if self.admit and req.deadline_cycles > 0:
                    est = float(np.mean(done_cycles)) if done_cycles \
                        else 0.0
                    gate = costmodel.queue_aware_floor(
                        floor, len(queue), self.width, est)
                    if bucket_deadline(req.deadline_cycles) < gate:
                        rejected.append(req.rid)
                        records[req.rid] = dict(
                            rid=req.rid, admitted=False, tenant=req.tenant,
                            arrival_tick=req.arrival, retire_tick=-1,
                            latency_ticks=-1,
                            ids=np.full(self.params.k, -1, np.int32),
                            dists=np.full(self.params.k, np.inf,
                                          np.float32),
                            stats=None, anytime=None, storage=None,
                            rung="rejected", rung_level=-1, retried=False)
                        continue
                queue.push(req)

        def _admit_free() -> None:
            for s in pool.free_slots():
                if not len(queue):
                    break
                prefer = self._prefer_key(pool) if keys is not None \
                    else None
                req = queue.pop(prefer_key=prefer, keys=keys)
                key = keys.get(req.rid, -1) if keys is not None else -1
                if self.index is not None:
                    # snapshot isolation: compose tombstones and freeze
                    # the delta tier's (count, rows) AS OF THIS TICK —
                    # DeltaExecutor.plan copies the buffer, so mutations
                    # landing mid-flight cannot leak into this request
                    live, base_bm = self._live_base_bitmap(req.bitmap)
                    delta_plans[req.rid] = \
                        self.index._delta_executor().plan(
                            jnp.asarray(req.query)[None],
                            jnp.asarray(live)[None], self.params)
                    req = dataclasses.replace(req, bitmap=base_bm)
                pool.admit(req, int(s), key=key)
                by_rid[req.rid] = req
                records[req.rid] = dict(
                    rid=req.rid, admitted=True, tenant=req.tenant,
                    arrival_tick=req.arrival, admit_tick=t,
                    retried=False)

        def _retire(slots: np.ndarray) -> None:
            for rec in pool.harvest(slots):
                req = by_rid[rec["rid"]]
                bucket = bucket_deadline(req.deadline_cycles) \
                    if req.deadline_cycles > 0 else 0.0
                done_cycles.append(rec["cycles"])
                extra = self._ladder_walk(req, rec, bucket, pool)
                rec.setdefault("rung", "primary")
                rec.setdefault("rung_level", 0)
                rec.setdefault("retried", False)
                if self.index is not None:
                    # merge-at-retire: the admit-time delta snapshot's
                    # exact top-k fuses with whichever rung served the
                    # base walk (ladder-degraded retires merge too)
                    dplan = delta_plans.pop(req.rid)
                    dres = self.index._delta_executor().execute(dplan)
                    md, mi = merge_topk(
                        jnp.asarray(rec["dists"])[None],
                        jnp.asarray(rec["ids"])[None],
                        dres.dists, dres.ids, self.params.k)
                    rec["dists"] = np.asarray(md)[0]
                    rec["ids"] = np.asarray(mi)[0]
                    if rec.get("stats") is not None:
                        rec["stats"] = rec["stats"] + dres.stats
                    rec["delta_count"] = int(dplan.notes["count"])
                rec["retire_tick"] = t + extra
                records[req.rid].update(rec)
                records[req.rid]["latency_ticks"] = \
                    rec["retire_tick"] - req.arrival

        by_rid: dict[int, Request] = {}
        served = 0
        while served < n - len(rejected) or ai < n:
            if self.index is not None:
                _apply_ingest()
            _enqueue_arrivals()
            if mode == "continuous":
                _admit_free()
            elif not pool.occupied.any():
                _admit_free()        # batch: refill only an empty pool
            queue_depth.append(len(queue))
            if pool.occupied.any():
                pool.step()
                step_ticks += 1
                occupied_ticks += int(pool.occupied.sum())
                t += 1
                if mode == "continuous":
                    done = pool.done_slots()
                elif pool.all_done():
                    done = np.flatnonzero(pool.occupied)
                else:
                    done = np.empty(0, np.int64)
                if len(done):
                    _retire(done)
                    served = sum(1 for r in records.values()
                                 if r.get("retire_tick", -1) >= 0)
            else:
                t += 1               # idle tick: waiting on arrivals
        if self.index is not None:
            _apply_ingest(force=True)   # drain events past the last tick
        info = dict(
            mode=mode, ticks=t, step_ticks=step_ticks,
            hop_chunk=self.hop_chunk, width=self.width,
            compiles=pool.compiles,
            slot_utilization=(occupied_ticks
                              / max(step_ticks * self.width, 1)),
            rejected=np.asarray(sorted(rejected), np.int64),
            rejected_frac=len(rejected) / max(n, 1),
            mean_queue_depth=float(np.mean(queue_depth))
            if queue_depth else 0.0,
            fairness="drr" if self.fairness is not None else "fifo",
            assign=self.assign if keys is not None else "fifo",
            ingest_inserts=ingested["inserts"],
            ingest_deletes=ingested["deletes"],
            ingest_rows=ingested["rows"])
        return records, info


def results_in_order(records: dict, nreq: int, k: int
                     ) -> tuple[np.ndarray, np.ndarray]:
    """Stack harvested ids/dists back into arrival (rid) order — the
    shape `serve_queue` returns, for bit-identicality checks."""
    ids = np.full((nreq, k), -1, np.int32)
    dists = np.full((nreq, k), np.inf, np.float32)
    for rid, rec in records.items():
        ids[rid] = rec["ids"]
        dists[rid] = rec["dists"]
    return ids, dists
