"""Batched serving engine: continuous prefill + decode over a KV cache.

This is the substrate the decode_* dry-run shapes lower: `decode_fn` is the
exact jitted `serve_step` (one new token against a seq_len cache).  The
engine adds batched request handling on top: greedy/temperature sampling,
per-request stop handling, and cache reuse across steps.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import ModelBundle


@dataclasses.dataclass
class ServeStats:
    prefill_tokens: int = 0
    decoded_tokens: int = 0
    steps: int = 0


class ServeEngine:
    def __init__(self, bundle: ModelBundle, params, max_seq: int,
                 batch_size: int, temperature: float = 0.0):
        self.bundle = bundle
        self.params = params
        self.max_seq = max_seq
        self.batch_size = batch_size
        self.temperature = temperature
        self.stats = ServeStats()
        self._decode = jax.jit(
            lambda p, c, b, pos: bundle.decode(p, c, b, pos))
        self._prefill = jax.jit(lambda p, b: bundle.prefill(p, b))

    def _sample(self, logits: jax.Array, key) -> jax.Array:
        logits = logits[:, -1, :]
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)
        return jax.random.categorical(key, logits / self.temperature)

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 seed: int = 0, stop_token: Optional[int] = None
                 ) -> np.ndarray:
        """prompts: (B, P) int32 token ids (uniform length — the engine pads
        batches upstream).  Returns (B, max_new_tokens)."""
        b, plen = prompts.shape
        assert b == self.batch_size
        logits = self._prefill(self.params, {"tokens": jnp.asarray(prompts)})
        self.stats.prefill_tokens += b * plen
        cache = self.bundle.init_cache(b, self.max_seq)
        # replay the prompt through the decode path to fill the cache
        key = jax.random.PRNGKey(seed)
        for t in range(plen):
            _, cache = self._decode(self.params, cache,
                                    {"tokens": jnp.asarray(prompts[:, t:t+1])},
                                    jnp.int32(t))
        tok = self._sample(logits, key)
        out = [np.asarray(tok)]
        done = np.zeros(b, bool)
        for i in range(max_new_tokens - 1):
            key, sub = jax.random.split(key)
            logits, cache = self._decode(
                self.params, cache, {"tokens": tok[:, None]},
                jnp.int32(plen + i))
            tok = self._sample(logits, sub)
            self.stats.decoded_tokens += int(b)
            self.stats.steps += 1
            if stop_token is not None:
                done |= np.asarray(tok) == stop_token
                if done.all():
                    out.append(np.asarray(tok))
                    break
            out.append(np.asarray(tok))
        return np.stack(out, axis=1)
