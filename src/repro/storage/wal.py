"""Write-ahead log for the streaming-mutability tier (DESIGN.md §12).

Every mutation against a `MutableIndex` (core/mutable.py) is made durable
here BEFORE it is applied to the in-memory delta tier — the classic WAL
protocol, so a crash at any instant loses at most the un-fsynced tail and
never leaves the applied state ahead of the log.

Record format (little-endian, fixed 20-byte header + payload):

    magic   u16   0xDA7A  — resync guard; a mismatch means corruption
    type    u8    record kind (INSERT / DELETE / CHECKPOINT / COMPACT)
    _pad    u8    zero
    lsn     u64   monotone log sequence number (1-based)
    len     u32   payload byte length
    crc     u32   CRC32C (Castagnoli) over (type, _pad, lsn, len, payload)
    payload len bytes

The CRC covers the header fields *and* the payload, so a torn tail — a
crash mid-append leaving a prefix of a record on disk — is detected
exactly: `replay()` stops at the first record whose bytes are incomplete
OR whose CRC mismatches at end-of-log (the torn tail, reported via
`tail_torn`), and raises `WalCorruption` only when garbage is followed by
further intact records (true corruption, not a crash artifact).

Durability model: `append()` buffers through the OS file (write syscall);
`sync()` flushes + fsyncs and advances `durable_offset`.  The
deterministic crash harness uses `durable_offset` / record boundaries as
its crash points: `crash_copy(path, at_bytes)` materializes what the disk
would hold if the process died after exactly `at_bytes` bytes reached
storage.  Write-path fault injection (storage/faults.py): a torn-append
fault writes a deterministic prefix of the record and raises
`WalTornWrite` (the process "died" mid-write); a failed fsync raises
`WalSyncError` with the log rolled back to the last durable point — both
draws are counter-keyed splitmix64, replayable run after run.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import struct
from typing import Iterator, Optional

import numpy as np

_MAGIC = 0xDA7A
_HEADER = struct.Struct("<HBBQLL")        # magic, type, pad, lsn, len, crc
HEADER_BYTES = _HEADER.size               # 20

# record kinds
REC_INSERT = 1
REC_DELETE = 2
REC_CHECKPOINT = 3
REC_COMPACT = 4
_KINDS = (REC_INSERT, REC_DELETE, REC_CHECKPOINT, REC_COMPACT)


# -- CRC32C (Castagnoli), table-driven ---------------------------------------

def _make_crc32c_table() -> list[int]:
    poly = 0x82F63B78                      # reflected Castagnoli
    table = []
    for i in range(256):
        c = i
        for _ in range(8):
            c = (c >> 1) ^ poly if c & 1 else c >> 1
        table.append(c)
    return table


_CRC_TABLE = _make_crc32c_table()


def crc32c(data: bytes, crc: int = 0) -> int:
    """CRC32C over `data` (optionally continuing a running crc)."""
    c = crc ^ 0xFFFFFFFF
    for b in data:
        c = _CRC_TABLE[(c ^ b) & 0xFF] ^ (c >> 8)
    return c ^ 0xFFFFFFFF


class WalCorruption(Exception):
    """Garbage mid-log (bad magic / CRC with MORE valid data after it) —
    not a torn tail, which replay() truncates silently."""


class WalTornWrite(Exception):
    """Injected torn append: the process 'crashed' mid-write.  The WAL
    file holds a prefix of the record; the owning MutableIndex must be
    recovered before further use."""


class WalSyncError(Exception):
    """Injected fsync failure: bytes since the last successful sync may
    not have reached storage (wal.durable_offset did not advance)."""


@dataclasses.dataclass(frozen=True)
class WalRecord:
    lsn: int
    kind: int
    payload: bytes
    offset: int          # byte offset of the record header in the file
    length: int          # total on-disk bytes (header + payload)

    @property
    def end(self) -> int:
        return self.offset + self.length


def encode_record(kind: int, lsn: int, payload: bytes) -> bytes:
    if kind not in _KINDS:
        raise ValueError(f"unknown WAL record kind {kind}")
    body = struct.pack("<BBQL", kind, 0, lsn, len(payload)) + payload
    crc = crc32c(body)
    return _HEADER.pack(_MAGIC, kind, 0, lsn, len(payload), crc) + payload


# -- payload codecs (numpy, fixed little-endian) -----------------------------

def encode_insert(start_id: int, vectors: np.ndarray) -> bytes:
    v = np.ascontiguousarray(vectors, dtype="<f4")
    head = struct.pack("<QLL", start_id, v.shape[0], v.shape[1])
    return head + v.tobytes()


def decode_insert(payload: bytes) -> tuple[int, np.ndarray]:
    start_id, rows, dim = struct.unpack_from("<QLL", payload, 0)
    v = np.frombuffer(payload, dtype="<f4", offset=16,
                      count=rows * dim).reshape(rows, dim)
    return start_id, np.array(v, dtype=np.float32)   # writable copy


def encode_delete(ids: np.ndarray) -> bytes:
    a = np.ascontiguousarray(ids, dtype="<i8")
    return struct.pack("<L", a.shape[0]) + a.tobytes()


def decode_delete(payload: bytes) -> np.ndarray:
    (n,) = struct.unpack_from("<L", payload, 0)
    return np.array(np.frombuffer(payload, dtype="<i8", offset=4, count=n),
                    dtype=np.int64)


def encode_meta(meta: dict) -> bytes:
    return json.dumps(meta, sort_keys=True).encode()


def decode_meta(payload: bytes) -> dict:
    return json.loads(payload.decode())


class WriteAheadLog:
    """Append-only WAL over one file.

    `faults` is an optional storage/faults.FaultInjector whose WRITE-path
    draws (`on_wal_append`, `on_fsync`) are counter-keyed on the WAL's own
    append/sync counters — deterministic per (plan.seed, counter), exactly
    like the read-path faults (DESIGN.md §10).

    `page_hook(offset, nbytes, kind)` is called once per physical write
    ("append" data, "sync" flush) so the storage engine can charge WAL
    page I/O through the buffer pool (kind "append" dirties the touched
    pages; "sync" flushes them — DESIGN.md §12 write accounting).
    """

    def __init__(self, path: str, faults=None, page_hook=None):
        self.path = path
        self.faults = faults
        self.page_hook = page_hook
        exists = os.path.exists(path)
        self._f = open(path, "ab" if exists else "wb")
        self._f.seek(0, os.SEEK_END)
        self.offset = self._f.tell()         # logical end of log
        self.durable_offset = self.offset    # advanced by sync()
        self.next_lsn = 1
        if exists and self.offset:
            last = None
            for rec in iter_records(path):
                last = rec
            if last is not None:
                self.next_lsn = last.lsn + 1
                # anything past the last intact record is a torn tail
                self.offset = last.end
                self.durable_offset = last.end
                self._f.seek(last.end)
                self._f.truncate(last.end)

    # -- write path ---------------------------------------------------------
    def append(self, kind: int, payload: bytes) -> WalRecord:
        """Durably order one record (buffered; call sync() for fsync).
        Raises WalTornWrite when an injected torn-append fault fires —
        the on-disk file then holds a prefix of the record."""
        lsn = self.next_lsn
        raw = encode_record(kind, lsn, payload)
        torn = None
        if self.faults is not None:
            torn = self.faults.on_wal_append(len(raw))
        if torn is not None:
            self._f.write(raw[:torn])
            self._f.flush()
            raise WalTornWrite(
                f"torn WAL append at lsn {lsn}: {torn}/{len(raw)} bytes "
                f"reached the file")
        self._f.write(raw)
        rec = WalRecord(lsn, kind, payload, self.offset, len(raw))
        if self.page_hook is not None:
            self.page_hook(self.offset, len(raw), "append")
        self.offset += len(raw)
        self.next_lsn = lsn + 1
        return rec

    def sync(self) -> int:
        """fsync the log; returns the new durable offset.  An injected
        fsync failure raises WalSyncError and leaves durable_offset where
        it was (the tail may be lost on crash)."""
        if self.faults is not None and self.faults.on_fsync():
            raise WalSyncError(
                f"fsync failed; durable through byte {self.durable_offset} "
                f"of {self.offset}")
        self._f.flush()
        os.fsync(self._f.fileno())
        if self.page_hook is not None and self.offset > self.durable_offset:
            self.page_hook(self.durable_offset,
                           self.offset - self.durable_offset, "sync")
        self.durable_offset = self.offset
        return self.durable_offset

    def discard_torn(self) -> None:
        """After a WalTornWrite: drop the torn fragment (bytes past the
        last complete record) so in-process appends can continue without
        a full recover — exactly what reopening the file would do."""
        self._f.flush()
        self._f.truncate(self.offset)
        self._f.seek(self.offset)

    def rollback_to_durable(self) -> None:
        """After a WalSyncError: the un-fsynced tail may never reach
        storage, so applying its mutations anyway would let memory run
        ahead of the log.  Drop the tail (truncate to durable_offset) and
        rewind next_lsn from the surviving records — the failed batch is
        simply 'not written', deterministically."""
        self._f.flush()
        self._f.truncate(self.durable_offset)
        self._f.seek(self.durable_offset)
        self.offset = self.durable_offset
        last = None
        for rec in iter_records(self.path):
            last = rec
        self.next_lsn = last.lsn + 1 if last is not None else 1

    def close(self) -> None:
        self._f.close()

    # -- read path ----------------------------------------------------------
    def replay(self, from_lsn: int = 0) -> list[WalRecord]:
        """All intact records with lsn > from_lsn, in order.  Stops
        cleanly at a torn tail (see iter_records)."""
        self._f.flush()
        return [r for r in iter_records(self.path) if r.lsn > from_lsn]

    # -- crash simulation ---------------------------------------------------
    def crash_copy(self, dest: str, at_bytes: Optional[int] = None) -> str:
        """Materialize the file a crash would leave behind: the first
        `at_bytes` bytes of the log (default: `durable_offset` — what an
        OS that lost the un-fsynced page cache would present)."""
        self._f.flush()
        cut = self.durable_offset if at_bytes is None else at_bytes
        shutil.copyfile(self.path, dest)
        with open(dest, "r+b") as f:
            f.truncate(cut)
        return dest


def iter_records(path: str) -> Iterator[WalRecord]:
    """Scan a WAL file, yielding intact records in order.

    Termination contract (the crash-consistency core, tested at every
    record boundary): a record whose header is incomplete, whose payload
    is shorter than its header claims, or whose CRC mismatches is treated
    as the TORN TAIL iff it reaches end-of-file — iteration stops there
    (the crash lost that record; everything before it is intact).  The
    same damage followed by more bytes than the record claims is true
    corruption and raises WalCorruption.
    """
    with open(path, "rb") as f:
        data = f.read()
    off = 0
    total = len(data)
    expect_lsn = None
    while off < total:
        if off + HEADER_BYTES > total:
            return                               # torn header at the tail
        magic, kind, pad, lsn, plen, crc = _HEADER.unpack_from(data, off)
        end = off + HEADER_BYTES + plen
        if magic != _MAGIC:
            raise WalCorruption(f"bad magic at byte {off}")
        if end > total:
            return                               # torn payload at the tail
        # CRC covers (type, pad, lsn, len) — header bytes 2..16, i.e.
        # everything after the magic and before the crc field — + payload
        body = data[off + 2: off + HEADER_BYTES - 4] + \
            data[off + HEADER_BYTES: end]
        if crc32c(body) != crc:
            if end >= total:
                return                           # torn/corrupt tail record
            raise WalCorruption(
                f"CRC mismatch at byte {off} (lsn {lsn}) with intact data "
                f"after it")
        if expect_lsn is not None and lsn != expect_lsn:
            raise WalCorruption(
                f"LSN discontinuity at byte {off}: got {lsn}, "
                f"expected {expect_lsn}")
        yield WalRecord(lsn, kind, data[off + HEADER_BYTES: end], off,
                        HEADER_BYTES + plen)
        expect_lsn = lsn + 1
        off = end
