"""Paged storage engine: page layouts, buffer pool, batch accounting.

The subsystem that turns the repo's analytic page counters into measured
ones (DESIGN.md §8): `pages` owns all page geometry, `bufferpool` models
shared buffers (LRU/clock, cold/warm, telemetry), `engine` translates
executor access traces into pooled page streams and `StorageStats`.
"""
from repro.storage.pages import (PAGE_BYTES, HEAP_PAGE_BYTES,
                                 GraphAdjacencyLayout, HeapLayout,
                                 ScannLeafLayout, heap_pages_per_vector,
                                 quant_heap_pages_per_vector,
                                 scann_pages_per_leaf)
from repro.storage.bufferpool import (POLICIES, BufferPool, BufferPoolState,
                                      PoolCounters)
from repro.storage.faults import FaultInjector, FaultPlan
from repro.storage.engine import (SEGMENTS, TRACE_UNTOUCHED, StorageEngine,
                                  StorageStats, make_storage_engine,
                                  merge_storage_stats)
from repro.storage.delta import DeltaFull, DeltaTier, Tombstones
from repro.storage.wal import (REC_CHECKPOINT, REC_COMPACT, REC_DELETE,
                               REC_INSERT, WalCorruption, WalRecord,
                               WalSyncError, WalTornWrite, WriteAheadLog,
                               crc32c, iter_records)

__all__ = [
    "PAGE_BYTES", "HEAP_PAGE_BYTES", "GraphAdjacencyLayout", "HeapLayout",
    "ScannLeafLayout", "heap_pages_per_vector",
    "quant_heap_pages_per_vector", "scann_pages_per_leaf",
    "POLICIES", "BufferPool", "BufferPoolState", "PoolCounters",
    "FaultInjector", "FaultPlan",
    "SEGMENTS", "TRACE_UNTOUCHED", "StorageEngine", "StorageStats",
    "make_storage_engine", "merge_storage_stats",
    "DeltaFull", "DeltaTier", "Tombstones",
    "REC_CHECKPOINT", "REC_COMPACT", "REC_DELETE", "REC_INSERT",
    "WalCorruption", "WalRecord", "WalSyncError", "WalTornWrite",
    "WriteAheadLog", "crc32c", "iter_records",
]
