"""LSM-style mutable delta tier: append-only rows + tombstone bitmaps
(DESIGN.md §12).

Every build-time index in the repo (graph, ScaNN, SQ8 shadows) is an
immutable artifact; live mutation lands here instead:

  * inserts append rows to a CAPACITY-padded, unindexed segment — scanned
    exactly by `core.executor.DeltaExecutor` and merged into every base
    executor's top-k (core/mutable.py);
  * deletes set bits in a tombstone bitmap over the GLOBAL id space
    [0, capacity) — the same packed uint32 word layout as the filter
    bitmaps, so composing "live" into any query is one AND-NOT over
    words and deleted rows vanish from all strategies without touching
    their indexes.

The capacity padding is what keeps the hot path compile-stable: the delta
arrays have fixed shape (capacity_delta, dim) and only the live `count`
changes per mutation, so the jitted delta scan never recompiles as the
tier fills.

Pure numpy (no repro.core imports — core/types.py imports from this
package); the jitted scan view lives with DeltaExecutor.
"""
from __future__ import annotations

import dataclasses

import numpy as np


def _words(n: int) -> int:
    return (n + 31) // 32


@dataclasses.dataclass
class DeltaTier:
    """Append-only mutable segment over global ids
    [base_n, base_n + count).

    vectors/norms beyond `count` are zero (never scored: the scan masks
    rows >= count).  `version` increments on every mutation — consistent
    snapshots (serving mid-flight lanes, DESIGN.md §12) pin
    (count, version) at admission.
    """

    base_n: int
    capacity: int                 # max delta rows before compaction MUST run
    dim: int
    count: int = 0
    version: int = 0
    vectors: np.ndarray = None    # (capacity, dim) f32
    inserted_bytes: int = 0       # cumulative logical payload (write-amp)

    def __post_init__(self):
        if self.capacity <= 0:
            raise ValueError(f"delta capacity must be > 0, got "
                             f"{self.capacity}")
        if self.vectors is None:
            self.vectors = np.zeros((self.capacity, self.dim), np.float32)
        if self.vectors.shape != (self.capacity, self.dim):
            raise ValueError(
                f"delta vectors shape {self.vectors.shape} != "
                f"{(self.capacity, self.dim)}")

    @property
    def fill(self) -> float:
        return self.count / self.capacity

    def append(self, rows: np.ndarray) -> np.ndarray:
        """Append rows; returns their GLOBAL ids.  Raises when the tier
        is full — the caller must compact first (`MutableIndex.insert`
        auto-compacts)."""
        rows = np.asarray(rows, np.float32)
        if rows.ndim != 2 or rows.shape[1] != self.dim:
            raise ValueError(f"expected (m, {self.dim}) rows, got "
                             f"{rows.shape}")
        m = rows.shape[0]
        if self.count + m > self.capacity:
            raise DeltaFull(
                f"delta tier full: {self.count}+{m} > {self.capacity}")
        self.vectors[self.count: self.count + m] = rows
        ids = self.base_n + self.count + np.arange(m, dtype=np.int64)
        self.count += m
        self.version += 1
        self.inserted_bytes += int(rows.nbytes)
        return ids

    def local_of(self, global_ids: np.ndarray) -> np.ndarray:
        return np.asarray(global_ids, np.int64) - self.base_n

    def reset(self, base_n: int) -> None:
        """Empty the tier after compaction folded it into the base at
        the new `base_n` (rows keep their global ids — the base grew
        underneath them)."""
        self.base_n = base_n
        self.count = 0
        self.version += 1
        self.vectors[:] = 0.0


class DeltaFull(RuntimeError):
    """The delta tier hit capacity; compaction must fold it first."""


class Tombstones:
    """Packed delete bitmap over the global id space [0, capacity).

    Same uint32-word layout as the filter bitmaps (core.types), so
    `live_mask(filter_words)` — filter AND NOT tombstone — is the whole
    delete story for every executor: a deleted row's filter bit is
    cleared before any index ever probes it.
    """

    def __init__(self, capacity: int,
                 words: np.ndarray | None = None):
        self.capacity = capacity
        if words is None:
            self.words = np.zeros(_words(capacity), np.uint32)
        else:
            words = np.asarray(words, np.uint32)
            if words.shape != (_words(capacity),):
                raise ValueError(
                    f"tombstone words shape {words.shape} != "
                    f"({_words(capacity)},)")
            self.words = words.copy()
        self.version = 0

    @property
    def count(self) -> int:
        return int(np.unpackbits(
            self.words.view(np.uint8), bitorder="little").sum())

    def mark(self, ids: np.ndarray) -> int:
        """Tombstone `ids`; returns how many were newly dead (repeat
        deletes are idempotent)."""
        ids = np.asarray(ids, np.int64)
        if ids.size == 0:
            return 0
        if (ids < 0).any() or (ids >= self.capacity).any():
            raise ValueError(f"delete ids out of range [0, "
                             f"{self.capacity})")
        before = self.count
        w = ids >> 5
        b = (np.uint32(1) << (ids & 31).astype(np.uint32))
        np.bitwise_or.at(self.words, w, b)
        self.version += 1
        return self.count - before

    def is_dead(self, ids: np.ndarray) -> np.ndarray:
        ids = np.asarray(ids, np.int64)
        return ((self.words[ids >> 5] >> (ids & 31).astype(np.uint32))
                & np.uint32(1)).astype(bool)

    def live_mask(self, filter_words: np.ndarray) -> np.ndarray:
        """Compose deletes into packed filter bitmaps: filter ∧ ¬dead.
        `filter_words` (..., W') may be narrower than the tombstone span
        (e.g. sized for the base store only) — only the overlapping words
        are masked, and the input is never mutated."""
        fw = np.asarray(filter_words, np.uint32)
        w = min(fw.shape[-1], self.words.shape[0])
        out = fw.copy()
        out[..., :w] &= ~self.words[:w]
        return out

    def dead_ids(self) -> np.ndarray:
        bits = np.unpackbits(self.words.view(np.uint8), bitorder="little")
        return np.nonzero(bits[: self.capacity])[0].astype(np.int64)
