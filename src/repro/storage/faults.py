"""Seeded, deterministic storage fault injection (DESIGN.md §10).

The robustness layer's chaos source: a `FaultPlan` describes *what can go
wrong* on the physical read path — transient page-read failures, latency
spikes, buffer-pool pressure windows — and a `FaultInjector` turns it into
a reproducible schedule.  Faults only ever fire on buffer-pool MISSES
(the physical reads); pool hits are memory reads and stay clean.

Determinism contract: every random draw is a pure hash of
(plan.seed, access counter, salt) — splitmix64, no global RNG state — and
the access counter advances once per logical page access.  Therefore the
same seed driven by the same page-access stream yields the same fault
schedule, the same retry/spike accounting, and the same flagged queries,
run after run (the chaos tests replay this exactly).

Faults are ACCOUNTING-ONLY, like the rest of the storage layer: search
results are always computed from the dense arrays and stay bit-identical;
a failed read marks the owning query `faulted` in StorageStats so the
serving layer can degrade or retry it — it never corrupts data.  An
all-zero plan (`FaultPlan()`) draws nothing and is byte-for-byte the
fault-free path.
"""
from __future__ import annotations

import dataclasses

_M64 = (1 << 64) - 1


def _splitmix64(x: int) -> int:
    x = (x + 0x9E3779B97F4A7C15) & _M64
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & _M64
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & _M64
    return (x ^ (x >> 31)) & _M64


def _uniform(seed: int, counter: int, salt: int) -> float:
    """Deterministic U[0,1) from (seed, counter, salt) — counter-keyed so
    the schedule is a pure function of the access stream.  (counter, salt)
    pack disjoint bit ranges (salt < 2**16, counter < 2**48), so every
    (access, decision-kind, attempt) triple draws independently."""
    h = _splitmix64((seed & _M64) ^ _splitmix64(((counter << 16) ^ salt)
                                               & _M64))
    return h / float(1 << 64)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """What the injector may do, with what probability.  All-zero
    probabilities (the default) disable injection entirely."""

    seed: int = 0
    # transient page-read failure per physical read ATTEMPT; each failed
    # attempt retries (with accounting) up to max_retries times — a read
    # whose every attempt fails is a failed read and flags the query
    read_fail_prob: float = 0.0
    max_retries: int = 3
    # latency spike per successful physical read (charged a
    # page_miss_extra-style surcharge by costmodel.fault_penalty)
    latency_spike_prob: float = 0.0
    # pool-pressure windows: per logical access, chance a window opens
    # during which the pool's effective capacity shrinks to pressure_frac
    # of nominal for the next pressure_len logical accesses
    pressure_prob: float = 0.0
    pressure_len: int = 256
    pressure_frac: float = 0.5
    # -- write-path faults (DESIGN.md §12) --------------------------------
    # torn WAL append: per append, chance the "process dies" mid-write,
    # leaving a deterministic prefix of the record on disk (the prefix
    # fraction is itself a counter-keyed draw, never 0 or all bytes) —
    # storage/wal.py raises WalTornWrite and the recovery harness must
    # truncate the torn tail via the record CRC
    wal_torn_prob: float = 0.0
    # failed fsync: per sync, chance the flush never reaches storage —
    # wal.durable_offset does not advance and WalSyncError is raised
    fsync_fail_prob: float = 0.0

    @property
    def active(self) -> bool:
        return (self.read_fail_prob > 0 or self.latency_spike_prob > 0
                or self.pressure_prob > 0 or self.write_active)

    @property
    def write_active(self) -> bool:
        return self.wal_torn_prob > 0 or self.fsync_fail_prob > 0


# draw salts (namespacing the counter-keyed hash per decision kind)
_SALT_FAIL = 1
_SALT_SPIKE = 2
_SALT_PRESSURE = 3
_SALT_WAL_TORN = 4
_SALT_WAL_FRAC = 5
_SALT_FSYNC = 6


class FaultInjector:
    """Stateful executor of one FaultPlan over one pool's access stream.

    State is a handful of integers — the monotone logical-access counter,
    the end of the current pressure window, and the write-path counters
    (WAL appends / fsyncs seen) — so `reset()` (or constructing a fresh
    injector) replays the identical schedule.  Read-path and write-path
    draws are keyed on DISJOINT counters: interleaving searches with
    ingestion does not perturb either schedule.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.counter = 0
        self._pressure_until = 0
        self.wal_appends = 0
        self.wal_syncs = 0

    def reset(self) -> None:
        self.counter = 0
        self._pressure_until = 0
        self.wal_appends = 0
        self.wal_syncs = 0

    # -- per-access hooks (called by BufferPool.access) ---------------------
    def tick(self) -> None:
        """Advance the logical-access counter; maybe open a pressure
        window.  Called once per logical page access, hit or miss, so the
        schedule depends only on the access stream."""
        self.counter += 1
        p = self.plan
        if p.pressure_prob > 0 and self.counter >= self._pressure_until:
            if _uniform(p.seed, self.counter, _SALT_PRESSURE) \
                    < p.pressure_prob:
                self._pressure_until = self.counter + p.pressure_len

    def capacity_frac(self) -> float:
        """Effective-capacity fraction right now (1.0 outside windows)."""
        if self.counter < self._pressure_until:
            return self.plan.pressure_frac
        return 1.0

    def on_miss(self) -> tuple[int, bool, bool]:
        """Fault outcome of one physical read (a pool miss).

        Returns (retries, failed, spike): `retries` attempts were repeated
        after transient failures; `failed` means every attempt (1 +
        max_retries) failed — the read never completed and the owning
        query must be flagged; `spike` marks a slow (but successful) read.
        """
        p = self.plan
        retries = 0
        failed = False
        if p.read_fail_prob > 0:
            for attempt in range(1 + p.max_retries):
                if _uniform(p.seed, self.counter,
                            _SALT_FAIL + (attempt << 8)) >= p.read_fail_prob:
                    break
                if attempt == p.max_retries:
                    failed = True
                else:
                    retries += 1
        spike = False
        if not failed and p.latency_spike_prob > 0:
            spike = _uniform(p.seed, self.counter, _SALT_SPIKE) \
                < p.latency_spike_prob
        return retries, failed, spike

    # -- write-path hooks (called by storage/wal.py) ------------------------
    def on_wal_append(self, record_bytes: int):
        """Torn-append decision for one WAL append of `record_bytes`
        bytes.  Returns None (clean write) or the number of bytes that
        reach the file before the simulated crash — always at least 1 and
        strictly less than the record, so the tail is genuinely torn (the
        CRC must catch it).  Counter-keyed on the append counter."""
        self.wal_appends += 1
        p = self.plan
        if p.wal_torn_prob <= 0:
            return None
        if _uniform(p.seed, self.wal_appends, _SALT_WAL_TORN) \
                >= p.wal_torn_prob:
            return None
        frac = _uniform(p.seed, self.wal_appends, _SALT_WAL_FRAC)
        return max(1, min(record_bytes - 1, int(frac * record_bytes)))

    def on_fsync(self) -> bool:
        """True when this fsync fails (counter-keyed on the sync
        counter): the flushed bytes may never reach storage."""
        self.wal_syncs += 1
        p = self.plan
        if p.fsync_fail_prob <= 0:
            return False
        return _uniform(p.seed, self.wal_syncs, _SALT_FSYNC) \
            < p.fsync_fail_prob
