"""Buffer pool: fixed-capacity page cache with LRU/clock replacement and
hit/miss/eviction telemetry (DESIGN.md §8).

This is the system component the paper keeps pointing at: the winning FVS
strategy is decided by buffer-manager behavior — hit rates, cold vs warm,
page-level locality — not distance FLOPs.  The pool models a PostgreSQL
shared-buffers analogue over the global page-id space the storage layouts
(pages.py) define: executors feed it their page-access traces and it
answers which accesses were physical (misses) vs served from the pool
(hits).

Data plane and accounting are deliberately decoupled: vector *values* are
always gathered from the dense JAX arrays (bit-identical results by
construction); the pool tracks which 8 KB pages those gathers would have
pinned.  Accounting runs host-side on numpy traces — it never enters a
jitted loop.

Modes:
  cold  — `reset()` empties the pool (first-touch of every page misses);
  warm  — the pool persists across `access` calls (and, held by an
          executor, across whole request batches — serving/rag.py).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Mapping, Optional

import numpy as np

POLICIES = ("lru", "clock")


@dataclasses.dataclass
class PoolCounters:
    """Cumulative telemetry since construction / last `reset_counters`."""

    logical: int = 0       # page accesses fed to the pool
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    # fault-injection telemetry (storage/faults.py; zero without a plan):
    retries: int = 0       # transient read failures that were retried
    failed_reads: int = 0  # reads whose every attempt failed
    spikes: int = 0        # slow (latency-spiked) physical reads
    # write-path telemetry (DESIGN.md §12; zero on a read-only workload):
    dirtied: int = 0       # clean->dirty page transitions
    page_writes: int = 0   # physical write-backs (dirty eviction or flush)
    invalidated: int = 0   # pages dropped WITHOUT write-back (compaction)

    @property
    def hit_rate(self) -> float:
        return self.hits / self.logical if self.logical else 0.0

    def as_dict(self) -> dict:
        return dict(logical=self.logical, hits=self.hits,
                    misses=self.misses, evictions=self.evictions,
                    retries=self.retries, failed_reads=self.failed_reads,
                    spikes=self.spikes, dirtied=self.dirtied,
                    page_writes=self.page_writes,
                    invalidated=self.invalidated,
                    hit_rate=round(self.hit_rate, 4))


@dataclasses.dataclass(frozen=True)
class BufferPoolState:
    """Residency snapshot the AdaptivePlanner consumes (DESIGN.md §8):
    per-segment fraction of that segment's pages currently resident.
    A strategy about to touch segment S expects ~`1 - residency[S]` of its
    page accesses to miss (uniform-touch approximation)."""

    capacity: int
    used: int
    residency: Mapping[str, float]     # segment name -> resident fraction
    # dirty-page exposure (DESIGN.md §12): pages resident-and-modified,
    # i.e. write-back debt a checkpoint/flush would have to pay.  Zero on
    # read-only workloads, so read-side callers can ignore these.
    dirty: int = 0
    dirty_by_segment: Mapping[str, int] = dataclasses.field(
        default_factory=dict)

    def miss_fraction(self, segment: str) -> float:
        return 1.0 - self.residency.get(segment, 0.0)


class BufferPool:
    """Fixed-capacity page cache. `capacity_pages <= 0` means unbounded
    (everything stays resident once touched — the flat-memory LIBRARY
    regime).

    `segments` (name -> (lo, hi) page-id range, non-overlapping) enables
    O(1)-maintained per-segment residency counters, so `state()` — called
    by AdaptivePlanner on every plan — never scans the resident set."""

    def __init__(self, capacity_pages: int, policy: str = "lru",
                 segments: Optional[Mapping[str, tuple[int, int]]] = None,
                 faults=None):
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; one of {POLICIES}")
        self.capacity = int(capacity_pages)
        self.policy = policy
        # optional storage/faults.FaultInjector consulted on the access
        # path; None (or an inactive plan) keeps this path byte-identical
        # to the fault-free pool
        self.faults = faults
        # page id -> clock reference bit (ignored under LRU; OrderedDict
        # order IS the recency/insertion order for lru/clock respectively)
        self._pages: OrderedDict[int, bool] = OrderedDict()
        # resident pages that have been modified since they were read —
        # write-back debt: a dirty page costs one physical write when it
        # leaves the pool via eviction or flush() (never via invalidate())
        self._dirty: set[int] = set()
        self.counters = PoolCounters()
        self._segments = dict(segments) if segments else {}
        self._seg_los = sorted((lo, hi, name)
                               for name, (lo, hi) in self._segments.items())
        self._seg_count = dict.fromkeys(self._segments, 0)
        self._seg_dirty = dict.fromkeys(self._segments, 0)

    def _segment_of(self, page: int) -> Optional[str]:
        import bisect
        i = bisect.bisect_right(self._seg_los, (page, float("inf"), "")) - 1
        if i >= 0:
            lo, hi, name = self._seg_los[i]
            if lo <= page < hi:
                return name
        return None

    def _count(self, page: int, delta: int) -> None:
        if self._segments:
            seg = self._segment_of(page)
            if seg is not None:
                self._seg_count[seg] += delta

    def _mark_dirty(self, page: int, counters: "PoolCounters") -> None:
        if page in self._dirty:
            return
        self._dirty.add(page)
        counters.dirtied += 1
        if self._segments:
            seg = self._segment_of(page)
            if seg is not None:
                self._seg_dirty[seg] += 1

    def _clear_dirty(self, page: int) -> bool:
        """Drop `page`'s dirty bit; True iff it was dirty."""
        if page not in self._dirty:
            return False
        self._dirty.discard(page)
        if self._segments:
            seg = self._segment_of(page)
            if seg is not None:
                self._seg_dirty[seg] -= 1
        return True

    # -- introspection ------------------------------------------------------
    def __len__(self) -> int:
        return len(self._pages)

    def __contains__(self, page: int) -> bool:
        return int(page) in self._pages

    def resident_in(self, lo: int, hi: int) -> int:
        """Resident pages with lo <= id < hi (a segment range)."""
        return sum(1 for p in self._pages if lo <= p < hi)

    # -- modes --------------------------------------------------------------
    def reset(self) -> None:
        """Cold mode: drop every resident page (telemetry survives).

        Explicit semantics for the write path (DESIGN.md §12): reset()
        models a cold RESTART, not an orderly shutdown — dirty pages are
        dropped WITHOUT write-back and without touching `page_writes`
        (their contents are presumed lost; durability comes from the WAL,
        never from the pool).  Callers that need the write-back accounted
        must `flush()` first; callers retiring compaction-rebuilt segments
        must use `invalidate(lo, hi)` so stale residency/dirty counters
        for the dead page range cannot leak into planner snapshots."""
        self._pages.clear()
        self._dirty.clear()
        self._seg_count = dict.fromkeys(self._segments, 0)
        self._seg_dirty = dict.fromkeys(self._segments, 0)

    def flush(self, lo: int = 0, hi: Optional[int] = None) -> int:
        """Write back every dirty page with lo <= id < hi (default: all).
        Pages stay resident, now clean; returns (and counts as
        `page_writes`) how many physical writes that took — the
        checkpoint / fsync-point cost."""
        if hi is None:
            victims = list(self._dirty)
        else:
            victims = [p for p in self._dirty if lo <= p < hi]
        for p in victims:
            self._clear_dirty(p)
        self.counters.page_writes += len(victims)
        return len(victims)

    def invalidate(self, lo: int, hi: int) -> int:
        """Drop every resident page with lo <= id < hi WITHOUT write-back
        — the page range's backing objects no longer exist (compaction
        rebuilt the segment), so residency would be stale and a write-back
        would be I/O for garbage.  Counted as `invalidated`, never as
        evictions or page_writes.  Returns the number of pages dropped."""
        victims = [p for p in self._pages if lo <= p < hi]
        for p in victims:
            del self._pages[p]
            self._count(p, -1)
            self._clear_dirty(p)
        self.counters.invalidated += len(victims)
        return len(victims)

    def reset_counters(self) -> None:
        self.counters = PoolCounters()

    # -- the access path ----------------------------------------------------
    def access(self, pages: np.ndarray, dedup: bool = False,
               dirty: bool = False) -> PoolCounters:
        """Run a page-access trace through the pool, in order.

        `dedup=True` is the batch semantics (DESIGN.md §5/§8): duplicate
        pages within THIS call are charged once — first occurrence decides
        hit/miss, repeats are neither logical accesses nor touches
        (idempotent: access(p, dedup=True) twice in one call == once).
        `dirty=True` is the write path (DESIGN.md §12): each touched page
        is marked modified (clean->dirty transitions count as `dirtied`)
        and will cost a physical write when evicted or flushed.
        Returns the per-call delta counters (cumulative ones accrue on
        `self.counters`).
        """
        pages = np.asarray(pages).reshape(-1)
        if dedup and len(pages):
            _, first = np.unique(pages, return_index=True)
            pages = pages[np.sort(first)]        # first-touch order kept
        inj = self.faults if (self.faults is not None
                              and self.faults.plan.active) else None
        delta = PoolCounters()
        for p in pages.tolist():
            delta.logical += 1
            if inj is not None:
                inj.tick()
            if p in self._pages:
                delta.hits += 1
                if self.policy == "lru":
                    self._pages.move_to_end(p)
                else:
                    self._pages[p] = True        # clock reference bit
                if dirty:
                    self._mark_dirty(p, delta)
                continue
            delta.misses += 1
            if inj is not None:
                retries, failed, spike = inj.on_miss()
                delta.retries += retries
                delta.spikes += int(spike)
                if failed:
                    # read never completed: page stays non-resident (a
                    # later access retries the physical read afresh)
                    delta.failed_reads += 1
                    continue
            cap = self.capacity
            if cap > 0 and inj is not None:
                cap = max(1, int(cap * inj.capacity_frac()))
            if cap > 0:
                while len(self._pages) >= cap:   # pressure may shrink cap
                    self._evict(delta)           # below current residency
                    delta.evictions += 1
            self._pages[p] = False
            self._count(p, +1)
            if dirty:
                self._mark_dirty(p, delta)
        self._merge(delta)
        return delta

    def _merge(self, delta: "PoolCounters") -> None:
        c, d = self.counters, delta
        (c.logical, c.hits, c.misses, c.evictions, c.retries,
         c.failed_reads, c.spikes, c.dirtied, c.page_writes,
         c.invalidated) = (
            c.logical + d.logical, c.hits + d.hits, c.misses + d.misses,
            c.evictions + d.evictions, c.retries + d.retries,
            c.failed_reads + d.failed_reads, c.spikes + d.spikes,
            c.dirtied + d.dirtied, c.page_writes + d.page_writes,
            c.invalidated + d.invalidated)

    def _evict(self, delta: Optional["PoolCounters"] = None) -> None:
        if self.policy == "lru":
            page, _ = self._pages.popitem(last=False)   # least recently used
            self._count(page, -1)
            if self._clear_dirty(page) and delta is not None:
                delta.page_writes += 1          # dirty eviction writes back
            return
        # clock / second-chance as a FIFO ring: sweep from the oldest
        # entry, rotating referenced pages to the back with their bit
        # cleared — O(1) amortized, no key-list materialization
        while True:
            k, ref = next(iter(self._pages.items()))
            if ref:
                self._pages[k] = False
                self._pages.move_to_end(k)
            else:
                del self._pages[k]
                self._count(k, -1)
                if self._clear_dirty(k) and delta is not None:
                    delta.page_writes += 1
                return

    # -- planner snapshot ---------------------------------------------------
    def state(self, segments: Optional[Mapping[str, tuple[int, int]]] = None
              ) -> BufferPoolState:
        """Residency snapshot. `segments` maps name -> (lo, hi) page-id
        range; residency = resident / segment size — the plain fraction of
        the segment's pages currently resident, so `1 − residency` is the
        expected miss fraction of a uniform access over the segment
        (`costmodel.cache_miss_penalty`'s contract).  A pool smaller than
        the segment can therefore never report it fully warm.  Segments
        configured at construction read the incrementally-maintained
        counters (O(1)); ad-hoc ranges fall back to a resident-set scan."""
        res = {}
        for name, (lo, hi) in (segments or self._segments).items():
            size = max(1, hi - lo)
            if name in self._segments and self._segments[name] == (lo, hi):
                n_res = self._seg_count[name]
            else:
                n_res = self.resident_in(lo, hi)
            res[name] = min(1.0, n_res / size)
        dirty_by_seg = {name: self._seg_dirty.get(name, 0)
                        for name in (segments or self._segments)}
        return BufferPoolState(capacity=self.capacity, used=len(self._pages),
                               residency=res, dirty=len(self._dirty),
                               dirty_by_segment=dirty_by_seg)
