"""Fixed-size page layouts — the one owner of page geometry (DESIGN.md §8).

The paper's central object model is a page engine: full-precision vector
rows live on 8 KB *heap* pages, quantized ScaNN posting lists on leaf
pages, and graph adjacency (HNSW element tuples) on index pages.  Until
this module, the repo asserted that geometry in scattered constants
(`heap_pages_per_vector` in core/types.py, `PAGE_BYTES` in core/scann.py);
every layout now lives here and everything else (counters, cost model,
buffer-pool accounting) derives from it.

Layouts are PostgreSQL-like in the one property the counters depend on:
**an object never straddles a page boundary it doesn't have to** — a row
that fits in a page occupies exactly one page, a row larger than a page
occupies `ceil(bytes / PAGE_BYTES)` dedicated pages.  Hence logical page
accesses per object touch are exactly the analytic per-object constants
the SearchStats counters have always charged (`pages_per_row`,
`pages_per_leaf`, 1 adjacency page per node), and the layouts additionally
pin *which* physical pages those are — what the buffer pool needs.

Pure numpy; no repro.core imports (core/types.py imports from here).
"""
from __future__ import annotations

import dataclasses

import numpy as np

PAGE_BYTES = 8192
# Backward-compat alias (core/types.py re-exports it under this name).
HEAP_PAGE_BYTES = PAGE_BYTES


def heap_pages_per_vector(dim: int) -> int:
    """Heap pages touched per full-precision vector fetch (8 KB pages)."""
    return max(1, -(-dim * 4 // PAGE_BYTES))


def quant_heap_pages_per_vector(dim: int) -> int:
    """Heap pages touched per SQ8 (1 byte/dim) vector fetch.  Same
    no-straddle convention as the f32 formula; 4× more rows pack per page,
    so the per-fetch constant only drops for rows wider than a page —
    the density win shows up in *which* pages are touched (fewer distinct
    pages per traversal), which the buffer pool measures (DESIGN.md §9)."""
    return max(1, -(-dim // PAGE_BYTES))


def scann_pages_per_leaf(cap: int, dp: int) -> int:
    """Quantized-leaf pages per ScaNN leaf: (C, dp) int8 tile on 8 KB pages."""
    return max(1, -(-cap * dp // PAGE_BYTES))


@dataclasses.dataclass(frozen=True)
class HeapLayout:
    """Vector rows on 8 KB heap pages.

    If a row fits in a page, `rows_per_page` rows pack per page and one
    fetch touches 1 page; otherwise each row owns `pages_per_row`
    consecutive pages and one fetch touches all of them.  Either way the
    logical page touches per fetched row equal
    `heap_pages_per_vector(dim)` — the analytic constant, now derived.

    `value_bytes` is the stored width per dimension: 4 for the
    full-precision heap, 1 for the SQ8 shadow heap (DESIGN.md §9) —
    quantized rows pack 4× denser, so the same traversal touches ~4×
    fewer distinct pages.
    """

    n: int
    dim: int
    value_bytes: int = 4

    @property
    def row_bytes(self) -> int:
        return self.dim * self.value_bytes

    @property
    def pages_per_row(self) -> int:
        return max(1, -(-self.row_bytes // PAGE_BYTES))

    @property
    def rows_per_page(self) -> int:
        if self.pages_per_row > 1:
            return 1
        return max(1, PAGE_BYTES // self.row_bytes)

    @property
    def num_pages(self) -> int:
        if self.pages_per_row > 1:
            return self.n * self.pages_per_row
        return -(-self.n // self.rows_per_page)

    def pages_for_rows(self, rows: np.ndarray) -> np.ndarray:
        """Page ids touched fetching `rows`, in fetch order: `pages_per_row`
        consecutive pages per row (so len == len(rows) * pages_per_row —
        the logical access count)."""
        rows = np.asarray(rows, np.int64)
        ppr = self.pages_per_row
        if ppr == 1:
            return rows // self.rows_per_page
        return (rows[:, None] * ppr + np.arange(ppr)).reshape(-1)


@dataclasses.dataclass(frozen=True)
class ScannLeafLayout:
    """Quantized ScaNN posting lists: each leaf's (C, dp) int8 tile occupies
    `pages_per_leaf` consecutive index pages (the paper's "leaf packs as
    many vectors as fit in a page, linked list of pages")."""

    num_leaves: int
    cap: int
    dp: int

    @property
    def pages_per_leaf(self) -> int:
        return scann_pages_per_leaf(self.cap, self.dp)

    @property
    def num_pages(self) -> int:
        return self.num_leaves * self.pages_per_leaf

    def pages_for_leaves(self, leaves: np.ndarray) -> np.ndarray:
        """Page ids touched opening `leaves`, in open order (`pages_per_leaf`
        consecutive pages per leaf)."""
        leaves = np.asarray(leaves, np.int64)
        ppl = self.pages_per_leaf
        return (leaves[:, None] * ppl + np.arange(ppl)).reshape(-1)


@dataclasses.dataclass(frozen=True)
class GraphAdjacencyLayout:
    """HNSW element tuples (level-0 neighbor list + per-level links) on
    index pages.  One node touch = one logical index-page access — the
    analytic convention of every graph counter; the layout pins which
    page by packing `nodes_per_page` element tuples per 8 KB page."""

    n: int
    degree: int                    # level-0 neighbor count (2M)

    @property
    def entry_bytes(self) -> int:
        # neighbor ids (int32) + heaptid/level header, PG-tuple-ish
        return self.degree * 4 + 64

    @property
    def nodes_per_page(self) -> int:
        return max(1, PAGE_BYTES // self.entry_bytes)

    @property
    def num_pages(self) -> int:
        return -(-self.n // self.nodes_per_page)

    def pages_for_nodes(self, nodes: np.ndarray) -> np.ndarray:
        """Page ids of `nodes`' adjacency entries, one per node touch."""
        return np.asarray(nodes, np.int64) // self.nodes_per_page
