"""Paged storage engine: layouts + buffer pool + per-batch accounting
(DESIGN.md §8).

`StorageEngine` owns the page segments of the paged object model —

    heap   — full-precision vector rows        (pages.HeapLayout)
    scann  — quantized ScaNN posting lists     (pages.ScannLeafLayout)
    graph  — HNSW adjacency / element tuples   (pages.GraphAdjacencyLayout)
    qheap  — SQ8 shadow vector rows            (pages.HeapLayout, 1 B/dim)

— mapped into one global page-id space, fronted by one `BufferPool`
(shared buffers).  Executors run their (bit-identical) jitted searches
with trace collection on, then hand the traces here; the engine translates
object touches into page-access streams through the layouts, runs them
through the pool, and returns a `StorageStats`: measured logical accesses
per query plus the pool's physical hit/miss/eviction split.

Accounting semantics (matching the SearchStats counter semantics they are
validated against — tests/test_storage.py):

  * scann "per_query": every query's opened leaves are charged through the
    pool individually (repeat opens across queries are pool *hits*, but
    every open is a logical access) — measured logical index pages per
    query == nl × pages_per_leaf, exactly the analytic counter.
  * scann "batch": duplicate leaves across the batch are charged once, to
    the first query that opened them — measured logical ==
    unique_opened_leaves × pages_per_leaf, summed over the batch, exactly
    the "batch" accounting of scann_search_batch (DESIGN.md §5).
  * heap (reorder / seqscan / graph fetches): always per query —
    `pages_per_row` logical pages per fetched row; cross-query repeats
    are hits, not elided accesses.
  * graph traces arrive as per-query FIRST-TOUCH superstep stamps
    (`steps[obj]` = hop counter of the step that first fetched the
    object, TRACE_UNTOUCHED where never fetched), so graph
    measured-logical counts each touched object once AND the replay is
    superstep-order-faithful: within a query, objects are fed to the
    pool sorted by (first-touch step, id) — LRU/clock sees them in the
    order the traversal actually fetched them, id-ascending only as the
    within-step tiebreak.  Zoom-in re-scores (a node scored at two upper
    levels) are charged once here but twice by the analytic counters —
    the only place measured ≤ analytic instead of ==.
  * sq8 quantized traversal (DESIGN.md §9): the traversal's row fetches
    replay through the dense "qheap" shadow segment (4× more rows per
    page), and the exact rerank's full-width fetches replay through
    "heap" in candidate order — so the Table 4 question (does quantized
    traversal actually shrink heap traffic?) is answered by measured
    pages, not a rescaled counter.

Host-side numpy only; nothing here enters a jitted trace.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional

import numpy as np

from repro.storage.bufferpool import BufferPool, BufferPoolState
from repro.storage.faults import FaultInjector, FaultPlan
from repro.storage.pages import (PAGE_BYTES, GraphAdjacencyLayout,
                                 HeapLayout, ScannLeafLayout)

SEGMENTS = ("heap", "scann", "graph", "qheap", "delta", "wal")

# First-touch stamp sentinel for untouched objects — numerically pinned to
# int32 max, the same value core.graph_search.TRACE_UNTOUCHED stamps with
# (both derive from iinfo(int32); they cannot drift).
TRACE_UNTOUCHED = np.iinfo(np.int32).max


def _unpack_bits(words: np.ndarray, n: int) -> np.ndarray:
    """(W,) uint32 packed bitset -> (n,) bool (numpy-local; no core dep)."""
    w = np.asarray(words, np.uint32)
    bits = (w[:, None] >> np.arange(32, dtype=np.uint32)) & np.uint32(1)
    return bits.reshape(-1)[:n].astype(bool)


def _ordered_touches(steps: np.ndarray) -> np.ndarray:
    """Touched object ids of one query's first-touch stamp array, in
    replay order: sorted by (first-touch step, id)."""
    steps = np.asarray(steps)
    ids = np.nonzero(steps < TRACE_UNTOUCHED)[0]
    return ids[np.argsort(steps[ids], kind="stable")]


@dataclasses.dataclass
class StorageStats:
    """Measured per-batch storage telemetry (one executor call)."""

    logical: dict            # segment -> logical page accesses (batch sum)
    hits: dict               # segment -> pool hits
    misses: dict             # segment -> pool misses (physical reads)
    evictions: int
    # per-query measured logical counters (the SearchStats comparables):
    index_pages: np.ndarray  # (Q,) scann-or-graph index pages charged
    heap_pages: np.ndarray   # (Q,) heap pages charged
    # segment -> DISTINCT pages touched this batch (pool-independent):
    # unique/logical is the batch's page-sharing (unique-fetch) fraction,
    # the measured replacement for costmodel.FRONTIER_PAGE_AMORT's
    # calibration anchor (DESIGN.md §9).
    unique: dict = dataclasses.field(default_factory=dict)
    # fault-injection telemetry (storage/faults.py; zeros without a plan):
    retries: int = 0                          # retried transient failures
    failed_reads: int = 0                     # reads that never completed
    spikes: int = 0                           # latency-spiked reads
    faulted: Optional[np.ndarray] = None      # (Q,) bool: query saw a
    #                                           failed read (serving ladder
    #                                           degrades/retries these)

    @property
    def logical_total(self) -> int:
        return int(sum(self.logical.values()))

    @property
    def miss_total(self) -> int:
        return int(sum(self.misses.values()))

    @property
    def hit_rate(self) -> float:
        t = self.logical_total
        return float(sum(self.hits.values())) / t if t else 0.0

    def unique_fraction(self, segments=None) -> float:
        """Distinct/logical page fraction over `segments` (default: all).
        1.0 = no intra-batch page sharing; lower = queries share pages."""
        segs = segments if segments is not None else self.logical.keys()
        log = sum(self.logical.get(s, 0) for s in segs)
        unq = sum(self.unique.get(s, 0) for s in segs)
        return unq / log if log else 1.0

    def as_dict(self) -> dict:
        return dict(logical=dict(self.logical), hits=dict(self.hits),
                    misses=dict(self.misses), evictions=self.evictions,
                    hit_rate=round(self.hit_rate, 4),
                    unique=dict(self.unique),
                    retries=self.retries, failed_reads=self.failed_reads,
                    spikes=self.spikes,
                    faulted=(self.faulted.tolist()
                             if self.faulted is not None else None),
                    index_pages=self.index_pages.tolist(),
                    heap_pages=self.heap_pages.tolist())


def merge_storage_stats(parts: list[StorageStats]) -> StorageStats:
    """Aggregate per-shard StorageStats into one batch total (DESIGN.md
    §13): counter dicts and per-query page arrays sum segment-/query-wise,
    fault flags OR.  Shards own disjoint row ranges, so summing `unique`
    counts distinct pages exactly up to the one heap page a range boundary
    can split across two shards — the same page id counted once per
    engine that touched it (each engine has its own pool, so the access
    really was replayed in both)."""
    if not parts:
        raise ValueError("merge_storage_stats needs at least one part")

    def dsum(key):
        out: dict = {}
        for p in parts:
            for seg, v in getattr(p, key).items():
                out[seg] = out.get(seg, 0) + v
        return out

    faulted = None
    if any(p.faulted is not None for p in parts):
        faulted = np.zeros_like(
            next(p.faulted for p in parts if p.faulted is not None))
        for p in parts:
            if p.faulted is not None:
                faulted |= p.faulted
    return StorageStats(
        logical=dsum("logical"), hits=dsum("hits"), misses=dsum("misses"),
        evictions=sum(p.evictions for p in parts),
        index_pages=sum(p.index_pages for p in parts),
        heap_pages=sum(p.heap_pages for p in parts),
        unique=dsum("unique"),
        retries=sum(p.retries for p in parts),
        failed_reads=sum(p.failed_reads for p in parts),
        spikes=sum(p.spikes for p in parts), faulted=faulted)


class StorageEngine:
    """Layouts + pool + accounting for one dataset's page space."""

    def __init__(self, heap: HeapLayout,
                 scann: Optional[ScannLeafLayout] = None,
                 graph: Optional[GraphAdjacencyLayout] = None,
                 capacity_pages: Optional[int] = None,
                 capacity_frac: float = 0.5, policy: str = "lru",
                 qheap: Optional[HeapLayout] = None,
                 faults: Optional[FaultPlan] = None,
                 delta: Optional[HeapLayout] = None,
                 wal_pages: int = 0):
        self.heap = heap
        self.scann = scann
        self.graph = graph
        self.qheap = qheap
        # mutable delta tier (DESIGN.md §12): `delta` lays out the
        # capacity-padded append-only rows; the tombstone bitmap over the
        # WHOLE id space (base + delta) rides in the same segment, after
        # the row pages.  `wal_pages` reserves a ring of WAL pages —
        # append offsets wrap, modelling log recycling past checkpoints.
        self.delta = delta
        self.wal_pages = int(wal_pages)
        self._tomb_pages = 0
        if delta is not None:
            tomb_bytes = 4 * ((heap.n + delta.n + 31) // 32)
            self._tomb_pages = -(-tomb_bytes // PAGE_BYTES)
        # global page-id space: [heap | scann | graph | qheap | delta | wal]
        self._sizes = {"heap": heap.num_pages}
        if scann is not None:
            self._sizes["scann"] = scann.num_pages
        if graph is not None:
            self._sizes["graph"] = graph.num_pages
        if qheap is not None:
            self._sizes["qheap"] = qheap.num_pages
        if delta is not None:
            self._sizes["delta"] = delta.num_pages + self._tomb_pages
        if self.wal_pages > 0:
            self._sizes["wal"] = self.wal_pages
        self._base = {}
        off = 0
        for name, size in self._sizes.items():
            self._base[name] = off
            off += size
        self.total_pages = off
        if capacity_pages is None:
            capacity_pages = max(1, int(round(capacity_frac * off)))
        self.faults = faults
        injector = FaultInjector(faults) if (faults is not None
                                            and faults.active) else None
        self.pool = BufferPool(capacity_pages, policy=policy,
                               segments=self.segment_ranges(),
                               faults=injector)

    # -- segment helpers ----------------------------------------------------
    def segment_ranges(self) -> dict[str, tuple[int, int]]:
        return {name: (lo, lo + self._sizes[name])
                for name, lo in self._base.items()}

    def state(self) -> BufferPoolState:
        return self.pool.state(self.segment_ranges())

    def reset_cold(self) -> None:
        self.pool.reset()

    # -- accounting entry points --------------------------------------------
    def _replay(self, streams) -> StorageStats:
        """Run per-query page streams through the pool and accumulate one
        StorageStats.  `streams` is, per query, a list of
        (segment, page_ids) in access order; segment "heap" accrues to the
        per-query heap counter, anything else to the index counter."""
        q = len(streams)
        segs = sorted({s for per_q in streams for s, _ in per_q})
        log = dict.fromkeys(segs, 0)
        hit = dict.fromkeys(segs, 0)
        mis = dict.fromkeys(segs, 0)
        uniq: dict[str, set] = {s: set() for s in segs}
        ev = ret = fail = spk = 0
        idx_pages = np.zeros(q, np.int64)
        heap_pages = np.zeros(q, np.int64)
        faulted = np.zeros(q, bool)
        for i, per_q in enumerate(streams):
            for seg, pages in per_q:
                pages = np.asarray(pages)
                d = self.pool.access(self._base[seg] + pages)
                log[seg] += d.logical
                hit[seg] += d.hits
                mis[seg] += d.misses
                uniq[seg].update(pages.tolist())
                ev += d.evictions
                ret += d.retries
                fail += d.failed_reads
                spk += d.spikes
                if d.failed_reads:
                    faulted[i] = True
                if seg in ("heap", "qheap", "delta"):
                    heap_pages[i] += d.logical
                else:
                    idx_pages[i] += d.logical
        return StorageStats(log, hit, mis, ev, idx_pages, heap_pages,
                            unique={s: len(v) for s, v in uniq.items()},
                            retries=ret, failed_reads=fail, spikes=spk,
                            faulted=faulted)

    def account_scann(self, leaves: np.ndarray, cand_rows: np.ndarray,
                      cand_ok: np.ndarray,
                      accounting: str = "per_query",
                      query_block: int = 0) -> StorageStats:
        """leaves (Q, nl) opened per query; cand_rows/cand_ok (Q, r) the
        reorder gather.  `accounting` mirrors
        SearchParams.scann_page_accounting; `query_block` mirrors
        SearchParams.scann_query_block — under "batch" accounting the
        pipeline amortizes leaf opens per query-block TILE, not per whole
        batch (DESIGN.md §4/§5), so the first-touch dedup window resets at
        every tile boundary to keep measured == analytic.  Batch-mode
        dedup applies within a query's own leaf list too (the analytic
        counter charges the leaf UNION, which collapses repeats)."""
        if self.scann is None:
            raise ValueError("engine built without a scann layout")
        if accounting not in ("per_query", "batch"):
            raise ValueError(f"unknown accounting {accounting!r}")
        leaves = np.asarray(leaves)
        cand_rows = np.asarray(cand_rows)
        cand_ok = np.asarray(cand_ok, bool)
        streams = []
        seen: set[int] = set()
        for i in range(leaves.shape[0]):
            lv = leaves[i]
            if accounting == "batch":
                if query_block > 0 and i % query_block == 0:
                    seen.clear()              # new tile: fresh dedup window
                first = []
                for leaf in lv.tolist():
                    if leaf not in seen:
                        seen.add(leaf)
                        first.append(leaf)
                lv = np.array(first, np.int64)
            streams.append([
                ("scann", self.scann.pages_for_leaves(lv)),
                ("heap", self.heap.pages_for_rows(cand_rows[i][cand_ok[i]])),
            ])
        return self._replay(streams)

    def account_graph(self, heap_steps: np.ndarray,
                      index_steps: np.ndarray,
                      rerank_rows: Optional[np.ndarray] = None,
                      quant: bool = False) -> StorageStats:
        """Per-query first-touch superstep stamps from the frontier
        engine's trace: heap_steps (rows fetched during traversal),
        index_steps (adjacency entries read) — each (Q, n) int32,
        TRACE_UNTOUCHED where never touched.  Within a query, pages
        replay in (first-touch step, id) order: superstep-faithful for
        LRU/clock, id-ascending only as the within-step tiebreak.

        `quant=True` (graph_quant="sq8", DESIGN.md §9) routes the
        traversal's row fetches through the dense SQ8 "qheap" shadow
        segment, and `rerank_rows` ((Q, r) int32, -1-padded, candidate
        order) charges the exact rerank's full-width fetches to "heap"."""
        if self.graph is None:
            raise ValueError("engine built without a graph layout")
        if quant and self.qheap is None:
            raise ValueError("engine built without a qheap (SQ8 shadow) "
                             "layout; build it from a quantize_store'd "
                             "store")
        hsteps = np.asarray(heap_steps)
        isteps = np.asarray(index_steps)
        row_seg = "qheap" if quant else "heap"
        row_layout = self.qheap if quant else self.heap
        streams = []
        for i in range(hsteps.shape[0]):
            per_q = [
                ("graph", self.graph.pages_for_nodes(
                    _ordered_touches(isteps[i]))),
                (row_seg, row_layout.pages_for_rows(
                    _ordered_touches(hsteps[i]))),
            ]
            if rerank_rows is not None:
                rr = np.asarray(rerank_rows[i])
                per_q.append(("heap", self.heap.pages_for_rows(rr[rr >= 0])))
            streams.append(per_q)
        return self._replay(streams)

    def account_seqscan(self, bitmaps: np.ndarray) -> StorageStats:
        """Bruteforce: every passing row fetched from the heap in row-id
        order (the seqscan).  bitmaps (Q, W) packed filter bitmaps."""
        bm = np.asarray(bitmaps)
        streams = [[
            ("heap", self.heap.pages_for_rows(
                np.nonzero(_unpack_bits(bm[i], self.heap.n))[0])),
        ] for i in range(bm.shape[0])]
        return self._replay(streams)

    # -- write path (DESIGN.md §12) -----------------------------------------
    # The mutation side of the paper's system-cost lens: inserts, deletes,
    # WAL appends, checkpoints, and compaction all flow through the SAME
    # pool as the searches, so dirty-page debt and write-back I/O show up
    # in StorageStats/BufferPoolState right next to read misses.

    def _require(self, seg: str):
        if seg not in self._base:
            raise ValueError(f"engine built without a {seg!r} segment "
                             f"(pass delta=/wal_pages= at construction)")

    def account_delta_scan(self, count: int,
                           num_queries: int) -> StorageStats:
        """The DeltaExecutor's storage story: every query seq-scans the
        first `count` live delta rows exactly (the unindexed LSM tail),
        charged per query like any heap seqscan."""
        self._require("delta")
        rows = np.arange(int(count), dtype=np.int64)
        pages = self.delta.pages_for_rows(rows)
        streams = [[("delta", pages)] for _ in range(int(num_queries))]
        return self._replay(streams)

    def account_delta_write(self, local_rows: np.ndarray):
        """Insert batch applied to the delta tier: the touched delta row
        pages are dirtied.  `local_rows` are delta-local row ids."""
        self._require("delta")
        pages = self.delta.pages_for_rows(np.asarray(local_rows,
                                                     np.int64))
        return self.pool.access(self._base["delta"] + pages, dedup=True,
                                dirty=True)

    def account_tombstone_write(self, global_ids: np.ndarray):
        """Delete batch: the tombstone-bitmap pages holding the marked
        ids' words are dirtied (the bitmap lives after the delta rows)."""
        self._require("delta")
        ids = np.asarray(global_ids, np.int64)
        words = ids >> 5
        tomb_lo = self._base["delta"] + self.delta.num_pages
        pages = np.unique(tomb_lo + (words * 4) // PAGE_BYTES)
        return self.pool.access(pages, dedup=True, dirty=True)

    def _wal_range(self, offset: int, nbytes: int) -> np.ndarray:
        first = offset // PAGE_BYTES
        last = (offset + max(1, nbytes) - 1) // PAGE_BYTES
        ring = np.arange(first, last + 1) % self.wal_pages
        return self._base["wal"] + np.unique(ring)

    def account_wal_append(self, offset: int, nbytes: int):
        """One WAL record hits the log: its byte range's pages (a ring of
        `wal_pages` — the log recycles past checkpoints) are dirtied."""
        self._require("wal")
        return self.pool.access(self._wal_range(offset, nbytes),
                                dedup=True, dirty=True)

    def account_wal_sync(self) -> int:
        """fsync point: every dirty WAL page is forced to storage
        (ranged flush; returns pages written)."""
        self._require("wal")
        lo, hi = self.segment_ranges()["wal"]
        return self.pool.flush(lo, hi)

    def account_checkpoint(self, count: int) -> dict:
        """Checkpoint = read the live delta state (first `count` rows +
        the whole tombstone bitmap) and force the delta segment's dirty
        pages to storage.  Returns the logical reads and page writes."""
        self._require("delta")
        lo, hi = self.segment_ranges()["delta"]
        rows = np.arange(int(count), dtype=np.int64)
        d = self.pool.access(lo + self.delta.pages_for_rows(rows),
                             dedup=True)
        t = self.pool.access(np.arange(lo + self.delta.num_pages, hi),
                             dedup=True)
        written = self.pool.flush(lo, hi)
        return dict(logical=d.logical + t.logical, page_writes=written)

    def account_compaction_read(self, count: int) -> dict:
        """Compaction's read half, charged to THIS (pre-compaction)
        engine: fold-in reads every base heap row and every live delta
        row, then the rebuilt segments (scann/graph/qheap/delta) are
        invalidated — dropped without write-back, so no stale residency
        survives into the successor engine's planner snapshots."""
        self._require("delta")
        heap_rows = np.arange(self.heap.n, dtype=np.int64)
        d = self.pool.access(self._base["heap"]
                             + self.heap.pages_for_rows(heap_rows),
                             dedup=True)
        rows = np.arange(int(count), dtype=np.int64)
        d2 = self.pool.access(self._base["delta"]
                              + self.delta.pages_for_rows(rows), dedup=True)
        inv = 0
        ranges = self.segment_ranges()
        for seg in ("scann", "graph", "qheap", "delta"):
            if seg in ranges:
                inv += self.pool.invalidate(*ranges[seg])
        return dict(logical=d.logical + d2.logical, invalidated=inv)

    def account_compaction_write(self) -> dict:
        """Compaction's write half, charged to the SUCCESSOR engine: the
        rebuilt heap/scann/graph/qheap segments are written page by page
        (dirty first-touch), then flushed — `page_writes` here plus the
        WAL/checkpoint writes is the denominator-facing write-amplification
        I/O (costmodel.write_amplification)."""
        writes = dirtied = 0
        ranges = self.segment_ranges()
        for seg in ("heap", "scann", "graph", "qheap"):
            if seg in ranges:
                lo, hi = ranges[seg]
                d = self.pool.access(np.arange(lo, hi), dedup=True,
                                     dirty=True)
                writes += d.page_writes       # dirty evictions mid-write
                dirtied += d.dirtied
        writes += self.pool.flush()
        return dict(page_writes=writes, dirtied=dirtied)


def make_storage_engine(store, index=None, graph=None,
                        capacity_pages: Optional[int] = None,
                        capacity_frac: float = 0.5,
                        policy: str = "lru",
                        faults: Optional[FaultPlan] = None,
                        delta_capacity: int = 0,
                        wal_pages: int = 0) -> StorageEngine:
    """Build an engine from live components: a core VectorStore, optional
    ScannIndex, optional HNSWGraph (duck-typed on shapes — no core import).
    The dense "qheap" SQ8-shadow segment is always laid out (it is pure
    geometry — n rows at 1 B/dim), so quantized traversal replays through
    shadow pages whether or not the store object in hand carries the
    shadow arrays (DESIGN.md §9).

    `delta_capacity > 0` additionally lays out the mutable delta tier —
    that many capacity-padded delta rows plus the tombstone bitmap — and
    `wal_pages > 0` a WAL page ring, enabling the write-path accounting
    (DESIGN.md §12); both default off, keeping read-only engines
    byte-identical to before."""
    heap = HeapLayout(n=int(store.vectors.shape[0]),
                      dim=int(store.vectors.shape[1]))
    qheap = HeapLayout(n=int(store.vectors.shape[0]),
                       dim=int(store.vectors.shape[1]), value_bytes=1)
    scann = None
    if index is not None:
        L, C, dp = index.leaf_tiles.shape
        scann = ScannLeafLayout(num_leaves=int(L), cap=int(C), dp=int(dp))
    gl = None
    if graph is not None:
        gl = GraphAdjacencyLayout(n=int(graph.neighbors.shape[1]),
                                  degree=int(graph.neighbors.shape[2]))
    delta = None
    if delta_capacity > 0:
        delta = HeapLayout(n=int(delta_capacity),
                           dim=int(store.vectors.shape[1]))
    return StorageEngine(heap, scann, gl, capacity_pages=capacity_pages,
                         capacity_frac=capacity_frac, policy=policy,
                         qheap=qheap, faults=faults, delta=delta,
                         wal_pages=wal_pages)
