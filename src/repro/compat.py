"""Version compatibility shims for the jax API surface we depend on.

The repo targets the modern mesh-context API (`jax.set_mesh`,
`jax.sharding.get_abstract_mesh`), which landed after jax 0.4.x.  On older
runtimes the same thread-local state exists behind `Mesh.__enter__` and
`jax._src.mesh.thread_resources`; these wrappers pick whichever is present
so every module imports from here instead of probing `jax` directly.
"""
from __future__ import annotations

import contextlib

import jax


def get_abstract_mesh():
    """Active abstract mesh, or None when no mesh context is active."""
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is not None:
        return fn()
    from jax._src import mesh as _mesh  # 0.4.x thread-local fallback
    pm = _mesh.thread_resources.env.physical_mesh
    if pm is None or pm.empty:
        return None
    return pm.abstract_mesh


def get_concrete_mesh():
    """Active concrete Mesh (needed by 0.4.x shard_map), or None."""
    fn = getattr(jax.sharding, "get_concrete_mesh", None)
    if fn is not None:
        return fn()
    from jax._src import mesh as _mesh
    pm = _mesh.thread_resources.env.physical_mesh
    if pm is None or pm.empty:
        return None
    return pm


def set_mesh(mesh) -> contextlib.AbstractContextManager:
    """Context manager activating `mesh` (jax.set_mesh on new runtimes,
    the Mesh's own context manager on 0.4.x)."""
    fn = getattr(jax, "set_mesh", None)
    if fn is not None:
        return fn(mesh)
    return mesh


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """jax.shard_map on new runtimes; the 0.4.x experimental entry point
    (kwarg `check_rep`, concrete-Mesh-only for plain-array inputs) else."""
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        return fn(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm
    if not isinstance(mesh, jax.sharding.Mesh):
        concrete = get_concrete_mesh()
        if concrete is not None:
            mesh = concrete
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)


def make_mesh(shape, axes) -> jax.sharding.Mesh:
    """jax.make_mesh with Auto axis types where supported."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(shape, axes,
                                 axis_types=(axis_type.Auto,) * len(axes))
        except TypeError:
            pass
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(shape, axes)
    from jax.experimental import mesh_utils  # pre-0.4.35 fallback
    return jax.sharding.Mesh(mesh_utils.create_device_mesh(shape), axes)
