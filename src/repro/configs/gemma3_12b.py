"""gemma3-12b [dense]: 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144 — 5:1 local:global, 128k ctx [hf:google/gemma-3].

window=1024 sliding-window for the 5 local layers per group of 6; the 6th
layer is global.  long_500k is skipped: the global layers are full
attention (DESIGN.md §5).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-12b", family="dense",
    n_layers=48, d_model=3840, n_heads=16, n_kv=8, d_ff=15360, vocab=262144,
    d_head=256, window=1024, global_every=6, rope_theta=1e6,
    remat="dots", fsdp=True,
)
