"""zamba2-1.2b [hybrid]: 38L d_model=2048 32H (GQA kv=32) d_ff=8192
vocab=32000, ssm_state=64 — Mamba2 + shared attn blocks [arXiv:2411.15242].

Shared attention every 6 Mamba2 layers (6 call sites + 2 tail layers);
ring-buffered 4096-window shared-attn KV for long_500k (DESIGN.md §5).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv=32, d_ff=8192, vocab=32000,
    ssm_state=64, attn_every=6, shared_attn_window=4096, remat="dots",
)
