"""Architecture config schema + input-shape grid (assignment §f).

One `ArchConfig` per assigned architecture lives in `repro.configs.<id>`;
`repro.configs.registry` maps `--arch <id>` strings to them.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | hybrid | ssm | encoder | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None          # default d_model // n_heads
    # MoE
    n_experts: int = 0
    moe_top_k: int = 0
    capacity_factor: float = 1.25
    # attention pattern (gemma3: window>0 with global_every for 5:1 mix)
    window: int = 0                        # 0 = full attention
    global_every: int = 0                  # every k-th layer is global
    # SSM / RWKV
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_chunk: int = 64
    rwkv_mode: str = "scan"                # scan | chunked (perf variant)
    # hybrid (zamba2): shared attention block cadence
    attn_every: int = 0
    shared_attn_window: int = 4096         # long-context decode window
    # modality stub frontends (assignment: backbone only)
    frontend: str = "none"                 # none | patch | frame
    num_patches: int = 0
    # encoder-only
    causal: bool = True
    num_classes: int = 0                   # hubert masked-prediction classes
    # numerics / training
    rope_theta: float = 1e4
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: str = "dots"                    # none | dots | full
    tie_embeddings: bool = False
    fsdp: bool = False                     # shard weights over data axis too
    sharding_scheme: str = "tp"            # tp | sp (§Perf: sequence-parallel
    #                                        activations + FSDP weights)
    windowed_kernel: bool = False          # O(T·window) local-attention path
    moe_local_combine: bool = False        # shard_map EP combine (§Perf A-it4)
    pallas_flash: bool = False             # fused flash kernel on the
    #                                        prefill/serving path (§Perf C)

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head else self.d_model // self.n_heads

    def param_count(self) -> int:
        """Analytic parameter count (for roofline MODEL_FLOPS)."""
        d, hd = self.d_model, self.head_dim
        embed = self.vocab * d * (1 if self.tie_embeddings else 2)
        if self.family in ("ssm",):                   # rwkv6
            per = d * d * 4 + d * self.d_ff * 2 + d * 14  # tmix r,k,v,g,o + cmix
            return embed + self.n_layers * per
        attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv * hd) * 2
        if self.family == "moe":
            ffn = self.n_experts * 3 * d * self.d_ff + d * self.n_experts
        else:
            ffn = 3 * d * self.d_ff
        per = attn + ffn
        if self.family == "hybrid":                    # zamba2-style
            d_in = 2 * d
            mamba = d * d_in * 2 + d_in * d + d_in * (2 * self.ssm_state) \
                + d_in * 2
            n_attn = max(1, self.n_layers // max(self.attn_every, 1))
            return embed + self.n_layers * mamba + attn + 3 * d * self.d_ff
        if self.family == "encoder":
            head = d * self.num_classes
            return embed + self.n_layers * per + head
        return embed + self.n_layers * per

    def active_param_count(self) -> int:
        """Active params per token (MoE: routed experts only)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        attn = d * (self.n_heads * self.head_dim) * 2 \
            + d * (self.n_kv * self.head_dim) * 2
        ffn = self.moe_top_k * 3 * d * self.d_ff + d * self.n_experts
        embed = self.vocab * d * (1 if self.tie_embeddings else 2)
        return embed + self.n_layers * (attn + ffn)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


# The assignment's four LM shapes.
SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def applicable_shapes(cfg: ArchConfig) -> list[str]:
    """Assignment skip rules (DESIGN.md §5)."""
    out = ["train_4k", "prefill_32k"]
    if cfg.family == "encoder":
        return out                       # no decode step
    out.append("decode_32k")
    if cfg.family in ("ssm", "hybrid"):  # sub-quadratic archs only
        out.append("long_500k")
    return out
