"""llava-next-mistral-7b [vlm]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000 — anyres tiling [hf:llava-hf/llava-v1.6-mistral-7b-hf].

Vision tower is a STUB: input_specs() provides 2880 precomputed anyres
patch embeddings (5 tiles x 576), spliced as a prefix.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv=8, d_ff=14336, vocab=32000,
    frontend="patch", num_patches=2880, remat="dots", fsdp=True,
)
