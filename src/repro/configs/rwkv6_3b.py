"""rwkv6-3b [ssm]: 32L d_model=2560 (attention-free) d_ff=8960 vocab=65536
— Finch: data-dependent decay [arXiv:2404.05892].

rwkv_mode="scan" is the faithful baseline; "chunked" is the GLA-style perf
variant (EXPERIMENTS.md §Perf hillclimb).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=40, n_kv=40, d_ff=8960, vocab=65536,
    ssm_chunk=64, rwkv_mode="scan", remat="dots",
)
