"""kimi-k2-1t-a32b [moe]: 61L d_model=7168 64H (GQA kv=8) d_ff=2048
vocab=163840, MoE 384e top-8 — trillion-param MoE [arXiv:2501.kimi2].

Deviation note (DESIGN.md §5): the public Kimi-K2 uses MLA attention and a
dense first layer; the assignment line specifies GQA kv=8 and uniform MoE,
which we follow.  bf16 params + bf16 optimizer state (ZeRO-sharded) keep
the 1.03T-param model addressable on the 512-chip mesh.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv=8, d_ff=2048, vocab=163840,
    d_head=112, n_experts=384, moe_top_k=8, capacity_factor=1.25,
    param_dtype="bfloat16", remat="full", fsdp=True,
)
