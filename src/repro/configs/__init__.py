from repro.configs.base import SHAPES, ArchConfig, ShapeSpec, applicable_shapes
from repro.configs.registry import ARCH_IDS, get_config, smoke_config

__all__ = ["SHAPES", "ArchConfig", "ShapeSpec", "applicable_shapes",
           "ARCH_IDS", "get_config", "smoke_config"]
