"""`--arch <id>` registry + reduced smoke-test variants."""
from __future__ import annotations

import dataclasses
import importlib

from repro.configs.base import ArchConfig

ARCH_IDS = (
    "hubert-xlarge", "kimi-k2-1t-a32b", "granite-moe-1b-a400m", "granite-8b",
    "gemma3-12b", "llama3.2-3b", "granite-20b", "zamba2-1.2b",
    "llava-next-mistral-7b", "rwkv6-3b",
)

_MODULES = {
    "hubert-xlarge": "hubert_xlarge",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "granite-8b": "granite_8b",
    "gemma3-12b": "gemma3_12b",
    "llama3.2-3b": "llama3_2_3b",
    "granite-20b": "granite_20b",
    "zamba2-1.2b": "zamba2_1_2b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "rwkv6-3b": "rwkv6_3b",
}


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def smoke_config(arch_id: str) -> ArchConfig:
    """Reduced same-family config: small depth/width/experts/vocab, runnable
    on CPU for one forward/train step (assignment §f)."""
    cfg = get_config(arch_id)
    changes = dict(
        n_layers=max(2, min(cfg.n_layers, 2 if cfg.attn_every == 0
                            else 2 * cfg.attn_every)),
        d_model=128, n_heads=4, d_ff=256, vocab=512,
        n_kv=min(cfg.n_kv, 4) if cfg.n_kv > 1 else 1,
        d_head=32, param_dtype="float32", compute_dtype="float32",
        remat="none", fsdp=False,
    )
    if cfg.family == "moe":
        changes.update(n_experts=8, moe_top_k=2, d_ff=64)
    if cfg.family == "hybrid":
        changes.update(attn_every=2, n_layers=4, ssm_state=16)
    if cfg.family == "ssm":
        changes.update(d_model=128, ssm_chunk=16)
    if cfg.family == "encoder":
        changes.update(num_classes=32)
    if cfg.window:
        changes.update(window=64)
    if cfg.num_patches:
        changes.update(num_patches=16)
    return dataclasses.replace(cfg, **changes)
