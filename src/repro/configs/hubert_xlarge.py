"""hubert-xlarge [audio]: 48L d_model=1280 16H (GQA kv=16) d_ff=5120
vocab=504 — encoder-only, same arch as wav2vec2 [arXiv:2106.07447].

`vocab` in the assignment line is the masked-prediction codebook size
(HuBERT units); the waveform conv frontend is a stub (frame embeddings in).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge", family="encoder",
    n_layers=48, d_model=1280, n_heads=16, n_kv=16, d_ff=5120, vocab=504,
    causal=False, num_classes=504, frontend="frame",
    remat="dots",
)
