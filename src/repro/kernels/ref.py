"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def distance_matrix_ref(queries: jax.Array, rows: jax.Array,
                        metric: str = "l2") -> jax.Array:
    """(Q, N) distances; lower = closer. queries (Q, d), rows (N, d) f32."""
    ip = queries @ rows.T
    if metric == "ip":
        return -ip
    qn = jnp.sum(queries * queries, axis=1, keepdims=True)
    rn = jnp.sum(rows * rows, axis=1)[None, :]
    return qn + rn - 2.0 * ip


def probe_bitmap_ref(bitmap: jax.Array, row_ids: jax.Array) -> jax.Array:
    safe = jnp.maximum(row_ids, 0)
    word = bitmap[safe >> 5]
    bit = (word >> (safe & 31).astype(jnp.uint32)) & jnp.uint32(1)
    return jnp.where(row_ids >= 0, bit.astype(bool), False)


def leaf_scan_ref(query: jax.Array, tiles: jax.Array, rowids: jax.Array,
                  scale: jax.Array, mean: jax.Array, bitmap: jax.Array,
                  metric: str = "l2") -> jax.Array:
    """Fused filtered quantized leaf scoring, reference semantics.

    query  (d,) f32           — already PCA-projected if applicable
    tiles  (nl, C, d) int8    — SQ8-quantized leaf rows
    rowids (nl, C) int32      — heap row ids, -1 padded
    scale/mean (d,) f32       — dequantization: x = tile * scale + mean
    bitmap (words,) uint32    — filter bitmap over heap row ids
    returns (nl, C) f32 scores with +inf where padded or filtered out.
    """
    x = tiles.astype(jnp.float32) * scale + mean          # (nl, C, d)
    if metric == "ip":
        d = -jnp.einsum("lcd,d->lc", x, query)
    else:
        qn = jnp.sum(query * query)
        xn = jnp.sum(x * x, axis=-1)
        d = qn + xn - 2.0 * jnp.einsum("lcd,d->lc", x, query)
    ok = probe_bitmap_ref(bitmap, rowids)
    return jnp.where(ok, d, jnp.inf)


def leaf_scan_batched_ref(queries: jax.Array, tiles: jax.Array,
                          rowids: jax.Array, scale: jax.Array,
                          mean: jax.Array, bitmaps: jax.Array,
                          row_norms_sq: jax.Array | None = None,
                          metric: str = "l2") -> jax.Array:
    """Query-batched fused filtered leaf scoring, reference semantics.

    Each leaf tile is read once for the whole query batch and scored via a
    single (Q, d) × (d, C) contraction per leaf (DESIGN.md §4).

    queries (Q, d) f32        — already PCA-projected if applicable
    tiles   (U, C, d) int8    — SQ8-quantized rows of the leaves to scan
    rowids  (U, C) int32      — heap row ids, -1 padded
    scale/mean (d,) f32       — dequantization: x = tile * scale + mean
    bitmaps (Q, words) uint32 — one packed filter bitmap per query
    row_norms_sq (U, C) f32   — optional precomputed ||x||² of the
                                dequantized rows (L2 fast path)
    returns (Q, U, C) f32 scores with +inf where padded or filtered out.
    """
    x = tiles.astype(jnp.float32) * scale + mean          # (U, C, d)
    ip = jnp.einsum("qd,ucd->quc", queries, x)
    if metric == "ip":
        d = -ip
    else:
        xn = (row_norms_sq if row_norms_sq is not None
              else jnp.sum(x * x, axis=-1))               # (U, C)
        qn = jnp.sum(queries * queries, axis=-1)          # (Q,)
        d = qn[:, None, None] + xn[None] - 2.0 * ip
    ok = jax.vmap(lambda bm: probe_bitmap_ref(bm, rowids))(bitmaps)
    return jnp.where(ok, d, jnp.inf)


def frontier_scan_ref(queries: jax.Array, vecs: jax.Array, norms: jax.Array,
                      ids: jax.Array, bitmaps: jax.Array,
                      metric: str = "l2") -> tuple[jax.Array, jax.Array]:
    """Fused frontier-chunk scoring + filter probe, reference semantics.

    queries (Q, d) f32   — one query per in-flight traversal
    vecs    (Q, C, d) f32 — each query's candidate chunk, gathered from the
                            deduplicated frontier union block (graph engine,
                            DESIGN.md §7)
    norms   (Q, C) f32   — precomputed ||x||² of the chunk rows (L2 path)
    ids     (Q, C) int32 — heap row ids, -1 padded
    bitmaps (Q, W) uint32 — per-query packed filter bitmaps
    returns (dists (Q, C) f32 with +inf at padded slots, pass (Q, C) bool).

    The distance arithmetic deliberately mirrors `types.distance` under
    `jax.vmap` — elementwise product + last-axis sum, never a dot — so the
    frontier engine's scores are bit-identical to the legacy vmapped
    beam search (the equivalence guarantee of tests/test_frontier.py).
    """
    def one(q, x, xn):
        if metric == "ip":
            return -jnp.sum(q * x, axis=-1)
        if metric == "cos":
            qn = jnp.linalg.norm(q, axis=-1) + 1e-12
            vn = jnp.linalg.norm(x, axis=-1) + 1e-12
            return 1.0 - jnp.sum(q * x, axis=-1) / (qn * vn)
        qn = jnp.sum(q * q, axis=-1)
        return qn + xn - 2.0 * jnp.sum(q * x, axis=-1)

    d = jax.vmap(one)(queries, vecs, norms)
    ok = jax.vmap(probe_bitmap_ref)(bitmaps, ids)
    return jnp.where(ids >= 0, d, jnp.inf), ok


def frontier_scan_sq8_ref(queries: jax.Array, qvecs: jax.Array,
                          scale: jax.Array, mean: jax.Array,
                          norms: jax.Array, ids: jax.Array,
                          bitmaps: jax.Array, metric: str = "l2"
                          ) -> tuple[jax.Array, jax.Array]:
    """SQ8 quantized-traversal frontier scoring, reference semantics
    (DESIGN.md §9).

    queries (Q, d) f32    — one query per in-flight traversal
    qvecs   (Q, C, d) int8 — SQ8 shadow rows of each query's chunk
    scale/mean (d,) f32   — dequantization: x̂ = qvecs * scale + mean
    norms   (Q, C) f32    — precomputed ‖x̂‖² (L2 path; the shadow store's
                            build-time `q_norms_sq`)
    ids     (Q, C) int32  — heap row ids, -1 padded
    bitmaps (Q, W) uint32 — per-query packed filter bitmaps
    returns (dists (Q, C) f32 with +inf at padded slots, pass (Q, C) bool).

    Dequantization + distance arithmetic deliberately mirror the legacy
    vmapped engine's quantized gather path (elementwise product +
    last-axis sum on the dequantized rows), so the two graph engines stay
    bit-identical under graph_quant="sq8" (tests/test_graph_quant.py).
    """
    x = qvecs.astype(jnp.float32) * scale + mean          # (Q, C, d)
    return frontier_scan_ref(queries, x, norms, ids, bitmaps, metric)


def excl_keep_mask(dists: jax.Array, excl: jax.Array, tau: jax.Array,
                   ok: jax.Array, margin: float) -> jax.Array:
    """Fused FAVOR keep rule (DESIGN.md §14), shared VERBATIM by the
    Pallas excl kernels and the jnp oracles so the pruning mask is
    bit-identical on both paths.

    All distances are squared l2; the triangle inequality only holds in
    root space, so the rule compares square roots: keep candidate v iff
    it passes the filter, or its exclusion radius e(v) (distance to its
    nearest passing row) satisfies
        sqrt(e) <= margin * (sqrt(d(q, v)) + sqrt(tau)),
    tau being the current W tail (the distance a row must beat to enter
    the result queue).  tau = +inf (W not yet full) keeps everything —
    the pre-fill navigation phase is never pruned.  With exact family
    radii and margin >= 1 the rule provably never fires (the passing row
    that produced tau witnesses the triangle bound); margin < 1 is the
    productive, recall-gated regime.
    """
    dr = jnp.sqrt(jnp.maximum(dists, 0.0))
    er = jnp.sqrt(jnp.maximum(excl, 0.0))
    tr = jnp.sqrt(jnp.maximum(tau, 0.0))
    return ok | (er <= jnp.float32(margin) * (dr + tr))


def frontier_scan_excl_ref(queries: jax.Array, vecs: jax.Array,
                           norms: jax.Array, ids: jax.Array,
                           bitmaps: jax.Array, excl: jax.Array,
                           tau: jax.Array, metric: str = "l2",
                           margin: float = 0.5
                           ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """`frontier_scan_ref` + the fused exclusion keep mask.

    excl (Q, C) f32 — squared exclusion radii of the chunk rows
    tau  (Q, 1) f32 — per-query W tail (squared; +inf until W fills)
    returns (dists, pass, keep (Q, C) bool).  dists/pass are bit-identical
    to `frontier_scan_ref` — the mask is a third output, not a rescore.
    """
    d, ok = frontier_scan_ref(queries, vecs, norms, ids, bitmaps, metric)
    return d, ok, excl_keep_mask(d, excl, tau, ok, margin)


def frontier_scan_excl_sq8_ref(queries: jax.Array, qvecs: jax.Array,
                               scale: jax.Array, mean: jax.Array,
                               norms: jax.Array, ids: jax.Array,
                               bitmaps: jax.Array, excl: jax.Array,
                               tau: jax.Array, metric: str = "l2",
                               margin: float = 0.5
                               ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """`frontier_scan_sq8_ref` + the fused exclusion keep mask (the mask
    compares the QUANTIZED distances against the full-precision radii —
    the same distances the pool insertion uses, so prune decisions and
    scores always agree)."""
    d, ok = frontier_scan_sq8_ref(queries, qvecs, scale, mean, norms, ids,
                                  bitmaps, metric)
    return d, ok, excl_keep_mask(d, excl, tau, ok, margin)


def topk_partial_ref(values: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """Global k smallest (values, indices) over a 1-D array.

    Mirrors topk_pallas's sentinel contract: +inf entries (the universal
    filtered/padded marker) and k > n overflow slots report index -1."""
    n = values.shape[0]
    kk = min(k, n)
    neg, idx = jax.lax.top_k(-values, kk)
    vals = -neg
    idx = jnp.where(vals == jnp.inf, -1, idx)
    if kk < k:
        vals = jnp.concatenate(
            [vals, jnp.full((k - kk,), jnp.inf, vals.dtype)])
        idx = jnp.concatenate([idx, jnp.full((k - kk,), -1, idx.dtype)])
    return vals, idx
