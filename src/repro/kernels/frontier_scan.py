"""Fused frontier-scan Pallas kernel (graph engine superstep, DESIGN.md §7).

One superstep of the batch-synchronous graph engine scores, per in-flight
query, a chunk of candidate nodes compacted out of the frontier's
neighborhood (only the candidates the strategy actually needs — unvisited
for traversal-first, passing/unvisited 2-hop for filter-first).  The
candidate vectors arrive already gathered through the deduplicated
frontier-union block (each distinct node is fetched from HBM once per
superstep, however many queries touch it); this kernel fuses the remaining
hot work in one VMEM-resident pass per query:

  * distance of the query against its (C, d) candidate chunk — one
    MXU-friendly (C, d) × (d,) contraction, plus the precomputed-norm L2
    completion (the per-row ‖x‖² never recomputes inside the step);
  * the packed-bitmap filter probe (one uint32 word gather per row — the
    same batched-probe shape as the leaf-scan kernels).

Outputs are the raw distances (+inf only at id padding — strategies decide
how filtering gates insertion, so the pass mask is returned separately as
int8) — semantics mirrored exactly by `ref.frontier_scan_ref`, the jnp
oracle the engine uses on non-TPU backends and the allclose target of the
interpret-mode parity tests.

VMEM envelope per grid step (f32): query d + chunk C×d + norms/ids/out C
+ bitmap W words.  For C=128, d=1024: 0.5 MB chunk — far inside v5e's
16 MB/core, leaving the double-buffered prefetch of the next query's
chunk free (the grid walks queries, so the union block's rows stream
HBM→VMEM at most once per appearance in a chunk).

`frontier_scan_sq8_pallas` is the quantized-traversal variant
(DESIGN.md §9): the chunk arrives as SQ8 int8 rows straight from the
shadow heap — 4× less HBM→VMEM traffic per candidate — and is
dequantized IN-KERNEL (x = t·scale + mean, the `leaf_scan` fusion math)
before the same contraction + bitmap probe.  Per-row ‖x̂‖² of the
dequantized rows is precomputed at quantization time and streamed in,
so the L2 completion never re-reduces inside the step.  VMEM per grid
step: C×d int8 chunk + C×d f32 dequant + 2×d scale/mean — for C=128,
d=1024: 0.64 MB.  int8 blocks obey the (32, 128) min-tile; C pads to
the 128-lane output axis, satisfying both.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import excl_keep_mask


def _frontier_scan_kernel(q_ref, vec_ref, norm_ref, id_ref, bitmap_ref,
                          dist_ref, pass_ref, *, metric: str):
    q = q_ref[...][0]                                # (d,) f32
    x = vec_ref[...][0]                              # (C, d) f32
    xn = norm_ref[...][0]                            # (C,) f32
    rid = id_ref[...][0]                             # (C,) int32
    ip = jnp.dot(x, q, preferred_element_type=jnp.float32)     # (C,)
    if metric == "ip":
        d = -ip
    else:
        qn = jnp.sum(q * q)
        d = qn + xn - 2.0 * ip
    safe = jnp.maximum(rid, 0)
    words = bitmap_ref[...][0]                       # (W,) uint32
    w = jnp.take(words, safe >> 5, axis=0)
    bit = (w >> (safe & 31).astype(jnp.uint32)) & jnp.uint32(1)
    ok = (bit == 1) & (rid >= 0)
    dist_ref[...] = jnp.where(rid >= 0, d, jnp.inf)[None, :]
    pass_ref[...] = ok.astype(jnp.int8)[None, :]


def frontier_scan_pallas(queries: jax.Array, vecs: jax.Array,
                         norms: jax.Array, ids: jax.Array,
                         bitmaps: jax.Array, metric: str = "l2",
                         interpret: bool = False
                         ) -> tuple[jax.Array, jax.Array]:
    """queries (Q, d), vecs (Q, C, d) f32, norms (Q, C), ids (Q, C) int32,
    bitmaps (Q, W) uint32 → (dists (Q, C) f32, pass (Q, C) bool).

    Grid is (Q,): one step fuses one query's chunk scoring + filter probe.
    """
    nq, c, d = vecs.shape
    w = bitmaps.shape[1]
    pd = (-d) % 128
    pc = (-c) % 128          # C is the lane axis of the (1, C) outputs
    q = jnp.pad(queries.astype(jnp.float32), ((0, 0), (0, pd)))
    v = jnp.pad(vecs.astype(jnp.float32), ((0, 0), (0, pc), (0, pd)))
    nrm = jnp.pad(norms.astype(jnp.float32), ((0, 0), (0, pc)))
    idp = jnp.pad(ids, ((0, 0), (0, pc)), constant_values=-1)
    cp, dp = c + pc, d + pd
    dist, ok = pl.pallas_call(
        functools.partial(_frontier_scan_kernel, metric=metric),
        grid=(nq,),
        in_specs=[
            pl.BlockSpec((1, dp), lambda i: (i, 0)),          # query
            pl.BlockSpec((1, cp, dp), lambda i: (i, 0, 0)),   # chunk vecs
            pl.BlockSpec((1, cp), lambda i: (i, 0)),          # row norms
            pl.BlockSpec((1, cp), lambda i: (i, 0)),          # row ids
            pl.BlockSpec((1, w), lambda i: (i, 0)),           # bitmap
        ],
        out_specs=[
            pl.BlockSpec((1, cp), lambda i: (i, 0)),
            pl.BlockSpec((1, cp), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nq, cp), jnp.float32),
            jax.ShapeDtypeStruct((nq, cp), jnp.int8),
        ],
        interpret=interpret,
    )(q, v, nrm, idp, bitmaps)
    return dist[:, :c], ok[:, :c].astype(bool)


def _frontier_scan_sq8_kernel(q_ref, vec_ref, scale_ref, mean_ref, norm_ref,
                              id_ref, bitmap_ref, dist_ref, pass_ref, *,
                              metric: str):
    q = q_ref[...][0]                                # (d,) f32
    t = vec_ref[...][0]                              # (C, d) int8
    scale = scale_ref[...]                           # (1, d) f32
    mean = mean_ref[...]                             # (1, d) f32
    xn = norm_ref[...][0]                            # (C,) f32 ||x̂||²
    rid = id_ref[...][0]                             # (C,) int32
    x = t.astype(jnp.float32) * scale + mean         # in-kernel dequant
    ip = jnp.dot(x, q, preferred_element_type=jnp.float32)     # (C,)
    if metric == "ip":
        d = -ip
    else:
        qn = jnp.sum(q * q)
        d = qn + xn - 2.0 * ip
    safe = jnp.maximum(rid, 0)
    words = bitmap_ref[...][0]                       # (W,) uint32
    w = jnp.take(words, safe >> 5, axis=0)
    bit = (w >> (safe & 31).astype(jnp.uint32)) & jnp.uint32(1)
    ok = (bit == 1) & (rid >= 0)
    dist_ref[...] = jnp.where(rid >= 0, d, jnp.inf)[None, :]
    pass_ref[...] = ok.astype(jnp.int8)[None, :]


def frontier_scan_sq8_pallas(queries: jax.Array, qvecs: jax.Array,
                             scale: jax.Array, mean: jax.Array,
                             norms: jax.Array, ids: jax.Array,
                             bitmaps: jax.Array, metric: str = "l2",
                             interpret: bool = False
                             ) -> tuple[jax.Array, jax.Array]:
    """queries (Q, d) f32, qvecs (Q, C, d) int8 (SQ8 shadow rows),
    scale/mean (d,) f32, norms (Q, C) f32 (precomputed ‖dequant‖²),
    ids (Q, C) int32, bitmaps (Q, W) uint32
    → (dists (Q, C) f32, pass (Q, C) bool).

    Same grid/fusion as `frontier_scan_pallas`, with the dequantization
    folded into the kernel so only int8 rows cross HBM→VMEM."""
    nq, c, d = qvecs.shape
    w = bitmaps.shape[1]
    pd = (-d) % 128
    pc = (-c) % 128          # C is the lane axis of the (1, C) outputs
    q = jnp.pad(queries.astype(jnp.float32), ((0, 0), (0, pd)))
    v = jnp.pad(qvecs, ((0, 0), (0, pc), (0, pd)))
    s = jnp.pad(scale.astype(jnp.float32), (0, pd))[None, :]
    m = jnp.pad(mean.astype(jnp.float32), (0, pd))[None, :]
    nrm = jnp.pad(norms.astype(jnp.float32), ((0, 0), (0, pc)))
    idp = jnp.pad(ids, ((0, 0), (0, pc)), constant_values=-1)
    cp, dp = c + pc, d + pd
    dist, ok = pl.pallas_call(
        functools.partial(_frontier_scan_sq8_kernel, metric=metric),
        grid=(nq,),
        in_specs=[
            pl.BlockSpec((1, dp), lambda i: (i, 0)),          # query
            pl.BlockSpec((1, cp, dp), lambda i: (i, 0, 0)),   # int8 chunk
            pl.BlockSpec((1, dp), lambda i: (0, 0)),          # scale
            pl.BlockSpec((1, dp), lambda i: (0, 0)),          # mean
            pl.BlockSpec((1, cp), lambda i: (i, 0)),          # dequant norms
            pl.BlockSpec((1, cp), lambda i: (i, 0)),          # row ids
            pl.BlockSpec((1, w), lambda i: (i, 0)),           # bitmap
        ],
        out_specs=[
            pl.BlockSpec((1, cp), lambda i: (i, 0)),
            pl.BlockSpec((1, cp), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nq, cp), jnp.float32),
            jax.ShapeDtypeStruct((nq, cp), jnp.int8),
        ],
        interpret=interpret,
    )(q, v, s, m, nrm, idp, bitmaps)
    return dist[:, :c], ok[:, :c].astype(bool)


def _frontier_scan_excl_kernel(q_ref, vec_ref, norm_ref, id_ref, bitmap_ref,
                               excl_ref, tau_ref, dist_ref, pass_ref,
                               keep_ref, *, metric: str, margin: float):
    q = q_ref[...][0]                                # (d,) f32
    x = vec_ref[...][0]                              # (C, d) f32
    xn = norm_ref[...][0]                            # (C,) f32
    rid = id_ref[...][0]                             # (C,) int32
    ip = jnp.dot(x, q, preferred_element_type=jnp.float32)     # (C,)
    if metric == "ip":
        d = -ip
    else:
        qn = jnp.sum(q * q)
        d = qn + xn - 2.0 * ip
    safe = jnp.maximum(rid, 0)
    words = bitmap_ref[...][0]                       # (W,) uint32
    w = jnp.take(words, safe >> 5, axis=0)
    bit = (w >> (safe & 31).astype(jnp.uint32)) & jnp.uint32(1)
    ok = (bit == 1) & (rid >= 0)
    dfin = jnp.where(rid >= 0, d, jnp.inf)
    e = excl_ref[...][0]                             # (C,) f32 radii
    tau = tau_ref[0, 0]                              # scalar W tail
    keep = excl_keep_mask(dfin, e, tau, ok, margin)
    dist_ref[...] = dfin[None, :]
    pass_ref[...] = ok.astype(jnp.int8)[None, :]
    keep_ref[...] = keep.astype(jnp.int8)[None, :]


def frontier_scan_excl_pallas(queries: jax.Array, vecs: jax.Array,
                              norms: jax.Array, ids: jax.Array,
                              bitmaps: jax.Array, excl: jax.Array,
                              tau: jax.Array, metric: str = "l2",
                              margin: float = 0.5, interpret: bool = False
                              ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """`frontier_scan_pallas` + the fused FAVOR keep mask (DESIGN.md §14).

    Extra inputs: excl (Q, C) f32 squared exclusion radii of the chunk
    rows (gathered alongside the vectors — zero extra HBM round trips)
    and tau (Q, 1) f32 per-query W tail.  Third output: keep (Q, C) bool,
    computed by the SAME `excl_keep_mask` ops as the jnp oracle so the
    mask is bit-identical across paths.  dists/pass semantics unchanged.
    """
    nq, c, d = vecs.shape
    w = bitmaps.shape[1]
    pd = (-d) % 128
    pc = (-c) % 128          # C is the lane axis of the (1, C) outputs
    q = jnp.pad(queries.astype(jnp.float32), ((0, 0), (0, pd)))
    v = jnp.pad(vecs.astype(jnp.float32), ((0, 0), (0, pc), (0, pd)))
    nrm = jnp.pad(norms.astype(jnp.float32), ((0, 0), (0, pc)))
    idp = jnp.pad(ids, ((0, 0), (0, pc)), constant_values=-1)
    ex = jnp.pad(excl.astype(jnp.float32), ((0, 0), (0, pc)))
    cp, dp = c + pc, d + pd
    dist, ok, keep = pl.pallas_call(
        functools.partial(_frontier_scan_excl_kernel, metric=metric,
                          margin=margin),
        grid=(nq,),
        in_specs=[
            pl.BlockSpec((1, dp), lambda i: (i, 0)),          # query
            pl.BlockSpec((1, cp, dp), lambda i: (i, 0, 0)),   # chunk vecs
            pl.BlockSpec((1, cp), lambda i: (i, 0)),          # row norms
            pl.BlockSpec((1, cp), lambda i: (i, 0)),          # row ids
            pl.BlockSpec((1, w), lambda i: (i, 0)),           # bitmap
            pl.BlockSpec((1, cp), lambda i: (i, 0)),          # excl radii
            pl.BlockSpec((1, 1), lambda i: (i, 0)),           # W tail
        ],
        out_specs=[
            pl.BlockSpec((1, cp), lambda i: (i, 0)),
            pl.BlockSpec((1, cp), lambda i: (i, 0)),
            pl.BlockSpec((1, cp), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nq, cp), jnp.float32),
            jax.ShapeDtypeStruct((nq, cp), jnp.int8),
            jax.ShapeDtypeStruct((nq, cp), jnp.int8),
        ],
        interpret=interpret,
    )(q, v, nrm, idp, bitmaps, ex, tau.astype(jnp.float32))
    return dist[:, :c], ok[:, :c].astype(bool), keep[:, :c].astype(bool)


def _frontier_scan_excl_sq8_kernel(q_ref, vec_ref, scale_ref, mean_ref,
                                   norm_ref, id_ref, bitmap_ref, excl_ref,
                                   tau_ref, dist_ref, pass_ref, keep_ref, *,
                                   metric: str, margin: float):
    q = q_ref[...][0]                                # (d,) f32
    t = vec_ref[...][0]                              # (C, d) int8
    scale = scale_ref[...]                           # (1, d) f32
    mean = mean_ref[...]                             # (1, d) f32
    xn = norm_ref[...][0]                            # (C,) f32 ||x̂||²
    rid = id_ref[...][0]                             # (C,) int32
    x = t.astype(jnp.float32) * scale + mean         # in-kernel dequant
    ip = jnp.dot(x, q, preferred_element_type=jnp.float32)     # (C,)
    if metric == "ip":
        d = -ip
    else:
        qn = jnp.sum(q * q)
        d = qn + xn - 2.0 * ip
    safe = jnp.maximum(rid, 0)
    words = bitmap_ref[...][0]                       # (W,) uint32
    w = jnp.take(words, safe >> 5, axis=0)
    bit = (w >> (safe & 31).astype(jnp.uint32)) & jnp.uint32(1)
    ok = (bit == 1) & (rid >= 0)
    dfin = jnp.where(rid >= 0, d, jnp.inf)
    e = excl_ref[...][0]                             # (C,) f32 radii
    tau = tau_ref[0, 0]                              # scalar W tail
    keep = excl_keep_mask(dfin, e, tau, ok, margin)
    dist_ref[...] = dfin[None, :]
    pass_ref[...] = ok.astype(jnp.int8)[None, :]
    keep_ref[...] = keep.astype(jnp.int8)[None, :]


def frontier_scan_excl_sq8_pallas(queries: jax.Array, qvecs: jax.Array,
                                  scale: jax.Array, mean: jax.Array,
                                  norms: jax.Array, ids: jax.Array,
                                  bitmaps: jax.Array, excl: jax.Array,
                                  tau: jax.Array, metric: str = "l2",
                                  margin: float = 0.5,
                                  interpret: bool = False
                                  ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """`frontier_scan_sq8_pallas` + the fused FAVOR keep mask: int8 chunk
    rows dequantized in-kernel, keep rule applied to the quantized
    distances (the distances pool insertion uses)."""
    nq, c, d = qvecs.shape
    w = bitmaps.shape[1]
    pd = (-d) % 128
    pc = (-c) % 128          # C is the lane axis of the (1, C) outputs
    q = jnp.pad(queries.astype(jnp.float32), ((0, 0), (0, pd)))
    v = jnp.pad(qvecs, ((0, 0), (0, pc), (0, pd)))
    s = jnp.pad(scale.astype(jnp.float32), (0, pd))[None, :]
    m = jnp.pad(mean.astype(jnp.float32), (0, pd))[None, :]
    nrm = jnp.pad(norms.astype(jnp.float32), ((0, 0), (0, pc)))
    idp = jnp.pad(ids, ((0, 0), (0, pc)), constant_values=-1)
    ex = jnp.pad(excl.astype(jnp.float32), ((0, 0), (0, pc)))
    cp, dp = c + pc, d + pd
    dist, ok, keep = pl.pallas_call(
        functools.partial(_frontier_scan_excl_sq8_kernel, metric=metric,
                          margin=margin),
        grid=(nq,),
        in_specs=[
            pl.BlockSpec((1, dp), lambda i: (i, 0)),          # query
            pl.BlockSpec((1, cp, dp), lambda i: (i, 0, 0)),   # int8 chunk
            pl.BlockSpec((1, dp), lambda i: (0, 0)),          # scale
            pl.BlockSpec((1, dp), lambda i: (0, 0)),          # mean
            pl.BlockSpec((1, cp), lambda i: (i, 0)),          # dequant norms
            pl.BlockSpec((1, cp), lambda i: (i, 0)),          # row ids
            pl.BlockSpec((1, w), lambda i: (i, 0)),           # bitmap
            pl.BlockSpec((1, cp), lambda i: (i, 0)),          # excl radii
            pl.BlockSpec((1, 1), lambda i: (i, 0)),           # W tail
        ],
        out_specs=[
            pl.BlockSpec((1, cp), lambda i: (i, 0)),
            pl.BlockSpec((1, cp), lambda i: (i, 0)),
            pl.BlockSpec((1, cp), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nq, cp), jnp.float32),
            jax.ShapeDtypeStruct((nq, cp), jnp.int8),
            jax.ShapeDtypeStruct((nq, cp), jnp.int8),
        ],
        interpret=interpret,
    )(q, v, s, m, nrm, idp, bitmaps, ex, tau.astype(jnp.float32))
    return dist[:, :c], ok[:, :c].astype(bool), keep[:, :c].astype(bool)
