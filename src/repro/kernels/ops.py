"""Jitted public wrappers for the Pallas kernels.

On the CPU container the kernels run in `interpret=True` mode (Pallas
executes the kernel body with the same blocking); on TPU they compile to
Mosaic.  `use_pallas=False` falls through to the jnp oracles — tests compare
both paths.
"""
from __future__ import annotations

from functools import partial

import jax

from repro import compat

import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels import ref
from repro.kernels.distance import distance_matrix_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.frontier_scan import (frontier_scan_excl_pallas,
                                         frontier_scan_excl_sq8_pallas,
                                         frontier_scan_pallas,
                                         frontier_scan_sq8_pallas)
from repro.kernels.leaf_scan import leaf_scan_batched_pallas, leaf_scan_pallas
from repro.kernels.topk import topk_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("metric", "use_pallas"))
def distance_matrix(queries, rows, metric: str = "l2",
                    use_pallas: bool = True):
    if use_pallas:
        return distance_matrix_pallas(queries, rows, metric,
                                      interpret=_interpret())
    return ref.distance_matrix_ref(queries, rows, metric)


@partial(jax.jit, static_argnames=("metric", "use_pallas"))
def leaf_scan(query, tiles, rowids, scale, mean, bitmap, metric: str = "l2",
              use_pallas: bool = True):
    if use_pallas:
        return leaf_scan_pallas(query, tiles, rowids, scale, mean, bitmap,
                                metric, interpret=_interpret())
    return ref.leaf_scan_ref(query, tiles, rowids, scale, mean, bitmap,
                             metric)


@partial(jax.jit, static_argnames=("metric", "use_pallas"))
def leaf_scan_batched(queries, tiles, rowids, scale, mean, bitmaps,
                      row_norms_sq, metric: str = "l2",
                      use_pallas: bool = True):
    """Query-batched fused leaf scan: each tile is fetched once and scored
    against the whole query block (DESIGN.md §4). Returns (Q, U, C)."""
    if use_pallas:
        return leaf_scan_batched_pallas(queries, tiles, rowids, scale, mean,
                                        bitmaps, row_norms_sq, metric,
                                        interpret=_interpret())
    return ref.leaf_scan_batched_ref(queries, tiles, rowids, scale, mean,
                                     bitmaps, row_norms_sq, metric)


@partial(jax.jit, static_argnames=("metric", "use_pallas"))
def frontier_scan(queries, vecs, norms, ids, bitmaps, metric: str = "l2",
                  use_pallas: bool = False):
    """Fused frontier-chunk scoring + filter probe for the graph engine
    (DESIGN.md §7).  Returns (dists (Q, C), pass (Q, C)).

    Unlike the other wrappers this defaults to the jnp oracle: its
    elementwise+sum arithmetic is the bit-identical mirror of the legacy
    beam search (the frontier engine's equivalence contract), while the
    MXU kernel is allclose-only — opt into it explicitly.  The cos metric
    has no kernel (like the batched leaf scan) and always routes through
    the oracle."""
    if use_pallas and metric != "cos":
        return frontier_scan_pallas(queries, vecs, norms, ids, bitmaps,
                                    metric, interpret=_interpret())
    return ref.frontier_scan_ref(queries, vecs, norms, ids, bitmaps, metric)


@partial(jax.jit, static_argnames=("metric", "use_pallas"))
def frontier_scan_sq8(queries, qvecs, scale, mean, norms, ids, bitmaps,
                      metric: str = "l2", use_pallas: bool = False):
    """SQ8 frontier-chunk scoring + filter probe (DESIGN.md §9): the chunk
    arrives int8 from the shadow heap and is dequantized in-kernel.
    Returns (dists (Q, C), pass (Q, C)).

    Like `frontier_scan`, defaults to the jnp oracle — its dequant +
    elementwise arithmetic is the bit-identical mirror of the legacy
    vmapped engine's quantized gather path; the MXU kernel is
    allclose-only.  cos always routes through the oracle."""
    if use_pallas and metric != "cos":
        return frontier_scan_sq8_pallas(queries, qvecs, scale, mean, norms,
                                        ids, bitmaps, metric,
                                        interpret=_interpret())
    return ref.frontier_scan_sq8_ref(queries, qvecs, scale, mean, norms,
                                     ids, bitmaps, metric)


@partial(jax.jit, static_argnames=("metric", "margin", "use_pallas"))
def frontier_scan_excl(queries, vecs, norms, ids, bitmaps, excl, tau,
                       metric: str = "l2", margin: float = 0.5,
                       use_pallas: bool = False):
    """Frontier-chunk scoring + filter probe + fused FAVOR keep mask
    (DESIGN.md §14).  excl (Q, C) squared exclusion radii of the chunk
    rows, tau (Q, 1) current W tail.  Returns (dists, pass, keep).

    dists/pass follow `frontier_scan`'s contract exactly (oracle default,
    bit-identical to the legacy engine); keep is computed by the shared
    `excl_keep_mask` ops on both paths so the mask is bit-identical
    kernel-vs-oracle."""
    if use_pallas and metric != "cos":
        return frontier_scan_excl_pallas(queries, vecs, norms, ids, bitmaps,
                                         excl, tau, metric, margin,
                                         interpret=_interpret())
    return ref.frontier_scan_excl_ref(queries, vecs, norms, ids, bitmaps,
                                      excl, tau, metric, margin)


@partial(jax.jit, static_argnames=("metric", "margin", "use_pallas"))
def frontier_scan_excl_sq8(queries, qvecs, scale, mean, norms, ids, bitmaps,
                           excl, tau, metric: str = "l2",
                           margin: float = 0.5, use_pallas: bool = False):
    """SQ8 variant of `frontier_scan_excl`: int8 chunk dequantized
    in-kernel, keep rule applied to the quantized distances.
    Returns (dists, pass, keep)."""
    if use_pallas and metric != "cos":
        return frontier_scan_excl_sq8_pallas(queries, qvecs, scale, mean,
                                             norms, ids, bitmaps, excl, tau,
                                             metric, margin,
                                             interpret=_interpret())
    return ref.frontier_scan_excl_sq8_ref(queries, qvecs, scale, mean, norms,
                                          ids, bitmaps, excl, tau, metric,
                                          margin)


@partial(jax.jit, static_argnames=("k", "use_pallas"))
def topk_smallest(values, k: int, use_pallas: bool = True):
    if use_pallas:
        return topk_pallas(values, k, interpret=_interpret())
    return ref.topk_partial_ref(values, k)


def flash_attention_fused(q, k, v, causal: bool = True):
    """Pallas flash attention, shard_map-wrapped when a mesh is active:
    batch shards over (pod, data), kv heads over `model` (when divisible).
    Interpret mode on non-TPU backends."""
    mesh = compat.get_abstract_mesh()
    interp = _interpret()
    if mesh is None or mesh.empty:
        return flash_attention_pallas(q, k, v, causal=causal,
                                      interpret=interp)
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    baxes = tuple(a for a in ("pod", "data") if a in sizes)
    bsz = 1
    for a in baxes:
        bsz *= sizes[a]
    bspec = (baxes if len(baxes) > 1 else baxes[0]) \
        if baxes and q.shape[0] % bsz == 0 else None
    kvspec = "model" if ("model" in sizes
                         and k.shape[2] % sizes["model"] == 0) else None
    qs = P(bspec, None, kvspec, None)
    fn = compat.shard_map(
        lambda q_, k_, v_: flash_attention_pallas(q_, k_, v_, causal=causal,
                                                  interpret=interp),
        mesh=mesh, in_specs=(qs, qs, qs), out_specs=qs, check_vma=False)
    return fn(q, k, v)
