"""Fused flash-attention Pallas kernel (inference/prefill path).

The pure-JAX blocked attention in `models/layers.py` materializes the
(Tq × block) score tensor between its two einsums — XLA will not fuse two
dots, so on TPU that tensor round-trips HBM and the 32k-prefill cells go
memory-bound (EXPERIMENTS.md §Roofline).  This kernel keeps the whole
online-softmax block pipeline in VMEM: HBM traffic collapses to Q/K/V/O.

Grid: (batch·kv_heads, q_blocks).  Each step loads one (BQ, hd) query
block and loops over KV blocks with the standard running-max/sum update.
Causal masking via block-index arithmetic.  GQA handled by head grouping
(q heads of one kv head processed together: (BQ, G, hd) resident).

Forward-only (serving/prefill); training keeps the autodiff-able jnp path.
Validated in interpret mode against models.layers.flash_attention.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_q: int, block_k: int,
                  seq_k: int, seq_valid: int, causal: bool, scale: float):
    qi = pl.program_id(1)
    q = q_ref[...][0].astype(jnp.float32) * scale          # (BQ, G, hd)
    bq, g, hd = q.shape
    nkv = seq_k // block_k

    def body(i, carry):
        m, l, acc = carry
        k = k_ref[0, pl.dslice(i * block_k, block_k)].astype(jnp.float32)
        v = v_ref[0, pl.dslice(i * block_k, block_k)].astype(jnp.float32)
        s = jnp.einsum("qgh,kh->qgk", q, k,
                       preferred_element_type=jnp.float32)  # (BQ, G, BK)
        kpos = i * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (bq, 1, block_k), 2)
        valid = kpos < seq_valid
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (bq, 1, block_k), 0)
            valid &= kpos <= qpos
        s = jnp.where(valid, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        pv = jnp.einsum("qgk,kh->qgh", p, v,
                        preferred_element_type=jnp.float32)
        return m_new, l_new, acc * corr[..., None] + pv

    m0 = jnp.full((bq, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq, g), jnp.float32)
    a0 = jnp.zeros((bq, g, hd), jnp.float32)
    if causal:
        # only kv blocks up to (and including) the diagonal contribute
        hi = jnp.minimum((qi + 1) * block_q + block_k - 1, seq_k) // block_k
    else:
        hi = nkv
    m, l, acc = jax.lax.fori_loop(0, hi, body, (m0, l0, a0))
    out = acc / jnp.maximum(l, 1e-20)[..., None]
    o_ref[...] = out[None].astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                           causal: bool = True, block_q: int = 512,
                           block_k: int = 512,
                           interpret: bool = False) -> jax.Array:
    """q: (B, T, H, hd); k, v: (B, S, KV, hd) → (B, T, H, hd).

    Requires T % block_q == 0 and S % block_k == 0 after internal padding.
    """
    b, t, h, hd = q.shape
    s_len, kv = k.shape[1], k.shape[2]
    g = h // kv
    scale = 1.0 / np.sqrt(hd)
    block_q = min(block_q, t)
    block_k = min(block_k, s_len)
    pq = (-t) % block_q
    pk = (-s_len) % block_k
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    tp, sp = t + pq, s_len + pk
    # layout: (B·KV, T, G, hd) so one grid row owns one kv head
    qr = q.reshape(b, tp, kv, g, hd).transpose(0, 2, 1, 3, 4).reshape(
        b * kv, tp, g, hd)
    kr = k.transpose(0, 2, 1, 3).reshape(b * kv, sp, hd)
    vr = v.transpose(0, 2, 1, 3).reshape(b * kv, sp, hd)
    grid = (b * kv, tp // block_q)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, block_q=block_q, block_k=block_k,
                          seq_k=sp, seq_valid=s_len, causal=causal,
                          scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, g, hd), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, sp, hd), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, sp, hd), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, g, hd), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kv, tp, g, hd), q.dtype),
        interpret=interpret,
    )(qr, kr, vr)
    out = out.reshape(b, kv, tp, g, hd).transpose(0, 2, 1, 3, 4).reshape(
        b, tp, h, hd)
    return out[:, :t]
