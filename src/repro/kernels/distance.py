"""MXU-tiled batched distance-matrix Pallas kernel.

Target: TPU v5e.  The (Q, N) distance matrix is the compute hot-spot of
centroid scoring (ScaNN root/branch levels) and of the workload generator.
Tiling: (BQ, D) × (BN, D) blocks in VMEM, output (BQ, BN); the inner
contraction runs on the MXU via jnp.dot with preferred_element_type=f32.
Block sizes default to 128×128 — MXU-aligned (multiples of 8×128 lanes).

L2 uses the ||q||² + ||x||² − 2q·x expansion so the MXU does all the work;
norms are computed inside the kernel from the resident blocks (cheap VPU
reduction, avoids a second HBM stream).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dist_kernel(q_ref, x_ref, out_ref, *, metric: str):
    q = q_ref[...]                       # (BQ, D) f32
    x = x_ref[...]                       # (BN, D) f32
    ip = jnp.dot(q, x.T, preferred_element_type=jnp.float32)
    if metric == "ip":
        out_ref[...] = -ip
    else:
        qn = jnp.sum(q * q, axis=1, keepdims=True)
        xn = jnp.sum(x * x, axis=1)[None, :]
        out_ref[...] = qn + xn - 2.0 * ip


def distance_matrix_pallas(queries: jax.Array, rows: jax.Array,
                           metric: str = "l2", bq: int = 128, bn: int = 128,
                           interpret: bool = False) -> jax.Array:
    """(Q, N) distances. Pads Q/N up to block multiples, D to lane multiple."""
    q0, n0, d0 = queries.shape[0], rows.shape[0], rows.shape[1]
    bq = min(bq, max(8, q0))
    pq, pn, pd = (-q0) % bq, (-n0) % bn, (-d0) % 128
    q = jnp.pad(queries.astype(jnp.float32), ((0, pq), (0, pd)))
    x = jnp.pad(rows.astype(jnp.float32), ((0, pn), (0, pd)))
    grid = (q.shape[0] // bq, x.shape[0] // bn)
    out = pl.pallas_call(
        functools.partial(_dist_kernel, metric=metric),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, q.shape[1]), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, x.shape[1]), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((q.shape[0], x.shape[0]), jnp.float32),
        interpret=interpret,
    )(q, x)
    return out[:q0, :n0]
