"""Two-stage tiled partial top-k Pallas kernel.

Stage 1 (this kernel): each grid step reduces one VMEM-resident block of
scores to its local k smallest via k iterative masked-min extractions —
k is small (10–100) so this is k cheap VPU reductions, no sort network.
Stage 2 (host/XLA): jnp.top_k over the (nblocks × k) survivors.

This is the TPU shape of ScaNN's per-leaf candidate selection: selection is
done while the scores are still VMEM-resident, so only k survivors per block
travel back to HBM instead of the full score stream.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _topk_block_kernel(v_ref, outv_ref, outi_ref, *, k: int, block: int):
    v = v_ref[...][0]                                # (block,) f32
    idx_base = pl.program_id(0) * block
    vals = jnp.full((k,), jnp.inf, jnp.float32)
    idxs = jnp.full((k,), -1, jnp.int32)
    cur = v
    for j in range(k):                               # k masked-min extractions
        i = jnp.argmin(cur)
        vals = vals.at[j].set(cur[i])
        # +inf means filtered/padded everywhere in this codebase: once a
        # block is exhausted argmin degenerates to 0, so a +inf extraction
        # must report -1, not a bogus real index (k > n case).  Only +inf:
        # -inf is a legitimate smallest value and keeps its real index.
        idxs = idxs.at[j].set(jnp.where(cur[i] == jnp.inf, -1, idx_base + i))
        cur = cur.at[i].set(jnp.inf)
    outv_ref[...] = vals[None, :]
    outi_ref[...] = idxs[None, :]


def topk_pallas(values: jax.Array, k: int, block: int = 1024,
                interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """Global k smallest of a 1-D array: (values, indices)."""
    n = values.shape[0]
    block = min(block, max(k, n))
    pad = (-n) % block
    v = jnp.pad(values.astype(jnp.float32), (0, pad),
                constant_values=jnp.inf)[None, :]
    nb = v.shape[1] // block
    outv, outi = pl.pallas_call(
        functools.partial(_topk_block_kernel, k=k, block=block),
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, block), lambda i: (0, i))],
        out_specs=[pl.BlockSpec((1, k), lambda i: (i, 0)),
                   pl.BlockSpec((1, k), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((nb, k), jnp.float32),
                   jax.ShapeDtypeStruct((nb, k), jnp.int32)],
        interpret=interpret,
    )(v)
    flatv, flati = outv.reshape(-1), outi.reshape(-1)
    neg, pos = jax.lax.top_k(-flatv, k)
    idx = flati[pos]
    return -neg, jnp.where(idx < n, idx, -1)
