"""Fused filtered quantized leaf-scan Pallas kernels (the paper's Fig. 7 flow).

Two variants share the layout:

`leaf_scan_pallas` — one grid step processes one ScaNN leaf for ONE query:
the int8 tile is DMA'd HBM→VMEM (the TPU analogue of the paper's sequential
leaf-page walk), rows are filter-checked against the packed bitmap (batched
probe — the paper's §6.2.3(iii) SIMD advantage), dequantized, and scored
against the query in a single VMEM-resident pass.  Filtered-out and padded
rows emit +inf.

`leaf_scan_batched_pallas` — the query-batched pipeline (DESIGN.md §4): one
grid step DMAs one int8 leaf tile into VMEM ONCE and scores it against the
whole query block via a single MXU (Q, d) × (d, C) contraction (the
transpose of the (C, d) × (d, Q) form — same contraction, friendlier
padding: Q rides the 8-sublane axis, C the 128-lane axis).  Per-query
packed bitmaps are probed with one word-gather per (query, row) and
precomputed row norms replace the per-query ||x||² reduction of the single
query kernel.  This is what amortizes leaf fetch + filter + score across a
concurrent query batch, instead of re-streaming every tile per query under
`jax.vmap`.

Fusion rationale (DESIGN.md §3): in an unfused pipeline the f32 dequantized
tile and the boolean mask each round-trip through HBM; fusing keeps the
working set at (C × d) int8 + (C × d) f32 in VMEM and streams the bitmap
words once.  With C=512, d=1024: 0.5 MB int8 + 2 MB f32 — comfortably
inside the 16 MB/core VMEM envelope of v5e, MXU-aligned (C, d multiples of
8/128 after padding).  VMEM budget math for the batched tile is in
DESIGN.md §4.

The bitmap probe uses a gather of one uint32 word per row.  On TPU this
lowers to a dynamic-slice loop over the (small) rowid vector — cheap next to
the (C × d) contraction; correctness is validated in interpret mode against
ref.leaf_scan_ref / ref.leaf_scan_batched_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _leaf_scan_kernel(q_ref, tile_ref, rowid_ref, scale_ref, mean_ref,
                      bitmap_ref, out_ref, *, metric: str):
    q = q_ref[...]                                   # (1, d) f32
    t = tile_ref[...][0]                             # (C, d) int8
    rid = rowid_ref[...][0]                          # (C,) int32
    scale = scale_ref[...]                           # (1, d)
    mean = mean_ref[...]                             # (1, d)
    x = t.astype(jnp.float32) * scale + mean         # dequant (C, d)
    ip = jnp.dot(x, q[0], preferred_element_type=jnp.float32)  # (C,)
    if metric == "ip":
        d = -ip
    else:
        qn = jnp.sum(q[0] * q[0])
        xn = jnp.sum(x * x, axis=-1)
        d = qn + xn - 2.0 * ip
    # batched bitmap probe
    safe = jnp.maximum(rid, 0)
    words = bitmap_ref[...][0]                       # (W,) uint32
    w = jnp.take(words, safe >> 5, axis=0)
    bit = (w >> (safe & 31).astype(jnp.uint32)) & jnp.uint32(1)
    ok = (bit == 1) & (rid >= 0)
    out_ref[...] = jnp.where(ok, d, jnp.inf)[None, :]


def leaf_scan_pallas(query: jax.Array, tiles: jax.Array, rowids: jax.Array,
                     scale: jax.Array, mean: jax.Array, bitmap: jax.Array,
                     metric: str = "l2", interpret: bool = False) -> jax.Array:
    """query (d,), tiles (nl, C, d) int8, rowids (nl, C), scale/mean (d,),
    bitmap (W,) uint32 → scores (nl, C) f32 (+inf = filtered/padded)."""
    nl, c, d = tiles.shape
    pd = (-d) % 128
    pc = (-c) % 8
    tiles_p = jnp.pad(tiles, ((0, 0), (0, pc), (0, pd)))
    rowids_p = jnp.pad(rowids, ((0, 0), (0, pc)), constant_values=-1)
    q = jnp.pad(query.astype(jnp.float32), (0, pd))[None, :]
    s = jnp.pad(scale.astype(jnp.float32), (0, pd))[None, :]
    m = jnp.pad(mean.astype(jnp.float32), (0, pd))[None, :]
    bm = bitmap[None, :]
    cp, dp = c + pc, d + pd
    out = pl.pallas_call(
        functools.partial(_leaf_scan_kernel, metric=metric),
        grid=(nl,),
        in_specs=[
            pl.BlockSpec((1, dp), lambda i: (0, 0)),          # query
            pl.BlockSpec((1, cp, dp), lambda i: (i, 0, 0)),   # leaf tile
            pl.BlockSpec((1, cp), lambda i: (i, 0)),          # rowids
            pl.BlockSpec((1, dp), lambda i: (0, 0)),          # scale
            pl.BlockSpec((1, dp), lambda i: (0, 0)),          # mean
            pl.BlockSpec((1, bitmap.shape[0]), lambda i: (0, 0)),  # bitmap
        ],
        out_specs=pl.BlockSpec((1, cp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nl, cp), jnp.float32),
        interpret=interpret,
    )(q, tiles_p, rowids_p, s, m, bm)
    return out[:, :c]


def _leaf_scan_batched_kernel(q_ref, tile_ref, rowid_ref, scale_ref,
                              mean_ref, norms_ref, bitmap_ref, out_ref, *,
                              metric: str):
    q = q_ref[...]                                   # (Qp, d) f32
    t = tile_ref[...][0]                             # (C, d) int8
    rid = rowid_ref[...][0]                          # (C,) int32
    scale = scale_ref[...]                           # (1, d)
    mean = mean_ref[...]                             # (1, d)
    x = t.astype(jnp.float32) * scale + mean         # dequant (C, d)
    # MXU: score the whole query block against the resident tile at once
    ip = jnp.dot(q, x.T, preferred_element_type=jnp.float32)   # (Qp, C)
    if metric == "ip":
        d = -ip
    else:
        xn = norms_ref[...][0]                       # (C,) precomputed ||x||²
        qn = jnp.sum(q * q, axis=1, keepdims=True)   # (Qp, 1)
        d = qn + xn[None, :] - 2.0 * ip
    # per-query batched bitmap probe: one word gather per (query, row)
    safe = jnp.maximum(rid, 0)
    words = bitmap_ref[...]                          # (Qp, W) uint32
    w = jnp.take(words, safe >> 5, axis=1)           # (Qp, C)
    bit = (w >> (safe & 31).astype(jnp.uint32)[None, :]) & jnp.uint32(1)
    ok = (bit == 1) & (rid >= 0)[None, :]
    out_ref[...] = jnp.where(ok, d, jnp.inf)[None]


def leaf_scan_batched_pallas(queries: jax.Array, tiles: jax.Array,
                             rowids: jax.Array, scale: jax.Array,
                             mean: jax.Array, bitmaps: jax.Array,
                             row_norms_sq: jax.Array, metric: str = "l2",
                             interpret: bool = False) -> jax.Array:
    """queries (Q, d) f32, tiles (U, C, d) int8, rowids (U, C) int32,
    scale/mean (d,) f32, bitmaps (Q, W) uint32, row_norms_sq (U, C) f32
    → scores (Q, U, C) f32 (+inf = filtered/padded).

    Grid is (U,): each step fetches one leaf tile once and scores the whole
    query batch against it (DESIGN.md §4)."""
    u, c, d = tiles.shape
    nq = queries.shape[0]
    pd = (-d) % 128
    pc = (-c) % 128          # C is the lane axis of the (Qp, C) output
    pq = (-nq) % 8
    tiles_p = jnp.pad(tiles, ((0, 0), (0, pc), (0, pd)))
    rowids_p = jnp.pad(rowids, ((0, 0), (0, pc)), constant_values=-1)
    norms_p = jnp.pad(row_norms_sq.astype(jnp.float32), ((0, 0), (0, pc)))
    q = jnp.pad(queries.astype(jnp.float32), ((0, pq), (0, pd)))
    s = jnp.pad(scale.astype(jnp.float32), (0, pd))[None, :]
    m = jnp.pad(mean.astype(jnp.float32), (0, pd))[None, :]
    bm = jnp.pad(bitmaps, ((0, pq), (0, 0)))         # padded queries: all 0
    qp, cp, dp, w = nq + pq, c + pc, d + pd, bitmaps.shape[1]
    out = pl.pallas_call(
        functools.partial(_leaf_scan_batched_kernel, metric=metric),
        grid=(u,),
        in_specs=[
            pl.BlockSpec((qp, dp), lambda i: (0, 0)),         # query block
            pl.BlockSpec((1, cp, dp), lambda i: (i, 0, 0)),   # leaf tile
            pl.BlockSpec((1, cp), lambda i: (i, 0)),          # rowids
            pl.BlockSpec((1, dp), lambda i: (0, 0)),          # scale
            pl.BlockSpec((1, dp), lambda i: (0, 0)),          # mean
            pl.BlockSpec((1, cp), lambda i: (i, 0)),          # row norms
            pl.BlockSpec((qp, w), lambda i: (0, 0)),          # bitmaps
        ],
        out_specs=pl.BlockSpec((1, qp, cp), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((u, qp, cp), jnp.float32),
        interpret=interpret,
    )(q, tiles_p, rowids_p, s, m, norms_p, bm)
    return out.transpose(1, 0, 2)[:nq, :, :c]
