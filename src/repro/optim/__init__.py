from repro.optim.adamw import (AdamWConfig, adamw_init, adamw_update,
                               cosine_schedule)
from repro.optim.compression import (compress_int8, decompress_int8,
                                     ef_compress_grads)

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_schedule",
           "compress_int8", "decompress_int8", "ef_compress_grads"]
