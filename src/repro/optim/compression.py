"""Int8 error-feedback gradient compression (distributed-optimization trick).

Applied around the DP gradient all-reduce: grads are quantized to int8 with
a per-tensor scale before crossing the ICI, the quantization residual is
carried in an error-feedback buffer and added back next step (Seide et al. /
EF-SGD semantics — unbiased in the long run, convergence-safe).

`ef_compress_grads` is the pure transformation; the trainer wires it in
when `grad_compression=True`, and EXPERIMENTS.md §Perf ablates the
collective-bytes saving (4× smaller DP all-reduce payload).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def compress_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress_grads(grads: Any, error: Any) -> tuple[Any, Any]:
    """Returns (compressed-then-decompressed grads, new error buffers).

    The round-trip models exactly what the collective would transport; the
    error buffer accumulates what was lost so it is re-sent next step.
    """

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, s = compress_int8(gf)
        deq = decompress_int8(q, s)
        return deq.astype(g.dtype), gf - deq

    out = jax.tree.map(one, grads, error)
    newg = jax.tree.map(lambda t: t[0], out,
                        is_leaf=lambda t: isinstance(t, tuple))
    newe = jax.tree.map(lambda t: t[1], out,
                        is_leaf=lambda t: isinstance(t, tuple))
    return newg, newe


def init_error_buffers(grads_shape: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                        grads_shape)
