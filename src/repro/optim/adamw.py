"""AdamW with configurable state dtype (ZeRO-style sharding is applied by
the launcher's partition rules — optimizer states inherit the weights'
sharding plus full sharding over the data axis for fsdp archs)."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    state_dtype: str = "float32"    # bfloat16 halves optimizer memory


def cosine_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def adamw_init(params: Any, cfg: AdamWConfig) -> dict:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def _global_norm(grads: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(grads)))


def adamw_update(params: Any, grads: Any, state: dict,
                 cfg: AdamWConfig) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = cosine_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    sdt = jnp.dtype(cfg.state_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        mf = b1 * m.astype(jnp.float32) + (1 - b1) * g
        vf = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        update = (mf / bc1) / (jnp.sqrt(vf / bc2) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * (update + decay)
        return newp.astype(p.dtype), mf.astype(sdt), vf.astype(sdt)

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    newp = jax.tree.map(lambda t: t[0], out,
                        is_leaf=lambda t: isinstance(t, tuple))
    newm = jax.tree.map(lambda t: t[1], out,
                        is_leaf=lambda t: isinstance(t, tuple))
    newv = jax.tree.map(lambda t: t[2], out,
                        is_leaf=lambda t: isinstance(t, tuple))
    return newp, {"m": newm, "v": newv, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
