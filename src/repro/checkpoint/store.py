"""Fault-tolerant checkpointing: atomic, resharding-on-restore, async.

Layout: <dir>/step_<N>/ with one .npy per pytree leaf (path-encoded
filenames) + manifest.json (step, leaf index, dtypes/shapes, integrity
sizes).  Writes go to step_<N>.tmp and are atomically renamed — a killed
writer never corrupts the latest checkpoint (preemption safety).

Restore takes an optional `shardings` pytree: arrays are `device_put` to
the *current* mesh, which may differ from the writer's mesh (elastic
re-mesh: scale from 256 to 512 chips and keep training).  Leaves are
addressed by path, so a restore also tolerates optimizer-state layout
changes as long as paths match.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

_SAFE = re.compile(r"[^A-Za-z0-9_.-]+")


def _leaf_paths(tree: Any) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = _SAFE.sub("_", jax.tree_util.keystr(path)).strip("_")
        out.append((name, leaf))
    return out


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra: Optional[dict] = None,
                    fsync: bool = False) -> str:
    """Write one checkpoint.  `fsync=True` is the crash-consistency mode
    (DESIGN.md §12): every leaf file, the manifest, and the parent
    directory entry are fsynced BEFORE the atomic rename publishes the
    step — a checkpoint a WAL compaction marker points at must actually
    be on storage, or recovery could land on a marker whose checkpoint
    evaporated with the page cache."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest = {"step": step, "leaves": {}, "extra": extra or {}}
    for name, leaf in _leaf_paths(tree):
        arr = np.asarray(leaf)
        path = os.path.join(tmp, name + ".npy")
        with open(path, "wb") as f:
            np.save(f, arr)
            if fsync:
                f.flush()
                os.fsync(f.fileno())
        manifest["leaves"][name] = {
            "shape": list(arr.shape), "dtype": str(arr.dtype),
            "bytes": int(arr.nbytes)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        if fsync:
            f.flush()
            os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)          # atomic publish
    if fsync:
        dfd = os.open(directory, os.O_RDONLY)
        try:
            os.fsync(dfd)          # durably order the rename itself
        finally:
            os.close(dfd)
    return final


def read_manifest(directory: str, step: int) -> dict:
    """The step's manifest (step, leaves index, extra) without restoring
    any arrays — recovery reads `extra` first to learn the shapes the
    `tree_like` for restore_checkpoint must have."""
    path = os.path.join(directory, f"step_{step:08d}", "manifest.json")
    with open(path) as f:
        return json.load(f)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, tree_like: Any,
                       shardings: Optional[Any] = None) -> tuple[Any, dict]:
    """Restore into the structure of `tree_like`; device_put with
    `shardings` (same structure) if given — this is the elastic reshard."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    names = [n for n, _ in _leaf_paths(tree_like)]
    flat_like, treedef = jax.tree_util.tree_flatten(tree_like)
    shard_flat = (jax.tree_util.tree_flatten(shardings)[0]
                  if shardings is not None else [None] * len(flat_like))
    leaves = []
    for name, like, shd in zip(names, flat_like, shard_flat):
        meta = manifest["leaves"][name]
        arr = np.load(os.path.join(path, name + ".npy"))
        if arr.nbytes != meta["bytes"]:
            raise IOError(f"integrity check failed for {name}")
        leaves.append(jax.device_put(arr, shd) if shd is not None
                      else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["extra"]


class CheckpointManager:
    """Async, bounded-retention checkpoint writer with preemption safety."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save_async(self, step: int, tree: Any,
                   extra: Optional[dict] = None) -> None:
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot before async

        def work():
            save_checkpoint(self.directory, step, host_tree, extra)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(
            self.directory) if d.startswith("step_")
            and not d.endswith(".tmp"))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
