"""Exact (optionally filtered) KNN — ground truth for every recall number."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.types import VectorStore, probe_bitmap, topk_smallest
from repro.core.workload import full_distances


@partial(jax.jit, static_argnames=("k",))
def knn(store: VectorStore, queries: jax.Array, k: int):
    """Unfiltered exact top-k. Returns (dists, ids) each (Q, k)."""
    d = full_distances(store, queries)
    return topk_smallest(d, k)


@partial(jax.jit, static_argnames=("k",))
def filtered_knn(store: VectorStore, queries: jax.Array, bitmaps: jax.Array,
                 k: int):
    """Exact top-k restricted to rows whose bitmap bit is set.

    bitmaps: (Q, ceil(N/32)) uint32.  Rows failing the filter get +inf.
    Returns (dists, ids); ids are -1 where fewer than k rows pass.
    """
    d = full_distances(store, queries)
    ids = jnp.arange(store.n)
    passing = jax.vmap(lambda bm: probe_bitmap(bm, ids))(bitmaps)
    d = jnp.where(passing, d, jnp.inf)
    dists, idx = topk_smallest(d, k)
    idx = jnp.where(jnp.isinf(dists), -1, idx)
    return dists, idx
