"""Exact (optionally filtered) KNN — ground truth for every recall number."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.types import VectorStore, probe_bitmap, topk_smallest
from repro.core.workload import full_distances


@partial(jax.jit, static_argnames=("k",))
def knn(store: VectorStore, queries: jax.Array, k: int):
    """Unfiltered exact top-k. Returns (dists, ids) each (Q, k)."""
    d = full_distances(store, queries)
    return topk_smallest(d, k)


@partial(jax.jit, static_argnames=("k",))
def filtered_knn(store: VectorStore, queries: jax.Array, bitmaps: jax.Array,
                 k: int):
    """Exact top-k restricted to rows whose bitmap bit is set.

    bitmaps: (Q, ceil(N/32)) uint32.  Rows failing the filter get +inf.
    Returns (dists, ids); ids are -1 where fewer than k rows pass.
    """
    d = full_distances(store, queries)
    ids = jnp.arange(store.n)
    passing = jax.vmap(lambda bm: probe_bitmap(bm, ids))(bitmaps)
    d = jnp.where(passing, d, jnp.inf)
    dists, idx = topk_smallest(d, k)
    idx = jnp.where(jnp.isinf(dists), -1, idx)
    return dists, idx


@partial(jax.jit, static_argnames=("k", "max_rows"))
def filtered_knn_partial(store: VectorStore, queries: jax.Array,
                         bitmaps: jax.Array, k: int, max_rows: int):
    """Budgeted partial seqscan (DESIGN.md §10): exact top-k over the
    first `max_rows` PASSING rows in row order — the scan a page budget
    can afford, stopping once the budget's worth of heap fetches is
    spent.  The degradation ladder's last rung: always returns something,
    flagged partial when the scan stopped early.

    Returns (dists, ids, n_scored, probes, truncated), all per-query:
    n_scored = passing rows actually fetched+scored (≤ max_rows),
    probes = rows filter-probed before the scan stopped (= n when the
    whole bitmap fit the budget), truncated = the cap cut the scan short.
    """
    d = full_distances(store, queries)
    ids = jnp.arange(store.n)
    passing = jax.vmap(lambda bm: probe_bitmap(bm, ids))(bitmaps)
    cum = jnp.cumsum(passing.astype(jnp.int32), axis=1)
    scored = passing & (cum <= max_rows)
    d = jnp.where(scored, d, jnp.inf)
    dists, idx = topk_smallest(d, k)
    idx = jnp.where(jnp.isinf(dists), -1, idx)
    n_scored = scored.sum(1).astype(jnp.int32)
    truncated = cum[:, -1] > max_rows
    probes = jnp.where(truncated,
                       jnp.argmax(cum > max_rows, axis=1).astype(jnp.int32)
                       + 1,
                       jnp.int32(store.n))
    return dists, idx, n_scored, probes, truncated
