"""Core datatypes for the filter-agnostic FVS framework.

Mirrors the paper's object model:
  - a vector collection stored in fixed-size "pages" (TPU analogue: dense
    HBM tiles; see DESIGN.md §3),
  - per-query filter *bitmaps* produced by the workload generator (§4 of the
    paper): the index never sees predicates, only row-id bitmaps,
  - per-query system counters (distance computations, filter checks, hops,
    page accesses) exactly matching the columns of the paper's Table 6.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

# Page geometry has one owner: the storage layer (DESIGN.md §8).  The
# names are re-exported here for backward compatibility — every historical
# consumer imported them from core.types.
from repro.storage.pages import (HEAP_PAGE_BYTES,  # noqa: F401
                                 heap_pages_per_vector,
                                 quant_heap_pages_per_vector)

Array = jax.Array

# Metrics supported by the paper's datasets (Table 2): L2 and inner product.
METRIC_L2 = "l2"
METRIC_IP = "ip"
METRIC_COS = "cos"


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class VectorStore:
    """A vector collection, optionally with a quantized shadow copy.

    vectors: (N, d) float32 full-precision rows ("heap" in the paper).
    norms_sq: (N,) precomputed squared norms (L2 fast path).

    The SQ8 shadow (DESIGN.md §9) is the quantized-traversal tier of the
    graph engine: per-dimension affine int8 rows (the same quantizer the
    ScaNN leaves use) plus build-time ||dequant(x)||² so the L2 fast path
    never recomputes norms during traversal.  None until `quantize_store`
    attaches it; the full-precision rows stay authoritative (exact rerank,
    reordering, ground truth).
    """

    vectors: Array
    norms_sq: Array
    metric: str = dataclasses.field(metadata=dict(static=True), default=METRIC_L2)
    # SQ8 shadow (graph_quant="sq8"): dequant is x = q_vectors*q_scale+q_mean
    q_vectors: Optional[Array] = None      # (N, d) int8
    q_scale: Optional[Array] = None        # (d,) f32
    q_mean: Optional[Array] = None         # (d,) f32
    q_norms_sq: Optional[Array] = None     # (N,) f32 of the dequantized rows

    @property
    def n(self) -> int:
        return self.vectors.shape[0]

    @property
    def dim(self) -> int:
        return self.vectors.shape[1]

    @property
    def has_sq8(self) -> bool:
        return self.q_vectors is not None

    @staticmethod
    def build(vectors: Array | np.ndarray, metric: str = METRIC_L2) -> "VectorStore":
        vectors = jnp.asarray(vectors, jnp.float32)
        norms_sq = jnp.sum(vectors * vectors, axis=-1)
        return VectorStore(vectors=vectors, norms_sq=norms_sq, metric=metric)


def sq8_quantize(x: np.ndarray) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-dimension affine SQ8 over a dataset (the one quantizer in the
    repo — the ScaNN leaf builder and the graph shadow store share it).

    Returns (q (n, d) int8, scale (d,) f32, mean (d,) f32) with
    dequantization x̂ = q * scale + mean.
    """
    x = np.asarray(x, np.float32)
    lo, hi = x.min(0), x.max(0)
    scale = np.maximum((hi - lo) / 254.0, 1e-8).astype(np.float32)
    mean = ((hi + lo) / 2.0).astype(np.float32)
    q = np.clip(np.round((x - mean) / scale), -127, 127).astype(np.int8)
    return q, scale, mean


def quantize_store(store: "VectorStore") -> "VectorStore":
    """Attach the SQ8 shadow to a store (idempotent).  The shadow norms are
    computed with the same dequant + reduction arithmetic the frontier
    kernels/oracles apply, so precomputed and inline norms agree."""
    if store.has_sq8:
        return store
    q, scale, mean = sq8_quantize(np.asarray(store.vectors))
    qj = jnp.asarray(q)
    scale_j, mean_j = jnp.asarray(scale), jnp.asarray(mean)
    deq = qj.astype(jnp.float32) * scale_j + mean_j
    return dataclasses.replace(
        store, q_vectors=qj, q_scale=scale_j, q_mean=mean_j,
        q_norms_sq=jnp.sum(deq * deq, axis=-1))


def distance(metric: str, q: Array, x: Array, x_norm_sq: Optional[Array] = None) -> Array:
    """Distance between query q (..., d) and rows x (..., d). Lower is closer."""
    if metric == METRIC_L2:
        if x_norm_sq is None:
            x_norm_sq = jnp.sum(x * x, axis=-1)
        qn = jnp.sum(q * q, axis=-1)
        return qn + x_norm_sq - 2.0 * jnp.sum(q * x, axis=-1)
    if metric == METRIC_IP:
        return -jnp.sum(q * x, axis=-1)
    if metric == METRIC_COS:
        qn = jnp.linalg.norm(q, axis=-1) + 1e-12
        xn = jnp.linalg.norm(x, axis=-1) + 1e-12
        return 1.0 - jnp.sum(q * x, axis=-1) / (qn * xn)
    raise ValueError(f"unknown metric {metric!r}")


# ---------------------------------------------------------------------------
# Filter bitmaps.  The workload generator (workload.py) emits, per query, the
# set of row ids satisfying the (simulated) relational predicate.  Probing
# the bitmap during traversal == the paper's "filter check".
# ---------------------------------------------------------------------------

def pack_bitmap(passing_rows: np.ndarray | Array, n: int) -> Array:
    """Pack row-id set into a (ceil(n/32),) uint32 bitmap."""
    bits = np.zeros(n, dtype=bool)
    bits[np.asarray(passing_rows)] = True
    return pack_bool_bitmap(bits)


def pack_bool_bitmap(bits: np.ndarray | Array) -> Array:
    bits = np.asarray(bits, dtype=bool)
    n = bits.shape[-1]
    pad = (-n) % 32
    if pad:
        bits = np.concatenate([bits, np.zeros(bits.shape[:-1] + (pad,), bool)], -1)
    words = bits.reshape(bits.shape[:-1] + (-1, 32))
    weights = (1 << np.arange(32, dtype=np.uint64)).astype(np.uint32)
    packed = (words.astype(np.uint32) * weights).sum(-1, dtype=np.uint32)
    return jnp.asarray(packed)


def probe_bitmap(bitmap: Array, row_ids: Array) -> Array:
    """Vectorized filter check: bitmap probe per row id. Negative ids -> False."""
    row_ids = jnp.asarray(row_ids)
    safe = jnp.maximum(row_ids, 0)
    word = bitmap[safe >> 5]
    bit = (word >> (safe & 31).astype(jnp.uint32)) & jnp.uint32(1)
    return jnp.where(row_ids >= 0, bit.astype(bool), False)


def unpack_bitmap(bitmap: np.ndarray | Array, n: int) -> np.ndarray:
    words = np.asarray(bitmap)
    bits = (words[..., :, None] >> np.arange(32, dtype=np.uint32)) & 1
    return bits.reshape(words.shape[:-1] + (-1,))[..., :n].astype(bool)


def bitmap_andnot(bitmap: Array, minus: Array) -> Array:
    """bitmap ∧ ¬minus over packed uint32 words — the tombstone
    composition (DESIGN.md §12): `minus` is the delete bitmap, and the
    result is the live filter every executor actually probes, so deleted
    rows vanish from all strategies without touching their indexes.
    `minus` may be shorter (or longer) than the filter's word count —
    words past either end pass through unchanged (a missing word deletes
    nothing)."""
    bm = jnp.asarray(bitmap)
    mi = jnp.asarray(minus, jnp.uint32)
    w = min(bm.shape[-1], mi.shape[-1])
    return bm.at[..., :w].set(bm[..., :w] & ~mi[..., :w])


# ---------------------------------------------------------------------------
# Packed bitsets over row ids.  The filter bitmaps above are the read-only
# instance; the frontier graph engine also keeps its per-query *visited* set
# in the same uint32-word layout (8x less in-flight state than an (n,) bool
# array) and probes it with the same `probe_bitmap`.
# ---------------------------------------------------------------------------

def bitset_words(n: int) -> int:
    """Words needed for a packed bitset over n row ids."""
    return (n + 31) // 32


def bitset_zeros(n: int) -> Array:
    return jnp.zeros((bitset_words(n),), jnp.uint32)


def bitset_mark(words: Array, row_ids: Array, mask: Array) -> Array:
    """Set the bits of `row_ids[mask]` in a packed bitset.

    Contract: the masked ids must be distinct and currently unset (the
    scatter adds each bit's weight, so a repeated or already-set bit would
    carry into neighboring bits).  Every engine call site guarantees this:
    marked nodes are filtered through an unvisited mask and deduplicated
    first.  Negative ids are ignored regardless of `mask`.
    """
    live = mask & (row_ids >= 0)
    safe = jnp.maximum(row_ids, 0)
    bit = jnp.where(live, jnp.uint32(1) << (safe & 31).astype(jnp.uint32),
                    jnp.uint32(0))
    return words.at[(safe >> 5).reshape(-1)].add(bit.reshape(-1))


# ---------------------------------------------------------------------------
# Search statistics — the exact columns of the paper's Table 6, carried as a
# pytree through every jitted search loop.
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SearchStats:
    distance_comps: Array          # scored candidates
    filter_checks: Array           # bitmap probes
    hops: Array                    # graph hops / (leaves scanned for ScaNN)
    page_accesses_index: Array     # index-page analogue accesses (metadata)
    page_accesses_heap: Array      # heap-page analogue accesses (vector rows)
    tmap_lookups: Array            # translation-map lookups (Fig. 13 ablation)
    reorder_rows: Array            # ScaNN reordering candidates (Table 6 col)

    @staticmethod
    def zeros(dtype=jnp.int32) -> "SearchStats":
        z = jnp.zeros((), dtype)
        return SearchStats(z, z, z, z, z, z, z)

    def __add__(self, other: "SearchStats") -> "SearchStats":
        return jax.tree.map(lambda a, b: a + b, self, other)

    def as_dict(self) -> dict[str, Any]:
        return {f.name: np.asarray(getattr(self, f.name)).tolist()
                for f in dataclasses.fields(self)}


@dataclasses.dataclass(frozen=True)
class SearchParams:
    """Run-time knobs (paper §5 'Hyperparameter Tuning')."""

    k: int = 10
    ef_search: int = 64            # result-queue width (HNSW ef / W size)
    beam_width: int = 64           # candidate pool width
    max_hops: int = 512            # safety cap on traversal length
    strategy: str = "sweeping"     # sweeping|acorn|navix|iterative_scan|scann|...
    two_hop: bool = True           # filter-first 2-hop expansion (ACORN/NaviX)
    adaptive_skip_2hop: bool = True  # the paper's "hardened ACORN" optimization
    translation_map: bool = True   # paper §3.1 optimization (i); Fig. 13 ablation
    navix_heuristic: str = "adaptive"  # blind|directed|onehop|adaptive
    # Graph execution engine (DESIGN.md §7): "frontier" advances the whole
    # query batch one superstep at a time with deduplicated union fetches,
    # packed visited bitsets, and chunked need-only scoring; "vmapped" is
    # the legacy per-query beam loop kept as the bit-identical oracle.
    graph_exec_mode: str = "frontier"
    # Quantized graph traversal (DESIGN.md §9): "sq8" makes BOTH graph
    # engines navigate over the store's SQ8 shadow rows (int8 fetches,
    # in-kernel dequant on the Pallas path) and exactly re-score the final
    # result beam from the full-precision heap (ScaNN-reorder-style,
    # counted in reorder_rows + full-width heap pages).  "none" is the
    # classic full-precision traversal — bit-identical to the
    # pre-quantization engines.  Requires a `quantize_store`d VectorStore.
    graph_quant: str = "none"
    # Frontier-engine chunk sizes (DESIGN.md §7): candidates that actually
    # need scoring are compacted and scored `chunk` at a time.  0 = score
    # the full candidate width in one pass (no compaction) — the right
    # call for the (2M,)-wide 1-hop stage, where compaction machinery
    # costs more than the gathers it saves; `frontier_chunk2` sizes the
    # lazy 2-hop chunks of the filter-first strategies, whose (2M·2M)
    # candidate block is mostly never scored.
    frontier_chunk: int = 0
    frontier_chunk2: int = 64
    # ScaNN knobs:
    num_leaves_to_search: int = 32
    reorder_factor: int = 4        # rescoring budget = k * reorder_factor
    # Index-page accounting for the batched ScaNN pipeline (DESIGN.md §5):
    # "batch" charges each quantized leaf page once per opened leaf per
    # query *batch* (attributed to the first query that opens it); the
    # legacy "per_query" mode charges every query for every leaf it opens
    # (the pre-batching semantics — use for Fig. 10/13 reproduction).
    scann_page_accounting: str = "batch"
    # Query-block tiling for the batched ScaNN pipeline (DESIGN.md §4
    # "Scaling envelope"): the (Q, U, C) union-scan block is processed in
    # query tiles of this size so huge batches stay VMEM/HBM-bounded.
    # 0 = one tile (the whole batch).  ids/dists are tile-size-invariant;
    # "batch" index-page accounting amortizes per tile (DESIGN.md §5).
    scann_query_block: int = 0
    # Iterative-scan knobs (pgvector max_scan_tuples analogue):
    batch_tuples: int = 128
    max_rounds: int = 16
    # Anytime budgets (DESIGN.md §10).  0 / 0.0 disables a budget and the
    # jitted programs are identical to the unbudgeted ones (the predicate
    # is only traced when a budget is set, so zero-budget runs stay
    # bit-identical to pre-budget behavior).  A query that stops on a
    # budget keeps its best-so-far beam; the executor surfaces per-query
    # truncation flags in SearchResult.anytime (costmodel.evaluate_anytime).
    page_budget: int = 0           # stop once index+heap page accesses >= budget
    hop_budget: int = 0            # stop once hops >= budget (< max_hops cap)
    deadline_cycles: float = 0.0   # stop once modeled cycles >= deadline
    # Exact full-precision rerank of the SQ8 beam (DESIGN.md §9).  False is
    # the "sq8-no-rerank" degradation rung: quantized distances are
    # returned as-is, saving the full-width heap fetch per result row.
    sq8_rerank: bool = True
    # Mesh-sharded traversal (DESIGN.md §13): all-gather the per-shard
    # top-k beams every E supersteps.  1 = lockstep mode — every candidate
    # is resolved collectively each hop and results are bit-identical to
    # the single-device engine for any shard count; E > 1 lets each shard
    # drift on its induced subgraph between exchanges (cheaper collectives,
    # approximate results).  Ignored by single-device executors.
    beam_exchange_interval: int = 1
    # FAVOR-style exclusion pruning (DESIGN.md §14): "prune" gates pool
    # insertion in the sweeping frontier engine on precomputed per-node
    # exclusion radii (core/exclusion.py) — a candidate whose nearest
    # passing row provably (in root space, up to `exclusion_margin`) cannot
    # beat the current W tail is dropped before it is ever popped, so its
    # whole branch costs no filter checks, no expansions, no pages.
    # "none" traces nothing and is bit-identical to the pre-exclusion
    # engine (the graph_quant="none" convention).  "prune_exact" is the
    # same traversal with FAVOR's probe-free accounting: the radius test
    # replaces the bitmap probe for pruned candidates, so they are not
    # charged filter checks — sound ONLY with family-exact radii (e = 0
    # iff the row passes; the caller owns that contract).  l2 + frontier
    # + sweeping only; requires `excl=` radii at the search_batch call.
    exclusion: str = "none"
    # Prune aggressiveness: keep a candidate v iff pass(v) or
    # sqrt(e(v)) <= margin * (sqrt(d(q,v)) + sqrt(tau)), tau = W tail.
    # margin >= 1.0 with exact family radii provably never prunes
    # (triangle inequality); < 1.0 trades recall for pruned branches.
    exclusion_margin: float = 0.5


@dataclasses.dataclass
class AnytimeInfo:
    """Per-query anytime-execution flags (DESIGN.md §10), derived
    host-side from the final SearchStats counters (`costmodel.
    evaluate_anytime`) — never carried through a jitted loop.

    truncated: the query stopped before its stop condition converged
    (budget hit OR the max_hops/max_rounds safety cap fired); its
    ids/dists are still the best-so-far beam.
    budget_exhausted: a user-set budget (page/hop/deadline or a
    plan-level clamp) specifically caused the stop.
    completion: fraction of the k result slots holding a valid row id —
    the uniform "how much of the answer did I get" measure across all
    executors (1.0 = full top-k, possibly still truncated-but-converged).
    """

    truncated: np.ndarray          # (Q,) bool
    budget_exhausted: np.ndarray   # (Q,) bool
    completion: np.ndarray         # (Q,) f32 in [0, 1]

    def as_dict(self) -> dict[str, Any]:
        return dict(truncated=self.truncated.tolist(),
                    budget_exhausted=self.budget_exhausted.tolist(),
                    completion=self.completion.tolist())


@dataclasses.dataclass
class SearchResult:
    """Unified return convention of every executor (DESIGN.md §6).

    ids/dists: (Q, k), ids -1-padded where fewer than k rows pass.
    stats: per-query SearchStats ((Q,) leaves), or None when the backend
    cannot carry counters (e.g. the collective distributed path).
    strategy: the strategy that actually executed (for the AdaptivePlanner
    this is the *chosen* fixed strategy, not "adaptive").
    plan: the SearchPlan that produced this result (selectivity estimates,
    predicted cycles — executor.py).
    storage: measured storage telemetry (storage.StorageStats) when the
    executor ran with a StorageEngine attached; None otherwise.
    anytime: per-query AnytimeInfo flags when the executor derives them
    (all local executors do); None on backends without counters.
    """

    dists: Array
    ids: Array
    stats: Optional[SearchStats]
    strategy: str
    plan: Any = None
    storage: Any = None
    anytime: Any = None


def topk_smallest(values: Array, k: int) -> tuple[Array, Array]:
    """(values, indices) of the k smallest entries. jnp.top_k on negated vals."""
    neg, idx = jax.lax.top_k(-values, k)
    return -neg, idx


@partial(jax.jit, static_argnames=("k",))
def merge_topk(dists_a: Array, ids_a: Array, dists_b: Array, ids_b: Array,
               k: int) -> tuple[Array, Array]:
    """K-way merge of two top-k result sets into one (Q, k) top-k — the
    `MergedResult` primitive fusing a base executor's answer with the
    delta tier's exact scan (DESIGN.md §12).

    Inputs are (Q, ka)/(Q, kb) dists with -1-padded ids; padded slots must
    carry +inf dists (every executor's contract).  Concat order is
    (a then b): `lax.top_k` breaks exact ties by position, and since base
    ids are always < delta ids, passing the base result as `a` reproduces
    the id-ascending tie order of a from-scratch rebuild oracle —
    bit-identical merges, not approximately-equal ones.  Slots beyond the
    number of finite candidates come back as (+inf, -1)."""
    dists = jnp.concatenate([dists_a, dists_b], axis=-1)
    ids = jnp.concatenate([ids_a, ids_b], axis=-1)
    best, pos = topk_smallest(dists, k)
    out_ids = jnp.take_along_axis(ids, pos, axis=-1)
    return best, jnp.where(jnp.isinf(best), -1, out_ids)


@partial(jax.jit, static_argnames=("k",))
def recall_at_k(found_ids: Array, true_ids: Array, k: int) -> Array:
    """|found ∩ true| / k for one query. ids may contain -1 padding."""
    f = found_ids[..., :k]
    t = true_ids[..., :k]
    eq = (f[..., :, None] == t[..., None, :]) & (f[..., :, None] >= 0)
    return eq.any(-1).sum(-1).astype(jnp.float32) / k
