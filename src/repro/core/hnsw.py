"""HNSW-family navigable graph: construction + container.

Construction here is the *deterministic, vectorizable* variant described in
DESIGN.md §8(2): geometric level assignment exactly as HNSW, per-level exact
kNN candidate generation, and the standard HNSW select-neighbors *diversity
heuristic* for pruning, plus reverse-edge augmentation.  This produces the
same navigable-small-world topology class the paper's pgvector index has
(M connections per node per layer, 2M at the base layer), while being
buildable in seconds on CPU.  An incremental reference builder
(`build_incremental`) with classic insert semantics is kept for small-N
validation tests.

The graph is stored the way pgvector stores it (paper §3.1): a padded
neighbor table per level — the TPU analogue of index pages.  Fetching row i
of `neighbors[l]` is one "index page access".
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import VectorStore


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class HNSWGraph:
    """Padded neighbor tables. neighbors: (L, N, 2M) int32, -1 padded.

    Level 0 may use all 2M slots (HNSW spec); levels >=1 use at most M.
    """

    neighbors: jax.Array
    node_level: jax.Array  # (N,)
    entry_point: jax.Array  # ()
    m: int = dataclasses.field(metadata=dict(static=True), default=16)

    @property
    def num_levels(self) -> int:
        return self.neighbors.shape[0]

    @property
    def n(self) -> int:
        return self.neighbors.shape[1]


# ---------------------------------------------------------------------------
# Vectorized construction
# ---------------------------------------------------------------------------

def _pairwise_dists(x: np.ndarray, y: np.ndarray, metric: str) -> np.ndarray:
    if metric == "ip":
        return -x @ y.T
    if metric == "cos":
        xn = x / (np.linalg.norm(x, axis=1, keepdims=True) + 1e-12)
        yn = y / (np.linalg.norm(y, axis=1, keepdims=True) + 1e-12)
        return 1.0 - xn @ yn.T
    d = (x * x).sum(1)[:, None] + (y * y).sum(1)[None, :] - 2.0 * (x @ y.T)
    return np.maximum(d, 0.0)


def _knn_among(vectors: np.ndarray, metric: str, k: int,
               block: int = 2048) -> tuple[np.ndarray, np.ndarray]:
    """Exact kNN of each row among all rows (self excluded)."""
    n = vectors.shape[0]
    k = min(k, n - 1)
    ids = np.empty((n, k), np.int64)
    dst = np.empty((n, k), np.float32)
    for s in range(0, n, block):
        e = min(s + block, n)
        d = _pairwise_dists(vectors[s:e], vectors, metric)
        d[np.arange(e - s), np.arange(s, e)] = np.inf  # drop self
        part = np.argpartition(d, k - 1, axis=1)[:, :k]
        pd = np.take_along_axis(d, part, axis=1)
        order = np.argsort(pd, axis=1)
        ids[s:e] = np.take_along_axis(part, order, axis=1)
        dst[s:e] = np.take_along_axis(pd, order, axis=1)
    return ids, dst


def _rows_dist(vectors: np.ndarray, ids: np.ndarray, metric: str) -> np.ndarray:
    """Distance from row i to vectors[ids[i, j]] — (n, k)."""
    x = vectors[:, None, :]
    y = vectors[ids]
    if metric == "ip":
        return -np.einsum("nod,nkd->nk", x, y)[:, :]
    if metric == "cos":
        xn = x / (np.linalg.norm(x, axis=2, keepdims=True) + 1e-12)
        yn = y / (np.linalg.norm(y, axis=2, keepdims=True) + 1e-12)
        return 1.0 - np.einsum("nod,nkd->nk", xn, yn)
    diff = y - x
    return np.einsum("nkd,nkd->nk", diff, diff)


def _repair_connectivity(level_nbrs: np.ndarray, vectors: np.ndarray,
                         metric: str, max_iters: int = 64) -> None:
    """Ensure the base layer is a single weakly-connected component.

    Real HNSW graphs are connected by construction; batch construction can
    leave rare islands.  Repair: link each minor component to its nearest
    node in the major component (bidirectional, overwriting the last slot
    if full).  In-place on level_nbrs.
    """
    n = level_nbrs.shape[0]
    for _ in range(max_iters):
        comp = _components(level_nbrs)
        ids, counts = np.unique(comp, return_counts=True)
        if len(ids) == 1:
            return
        major = ids[np.argmax(counts)]
        minor = ids[ids != major][np.argmin(counts[ids != major])]
        a_ids = np.where(comp == minor)[0]
        b_ids = np.where(comp == major)[0]
        # nearest cross pair (blocked if large)
        sub = b_ids if len(b_ids) <= 20000 else \
            b_ids[np.random.RandomState(0).choice(len(b_ids), 20000, False)]
        d = _pairwise_dists(vectors[a_ids], vectors[sub], metric)
        ai, bi = np.unravel_index(np.argmin(d), d.shape)
        a, b = int(a_ids[ai]), int(sub[bi])
        for u, v in ((a, b), (b, a)):
            row = level_nbrs[u]
            free = np.where(row < 0)[0]
            row[free[0] if len(free) else len(row) - 1] = v


def _components(level_nbrs: np.ndarray) -> np.ndarray:
    """Weakly-connected components via union-find over the edge list."""
    n = level_nbrs.shape[0]
    parent = np.arange(n)

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    src = np.repeat(np.arange(n), level_nbrs.shape[1])
    dst = level_nbrs.reshape(-1)
    ok = dst >= 0
    for u, v in zip(src[ok], dst[ok]):
        ru, rv = find(u), find(v)
        if ru != rv:
            parent[ru] = rv
    return np.array([find(i) for i in range(n)])


def _diversity_prune(vectors: np.ndarray, cand_ids: np.ndarray,
                     cand_d: np.ndarray, m: int, metric: str,
                     block: int = 4096) -> np.ndarray:
    """HNSW select-neighbors heuristic, vectorized over nodes.

    Keep candidate c (in increasing-distance order) iff it is closer to the
    node than to every already-kept neighbor.  Returns (n, m) ids, -1 padded.
    """
    n, kc = cand_ids.shape
    out = np.full((n, m), -1, np.int64)
    for s in range(0, n, block):
        e = min(s + block, n)
        cids = cand_ids[s:e]                       # (b, kc)
        cvec = vectors[cids]                       # (b, kc, d)
        # pairwise distances between candidates of the same node: (b, kc, kc)
        if metric == "ip":
            cc = -np.einsum("bid,bjd->bij", cvec, cvec)
        elif metric == "cos":
            cn = cvec / (np.linalg.norm(cvec, axis=2, keepdims=True) + 1e-12)
            cc = 1.0 - np.einsum("bid,bjd->bij", cn, cn)
        else:
            sq = (cvec * cvec).sum(2)
            cc = sq[:, :, None] + sq[:, None, :] - 2.0 * np.einsum(
                "bid,bjd->bij", cvec, cvec)
        kept = np.zeros((e - s, kc), bool)
        kept_cnt = np.zeros(e - s, np.int64)
        for j in range(kc):
            d_to_node = cand_d[s:e, j]
            # distance from candidate j to every kept candidate
            d_to_kept = np.where(kept, cc[:, j, :], np.inf)
            ok = (d_to_node < d_to_kept.min(axis=1)) & (kept_cnt < m)
            kept[:, j] = ok
            kept_cnt += ok
        for b in range(e - s):
            sel = list(cids[b, kept[b]][:m])
            if len(sel) < m:
                # keepPrunedConnections (standard HNSW): fill remaining
                # slots with the closest pruned candidates.
                for c in cids[b, ~kept[b]]:
                    if len(sel) >= m:
                        break
                    if c not in sel:
                        sel.append(c)
            out[s + b, : len(sel)] = sel
    return out


def build_graph(store: VectorStore, m: int = 16, ef_construction: int = 64,
                seed: int = 0, max_level: int | None = None) -> HNSWGraph:
    vectors = np.asarray(store.vectors)
    n = vectors.shape[0]
    rng = np.random.RandomState(seed)
    ml = 1.0 / np.log(max(m, 2))
    levels = np.minimum(
        np.floor(-np.log(rng.uniform(1e-12, 1.0, n)) * ml).astype(np.int64),
        12)
    if max_level is not None:
        levels = np.minimum(levels, max_level)
    top = int(levels.max())
    entry = int(np.argmax(levels))
    mmax0 = 2 * m
    nbrs = np.full((top + 1, n, mmax0), -1, np.int64)

    for lvl in range(top + 1):
        members = np.where(levels >= lvl)[0]
        if len(members) <= 1:
            continue
        mv = vectors[members]
        m_l = mmax0 if lvl == 0 else m
        kc = min(max(ef_construction, m_l + 8), len(members) - 1)
        cand_local, cand_d = _knn_among(mv, store.metric, kc)
        # Long-range candidates (NSW semantics): real HNSW's insertion search
        # exposes far nodes to the pruning heuristic, which keeps a few long
        # edges for navigability.  We reproduce that by appending random
        # candidates before pruning.
        n_m = len(members)
        n_rand = min(8, n_m - 1)
        if n_rand > 0:
            rnd = rng.randint(0, n_m, size=(n_m, n_rand)).astype(np.int64)
            rnd = np.where(rnd == np.arange(n_m)[:, None],
                           (rnd + 1) % n_m, rnd)
            rd = _rows_dist(mv, rnd, store.metric)
            cand_local = np.concatenate([cand_local, rnd], 1)
            cand_d = np.concatenate([cand_d, rd], 1)
            order = np.argsort(cand_d, axis=1, kind="stable")
            cand_local = np.take_along_axis(cand_local, order, 1)
            cand_d = np.take_along_axis(cand_d, order, 1)
        pruned_local = _diversity_prune(mv, cand_local, cand_d, m_l, store.metric)
        # map local ids back to global
        valid = pruned_local >= 0
        pruned = np.where(valid, members[np.clip(pruned_local, 0, None)], -1)
        nbrs[lvl, members, :m_l] = pruned[:, :m_l]
        # reverse-edge augmentation: fill free slots with reverse links
        _augment_reverse(nbrs[lvl], members, pruned, m_l)
        if lvl == 0:
            _repair_connectivity(nbrs[0], vectors, store.metric)

    return HNSWGraph(neighbors=jnp.asarray(nbrs, jnp.int32),
                     node_level=jnp.asarray(levels, jnp.int32),
                     entry_point=jnp.asarray(entry, jnp.int32), m=m)


def _augment_reverse(level_nbrs: np.ndarray, members: np.ndarray,
                     pruned: np.ndarray, m_l: int) -> None:
    """Add reverse edges into free (-1) slots, capped at m_l per node."""
    src = np.repeat(members, pruned.shape[1])
    dst = pruned.reshape(-1)
    ok = dst >= 0
    src, dst = src[ok], dst[ok]
    counts = (level_nbrs[:, :m_l] >= 0).sum(1)
    order = np.argsort(dst, kind="stable")
    for s, d in zip(src[order], dst[order]):
        c = counts[d]
        if c < m_l and not np.any(level_nbrs[d, :c] == s):
            level_nbrs[d, c] = s
            counts[d] += 1


# ---------------------------------------------------------------------------
# Incremental reference builder (classic HNSW inserts) — small N only.
# ---------------------------------------------------------------------------

def build_incremental(store: VectorStore, m: int = 16,
                      ef_construction: int = 64, seed: int = 0) -> HNSWGraph:
    vectors = np.asarray(store.vectors)
    n = vectors.shape[0]
    rng = np.random.RandomState(seed)
    ml = 1.0 / np.log(max(m, 2))
    levels = np.minimum(
        np.floor(-np.log(rng.uniform(1e-12, 1.0, n)) * ml).astype(np.int64), 12)
    top = int(levels.max())
    mmax0 = 2 * m
    nbrs = np.full((top + 1, n, mmax0), -1, np.int64)
    metric = store.metric

    def dist(a, b_ids):
        return _pairwise_dists(vectors[a][None], vectors[b_ids], metric)[0]

    def greedy(q, entry, lvl):
        cur, cur_d = entry, dist(q, np.array([entry]))[0]
        while True:
            nb = nbrs[lvl, cur]
            nb = nb[nb >= 0]
            if len(nb) == 0:
                return cur
            ds = dist(q, nb)
            j = int(np.argmin(ds))
            if ds[j] < cur_d:
                cur, cur_d = int(nb[j]), float(ds[j])
            else:
                return cur

    def search_layer(q, entry, lvl, ef):
        visited = {entry}
        ds0 = float(dist(q, np.array([entry]))[0])
        cand = [(ds0, entry)]
        result = [(ds0, entry)]
        while cand:
            cand.sort()
            d_c, c = cand.pop(0)
            result.sort()
            if d_c > result[min(len(result), ef) - 1][0] and len(result) >= ef:
                break
            nb = nbrs[lvl, c]
            nb = [int(x) for x in nb[nb >= 0] if int(x) not in visited]
            if not nb:
                continue
            visited.update(nb)
            ds = dist(q, np.array(nb))
            worst = result[min(len(result), ef) - 1][0]
            for dd, node in zip(ds, nb):
                if len(result) < ef or dd < worst:
                    cand.append((float(dd), node))
                    result.append((float(dd), node))
                    result.sort()
                    result = result[:ef]
                    worst = result[-1][0]
        return result

    def select(q_id, cand_pairs, m_l):
        cand_pairs = sorted(cand_pairs)
        kept: list[int] = []
        for d_c, c in cand_pairs:
            if len(kept) >= m_l:
                break
            if all(_pairwise_dists(vectors[c][None], vectors[np.array([k])],
                                   metric)[0, 0] > d_c for k in kept):
                kept.append(c)
        return kept

    entry = 0
    entry_level = int(levels[0])
    for i in range(1, n):
        lvl_i = int(levels[i])
        ep = entry
        for lvl in range(entry_level, lvl_i, -1):
            ep = greedy(i, ep, min(lvl, entry_level))
        for lvl in range(min(lvl_i, entry_level), -1, -1):
            res = search_layer(i, ep, lvl, ef_construction)
            m_l = mmax0 if lvl == 0 else m
            sel = select(i, res, m_l)
            nbrs[lvl, i, : len(sel)] = sel
            for s in sel:
                cur = nbrs[lvl, s]
                free = np.where(cur < 0)[0]
                if len(free):
                    cur[free[0]] = i
                else:
                    # re-prune neighbor's list with i included
                    cand = [(float(_pairwise_dists(vectors[s][None],
                                                   vectors[np.array([c])],
                                                   metric)[0, 0]), int(c))
                            for c in cur] + [
                        (float(_pairwise_dists(vectors[s][None],
                                               vectors[np.array([i])],
                                               metric)[0, 0]), i)]
                    sel2 = select(s, cand, m_l)
                    cur[:] = -1
                    cur[: len(sel2)] = sel2
            ep = res[0][1]
        if lvl_i > entry_level:
            entry, entry_level = i, lvl_i

    return HNSWGraph(neighbors=jnp.asarray(nbrs, jnp.int32),
                     node_level=jnp.asarray(levels, jnp.int32),
                     entry_point=jnp.asarray(entry, jnp.int32), m=m)


# ---------------------------------------------------------------------------
# Blocked (cluster-routed) construction — the >=1M-row path (DESIGN.md §13).
#
# `build_graph`'s per-level exact kNN is O(n²) per level; at the sharding
# bench's operating point (1M-5M × 768) that is days of single-core work.
# The blocked builder keeps the construction *recipe* — geometric levels,
# long-range candidates, diversity pruning, reverse augmentation,
# base-layer connectivity repair — and replaces only the candidate
# generation on large levels with cluster routing: rows route to their
# `route_expand` nearest of ~2√n sampled centroids and take exact kNN
# within the routed buckets (expected candidate work ≈ expand·n²/C).
# Small levels (< exact_threshold members) still use the exact kNN, so
# upper navigation layers are identical in kind to build_graph's.
# ---------------------------------------------------------------------------

def _knn_routed(mv: np.ndarray, metric: str, kc: int,
                rng: np.random.RandomState, route_expand: int = 3,
                num_centroids: int | None = None
                ) -> tuple[np.ndarray, np.ndarray]:
    """Approximate kNN among rows via sampled-centroid bucket routing."""
    n = mv.shape[0]
    kc = min(kc, n - 1)
    C = num_centroids or int(np.clip(2 * np.sqrt(n), 64, 4096))
    C = min(C, n)
    expand = min(route_expand, C)
    cents = mv[rng.choice(n, C, replace=False)]
    routes = np.empty((n, expand), np.int64)
    for s in range(0, n, 8192):
        e = min(s + 8192, n)
        d = _pairwise_dists(mv[s:e], cents, metric)
        routes[s:e] = np.argpartition(d, expand - 1, axis=1)[:, :expand]
    primary = routes[:, 0]
    order = np.argsort(primary, kind="stable")
    bounds = np.searchsorted(primary[order], np.arange(C + 1))
    # rows querying bucket c = rows routing to c through ANY slot
    q_order = np.argsort(routes.reshape(-1), kind="stable")
    q_rows = q_order // expand
    q_bounds = np.searchsorted(routes.reshape(-1)[q_order],
                               np.arange(C + 1))
    ids = np.full((n, kc), -1, np.int64)
    dst = np.full((n, kc), np.inf, np.float32)
    for c in range(C):
        grp = order[bounds[c]:bounds[c + 1]]
        qr = q_rows[q_bounds[c]:q_bounds[c + 1]]
        if len(grp) == 0 or len(qr) == 0:
            continue
        d = _pairwise_dists(mv[qr], mv[grp], metric)
        d[qr[:, None] == grp[None, :]] = np.inf      # drop self
        t = min(kc, len(grp))
        part = np.argpartition(d, t - 1, axis=1)[:, :t]
        pd = np.take_along_axis(d, part, axis=1).astype(np.float32)
        # merge bucket top-t into the running per-row top-kc
        cat_d = np.concatenate([dst[qr], pd], axis=1)
        cat_i = np.concatenate([ids[qr], grp[part]], axis=1)
        sel = np.argpartition(cat_d, kc - 1, axis=1)[:, :kc]
        sd = np.take_along_axis(cat_d, sel, axis=1)
        si = np.take_along_axis(cat_i, sel, axis=1)
        o = np.argsort(sd, axis=1, kind="stable")
        dst[qr] = np.take_along_axis(sd, o, axis=1)
        ids[qr] = np.take_along_axis(si, o, axis=1)
    # a row can reach the same neighbor through several buckets: mask the
    # sorted-adjacent duplicates so the pruner never keeps a repeat
    dup = np.zeros_like(ids, bool)
    srt = np.sort(ids, axis=1)
    inv = np.argsort(ids, axis=1, kind="stable")
    dup_sorted = np.concatenate(
        [np.zeros((n, 1), bool), srt[:, 1:] == srt[:, :-1]], axis=1)
    np.put_along_axis(dup, inv, dup_sorted, axis=1)
    dst[dup] = np.inf
    ids[dup] = -1
    o = np.argsort(dst, axis=1, kind="stable")
    return (np.take_along_axis(ids, o, axis=1),
            np.take_along_axis(dst, o, axis=1))


def _augment_reverse_blocked(level_nbrs: np.ndarray, members: np.ndarray,
                             pruned: np.ndarray, m_l: int) -> None:
    """Vectorized reverse-edge fill: rank edges within each destination
    group and scatter into the free slots in one shot (the per-edge
    python loop of `_augment_reverse` is the 1M-row bottleneck).  Unlike
    the exact twin it does not dedup against existing forward edges — a
    repeated adjacency id only wastes the slot (the engine's visited
    bitset dedups at traversal time)."""
    src = np.repeat(members, pruned.shape[1])
    dst = pruned.reshape(-1)
    ok = dst >= 0
    src, dst = src[ok], dst[ok]
    if len(dst) == 0:
        return
    order = np.argsort(dst, kind="stable")
    src, dst = src[order], dst[order]
    first = np.concatenate([[True], dst[1:] != dst[:-1]])
    grp_start = np.flatnonzero(first)
    rank = np.arange(len(dst)) - grp_start[np.cumsum(first) - 1]
    slot = (level_nbrs[dst, :m_l] >= 0).sum(1) + rank
    keep = slot < m_l
    level_nbrs[dst[keep], slot[keep]] = src[keep]


def _repair_connectivity_blocked(level_nbrs: np.ndarray,
                                 vectors: np.ndarray, metric: str,
                                 rng: np.random.RandomState,
                                 max_iters: int = 16) -> None:
    """scipy-csgraph twin of `_repair_connectivity`: one sparse
    connected-components pass links EVERY minor component to the major
    one per iteration (the union-find python loop is quadratic-ish in
    practice at 1M rows)."""
    import scipy.sparse as sp
    from scipy.sparse.csgraph import connected_components
    n = level_nbrs.shape[0]
    for _ in range(max_iters):
        src = np.repeat(np.arange(n), level_nbrs.shape[1])
        dstf = level_nbrs.reshape(-1)
        ok = dstf >= 0
        g = sp.coo_matrix((np.ones(int(ok.sum()), np.int8),
                           (src[ok], dstf[ok])), shape=(n, n))
        ncomp, comp = connected_components(g, directed=False)
        if ncomp == 1:
            return
        ids, counts = np.unique(comp, return_counts=True)
        major = ids[np.argmax(counts)]
        b_ids = np.flatnonzero(comp == major)
        sub = b_ids if len(b_ids) <= 20000 else \
            rng.choice(b_ids, 20000, replace=False)
        for minor in ids[ids != major]:
            a_ids = np.flatnonzero(comp == minor)
            asub = a_ids if len(a_ids) <= 4096 else \
                rng.choice(a_ids, 4096, replace=False)
            d = _pairwise_dists(vectors[asub], vectors[sub], metric)
            ai, bi = np.unravel_index(np.argmin(d), d.shape)
            a, b = int(asub[ai]), int(sub[bi])
            for u, v in ((a, b), (b, a)):
                row = level_nbrs[u]
                free = np.where(row < 0)[0]
                row[free[0] if len(free) else len(row) - 1] = v


def build_graph_blocked(store: VectorStore, m: int = 16,
                        ef_construction: int = 32, seed: int = 0,
                        max_level: int | None = None,
                        exact_threshold: int = 20_000,
                        route_expand: int = 3) -> HNSWGraph:
    """`build_graph` recipe with cluster-routed candidates on big levels.

    Levels with <= `exact_threshold` members build exactly like
    `build_graph`; larger levels (at 1M rows: levels 0 and 1) swap the
    O(n²) exact kNN for `_knn_routed` and the python-loop reverse/repair
    passes for their vectorized twins.  Same topology class, not
    bit-identical to `build_graph`.
    """
    vectors = np.asarray(store.vectors)
    n = vectors.shape[0]
    rng = np.random.RandomState(seed)
    ml = 1.0 / np.log(max(m, 2))
    levels = np.minimum(
        np.floor(-np.log(rng.uniform(1e-12, 1.0, n)) * ml).astype(np.int64),
        12)
    if max_level is not None:
        levels = np.minimum(levels, max_level)
    top = int(levels.max())
    entry = int(np.argmax(levels))
    mmax0 = 2 * m
    nbrs = np.full((top + 1, n, mmax0), -1, np.int64)

    for lvl in range(top + 1):
        members = np.where(levels >= lvl)[0]
        if len(members) <= 1:
            continue
        mv = vectors[members]
        m_l = mmax0 if lvl == 0 else m
        kc = min(max(ef_construction, m_l + 8), len(members) - 1)
        if len(members) <= exact_threshold:
            cand_local, cand_d = _knn_among(mv, store.metric, kc)
        else:
            cand_local, cand_d = _knn_routed(mv, store.metric, kc, rng,
                                             route_expand=route_expand)
        n_m = len(members)
        n_rand = min(8, n_m - 1)
        if n_rand > 0:
            rnd = rng.randint(0, n_m, size=(n_m, n_rand)).astype(np.int64)
            rnd = np.where(rnd == np.arange(n_m)[:, None],
                           (rnd + 1) % n_m, rnd)
            rd = _rows_dist(mv, rnd, store.metric)
            cand_local = np.concatenate([cand_local, rnd], 1)
            cand_d = np.concatenate([cand_d, rd], 1)
            order = np.argsort(cand_d, axis=1, kind="stable")
            cand_local = np.take_along_axis(cand_local, order, 1)
            cand_d = np.take_along_axis(cand_d, order, 1)
        pruned_local = _diversity_prune(mv, cand_local, cand_d, m_l,
                                        store.metric)
        valid = pruned_local >= 0
        pruned = np.where(valid, members[np.clip(pruned_local, 0, None)], -1)
        nbrs[lvl, members, :m_l] = pruned[:, :m_l]
        if len(members) <= exact_threshold:
            _augment_reverse(nbrs[lvl], members, pruned, m_l)
        else:
            _augment_reverse_blocked(nbrs[lvl], members, pruned, m_l)
        if lvl == 0:
            if n <= exact_threshold:
                _repair_connectivity(nbrs[0], vectors, store.metric)
            else:
                _repair_connectivity_blocked(nbrs[0], vectors,
                                             store.metric, rng)

    return HNSWGraph(neighbors=jnp.asarray(nbrs, jnp.int32),
                     node_level=jnp.asarray(levels, jnp.int32),
                     entry_point=jnp.asarray(entry, jnp.int32), m=m)


# ---------------------------------------------------------------------------
# JAG-style attribute-partitioned graphs (DESIGN.md §14).  For a hot
# predicate *family* — a concrete filter bitmap shared by many queries —
# the agnostic/filtered trade-off can be skipped entirely: build a
# dedicated subgraph over exactly the family's passing rows and traverse
# it UNFILTERED (every row passes by construction, so the per-node filter
# checks the paper measures vanish).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GraphPartition:
    """One predicate family's dedicated subgraph.

    rows: (n_f,) int64 ascending global row ids of the family's passing
    set — the local→global id map (subgraph results are `rows[local]`).
    store/graph index the *gathered* rows, so local ids are dense and the
    heap rows are physically the same vectors as the base store's (the
    storage layer charges the same heap pages; only the adjacency tier is
    family-private).
    """

    tag: str
    bitmap: np.ndarray          # (W,) uint32 packed family bitmap
    rows: np.ndarray            # (n_f,) int64 global row ids, ascending
    store: VectorStore          # gathered family rows (+ SQ8 shadow)
    graph: HNSWGraph            # subgraph over the local rows


@dataclasses.dataclass(frozen=True)
class PartitionedGraph:
    """The registered family subgraphs + the staleness guard.

    built_n: base-store row count at build time.  A store that has grown
    past it (live ingest, DESIGN.md §12) invalidates every partition —
    new rows may pass a family's predicate but are absent from its
    subgraph, so the executor must fall back to the base index until a
    rebuild re-registers the families.
    """

    partitions: tuple[GraphPartition, ...]
    built_n: int

    @property
    def tags(self) -> tuple[str, ...]:
        return tuple(p.tag for p in self.partitions)

    def match(self, bitmaps) -> np.ndarray:
        """(Q,) int32 partition index whose bitmap equals each query's
        bitmap word-for-word, or -1 (exact match only — a family
        subgraph can never serve a predicate it was not built for)."""
        bm = np.asarray(bitmaps)
        if not self.partitions:
            return np.full(bm.shape[0], -1, np.int32)
        # dedupe first: family workloads repeat the same predicate bitmap
        # across the batch, and each distinct bitmap needs exactly one
        # comparison against the family catalog
        uniq, inv = np.unique(bm, axis=0, return_inverse=True)
        fam = np.stack([p.bitmap for p in self.partitions])
        eq = (uniq[:, None, :] == fam[None, :, :]).all(-1)
        hit = eq.any(1)
        um = np.where(hit, eq.argmax(1), -1).astype(np.int32)
        return um[inv.reshape(-1)]


def build_graph_partitioned(store: VectorStore,
                            families: dict[str, np.ndarray], m: int = 16,
                            ef_construction: int = 32, seed: int = 0,
                            blocked_threshold: int = 20_000
                            ) -> PartitionedGraph:
    """Build one subgraph per predicate family (JAG tier, DESIGN.md §14).

    families maps tag -> packed (W,) uint32 bitmap over the store's rows.
    Each family's passing rows are gathered into a dense sub-store
    (carrying the SQ8 shadow rows verbatim when present, so quantized
    traversal works unchanged) and indexed with the same recipe as the
    base graph — `build_graph` below `blocked_threshold` rows, the
    cluster-routed `build_graph_blocked` above it (the PR-9 builder that
    scales past the toy grids).
    """
    from repro.core.types import unpack_bitmap
    n = store.n
    parts = []
    for i, tag in enumerate(sorted(families)):
        bm = np.asarray(families[tag], np.uint32)
        rows = np.nonzero(unpack_bitmap(bm, n))[0].astype(np.int64)
        if rows.size < 2:
            raise ValueError(f"family {tag!r} has {rows.size} passing "
                             "rows; a subgraph needs at least 2")
        sub = gather_substore(store, rows)
        build = (build_graph if rows.size <= blocked_threshold
                 else build_graph_blocked)
        g = build(sub, m=m, ef_construction=ef_construction, seed=seed + i)
        parts.append(GraphPartition(tag=tag, bitmap=bm, rows=rows,
                                    store=sub, graph=g))
    return PartitionedGraph(partitions=tuple(parts), built_n=n)


def gather_substore(store: VectorStore, rows: np.ndarray) -> VectorStore:
    """Dense sub-store over `rows` (ascending global ids), carrying the
    SQ8 shadow rows verbatim when present so quantized traversal works
    unchanged on the subgraph."""
    sub = VectorStore.build(np.asarray(store.vectors)[rows],
                            metric=store.metric)
    if store.has_sq8:
        sub = dataclasses.replace(
            sub, q_vectors=jnp.asarray(np.asarray(store.q_vectors)[rows]),
            q_scale=store.q_scale, q_mean=store.q_mean,
            q_norms_sq=jnp.asarray(np.asarray(store.q_norms_sq)[rows]))
    return sub
