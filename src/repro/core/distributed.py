"""Distributed filtered vector search over the production mesh.

The paper's single-node PostgreSQL study scales out here (DESIGN.md §4):

  * ScaNN leaves (and their heap rows) are sharded across mesh devices.
    Each device runs the fused filtered leaf-scan on its shard, reranks its
    own candidates against its *local* full-precision rows (exact distances
    never cross devices), and contributes a local top-k.  The only
    collective is an all-gather of (devices × k) (dist, id) pairs — a few
    KB — followed by a replicated final top-k.  The collective-roofline
    term of FVS serving is therefore negligible by construction.
  * Graph search is query-parallel: queries shard over devices, the graph
    is replicated.  This is the honest TPU mapping of the paper's Table 1
    "Parallelism" row — graph traversal itself does not shard (dependent
    gathers), and the paper shows why trying is a system tax.
  * Index construction (k-means) is data-parallel: local assignment +
    psum centroid reduction (classic distributed Lloyd's).

Everything lowers under `shard_map` on an abstract mesh, so the multi-pod
dry-run can compile it for 512 devices from this CPU container.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax

from repro import compat
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.scann import (ScannIndex, _quant_pages_per_leaf,
                              build_scann)
from repro.core.types import SearchParams, SearchStats, VectorStore, \
    distance, heap_pages_per_vector, probe_bitmap, topk_smallest
from repro.kernels import ref as kref


@dataclasses.dataclass(frozen=True)
class ShardedFVS:
    """Host-side container: per-device leaf/heap shards stacked on axis 0."""

    index: ScannIndex          # leaf arrays padded to devices × per-device
    store: VectorStore         # heap rows (row ids remain global)
    mesh: Mesh
    axis: str                  # mesh axis (or flattened axes) leaves shard on


def shard_index(index: ScannIndex, store: VectorStore, mesh: Mesh,
                axis: str) -> ShardedFVS:
    """Pad leaf count to a multiple of the axis size; device d owns leaves
    [d*Lp, (d+1)*Lp). Heap stays globally addressed (rows gathered only on
    the owner — local leaves only reference local-shard rows by build)."""
    nd = mesh.shape[axis]
    L = index.num_leaves
    pad = (-L) % nd
    if pad:
        index = dataclasses.replace(
            index,
            leaf_tiles=jnp.pad(index.leaf_tiles, ((0, pad), (0, 0), (0, 0))),
            leaf_rowids=jnp.pad(index.leaf_rowids, ((0, pad), (0, 0)),
                                constant_values=-1),
            leaf_centroids=jnp.pad(index.leaf_centroids,
                                   ((0, pad), (0, 0)),
                                   constant_values=jnp.inf),
        )
    return ShardedFVS(index=index, store=store, mesh=mesh, axis=axis)


def distributed_search_raw(sharded: ShardedFVS, params: SearchParams,
                           use_pallas: bool = False,
                           heap_layout: str = "replicated",
                           with_stats: bool = False):
    """shard_map'd search over EXPLICIT array args (lowerable against
    ShapeDtypeStructs — used by launch/fvs_dryrun.py):
    fn(tiles, rowids, cents, scale, mean, pca, vectors, norms_sq,
       queries, bitmaps) -> (dists, ids).

    use_pallas=True runs the FUSED leaf-scan kernel (int8 tiles stream
    HBM→VMEM once; dequant+probe+score stay in VMEM) — §Perf FVS it2.

    heap_layout: "replicated" (default — full-precision rows on every
    device; correct for arbitrary kmeans row placement, used at test
    scale) or "leaf_ordered" (rows permuted into leaf-major order at
    build so each device's leaves reference its local heap slice —
    the production layout modeled by launch/fvs_dryrun.py).

    with_stats=True additionally returns per-query Table-6 counters as a
    third output, (Q, 7) int32 in SearchStats field order: each device
    counts its local work (leaves opened, valid/passing rows, reorder
    candidates, analytic page counters) and the counters cross the mesh
    with the SAME all-gather the (dist, id) pairs already ride — 28 more
    bytes per query, still collective-negligible."""
    mesh, axis = sharded.mesh, sharded.axis
    idx, store = sharded.index, sharded.store
    k = params.k
    nl = params.num_leaves_to_search
    metric = idx.metric
    qppl = _quant_pages_per_leaf(idx)
    ppv = heap_pages_per_vector(store.dim)

    n_total = sharded.store.n
    nd_axis = mesh.shape[axis]

    def local_search(tiles, rowids, cents, scale, mean, pca, vectors,
                     norms_sq, queries, bitmaps):
        # tiles: (Lp, C, dp) local shard. queries: (Q, d) replicated.
        if heap_layout == "leaf_ordered":
            offset = jax.lax.axis_index(axis) * (n_total // nd_axis)
        else:
            offset = 0

        def one(q, bm):
            proj, mu_p = pca[:-1], pca[-1]
            qp = q @ proj - mu_p
            cd = distance(metric, qp[None], cents,
                          jnp.sum(cents * cents, -1))
            cd = jnp.where(jnp.isfinite(cents[:, 0]), cd, jnp.inf)
            nsel = min(max(1, -(-nl // mesh.shape[axis])), cents.shape[0])
            _, leaves = topk_smallest(cd, nsel)
            if use_pallas:
                from repro.kernels.leaf_scan import leaf_scan_pallas
                scores = leaf_scan_pallas(
                    qp, tiles[leaves], rowids[leaves], scale, mean, bm,
                    metric, interpret=jax.default_backend() != "tpu")
            else:
                scores = kref.leaf_scan_ref(qp, tiles[leaves],
                                            rowids[leaves], scale, mean,
                                            bm, metric)
            r = min(k * params.reorder_factor, nsel * tiles.shape[1])
            fs, fp = topk_smallest(scores.reshape(-1), r)
            rows = rowids[leaves].reshape(-1)[fp]
            ok = jnp.isfinite(fs) & (rows >= 0)
            local_rows = rows - offset
            ok &= (local_rows >= 0) & (local_rows < vectors.shape[0])
            safe = jnp.clip(local_rows, 0, vectors.shape[0] - 1)
            exact = distance(metric, q[None], vectors[safe], norms_sq[safe])
            exact = jnp.where(ok, exact, jnp.inf)
            ld, lp = topk_smallest(exact, k)
            lids = jnp.where(jnp.isinf(ld), -1, rows[lp])
            if not with_stats:
                return ld, lids
            # local Table-6 counters (single-node ScaNN semantics per
            # shard: fc = valid rows in opened leaves, dc = passing rows
            # + centroids scored + reorder candidates, analytic pages)
            n_reorder = ok.sum().astype(jnp.int32)
            fc = (rowids[leaves] >= 0).sum().astype(jnp.int32)
            n_pass = jnp.isfinite(scores).sum().astype(jnp.int32)
            cent_fin = jnp.isfinite(cents[:, 0]).sum().astype(jnp.int32)
            st = jnp.stack([
                n_pass + cent_fin + n_reorder,            # distance_comps
                fc,                                       # filter_checks
                jnp.int32(nsel),                          # hops (leaves)
                jnp.int32(nsel * qppl),                   # index pages
                n_reorder * ppv,                          # heap pages
                jnp.int32(0),                             # tmap_lookups
                n_reorder])                               # reorder_rows
            return ld, lids, st

        if with_stats:
            ld, lids, lst = jax.vmap(one)(queries, bitmaps)
        else:
            ld, lids = jax.vmap(one)(queries, bitmaps)   # (Q, k) local
        gd = jax.lax.all_gather(ld, axis, axis=1)        # (Q, nd, k)
        gi = jax.lax.all_gather(lids, axis, axis=1)
        q_ = gd.shape[0]
        gd = gd.reshape(q_, -1)
        gi = gi.reshape(q_, -1)
        fd, fpos = jax.vmap(lambda d_: topk_smallest(d_, k))(gd)
        fids = jnp.take_along_axis(gi, fpos, axis=1)
        fids = jnp.where(jnp.isinf(fd), -1, fids)
        if not with_stats:
            return fd, fids
        gst = jax.lax.all_gather(lst, axis, axis=1)      # (Q, nd, 7)
        return fd, fids, gst.sum(axis=1)

    pspec = P(axis)
    rep = P()
    vspec = P(axis) if heap_layout == "leaf_ordered" else rep
    return compat.shard_map(
        local_search, mesh=mesh,
        in_specs=(pspec, pspec, pspec, rep, rep, rep, vspec, vspec,
                  rep, rep),
        out_specs=(rep, rep, rep) if with_stats else (rep, rep),
        check_vma=False)


def distributed_search_fn(sharded: ShardedFVS, params: SearchParams,
                          use_pallas: bool = False,
                          heap_layout: str = "replicated",
                          with_stats: bool = False):
    """Jittable distributed filtered-search step bound to a concrete store:
    (queries (Q, d), bitmaps (Q, W)) -> (dists (Q, k), ids[, stats])."""
    fn = distributed_search_raw(sharded, params, use_pallas=use_pallas,
                                heap_layout=heap_layout,
                                with_stats=with_stats)
    idx, store = sharded.index, sharded.store

    def search(queries, bitmaps):
        return fn(idx.leaf_tiles, idx.leaf_rowids, idx.leaf_centroids,
                  idx.scale, idx.mean, idx.pca, store.vectors,
                  store.norms_sq, queries, bitmaps)

    return jax.jit(search)


# ---------------------------------------------------------------------------
# Distributed k-means index build (data-parallel Lloyd's with psum)
# ---------------------------------------------------------------------------

def distributed_kmeans_fn(mesh: Mesh, axis: str, k: int, iters: int,
                          metric: str = "l2"):
    """Returns jittable fn: (x_shard (N, d) sharded, init_cent (k, d)) ->
    centroids.  Local assignment, psum'd centroid sums — the canonical
    distributed index build (paper Table 3 build-time scaling, scaled out).
    """

    def local(x, cent):
        def step(cent, _):
            d = (jnp.sum(x * x, 1)[:, None] + jnp.sum(cent * cent, 1)[None]
                 - 2.0 * x @ cent.T)
            a = jnp.argmin(d, 1)
            one_hot = jax.nn.one_hot(a, k, dtype=x.dtype)
            sums = jax.lax.psum(one_hot.T @ x, axis)
            cnts = jax.lax.psum(one_hot.sum(0), axis)
            newc = sums / jnp.maximum(cnts, 1.0)[:, None]
            return jnp.where((cnts > 0)[:, None], newc, cent), None

        cent, _ = jax.lax.scan(step, cent, None, length=iters)
        return cent

    fn = compat.shard_map(local, mesh=mesh, in_specs=(P(axis), P()),
                       out_specs=P(), check_vma=False)
    return jax.jit(fn)


def build_sharded_scann(store: VectorStore, mesh: Mesh, axis: str,
                        num_leaves: int, **kw) -> ShardedFVS:
    """Build on host (small scale) then shard leaves over the mesh axis.

    Leaves are assigned to devices contiguously; the heap rows referenced by
    a device's leaves live with the device (row locality by construction).
    """
    idx = build_scann(store, num_leaves=num_leaves, **kw)
    return shard_index(idx, store, mesh, axis)


class DistributedScannExecutor:
    """Executor-protocol port of the sharded ScaNN path (DESIGN.md §6).

    Consumers (serving/rag.py, launch/fvs_dryrun.py) hold an Executor and
    never touch the mesh plumbing.  Per-query SearchStats ride the
    existing all-gather as a (Q, 7) int32 block (`with_stats`), so
    table6/fig10-style accounting covers the mesh path too; pass
    `with_stats=False` to drop the counters from the collective (the
    launch dry-run compiles the raw fn without them).
    """

    name = "scann_distributed"

    def __init__(self, sharded: ShardedFVS, use_pallas: bool = False,
                 heap_layout: str = "replicated", with_stats: bool = True):
        self.sharded = sharded
        self.store = sharded.store
        self.use_pallas = use_pallas
        self.heap_layout = heap_layout
        self.with_stats = with_stats
        self._fns: dict = {}      # params -> jitted bound search fn

    def plan(self, queries, bitmaps, params: SearchParams):
        from repro.core.executor import SearchPlan
        if params.strategy != "scann":
            params = dataclasses.replace(params, strategy="scann")
        return SearchPlan("scann", params, queries, bitmaps)

    def execute(self, plan):
        from repro.core.types import SearchResult
        fn = self._fns.get(plan.params)
        if fn is None:
            fn = self._fns[plan.params] = distributed_search_fn(
                self.sharded, plan.params, use_pallas=self.use_pallas,
                heap_layout=self.heap_layout, with_stats=self.with_stats)
        out = fn(plan.queries, plan.bitmaps)
        stats = None
        if self.with_stats:
            d, ids, st = out
            stats = SearchStats(*(st[:, i] for i in range(7)))
        else:
            d, ids = out
        return SearchResult(dists=d, ids=ids, stats=stats, strategy="scann",
                            plan=plan)

    def search(self, queries, bitmaps, params: SearchParams):
        return self.execute(self.plan(queries, bitmaps, params))

    def raw_search_fn(self, params: SearchParams, use_pallas=None,
                      heap_layout=None):
        """The shard_map'd explicit-args fn (lowerable against
        ShapeDtypeStructs) — what launch/fvs_dryrun.py compiles."""
        return distributed_search_raw(
            self.sharded, params,
            use_pallas=self.use_pallas if use_pallas is None else use_pallas,
            heap_layout=heap_layout or self.heap_layout)
