"""Distributed filtered vector search over the production mesh.

The paper's single-node PostgreSQL study scales out here (DESIGN.md §4):

  * ScaNN leaves (and their heap rows) are sharded across mesh devices.
    Each device runs the fused filtered leaf-scan on its shard, reranks its
    own candidates against its *local* full-precision rows (exact distances
    never cross devices), and contributes a local top-k.  The only
    collective is an all-gather of (devices × k) (dist, id) pairs — a few
    KB — followed by a replicated final top-k.  The collective-roofline
    term of FVS serving is therefore negligible by construction.
  * Graph search is query-parallel: queries shard over devices, the graph
    is replicated.  This is the honest TPU mapping of the paper's Table 1
    "Parallelism" row — graph traversal itself does not shard (dependent
    gathers), and the paper shows why trying is a system tax.
  * Index construction (k-means) is data-parallel: local assignment +
    psum centroid reduction (classic distributed Lloyd's).

Everything lowers under `shard_map` on an abstract mesh, so the multi-pod
dry-run can compile it for 512 devices from this CPU container.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax

from repro import compat
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.scann import (ScannIndex, _quant_pages_per_leaf,
                              build_scann)
from repro.core.types import SearchParams, SearchStats, VectorStore, \
    distance, heap_pages_per_vector, probe_bitmap, topk_smallest
from repro.kernels import ref as kref


@dataclasses.dataclass(frozen=True)
class ShardedFVS:
    """Host-side container: per-device leaf/heap shards stacked on axis 0."""

    index: ScannIndex          # leaf arrays padded to devices × per-device
    store: VectorStore         # heap rows (row ids remain global)
    mesh: Mesh
    axis: str                  # mesh axis (or flattened axes) leaves shard on


def shard_index(index: ScannIndex, store: VectorStore, mesh: Mesh,
                axis: str) -> ShardedFVS:
    """Pad leaf count to a multiple of the axis size; device d owns leaves
    [d*Lp, (d+1)*Lp). Heap stays globally addressed (rows gathered only on
    the owner — local leaves only reference local-shard rows by build)."""
    nd = mesh.shape[axis]
    L = index.num_leaves
    pad = (-L) % nd
    if pad:
        index = dataclasses.replace(
            index,
            leaf_tiles=jnp.pad(index.leaf_tiles, ((0, pad), (0, 0), (0, 0))),
            leaf_rowids=jnp.pad(index.leaf_rowids, ((0, pad), (0, 0)),
                                constant_values=-1),
            leaf_centroids=jnp.pad(index.leaf_centroids,
                                   ((0, pad), (0, 0)),
                                   constant_values=jnp.inf),
        )
    return ShardedFVS(index=index, store=store, mesh=mesh, axis=axis)


def distributed_search_raw(sharded: ShardedFVS, params: SearchParams,
                           use_pallas: bool = False,
                           heap_layout: str = "replicated",
                           with_stats: bool = False):
    """shard_map'd search over EXPLICIT array args (lowerable against
    ShapeDtypeStructs — used by launch/fvs_dryrun.py):
    fn(tiles, rowids, cents, scale, mean, pca, vectors, norms_sq,
       queries, bitmaps) -> (dists, ids).

    use_pallas=True runs the FUSED leaf-scan kernel (int8 tiles stream
    HBM→VMEM once; dequant+probe+score stay in VMEM) — §Perf FVS it2.

    heap_layout: "replicated" (default — full-precision rows on every
    device; correct for arbitrary kmeans row placement, used at test
    scale) or "leaf_ordered" (rows permuted into leaf-major order at
    build so each device's leaves reference its local heap slice —
    the production layout modeled by launch/fvs_dryrun.py).

    with_stats=True additionally returns per-query Table-6 counters as a
    third output, (Q, 7) int32 in SearchStats field order: each device
    counts its local work (leaves opened, valid/passing rows, reorder
    candidates, analytic page counters) and the counters cross the mesh
    with the SAME all-gather the (dist, id) pairs already ride — 28 more
    bytes per query, still collective-negligible."""
    mesh, axis = sharded.mesh, sharded.axis
    idx, store = sharded.index, sharded.store
    k = params.k
    nl = params.num_leaves_to_search
    metric = idx.metric
    qppl = _quant_pages_per_leaf(idx)
    ppv = heap_pages_per_vector(store.dim)

    n_total = sharded.store.n
    nd_axis = mesh.shape[axis]

    def local_search(tiles, rowids, cents, scale, mean, pca, vectors,
                     norms_sq, queries, bitmaps):
        # tiles: (Lp, C, dp) local shard. queries: (Q, d) replicated.
        if heap_layout == "leaf_ordered":
            offset = jax.lax.axis_index(axis) * (n_total // nd_axis)
        else:
            offset = 0

        def one(q, bm):
            proj, mu_p = pca[:-1], pca[-1]
            qp = q @ proj - mu_p
            cd = distance(metric, qp[None], cents,
                          jnp.sum(cents * cents, -1))
            cd = jnp.where(jnp.isfinite(cents[:, 0]), cd, jnp.inf)
            nsel = min(max(1, -(-nl // mesh.shape[axis])), cents.shape[0])
            _, leaves = topk_smallest(cd, nsel)
            if use_pallas:
                from repro.kernels.leaf_scan import leaf_scan_pallas
                scores = leaf_scan_pallas(
                    qp, tiles[leaves], rowids[leaves], scale, mean, bm,
                    metric, interpret=jax.default_backend() != "tpu")
            else:
                scores = kref.leaf_scan_ref(qp, tiles[leaves],
                                            rowids[leaves], scale, mean,
                                            bm, metric)
            r = min(k * params.reorder_factor, nsel * tiles.shape[1])
            fs, fp = topk_smallest(scores.reshape(-1), r)
            rows = rowids[leaves].reshape(-1)[fp]
            ok = jnp.isfinite(fs) & (rows >= 0)
            local_rows = rows - offset
            ok &= (local_rows >= 0) & (local_rows < vectors.shape[0])
            safe = jnp.clip(local_rows, 0, vectors.shape[0] - 1)
            exact = distance(metric, q[None], vectors[safe], norms_sq[safe])
            exact = jnp.where(ok, exact, jnp.inf)
            ld, lp = topk_smallest(exact, k)
            lids = jnp.where(jnp.isinf(ld), -1, rows[lp])
            if not with_stats:
                return ld, lids
            # local Table-6 counters (single-node ScaNN semantics per
            # shard: fc = valid rows in opened leaves, dc = passing rows
            # + centroids scored + reorder candidates, analytic pages)
            n_reorder = ok.sum().astype(jnp.int32)
            fc = (rowids[leaves] >= 0).sum().astype(jnp.int32)
            n_pass = jnp.isfinite(scores).sum().astype(jnp.int32)
            cent_fin = jnp.isfinite(cents[:, 0]).sum().astype(jnp.int32)
            st = jnp.stack([
                n_pass + cent_fin + n_reorder,            # distance_comps
                fc,                                       # filter_checks
                jnp.int32(nsel),                          # hops (leaves)
                jnp.int32(nsel * qppl),                   # index pages
                n_reorder * ppv,                          # heap pages
                jnp.int32(0),                             # tmap_lookups
                n_reorder])                               # reorder_rows
            return ld, lids, st

        if with_stats:
            ld, lids, lst = jax.vmap(one)(queries, bitmaps)
        else:
            ld, lids = jax.vmap(one)(queries, bitmaps)   # (Q, k) local
        gd = jax.lax.all_gather(ld, axis, axis=1)        # (Q, nd, k)
        gi = jax.lax.all_gather(lids, axis, axis=1)
        q_ = gd.shape[0]
        gd = gd.reshape(q_, -1)
        gi = gi.reshape(q_, -1)
        fd, fpos = jax.vmap(lambda d_: topk_smallest(d_, k))(gd)
        fids = jnp.take_along_axis(gi, fpos, axis=1)
        fids = jnp.where(jnp.isinf(fd), -1, fids)
        if not with_stats:
            return fd, fids
        gst = jax.lax.all_gather(lst, axis, axis=1)      # (Q, nd, 7)
        return fd, fids, gst.sum(axis=1)

    pspec = P(axis)
    rep = P()
    vspec = P(axis) if heap_layout == "leaf_ordered" else rep
    return compat.shard_map(
        local_search, mesh=mesh,
        in_specs=(pspec, pspec, pspec, rep, rep, rep, vspec, vspec,
                  rep, rep),
        out_specs=(rep, rep, rep) if with_stats else (rep, rep),
        check_vma=False)


def distributed_search_fn(sharded: ShardedFVS, params: SearchParams,
                          use_pallas: bool = False,
                          heap_layout: str = "replicated",
                          with_stats: bool = False):
    """Jittable distributed filtered-search step bound to a concrete store:
    (queries (Q, d), bitmaps (Q, W)) -> (dists (Q, k), ids[, stats])."""
    fn = distributed_search_raw(sharded, params, use_pallas=use_pallas,
                                heap_layout=heap_layout,
                                with_stats=with_stats)
    idx, store = sharded.index, sharded.store

    def search(queries, bitmaps):
        return fn(idx.leaf_tiles, idx.leaf_rowids, idx.leaf_centroids,
                  idx.scale, idx.mean, idx.pca, store.vectors,
                  store.norms_sq, queries, bitmaps)

    return jax.jit(search)


# ---------------------------------------------------------------------------
# Distributed k-means index build (data-parallel Lloyd's with psum)
# ---------------------------------------------------------------------------

def distributed_kmeans_fn(mesh: Mesh, axis: str, k: int, iters: int,
                          metric: str = "l2"):
    """Returns jittable fn: (x_shard (N, d) sharded, init_cent (k, d)) ->
    centroids.  Local assignment, psum'd centroid sums — the canonical
    distributed index build (paper Table 3 build-time scaling, scaled out).
    """

    def local(x, cent):
        def step(cent, _):
            d = (jnp.sum(x * x, 1)[:, None] + jnp.sum(cent * cent, 1)[None]
                 - 2.0 * x @ cent.T)
            a = jnp.argmin(d, 1)
            one_hot = jax.nn.one_hot(a, k, dtype=x.dtype)
            sums = jax.lax.psum(one_hot.T @ x, axis)
            cnts = jax.lax.psum(one_hot.sum(0), axis)
            newc = sums / jnp.maximum(cnts, 1.0)[:, None]
            return jnp.where((cnts > 0)[:, None], newc, cent), None

        cent, _ = jax.lax.scan(step, cent, None, length=iters)
        return cent

    fn = compat.shard_map(local, mesh=mesh, in_specs=(P(axis), P()),
                       out_specs=P(), check_vma=False)
    return jax.jit(fn)


def build_sharded_scann(store: VectorStore, mesh: Mesh, axis: str,
                        num_leaves: int, **kw) -> ShardedFVS:
    """Build on host (small scale) then shard leaves over the mesh axis.

    Leaves are assigned to devices contiguously; the heap rows referenced by
    a device's leaves live with the device (row locality by construction).
    """
    idx = build_scann(store, num_leaves=num_leaves, **kw)
    return shard_index(idx, store, mesh, axis)


class DistributedScannExecutor:
    """Executor-protocol port of the sharded ScaNN path (DESIGN.md §6).

    Consumers (serving/rag.py, launch/fvs_dryrun.py) hold an Executor and
    never touch the mesh plumbing.  Per-query SearchStats ride the
    existing all-gather as a (Q, 7) int32 block (`with_stats`), so
    table6/fig10-style accounting covers the mesh path too; pass
    `with_stats=False` to drop the counters from the collective (the
    launch dry-run compiles the raw fn without them).
    """

    name = "scann_distributed"

    def __init__(self, sharded: ShardedFVS, use_pallas: bool = False,
                 heap_layout: str = "replicated", with_stats: bool = True):
        self.sharded = sharded
        self.store = sharded.store
        self.use_pallas = use_pallas
        self.heap_layout = heap_layout
        self.with_stats = with_stats
        self._fns: dict = {}      # params -> jitted bound search fn

    def plan(self, queries, bitmaps, params: SearchParams):
        from repro.core.executor import SearchPlan
        if params.strategy != "scann":
            params = dataclasses.replace(params, strategy="scann")
        return SearchPlan("scann", params, queries, bitmaps)

    def execute(self, plan):
        from repro.core.types import SearchResult
        fn = self._fns.get(plan.params)
        if fn is None:
            fn = self._fns[plan.params] = distributed_search_fn(
                self.sharded, plan.params, use_pallas=self.use_pallas,
                heap_layout=self.heap_layout, with_stats=self.with_stats)
        out = fn(plan.queries, plan.bitmaps)
        stats = None
        if self.with_stats:
            d, ids, st = out
            stats = SearchStats(*(st[:, i] for i in range(7)))
        else:
            d, ids = out
        return SearchResult(dists=d, ids=ids, stats=stats, strategy="scann",
                            plan=plan)

    def search(self, queries, bitmaps, params: SearchParams):
        return self.execute(self.plan(queries, bitmaps, params))

    def raw_search_fn(self, params: SearchParams, use_pallas=None,
                      heap_layout=None):
        """The shard_map'd explicit-args fn (lowerable against
        ShapeDtypeStructs) — what launch/fvs_dryrun.py compiles."""
        return distributed_search_raw(
            self.sharded, params,
            use_pallas=self.use_pallas if use_pallas is None else use_pallas,
            heap_layout=heap_layout or self.heap_layout)


# ===========================================================================
# Mesh-sharded graph + storage tiers (DESIGN.md §13)
# ===========================================================================
# The ScaNN tier above shards *leaves*; the tier below shards the graph's
# row-indexed state — base/upper adjacency, the f32 heap, the SQ8 shadow
# heap, and (host-side) one BufferPool per shard.  Traversal runs as
# per-shard frontier supersteps over the `ShardGraph`/`ShardStore` views
# (core/shardtypes.py), whose gather helpers inside core/graph_search.py
# resolve every global-row read by ownership + a pmin/pmax collective:
#
#   * beam_exchange_interval == 1 (lockstep): per-query lane state is
#     replicated on every shard and only the storage reads shard.  The
#     owner-masked reductions SELECT the owner's untouched payload, so the
#     final ids/dists/counters are bit-identical to the single-device
#     frontier engine for ANY shard count — by construction, not by luck.
#   * beam_exchange_interval == E > 1 (drift): each shard traverses its
#     own induced subgraph (remote adjacency masked -1, remote distances
#     +inf) for E supersteps, then an all-gather top-k beam exchange
#     re-seeds every shard's beam from the global top-ef.  Cheaper
#     collectives (ef ids+dists every E hops instead of every candidate
#     every hop), approximate results.
#
# The same shard body runs under jax.vmap(..., axis_name=...) — the
# single-device emulation this CPU container uses — and under shard_map on
# a real mesh (`sharded_graph_search_fn`); `ShardStore.offset` is derived
# from lax.axis_index at trace time so both bind identically.

from repro.core import costmodel
from repro.core import graph_search as gs
from repro.core.hnsw import HNSWGraph
from repro.core.shardtypes import SHARD_AXIS, ShardGraph, ShardStore
from repro.storage.bufferpool import BufferPoolState
from repro.storage.engine import StorageStats, merge_storage_stats


def shard_graph_tiers(graph: HNSWGraph, store: VectorStore,
                      num_shards: int, axis: str = SHARD_AXIS,
                      f32: bool = True):
    """Partition the adjacency + heap tiers by contiguous row range.

    Returns (ShardGraph, ShardStore) view pytrees whose data leaves carry
    a leading (num_shards,) stack axis — shard s owns global rows
    [s*rps, (s+1)*rps) with rps = ceil(n / num_shards), the last block
    zero/-1 padded.  Adjacency values stay GLOBAL row ids.  `f32=False`
    drops the full-precision tier from the views (SQ8-only giant-scale
    mode; the executor validates quant/rerank compatibility).
    """
    n = graph.n
    if store.n != n:
        raise ValueError(f"graph ({n} rows) and store ({store.n} rows) "
                         "disagree")
    S = int(num_shards)
    if S < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    rps = -(-n // S)

    def block(a, fill):
        a = np.asarray(a)
        pad = S * rps - a.shape[0]
        if pad:
            a = np.concatenate(
                [a, np.full((pad,) + a.shape[1:], fill, a.dtype)])
        return jnp.asarray(a.reshape(S, rps, *a.shape[1:]))

    nb = np.asarray(graph.neighbors)                    # (L, n, deg)
    L, _, deg = nb.shape
    pad = S * rps - n
    if pad:
        nb = np.concatenate([nb, np.full((L, pad, deg), -1, nb.dtype)],
                            axis=1)
    nb = np.ascontiguousarray(
        nb.reshape(L, S, rps, deg).transpose(1, 0, 2, 3))

    # Per-shard drift entry: the shard's own highest-level node (global
    # id), -1 on an all-padding shard.  For S=1 this IS the global entry.
    levels = np.asarray(graph.node_level)
    local_entry = np.full((S,), -1, np.int32)
    for s in range(S):
        lo, hi = s * rps, min((s + 1) * rps, n)
        if lo < hi:
            local_entry[s] = lo + int(np.argmax(levels[lo:hi]))

    gviews = ShardGraph(
        neighbors=jnp.asarray(nb),
        entry_point=jnp.full((S,), int(graph.entry_point), jnp.int32),
        local_entry=jnp.asarray(local_entry),
        m=graph.m, axis=axis, n_total=n, collective=True)

    has_f32 = f32 and store.vectors is not None
    has_q = store.q_vectors is not None
    sviews = ShardStore(
        vectors=block(store.vectors, 0) if has_f32 else None,
        norms_sq=block(store.norms_sq, 0) if has_f32 else None,
        metric=store.metric, axis=axis, n_total=n, collective=True,
        q_vectors=block(store.q_vectors, 0) if has_q else None,
        q_scale=(jnp.broadcast_to(jnp.asarray(store.q_scale),
                                  (S,) + np.shape(store.q_scale))
                 if has_q else None),
        q_mean=(jnp.broadcast_to(jnp.asarray(store.q_mean),
                                 (S,) + np.shape(store.q_mean))
                if has_q else None),
        q_norms_sq=block(store.q_norms_sq, 0) if has_q else None)
    return gviews, sviews


def _graph_shard_body(gv: ShardGraph, sv: ShardStore, queries, bitmaps,
                      params: SearchParams, use_pallas: bool,
                      collect_trace: bool):
    """One shard's program — bound under vmap-with-axis-name or shard_map."""
    E = params.beam_exchange_interval
    if E <= 1:
        # Lockstep: the full frontier engine over collective views.  Lane
        # state (beams, pools, visited bitsets, counters) is replicated,
        # so the carried stats are already the single-device counters —
        # no psum (it would multiply the replicated counts by S).
        return gs._frontier_search_batch(gv, sv, queries, bitmaps, params,
                                         use_pallas, collect_trace)
    # Drift: induced-subgraph traversal between beam exchanges.  Each
    # shard zooms in from its own local_entry, runs E supersteps on
    # masked (non-collective) views, then the all-gather top-ef exchange
    # re-seeds W.  The outer cond all-gathers `done` so every shard runs
    # the same trip count and the in-body collectives stay aligned.
    lg = dataclasses.replace(gv, collective=False,
                             entry_point=gv.local_entry)
    ls = dataclasses.replace(sv, collective=False)
    st = gs.frontier_init(lg, ls, queries, bitmaps, params)
    rounds = -(-params.max_hops // E)

    def cond(c):
        t, s = c
        return (t < rounds) & ~jnp.all(jax.lax.all_gather(s.done, gv.axis))

    def body(c):
        t, s = c
        s = gs.step_supersteps(lg, ls, s, params, E, use_pallas=use_pallas)
        s = gs.beam_exchange(ls, s, params, gv.axis)
        return t + 1, s

    _, st = jax.lax.while_loop(cond, body,
                               (jnp.asarray(0, jnp.int32), st))
    # Finalize on COLLECTIVE views: the beam now holds remote rows, and
    # the exact sq8 rerank / emit must read their true payloads.  The
    # finalize delta (rerank counters) is computed replicated, so add it
    # once to the psum'd (per-shard, genuinely different) traversal work.
    d, ids, fstats, _ = gs.frontier_finalize(gv, sv, st, params)
    delta = jax.tree.map(lambda a, b: a - b, fstats, st.stats)
    total = jax.tree.map(lambda a: jax.lax.psum(a, gv.axis), st.stats)
    stats = jax.tree.map(lambda a, b: a + b, total, delta)
    return d, ids, stats


def _squeeze0(tree):
    return jax.tree.map(lambda a: a[0], tree)


@partial(jax.jit, static_argnames=("params", "use_pallas", "collect_trace"))
def _sharded_search(gviews, sviews, queries, bitmaps, params, use_pallas,
                    collect_trace):
    out = jax.vmap(
        lambda gv, sv: _graph_shard_body(gv, sv, queries, bitmaps, params,
                                         use_pallas, collect_trace),
        in_axes=(0, 0), axis_name=gviews.axis)(gviews, sviews)
    # Every output leaf is replicated across the stack axis (lockstep) or
    # already reduced to identical values (drift: all-gather/psum), so
    # shard 0's copy IS the answer.
    return _squeeze0(out)


@partial(jax.jit, static_argnames=("params", "collect_trace"))
def _sharded_init(gviews, sviews, queries, bitmaps, deadlines, params,
                  collect_trace):
    out = jax.vmap(
        lambda gv, sv: gs.frontier_init(gv, sv, queries, bitmaps, params,
                                        collect_trace=collect_trace,
                                        deadlines=deadlines),
        in_axes=(0, 0), axis_name=gviews.axis)(gviews, sviews)
    return _squeeze0(out)


@partial(jax.jit, static_argnames=("params", "width", "collect_trace"))
def _sharded_idle(gviews, sviews, params, width, collect_trace):
    out = jax.vmap(
        lambda gv, sv: gs.frontier_idle(gv, sv, params, width,
                                        collect_trace=collect_trace),
        in_axes=(0, 0), axis_name=gviews.axis)(gviews, sviews)
    return _squeeze0(out)


@partial(jax.jit, static_argnames=("params", "n_hops", "use_pallas",
                                   "dynamic_deadline"))
def _sharded_step(gviews, sviews, state, params, n_hops, use_pallas,
                  dynamic_deadline):
    out = jax.vmap(
        lambda gv, sv: gs.step_supersteps(gv, sv, state, params, n_hops,
                                          use_pallas=use_pallas,
                                          dynamic_deadline=dynamic_deadline),
        in_axes=(0, 0), axis_name=gviews.axis)(gviews, sviews)
    return _squeeze0(out)


@partial(jax.jit, static_argnames=("params",))
def _sharded_finalize(gviews, sviews, state, params):
    out = jax.vmap(
        lambda gv, sv: gs.frontier_finalize(gv, sv, state, params),
        in_axes=(0, 0), axis_name=gviews.axis)(gviews, sviews)
    return _squeeze0(out)


class ShardedStorageAccountant:
    """Per-shard BufferPool replay facade (DESIGN.md §13).

    One `StorageEngine` (with its own pool) per shard, each over the
    GLOBAL page layout — shard s's pool only ever sees pages of rows
    [lo, hi), so per-shard capacity is naturally `capacity_frac /
    num_shards` of the global budget (the caller builds the engines that
    way).  `account_graph` slices the replicated lockstep trace by row
    ownership, replays each slice through its shard's pool, and merges
    the per-shard StorageStats into the aggregate the cost model and
    benchmarks consume; `last_per_shard` keeps the unmerged parts for
    per-shard hit-rate telemetry."""

    def __init__(self, engines, n: int):
        if not engines:
            raise ValueError("need at least one per-shard engine")
        self.engines = list(engines)
        self.num_shards = len(self.engines)
        self.n = int(n)
        self.rows_per_shard = -(-self.n // self.num_shards)
        self.last_per_shard: list[StorageStats] | None = None

    # GraphExecutor-compatible layout probes (constructor validation).
    @property
    def graph(self):
        return self.engines[0].graph

    @property
    def qheap(self):
        return self.engines[0].qheap

    def reset_cold(self) -> None:
        for e in self.engines:
            e.reset_cold()

    def state(self) -> BufferPoolState:
        """Aggregate residency snapshot: capacities/used/dirty sum;
        per-segment residency averages across shards (every engine lays
        out the same global segments, and a row's pages live in exactly
        one shard's pool — the mean is the global resident fraction up to
        the per-shard page rounding)."""
        states = [e.state() for e in self.engines]
        residency = {seg: float(np.mean([s.residency.get(seg, 0.0)
                                         for s in states]))
                     for seg in states[0].residency}
        dirty_by: dict[str, int] = {}
        for s in states:
            for seg, v in s.dirty_by_segment.items():
                dirty_by[seg] = dirty_by.get(seg, 0) + v
        return BufferPoolState(
            capacity=sum(s.capacity for s in states),
            used=sum(s.used for s in states),
            residency=residency,
            dirty=sum(s.dirty for s in states),
            dirty_by_segment=dirty_by)

    def account_graph(self, heap_steps, index_steps, rerank_rows=None,
                      quant: bool = False) -> StorageStats:
        hsteps = np.asarray(heap_steps)
        isteps = np.asarray(index_steps)
        parts = []
        for s, eng in enumerate(self.engines):
            lo = s * self.rows_per_shard
            hi = min(lo + self.rows_per_shard, self.n)
            hs = np.array(hsteps, copy=True)
            is_ = np.array(isteps, copy=True)
            hs[:, :lo] = gs.TRACE_UNTOUCHED
            hs[:, hi:] = gs.TRACE_UNTOUCHED
            is_[:, :lo] = gs.TRACE_UNTOUCHED
            is_[:, hi:] = gs.TRACE_UNTOUCHED
            rr = None
            if rerank_rows is not None:
                rr = np.array(rerank_rows, copy=True)
                rr[(rr < lo) | (rr >= hi)] = -1
            parts.append(eng.account_graph(hs, is_, rerank_rows=rr,
                                           quant=quant))
        self.last_per_shard = parts
        return merge_storage_stats(parts)


def make_sharded_storage(engines, n: int) -> ShardedStorageAccountant:
    """Wrap per-shard engines (one BufferPool each, typically built with
    capacity_frac / num_shards) into the accounting facade."""
    return ShardedStorageAccountant(engines, n)


class ShardedGraphExecutor:
    """The five graph strategies over mesh-sharded tiers (DESIGN.md §13).

    Mirrors `GraphExecutor`'s full surface — plan/execute/search plus the
    stepped-frontier delegates — so benchmarks and the continuous-batching
    server consume it unchanged.  In lockstep mode the per-lane
    FrontierState is replicated on every shard, so the executor keeps ONE
    single-device-shaped state and binds it to all shards per step; ids,
    dists, and all seven counters are bit-identical to `GraphExecutor`
    for any shard count."""

    def __init__(self, graph: HNSWGraph, store: VectorStore,
                 num_shards: int, strategy: str = "sweeping",
                 use_pallas: bool = False,
                 storage: ShardedStorageAccountant | None = None,
                 graph_quant: str = "none", axis: str = SHARD_AXIS,
                 f32: bool = True):
        if strategy not in costmodel.GRAPH_STRATEGIES:
            raise ValueError(f"unknown graph strategy {strategy!r}")
        if graph_quant not in ("none", "sq8"):
            raise ValueError(f"unknown graph_quant {graph_quant!r}")
        if graph_quant == "sq8" and store.q_vectors is None:
            raise ValueError("graph_quant='sq8' needs a quantize_store'd "
                             "VectorStore (SQ8 shadow missing)")
        if not f32:
            if graph_quant != "sq8":
                raise ValueError("f32=False (no full-precision tier) "
                                 "requires graph_quant='sq8'")
        if storage is not None:
            if storage.num_shards != num_shards:
                raise ValueError(
                    f"storage facade has {storage.num_shards} shards, "
                    f"executor has {num_shards}")
            if storage.graph is None:
                raise ValueError("storage engines lack a graph adjacency "
                                 "layout; build them with graph=")
            if graph_quant == "sq8" and storage.qheap is None:
                raise ValueError("storage engines lack the qheap (SQ8 "
                                 "shadow) segment")
        self.graph = graph
        self.store = store
        self.num_shards = int(num_shards)
        self.strategy = strategy
        self.use_pallas = use_pallas
        self.storage = storage
        self.graph_quant = graph_quant
        self.axis = axis
        self._f32 = f32
        self._gv, self._sv = shard_graph_tiers(graph, store, num_shards,
                                               axis=axis, f32=f32)
        base = strategy if graph_quant == "none" \
            else f"{strategy}_{graph_quant}"
        self.name = f"sharded{self.num_shards}_{base}"

    def resolve_params(self, params: SearchParams) -> SearchParams:
        """Plan-time coercion — same contract as GraphExecutor (the
        resolved object is the jit cache key), plus the sharded-mode
        validations."""
        if params.strategy != self.strategy or \
                params.graph_quant != self.graph_quant:
            params = dataclasses.replace(params, strategy=self.strategy,
                                         graph_quant=self.graph_quant)
        if params.graph_exec_mode != "frontier":
            raise ValueError("the sharded executor runs the frontier "
                             "engine only (graph_exec_mode='frontier')")
        E = params.beam_exchange_interval
        if E < 1:
            raise ValueError(f"beam_exchange_interval must be >= 1, "
                             f"got {E}")
        if E > 1:
            if self.strategy == "iterative_scan":
                raise ValueError(
                    "drift mode (beam_exchange_interval > 1) drives the "
                    "base beam engine; iterative_scan's W is an emission "
                    "buffer, not a beam — run it lockstep "
                    "(beam_exchange_interval=1)")
            if self.storage is not None:
                raise ValueError(
                    "storage accounting needs the lockstep replicated "
                    "trace; set beam_exchange_interval=1")
        if not self._f32 and params.sq8_rerank:
            raise ValueError("no f32 tier to rerank from (f32=False); "
                             "set sq8_rerank=False")
        return params

    def _lockstep(self, params: SearchParams) -> SearchParams:
        params = self.resolve_params(params)
        if params.beam_exchange_interval > 1:
            raise ValueError("stepped serving runs lockstep only; drift "
                             "mode (beam_exchange_interval > 1) is "
                             "batch-path only")
        return params

    def plan(self, queries, bitmaps, params: SearchParams):
        from repro.core.executor import SearchPlan
        return SearchPlan(self.strategy, self.resolve_params(params),
                          queries, bitmaps)

    def execute(self, plan):
        from repro.core.types import SearchResult
        p = plan.params
        if self.storage is None:
            d, ids, stats = _sharded_search(self._gv, self._sv,
                                            plan.queries, plan.bitmaps, p,
                                            self.use_pallas, False)
            return SearchResult(dists=d, ids=ids, stats=stats,
                                strategy=self.strategy, plan=plan,
                                anytime=costmodel.evaluate_anytime(
                                    stats, p, self.store.dim, ids,
                                    hop_cap=p.max_hops))
        d, ids, stats, trace = _sharded_search(self._gv, self._sv,
                                               plan.queries, plan.bitmaps,
                                               p, self.use_pallas, True)
        rr = trace.get("rerank_rows")
        sstats = self.storage.account_graph(
            np.asarray(trace["heap_steps"]),
            np.asarray(trace["index_steps"]),
            rerank_rows=None if rr is None else np.asarray(rr),
            quant=self.graph_quant == "sq8")
        return SearchResult(dists=d, ids=ids, stats=stats,
                            strategy=self.strategy, plan=plan,
                            storage=sstats,
                            anytime=costmodel.evaluate_anytime(
                                stats, p, self.store.dim, ids,
                                hop_cap=p.max_hops))

    def search(self, queries, bitmaps, params: SearchParams):
        return self.execute(self.plan(queries, bitmaps, params))

    # ---- stepped frontier delegates (serving/continuous.py) ----------

    def idle_frontier(self, params: SearchParams, width: int):
        return _sharded_idle(self._gv, self._sv, self._lockstep(params),
                             width, self.storage is not None)

    def init_frontier(self, queries, bitmaps, params: SearchParams,
                      deadlines=None):
        return _sharded_init(self._gv, self._sv, queries, bitmaps,
                             deadlines, self._lockstep(params),
                             self.storage is not None)

    def write_frontier_slot(self, state, lane, slot):
        return gs.frontier_write_slot(state, lane, slot)

    def step_frontier(self, state, params: SearchParams, n_hops: int,
                      dynamic_deadline: bool = False):
        return _sharded_step(self._gv, self._sv, state,
                             self._lockstep(params), n_hops,
                             self.use_pallas, dynamic_deadline)

    def finalize_frontier(self, state, params: SearchParams):
        return _sharded_finalize(self._gv, self._sv, state,
                                 self._lockstep(params))


def sharded_graph_search_fn(graph: HNSWGraph, store: VectorStore,
                            num_shards: int, params: SearchParams,
                            mesh: Mesh | None = None,
                            axis: str = SHARD_AXIS,
                            use_pallas: bool = False):
    """The real-mesh path: the same shard body under `shard_map`.

    Builds (or takes) a 1-D mesh over the first `num_shards` devices and
    returns a jitted (queries, bitmaps) -> (dists, ids, SearchStats) fn.
    Validation twin of the vmap emulation — in lockstep mode both produce
    bit-identical results (tests/test_sharding.py runs this under
    --xla_force_host_platform_device_count)."""
    if mesh is None:
        devs = jax.devices()
        if len(devs) < num_shards:
            raise ValueError(f"need {num_shards} devices for "
                             f"{num_shards} shards, have {len(devs)}")
        mesh = Mesh(np.asarray(devs[:num_shards]), (axis,))
    gv, sv = shard_graph_tiers(graph, store, num_shards, axis=axis)

    def local(gstack, sstack, queries, bitmaps):
        g = _squeeze0(gstack)
        s = _squeeze0(sstack)
        return _graph_shard_body(g, s, queries, bitmaps, params,
                                 use_pallas, False)

    fn = compat.shard_map(local, mesh=mesh,
                          in_specs=(P(axis), P(axis), P(), P()),
                          out_specs=(P(), P(), P()),
                          check_vma=False)
    return jax.jit(lambda q, b: fn(gv, sv, q, b))
