"""Query-hardness metrics (paper §5, Table 2): LID and LRC.

LID — Local Intrinsic Dimensionality, MLE estimator (Amsaleg et al. 2015):
  LID(q) = - (1/k · Σ_i ln(d_i / d_k))^{-1}  over the query's k NNs.
LRC — Local Relative Contrast (He et al. 2012 variant used by the paper):
  contrast between the mean distance and the NN distance; values near 1
  mean a harder search task (we report 1 - d_1/d_mean ∈ (0, 1)).
"""
from __future__ import annotations

import numpy as np

from repro.core.types import VectorStore
from repro.core.workload import full_distances


def lid_mle(store: VectorStore, queries, k: int = 100) -> float:
    d = np.asarray(full_distances(store, queries))
    d = np.sort(d, axis=1)[:, :k]
    if store.metric == "l2":
        d = np.sqrt(np.maximum(d, 1e-12))
    else:
        # IP "distances" are negative; shift to a positive ray per query
        d = d - d[:, :1] + 1e-3 * (d[:, -1:] - d[:, :1] + 1e-9)
    ratios = np.log(np.maximum(d[:, :-1], 1e-12)
                    / np.maximum(d[:, -1:], 1e-12))
    lid = -1.0 / np.mean(ratios, axis=1)
    return float(np.mean(np.clip(lid, 0, 1e4)))


def lrc(store: VectorStore, queries, k: int = 10,
        selectivity: float = 0.1, seed: int = 0,
        correlation: str = "low_pos") -> float:
    """Paper's LRC semantics: how little the UNFILTERED NNs overlap the true
    FILTERED NNs — 1 − |NN_unfiltered ∩ NN_filtered|/k at a reference
    selectivity (uncorrelated filter).  In (0, 1); higher = harder."""
    from repro.core.workload import WorkloadSpec, generate_passing_rows
    d = np.asarray(full_distances(store, queries))
    order = np.argsort(d, axis=1)
    rows = generate_passing_rows(store, queries,
                                 WorkloadSpec(selectivity, correlation),
                                 seed)
    vals = []
    for i in range(d.shape[0]):
        unf = order[i, :k]
        passing = np.asarray(rows[i])
        mask = np.isin(order[i], passing)
        filt = order[i][mask][:k]
        vals.append(1.0 - len(np.intersect1d(unf, filt)) / k)
    return float(np.mean(vals))


def dist_filter_relative_cost(dim: int, trials: int = 50,
                              n: int = 4096) -> float:
    """Paper Table 2 'Dist-Filt. Rel. Cost': wall-time of one distance
    computation vs one bitmap probe, measured in isolation (library-style,
    no storage engine)."""
    import time
    import jax
    import jax.numpy as jnp
    from repro.core.types import pack_bool_bitmap, probe_bitmap

    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(n, dim).astype(np.float32))
    q = jnp.asarray(rng.randn(dim).astype(np.float32))
    bm = pack_bool_bitmap(rng.rand(n) < 0.5)
    ids = jnp.asarray(rng.randint(0, n, n))

    dist_fn = jax.jit(lambda q, x: jnp.sum((x - q) ** 2, -1))
    probe_fn = jax.jit(lambda b, i: probe_bitmap(b, i))
    dist_fn(q, x).block_until_ready()
    probe_fn(bm, ids).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(trials):
        dist_fn(q, x).block_until_ready()
    td = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(trials):
        probe_fn(bm, ids).block_until_ready()
    tf = time.perf_counter() - t0
    return td / max(tf, 1e-9)
