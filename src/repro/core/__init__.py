"""Core filter-agnostic FVS library (the paper's contribution in JAX)."""
from repro.core.types import (METRIC_COS, METRIC_IP, METRIC_L2, AnytimeInfo,
                              SearchParams, SearchResult, SearchStats,
                              VectorStore, bitset_mark, bitset_words,
                              bitset_zeros, heap_pages_per_vector,
                              pack_bitmap, pack_bool_bitmap, probe_bitmap,
                              quant_heap_pages_per_vector, quantize_store,
                              recall_at_k, sq8_quantize, topk_smallest,
                              unpack_bitmap, bitmap_andnot, merge_topk)
from repro.core.workload import (CORRELATIONS, PAPER_SELECTIVITIES,
                                 WorkloadSpec, assign_family_bitmaps,
                                 generate_bitmaps, generate_families,
                                 generate_grid, generate_passing_rows)
from repro.core.bruteforce import filtered_knn, filtered_knn_partial, knn
from repro.core.exclusion import (ExclusionIndex, build_exclusion,
                                  ladder_rung, match_families, select_radii)
from repro.core.hnsw import (GraphPartition, HNSWGraph, PartitionedGraph,
                             build_graph, build_graph_partitioned,
                             build_incremental)
from repro.core.graph_search import search_batch
from repro.core.scann import (ScannIndex, build_scann, leaves_within_budget,
                              scann_search_batch, scann_search_batch_vmapped)
from repro.core.costmodel import (LIBRARY, SYSTEM, CostConstants, IndexShape,
                                  budget_cycle_weights, cache_miss_penalty,
                                  component_cycles, cycle_breakdown,
                                  engine_scale, evaluate_anytime,
                                  fault_penalty, index_segment,
                                  linear_cycles, measured_miss_penalty,
                                  modeled_qps, predict_counters,
                                  predict_cycles, stats_table_row)
from repro.core.executor import (AdaptivePlanner, BruteForceExecutor,
                                 DeltaExecutor, Executor, GraphExecutor,
                                 PartitionedGraphExecutor, ScannExecutor,
                                 SearchPlan, index_shape, make_executor,
                                 EXCL_METHODS, GRAPH_SQ8_METHODS,
                                 PARTITIONED_METHODS, REGISTERED_METHODS)
from repro.core.mutable import (MergedResult, MutableIndex,
                                rebuild_oracle_store)

__all__ = [
    "METRIC_COS", "METRIC_IP", "METRIC_L2", "AnytimeInfo",
    "budget_cycle_weights", "evaluate_anytime", "fault_penalty",
    "filtered_knn_partial", "leaves_within_budget", "linear_cycles",
    "SearchParams", "SearchResult",
    "SearchStats", "VectorStore", "heap_pages_per_vector", "pack_bitmap",
    "pack_bool_bitmap", "probe_bitmap", "quant_heap_pages_per_vector",
    "quantize_store", "recall_at_k", "sq8_quantize", "topk_smallest",
    "unpack_bitmap", "bitset_mark", "bitset_words", "bitset_zeros",
    "CORRELATIONS", "PAPER_SELECTIVITIES", "WorkloadSpec",
    "generate_bitmaps", "generate_grid", "generate_passing_rows",
    "filtered_knn", "knn", "HNSWGraph", "build_graph", "build_incremental",
    "search_batch", "ScannIndex", "build_scann", "scann_search_batch",
    "scann_search_batch_vmapped", "LIBRARY", "SYSTEM", "CostConstants",
    "IndexShape", "cache_miss_penalty", "component_cycles",
    "cycle_breakdown", "engine_scale", "index_segment",
    "measured_miss_penalty", "modeled_qps", "predict_counters",
    "predict_cycles", "stats_table_row",
    "AdaptivePlanner", "BruteForceExecutor", "Executor", "GraphExecutor",
    "PartitionedGraphExecutor", "ScannExecutor", "SearchPlan",
    "index_shape", "make_executor", "EXCL_METHODS", "GRAPH_SQ8_METHODS",
    "PARTITIONED_METHODS", "REGISTERED_METHODS",
    "ExclusionIndex", "build_exclusion", "ladder_rung", "match_families",
    "select_radii", "GraphPartition", "PartitionedGraph",
    "build_graph_partitioned", "generate_families", "assign_family_bitmaps",
    "bitmap_andnot", "merge_topk", "DeltaExecutor",
    "MergedResult", "MutableIndex", "rebuild_oracle_store",
]
