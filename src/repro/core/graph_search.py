"""Graph-based filtered vector search strategies (paper §2.3, §3.1–3.2).

One unified, jittable beam-search core implements:

  unfiltered      — plain HNSW base-layer search (zoom-in + beam)
  sweeping        — traversal-first: navigate the full graph, filter-check a
                    candidate only when it would enter the result queue W
  acorn           — filter-first: predicate-subgraph traversal with run-time
                    2-hop neighbor expansion (ACORN-1), incl. the paper's
                    "hardened" adaptive skip of 2-hop for passing branches
  navix           — ACORN-1 base + NaviX heuristics: blind / directed /
                    onehop-s, selected per step by the adaptive-local rule
  iterative_scan  — pgvector 0.8.0 resumable post-filtering: unfiltered
                    traversal emits candidate batches; filters are applied
                    after traversal; the scan resumes from preserved state
                    until k passing results are found

System-cost counters (SearchStats) mirror the paper's Table 6 exactly:
distance computations, filter checks, hops, index/heap page accesses and
translation-map lookups.  `translation_map=False` reproduces the Fig. 13
ablation: every heaptid resolution then costs an index-page access instead
of an in-memory map lookup.

All loops are `jax.lax.while_loop`s over fixed-shape state so the whole
search vmaps over queries and jits once per (graph shape, params).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.hnsw import HNSWGraph
from repro.core.types import (SearchParams, SearchStats, VectorStore,
                              distance, heap_pages_per_vector,
                              probe_bitmap, topk_smallest)

INF = jnp.inf

_pages_per_vector = heap_pages_per_vector  # shared formula (types.py)


def _dedup_first(ids: jax.Array) -> jax.Array:
    """Mask of first occurrences (ids may contain -1 padding; -1 -> False)."""
    order = jnp.argsort(ids)
    s = ids[order]
    first = jnp.concatenate([jnp.array([True]), s[1:] != s[:-1]])
    mask = jnp.zeros_like(first).at[order].set(first)
    return mask & (ids >= 0)


def _insert_sorted(w_d, w_id, cand_d, cand_id):
    """Merge candidates into sorted-ascending result array of fixed size."""
    ef = w_d.shape[0]
    d = jnp.concatenate([w_d, cand_d])
    i = jnp.concatenate([w_id, cand_id])
    nd, pos = topk_smallest(d, ef)
    return nd, i[pos]


def _gather_vec_dist(store: VectorStore, q, ids):
    safe = jnp.maximum(ids, 0)
    vecs = store.vectors[safe]
    nsq = store.norms_sq[safe]
    return distance(store.metric, q, vecs, nsq)


# ---------------------------------------------------------------------------
# Zoom-in phase (upper layers, always unfiltered — paper §2.3.1 phase (i))
# ---------------------------------------------------------------------------

def _zoom_in(graph: HNSWGraph, store: VectorStore, q, stats: SearchStats):
    cur = graph.entry_point
    cur_d = _gather_vec_dist(store, q, cur[None])[0]
    ppv = _pages_per_vector(store.dim)
    stats = SearchStats(stats.distance_comps + 1, stats.filter_checks,
                        stats.hops, stats.page_accesses_index,
                        stats.page_accesses_heap + ppv, stats.tmap_lookups,
                        stats.reorder_rows)
    for lvl in range(graph.num_levels - 1, 0, -1):
        def cond(state):
            _, _, improved, _ = state
            return improved

        def body(state):
            cur, cur_d, _, st = state
            nbrs = graph.neighbors[lvl, cur]
            valid = nbrs >= 0
            d = jnp.where(valid, _gather_vec_dist(store, q, nbrs), INF)
            j = jnp.argmin(d)
            better = d[j] < cur_d
            n_valid = valid.sum()
            st = SearchStats(
                st.distance_comps + n_valid, st.filter_checks,
                st.hops + 1, st.page_accesses_index + 1,
                st.page_accesses_heap + n_valid * _pages_per_vector(store.dim),
                st.tmap_lookups, st.reorder_rows)
            return (jnp.where(better, nbrs[j], cur),
                    jnp.where(better, d[j], cur_d), better, st)

        cur, cur_d, _, stats = jax.lax.while_loop(
            cond, body, (cur, cur_d, jnp.array(True), stats))
    return cur, cur_d, stats


# ---------------------------------------------------------------------------
# Unified base-layer step: gather 1-hop + 2-hop neighborhoods and all masks.
# Strategies differ only in which masks gate scoring/insertion/counting.
# ---------------------------------------------------------------------------

def _expand(graph: HNSWGraph, store: VectorStore, q, bitmap, node, visited):
    nb1 = graph.neighbors[0, node]                      # (2M,)
    v1 = nb1 >= 0
    unv1 = v1 & ~visited[jnp.maximum(nb1, 0)]
    pass1 = probe_bitmap(bitmap, nb1)
    d1 = jnp.where(v1, _gather_vec_dist(store, q, nb1), INF)
    nb2 = graph.neighbors[0, jnp.maximum(nb1, 0)]       # (2M, 2M)
    nb2 = jnp.where(v1[:, None], nb2, -1)
    v2 = nb2 >= 0
    pass2 = probe_bitmap(bitmap, nb2)
    unv2 = v2 & ~visited[jnp.maximum(nb2, 0)]
    d2 = jnp.where(v2, _gather_vec_dist(store, q, nb2), INF)
    return dict(nb1=nb1, v1=v1, unv1=unv1, pass1=pass1, d1=d1,
                nb2=nb2, v2=v2, unv2=unv2, pass2=pass2, d2=d2)


def _base_search(graph: HNSWGraph, store: VectorStore, q, bitmap,
                 params: SearchParams, entry, entry_d, stats: SearchStats,
                 ef_result: int):
    """Shared beam loop. Returns (W_d, W_id sorted asc, pool, visited, stats).

    `strategy` semantics are resolved here (static params → traced masks).
    For iterative_scan this runs the *unfiltered* navigation with the big
    result buffer; the resumable outer logic lives in `_iterative_scan`.
    """
    n = graph.n
    P = params.beam_width
    strat = params.strategy
    ppv = _pages_per_vector(store.dim)
    M2 = graph.neighbors.shape[2]

    pool_d = jnp.full((P,), INF).at[0].set(entry_d)
    pool_id = jnp.full((P,), -1, jnp.int32).at[0].set(entry)
    visited = jnp.zeros((n,), bool).at[entry].set(True)
    w_d = jnp.full((ef_result,), INF)
    w_id = jnp.full((ef_result,), -1, jnp.int32)
    # seed W with the entry if it passes the filter (or always, unfiltered)
    entry_pass = probe_bitmap(bitmap, entry[None])[0]
    seed_ok = entry_pass | (strat in ("unfiltered", "iterative_scan"))
    w_d = jnp.where(seed_ok, w_d.at[0].set(entry_d), w_d)
    w_id = jnp.where(seed_ok, w_id.at[0].set(entry), w_id)

    def cond(state):
        pool_d, pool_id, w_d, w_id, visited, st, done = state
        return ~done

    def body(state):
        pool_d, pool_id, w_d, w_id, visited, st, done = state
        j = jnp.argmin(pool_d)
        best_d, best_id = pool_d[j], pool_id[j]
        w_worst = w_d[params.ef_search - 1] if ef_result >= params.ef_search \
            else w_d[-1]
        stop = (best_d > w_worst) | jnp.isinf(best_d) | \
            (st.hops >= params.max_hops)
        # pop
        pool_d = pool_d.at[j].set(INF)
        pool_id = pool_id.at[j].set(-1)

        e = _expand(graph, store, q, bitmap, jnp.maximum(best_id, 0), visited)
        dc = fc = pai = pah = tm = jnp.int32(0)
        pai += 1  # step ①: current node's index page

        if strat in ("unfiltered", "iterative_scan", "sweeping"):
            # -------- traversal-first: score every unvisited 1-hop neighbor
            score_m = e["unv1"]
            n_s = score_m.sum()
            dc += n_s
            pah += n_s * ppv
            cd = jnp.where(score_m, e["d1"], INF)
            cid = jnp.where(score_m, e["nb1"], -1)
            pool_d, pool_id = _pool_insert(pool_d, pool_id, cd, cid)
            visited = visited.at[jnp.maximum(e["nb1"], 0)].set(
                visited[jnp.maximum(e["nb1"], 0)] | score_m)
            if strat == "sweeping":
                # filter-check only candidates that would enter W
                would = score_m & (cd < w_worst)
                n_w = would.sum()
                fc += n_w
                tm_inc = jnp.where(params.translation_map, n_w, 0)
                pai_inc = jnp.where(params.translation_map, 0, n_w)
                tm += tm_inc
                pai += pai_inc
                wd = jnp.where(would & e["pass1"], cd, INF)
                wid = jnp.where(would & e["pass1"], cid, -1)
            else:
                wd, wid = cd, cid
            w_d, w_id = _insert_sorted(w_d, w_id, wd, wid)
        else:
            # -------- filter-first (acorn / navix): predicate subgraph
            n1 = e["v1"].sum()
            fc += n1                                   # check all 1-hop
            tm += jnp.where(params.translation_map, n1, 0)
            pai += jnp.where(params.translation_map, 0, n1)
            pass1 = e["pass1"] & e["v1"]
            local_sel = pass1.sum() / jnp.maximum(n1, 1)

            if strat == "acorn":
                do_onehop_score = jnp.array(True)
                do_directed = jnp.array(False)
                do_twohop_all = jnp.array(True)
            else:  # navix heuristics
                h = params.navix_heuristic
                if h == "blind":
                    do_onehop_score, do_directed, do_twohop_all = (
                        jnp.array(True), jnp.array(False), jnp.array(True))
                elif h == "directed":
                    do_onehop_score, do_directed, do_twohop_all = (
                        jnp.array(True), jnp.array(True), jnp.array(False))
                elif h == "onehop":
                    do_onehop_score, do_directed, do_twohop_all = (
                        jnp.array(True), jnp.array(False), jnp.array(False))
                else:  # adaptive-local (paper §2.3.4)
                    do_onehop_score = jnp.array(True)
                    do_directed = (local_sel > 0.08) & (local_sel <= 0.35)
                    do_twohop_all = local_sel <= 0.08

            # 1-hop: score the passing, unvisited ones
            s1 = pass1 & e["unv1"]
            n_s1 = s1.sum()
            dc += n_s1
            pah += n_s1 * ppv
            cd1 = jnp.where(s1, e["d1"], INF)
            cid1 = jnp.where(s1, e["nb1"], -1)

            # decide which branches expand to 2 hops
            expand_branch = e["v1"]
            if params.adaptive_skip_2hop:
                # hardened ACORN (paper §3.1 opt ii): skip 2-hop for branches
                # whose 1-hop neighbor already passed the filter
                expand_branch = expand_branch & ~pass1
            if strat == "navix" and params.navix_heuristic in ("directed",
                                                               "adaptive"):
                # directed: expand only from top-ranked (closest) 1-hop nodes
                rank = jnp.argsort(jnp.where(e["v1"], e["d1"], INF))
                topr = jnp.zeros_like(e["v1"]).at[
                    rank[: max(1, M2 // 4)]].set(True)
                directed_branch = expand_branch & topr
                expand_branch = jnp.where(
                    do_twohop_all, expand_branch,
                    jnp.where(do_directed, directed_branch, False))
                # directed mode ranks ALL 1-hop neighbors → scores them
                extra_rank_dc = jnp.where(
                    do_directed, (e["v1"] & ~s1).sum(), 0)
                dc += extra_rank_dc
                pah += extra_rank_dc * ppv
            elif strat == "navix" and params.navix_heuristic == "onehop":
                expand_branch = jnp.zeros_like(expand_branch)

            n_exp = expand_branch.sum()
            pai += n_exp                               # step ②: branch pages
            m2 = e["v2"] & expand_branch[:, None]
            n2 = m2.sum()
            fc += n2                                   # step ④: 2-hop checks
            tm += jnp.where(params.translation_map, n2, 0)
            pai += jnp.where(params.translation_map, 0, n2)
            s2 = m2 & e["pass2"] & e["unv2"]
            n_s2 = s2.sum()
            dc += n_s2                                 # step ⑤
            pah += n_s2 * ppv
            cd2 = jnp.where(s2, e["d2"], INF).reshape(-1)
            cid2 = jnp.where(s2, e["nb2"], -1).reshape(-1)

            cd = jnp.concatenate([cd1, cd2])
            cid = jnp.concatenate([cid1, cid2])
            uniq = _dedup_first(cid)
            cd = jnp.where(uniq, cd, INF)
            cid = jnp.where(uniq, cid, -1)
            pool_d, pool_id = _pool_insert(pool_d, pool_id, cd, cid)
            visited = visited.at[jnp.maximum(cid, 0)].set(
                visited[jnp.maximum(cid, 0)] | (cid >= 0))
            w_d, w_id = _insert_sorted(w_d, w_id, cd, cid)

        st = SearchStats(st.distance_comps + dc, st.filter_checks + fc,
                         st.hops + 1, st.page_accesses_index + pai,
                         st.page_accesses_heap + pah, st.tmap_lookups + tm,
                         st.reorder_rows)
        # When `stop` fired we must not apply this step: select old state.
        new = (pool_d, pool_id, w_d, w_id, visited, st, stop)
        old = (state[0], state[1], state[2], state[3], state[4], state[5],
               jnp.array(True))
        return jax.tree.map(lambda a, b: jnp.where(stop, b, a), new, old)

    state = (pool_d, pool_id, w_d, w_id, visited, stats, jnp.array(False))
    pool_d, pool_id, w_d, w_id, visited, stats, _ = jax.lax.while_loop(
        cond, body, state)
    return w_d, w_id, (pool_d, pool_id), visited, stats


def _pool_insert(pool_d, pool_id, cand_d, cand_id):
    P = pool_d.shape[0]
    d = jnp.concatenate([pool_d, cand_d])
    i = jnp.concatenate([pool_id, cand_id])
    nd, pos = topk_smallest(d, P)
    ni = i[pos]
    nd = jnp.where(ni >= 0, nd, INF)
    return nd, ni


# ---------------------------------------------------------------------------
# Top-level strategy entry points
# ---------------------------------------------------------------------------

def _finalize(w_d, w_id, bitmap, k, check_filter: bool):
    """Top-k filter-passing results out of W (W is sorted ascending)."""
    if check_filter:
        ok = probe_bitmap(bitmap, w_id) & (w_id >= 0)
    else:
        ok = w_id >= 0
    d = jnp.where(ok, w_d, INF)
    dk, pos = topk_smallest(d, k)
    ids = jnp.where(jnp.isinf(dk), -1, w_id[pos])
    return dk, ids


def _search_single(graph: HNSWGraph, store: VectorStore, q, bitmap,
                   params: SearchParams):
    stats = SearchStats.zeros()
    entry, entry_d, stats = _zoom_in(graph, store, q, stats)
    if params.strategy == "iterative_scan":
        return _iterative_scan(graph, store, q, bitmap, params, entry,
                               entry_d, stats)
    w_d, w_id, _, _, stats = _base_search(
        graph, store, q, bitmap, params, entry, entry_d, stats,
        ef_result=params.ef_search)
    check = params.strategy in ("unfiltered",)
    dk, ids = _finalize(w_d, w_id, bitmap, params.k,
                        check_filter=not check)
    return dk, ids, stats


def _iterative_scan(graph: HNSWGraph, store: VectorStore, q, bitmap,
                    params: SearchParams, entry, entry_d,
                    stats: SearchStats):
    """pgvector 0.8.0 iterative scan: unfiltered traversal, post-filter the
    emitted batch, resume from preserved state until k passing results.

    State preservation (the paper's discarded-queue D) falls out of the beam
    representation: the pool retains seen-but-unexpanded candidates, and the
    result buffer W_raw keeps everything ever emitted, so "resuming" is just
    continuing the same loop with a larger effective ef.
    """
    n = graph.n
    P = params.beam_width
    ppv = _pages_per_vector(store.dim)
    EFMAX = params.batch_tuples * params.max_rounds

    pool_d = jnp.full((P,), INF).at[0].set(entry_d)
    pool_id = jnp.full((P,), -1, jnp.int32).at[0].set(entry)
    visited = jnp.zeros((n,), bool).at[entry].set(True)
    w_d = jnp.full((EFMAX,), INF).at[0].set(entry_d)
    w_id = jnp.full((EFMAX,), -1, jnp.int32).at[0].set(entry)

    def cond(state):
        *_, done = state
        return ~done

    def body(state):
        pool_d, pool_id, w_d, w_id, visited, st, eff, rnd, checked, done = state
        j = jnp.argmin(pool_d)
        best_d, best_id = pool_d[j], pool_id[j]
        w_worst = w_d[jnp.minimum(eff, EFMAX) - 1]
        batch_done = (best_d > w_worst) | jnp.isinf(best_d) | \
            (st.hops >= params.max_hops)

        # ---- resume/emit path: filter the batch, maybe extend the scan
        n_pass = (probe_bitmap(bitmap, w_id) &
                  (jnp.arange(EFMAX) < eff) & (w_id >= 0)).sum()
        newly = jnp.maximum(jnp.minimum(eff, EFMAX) - checked, 0)
        fc_emit = jnp.where(batch_done, newly, 0)
        tm_emit = jnp.where(params.translation_map, fc_emit, 0)
        pai_emit = jnp.where(params.translation_map, 0, fc_emit)
        enough = n_pass >= params.k
        exhausted = jnp.isinf(best_d) | (st.hops >= params.max_hops) | \
            (rnd + 1 >= params.max_rounds)
        finish = batch_done & (enough | exhausted)
        eff2 = jnp.where(batch_done & ~finish, eff + params.batch_tuples, eff)
        rnd2 = jnp.where(batch_done & ~finish, rnd + 1, rnd)
        checked2 = jnp.where(batch_done, jnp.minimum(eff, EFMAX), checked)

        # ---- normal expansion path (only applied when ~batch_done)
        pool_d2 = pool_d.at[j].set(INF)
        pool_id2 = pool_id.at[j].set(-1)
        e = _expand(graph, store, q, bitmap, jnp.maximum(best_id, 0), visited)
        score_m = e["unv1"]
        n_s = score_m.sum()
        cd = jnp.where(score_m, e["d1"], INF)
        cid = jnp.where(score_m, e["nb1"], -1)
        pool_d2, pool_id2 = _pool_insert(pool_d2, pool_id2, cd, cid)
        visited2 = visited.at[jnp.maximum(e["nb1"], 0)].set(
            visited[jnp.maximum(e["nb1"], 0)] | score_m)
        w_d2, w_id2 = _insert_sorted(w_d, w_id, cd, cid)

        st2 = SearchStats(
            st.distance_comps + jnp.where(batch_done, 0, n_s),
            st.filter_checks + fc_emit,
            st.hops + jnp.where(batch_done, 0, 1),
            st.page_accesses_index + jnp.where(batch_done, 0, 1) + pai_emit,
            st.page_accesses_heap + jnp.where(batch_done, 0, n_s * ppv),
            st.tmap_lookups + tm_emit, st.reorder_rows)

        sel = lambda a, b: jax.tree.map(
            lambda x, y: jnp.where(batch_done, x, y), a, b)
        pool_d3, pool_id3, w_d3, w_id3, visited3 = sel(
            (pool_d, pool_id, w_d, w_id, visited),
            (pool_d2, pool_id2, w_d2, w_id2, visited2))
        return (pool_d3, pool_id3, w_d3, w_id3, visited3, st2, eff2, rnd2,
                checked2, finish)

    state = (pool_d, pool_id, w_d, w_id, visited, stats,
             jnp.int32(params.batch_tuples), jnp.int32(0), jnp.int32(0),
             jnp.array(False))
    pool_d, pool_id, w_d, w_id, visited, stats, eff, rnd, checked, _ = \
        jax.lax.while_loop(cond, body, state)
    in_batch = jnp.arange(EFMAX) < eff
    d = jnp.where(in_batch, w_d, INF)
    ids = jnp.where(in_batch, w_id, -1)
    dk, pos = topk_smallest(
        jnp.where(probe_bitmap(bitmap, ids) & (ids >= 0), d, INF), params.k)
    out_ids = jnp.where(jnp.isinf(dk), -1, ids[pos])
    return dk, out_ids, stats


@partial(jax.jit, static_argnames=("params",))
def search_batch(graph: HNSWGraph, store: VectorStore, queries, bitmaps,
                 params: SearchParams):
    """vmapped filtered search. queries (Q, d), bitmaps (Q, words).

    Returns (dists (Q, k), ids (Q, k), SearchStats with (Q,) leaves).
    """
    return jax.vmap(lambda q, b: _search_single(graph, store, q, b, params))(
        queries, bitmaps)
