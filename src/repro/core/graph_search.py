"""Graph-based filtered vector search strategies (paper §2.3, §3.1–3.2).

One unified, jittable beam-search core implements:

  unfiltered      — plain HNSW base-layer search (zoom-in + beam)
  sweeping        — traversal-first: navigate the full graph, filter-check a
                    candidate only when it would enter the result queue W
  acorn           — filter-first: predicate-subgraph traversal with run-time
                    2-hop neighbor expansion (ACORN-1), incl. the paper's
                    "hardened" adaptive skip of 2-hop for passing branches
  navix           — ACORN-1 base + NaviX heuristics: blind / directed /
                    onehop-s, selected per step by the adaptive-local rule
  iterative_scan  — pgvector 0.8.0 resumable post-filtering: unfiltered
                    traversal emits candidate batches; filters are applied
                    after traversal; the scan resumes from preserved state
                    until k passing results are found

System-cost counters (SearchStats) mirror the paper's Table 6 exactly:
distance computations, filter checks, hops, index/heap page accesses and
translation-map lookups.  `translation_map=False` reproduces the Fig. 13
ablation: every heaptid resolution then costs an index-page access instead
of an in-memory map lookup.

All loops are `jax.lax.while_loop`s over fixed-shape state so the whole
search vmaps over queries and jits once per (graph shape, params).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.costmodel import budget_cycle_weights
from repro.core.hnsw import HNSWGraph
from repro.core.shardtypes import ShardGraph, ShardStore
from repro.core.types import (Array, SearchParams, SearchStats, VectorStore,
                              bitset_mark, bitset_words, distance,
                              heap_pages_per_vector, probe_bitmap,
                              quant_heap_pages_per_vector, topk_smallest)
from repro.kernels import ops as kops

INF = jnp.inf

GRAPH_QUANT_MODES = ("none", "sq8")


def _budget_over(st: SearchStats, params: SearchParams, dim: int,
                 deadline=None):
    """Anytime budget-stop predicate over the carried counters
    (DESIGN.md §10).  Returns None when no budget is set — the predicate
    is then never traced, so zero-budget programs are jaxpr-identical to
    the pre-budget engines (bit-identicality by construction).  Works on
    scalar (legacy per-query) and (Q,)-leaved (frontier) stats alike.

    The deadline term prices the counters with the linear
    `costmodel.budget_cycle_weights` form in float32, term order fixed —
    `costmodel.linear_cycles` applies the identical arithmetic post-hoc,
    so the derived budget_exhausted flag agrees with the in-loop stop.

    `deadline` (DESIGN.md §11): optional traced (Q,) float32 per-lane
    deadline array for the externally stepped driver, where slots hold
    requests from DIFFERENT deadline buckets at once (+inf = no
    deadline, so the term is inert per lane).  Same weights, same float32
    comparison as the static `params.deadline_cycles` term — a lane with
    deadline array value b stops exactly where a batch run with
    deadline_cycles=b would.
    """
    terms = []
    if params.page_budget > 0:
        pages = st.page_accesses_index + st.page_accesses_heap
        terms.append(pages >= params.page_budget)
    if params.hop_budget > 0:
        terms.append(st.hops >= params.hop_budget)
    if params.deadline_cycles > 0 or deadline is not None:
        w = budget_cycle_weights(dim)
        cyc = None
        for name, weight in w.items():
            t = getattr(st, name).astype(jnp.float32) * jnp.float32(weight)
            cyc = t if cyc is None else cyc + t
        if params.deadline_cycles > 0:
            terms.append(cyc >= jnp.float32(params.deadline_cycles))
        if deadline is not None:
            terms.append(cyc >= deadline)
    if not terms:
        return None
    out = terms[0]
    for t in terms[1:]:
        out = out | t
    return out


def _ppv(store: VectorStore, quant: str) -> int:
    """Heap pages per traversal-fetched vector: full-width rows for the
    classic tier, SQ8 shadow rows for the quantized tier (DESIGN.md §9)."""
    return (quant_heap_pages_per_vector(store.dim) if quant == "sq8"
            else heap_pages_per_vector(store.dim))


def _dedup_first(ids: jax.Array) -> jax.Array:
    """Mask of first occurrences (ids may contain -1 padding; -1 -> False)."""
    order = jnp.argsort(ids)
    s = ids[order]
    first = jnp.concatenate([jnp.array([True]), s[1:] != s[:-1]])
    mask = jnp.zeros_like(first).at[order].set(first)
    return mask & (ids >= 0)


def _insert_sorted(w_d, w_id, cand_d, cand_id):
    """Merge candidates into sorted-ascending result array of fixed size."""
    ef = w_d.shape[0]
    d = jnp.concatenate([w_d, cand_d])
    i = jnp.concatenate([w_id, cand_id])
    nd, pos = topk_smallest(d, ef)
    return nd, i[pos]


def _gather_vec_dist(store: VectorStore, q, ids, quant: str = "none"):
    """Gather rows + distance to q.  quant="sq8" reads the SQ8 shadow heap
    and dequantizes (x̂ = q_vectors·scale + mean) with the precomputed
    dequantized norms — the exact arithmetic `ref.frontier_scan_sq8_ref`
    mirrors, so both engines stay bit-identical per quant mode.

    On a `ShardStore` view (DESIGN.md §13) the gather resolves by row
    ownership: each shard scores its own rows (same clamp semantics — a
    -1 id clamps to global row 0, which shard 0 owns, reproducing the
    single-device garbage value bit-exactly) and, in collective mode, a
    `pmin` over the mesh axis selects the owner's distance (non-owners
    contribute +inf) — no arithmetic touches the owner's value, so the
    result is bit-identical to the single-device gather.  Non-collective
    views return +inf for remote rows (drift-mode induced subgraph)."""
    safe = jnp.maximum(ids, 0)
    if isinstance(store, ShardStore):
        off = store.offset
        own = (safe >= off) & (safe < off + store.local_n)
        local = jnp.clip(safe - off, 0, store.local_n - 1)
        if quant == "sq8":
            vecs = (store.q_vectors[local].astype(jnp.float32)
                    * store.q_scale + store.q_mean)
            nsq = store.q_norms_sq[local]
        else:
            vecs = store.vectors[local]
            nsq = store.norms_sq[local]
        d = jnp.where(own, distance(store.metric, q, vecs, nsq), INF)
        if store.collective:
            d = jax.lax.pmin(d, store.axis)
        return d
    if quant == "sq8":
        vecs = (store.q_vectors[safe].astype(jnp.float32) * store.q_scale
                + store.q_mean)
        nsq = store.q_norms_sq[safe]
    else:
        vecs = store.vectors[safe]
        nsq = store.norms_sq[safe]
    return distance(store.metric, q, vecs, nsq)


def _adj(graph, lvl, ids):
    """Adjacency read `graph.neighbors[lvl, ids]`, dispatched on the view.

    `ids` are non-negative at every call site (popped/clamped upstream).
    On a `ShardGraph` (DESIGN.md §13) each shard reads the rows it owns;
    in collective mode the owner's row is broadcast via `pmax` over the
    mesh axis (non-owners contribute INT32_MIN, below the -1 padding, so
    the reduction returns the owner's int32 row untouched — bit-exact).
    Non-collective views keep traversal on the induced subgraph: remote
    rows read as all--1 and remote neighbor *values* are masked to -1.
    """
    if not isinstance(graph, ShardGraph):
        return graph.neighbors[lvl, ids]
    off = graph.offset
    own = (ids >= off) & (ids < off + graph.local_n)
    local = jnp.clip(ids - off, 0, graph.local_n - 1)
    nb = graph.neighbors[lvl, local]
    if graph.collective:
        nb = jnp.where(own[..., None], nb, jnp.iinfo(jnp.int32).min)
        return jax.lax.pmax(nb, graph.axis)
    nb = jnp.where(own[..., None], nb, -1)
    keep = (nb >= off) & (nb < off + graph.local_n)
    return jnp.where(keep, nb, -1)


# ---------------------------------------------------------------------------
# Storage-trace stamping (DESIGN.md §8).  Traces are per-query FIRST-TOUCH
# superstep stamps over the object id space: `steps[obj]` holds the
# SearchStats.hops value of the step that first fetched the object
# (TRACE_UNTOUCHED where never fetched), so the storage engine can replay
# page accesses in traversal order — LRU/clock behavior is order-faithful,
# not id-ascending.  Scatter-min marking is repeat- and order-safe (zoom-in
# revisits, pop/zoom overlaps, -1 padding all collapse to no-ops).
# ---------------------------------------------------------------------------

TRACE_UNTOUCHED = int(jnp.iinfo(jnp.int32).max)


def _stamp1(steps, ids, mask, step):
    """First-touch stamp over one query's (n,) step array."""
    live = mask & (ids >= 0)
    safe = jnp.maximum(ids, 0)
    val = jnp.where(live, step, TRACE_UNTOUCHED).astype(jnp.int32)
    return steps.at[safe.reshape(-1)].min(val.reshape(-1))


_stamp_batch = jax.vmap(_stamp1)


def _unpack_bitset_batch(words, n: int):
    """(Q, W) packed uint32 bitsets -> (Q, n) bool (trace-only cost)."""
    bits = (words[:, :, None] >> jnp.arange(32, dtype=jnp.uint32)) \
        & jnp.uint32(1)
    return bits.reshape(words.shape[0], -1)[:, :n].astype(bool)


def _stamp_newly_marked(steps, old_words, new_words, step):
    """Stamp every row whose packed-bitset mark appeared between two
    snapshots (the superstep's newly visited set) with `step` (Q,).
    The AND-NOT runs on the packed words (exact — marks only ever turn
    on), so only one (Q, n) unpack is paid per superstep, and only on
    tracing runs."""
    n = steps.shape[1]
    newly = _unpack_bitset_batch(new_words & ~old_words, n)
    return jnp.minimum(steps, jnp.where(newly, step[:, None],
                                        TRACE_UNTOUCHED).astype(jnp.int32))


# ---------------------------------------------------------------------------
# Zoom-in phase (upper layers, always unfiltered — paper §2.3.1 phase (i))
# ---------------------------------------------------------------------------

def _zoom_in(graph: HNSWGraph, store: VectorStore, q, stats: SearchStats,
             trace=None, quant: str = "none"):
    """Greedy upper-layer descent.  With `trace` = (heap_steps,
    index_steps) first-touch stamp arrays, touched objects are stamped
    with the hop counter at fetch time: every scored neighbor (and the
    entry) into heap_steps, every node whose adjacency is read into
    index_steps.  Returns (cur, cur_d, stats, trace).
    """
    tracing = trace is not None
    hs, is_ = trace if tracing else (jnp.zeros((0,), jnp.int32),) * 2
    cur = graph.entry_point
    cur_d = _gather_vec_dist(store, q, cur[None], quant)[0]
    ppv = _ppv(store, quant)
    stats = SearchStats(stats.distance_comps + 1, stats.filter_checks,
                        stats.hops, stats.page_accesses_index,
                        stats.page_accesses_heap + ppv, stats.tmap_lookups,
                        stats.reorder_rows)
    if tracing:
        hs = _stamp1(hs, cur[None], jnp.array([True]), stats.hops)
    for lvl in range(graph.num_levels - 1, 0, -1):
        def cond(state):
            _, _, improved, _, _, _ = state
            return improved

        def body(state):
            cur, cur_d, _, st, hs, is_ = state
            nbrs = _adj(graph, lvl, cur)
            valid = nbrs >= 0
            d = jnp.where(valid, _gather_vec_dist(store, q, nbrs, quant),
                          INF)
            j = jnp.argmin(d)
            better = d[j] < cur_d
            n_valid = valid.sum()
            st = SearchStats(
                st.distance_comps + n_valid, st.filter_checks,
                st.hops + 1, st.page_accesses_index + 1,
                st.page_accesses_heap + n_valid * _ppv(store, quant),
                st.tmap_lookups, st.reorder_rows)
            if tracing:
                is_ = _stamp1(is_, cur[None], jnp.array([True]), st.hops)
                hs = _stamp1(hs, nbrs, valid, st.hops)
            return (jnp.where(better, nbrs[j], cur),
                    jnp.where(better, d[j], cur_d), better, st, hs, is_)

        cur, cur_d, _, stats, hs, is_ = jax.lax.while_loop(
            cond, body, (cur, cur_d, jnp.array(True), stats, hs, is_))
    return cur, cur_d, stats, ((hs, is_) if tracing else None)


# ---------------------------------------------------------------------------
# Unified base-layer step: gather 1-hop + 2-hop neighborhoods and all masks.
# Strategies differ only in which masks gate scoring/insertion/counting.
# ---------------------------------------------------------------------------

def _expand(graph: HNSWGraph, store: VectorStore, q, bitmap, node, visited,
            two_hop: bool = True, quant: str = "none"):
    """1-hop (and, for filter-first strategies, 2-hop) neighborhood fetch.

    `two_hop` is a static flag: traversal-first strategies (unfiltered /
    sweeping / iterative_scan) never read the 2-hop block, so the (2M, 2M)
    gather + distance computation is gated out of their traces entirely
    instead of relying on XLA dead-code elimination.  `quant` picks the
    heap tier the candidate rows are fetched from (DESIGN.md §9).
    """
    nb1 = _adj(graph, 0, node)                          # (2M,)
    v1 = nb1 >= 0
    unv1 = v1 & ~visited[jnp.maximum(nb1, 0)]
    pass1 = probe_bitmap(bitmap, nb1)
    d1 = jnp.where(v1, _gather_vec_dist(store, q, nb1, quant), INF)
    e = dict(nb1=nb1, v1=v1, unv1=unv1, pass1=pass1, d1=d1)
    if not two_hop:
        return e
    nb2 = _adj(graph, 0, jnp.maximum(nb1, 0))           # (2M, 2M)
    nb2 = jnp.where(v1[:, None], nb2, -1)
    v2 = nb2 >= 0
    pass2 = probe_bitmap(bitmap, nb2)
    unv2 = v2 & ~visited[jnp.maximum(nb2, 0)]
    d2 = jnp.where(v2, _gather_vec_dist(store, q, nb2, quant), INF)
    e.update(nb2=nb2, v2=v2, unv2=unv2, pass2=pass2, d2=d2)
    return e


def _base_search(graph: HNSWGraph, store: VectorStore, q, bitmap,
                 params: SearchParams, entry, entry_d, stats: SearchStats,
                 ef_result: int):
    """Shared beam loop. Returns (W_d, W_id sorted asc, pool, visited, stats).

    `strategy` semantics are resolved here (static params → traced masks).
    For iterative_scan this runs the *unfiltered* navigation with the big
    result buffer; the resumable outer logic lives in `_iterative_scan`.
    """
    n = graph.n
    P = params.beam_width
    strat = params.strategy
    quant = params.graph_quant
    ppv = _ppv(store, quant)
    M2 = graph.neighbors.shape[2]

    pool_d = jnp.full((P,), INF).at[0].set(entry_d)
    pool_id = jnp.full((P,), -1, jnp.int32).at[0].set(entry)
    visited = jnp.zeros((n,), bool).at[entry].set(True)
    w_d = jnp.full((ef_result,), INF)
    w_id = jnp.full((ef_result,), -1, jnp.int32)
    # seed W with the entry if it passes the filter (or always, unfiltered)
    entry_pass = probe_bitmap(bitmap, entry[None])[0]
    seed_ok = entry_pass | (strat in ("unfiltered", "iterative_scan"))
    w_d = jnp.where(seed_ok, w_d.at[0].set(entry_d), w_d)
    w_id = jnp.where(seed_ok, w_id.at[0].set(entry), w_id)

    def cond(state):
        pool_d, pool_id, w_d, w_id, visited, st, done = state
        return ~done

    def body(state):
        pool_d, pool_id, w_d, w_id, visited, st, done = state
        j = jnp.argmin(pool_d)
        best_d, best_id = pool_d[j], pool_id[j]
        w_worst = w_d[params.ef_search - 1] if ef_result >= params.ef_search \
            else w_d[-1]
        stop = (best_d > w_worst) | jnp.isinf(best_d) | \
            (st.hops >= params.max_hops)
        over = _budget_over(st, params, store.dim)
        if over is not None:
            stop = stop | over
        # pop
        pool_d = pool_d.at[j].set(INF)
        pool_id = pool_id.at[j].set(-1)

        e = _expand(graph, store, q, bitmap, jnp.maximum(best_id, 0), visited,
                    two_hop=strat in ("acorn", "navix"), quant=quant)
        dc = fc = pai = pah = tm = jnp.int32(0)
        pai += 1  # step ①: current node's index page

        if strat in ("unfiltered", "iterative_scan", "sweeping"):
            # -------- traversal-first: score every unvisited 1-hop neighbor
            score_m = e["unv1"]
            n_s = score_m.sum()
            dc += n_s
            pah += n_s * ppv
            cd = jnp.where(score_m, e["d1"], INF)
            cid = jnp.where(score_m, e["nb1"], -1)
            pool_d, pool_id = _pool_insert(pool_d, pool_id, cd, cid)
            # scatter-max, not gather-or-set: -1 padding also maps to slot
            # 0, and a duplicate-index .set() would let a padding entry
            # clobber node 0's freshly written visited bit back to False
            # (node 0 then re-scores forever via 2-hop cycles)
            visited = visited.at[jnp.maximum(e["nb1"], 0)].max(score_m)
            if strat == "sweeping":
                # filter-check only candidates that would enter W
                would = score_m & (cd < w_worst)
                n_w = would.sum()
                fc += n_w
                tm_inc = jnp.where(params.translation_map, n_w, 0)
                pai_inc = jnp.where(params.translation_map, 0, n_w)
                tm += tm_inc
                pai += pai_inc
                wd = jnp.where(would & e["pass1"], cd, INF)
                wid = jnp.where(would & e["pass1"], cid, -1)
            else:
                wd, wid = cd, cid
            w_d, w_id = _insert_sorted(w_d, w_id, wd, wid)
        else:
            # -------- filter-first (acorn / navix): predicate subgraph
            n1 = e["v1"].sum()
            fc += n1                                   # check all 1-hop
            tm += jnp.where(params.translation_map, n1, 0)
            pai += jnp.where(params.translation_map, 0, n1)
            pass1 = e["pass1"] & e["v1"]
            local_sel = pass1.sum() / jnp.maximum(n1, 1)

            if strat == "acorn":
                do_onehop_score = jnp.array(True)
                do_directed = jnp.array(False)
                do_twohop_all = jnp.array(True)
            else:  # navix heuristics
                h = params.navix_heuristic
                if h == "blind":
                    do_onehop_score, do_directed, do_twohop_all = (
                        jnp.array(True), jnp.array(False), jnp.array(True))
                elif h == "directed":
                    do_onehop_score, do_directed, do_twohop_all = (
                        jnp.array(True), jnp.array(True), jnp.array(False))
                elif h == "onehop":
                    do_onehop_score, do_directed, do_twohop_all = (
                        jnp.array(True), jnp.array(False), jnp.array(False))
                else:  # adaptive-local (paper §2.3.4)
                    do_onehop_score = jnp.array(True)
                    do_directed = (local_sel > 0.08) & (local_sel <= 0.35)
                    do_twohop_all = local_sel <= 0.08

            # 1-hop: score the passing, unvisited ones
            s1 = pass1 & e["unv1"]
            n_s1 = s1.sum()
            dc += n_s1
            pah += n_s1 * ppv
            cd1 = jnp.where(s1, e["d1"], INF)
            cid1 = jnp.where(s1, e["nb1"], -1)

            # decide which branches expand to 2 hops
            expand_branch = e["v1"]
            if params.adaptive_skip_2hop:
                # hardened ACORN (paper §3.1 opt ii): skip 2-hop for branches
                # whose 1-hop neighbor already passed the filter
                expand_branch = expand_branch & ~pass1
            if strat == "navix" and params.navix_heuristic in ("directed",
                                                               "adaptive"):
                # directed: expand only from top-ranked (closest) 1-hop nodes
                rank = jnp.argsort(jnp.where(e["v1"], e["d1"], INF))
                topr = jnp.zeros_like(e["v1"]).at[
                    rank[: max(1, M2 // 4)]].set(True)
                directed_branch = expand_branch & topr
                expand_branch = jnp.where(
                    do_twohop_all, expand_branch,
                    jnp.where(do_directed, directed_branch, False))
                # directed mode ranks ALL 1-hop neighbors → scores them
                extra_rank_dc = jnp.where(
                    do_directed, (e["v1"] & ~s1).sum(), 0)
                dc += extra_rank_dc
                pah += extra_rank_dc * ppv
            elif strat == "navix" and params.navix_heuristic == "onehop":
                expand_branch = jnp.zeros_like(expand_branch)

            n_exp = expand_branch.sum()
            pai += n_exp                               # step ②: branch pages
            m2 = e["v2"] & expand_branch[:, None]
            n2 = m2.sum()
            fc += n2                                   # step ④: 2-hop checks
            tm += jnp.where(params.translation_map, n2, 0)
            pai += jnp.where(params.translation_map, 0, n2)
            s2 = m2 & e["pass2"] & e["unv2"]
            n_s2 = s2.sum()
            dc += n_s2                                 # step ⑤
            pah += n_s2 * ppv
            cd2 = jnp.where(s2, e["d2"], INF).reshape(-1)
            cid2 = jnp.where(s2, e["nb2"], -1).reshape(-1)

            cd = jnp.concatenate([cd1, cd2])
            cid = jnp.concatenate([cid1, cid2])
            uniq = _dedup_first(cid)
            cd = jnp.where(uniq, cd, INF)
            cid = jnp.where(uniq, cid, -1)
            pool_d, pool_id = _pool_insert(pool_d, pool_id, cd, cid)
            # scatter-max: order-safe for the -1 → slot-0 padding collisions
            visited = visited.at[jnp.maximum(cid, 0)].max(cid >= 0)
            w_d, w_id = _insert_sorted(w_d, w_id, cd, cid)

        st = SearchStats(st.distance_comps + dc, st.filter_checks + fc,
                         st.hops + 1, st.page_accesses_index + pai,
                         st.page_accesses_heap + pah, st.tmap_lookups + tm,
                         st.reorder_rows)
        # When `stop` fired we must not apply this step: select old state.
        new = (pool_d, pool_id, w_d, w_id, visited, st, stop)
        old = (state[0], state[1], state[2], state[3], state[4], state[5],
               jnp.array(True))
        return jax.tree.map(lambda a, b: jnp.where(stop, b, a), new, old)

    state = (pool_d, pool_id, w_d, w_id, visited, stats, jnp.array(False))
    pool_d, pool_id, w_d, w_id, visited, stats, _ = jax.lax.while_loop(
        cond, body, state)
    return w_d, w_id, (pool_d, pool_id), visited, stats


def _pool_insert(pool_d, pool_id, cand_d, cand_id):
    P = pool_d.shape[0]
    d = jnp.concatenate([pool_d, cand_d])
    i = jnp.concatenate([pool_id, cand_id])
    nd, pos = topk_smallest(d, P)
    ni = i[pos]
    nd = jnp.where(ni >= 0, nd, INF)
    return nd, ni


# ---------------------------------------------------------------------------
# Top-level strategy entry points
# ---------------------------------------------------------------------------

def _finalize(w_d, w_id, bitmap, k, check_filter: bool):
    """Top-k filter-passing results out of W (W is sorted ascending)."""
    if check_filter:
        ok = probe_bitmap(bitmap, w_id) & (w_id >= 0)
    else:
        ok = w_id >= 0
    d = jnp.where(ok, w_d, INF)
    dk, pos = topk_smallest(d, k)
    ids = jnp.where(jnp.isinf(dk), -1, w_id[pos])
    return dk, ids


def _rerank_beam(store: VectorStore, q, w_id, stats: SearchStats):
    """Exact full-precision rescore of the final result beam — the
    quantized-traversal tier's recall bound (DESIGN.md §9).  Every valid
    beam entry is re-fetched from the full-width heap and re-scored
    exactly, ScaNN-reorder-style: counted in reorder_rows, charged
    full-width heap pages and one distance comp per row.  Returns the
    beam's exact distances (same slots) + updated stats."""
    valid = w_id >= 0
    exact = jnp.where(valid, _gather_vec_dist(store, q, w_id), INF)
    n_r = valid.sum().astype(jnp.int32)
    ppv_full = heap_pages_per_vector(store.dim)
    stats = SearchStats(stats.distance_comps + n_r, stats.filter_checks,
                        stats.hops, stats.page_accesses_index,
                        stats.page_accesses_heap + n_r * ppv_full,
                        stats.tmap_lookups, stats.reorder_rows + n_r)
    return exact, stats


def _iter_emit_sq8(store: VectorStore, q, w_d, w_id, bitmap, eff, k: int,
                   r: int):
    """Quantized iterative-scan emit: post-filter the in-batch candidates,
    take the top-r by quantized distance (the EFMAX buffer is too wide to
    rerank whole — ScaNN-reorder-style budget r = k·reorder_factor), and
    re-score those exactly from the full-precision heap.  Returns
    (dists (k,), ids (k,), n_reranked, cand_rows (r,) -1-padded)."""
    efmax = w_d.shape[0]
    in_batch = jnp.arange(efmax) < eff
    d = jnp.where(in_batch, w_d, INF)
    ids = jnp.where(in_batch, w_id, -1)
    passing = probe_bitmap(bitmap, ids) & (ids >= 0)
    rd, rpos = topk_smallest(jnp.where(passing, d, INF), r)
    cand = jnp.where(jnp.isfinite(rd), ids[rpos], -1)
    exact = jnp.where(cand >= 0, _gather_vec_dist(store, q, cand), INF)
    dk, pos = topk_smallest(exact, k)
    out = jnp.where(jnp.isinf(dk), -1, cand[pos])
    return dk, out, (cand >= 0).sum().astype(jnp.int32), cand


def _search_single(graph: HNSWGraph, store: VectorStore, q, bitmap,
                   params: SearchParams):
    quant = params.graph_quant
    stats = SearchStats.zeros()
    entry, entry_d, stats, _ = _zoom_in(graph, store, q, stats, quant=quant)
    if params.strategy == "iterative_scan":
        return _iterative_scan(graph, store, q, bitmap, params, entry,
                               entry_d, stats)
    w_d, w_id, _, _, stats = _base_search(
        graph, store, q, bitmap, params, entry, entry_d, stats,
        ef_result=params.ef_search)
    if quant == "sq8" and params.sq8_rerank:
        w_d, stats = _rerank_beam(store, q, w_id, stats)
    check = params.strategy in ("unfiltered",)
    dk, ids = _finalize(w_d, w_id, bitmap, params.k,
                        check_filter=not check)
    return dk, ids, stats


def _iterative_scan(graph: HNSWGraph, store: VectorStore, q, bitmap,
                    params: SearchParams, entry, entry_d,
                    stats: SearchStats):
    """pgvector 0.8.0 iterative scan: unfiltered traversal, post-filter the
    emitted batch, resume from preserved state until k passing results.

    State preservation (the paper's discarded-queue D) falls out of the beam
    representation: the pool retains seen-but-unexpanded candidates, and the
    result buffer W_raw keeps everything ever emitted, so "resuming" is just
    continuing the same loop with a larger effective ef.
    """
    n = graph.n
    P = params.beam_width
    quant = params.graph_quant
    ppv = _ppv(store, quant)
    EFMAX = params.batch_tuples * params.max_rounds

    pool_d = jnp.full((P,), INF).at[0].set(entry_d)
    pool_id = jnp.full((P,), -1, jnp.int32).at[0].set(entry)
    visited = jnp.zeros((n,), bool).at[entry].set(True)
    w_d = jnp.full((EFMAX,), INF).at[0].set(entry_d)
    w_id = jnp.full((EFMAX,), -1, jnp.int32).at[0].set(entry)

    def cond(state):
        *_, done = state
        return ~done

    def body(state):
        pool_d, pool_id, w_d, w_id, visited, st, eff, rnd, checked, done = state
        j = jnp.argmin(pool_d)
        best_d, best_id = pool_d[j], pool_id[j]
        w_worst = w_d[jnp.minimum(eff, EFMAX) - 1]
        over = _budget_over(st, params, store.dim)
        batch_done = (best_d > w_worst) | jnp.isinf(best_d) | \
            (st.hops >= params.max_hops)
        if over is not None:
            batch_done = batch_done | over

        # ---- resume/emit path: filter the batch, maybe extend the scan
        n_pass = (probe_bitmap(bitmap, w_id) &
                  (jnp.arange(EFMAX) < eff) & (w_id >= 0)).sum()
        newly = jnp.maximum(jnp.minimum(eff, EFMAX) - checked, 0)
        fc_emit = jnp.where(batch_done, newly, 0)
        tm_emit = jnp.where(params.translation_map, fc_emit, 0)
        pai_emit = jnp.where(params.translation_map, 0, fc_emit)
        enough = n_pass >= params.k
        exhausted = jnp.isinf(best_d) | (st.hops >= params.max_hops) | \
            (rnd + 1 >= params.max_rounds)
        if over is not None:
            exhausted = exhausted | over
        finish = batch_done & (enough | exhausted)
        eff2 = jnp.where(batch_done & ~finish, eff + params.batch_tuples, eff)
        rnd2 = jnp.where(batch_done & ~finish, rnd + 1, rnd)
        checked2 = jnp.where(batch_done, jnp.minimum(eff, EFMAX), checked)

        # ---- normal expansion path (only applied when ~batch_done)
        pool_d2 = pool_d.at[j].set(INF)
        pool_id2 = pool_id.at[j].set(-1)
        e = _expand(graph, store, q, bitmap, jnp.maximum(best_id, 0), visited,
                    two_hop=False, quant=quant)
        score_m = e["unv1"]
        n_s = score_m.sum()
        cd = jnp.where(score_m, e["d1"], INF)
        cid = jnp.where(score_m, e["nb1"], -1)
        pool_d2, pool_id2 = _pool_insert(pool_d2, pool_id2, cd, cid)
        # scatter-max: order-safe for the -1 → slot-0 padding collisions
        visited2 = visited.at[jnp.maximum(e["nb1"], 0)].max(score_m)
        w_d2, w_id2 = _insert_sorted(w_d, w_id, cd, cid)

        st2 = SearchStats(
            st.distance_comps + jnp.where(batch_done, 0, n_s),
            st.filter_checks + fc_emit,
            st.hops + jnp.where(batch_done, 0, 1),
            st.page_accesses_index + jnp.where(batch_done, 0, 1) + pai_emit,
            st.page_accesses_heap + jnp.where(batch_done, 0, n_s * ppv),
            st.tmap_lookups + tm_emit, st.reorder_rows)

        sel = lambda a, b: jax.tree.map(
            lambda x, y: jnp.where(batch_done, x, y), a, b)
        pool_d3, pool_id3, w_d3, w_id3, visited3 = sel(
            (pool_d, pool_id, w_d, w_id, visited),
            (pool_d2, pool_id2, w_d2, w_id2, visited2))
        return (pool_d3, pool_id3, w_d3, w_id3, visited3, st2, eff2, rnd2,
                checked2, finish)

    state = (pool_d, pool_id, w_d, w_id, visited, stats,
             jnp.int32(params.batch_tuples), jnp.int32(0), jnp.int32(0),
             jnp.array(False))
    pool_d, pool_id, w_d, w_id, visited, stats, eff, rnd, checked, _ = \
        jax.lax.while_loop(cond, body, state)
    if quant == "sq8" and params.sq8_rerank:
        r = min(params.k * params.reorder_factor, EFMAX)
        dk, out_ids, n_r, _ = _iter_emit_sq8(store, q, w_d, w_id, bitmap,
                                             eff, params.k, r)
        ppv_full = heap_pages_per_vector(store.dim)
        stats = SearchStats(stats.distance_comps + n_r, stats.filter_checks,
                            stats.hops, stats.page_accesses_index,
                            stats.page_accesses_heap + n_r * ppv_full,
                            stats.tmap_lookups, stats.reorder_rows + n_r)
        return dk, out_ids, stats
    in_batch = jnp.arange(EFMAX) < eff
    d = jnp.where(in_batch, w_d, INF)
    ids = jnp.where(in_batch, w_id, -1)
    dk, pos = topk_smallest(
        jnp.where(probe_bitmap(bitmap, ids) & (ids >= 0), d, INF), params.k)
    out_ids = jnp.where(jnp.isinf(dk), -1, ids[pos])
    return dk, out_ids, stats


@partial(jax.jit, static_argnames=("params", "use_pallas", "collect_trace"))
def search_batch(graph: HNSWGraph, store: VectorStore, queries, bitmaps,
                 params: SearchParams, use_pallas: bool = False,
                 collect_trace: bool = False, excl=None):
    """Batched filtered graph search. queries (Q, d), bitmaps (Q, words).

    `params.graph_exec_mode` picks the engine (DESIGN.md §7):

      "frontier"  — batch-synchronous superstep engine: all queries advance
                    one hop per superstep, candidate vectors are fetched
                    through a deduplicated union block (Pallas path),
                    scoring is chunked to the candidates each strategy
                    actually needs (fused `frontier_scan` kernel / oracle,
                    lazy 2-hop + visited-probe dedup for filter-first),
                    visited sets live in packed uint32 bitsets, and the
                    pool pop folds into the insertion merge.  Bit-identical
                    ids/dists/SearchStats to the legacy path
                    (tests/test_frontier.py).
      "vmapped"   — the legacy per-query beam loop under `jax.vmap`, kept
                    as the equivalence oracle and microbenchmark baseline.

    Returns (dists (Q, k), ids (Q, k), SearchStats with (Q,) leaves).

    `params.graph_quant` picks the traversal tier (DESIGN.md §9):

      "none"  — classic full-precision traversal (bit-identical to the
                pre-quantization engines).
      "sq8"   — both engines navigate over the store's SQ8 shadow rows
                (int8 fetches + dequantized scoring; the fused
                `frontier_scan_sq8` kernel on the Pallas path) and the
                final result beam is exactly re-scored from the
                full-precision heap (ScaNN-reorder-style: reorder_rows +
                full-width heap pages).  Needs a `quantize_store`d store.

    `collect_trace=True` (frontier engine only) additionally returns a
    storage-access trace — per-query FIRST-TOUCH superstep stamps over
    the heap rows fetched during traversal and the graph nodes whose
    adjacency entries were read (DESIGN.md §8; `TRACE_UNTOUCHED` where
    never touched) — as a 4th element
    `{"heap_steps": (Q, n) int32, "index_steps": (Q, n) int32}`, plus
    `"rerank_rows": (Q, r) int32` (-1-padded, candidate order) under
    graph_quant="sq8".  The storage engine replays pages in stamp order,
    so LRU behavior is traversal-order-faithful.  ids/dists/stats are
    bit-identical with the flag on or off (the trace stamps are
    write-only bookkeeping).
    """
    if params.graph_quant not in GRAPH_QUANT_MODES:
        raise ValueError(f"unknown graph_quant {params.graph_quant!r}; "
                         f"expected one of {GRAPH_QUANT_MODES}")
    if params.graph_quant == "sq8" and store.q_vectors is None:
        raise ValueError("graph_quant='sq8' needs an SQ8 shadow store; "
                         "build it with core.types.quantize_store")
    mode = params.graph_exec_mode
    # FAVOR exclusion pruning (DESIGN.md §14): like graph_quant, the knob
    # and its data must agree, and "none" traces nothing — the jitted
    # program is identical to the pre-exclusion engine.
    if params.exclusion not in ("none", "prune", "prune_exact"):
        raise ValueError(f"unknown exclusion {params.exclusion!r}; "
                         "expected 'none', 'prune' or 'prune_exact'")
    if params.exclusion != "none":
        if excl is None:
            raise ValueError(f"exclusion={params.exclusion!r} needs "
                             "per-query radii (excl=(Q, n) f32; "
                             "core.exclusion)")
        if params.strategy != "sweeping":
            raise ValueError("exclusion pruning is a sweeping-strategy "
                             f"tier (got strategy={params.strategy!r})")
        if store.metric != "l2":
            raise ValueError("exclusion pruning requires metric='l2' "
                             f"(got {store.metric!r})")
        if mode != "frontier":
            raise ValueError("exclusion pruning needs the frontier engine "
                             "(graph_exec_mode='frontier')")
        if isinstance(store, ShardStore):
            raise ValueError("exclusion pruning is not supported on "
                             "sharded stores")
        if not params.exclusion_margin > 0.0:
            raise ValueError("exclusion_margin must be > 0 (0 would prune "
                             "everything once W fills)")
    elif excl is not None:
        raise ValueError("excl radii passed but params.exclusion='none'")
    if mode == "vmapped":
        if collect_trace:
            raise ValueError("storage traces need the frontier engine "
                             "(graph_exec_mode='frontier')")
        return jax.vmap(
            lambda q, b: _search_single(graph, store, q, b, params))(
                queries, bitmaps)
    if mode != "frontier":
        raise ValueError(f"unknown graph_exec_mode {mode!r}; "
                         "expected 'frontier' or 'vmapped'")
    return _frontier_search_batch(graph, store, queries, bitmaps, params,
                                  use_pallas, collect_trace, excl=excl)


# ===========================================================================
# Batch-synchronous frontier engine (DESIGN.md §7).
#
# The legacy path above runs Q independent beam searches under `jax.vmap`;
# every query re-gathers its own neighborhood vectors from HBM each hop and
# re-sorts its pool/W with a full `lax.top_k`.  The frontier engine keeps
# the *same per-query state machine* (same pop order, same masks, same
# counter formulas — bit-identical outputs) but restructures each
# superstep's hot work batch-wide:
#
#   * candidate vectors are fetched once per superstep through the
#     deduplicated union of every query's candidates (`_union_gather`);
#   * only candidates a strategy actually needs distances for are scored —
#     compacted and processed in fixed-size chunks through the fused
#     `frontier_scan` kernel/oracle (lazy 2-hop for filter-first);
#   * per-query visited sets are packed uint32 bitsets probed with the
#     same machinery as the filter bitmaps;
#   * the filter-first 2-hop stage is lazy: only passing/unvisited/
#     deduplicated survivors are gathered and scored, with the legacy
#     per-hop argsort dedup replaced by chunked visited-probe dedup;
#   * the pool stays sorted (so the pop is always slot 0) and the pop is
#     folded into the insertion merge (`_merge_smallest`).
# ===========================================================================


def _compact_positions(mask, pad_to: int):
    """Positions of True entries of `mask`, in order, -1-padded to pad_to.

    Gather-only (cumsum + searchsorted): XLA CPU scatters cost ~250 ns per
    scalar update, so the scatter formulation would dominate a superstep.
    """
    m = mask.shape[0]
    cs = jnp.cumsum(mask.astype(jnp.int32))
    pos = jnp.searchsorted(cs, jnp.arange(1, pad_to + 1, dtype=jnp.int32))
    return jnp.where(jnp.arange(pad_to) < cs[m - 1], pos.astype(jnp.int32),
                     -1)


def _union_gather(store: VectorStore, ids, dedup: bool,
                  quant: str = "none"):
    """Fetch vectors (+ norms) for a (Q, C) id block.

    With `dedup` (the Pallas/TPU path) the fetch goes through the
    deduplicated union: each distinct node is gathered from the (n, d)
    HBM store once per call, then per-query rows are re-gathered from the
    small union block — the frontier fetch-amortization (DESIGN.md §7).
    Without it (the CPU oracle path) rows are gathered directly; gathers
    preserve values exactly, so downstream distances are bit-identical
    either way.  quant="sq8" gathers the int8 shadow rows (4× less HBM
    traffic per candidate; dequantization happens downstream, in-kernel
    on the Pallas path) with the precomputed dequantized norms.
    """
    qn, c = ids.shape
    rows = store.q_vectors if quant == "sq8" else store.vectors
    norms = store.q_norms_sq if quant == "sq8" else store.norms_sq
    safe = jnp.maximum(ids, 0)
    if not dedup:
        return rows[safe], norms[safe]
    flat = safe.reshape(-1).astype(jnp.int32)
    s = jnp.sort(flat)
    firsts = jnp.concatenate([jnp.array([True]), s[1:] != s[:-1]])
    rank = jnp.cumsum(firsts) - 1
    uniq = jnp.full((qn * c,), store.n, jnp.int32).at[rank].set(s)
    pos = jnp.searchsorted(uniq, flat)
    safe_u = jnp.minimum(uniq, store.n - 1)
    blk = rows[safe_u]                          # the one HBM fetch per node
    bn = norms[safe_u]
    return blk[pos].reshape(qn, c, -1), bn[pos].reshape(qn, c)


def _frontier_scores(queries, store: VectorStore, cids, bitmaps,
                     use_pallas: bool, quant: str):
    """Deduplicated-union fetch + fused scoring/filter-probe of one
    candidate block, dispatched per quant tier (DESIGN.md §7/§9).

    On a `ShardStore` (DESIGN.md §13) the candidate rows are gathered
    from the local block by ownership (bypassing `_union_gather`, whose
    dedup sentinel indexes with the global n) and scored through the same
    fused kernel; the owner-mask + collective `pmin` then reconstructs
    the single-device distances bit-exactly (the kernel masks invalid ids
    to +inf on every shard identically, and the filter-probe half is a
    pure function of the replicated bitmaps + ids)."""
    if isinstance(store, ShardStore):
        safe = jnp.maximum(cids, 0)
        off = store.offset
        own = (safe >= off) & (safe < off + store.local_n)
        local = jnp.clip(safe - off, 0, store.local_n - 1)
        if quant == "sq8":
            d, pass_ = kops.frontier_scan_sq8(
                queries, store.q_vectors[local], store.q_scale,
                store.q_mean, store.q_norms_sq[local], cids, bitmaps,
                metric=store.metric, use_pallas=use_pallas)
        else:
            d, pass_ = kops.frontier_scan(
                queries, store.vectors[local], store.norms_sq[local], cids,
                bitmaps, metric=store.metric, use_pallas=use_pallas)
        d = jnp.where(own, d, INF)
        if store.collective:
            d = jax.lax.pmin(d, store.axis)
        return d, pass_
    vecs, nsq = _union_gather(store, cids, dedup=use_pallas, quant=quant)
    if quant == "sq8":
        return kops.frontier_scan_sq8(queries, vecs, store.q_scale,
                                      store.q_mean, nsq, cids, bitmaps,
                                      metric=store.metric,
                                      use_pallas=use_pallas)
    return kops.frontier_scan(queries, vecs, nsq, cids, bitmaps,
                              metric=store.metric, use_pallas=use_pallas)


def _frontier_scores_excl(queries, store: VectorStore, cids, bitmaps,
                          use_pallas: bool, quant: str, excl, tau,
                          margin: float):
    """`_frontier_scores` + the fused FAVOR keep mask (DESIGN.md §14).

    excl (Q, n) per-query squared exclusion radii; the chunk's per-row
    radii ride the same compacted id block as the vectors (one extra
    take_along_axis, zero extra HBM round trips through the heap).
    tau (Q,) current W tail.  Plain stores only (search_batch rejects
    sharded stores under exclusion).  Returns (dists, pass, keep)."""
    e = jnp.take_along_axis(excl, jnp.maximum(cids, 0), axis=1)
    vecs, nsq = _union_gather(store, cids, dedup=use_pallas, quant=quant)
    if quant == "sq8":
        return kops.frontier_scan_excl_sq8(
            queries, vecs, store.q_scale, store.q_mean, nsq, cids, bitmaps,
            e, tau[:, None], metric=store.metric, margin=margin,
            use_pallas=use_pallas)
    return kops.frontier_scan_excl(queries, vecs, nsq, cids, bitmaps, e,
                                   tau[:, None], metric=store.metric,
                                   margin=margin, use_pallas=use_pallas)


def _merge_smallest(buf_d, buf_id, cand_d, cand_id, drop_head=None):
    """Keep the B smallest of buffer ∪ candidates, sorted ascending.

    This is exactly the legacy `_pool_insert`/`_insert_sorted` concat +
    `topk_smallest` (same multiset, same stable tie order: buffer entries
    first, then candidates in order), batched over queries — measured
    faster on CPU than rank-merge or scatter formulations at the queue
    widths the engine runs (lax.top_k's sort machinery wins once the
    buffer is register-tiled).  `drop_head` (per-row bool) additionally
    drops the buffer's slot 0 — the pool pop, folded in as a masked shift
    so popping never rebuilds the pool separately.
    """
    qn, b = buf_d.shape
    if drop_head is not None:
        sd = jnp.concatenate([buf_d[:, 1:], jnp.full((qn, 1), INF)], 1)
        si = jnp.concatenate(
            [buf_id[:, 1:], jnp.full((qn, 1), -1, jnp.int32)], 1)
        buf_d = jnp.where(drop_head[:, None], sd, buf_d)
        buf_id = jnp.where(drop_head[:, None], si, buf_id)
    d = jnp.concatenate([buf_d, cand_d], 1)
    i = jnp.concatenate([buf_id, cand_id], 1)

    def one(dq, iq):
        nd, pos = topk_smallest(dq, b)
        return nd, iq[pos]

    return jax.vmap(one)(d, i)


def _probe_batch(words, ids):
    """Per-query packed-bitset probe: (Q, W) words × (Q, ...) ids."""
    flat = ids.reshape(ids.shape[0], -1)
    return jax.vmap(probe_bitmap)(words, flat).reshape(ids.shape)


_mark_batch = jax.vmap(bitset_mark)


def _score_insert_chunks(queries, bitmaps, store, cand_ids, sel_mask,
                         chunk: int, pool, w, visited, use_pallas: bool,
                         sweep_worst=None, dedup: bool = False,
                         drop_head=None, quant: str = "none",
                         excl=None, excl_margin: float = 0.5,
                         excl_exact: bool = False):
    """Score the selected candidates chunk-at-a-time and merge them into
    the pool and result queue, marking them visited as chunks complete.

    cand_ids (Q, m) int32, sel_mask (Q, m): candidates needing distances.
    Chunks walk the compacted positions in flat order, so insertion order
    (and hence tie behaviour) matches the legacy single-shot insert.

    When `sweep_worst` is given (sweeping), W-insertion is gated by
    d < sweep_worst (captured at superstep start, like the legacy body)
    AND the filter probe, and the per-query would-enter-W count is
    returned (the sweeping filter-check counter).

    With `dedup` (filter-first 2-hop), candidates already marked visited —
    by a previous chunk or by the pre-marked 1-hop stage — are dropped,
    and first-occurrence wins inside a chunk: together this reproduces the
    legacy `_dedup_first` over the whole concat, one small chunk at a
    time, without its O(m log m) argsort over the full 2-hop block.
    Without `dedup` the caller guarantees distinct candidates (neighbor
    lists are duplicate-free) and marking happens inside the loop anyway.

    `drop_head` (per-query bool) folds the superstep's pool pop into the
    first insertion.

    `excl` ((Q, n) squared radii, sweeping only) switches scoring to the
    fused excl kernels and gates POOL insertion on the keep mask
    (DESIGN.md §14): a dropped candidate keeps its distance in this
    superstep (dc/pah already paid, W eligibility and the would-enter-W
    filter-check count unchanged, visited marked) but never enters the
    pool — its branch is never popped, so all downstream hops, filter
    checks and pages vanish.  tau is `sweep_worst`, captured at superstep
    start like the legacy W gate (+inf until W fills, so the navigation
    phase is never pruned).  `excl_exact` (family-exact radii, where
    e = 0 iff the row passes) additionally stops charging filter checks
    for pruned candidates — the radius test PROVES them non-passing, so
    the bitmap probe FAVOR eliminates is not counted (the probe's other
    consumer, W insertion, is a no-op for them: pass ⇒ keep means a
    pruned candidate never passes).

    Returns (pool_d, pool_id, w_d, w_id, visited, n_would).
    """
    qn, m = cand_ids.shape
    c = m if chunk <= 0 else min(chunk, m)
    pool_d, pool_id = pool
    w_d, w_id = w

    def score(cids):
        if excl is not None:
            return _frontier_scores_excl(queries, store, cids, bitmaps,
                                         use_pallas, quant, excl,
                                         sweep_worst, excl_margin)
        dch, pch = _frontier_scores(queries, store, cids, bitmaps,
                                    use_pallas, quant)
        return dch, pch, None

    def insert(pd, pi, wd, wi, cd, cids, pch, keep, nw, drop):
        if sweep_worst is not None:
            would = (cids >= 0) & (cd < sweep_worst[:, None])
            charged = would & keep if (excl_exact and keep is not None) \
                else would
            nw = nw + charged.sum(-1).astype(jnp.int32)
            wd_in = jnp.where(would & pch, cd, INF)
            wi_in = jnp.where(would & pch, cids, -1)
        else:
            wd_in, wi_in = cd, cids
        cd_pool = cd if keep is None else jnp.where(keep, cd, INF)
        ci_pool = cids if keep is None else jnp.where(keep, cids, -1)
        pd, pi = _merge_smallest(pd, pi, cd_pool, ci_pool, drop)
        wd, wi = _merge_smallest(wd, wi, wd_in, wi_in)
        return pd, pi, wd, wi, nw

    if c >= m:
        # single-chunk fast path: no compaction, no inner loop — score the
        # masked candidates in place (at 1-hop width the compaction
        # machinery costs more than the gathers it would save)
        nw = jnp.zeros((qn,), jnp.int32)
        cids = jnp.where(sel_mask, cand_ids, -1)
        if dedup:
            seen = jax.vmap(probe_bitmap)(visited, cids)
            first = jax.vmap(_dedup_first)(cids)
            cids = jnp.where(first & ~seen, cids, -1)
        valid = cids >= 0
        dch, pch, keep = score(cids)
        cd = jnp.where(valid, dch, INF)
        pool_d, pool_id, w_d, w_id, nw = insert(
            pool_d, pool_id, w_d, w_id, cd, cids, pch, keep, nw, drop_head)
        visited = _mark_batch(visited, cids, valid)
        return pool_d, pool_id, w_d, w_id, visited, nw

    # chunked path: pop up front (the loop may run zero iterations)
    if drop_head is not None:
        pool_d = jnp.where(
            drop_head[:, None],
            jnp.concatenate([pool_d[:, 1:], jnp.full((qn, 1), INF)], 1),
            pool_d)
        pool_id = jnp.where(
            drop_head[:, None],
            jnp.concatenate(
                [pool_id[:, 1:], jnp.full((qn, 1), -1, jnp.int32)], 1),
            pool_id)
    padlen = -(-m // c) * c
    pos = jax.vmap(lambda mk: _compact_positions(mk, padlen))(sel_mask)
    count = sel_mask.sum(-1)

    def chunk_cond(cs):
        return (cs[0] * c < count).any()

    def chunk_body(cs):
        i, pd, pi, wd, wi, vis, nw = cs
        cpos = jax.lax.dynamic_slice_in_dim(pos, i * c, c, axis=1)
        valid = cpos >= 0
        cids = jnp.where(
            valid, jnp.take_along_axis(cand_ids, jnp.maximum(cpos, 0), 1),
            -1)
        if dedup:
            seen = jax.vmap(probe_bitmap)(vis, cids)
            first = jax.vmap(_dedup_first)(cids)
            cids = jnp.where(first & ~seen, cids, -1)
        valid = cids >= 0
        dch, pch, keep = score(cids)
        cd = jnp.where(valid, dch, INF)
        pd, pi, wd, wi, nw = insert(pd, pi, wd, wi, cd, cids, pch, keep,
                                    nw, None)
        vis = _mark_batch(vis, cids, valid)
        return i + 1, pd, pi, wd, wi, vis, nw

    _, pool_d, pool_id, w_d, w_id, visited, n_would = jax.lax.while_loop(
        chunk_cond, chunk_body,
        (jnp.int32(0), pool_d, pool_id, w_d, w_id, visited,
         jnp.zeros((qn,), jnp.int32)))
    return pool_d, pool_id, w_d, w_id, visited, n_would


def _base_state_init(graph: HNSWGraph, store: VectorStore, bitmaps,
                     params: SearchParams, entry, entry_d, ef_result: int):
    """Initial (pool, W, visited) lane state of the base frontier engine —
    shared by the one-shot driver and the stepped `frontier_init` so the
    two paths start from bit-identical state."""
    qn = entry.shape[0]
    p = params.beam_width
    nw = bitset_words(graph.n)
    pool_d = jnp.full((qn, p), INF).at[:, 0].set(entry_d)
    pool_id = jnp.full((qn, p), -1, jnp.int32).at[:, 0].set(entry)
    visited = _mark_batch(jnp.zeros((qn, nw), jnp.uint32), entry[:, None],
                          jnp.ones((qn, 1), bool))
    w_d = jnp.full((qn, ef_result), INF)
    w_id = jnp.full((qn, ef_result), -1, jnp.int32)
    entry_pass = _probe_batch(bitmaps, entry[:, None])[:, 0]
    seed_ok = entry_pass | (params.strategy in ("unfiltered",
                                                "iterative_scan"))
    w_d = jnp.where(seed_ok[:, None], w_d.at[:, 0].set(entry_d), w_d)
    w_id = jnp.where(seed_ok[:, None], w_id.at[:, 0].set(entry), w_id)
    return pool_d, pool_id, w_d, w_id, visited


def _base_superstep(graph: HNSWGraph, store: VectorStore, queries, bitmaps,
                    params: SearchParams, ef_result: int, use_pallas: bool,
                    tracing: bool, deadline, excl, state):
    """One superstep of the base (non-iterative) frontier engine.

    `state` is the 9-tuple (pool_d, pool_id, w_d, w_id, visited, hs, is_,
    stats, done); the function is the exact loop body of the one-shot
    `lax.while_loop` AND the unit the external driver steps in fixed-hop
    chunks (`step_supersteps`) — shared verbatim so chunked execution is
    bit-identical by construction.  A fully-done lane is an exact no-op
    (pops suppressed, all-INF merges, masked counters), so applying the
    body past a lane's stop point never changes its state — that is what
    makes mid-flight slot retire/admit sound.  `deadline` is the optional
    per-lane (Q,) float32 deadline array (see `_budget_over`).  `excl` is
    the optional (Q, n) exclusion-radii block (sweeping only, DESIGN.md
    §14) — None traces nothing, keeping the jaxpr identical to the
    pre-exclusion body.
    """
    qn = queries.shape[0]
    strat = params.strategy
    quant = params.graph_quant
    ppv = _ppv(store, quant)
    deg = graph.neighbors.shape[2]
    tm_on = params.translation_map
    we_idx = params.ef_search - 1 if ef_result >= params.ef_search \
        else ef_result - 1

    pool_d, pool_id, w_d, w_id, visited, hs, is_, st, done = state
    # the pool is kept sorted ascending, so the legacy argmin-pop is
    # always slot 0; the pop itself is folded into the insertions
    best_d, best_id = pool_d[:, 0], pool_id[:, 0]
    w_worst = w_d[:, we_idx]
    stop = (best_d > w_worst) | jnp.isinf(best_d) | \
        (st.hops >= params.max_hops)
    over = _budget_over(st, params, store.dim, deadline)
    if over is not None:
        stop = stop | over
    active = ~done & ~stop
    node = jnp.maximum(best_id, 0)
    step = st.hops + 1          # this superstep's post-increment stamp
    if tracing:   # adjacency read of the popped node (step ①)
        is_ = _stamp_batch(is_, node[:, None], active[:, None], step)

    nb1 = _adj(graph, 0, node)                           # (Q, deg)
    v1 = nb1 >= 0
    unv1 = v1 & ~_probe_batch(visited, nb1)

    z = jnp.zeros((qn,), jnp.int32)
    dc = fc = pai = pah = tm = z
    pai = pai + 1                      # step ①: current node's index page

    if strat in ("unfiltered", "sweeping"):
        # -------- traversal-first: score every unvisited 1-hop neighbor
        score_m = unv1
        n_s = score_m.sum(-1).astype(jnp.int32)
        dc = dc + n_s
        pah = pah + n_s * ppv
        (pool_d2, pool_id2, w_d2, w_id2, visited2,
         n_w) = _score_insert_chunks(
            queries, bitmaps, store, nb1, score_m & active[:, None],
            params.frontier_chunk, (pool_d, pool_id), (w_d, w_id),
            visited, use_pallas,
            sweep_worst=w_worst if strat == "sweeping" else None,
            drop_head=active, quant=quant,
            excl=excl if strat == "sweeping" else None,
            excl_margin=params.exclusion_margin,
            excl_exact=params.exclusion == "prune_exact")
        if strat == "sweeping":
            fc = fc + n_w
            tm = tm + jnp.where(tm_on, n_w, 0)
            pai = pai + jnp.where(tm_on, 0, n_w)
    else:
        # -------- filter-first (acorn / navix): predicate subgraph
        d1, pass1 = _frontier_scores(queries, store, nb1, bitmaps,
                                     use_pallas, quant)
        n1 = v1.sum(-1).astype(jnp.int32)
        fc = fc + n1                               # check all 1-hop
        tm = tm + jnp.where(tm_on, n1, 0)
        pai = pai + jnp.where(tm_on, 0, n1)
        pass1v = pass1 & v1
        local_sel = pass1v.sum(-1) / jnp.maximum(n1, 1)

        if strat == "acorn":
            do_directed = jnp.zeros((qn,), bool)
            do_twohop_all = jnp.ones((qn,), bool)
        else:  # navix heuristics
            h = params.navix_heuristic
            if h == "blind":
                do_directed = jnp.zeros((qn,), bool)
                do_twohop_all = jnp.ones((qn,), bool)
            elif h == "directed":
                do_directed = jnp.ones((qn,), bool)
                do_twohop_all = jnp.zeros((qn,), bool)
            elif h == "onehop":
                do_directed = jnp.zeros((qn,), bool)
                do_twohop_all = jnp.zeros((qn,), bool)
            else:  # adaptive-local (paper §2.3.4)
                do_directed = (local_sel > 0.08) & (local_sel <= 0.35)
                do_twohop_all = local_sel <= 0.08

        # 1-hop: score the passing, unvisited ones
        s1 = pass1v & unv1
        n_s1 = s1.sum(-1).astype(jnp.int32)
        dc = dc + n_s1
        pah = pah + n_s1 * ppv

        # decide which branches expand to 2 hops
        expand_branch = v1
        if params.adaptive_skip_2hop:
            expand_branch = expand_branch & ~pass1v
        if strat == "navix" and params.navix_heuristic in ("directed",
                                                           "adaptive"):
            rank = jnp.argsort(jnp.where(v1, d1, INF), axis=-1)
            topr = jax.vmap(
                lambda r: jnp.zeros((deg,), bool)
                .at[r[: max(1, deg // 4)]].set(True))(rank)
            directed_branch = expand_branch & topr
            expand_branch = jnp.where(
                do_twohop_all[:, None], expand_branch,
                jnp.where(do_directed[:, None], directed_branch, False))
            extra_rank_dc = jnp.where(
                do_directed, (v1 & ~s1).sum(-1), 0).astype(jnp.int32)
            dc = dc + extra_rank_dc
            pah = pah + extra_rank_dc * ppv
        elif strat == "navix" and params.navix_heuristic == "onehop":
            expand_branch = jnp.zeros_like(expand_branch)

        n_exp = expand_branch.sum(-1).astype(jnp.int32)
        pai = pai + n_exp                          # step ②: branch pages
        if tracing:   # adjacency reads of the expanded branches
            is_ = _stamp_batch(is_, nb1,
                               expand_branch & active[:, None], step)
        nb2 = _adj(graph, 0, jnp.maximum(nb1, 0))       # (Q, deg, deg)
        nb2 = jnp.where(v1[:, :, None], nb2, -1)
        v2 = nb2 >= 0
        pass2 = _probe_batch(bitmaps, nb2)
        unv2 = v2 & ~_probe_batch(visited, nb2)
        m2 = v2 & expand_branch[:, :, None]
        n2 = m2.sum((-2, -1)).astype(jnp.int32)
        fc = fc + n2                               # step ④: 2-hop checks
        tm = tm + jnp.where(tm_on, n2, 0)
        pai = pai + jnp.where(tm_on, 0, n2)
        s2 = m2 & pass2 & unv2
        n_s2 = s2.sum((-2, -1)).astype(jnp.int32)
        dc = dc + n_s2                             # step ⑤
        pah = pah + n_s2 * ppv

        # 1-hop insertion + marking first (neighbor lists are
        # duplicate-free, so every s1 candidate is a first occurrence
        # of the legacy concat dedup); the pool pop rides along
        ins1 = s1 & active[:, None]
        in1_d = jnp.where(ins1, d1, INF)
        in1_i = jnp.where(ins1, nb1, -1)
        pool_d2, pool_id2 = _merge_smallest(pool_d, pool_id, in1_d,
                                            in1_i, active)
        w_d2, w_id2 = _merge_smallest(w_d, w_id, in1_d, in1_i)
        visited2 = _mark_batch(visited, nb1, ins1)
        # lazy 2-hop: survivors of the chunked visited-probe dedup are
        # the exact survivors of the legacy `_dedup_first` (1-hop
        # occurrences were just marked, earlier chunks mark as they go)
        cid2 = jnp.where(s2, nb2, -1).reshape(qn, deg * deg)
        (pool_d2, pool_id2, w_d2, w_id2, visited2,
         _) = _score_insert_chunks(
            queries, bitmaps, store, cid2, s2.reshape(qn, deg * deg)
            & active[:, None], params.frontier_chunk2,
            (pool_d2, pool_id2), (w_d2, w_id2), visited2, use_pallas,
            dedup=True, quant=quant)

    if tracing:   # this superstep's newly scored rows, in stamp order
        hs = _stamp_newly_marked(hs, visited, visited2, step)
    inc = lambda v: jnp.where(active, v, 0)
    st2 = SearchStats(st.distance_comps + inc(dc),
                      st.filter_checks + inc(fc),
                      st.hops + inc(jnp.int32(1)),
                      st.page_accesses_index + inc(pai),
                      st.page_accesses_heap + inc(pah),
                      st.tmap_lookups + inc(tm), st.reorder_rows)
    return (pool_d2, pool_id2, w_d2, w_id2, visited2, hs, is_, st2,
            done | stop)


def _frontier_base(graph: HNSWGraph, store: VectorStore, queries, bitmaps,
                   params: SearchParams, entry, entry_d, stats: SearchStats,
                   ef_result: int, use_pallas: bool, trace=None, excl=None):
    """Superstep-driven port of `_base_search` over the whole query batch.

    Per-query control flow (pop order, masks, counter formulas) matches the
    legacy body exactly; only the physical execution differs (chunked
    need-only scoring, packed visited, fold-the-pop merges).  Stopped/finished
    lanes are frozen by gating: their pops are suppressed, their candidate
    masks zeroed (an all-INF merge is an exact identity), and their counter
    increments masked — the same per-lane semantics the legacy vmapped
    while_loop provides by select.  `trace` (optional (heap_steps,
    index_steps) (Q, n) int32 first-touch stamps, zoom-in already applied)
    accumulates the storage trace: adjacency reads (popped nodes, plus
    expanded branch nodes for filter-first) stamp index_steps; each
    superstep's newly scored rows stamp heap_steps with the post-increment
    hop counter, so replay order is superstep-faithful (DESIGN.md §8).
    The loop body is `_base_superstep` — the exact unit `step_supersteps`
    drives externally in fixed-hop chunks (DESIGN.md §11).
    Returns (W_d, W_id sorted asc, stats, (heap_steps, index_steps)-or-None).
    """
    tracing = trace is not None
    hs, is_ = trace if tracing else \
        (jnp.zeros((queries.shape[0], 0), jnp.int32),) * 2
    qn = queries.shape[0]
    pool_d, pool_id, w_d, w_id, visited = _base_state_init(
        graph, store, bitmaps, params, entry, entry_d, ef_result)
    body = partial(_base_superstep, graph, store, queries, bitmaps, params,
                   ef_result, use_pallas, tracing, None, excl)
    state = (pool_d, pool_id, w_d, w_id, visited, hs, is_, stats,
             jnp.zeros((qn,), bool))
    pool_d, pool_id, w_d, w_id, visited, hs, is_, stats, _ = \
        jax.lax.while_loop(lambda s: ~s[-1].all(), body, state)
    return w_d, w_id, stats, ((hs, is_) if tracing else None)


def _iter_state_init(graph: HNSWGraph, store: VectorStore, bitmaps,
                     params: SearchParams, entry, entry_d):
    """Initial (pool_d, pool_id, W_d, W_id, visited) for the iterative-scan
    superstep engine.  W is the (EFMAX,) resumable result buffer — seeded
    unconditionally with the entry (iterative_scan post-filters at emit
    time), pool seeded at slot 0, entry marked visited."""
    n = graph.n
    qn = entry.shape[0]
    p = params.beam_width
    nw = bitset_words(n)
    efmax = params.batch_tuples * params.max_rounds
    pool_d = jnp.full((qn, p), INF).at[:, 0].set(entry_d)
    pool_id = jnp.full((qn, p), -1, jnp.int32).at[:, 0].set(entry)
    visited = _mark_batch(jnp.zeros((qn, nw), jnp.uint32), entry[:, None],
                          jnp.ones((qn, 1), bool))
    w_d = jnp.full((qn, efmax), INF).at[:, 0].set(entry_d)
    w_id = jnp.full((qn, efmax), -1, jnp.int32).at[:, 0].set(entry)
    return pool_d, pool_id, w_d, w_id, visited


def _iter_superstep(graph: HNSWGraph, store: VectorStore, queries, bitmaps,
                    params: SearchParams, use_pallas: bool, tracing: bool,
                    deadline, state):
    """One superstep of the iterative-scan engine on its 12-tuple state
    `(pool_d, pool_id, w_d, w_id, visited, hs, is_, stats, eff, rnd,
    checked, done)`.

    Exactly one application of the legacy `_frontier_iterative` loop body:
    retired (`done`) lanes are frozen (pops suppressed, merges identity,
    counters masked), so applying the body k extra times to a finished
    lane is a no-op — the property `step_supersteps` relies on
    (DESIGN.md §11).  `deadline` is an optional (Q,) f32 per-lane cycle
    budget (+inf = none) folded into `_budget_over` alongside the static
    `params.deadline_cycles`, feeding both the emit trigger and the
    `exhausted` finish condition like the static budget does.
    """
    (pool_d, pool_id, w_d, w_id, visited, hs, is_, st, eff, rnd, checked,
     done) = state
    quant = params.graph_quant
    ppv = _ppv(store, quant)
    efmax = params.batch_tuples * params.max_rounds
    tm_on = params.translation_map

    best_d, best_id = pool_d[:, 0], pool_id[:, 0]
    w_worst = jnp.take_along_axis(
        w_d, (jnp.minimum(eff, efmax) - 1)[:, None], axis=1)[:, 0]
    over = _budget_over(st, params, store.dim, deadline)
    batch_done = (best_d > w_worst) | jnp.isinf(best_d) | \
        (st.hops >= params.max_hops)
    if over is not None:
        batch_done = batch_done | over
    live = ~done
    active = live & ~batch_done          # lanes that expand this step

    # ---- resume/emit path: filter the batch, maybe extend the scan
    in_batch = jnp.arange(efmax)[None, :] < eff[:, None]
    n_pass = (_probe_batch(bitmaps, w_id) & in_batch &
              (w_id >= 0)).sum(-1)
    newly = jnp.maximum(jnp.minimum(eff, efmax) - checked, 0)
    fc_emit = jnp.where(live & batch_done, newly, 0)
    tm_emit = jnp.where(tm_on, fc_emit, 0)
    pai_emit = jnp.where(tm_on, 0, fc_emit)
    enough = n_pass >= params.k
    exhausted = jnp.isinf(best_d) | (st.hops >= params.max_hops) | \
        (rnd + 1 >= params.max_rounds)
    if over is not None:
        exhausted = exhausted | over
    finish = batch_done & (enough | exhausted)
    extend = live & batch_done & ~finish
    eff2 = jnp.where(extend, eff + params.batch_tuples, eff)
    rnd2 = jnp.where(extend, rnd + 1, rnd)
    checked2 = jnp.where(live & batch_done, jnp.minimum(eff, efmax),
                         checked)

    # ---- normal expansion path (gated to active lanes)
    node = jnp.maximum(best_id, 0)
    step = st.hops + 1
    if tracing:
        is_ = _stamp_batch(is_, node[:, None], active[:, None], step)
    nb1 = _adj(graph, 0, node)
    score_m = (nb1 >= 0) & ~_probe_batch(visited, nb1)
    n_s = score_m.sum(-1).astype(jnp.int32)
    (pool_d2, pool_id2, w_d2, w_id2, visited2,
     _) = _score_insert_chunks(
        queries, bitmaps, store, nb1, score_m & active[:, None],
        params.frontier_chunk, (pool_d, pool_id), (w_d, w_id),
        visited, use_pallas, drop_head=active, quant=quant)
    if tracing:
        hs = _stamp_newly_marked(hs, visited, visited2, step)

    inc = lambda v: jnp.where(active, v, 0)
    st2 = SearchStats(
        st.distance_comps + inc(n_s),
        st.filter_checks + fc_emit,
        st.hops + inc(jnp.int32(1)),
        st.page_accesses_index + inc(jnp.int32(1)) + pai_emit,
        st.page_accesses_heap + inc(n_s * ppv),
        st.tmap_lookups + tm_emit, st.reorder_rows)
    return (pool_d2, pool_id2, w_d2, w_id2, visited2, hs, is_, st2, eff2,
            rnd2, checked2, done | (live & finish))


def _frontier_iterative(graph: HNSWGraph, store: VectorStore, queries,
                        bitmaps, params: SearchParams, entry, entry_d,
                        stats: SearchStats, use_pallas: bool, trace=None):
    """Superstep port of `_iterative_scan` (pgvector resumable post-filter).

    Same per-query emit/resume logic and counters as the legacy body; the
    expansion path shares the traversal-first chunked machinery, and the
    big (EFMAX,) result buffer is maintained with O(EFMAX) gather merges
    instead of a per-hop top_k over EFMAX + 2M candidates.  `trace`
    ((heap_steps, index_steps) first-touch stamps) records adjacency reads
    (popped nodes) and newly scored rows like `_frontier_base`; under
    graph_quant="sq8" the emit reranks through `_iter_emit_sq8`.
    Returns (dists, ids, stats, (heap_steps, index_steps)-or-None,
    rerank_rows-or-None).
    """
    tracing = trace is not None
    hs, is_ = trace if tracing else \
        (jnp.zeros((queries.shape[0], 0), jnp.int32),) * 2
    qn = queries.shape[0]
    pool_d, pool_id, w_d, w_id, visited = _iter_state_init(
        graph, store, bitmaps, params, entry, entry_d)
    body = partial(_iter_superstep, graph, store, queries, bitmaps, params,
                   use_pallas, tracing, None)
    state = (pool_d, pool_id, w_d, w_id, visited, hs, is_, stats,
             jnp.full((qn,), params.batch_tuples, jnp.int32),
             jnp.zeros((qn,), jnp.int32), jnp.zeros((qn,), jnp.int32),
             jnp.zeros((qn,), bool))
    (pool_d, pool_id, w_d, w_id, visited, hs, is_, stats, eff, rnd, checked,
     _) = jax.lax.while_loop(lambda s: ~s[-1].all(), body, state)
    trace_out = (hs, is_) if tracing else None
    quant = params.graph_quant
    efmax = params.batch_tuples * params.max_rounds

    if quant == "sq8" and params.sq8_rerank:
        r = min(params.k * params.reorder_factor, efmax)
        dk, out_ids, n_r, cand = jax.vmap(
            lambda q, wd, wi, bm, e: _iter_emit_sq8(store, q, wd, wi, bm, e,
                                                    params.k, r))(
            queries, w_d, w_id, bitmaps, eff)
        ppv_full = heap_pages_per_vector(store.dim)
        stats = SearchStats(stats.distance_comps + n_r, stats.filter_checks,
                            stats.hops, stats.page_accesses_index,
                            stats.page_accesses_heap + n_r * ppv_full,
                            stats.tmap_lookups, stats.reorder_rows + n_r)
        return dk, out_ids, stats, trace_out, cand

    def emit(d, ids, bm, eff_q):
        in_batch = jnp.arange(efmax) < eff_q
        dm = jnp.where(in_batch, d, INF)
        im = jnp.where(in_batch, ids, -1)
        dk, pos = topk_smallest(
            jnp.where(probe_bitmap(bm, im) & (im >= 0), dm, INF), params.k)
        return dk, jnp.where(jnp.isinf(dk), -1, im[pos])

    dk, out_ids = jax.vmap(emit)(w_d, w_id, bitmaps, eff)
    return dk, out_ids, stats, trace_out, None


def _frontier_search_batch(graph: HNSWGraph, store: VectorStore, queries,
                           bitmaps, params: SearchParams, use_pallas: bool,
                           collect_trace: bool = False, excl=None):
    n = graph.n
    quant = params.graph_quant

    def zoom(q):
        trace = ((jnp.full((n,), TRACE_UNTOUCHED, jnp.int32),) * 2
                 if collect_trace else None)
        return _zoom_in(graph, store, q, SearchStats.zeros(), trace=trace,
                        quant=quant)

    entry, entry_d, stats, zoom_trace = jax.vmap(zoom)(queries)
    rerank_rows = None
    if params.strategy == "iterative_scan":
        dk, ids, stats, trace0, rerank_rows = _frontier_iterative(
            graph, store, queries, bitmaps, params, entry, entry_d, stats,
            use_pallas, trace=zoom_trace)
    else:
        w_d, w_id, stats, trace0 = _frontier_base(
            graph, store, queries, bitmaps, params, entry, entry_d, stats,
            ef_result=params.ef_search, use_pallas=use_pallas,
            trace=zoom_trace, excl=excl)
        if quant == "sq8" and params.sq8_rerank:
            # exact full-precision rescore of the final beam — vmap of the
            # same per-query helper the legacy engine calls, so the two
            # engines stay bit-identical under sq8 too
            w_d, stats = jax.vmap(
                lambda q, wi, st: _rerank_beam(store, q, wi, st))(
                queries, w_id, stats)
            rerank_rows = w_id
        check = params.strategy in ("unfiltered",)
        dk, ids = jax.vmap(
            lambda wd, wi, bm: _finalize(wd, wi, bm, params.k,
                                         check_filter=not check))(
                                             w_d, w_id, bitmaps)
    if not collect_trace:
        return dk, ids, stats
    # heap_steps stamps zoom-in scored ∪ every superstep's newly scored
    # rows (first-touch superstep order); index_steps stamps adjacency
    # reads.  The sq8 rerank's full-width fetches are traced separately
    # (they hit the full-precision heap segment, not the shadow).
    trace = {"heap_steps": trace0[0], "index_steps": trace0[1]}
    if quant == "sq8" and rerank_rows is not None:
        trace["rerank_rows"] = rerank_rows
    return dk, ids, stats, trace


# ===========================================================================
# Externally stepped frontier driver (DESIGN.md §11).
#
# `search_batch` runs the superstep loop to completion inside one
# `lax.while_loop`.  Continuous batching needs the same loop *stepped from
# the outside* in fixed-hop chunks so a scheduler can retire finished lanes
# and admit waiting queries between chunks.  The contract that makes chunked
# execution bit-identical to the one-shot loop: the superstep body is an
# exact no-op on done lanes (pops suppressed via `drop_head=active`, all-INF
# merges are identity, counter increments masked), and each lane's
# trajectory depends only on its own row of the state — so the sequence of
# *effective* body applications per lane is the same no matter how the hops
# are chunked or which other lanes share the batch.
#
#   frontier_init       (Q, …) queries -> FrontierState (one compile per
#                       (Q, knobs) shape; the scheduler always calls it
#                       with Q=1 and writes the lane into the pool)
#   step_supersteps     advance every non-done lane up to n_hops supersteps
#   frontier_finalize   harvest ids/dists/stats/trace from the current state
#   frontier_write_slot splice a 1-lane state into slot `slot` of a pool
#   frontier_idle       an all-done pool to boot the scheduler from
# ===========================================================================


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class FrontierState:
    """Full per-lane frontier engine state, one row per slot.

    A pytree of (S, …) arrays: jitted steppers compile once per slot-count
    S and knob set, never per occupancy pattern.  `hs`/`is_` are the
    storage-trace stamp buffers ((S, n) int32 first-touch supersteps, or
    (S, 0) when tracing is off — the width doubles as the tracing flag).
    `deadline` is a per-lane anytime budget in modeled cycles (+inf = no
    deadline); it is data, not a compile-time knob, which is what lets one
    compiled stepper serve every deadline bucket.  `eff`/`rnd`/`checked`
    are the iterative_scan resume cursors (zeros for the base engine).
    `done` is the active-slot mask's complement: done lanes are frozen by
    the superstep bodies and can be harvested/replaced at any chunk edge.
    """
    queries: Array
    bitmaps: Array
    pool_d: Array
    pool_id: Array
    w_d: Array
    w_id: Array
    visited: Array
    hs: Array
    is_: Array
    stats: SearchStats
    deadline: Array
    eff: Array
    rnd: Array
    checked: Array
    done: Array


@partial(jax.jit, static_argnames=("params", "collect_trace"))
def _frontier_init_jit(graph, store, queries, bitmaps, deadline,
                       params: SearchParams, collect_trace: bool):
    n = graph.n
    qn = queries.shape[0]
    quant = params.graph_quant

    def zoom(q):
        trace = ((jnp.full((n,), TRACE_UNTOUCHED, jnp.int32),) * 2
                 if collect_trace else None)
        return _zoom_in(graph, store, q, SearchStats.zeros(), trace=trace,
                        quant=quant)

    entry, entry_d, stats, zoom_trace = jax.vmap(zoom)(queries)
    hs, is_ = zoom_trace if collect_trace else \
        (jnp.zeros((qn, 0), jnp.int32),) * 2
    if params.strategy == "iterative_scan":
        pool_d, pool_id, w_d, w_id, visited = _iter_state_init(
            graph, store, bitmaps, params, entry, entry_d)
        eff = jnp.full((qn,), params.batch_tuples, jnp.int32)
    else:
        pool_d, pool_id, w_d, w_id, visited = _base_state_init(
            graph, store, bitmaps, params, entry, entry_d, params.ef_search)
        eff = jnp.zeros((qn,), jnp.int32)
    return FrontierState(
        queries=queries, bitmaps=bitmaps, pool_d=pool_d, pool_id=pool_id,
        w_d=w_d, w_id=w_id, visited=visited, hs=hs, is_=is_, stats=stats,
        deadline=deadline, eff=eff, rnd=jnp.zeros((qn,), jnp.int32),
        checked=jnp.zeros((qn,), jnp.int32), done=jnp.zeros((qn,), bool))


def frontier_init(graph: HNSWGraph, store: VectorStore, queries, bitmaps,
                  params: SearchParams, collect_trace: bool = False,
                  deadlines=None) -> FrontierState:
    """Zoom-in + state init for the stepped frontier driver.

    Runs the same vmapped `_zoom_in` as `_frontier_search_batch` (upper
    HNSW layers, stats seeded with the zoom-in counters, trace stamps when
    `collect_trace`), then builds the engine state for `params.strategy`.
    `deadlines` is an optional per-query modeled-cycle budget ((Q,) float,
    +inf or None entries meaning "none"); it rides in the state as data so
    the stepper compiles once across deadline buckets (DESIGN.md §11).
    """
    if params.exclusion != "none":
        raise ValueError("exclusion pruning is not supported by the "
                         "stepped frontier driver (the excl radii block "
                         "does not ride in FrontierState); use the "
                         "one-shot search_batch path")
    qn = queries.shape[0]
    deadline = (jnp.full((qn,), jnp.inf, jnp.float32) if deadlines is None
                else jnp.asarray(deadlines, jnp.float32))
    return _frontier_init_jit(graph, store, queries, bitmaps, deadline,
                              params, collect_trace)


@partial(jax.jit,
         static_argnames=("params", "n_hops", "use_pallas",
                          "dynamic_deadline"))
def step_supersteps(graph: HNSWGraph, store: VectorStore,
                    state: FrontierState, params: SearchParams, n_hops: int,
                    use_pallas: bool = False,
                    dynamic_deadline: bool = False) -> FrontierState:
    """Advance every non-done lane by up to `n_hops` supersteps.

    The inner `lax.while_loop` exits early once every lane is done, so
    chunked execution applies the body the exact same number of effective
    times as the one-shot loop — chunk boundaries are unobservable in the
    results (bit-identical ids/dists/stats; tests/test_continuous.py).
    One jit cache entry per (slot-count, params, n_hops, flags) — the
    scheduler keeps `n_hops` fixed so the pool compiles once.

    `dynamic_deadline=True` additionally compares each lane's modeled
    cycles against `state.deadline` inside `_budget_over` (identical f32
    arithmetic to the static `params.deadline_cycles` path).  It is a
    static flag so deadline-free pools keep the jaxpr-identity guarantee
    of the budget-free loop.
    """
    tracing = state.hs.shape[1] > 0
    deadline = state.deadline if dynamic_deadline else None
    if params.strategy == "iterative_scan":
        body = partial(_iter_superstep, graph, store, state.queries,
                       state.bitmaps, params, use_pallas, tracing, deadline)
        tup = (state.pool_d, state.pool_id, state.w_d, state.w_id,
               state.visited, state.hs, state.is_, state.stats, state.eff,
               state.rnd, state.checked, state.done)
    else:
        body = partial(_base_superstep, graph, store, state.queries,
                       state.bitmaps, params, params.ef_search, use_pallas,
                       tracing, deadline, None)
        tup = (state.pool_d, state.pool_id, state.w_d, state.w_id,
               state.visited, state.hs, state.is_, state.stats, state.done)

    def cond(c):
        return (c[0] < n_hops) & ~c[1][-1].all()

    _, out = jax.lax.while_loop(cond, lambda c: (c[0] + 1, body(c[1])),
                                (jnp.int32(0), tup))
    if params.strategy == "iterative_scan":
        (pool_d, pool_id, w_d, w_id, visited, hs, is_, stats, eff, rnd,
         checked, done) = out
        return dataclasses.replace(
            state, pool_d=pool_d, pool_id=pool_id, w_d=w_d, w_id=w_id,
            visited=visited, hs=hs, is_=is_, stats=stats, eff=eff, rnd=rnd,
            checked=checked, done=done)
    pool_d, pool_id, w_d, w_id, visited, hs, is_, stats, done = out
    return dataclasses.replace(
        state, pool_d=pool_d, pool_id=pool_id, w_d=w_d, w_id=w_id,
        visited=visited, hs=hs, is_=is_, stats=stats, done=done)


@partial(jax.jit, static_argnames=("params",))
def frontier_finalize(graph: HNSWGraph, store: VectorStore,
                      state: FrontierState, params: SearchParams):
    """Harvest (dists, ids, stats, trace-or-None) from the current state.

    Runs the identical post-loop emit as `_frontier_search_batch`: sq8
    beams are exactly re-scored from the full-precision heap
    (`_rerank_beam` / `_iter_emit_sq8`) and results are top-k'd with the
    per-strategy filter check.  Pure function of the state — harvesting a
    pool mid-flight does not disturb lanes still running; the scheduler
    slices out the rows of lanes it is retiring.  The trace dict matches
    `search_batch(collect_trace=True)`: first-touch superstep stamps plus
    `rerank_rows` under sq8.
    """
    tracing = state.hs.shape[1] > 0
    quant = params.graph_quant
    stats = state.stats
    rerank_rows = None
    if params.strategy == "iterative_scan":
        efmax = params.batch_tuples * params.max_rounds
        if quant == "sq8" and params.sq8_rerank:
            r = min(params.k * params.reorder_factor, efmax)
            dk, out_ids, n_r, cand = jax.vmap(
                lambda q, wd, wi, bm, e: _iter_emit_sq8(
                    store, q, wd, wi, bm, e, params.k, r))(
                state.queries, state.w_d, state.w_id, state.bitmaps,
                state.eff)
            ppv_full = heap_pages_per_vector(store.dim)
            stats = SearchStats(
                stats.distance_comps + n_r, stats.filter_checks, stats.hops,
                stats.page_accesses_index,
                stats.page_accesses_heap + n_r * ppv_full,
                stats.tmap_lookups, stats.reorder_rows + n_r)
            rerank_rows = cand
        else:
            def emit(d, ids, bm, eff_q):
                in_batch = jnp.arange(efmax) < eff_q
                dm = jnp.where(in_batch, d, INF)
                im = jnp.where(in_batch, ids, -1)
                dk, pos = topk_smallest(
                    jnp.where(probe_bitmap(bm, im) & (im >= 0), dm, INF),
                    params.k)
                return dk, jnp.where(jnp.isinf(dk), -1, im[pos])

            dk, out_ids = jax.vmap(emit)(state.w_d, state.w_id,
                                         state.bitmaps, state.eff)
    else:
        w_d, w_id = state.w_d, state.w_id
        if quant == "sq8" and params.sq8_rerank:
            w_d, stats = jax.vmap(
                lambda q, wi, st: _rerank_beam(store, q, wi, st))(
                state.queries, w_id, stats)
            rerank_rows = w_id
        check = params.strategy in ("unfiltered",)
        dk, out_ids = jax.vmap(
            lambda wd, wi, bm: _finalize(wd, wi, bm, params.k,
                                         check_filter=not check))(
                                             w_d, w_id, state.bitmaps)
    if not tracing:
        return dk, out_ids, stats, None
    trace = {"heap_steps": state.hs, "index_steps": state.is_}
    if quant == "sq8" and rerank_rows is not None:
        trace["rerank_rows"] = rerank_rows
    return dk, out_ids, stats, trace


@jax.jit
def frontier_write_slot(state: FrontierState, lane: FrontierState,
                        slot) -> FrontierState:
    """Splice lane 0 of a width-1 state into row `slot` of a pool state.

    `slot` is a traced scalar, so admitting into any slot reuses one
    compiled entry.  Leaf-wise `dynamic_update_index_in_dim` over the
    pytree — every per-lane array (including the SearchStats leaves and
    the trace stamp rows) is replaced wholesale, so a freed slot carries
    nothing over from its previous occupant.
    """
    return jax.tree_util.tree_map(
        lambda dst, src: jax.lax.dynamic_update_index_in_dim(
            dst, src[0], slot, axis=0), state, lane)


def frontier_idle(graph: HNSWGraph, store: VectorStore,
                  params: SearchParams, width: int,
                  collect_trace: bool = False) -> FrontierState:
    """An all-done width-`width` pool state to boot a scheduler from.

    Built by running `frontier_init` on zero queries/empty bitmaps and
    marking every lane done — so the pool's array shapes (and therefore
    the stepper's compile key) are fixed before the first request arrives.
    Idle lanes are never stepped (done) and never harvested.
    """
    queries = jnp.zeros((width, store.dim), jnp.float32)
    bitmaps = jnp.zeros((width, bitset_words(store.n)), jnp.uint32)
    state = frontier_init(graph, store, queries, bitmaps, params,
                          collect_trace=collect_trace)
    return dataclasses.replace(state, done=jnp.ones((width,), bool))


# ===========================================================================
# Beam exchange (DESIGN.md §13) — the drift-mode synchronization point of
# the mesh-sharded traversal.  Between exchanges every shard runs plain
# supersteps on its induced subgraph (non-collective views); the exchange
# all-gathers the per-shard result beams, reduces them to the global top-ef,
# and re-seeds every shard's frontier from it.
# ===========================================================================


def beam_exchange(store, state: FrontierState, params: SearchParams,
                  axis: str) -> FrontierState:
    """All-gather the per-shard W beams and re-seed every lane from the
    global top-ef (base strategies only — iterative_scan's W is an
    emission buffer, not a beam, and is driven lockstep instead).

    A row id can appear in several shards' beams only after a previous
    exchange copied it, so duplicates always carry identical distances —
    the dedup keeps the first of each id group and drops the rest, never
    choosing between different values.  After the exchange:

      * W      := global top-ef beam (identical on every shard);
      * pool   := pool ∪ not-yet-visited beam entries (each shard may
                  resume expanding rows some other shard discovered —
                  their adjacency resolves to the local induced subgraph);
      * visited|= beam ids (their distances are already in W);
      * done   := the base-engine stop predicate re-evaluated against the
                  refreshed pool/W — a lane that had locally converged
                  revives when the global beam shows closer work.

    Collective volume: S·ef (distance f32 + id int32) per query per
    exchange — the `collective_bytes` term `costmodel` prices.
    """
    qn, ef = state.w_d.shape
    gd = jax.lax.all_gather(state.w_d, axis, axis=1)      # (Q, S, ef)
    gi = jax.lax.all_gather(state.w_id, axis, axis=1)
    fd = gd.reshape(qn, -1)
    fi = gi.reshape(qn, -1)
    order = jnp.argsort(fi, axis=-1)                      # group id copies
    sd = jnp.take_along_axis(fd, order, axis=-1)
    si = jnp.take_along_axis(fi, order, axis=-1)
    dup = jnp.concatenate(
        [jnp.zeros((qn, 1), bool), si[:, 1:] == si[:, :-1]], axis=1)
    keep = ~dup & (si >= 0)
    sd = jnp.where(keep, sd, INF)
    si = jnp.where(keep, si, -1)

    def one(dq, iq):
        nd, pos = topk_smallest(dq, ef)
        return nd, jnp.where(jnp.isinf(nd), -1, iq[pos])

    nwd, nwi = jax.vmap(one)(sd, si)
    seen = _probe_batch(state.visited, nwi)
    fresh = (nwi >= 0) & ~seen
    pool_d, pool_id = _merge_smallest(
        state.pool_d, state.pool_id,
        jnp.where(fresh, nwd, INF), jnp.where(fresh, nwi, -1))
    visited = _mark_batch(state.visited, nwi, fresh)
    we_idx = params.ef_search - 1
    stop = (pool_d[:, 0] > nwd[:, we_idx]) | jnp.isinf(pool_d[:, 0]) | \
        (state.stats.hops >= params.max_hops)
    over = _budget_over(state.stats, params, store.dim, None)
    if over is not None:
        stop = stop | over
    return dataclasses.replace(
        state, pool_d=pool_d, pool_id=pool_id, w_d=nwd, w_id=nwi,
        visited=visited, done=stop)
